//! Checkpointing: the coordinator's durable state is (round, theta,
//! per-worker EF residuals `e_t`, per-worker corrected gradients `p_t`).
//! Losing `e_t` silently degrades EF-SGD back to plain compression, and
//! losing `p_t` makes `ErrorFeedback::corrected()` read zeros after a
//! restore — so both are part of the checkpoint, not optimization caches.
//!
//! Format (`ef-sgd-checkpoint-v2`): `meta.json` + raw little-endian f32
//! blobs, one per tensor. v1 checkpoints (which lacked `p_t`) are rejected
//! with a clear error rather than half-restored.

use crate::util::json::{num, obj, s, Json};
use std::io::Write as _;
use std::path::{Path, PathBuf};

#[derive(Debug)]
pub enum CheckpointError {
    Io(std::io::Error),
    Json(crate::util::json::JsonError),
    Corrupt(String),
}

impl std::fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CheckpointError::Io(e) => write!(f, "io: {e}"),
            CheckpointError::Json(e) => write!(f, "json: {e}"),
            CheckpointError::Corrupt(msg) => write!(f, "corrupt checkpoint: {msg}"),
        }
    }
}

impl std::error::Error for CheckpointError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CheckpointError::Io(e) => Some(e),
            CheckpointError::Json(e) => Some(e),
            CheckpointError::Corrupt(_) => None,
        }
    }
}

impl From<std::io::Error> for CheckpointError {
    fn from(e: std::io::Error) -> Self {
        CheckpointError::Io(e)
    }
}

impl From<crate::util::json::JsonError> for CheckpointError {
    fn from(e: crate::util::json::JsonError) -> Self {
        CheckpointError::Json(e)
    }
}

/// On-disk format tag written to (and required in) `meta.json`.
pub const CHECKPOINT_FORMAT: &str = "ef-sgd-checkpoint-v2";

pub struct CheckpointStore {
    dir: PathBuf,
}

fn write_f32(path: &Path, data: &[f32]) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    let mut buf = Vec::with_capacity(data.len() * 4);
    for v in data {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    f.write_all(&buf)
}

fn read_f32(path: &Path, expect: usize) -> Result<Vec<f32>, CheckpointError> {
    // A tensor file named by meta.json but absent on disk is a corrupt
    // checkpoint (meta is written last, so a complete checkpoint has every
    // blob), not a transient IO condition — rejoin-from-checkpoint must
    // never half-restore.
    let bytes = match std::fs::read(path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Err(CheckpointError::Corrupt(format!(
                "missing tensor file {}",
                path.display()
            )))
        }
        Err(e) => return Err(CheckpointError::Io(e)),
    };
    if bytes.len() != expect * 4 {
        return Err(CheckpointError::Corrupt(format!(
            "{} has {} bytes, expected {}",
            path.display(),
            bytes.len(),
            expect * 4
        )));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// A full coordinator snapshot.
pub struct Snapshot {
    pub round: u64,
    /// Parameter-server shard count the run was trained with. Blockwise
    /// EF state only restores losslessly onto the same shard plan (the
    /// plan is a pure function of `(d, shards)`), so the driver's restore
    /// path checks this. Checkpoints written before sharding existed load
    /// as 1.
    pub shards: usize,
    /// Membership epoch at snapshot time: how many rounds with applied
    /// membership events precede this snapshot. Restore replays the seeded
    /// schedule and checks it against this value; churn-free checkpoints
    /// (and checkpoints from before elastic membership) load as 0.
    pub epoch: u64,
    pub theta: Vec<f32>,
    /// Per-worker EF residuals `e_t` (full-length: contiguous shards
    /// concatenate, so the tensor layout is plan-independent).
    pub worker_errors: Vec<Vec<f32>>,
    /// Per-worker corrected gradients `p_t = γg + e` of the last completed
    /// round (what the scaled-sign wire encoder reads for its ‖p‖₁/d
    /// scale). Same length/order as `worker_errors`.
    pub worker_corrected: Vec<Vec<f32>>,
}

impl CheckpointStore {
    pub fn new(dir: &Path) -> Result<Self, CheckpointError> {
        std::fs::create_dir_all(dir)?;
        Ok(CheckpointStore {
            dir: dir.to_path_buf(),
        })
    }

    pub fn save(&self, snap: &Snapshot) -> Result<(), CheckpointError> {
        assert_eq!(
            snap.worker_errors.len(),
            snap.worker_corrected.len(),
            "snapshot residuals/corrected out of sync"
        );
        write_f32(&self.dir.join("theta.f32"), &snap.theta)?;
        for (w, e) in snap.worker_errors.iter().enumerate() {
            write_f32(&self.dir.join(format!("error_{w}.f32")), e)?;
        }
        for (w, p) in snap.worker_corrected.iter().enumerate() {
            write_f32(&self.dir.join(format!("corrected_{w}.f32")), p)?;
        }
        let meta = obj(vec![
            ("round", num(snap.round as f64)),
            ("shards", num(snap.shards as f64)),
            ("epoch", num(snap.epoch as f64)),
            ("d", num(snap.theta.len() as f64)),
            ("workers", num(snap.worker_errors.len() as f64)),
            ("format", s(CHECKPOINT_FORMAT)),
        ]);
        // write meta last: its presence marks the checkpoint complete
        std::fs::write(self.dir.join("meta.json"), meta.to_string_compact())?;
        Ok(())
    }

    pub fn load(&self) -> Result<Snapshot, CheckpointError> {
        let meta_text = std::fs::read_to_string(self.dir.join("meta.json"))?;
        let meta = Json::parse(&meta_text)?;
        let format = meta
            .get("format")
            .and_then(|v| v.as_str().map(|s| s.to_string()))
            .unwrap_or_default();
        if format != CHECKPOINT_FORMAT {
            return Err(CheckpointError::Corrupt(format!(
                "checkpoint format '{format}' unsupported (expected '{CHECKPOINT_FORMAT}'): \
                 pre-v2 checkpoints lack the corrected gradients and cannot be \
                 restored losslessly; re-create the checkpoint"
            )));
        }
        let d = meta
            .get("d")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| CheckpointError::Corrupt("missing d".into()))?;
        let workers = meta
            .get("workers")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| CheckpointError::Corrupt("missing workers".into()))?;
        let round = meta.get("round").and_then(|v| v.as_usize()).unwrap_or(0) as u64;
        // checkpoints from before the sharded parameter server carry no
        // shard count; they were trained single-leader
        let shards = meta.get("shards").and_then(|v| v.as_usize()).unwrap_or(1);
        // checkpoints from before elastic membership carry no epoch; they
        // were trained churn-free
        let epoch = meta.get("epoch").and_then(|v| v.as_usize()).unwrap_or(0) as u64;
        let theta = read_f32(&self.dir.join("theta.f32"), d)?;
        let worker_errors = (0..workers)
            .map(|w| read_f32(&self.dir.join(format!("error_{w}.f32")), d))
            .collect::<Result<Vec<_>, _>>()?;
        let worker_corrected = (0..workers)
            .map(|w| read_f32(&self.dir.join(format!("corrected_{w}.f32")), d))
            .collect::<Result<Vec<_>, _>>()?;
        Ok(Snapshot {
            round,
            shards,
            epoch,
            theta,
            worker_errors,
            worker_corrected,
        })
    }

    pub fn exists(&self) -> bool {
        self.dir.join("meta.json").exists()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("efsgd_ckpt_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn roundtrip() {
        let dir = tmpdir("rt");
        let store = CheckpointStore::new(&dir).unwrap();
        assert!(!store.exists());
        let snap = Snapshot {
            round: 42,
            shards: 4,
            epoch: 3,
            theta: vec![1.0, -2.0, 3.0],
            worker_errors: vec![vec![0.1, 0.2, 0.3], vec![-0.1, 0.0, 0.5]],
            worker_corrected: vec![vec![1.1, 1.2, 1.3], vec![-1.1, 0.0, -0.5]],
        };
        store.save(&snap).unwrap();
        assert!(store.exists());
        let loaded = store.load().unwrap();
        assert_eq!(loaded.round, 42);
        assert_eq!(loaded.shards, 4);
        assert_eq!(loaded.epoch, 3);
        assert_eq!(loaded.theta, snap.theta);
        assert_eq!(loaded.worker_errors, snap.worker_errors);
        assert_eq!(loaded.worker_corrected, snap.worker_corrected);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_sizes_detected() {
        let dir = tmpdir("bad");
        let store = CheckpointStore::new(&dir).unwrap();
        let snap = Snapshot {
            round: 1,
            shards: 1,
            epoch: 0,
            theta: vec![1.0; 8],
            worker_errors: vec![vec![0.0; 8]],
            worker_corrected: vec![vec![0.0; 8]],
        };
        store.save(&snap).unwrap();
        // truncate a blob
        std::fs::write(dir.join("error_0.f32"), [0u8; 4]).unwrap();
        assert!(matches!(
            store.load(),
            Err(CheckpointError::Corrupt(_))
        ));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v1_format_rejected_with_clear_error() {
        let dir = tmpdir("v1");
        let store = CheckpointStore::new(&dir).unwrap();
        let snap = Snapshot {
            round: 2,
            shards: 1,
            epoch: 0,
            theta: vec![1.0; 4],
            worker_errors: vec![vec![0.0; 4]],
            worker_corrected: vec![vec![0.0; 4]],
        };
        store.save(&snap).unwrap();
        // rewrite meta as a v1 checkpoint (no corrected gradients)
        let meta = obj(vec![
            ("round", num(2.0)),
            ("d", num(4.0)),
            ("workers", num(1.0)),
            ("format", s("ef-sgd-checkpoint-v1")),
        ]);
        std::fs::write(dir.join("meta.json"), meta.to_string_compact()).unwrap();
        let err = match store.load() {
            Err(e) => e,
            Ok(_) => panic!("v1 checkpoint must be rejected"),
        };
        match err {
            CheckpointError::Corrupt(msg) => {
                assert!(msg.contains("ef-sgd-checkpoint-v1"), "msg: {msg}");
                assert!(msg.contains("re-create"), "msg: {msg}");
            }
            other => panic!("expected Corrupt, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn meta_without_shards_loads_as_single_leader() {
        let dir = tmpdir("noshard");
        let store = CheckpointStore::new(&dir).unwrap();
        let snap = Snapshot {
            round: 3,
            shards: 2,
            epoch: 0,
            theta: vec![1.0; 4],
            worker_errors: vec![vec![0.0; 4]],
            worker_corrected: vec![vec![0.0; 4]],
        };
        store.save(&snap).unwrap();
        // rewrite meta without the shards key (a pre-sharding checkpoint)
        let meta = obj(vec![
            ("round", num(3.0)),
            ("d", num(4.0)),
            ("workers", num(1.0)),
            ("format", s(CHECKPOINT_FORMAT)),
        ]);
        std::fs::write(dir.join("meta.json"), meta.to_string_compact()).unwrap();
        let loaded = store.load().unwrap();
        assert_eq!(loaded.shards, 1);
        // pre-membership checkpoints also carry no epoch key
        assert_eq!(loaded.epoch, 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    fn seeded_store(tag: &str) -> (PathBuf, CheckpointStore) {
        let dir = tmpdir(tag);
        let store = CheckpointStore::new(&dir).unwrap();
        let snap = Snapshot {
            round: 5,
            shards: 2,
            epoch: 1,
            theta: vec![0.5; 16],
            worker_errors: vec![vec![0.25; 16], vec![-0.25; 16]],
            worker_corrected: vec![vec![1.0; 16], vec![-1.0; 16]],
        };
        store.save(&snap).unwrap();
        (dir, store)
    }

    #[test]
    fn missing_tensor_file_is_corrupt_not_io() {
        // Rejoin-from-checkpoint runs load on the hot path: a checkpoint
        // whose meta names a blob that is gone must be Corrupt (with the
        // path in the message), never a panic or a half-restore.
        for victim in ["theta.f32", "error_1.f32", "corrected_0.f32"] {
            let (dir, store) = seeded_store("missing_blob");
            std::fs::remove_file(dir.join(victim)).unwrap();
            match store.load() {
                Err(CheckpointError::Corrupt(msg)) => {
                    assert!(msg.contains(victim), "victim {victim}: {msg}")
                }
                other => panic!("victim {victim}: expected Corrupt, got {other:?}"),
            }
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn meta_blob_length_mismatch_is_corrupt() {
        // meta claims a larger d than the blobs hold
        let (dir, store) = seeded_store("meta_mismatch");
        let meta = obj(vec![
            ("round", num(5.0)),
            ("shards", num(2.0)),
            ("epoch", num(1.0)),
            ("d", num(32.0)),
            ("workers", num(2.0)),
            ("format", s(CHECKPOINT_FORMAT)),
        ]);
        std::fs::write(dir.join("meta.json"), meta.to_string_compact()).unwrap();
        assert!(matches!(store.load(), Err(CheckpointError::Corrupt(_))));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn prop_truncated_blobs_always_corrupt_never_panic() {
        // Property: truncating any tensor blob to any shorter length
        // (including lengths that are not multiples of 4) yields Corrupt —
        // load never panics and never half-restores.
        let mut rng = crate::util::Pcg64::seeded(0xC0FFEE);
        let (dir, store) = seeded_store("prop_trunc");
        let blobs = ["theta.f32", "error_0.f32", "corrected_1.f32"];
        let full = 16 * 4;
        let mut cuts: Vec<usize> = vec![0, 1, 3, 4, full - 4, full - 1];
        for _ in 0..10 {
            cuts.push(rng.below(full));
        }
        for blob in blobs {
            let pristine = std::fs::read(dir.join(blob)).unwrap();
            assert_eq!(pristine.len(), full);
            for &cut in &cuts {
                std::fs::write(dir.join(blob), &pristine[..cut]).unwrap();
                match store.load() {
                    Err(CheckpointError::Corrupt(_)) => {}
                    other => panic!("{blob} truncated to {cut}: expected Corrupt, got {other:?}"),
                }
            }
            // over-long blobs are corrupt too
            let mut long = pristine.clone();
            long.extend_from_slice(&[0u8; 4]);
            std::fs::write(dir.join(blob), &long).unwrap();
            assert!(matches!(store.load(), Err(CheckpointError::Corrupt(_))));
            std::fs::write(dir.join(blob), &pristine).unwrap();
            // restored blob loads again — no state was half-mutated
            store.load().unwrap();
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_dir_load_fails() {
        let dir = tmpdir("missing");
        let store = CheckpointStore::new(&dir).unwrap();
        assert!(store.load().is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
