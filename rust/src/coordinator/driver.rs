//! The training driver: the leader's event loop gluing the worker pool,
//! fabric, aggregation, LR schedule, checkpointing and metrics.
//!
//! The leader never touches a `Worker` directly: workers live on the
//! [`WorkerPool`] threads and everything flows through channels and the
//! shared fabric. Gathers and reports are ordered by worker id, which
//! makes the training trajectory bit-identical for any thread count (see
//! the module docs of [`crate::coordinator`]).

use super::aggregate::{Aggregation, DecodeScratch};
use super::cost::DecodeCostModel;
use super::pool::{RoundReport, WorkerPool, WorkerState};
use super::round::{LeaderProfile, LrSchedule, RoundClock, StalenessStats};
use super::state::{CheckpointStore, Snapshot};
use super::worker::Worker;
use crate::collectives::{ShardPlan, ShardedParameterServer};
use crate::compress::wire::Encoded;
use crate::metrics::Recorder;
use crate::net::{
    AdversarySchedule, Fabric, LinkDiscipline, LinkModel, MembershipEvent, MembershipEventKind,
    MembershipSchedule, MembershipState, Message, SimClock, StragglerSchedule, TrafficStats,
};
use crate::obs::metrics::RunMetrics;
use crate::obs::trace::{DropReason, EventKind, TraceRecorder};
use std::sync::Arc;

/// How the leader turns the aggregate into a parameter update.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdateRule {
    /// x ← x − agg (workers already applied γ inside their EF step).
    ApplyAggregate,
    /// x ← x − γ·agg (workers sent γ-free vectors: sign votes, raw grads
    /// for plain SGD).
    ScaleByLr,
    /// Server-side momentum on the mean raw gradient (the SGDM baseline):
    /// m ← g + βm; x ← x − γm.
    ServerMomentum { beta_millis: u32 },
}

/// Everything the driver needs besides the workers.
pub struct DriverConfig {
    pub steps: usize,
    pub schedule: LrSchedule,
    pub aggregation: Aggregation,
    pub update_rule: UpdateRule,
    pub weight_decay: f32,
    pub link: LinkModel,
    /// How each node's sends share its physical link. The default
    /// ([`LinkDiscipline::Overlapped`]) prices every send independently —
    /// the historical infinite-fan-out model, under which all existing
    /// timing identities hold. [`LinkDiscipline::Serialized`] queues a
    /// node's sends FIFO on its uplink (`max(node_time, link_free_time)`;
    /// see `docs/WIRE.md`), so a worker's S per-shard pushes serialize.
    pub discipline: LinkDiscipline,
    /// Analytic leader decode-cost model. Disabled
    /// ([`DecodeCostModel::none`], the default) the drivers charge the
    /// *measured* decode wall-clock ([`LeaderProfile`]); enabled, the
    /// leader term of `sim_time_s` becomes
    /// `Σ_rounds max_shards Σ_frames frame_cost(format, d)` — a pure
    /// function of the seeded models, reproducible across machines.
    pub leader_cost: DecodeCostModel,
    /// Per-(worker, step) virtual compute-time model. The default charges
    /// zero compute, which reproduces the historical engine where only
    /// link time was priced; the async driver and the straggler sweeps
    /// set a real base time.
    pub straggler: StragglerSchedule,
    /// Byzantine worker model: which workers are hostile and what they
    /// put on the wire (see [`crate::net::adversary`]). The default
    /// ([`AdversarySchedule::none`]) corrupts nothing and is
    /// byte-identical to the pre-adversary engine.
    pub adversary: AdversarySchedule,
    /// Elastic-membership churn schedule (see [`crate::net::membership`]):
    /// seeded leave/crash/rejoin/join events applied at round starts. The
    /// default ([`MembershipSchedule::none`]) schedules nothing and is
    /// byte-identical to the fixed-fleet engine — every churn code path is
    /// gated on `membership.is_active()`.
    pub membership: MembershipSchedule,
    /// Worker-pool threads (clamped to 1..=workers; 1 = sequential).
    pub threads: usize,
    /// Parameter-server shards: the model vector splits into this many
    /// contiguous coordinate blocks, each with its own leader node
    /// (clamped to 1..=d). 1 = the single-leader topology, byte-identical
    /// to the historical engine.
    pub shards: usize,
    pub log_every: usize,
    pub eval_every: usize,
    /// Save a checkpoint every N rounds (0 = never).
    pub checkpoint_every: usize,
    pub checkpoint_dir: Option<std::path::PathBuf>,
    /// Flight-recorder ring capacity per node, in events (0 = tracing off;
    /// no recorder is built and the engine is byte-identical to the
    /// untraced one). See [`crate::obs::trace`].
    pub trace_capacity: usize,
    /// Unified metrics registry shared with the caller (`None` = no metric
    /// updates on the round path). See [`crate::obs::metrics`].
    pub metrics: Option<Arc<RunMetrics>>,
}

impl Default for DriverConfig {
    fn default() -> Self {
        DriverConfig {
            steps: 100,
            schedule: LrSchedule::constant(0.1),
            aggregation: Aggregation::Mean,
            update_rule: UpdateRule::ApplyAggregate,
            weight_decay: 0.0,
            link: LinkModel::default(),
            discipline: LinkDiscipline::Overlapped,
            leader_cost: DecodeCostModel::none(),
            straggler: StragglerSchedule::none(),
            adversary: AdversarySchedule::none(),
            membership: MembershipSchedule::none(),
            threads: 1,
            shards: 1,
            log_every: 0,
            eval_every: 0,
            checkpoint_every: 0,
            checkpoint_dir: None,
            trace_capacity: 0,
            metrics: None,
        }
    }
}

/// Result of a training run.
pub struct TrainOutcome {
    pub theta: Vec<f32>,
    pub recorder: Recorder,
    pub traffic: TrafficStats,
    pub rounds: u64,
    /// Wall-clock profile of the leader's decode+aggregate hot path.
    pub profile: LeaderProfile,
    /// Total simulated (virtual-clock) time of the run: broadcast +
    /// compute + gather + the leaders' measured decode+aggregate critical
    /// path. Both drivers keep the measured leader cost out of the event
    /// schedule (it is added only to this reported total), so the schedule
    /// — and with it the flight-recorder trace — stays bit-deterministic.
    pub sim_time_s: f64,
    /// Bounded-staleness accounting (all-zero for synchronous runs).
    pub staleness: StalenessStats,
    /// The flight recorder, when `DriverConfig::trace_capacity > 0`.
    pub trace: Option<Arc<TraceRecorder>>,
}

/// Apply the leader's parameter update for one aggregate. Shared verbatim
/// between the synchronous and async drivers so `--max-staleness 0
/// --quorum n` is bit-identical to the sync engine by construction (same
/// f32 expressions, same order).
pub(crate) fn apply_update(
    rule: UpdateRule,
    lr: f32,
    weight_decay: f32,
    agg: &[f32],
    theta: &mut [f32],
    momentum: &mut [f32],
    wd_buf: &mut [f32],
) {
    match rule {
        UpdateRule::ApplyAggregate => {
            crate::tensor::sub_assign(theta, agg);
        }
        UpdateRule::ScaleByLr => {
            crate::tensor::axpy(-lr, agg, theta);
        }
        UpdateRule::ServerMomentum { beta_millis } => {
            let beta = beta_millis as f32 / 1000.0;
            // fused momentum update + apply: one pass, no clone of the
            // full parameter-sized momentum vector per step
            for ((t, m), g) in theta.iter_mut().zip(momentum.iter_mut()).zip(agg) {
                *m = g + beta * *m;
                *t -= lr * *m;
            }
        }
    }
    // decoupled weight decay on the iterate
    if weight_decay > 0.0 {
        wd_buf.copy_from_slice(theta);
        crate::tensor::axpy(-lr * weight_decay, wd_buf, theta);
    }
}

/// Build the (possibly sharded) topology shared verbatim by the sync and
/// async drivers: derive the shard plan (the plan's clamp to
/// `1..=min(d, u16::MAX)` is the single source of truth for the effective
/// shard count), re-partition the workers' compressor/EF state when
/// sharded, and size the clock + fabric at `workers + shards` nodes. Kept
/// in one place so the two engines can never desynchronize on layout —
/// the async-degenerate-equals-sync contract depends on it.
pub(crate) fn build_topology(
    cfg: &DriverConfig,
    workers: &mut [Worker],
) -> (
    Arc<SimClock>,
    Arc<Fabric>,
    ShardedParameterServer,
    Option<Arc<TraceRecorder>>,
) {
    let d = workers[0].dim();
    let plan = ShardPlan::new(d, cfg.shards);
    let shards = plan.num_shards();
    if shards > 1 {
        // blockwise compressor/EF state; untouched for the single shard
        // so the historical pipeline stays byte-identical
        for w in workers.iter_mut() {
            w.set_shard_plan(plan.clone());
        }
    }
    let nodes = workers.len() + shards;
    let sim_clock = Arc::new(SimClock::new(nodes));
    let trace = (cfg.trace_capacity > 0)
        .then(|| Arc::new(TraceRecorder::new(workers.len(), shards, cfg.trace_capacity)));
    let mut fabric = Fabric::with_clock(nodes, cfg.link, sim_clock.clone());
    fabric.set_discipline(cfg.discipline);
    if let Some(tr) = &trace {
        fabric.set_trace(tr.clone());
    }
    let fabric = Arc::new(fabric);
    let ps = ShardedParameterServer::new(&fabric, plan);
    (sim_clock, fabric, ps, trace)
}

/// Persist a snapshot to `dir` if checkpointing is configured (shared by
/// the sync and async drivers).
pub(crate) fn save_checkpoint(dir: Option<&std::path::Path>, snap: &Snapshot) {
    let Some(dir) = dir else {
        return;
    };
    let store = CheckpointStore::new(dir).expect("checkpoint dir");
    store.save(snap).expect("checkpoint save");
}

/// The coordinator driver.
pub struct TrainDriver {
    cfg: DriverConfig,
    pool: WorkerPool,
    theta: Vec<f32>,
    fabric: Arc<Fabric>,
    sim_clock: Arc<SimClock>,
    ps: ShardedParameterServer,
    clock: RoundClock,
    momentum: Vec<f32>,
    wd_buf: Vec<f32>,
    profile: LeaderProfile,
    sim_time: f64,
    /// Accumulated analytic leader cost (Σ rounds of max-over-shards
    /// modeled decode time); only meaningful when
    /// `cfg.leader_cost.is_enabled()`.
    model_leader_s: f64,
    /// Flight recorder (also reachable by the pool via the fabric).
    trace: Option<Arc<TraceRecorder>>,
    /// Metrics registry shared with the caller.
    metrics: Option<Arc<RunMetrics>>,
    /// Last sighting of the fabric's dropped-frame counter, for per-round
    /// deltas into the trace/metrics (decode drops happen on pool threads,
    /// which never write rings directly).
    last_dropped: u64,
    /// Elastic-membership state: live bitmap + epoch. Stays at "all live,
    /// epoch 0" forever when `cfg.membership` is inactive.
    membership: MembershipState,
    /// Live worker ids for the current epoch, ascending. Initialized to
    /// the full fleet and refreshed only when an epoch transition fires,
    /// so churn-free rounds never touch it.
    live_ids: Vec<usize>,
    /// Copy of the round's `events_at` slice (releases the borrow on
    /// `cfg.membership` before the events mutate driver state).
    event_scratch: Vec<MembershipEvent>,
    // --- persistent round scratch (the zero-alloc steady state of
    // docs/PERF.md: after round 1 every buffer below is warm and the
    // round loop performs no heap allocation) ---
    /// Shared broadcast slices, refreshed in place each round
    /// (`ShardedParameterServer::make_broadcast`).
    bcast: Vec<Arc<[f32]>>,
    /// Per-worker round reports, refilled by `WorkerPool::round_into`.
    reports: Vec<RoundReport>,
    /// Raw gather drain buffer.
    msgs: Vec<(Message, f64)>,
    /// Per-shard gathered frames.
    frames_by_shard: Vec<Vec<Encoded>>,
    /// The round's aggregate.
    agg: Vec<f32>,
    /// Fused-decode scratch (groups, recycled partials, shard timings).
    scratch: DecodeScratch,
}

impl TrainDriver {
    pub fn new(cfg: DriverConfig, mut workers: Vec<Worker>, theta0: Vec<f32>) -> Self {
        assert!(!workers.is_empty());
        let d = workers[0].dim();
        assert!(workers.iter().all(|w| w.dim() == d));
        assert_eq!(theta0.len(), d);
        let (sim_clock, fabric, ps, trace) = build_topology(&cfg, &mut workers);
        let pool = WorkerPool::spawn_with_adversary(
            workers,
            fabric.clone(),
            cfg.threads.max(1),
            cfg.adversary.clone(),
        );
        let frames_by_shard = (0..ps.num_shards()).map(|_| Vec::new()).collect();
        let metrics = cfg.metrics.clone();
        if cfg.membership.is_active() {
            if let Err(e) = cfg.membership.validate(pool.n_workers()) {
                panic!("invalid membership schedule: {e}");
            }
        }
        let membership = MembershipState::new(pool.n_workers());
        let mut live_ids = Vec::with_capacity(pool.n_workers());
        membership.live_ids_into(&mut live_ids);
        TrainDriver {
            momentum: vec![0.0; d],
            wd_buf: vec![0.0; d],
            cfg,
            pool,
            theta: theta0,
            fabric,
            sim_clock,
            ps,
            clock: RoundClock::default(),
            profile: LeaderProfile::default(),
            sim_time: 0.0,
            model_leader_s: 0.0,
            trace,
            metrics,
            last_dropped: 0,
            membership,
            live_ids,
            event_scratch: Vec::new(),
            bcast: Vec::new(),
            reports: Vec::new(),
            msgs: Vec::new(),
            frames_by_shard,
            agg: vec![0.0; d],
            scratch: DecodeScratch::default(),
        }
    }

    pub fn theta(&self) -> &[f32] {
        &self.theta
    }

    pub fn rounds(&self) -> u64 {
        self.clock.current()
    }

    /// Snapshot of the fabric's traffic accounting so far (deep clone —
    /// end-of-run reporting; the round loop itself reads the lock-free
    /// `Fabric::total_bits`).
    pub fn traffic(&self) -> TrafficStats {
        self.fabric.snapshot_stats()
    }

    /// Wall-clock profile of the leader's decode+aggregate hot path.
    pub fn profile(&self) -> &LeaderProfile {
        &self.profile
    }

    /// Total simulated time consumed so far (virtual clock): per round,
    /// the parameter broadcast, the slowest worker's compute (per the
    /// straggler schedule), its gradient push, and the slowest shard
    /// leader's measured decode+aggregate all happen in sequence. The
    /// leader term closes the ROADMAP "async leader compute cost" gap:
    /// leader decode is no longer free in simulated time. The measured
    /// term is accumulated separately (`LeaderProfile::critical_s`) and
    /// only added here, mirroring the async driver's `leader_time_s`, so
    /// the event schedule — and the flight-recorder trace stamped from it
    /// — stays a pure function of the seeded models. With a
    /// [`DecodeCostModel`] configured the measured term is replaced by the
    /// analytic one, making the whole total machine-independent.
    pub fn sim_time_s(&self) -> f64 {
        self.sim_time + self.leader_term_s()
    }

    /// The leader term of `sim_time_s`: modeled when a cost model is
    /// enabled, measured otherwise.
    fn leader_term_s(&self) -> f64 {
        if self.cfg.leader_cost.is_enabled() {
            self.model_leader_s
        } else {
            self.profile.critical_s
        }
    }

    /// Per-worker EF states (fetched from the pool threads), by worker id.
    pub fn worker_states(&self) -> Vec<WorkerState> {
        self.pool.export_states()
    }

    /// Full coordinator snapshot (what [`restore`](Self::restore) takes).
    pub fn snapshot(&self) -> Snapshot {
        let states = self.pool.export_states();
        Snapshot {
            round: self.clock.current(),
            shards: self.ps.num_shards(),
            epoch: self.membership.epoch(),
            theta: self.theta.clone(),
            worker_errors: states.iter().map(|s| s.error.clone()).collect(),
            worker_corrected: states.into_iter().map(|s| s.corrected).collect(),
        }
    }

    /// Resume from a checkpoint: restores theta and per-worker EF state
    /// (residual `e` and corrected gradient `p`). The snapshot must come
    /// from the same shard plan — blockwise EF state is only meaningful on
    /// the split it was trained with.
    pub fn restore(&mut self, snap: &Snapshot) {
        assert_eq!(
            snap.shards,
            self.ps.num_shards(),
            "checkpoint was trained with a different shard count"
        );
        assert_eq!(snap.theta.len(), self.theta.len());
        assert_eq!(snap.worker_errors.len(), self.pool.n_workers());
        assert_eq!(snap.worker_corrected.len(), self.pool.n_workers());
        self.theta.copy_from_slice(&snap.theta);
        let states: Vec<WorkerState> = snap
            .worker_errors
            .iter()
            .zip(&snap.worker_corrected)
            .enumerate()
            .map(|(id, (e, p))| WorkerState {
                id,
                steps: snap.round,
                error: e.clone(),
                corrected: p.clone(),
            })
            .collect();
        self.pool.restore_states(states);
        while self.clock.current() < snap.round {
            self.clock.advance();
        }
        if self.cfg.membership.is_active() {
            // Replay the schedule up to the snapshot round so the live set
            // and epoch resume exactly where the checkpointing run stood.
            // Crash-departed workers got their (stale) snapshot state back
            // above; their rejoin event re-zeroes it, same as the original
            // run. Pre-membership checkpoints carry epoch 0, which replay
            // reproduces only when no event fired before the snapshot —
            // the debug assert catches schedule/checkpoint mismatches.
            self.membership =
                MembershipState::replay(&self.cfg.membership, self.pool.n_workers(), snap.round);
            debug_assert_eq!(
                self.membership.epoch(),
                snap.epoch,
                "checkpoint membership epoch disagrees with schedule replay"
            );
            self.membership.live_ids_into(&mut self.live_ids);
        }
    }

    fn checkpoint(&self) {
        save_checkpoint(self.cfg.checkpoint_dir.as_deref(), &self.snapshot());
    }

    /// One synchronous round. Returns the mean worker training loss.
    /// Steady-state allocation-free: every buffer involved is persistent
    /// driver scratch or cycles through a recycle pool (asserted by the
    /// `alloc_regression` integration test).
    pub fn round(&mut self, recorder: &mut Recorder) -> f64 {
        let step = self.clock.current();
        let lr = self.cfg.schedule.lr(step as usize) as f32;
        let churn = self.cfg.membership.is_active();
        if churn {
            // membership events apply at the *start* of the round, before
            // any wire traffic: a worker departing at round R never sees
            // round R's broadcast
            self.apply_membership(step);
        }
        let live = self.live_ids.len();

        if let Some(tr) = &self.trace {
            let t = self.sim_time;
            tr.record(tr.driver_track(), t, step, EventKind::RoundStart, live as u64);
            for s in 0..self.ps.num_shards() {
                tr.record(tr.leader_track(s), t, step, EventKind::BroadcastSent, s as u64);
            }
        }

        // 1. broadcast parameters from every shard leader (accounted;
        // arrivals stamped from the leaders' shared virtual time — the
        // sync engine keeps all shard leaders in lock-step). The shared
        // slices are refreshed in place: one copy of θ per round plus a
        // refcount bump per (worker, shard) — never a dense clone per
        // worker.
        for &l in &self.ps.leaders {
            self.sim_clock.set_node_time(l, self.sim_time);
        }
        self.ps.make_broadcast(&self.theta, &mut self.bcast);
        let params_arrival = if churn {
            // live-set broadcast: the same per-worker sends as
            // `broadcast_shared`, restricted to the live ids (ascending —
            // the identical wire schedule while nobody has departed)
            let mut latest = 0.0f64;
            for &w in &self.live_ids {
                latest = latest.max(self.ps.send_params_shared(&self.fabric, w, step, &self.bcast));
            }
            latest
        } else {
            self.ps.broadcast_shared(&self.fabric, step, &self.bcast)
        };
        // each worker's push departs once its (straggler-model) compute
        // finishes, so the frames the pool is about to send get stamped
        // with honest virtual arrival times (`live_ids` is the full fleet
        // whenever churn is off)
        for &w in &self.live_ids {
            let finish = params_arrival + self.cfg.straggler.compute_time(w, step);
            self.sim_clock.set_node_time(w, finish);
        }

        // 2-3. pool: every live worker drains its broadcast, computes, EF-
        // compresses, and pushes one encoded frame per shard leader (the
        // frame buffers come from the fabric's recycle pool). Departed
        // workers keep their actors — and, after a graceful leave, their
        // parked EF residual — but are never stepped.
        if churn {
            self.reports = self.pool.step_workers(&self.live_ids, step, lr);
        } else {
            self.pool.round_into(step, lr, &mut self.reports);
        }
        let mean_loss = self.reports.iter().map(|r| r.loss).sum::<f64>() / live as f64;

        // 4. shard leaders: gather, decode, aggregate, update. Each shard
        // sorts its frames by source so the f32 aggregation order is
        // independent of thread scheduling; the per-frame decode then fans
        // out across the pool threads in fixed worker-id groups (see
        // [`super::aggregate::decode_groups`]), fused straight into
        // recycled partial-sum buffers — no dense `Vec<f32>` per worker.
        let s_total = self.ps.num_shards();
        let mut round_end = self.sim_time;
        for s in 0..s_total {
            let latest = self
                .ps
                .gather_shard_expecting(
                    &self.fabric,
                    step,
                    s,
                    &mut self.msgs,
                    &mut self.frames_by_shard[s],
                    live,
                )
                .unwrap_or_else(|e| panic!("PS gather failed: {e}"));
            round_end = round_end.max(latest);
        }
        // shard-mismatch drops were traced individually inside the gather;
        // absorb them into the drop-counter baseline now so the
        // post-combine delta below is undecodable-only
        self.note_dropped(round_end, step, false);
        // frame-size metrics must run before the combine drains the frames
        if let Some(m) = &self.metrics {
            for frames in &self.frames_by_shard {
                for f in frames {
                    m.observe_frame(f.format, f.bits);
                }
            }
        }
        // analytic leader pricing: also reads (format, d) off the gathered
        // frames before the combine drains them. Shard leaders decode
        // concurrently, so the round charges the slowest shard.
        if self.cfg.leader_cost.is_enabled() {
            let mut worst = 0.0f64;
            for frames in &self.frames_by_shard {
                let mut shard_cost = 0.0f64;
                for f in frames {
                    shard_cost += self.cfg.leader_cost.frame_cost(f.format, f.d);
                }
                worst = worst.max(shard_cost);
            }
            self.model_leader_s += worst;
        }
        if let Some(tr) = &self.trace {
            tr.record(tr.driver_track(), round_end, step, EventKind::DecodeStart, live as u64);
        }
        // the synchronous barrier: every shard has every frame
        self.cfg.aggregation.combine_frames_sharded_into(
            &mut self.frames_by_shard,
            &self.ps.plan,
            &self.pool,
            &mut self.agg,
            &mut self.scratch,
        );
        // leader compute is priced on the virtual clock: the shard leaders
        // decode concurrently in the simulated deployment, so the round is
        // extended by the slowest one (max over shards = the critical path
        // the sharding shrinks). The measured term accumulates in the
        // profile and is added to the *reported* total only
        // (`sim_time_s`), never to the schedule itself: the schedule — and
        // the trace stamped from it — stays a pure function of the seeded
        // models, byte-identical across thread counts.
        let critical = self.profile.record_shards(&self.scratch.shard_times);
        self.sim_time = round_end;
        self.note_dropped(round_end, step, true);
        if let Some(m) = &self.metrics {
            m.inc_rounds();
            m.observe_decode_ns((critical * 1e9) as u64);
        }
        if let Some(tr) = &self.trace {
            tr.record(tr.driver_track(), round_end, step, EventKind::DecodeDone, live as u64);
        }

        apply_update(
            self.cfg.update_rule,
            lr,
            self.cfg.weight_decay,
            &self.agg,
            &mut self.theta,
            &mut self.momentum,
            &mut self.wd_buf,
        );

        // instrumentation (reports are sorted by worker id)
        recorder.record("train_loss", step, mean_loss);
        recorder.record("lr", step, lr as f64);
        let mean_err = self.reports.iter().map(|r| r.error_norm).sum::<f64>() / live as f64;
        recorder.record("error_norm", step, mean_err);
        let mean_phi = self.reports.iter().map(|r| r.phi).sum::<f64>() / live as f64;
        recorder.record("phi_corrected", step, mean_phi);
        let mean_phi_g = self.reports.iter().map(|r| r.grad_density).sum::<f64>() / live as f64;
        recorder.record("phi_grad", step, mean_phi_g);
        if let Some(m) = &self.metrics {
            // reports are sorted by worker id; ‖e_t‖ is the Lemma-3 residual
            for r in &self.reports {
                m.observe_residual(r.id, r.error_norm);
            }
        }
        if let Some(tr) = &self.trace {
            tr.record(tr.driver_track(), self.sim_time, step, EventKind::AggregateDone, 0);
        }

        self.clock.advance();
        mean_loss
    }

    /// Apply this round's membership events (leave/crash/rejoin/join):
    /// trace them, bump the epoch once if any fired, refresh the live-id
    /// scratch, and cold-start revived workers whose EF state was lost (a
    /// crash, or a brand-new join). Graceful leavers keep their residual
    /// parked inside their pool actor, so a warm rejoin moves no state at
    /// all. Only called when the schedule is active, and before any wire
    /// traffic for the round.
    fn apply_membership(&mut self, step: u64) {
        let evs = self.cfg.membership.events_at(step);
        if evs.is_empty() {
            return;
        }
        // copy the (Copy) events out: the slice borrows `cfg.membership`,
        // and applying them mutates driver state
        let mut events = std::mem::take(&mut self.event_scratch);
        events.clear();
        events.extend_from_slice(evs);
        for &ev in &events {
            let cold = self.membership.apply(&ev);
            if let Some(tr) = &self.trace {
                let kind = match ev.kind {
                    MembershipEventKind::Leave | MembershipEventKind::Crash => {
                        EventKind::MemberLeave
                    }
                    MembershipEventKind::Rejoin | MembershipEventKind::Join => {
                        EventKind::MemberJoin
                    }
                };
                tr.record(tr.driver_track(), self.sim_time, step, kind, ev.worker as u64);
            }
            if cold {
                // fail-stop lost the residual (or a join never had one):
                // revive with zeroed EF state at the current round
                let d = self.theta.len();
                self.pool.restore_states(vec![WorkerState {
                    id: ev.worker,
                    steps: step,
                    error: vec![0.0; d],
                    corrected: vec![0.0; d],
                }]);
            }
        }
        self.event_scratch = events;
        self.membership.bump_epoch();
        self.membership.live_ids_into(&mut self.live_ids);
    }

    /// Reconcile the fabric's dropped-frame counter with the last sighting:
    /// counts the delta into the metrics and (when `as_undecodable`) records
    /// one lumped driver-track `FrameDropped` event. Decode drops happen on
    /// pool threads, which never write trace rings — ring writes stay
    /// single-writer per node, so the trace stays deterministic.
    fn note_dropped(&mut self, t: f64, round: u64, as_undecodable: bool) {
        if self.trace.is_none() && self.metrics.is_none() {
            return;
        }
        let seen = self.fabric.with_stats(|s| s.dropped());
        let delta = seen - self.last_dropped;
        self.last_dropped = seen;
        if delta == 0 {
            return;
        }
        if let Some(m) = &self.metrics {
            m.add_dropped(delta);
        }
        if as_undecodable {
            if let Some(tr) = &self.trace {
                tr.record(
                    tr.driver_track(),
                    t,
                    round,
                    EventKind::FrameDropped(DropReason::Undecodable),
                    delta,
                );
            }
        }
    }

    /// Run the configured number of rounds.
    pub fn run(mut self) -> TrainOutcome {
        let mut recorder = Recorder::new();
        for step in 0..self.cfg.steps {
            let loss = self.round(&mut recorder);
            if self.cfg.log_every > 0 && step % self.cfg.log_every == 0 {
                // lock-free counter: no stats-map clone on the log path
                let bits = self.fabric.total_bits();
                log::info!(
                    "round {step}: loss {loss:.4}  comm {:.2} Mbit",
                    bits as f64 / 1e6
                );
            }
            if self.cfg.eval_every > 0 && (step + 1) % self.cfg.eval_every == 0 {
                // eval through worker 0's source
                let (el, ea) = self.pool.eval(0, &self.theta);
                if el.is_finite() {
                    recorder.record("eval_loss", step as u64, el);
                }
                if ea.is_finite() {
                    recorder.record("eval_acc", step as u64, ea);
                }
            }
            if self.cfg.checkpoint_every > 0 && (step + 1) % self.cfg.checkpoint_every == 0 {
                self.checkpoint();
                if let Some(tr) = &self.trace {
                    tr.record(
                        tr.driver_track(),
                        self.sim_time,
                        step as u64,
                        EventKind::CheckpointSaved,
                        0,
                    );
                }
            }
        }
        recorder.record("final_loss", self.clock.current(), recorder.last("train_loss"));
        let bits = self.fabric.total_bits();
        recorder.record("total_bits", self.clock.current(), bits as f64);
        let sim_time_s = self.sim_time_s();
        TrainOutcome {
            theta: self.theta,
            recorder,
            traffic: self.fabric.snapshot_stats(),
            rounds: self.clock.current(),
            profile: self.profile,
            sim_time_s,
            staleness: StalenessStats::default(),
            trace: self.trace,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CompressorKind;
    use crate::coordinator::worker::{ObjectiveSource, WorkerMode};
    use crate::model::toy::SparseNoiseQuadratic;
    use crate::util::Pcg64;

    fn quadratic_workers(n: usize, d: usize, mode: WorkerMode, kind: CompressorKind) -> Vec<Worker> {
        (0..n)
            .map(|id| {
                Worker::new(
                    id,
                    Box::new(ObjectiveSource::new(
                        SparseNoiseQuadratic::new(d, 0.0),
                        Pcg64::seeded(100 + id as u64),
                    )),
                    mode,
                    kind,
                    4,
                    4,
                    Pcg64::seeded(id as u64),
                )
            })
            .collect()
    }

    #[test]
    fn ef_multiworker_converges_on_quadratic() {
        let d = 64;
        let workers = quadratic_workers(4, d, WorkerMode::ErrorFeedback, CompressorKind::ScaledSign);
        let cfg = DriverConfig {
            steps: 400,
            schedule: LrSchedule::new(0.2, 400, vec![0.5, 0.75]),
            ..Default::default()
        };
        let theta0 = vec![1.0f32; d];
        let driver = TrainDriver::new(cfg, workers, theta0);
        let out = driver.run();
        let final_norm = crate::tensor::norm2(&out.theta);
        assert!(final_norm < 0.05, "||x|| = {final_norm}");
        assert!(out.traffic.total_bits > 0);
        assert_eq!(out.rounds, 400);
    }

    #[test]
    fn dense_sgd_with_server_momentum_converges() {
        let d = 32;
        let workers = quadratic_workers(2, d, WorkerMode::DenseGrad, CompressorKind::None);
        let cfg = DriverConfig {
            steps: 200,
            schedule: LrSchedule::constant(0.05),
            update_rule: UpdateRule::ServerMomentum { beta_millis: 900 },
            ..Default::default()
        };
        let out = TrainDriver::new(cfg, workers, vec![1.0f32; d]).run();
        assert!(crate::tensor::norm2(&out.theta) < 1e-2);
    }

    #[test]
    fn majority_vote_runs_and_descends() {
        let d = 16;
        let workers = quadratic_workers(3, d, WorkerMode::SignVote, CompressorKind::Sign);
        let cfg = DriverConfig {
            steps: 150,
            schedule: LrSchedule::new(0.05, 150, vec![0.5, 0.8]),
            aggregation: Aggregation::MajorityVote,
            update_rule: UpdateRule::ScaleByLr,
            ..Default::default()
        };
        let out = TrainDriver::new(cfg, workers, vec![1.0f32; d]).run();
        assert!(crate::tensor::norm2(&out.theta) < 0.5);
    }

    #[test]
    fn compressed_traffic_much_smaller_than_dense() {
        let d = 4096;
        let steps = 5;
        let run = |mode, kind| {
            let workers = quadratic_workers(2, d, mode, kind);
            let cfg = DriverConfig {
                steps,
                schedule: LrSchedule::constant(0.01),
                update_rule: if mode == WorkerMode::DenseGrad {
                    UpdateRule::ScaleByLr
                } else {
                    UpdateRule::ApplyAggregate
                },
                ..Default::default()
            };
            let out = TrainDriver::new(cfg, workers, vec![1.0f32; d]).run();
            out.traffic.bits_of_kind(crate::net::MessageKind::GradPush)
        };
        let dense = run(WorkerMode::DenseGrad, CompressorKind::None);
        let signed = run(WorkerMode::ErrorFeedback, CompressorKind::ScaledSign);
        let ratio = dense as f64 / signed as f64;
        assert!(ratio > 25.0, "push compression ratio {ratio}");
    }

    #[test]
    fn sim_time_integrates_broadcast_compute_and_push() {
        use crate::net::message::FRAME_OVERHEAD_BITS;
        use crate::net::{StragglerModel, StragglerSchedule};
        let d = 64;
        let steps = 5u64;
        let base = 2e-3;
        let workers = quadratic_workers(3, d, WorkerMode::ErrorFeedback, CompressorKind::ScaledSign);
        let link = LinkModel::ten_gbe();
        let cfg = DriverConfig {
            steps: steps as usize,
            schedule: LrSchedule::constant(0.05),
            straggler: StragglerSchedule::new(base, StragglerModel::Constant, 0),
            link,
            ..Default::default()
        };
        let out = TrainDriver::new(cfg, workers, vec![1.0f32; d]).run();
        // per round: params broadcast + constant compute + sign push + the
        // leader's measured decode+aggregate, in sequence on the virtual
        // clock. The comm terms are analytic; the leader term is exactly
        // the profiled critical path, so subtracting it must recover the
        // link-model arithmetic.
        let t_params = link.transfer_time(32 * d as u64 + FRAME_OVERHEAD_BITS);
        let t_push = link.transfer_time(d as u64 + 32 + FRAME_OVERHEAD_BITS);
        let expect = steps as f64 * (t_params + base + t_push);
        let comm_time = out.sim_time_s - out.profile.critical_s;
        assert!(
            (comm_time - expect).abs() < 1e-9 * expect,
            "sim-minus-leader {} vs expect {}",
            comm_time,
            expect
        );
        // the leader's decode genuinely consumed simulated time
        assert!(out.profile.critical_s > 0.0);
        assert!(out.sim_time_s > expect);
        // satellite: the traffic layer's per-kind simulated time must
        // equal the same link-model arithmetic, message by message
        let push_total = out.traffic.sim_time_of_kind(crate::net::MessageKind::GradPush);
        let expect_push = steps as f64 * 3.0 * t_push;
        assert!((push_total - expect_push).abs() < 1e-9 * expect_push);
        // sync runs report zero staleness
        assert_eq!(out.staleness.frames, 0);
    }

    /// Satellite identity (ISSUE 9): a 1-worker, S-shard run under the
    /// serialized-uplink discipline reports a `sim_time_s` equal to the
    /// closed-form FIFO replay **to the bit** — every send replayed with
    /// the same `max(node_time, link_free_time)` rule, the same
    /// `transfer_time`/`serialization_time` expressions, in the same
    /// order. The analytic [`DecodeCostModel`] replaces the measured
    /// leader term so the whole total is a pure function of the models.
    #[test]
    fn serialized_uplink_sim_time_matches_closed_form() {
        use crate::compress::wire::{Format, SHARD_TAG_BITS};
        use crate::net::message::FRAME_OVERHEAD_BITS;
        use crate::net::{StragglerModel, StragglerSchedule};
        let d = 96;
        let steps = 4u64;
        let base = 1e-3;
        let link = LinkModel::wan();
        for shards in [1usize, 4] {
            let cost = DecodeCostModel::calibrated();
            let run = |discipline| {
                let workers =
                    quadratic_workers(1, d, WorkerMode::ErrorFeedback, CompressorKind::ScaledSign);
                let cfg = DriverConfig {
                    steps: steps as usize,
                    schedule: LrSchedule::constant(0.05),
                    straggler: StragglerSchedule::new(base, StragglerModel::Constant, 0),
                    link,
                    discipline,
                    leader_cost: cost,
                    shards,
                    ..Default::default()
                };
                TrainDriver::new(cfg, workers, vec![1.0f32; d]).run()
            };
            let out = run(LinkDiscipline::Serialized);
            // closed-form replay: worker is node 0, shard leaders 1..=S
            let plan = ShardPlan::new(d, shards);
            let s_total = plan.num_shards();
            let mut free = vec![0.0f64; 1 + s_total];
            let mut sim = 0.0f64;
            let mut model = 0.0f64;
            for _ in 0..steps {
                // leaders broadcast at `sim`, one slice each on its own uplink
                let mut params_arrival = 0.0f64;
                for s in 0..s_total {
                    let bits = if s_total == 1 {
                        32 * d as u64 + FRAME_OVERHEAD_BITS
                    } else {
                        32 * plan.len_of(s) as u64 + SHARD_TAG_BITS + FRAME_OVERHEAD_BITS
                    };
                    let start = sim.max(free[1 + s]);
                    free[1 + s] = start + link.serialization_time(bits);
                    params_arrival = params_arrival.max(start + link.transfer_time(bits));
                }
                // the worker's S pushes serialize on its single uplink
                let finish = params_arrival + base;
                let mut round_end = sim;
                let mut worst = 0.0f64;
                for s in 0..s_total {
                    let tag = if s_total == 1 { 0 } else { SHARD_TAG_BITS };
                    let bits = plan.len_of(s) as u64 + 32 + tag + FRAME_OVERHEAD_BITS;
                    let start = finish.max(free[0]);
                    free[0] = start + link.serialization_time(bits);
                    round_end = round_end.max(start + link.transfer_time(bits));
                    worst = worst.max(cost.frame_cost(Format::SignScaled, plan.len_of(s)));
                }
                model += worst;
                sim = round_end;
            }
            assert_eq!(out.sim_time_s, sim + model, "shards={shards}");
            // cross-check against the legacy overlapped pricing: a single
            // frame per (node, instant) has nothing to queue behind, so
            // S=1 degenerates exactly; S>1 pushes genuinely serialize
            let ov = run(LinkDiscipline::Overlapped);
            if shards == 1 {
                assert_eq!(out.sim_time_s, ov.sim_time_s);
            } else {
                assert!(out.sim_time_s > ov.sim_time_s, "shards={shards}");
            }
            // the discipline only reprices time — the trained bits are
            // identical (timing never feeds back into the trajectory)
            assert_eq!(out.theta, ov.theta, "shards={shards}");
        }
    }

    #[test]
    fn checkpoint_restore_resumes_identically() {
        let d = 32;
        let mk = || quadratic_workers(2, d, WorkerMode::ErrorFeedback, CompressorKind::ScaledSign);
        // run A: 20 straight rounds
        let cfg_a = DriverConfig {
            steps: 20,
            schedule: LrSchedule::constant(0.1),
            ..Default::default()
        };
        let out_a = TrainDriver::new(cfg_a, mk(), vec![1.0f32; d]).run();

        // run B: 10 rounds, snapshot, restore into a fresh driver, 10 more
        let cfg_b1 = DriverConfig {
            steps: 10,
            schedule: LrSchedule::constant(0.1),
            ..Default::default()
        };
        let mut drv = TrainDriver::new(cfg_b1, mk(), vec![1.0f32; d]);
        let mut rec = Recorder::new();
        for _ in 0..10 {
            drv.round(&mut rec);
        }
        let snap = drv.snapshot();
        assert_eq!(snap.round, 10);
        let cfg_b2 = DriverConfig {
            steps: 0,
            schedule: LrSchedule::constant(0.1),
            ..Default::default()
        };
        let mut drv2 = TrainDriver::new(cfg_b2, mk(), vec![1.0f32; d]);
        drv2.restore(&snap);
        let mut rec2 = Recorder::new();
        for _ in 0..10 {
            drv2.round(&mut rec2);
        }
        // NOTE: worker RNG streams are reconstructed from seeds, and the
        // quadratic grad is deterministic (noise 0), so trajectories match.
        for (a, b) in out_a.theta.iter().zip(drv2.theta()) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }
}
