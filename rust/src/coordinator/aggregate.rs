//! Leader-side aggregation rules, including the fused
//! decode-and-accumulate fast path over the worker pool's threads.

use super::pool::WorkerPool;
use crate::collectives::{majority_vote, ShardPlan};
use crate::compress::wire::Encoded;

/// Fixed fan-out width of the leader's parallel frame decode. The `n`
/// worker frames are partitioned into at most this many contiguous groups;
/// each group is decoded (fused) into one partial sum and the partials are
/// merged in worker-id order. The partition depends only on `n` — never on
/// the thread count — so the f32 reduction tree, and therefore every bit
/// of the trained parameters, is identical for any `--threads` value.
pub const DECODE_LANES: usize = 8;

/// Frames per decode group for `n` worker frames — the single source of
/// truth behind [`decode_groups`] and the allocation-free partition in
/// [`Aggregation::combine_frames_into`]: both must derive the identical
/// grouping or the f32 reduction tree (and with it bit-determinism)
/// forks between the paths.
pub fn decode_group_size(n: usize) -> usize {
    debug_assert!(n > 0);
    n.div_ceil(DECODE_LANES)
}

/// The fixed decode partition: contiguous groups of ⌈n / DECODE_LANES⌉
/// frames. For n ≤ DECODE_LANES this is one group per worker, which makes
/// the blocked reduction identical to the historical strictly-sequential
/// per-worker sum.
pub fn decode_groups(n: usize) -> Vec<(usize, usize)> {
    assert!(n > 0);
    let size = decode_group_size(n);
    let mut groups = Vec::with_capacity(n.div_ceil(size));
    let mut start = 0;
    while start < n {
        let end = (start + size).min(n);
        groups.push((start, end));
        start = end;
    }
    groups
}

/// Persistent scratch for the fused combine path: per-group frame
/// containers, recycled partial-sum buffers, and the per-shard
/// decode+aggregate timings of the last sharded combine. One instance
/// lives in each driver; after round 1 nothing in here allocates (the
/// zero-alloc steady state of docs/PERF.md).
#[derive(Default)]
pub struct DecodeScratch {
    /// Per-group frame containers, moved through the pool's decode
    /// commands and returned empty.
    groups: Vec<Vec<Encoded>>,
    /// Partial sums of the current combine, in group order.
    partials: Vec<Vec<f32>>,
    /// Per-group decoded-frame counts from the last pooled decode
    /// (undecodable frames are dropped, so a count can fall short of the
    /// group size).
    decoded: Vec<usize>,
    /// Recycle stack for partial-sum buffers.
    spare: Vec<Vec<f32>>,
    /// Robust-aggregation scratch: one coordinate's values across the
    /// live workers, in worker-id order.
    column: Vec<f32>,
    /// Robust-aggregation scratch: an n × [`COL_BLOCK`] gather block
    /// (worker-major) so the per-coordinate rules read the per-worker
    /// vectors in contiguous runs instead of one strided element at a
    /// time.
    block: Vec<f32>,
    /// Robust-aggregation scratch: value-sorted positions of `column`.
    order: Vec<u32>,
    /// Robust-aggregation scratch: per-column trim mask.
    trimmed: Vec<bool>,
    /// Robust-aggregation scratch: per-worker keep mask.
    keep: Vec<bool>,
    /// Robust-aggregation scratch: per-worker update norms.
    norms: Vec<f64>,
    /// Robust-aggregation scratch: sorted copy of the live norms.
    norms_sorted: Vec<f64>,
    /// Seconds each shard leader spent in decode+aggregate during the
    /// last [`Aggregation::combine_frames_sharded_into`] call.
    pub shard_times: Vec<f64>,
}

/// Norm-thresholding cutoff: a worker whose update norm exceeds this
/// multiple of the median live-worker norm is excluded from the mean.
pub const NORM_THRESHOLD_FACTOR: f64 = 2.0;

/// Coordinates gathered per robust-reduce block: each kept worker
/// contributes this many contiguous values to the gather block before the
/// per-column rule runs. Purely a memory-access restructure — the values
/// entering each column, and their worker-id order, are exactly those of
/// the historical one-coordinate-at-a-time gather.
pub const COL_BLOCK: usize = 8;

/// How the leader combines per-worker updates.
///
/// The robust variants (`Median`, `TrimmedMean`, `NormThreshold`) are the
/// Byzantine defenses of Ghosh et al. 2019: they need the individual
/// per-worker updates rather than a blocked sum, so they densify through
/// the pool with one decode group per worker and reduce coordinate-wise
/// on the driver thread in a fixed worker-id order (bit-deterministic for
/// any `(shards, threads)`; each shard leader filters independently).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Aggregation {
    /// Element-wise mean of the decoded deltas — the EF-SGD rule (each
    /// worker's residual absorbs its own compression error).
    Mean,
    /// Coordinate-wise majority vote of signs, scaled by the mean of the
    /// senders' scales (the multi-worker SIGNSGD of Bernstein et al. 2019).
    MajorityVote,
    /// Coordinate-wise median of the live workers' updates (even counts
    /// average the two middle values). Tolerates just under half the
    /// workers being Byzantine.
    Median,
    /// Coordinate-wise trimmed mean: drop the `k` smallest and `k`
    /// largest values per coordinate, mean the rest in worker-id order.
    /// `TrimmedMean(0)` is bit-identical to [`Mean`](Self::Mean) for
    /// n ≤ [`DECODE_LANES`] workers (one decode group per worker — the
    /// same per-worker sum order).
    TrimmedMean(usize),
    /// Mean over workers whose update norm is within
    /// [`NORM_THRESHOLD_FACTOR`] × the median live norm — the defense
    /// matched to norm-inflation attacks (sign-flips keep their norm and
    /// pass straight through it).
    NormThreshold,
}

impl Aggregation {
    /// Parse a CLI/config spec: `mean`, `majority_vote` | `majority`,
    /// `median`, `trimmed[:K]` | `trimmed_mean[:K]` | `trim[:K]`
    /// (default K = 1), `norm_threshold` | `normthresh`.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "mean" => Some(Aggregation::Mean),
            "majority_vote" | "majority" => Some(Aggregation::MajorityVote),
            "median" => Some(Aggregation::Median),
            "trimmed" | "trimmed_mean" | "trim" => Some(Aggregation::TrimmedMean(1)),
            "norm_threshold" | "normthresh" => Some(Aggregation::NormThreshold),
            _ => {
                let (name, k) = s.split_once(':')?;
                if !matches!(name, "trimmed" | "trimmed_mean" | "trim") {
                    return None;
                }
                Some(Aggregation::TrimmedMean(k.parse().ok()?))
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Aggregation::Mean => "mean",
            Aggregation::MajorityVote => "majority_vote",
            Aggregation::Median => "median",
            Aggregation::TrimmedMean(_) => "trimmed_mean",
            Aggregation::NormThreshold => "norm_threshold",
        }
    }

    /// Decode + combine encoded worker frames (sorted by worker id) on the
    /// leader, fanning the per-frame decode out across the pool threads,
    /// into a caller-owned output buffer — the allocation-free hot path.
    /// `frames` is drained (its container keeps its capacity) and every
    /// decoded frame's byte buffer returns to the fabric's frame pool.
    ///
    /// * `Mean` uses the fused path: each fixed group of frames is decoded
    ///   straight into one recycled partial-sum buffer (`decode_*_add`, no
    ///   dense `Vec<f32>` per worker), and the partials are merged in
    ///   worker-id order before the 1/n scale.
    /// * `MajorityVote` needs the individual updates, so frames are
    ///   decoded densely in parallel and voted as before (this path
    ///   allocates its per-worker vectors).
    /// * The robust variants densify with one decode group per worker
    ///   (same fused kernels, same recycled buffers) and reduce
    ///   coordinate-wise on the driver thread through persistent scratch.
    ///
    /// Undecodable frames have been dropped by the pool (counted in the
    /// fabric's `TrafficStats`); every rule aggregates over the frames
    /// that decoded. If none did, the combined update is zero.
    pub fn combine_frames_into(
        &self,
        frames: &mut Vec<Encoded>,
        out: &mut [f32],
        pool: &WorkerPool,
        scratch: &mut DecodeScratch,
    ) {
        assert!(!frames.is_empty());
        let n = frames.len();
        let d = out.len();
        match self {
            Aggregation::Mean => {
                // the fixed partition of `decode_groups(n)`, computed
                // without materializing the boundary list
                let size = decode_group_size(n);
                let ngroups = n.div_ceil(size);
                if scratch.groups.len() < ngroups {
                    scratch.groups.resize_with(ngroups, Vec::new);
                }
                {
                    let mut it = frames.drain(..);
                    for g in 0..ngroups {
                        let take = size.min(n - g * size);
                        scratch.groups[g].extend(it.by_ref().take(take));
                    }
                }
                pool.decode_partials_pooled(
                    &mut scratch.groups[..ngroups],
                    d,
                    &mut scratch.partials,
                    &mut scratch.decoded,
                    &mut scratch.spare,
                );
                out.fill(0.0);
                for p in &scratch.partials {
                    crate::tensor::add_assign(out, p);
                }
                // mean over the frames that decoded; with none dropped
                // this is exactly the historical 1/n (same bits)
                let live: usize = scratch.decoded.iter().sum();
                if live > 0 {
                    crate::tensor::scale(1.0 / live as f32, out);
                }
                // partial buffers go back on the recycle stack
                scratch.spare.append(&mut scratch.partials);
            }
            Aggregation::MajorityVote => {
                // drain, don't take: the caller's container keeps its
                // capacity (the drained Vec itself is a fresh allocation,
                // but this path is documented as allocating anyway)
                let taken: Vec<Encoded> = frames.drain(..).collect();
                let updates = pool.decode_dense(taken);
                if updates.is_empty() {
                    out.fill(0.0);
                } else {
                    let combined = self.combine(&updates);
                    out.copy_from_slice(&combined);
                }
            }
            Aggregation::Median | Aggregation::TrimmedMean(_) | Aggregation::NormThreshold => {
                // densify: one decode group per worker, so partials[w] is
                // exactly worker w's update and decoded[w] says whether
                // its frame survived
                if scratch.groups.len() < n {
                    scratch.groups.resize_with(n, Vec::new);
                }
                {
                    let mut it = frames.drain(..);
                    for g in 0..n {
                        scratch.groups[g].extend(it.by_ref().take(1));
                    }
                }
                pool.decode_partials_pooled(
                    &mut scratch.groups[..n],
                    d,
                    &mut scratch.partials,
                    &mut scratch.decoded,
                    &mut scratch.spare,
                );
                let s = &mut *scratch;
                robust_reduce_into(
                    *self,
                    &s.partials,
                    &s.decoded,
                    out,
                    &mut s.column,
                    &mut s.block,
                    &mut s.order,
                    &mut s.trimmed,
                    &mut s.keep,
                    &mut s.norms,
                    &mut s.norms_sorted,
                );
                scratch.spare.append(&mut scratch.partials);
            }
        }
    }

    /// Allocating wrapper around [`combine_frames_into`](Self::combine_frames_into).
    pub fn combine_frames(&self, mut frames: Vec<Encoded>, d: usize, pool: &WorkerPool) -> Vec<f32> {
        let mut out = vec![0.0f32; d];
        let mut scratch = DecodeScratch::default();
        self.combine_frames_into(&mut frames, &mut out, pool, &mut scratch);
        out
    }

    /// Decode + combine per-shard frame sets into the full-length
    /// caller-owned aggregate, one shard leader at a time; each shard's
    /// result lands directly in its slice of `out` (no assembly copy).
    /// `scratch.shard_times` receives each shard leader's measured
    /// decode+aggregate wall-clock — the per-shard cost the driver charges
    /// on the virtual clock (the simulated deployment runs the shard
    /// leaders concurrently, so the round's leader cost is the max over
    /// shards).
    ///
    /// Within each shard the reduction uses the same fixed worker-id
    /// grouping as [`combine_frames`](Self::combine_frames), so any
    /// `(shards, threads)` combination is bit-deterministic; the
    /// single-shard case computes exactly the unsharded aggregate.
    // detlint: profiling — shard_times is a real wall-clock measurement by
    // contract (the driver prices it onto the virtual clock)
    pub fn combine_frames_sharded_into(
        &self,
        frames_by_shard: &mut [Vec<Encoded>],
        plan: &ShardPlan,
        pool: &WorkerPool,
        out: &mut [f32],
        scratch: &mut DecodeScratch,
    ) {
        assert_eq!(frames_by_shard.len(), plan.num_shards());
        assert_eq!(out.len(), plan.dim());
        // shard_times is detached while combine_frames_into borrows the
        // rest of the scratch
        let mut times = std::mem::take(&mut scratch.shard_times);
        times.clear();
        for (s, frames) in frames_by_shard.iter_mut().enumerate() {
            let r = plan.range(s);
            // only the decode+aggregate itself is timed — simulation
            // plumbing around it is not shard-leader work and must not
            // inflate the priced critical path (at S = 1 the measured
            // section is identical to the historical single-leader
            // profile)
            let t = std::time::Instant::now();
            self.combine_frames_into(frames, &mut out[r], pool, scratch);
            times.push(t.elapsed().as_secs_f64());
        }
        scratch.shard_times = times;
    }

    /// Allocating wrapper around
    /// [`combine_frames_sharded_into`](Self::combine_frames_sharded_into):
    /// returns the aggregate and the per-shard decode+aggregate seconds.
    pub fn combine_frames_sharded(
        &self,
        mut frames_by_shard: Vec<Vec<Encoded>>,
        plan: &ShardPlan,
        pool: &WorkerPool,
    ) -> (Vec<f32>, Vec<f64>) {
        let mut out = vec![0.0f32; plan.dim()];
        let mut scratch = DecodeScratch::default();
        self.combine_frames_sharded_into(&mut frames_by_shard, plan, pool, &mut out, &mut scratch);
        (out, scratch.shard_times)
    }

    /// Combine decoded dense updates (one per worker).
    pub fn combine(&self, updates: &[Vec<f32>]) -> Vec<f32> {
        assert!(!updates.is_empty());
        let d = updates[0].len();
        match self {
            Aggregation::Mean => {
                let refs: Vec<&[f32]> = updates.iter().map(|u| u.as_slice()).collect();
                let mut out = vec![0.0f32; d];
                crate::tensor::mean_of(&refs, &mut out);
                out
            }
            Aggregation::MajorityVote => {
                // vote over signs; magnitude = mean per-worker L1 scale
                let vote = majority_vote(updates);
                let mean_scale: f64 = updates
                    .iter()
                    .map(|u| crate::tensor::norm1(u) / d as f64)
                    .sum::<f64>()
                    / updates.len() as f64;
                vote.iter().map(|s| *s * mean_scale as f32).collect()
            }
            Aggregation::Median | Aggregation::TrimmedMean(_) | Aggregation::NormThreshold => {
                let decoded = vec![1usize; updates.len()];
                let mut out = vec![0.0f32; d];
                robust_reduce_into(
                    *self,
                    updates,
                    &decoded,
                    &mut out,
                    &mut Vec::new(),
                    &mut Vec::new(),
                    &mut Vec::new(),
                    &mut Vec::new(),
                    &mut Vec::new(),
                    &mut Vec::new(),
                    &mut Vec::new(),
                );
                out
            }
        }
    }
}

/// The robust coordinate-wise reduce: `partials[w]` is worker `w`'s
/// decoded update (in worker-id order) and `decoded[w] > 0` marks the
/// workers whose frames survived decoding. Every buffer argument is
/// caller-persistent scratch — after the first round nothing here
/// allocates (the column/order/mask buffers are warm), and every
/// tie-break and iteration runs in worker-id order, so the result is a
/// pure function of the live updates: bit-deterministic across any
/// `(shards, threads)` configuration.
///
/// Semantics per rule:
/// * `Median` — per coordinate, sort the live values (`total_cmp`) and
///   take the middle (even counts average the two middle values).
/// * `TrimmedMean(k)` — per coordinate, discard the `k` smallest and `k`
///   largest live values (ties broken by worker position; `k` clamped so
///   at least one value survives) and mean the rest in worker-id order.
/// * `NormThreshold` — drop workers whose update norm exceeds
///   [`NORM_THRESHOLD_FACTOR`] × the median live norm, then mean the
///   kept updates in worker-id order. The median worker always passes
///   its own threshold, so at least half the live workers survive.
#[allow(clippy::too_many_arguments)]
// detlint: hot
fn robust_reduce_into(
    agg: Aggregation,
    partials: &[Vec<f32>],
    decoded: &[usize],
    out: &mut [f32],
    column: &mut Vec<f32>,
    block: &mut Vec<f32>,
    order: &mut Vec<u32>,
    trimmed: &mut Vec<bool>,
    keep: &mut Vec<bool>,
    norms: &mut Vec<f64>,
    norms_sorted: &mut Vec<f64>,
) {
    let n = partials.len();
    keep.clear();
    keep.resize(n, false);
    for w in 0..n {
        keep[w] = decoded[w] > 0;
    }
    if agg == Aggregation::NormThreshold {
        norms.clear();
        norms_sorted.clear();
        for w in 0..n {
            let nw = if keep[w] {
                crate::tensor::norm2(&partials[w])
            } else {
                f64::INFINITY
            };
            norms.push(nw);
            if keep[w] {
                norms_sorted.push(nw);
            }
        }
        if !norms_sorted.is_empty() {
            norms_sorted.sort_unstable_by(f64::total_cmp);
            let m = norms_sorted.len();
            let med = if m % 2 == 1 {
                norms_sorted[m / 2]
            } else {
                (norms_sorted[m / 2 - 1] + norms_sorted[m / 2]) * 0.5
            };
            for w in 0..n {
                keep[w] = keep[w] && norms[w] <= NORM_THRESHOLD_FACTOR * med;
            }
        }
        // masked mean in worker-id order — with every worker kept this
        // replays Mean's per-worker sum order exactly
        out.fill(0.0);
        let mut live = 0usize;
        for w in 0..n {
            if keep[w] {
                crate::tensor::add_assign(out, &partials[w]);
                live += 1;
            }
        }
        if live > 0 {
            crate::tensor::scale(1.0 / live as f32, out);
        }
        return;
    }
    // Blocked gather: walk the output in COL_BLOCK-coordinate blocks and
    // copy each kept worker's contiguous slice of the block into `block`
    // (worker-major rows). The per-column rule then reads its column out
    // of that compact block — the same values in the same worker-id order
    // as the historical one-element-per-worker strided gather, but each
    // per-worker vector is touched once per block in a contiguous run.
    let d = out.len();
    let mut j0 = 0usize;
    while j0 < d {
        let b = COL_BLOCK.min(d - j0);
        block.clear();
        for (w, p) in partials.iter().enumerate() {
            if keep[w] {
                block.extend_from_slice(&p[j0..j0 + b]);
            }
        }
        let m = block.len() / b;
        if m == 0 {
            out[j0..j0 + b].fill(0.0);
            j0 += b;
            continue;
        }
        for (c, o) in out[j0..j0 + b].iter_mut().enumerate() {
            column.clear();
            for i in 0..m {
                column.push(block[i * b + c]);
            }
            *o = match agg {
                Aggregation::Median => {
                    column.sort_unstable_by(|a, b| f32::total_cmp(a, b));
                    if m % 2 == 1 {
                        column[m / 2]
                    } else {
                        (column[m / 2 - 1] + column[m / 2]) * 0.5
                    }
                }
                Aggregation::TrimmedMean(k) => {
                    // at least one value must survive the 2k discards
                    let k = k.min((m - 1) / 2);
                    trimmed.clear();
                    trimmed.resize(m, false);
                    if k > 0 {
                        order.clear();
                        for i in 0..m as u32 {
                            order.push(i);
                        }
                        order.sort_unstable_by(|a, b| {
                            f32::total_cmp(&column[*a as usize], &column[*b as usize])
                                .then(a.cmp(b))
                        });
                        for &i in order[..k].iter().chain(order[m - k..].iter()) {
                            trimmed[i as usize] = true;
                        }
                    }
                    // mean of the survivors, summed in worker-id order
                    // (k = 0 replays Mean's per-worker sum order exactly)
                    let mut acc = 0.0f32;
                    for i in 0..m {
                        if !trimmed[i] {
                            acc += column[i];
                        }
                    }
                    acc * (1.0 / (m - 2 * k) as f32)
                }
                _ => unreachable!("robust reduce called with a non-robust rule"),
            };
        }
        j0 += b;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn mean_combine() {
        let a = vec![1.0f32, 2.0];
        let b = vec![3.0f32, -2.0];
        assert_eq!(Aggregation::Mean.combine(&[a, b]), vec![2.0, 0.0]);
    }

    #[test]
    fn majority_combine_votes_and_scales() {
        let updates = vec![
            vec![1.0f32, -1.0, 1.0],  // scale 1
            vec![3.0f32, 3.0, -3.0],  // scale 3
            vec![2.0f32, -2.0, -2.0], // scale 2
        ];
        let out = Aggregation::MajorityVote.combine(&updates);
        // votes: +,-,- ; mean scale = 2
        assert_eq!(out, vec![2.0, -2.0, -2.0]);
    }

    #[test]
    fn decode_groups_partition_is_fixed_and_complete() {
        // n <= DECODE_LANES: one group per frame (historical sum order)
        assert_eq!(decode_groups(1), vec![(0, 1)]);
        assert_eq!(
            decode_groups(4),
            vec![(0, 1), (1, 2), (2, 3), (3, 4)]
        );
        // n = 16: 8 groups of 2
        let g16 = decode_groups(16);
        assert_eq!(g16.len(), 8);
        assert!(g16.iter().all(|(s, e)| e - s == 2));
        // ragged n: contiguous, complete, <= DECODE_LANES groups
        for n in [5usize, 9, 17, 23, 64, 100] {
            let g = decode_groups(n);
            assert!(g.len() <= DECODE_LANES, "n={n}: {} groups", g.len());
            assert_eq!(g[0].0, 0);
            assert_eq!(g.last().unwrap().1, n);
            for w in g.windows(2) {
                assert_eq!(w[0].1, w[1].0, "gap in partition at n={n}");
            }
        }
    }

    #[test]
    fn combine_frames_matches_dense_combine() {
        use crate::compress::wire;
        use crate::config::CompressorKind;
        use crate::coordinator::worker::{ObjectiveSource, Worker, WorkerMode};
        use crate::model::toy::SparseNoiseQuadratic;
        use crate::net::{Fabric, LinkModel};
        use crate::util::Pcg64;

        let d = 33;
        let n = 4;
        let workers: Vec<Worker> = (0..n)
            .map(|id| {
                Worker::new(
                    id,
                    Box::new(ObjectiveSource::new(
                        SparseNoiseQuadratic::new(d, 0.0),
                        Pcg64::seeded(id as u64),
                    )),
                    WorkerMode::ErrorFeedback,
                    CompressorKind::ScaledSign,
                    4,
                    4,
                    Pcg64::seeded(50 + id as u64),
                )
            })
            .collect();
        let fabric = Arc::new(Fabric::new(n + 1, LinkModel::default()));
        let pool = WorkerPool::spawn(workers, fabric, 2);

        let mut rng = Pcg64::seeded(77);
        let frames: Vec<wire::Encoded> = (0..n)
            .map(|_| {
                let mut p = vec![0.0f32; d];
                rng.fill_normal(&mut p, 0.0, 1.0);
                wire::encode_scaled_sign(&p)
            })
            .collect();
        let updates: Vec<Vec<f32>> = frames
            .iter()
            .map(|e| wire::decode_any(e).unwrap())
            .collect();
        for agg in [Aggregation::Mean, Aggregation::MajorityVote] {
            let fused = agg.combine_frames(frames.clone(), d, &pool);
            let dense = agg.combine(&updates);
            // n <= DECODE_LANES, so the fused reduction replays the dense
            // per-worker order exactly
            assert_eq!(fused, dense, "{}", agg.name());
        }
    }

    #[test]
    fn combine_frames_sharded_matches_per_shard_dense() {
        use crate::compress::wire;
        use crate::config::CompressorKind;
        use crate::coordinator::worker::{ObjectiveSource, Worker, WorkerMode};
        use crate::model::toy::SparseNoiseQuadratic;
        use crate::net::{Fabric, LinkModel};
        use crate::util::Pcg64;

        let d = 37; // ragged split on purpose
        let n = 3;
        let plan = ShardPlan::new(d, 3);
        let workers: Vec<Worker> = (0..n)
            .map(|id| {
                Worker::new(
                    id,
                    Box::new(ObjectiveSource::new(
                        SparseNoiseQuadratic::new(d, 0.0),
                        Pcg64::seeded(id as u64),
                    )),
                    WorkerMode::ErrorFeedback,
                    CompressorKind::ScaledSign,
                    4,
                    4,
                    Pcg64::seeded(60 + id as u64),
                )
            })
            .collect();
        let fabric = Arc::new(Fabric::new(n + 1, LinkModel::default()));
        let pool = WorkerPool::spawn(workers, fabric, 2);

        let mut rng = Pcg64::seeded(9);
        let vecs: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut p = vec![0.0f32; d];
                rng.fill_normal(&mut p, 0.0, 1.0);
                p
            })
            .collect();
        let frames_by_shard: Vec<Vec<wire::Encoded>> = (0..plan.num_shards())
            .map(|s| {
                let r = plan.range(s);
                vecs.iter()
                    .map(|v| {
                        wire::encode_scaled_sign(&v[r.clone()])
                            .with_shard(s as u16, r.start as u32)
                    })
                    .collect()
            })
            .collect();
        let (full, times) =
            Aggregation::Mean.combine_frames_sharded(frames_by_shard, &plan, &pool);
        assert_eq!(times.len(), plan.num_shards());
        assert!(times.iter().all(|t| *t >= 0.0));
        for s in 0..plan.num_shards() {
            let r = plan.range(s);
            let updates: Vec<Vec<f32>> = vecs
                .iter()
                .map(|v| wire::decode_any(&wire::encode_scaled_sign(&v[r.clone()])).unwrap())
                .collect();
            let want = Aggregation::Mean.combine(&updates);
            assert_eq!(&full[r], want.as_slice(), "shard {s}");
        }
    }

    #[test]
    fn parse_names() {
        assert_eq!(Aggregation::parse("mean"), Some(Aggregation::Mean));
        assert_eq!(
            Aggregation::parse("majority_vote"),
            Some(Aggregation::MajorityVote)
        );
        assert_eq!(Aggregation::parse("median"), Some(Aggregation::Median));
        assert_eq!(Aggregation::parse("trimmed"), Some(Aggregation::TrimmedMean(1)));
        assert_eq!(Aggregation::parse("trim:2"), Some(Aggregation::TrimmedMean(2)));
        assert_eq!(
            Aggregation::parse("trimmed_mean:0"),
            Some(Aggregation::TrimmedMean(0))
        );
        assert_eq!(
            Aggregation::parse("norm_threshold"),
            Some(Aggregation::NormThreshold)
        );
        assert_eq!(
            Aggregation::parse("normthresh"),
            Some(Aggregation::NormThreshold)
        );
        assert_eq!(Aggregation::parse("x"), None);
        assert_eq!(Aggregation::parse("trim:x"), None);
        assert_eq!(Aggregation::parse("median:1"), None);
        assert_eq!(Aggregation::MajorityVote.name(), "majority_vote");
        assert_eq!(Aggregation::TrimmedMean(2).name(), "trimmed_mean");
        assert_eq!(Aggregation::NormThreshold.name(), "norm_threshold");
    }

    #[test]
    fn median_combine_coordinatewise() {
        let updates = vec![
            vec![1.0f32, -5.0, 2.0],
            vec![3.0f32, 1.0, 0.0],
            vec![-9.0f32, 2.0, 1.0],
        ];
        // per coordinate: median of {1,3,-9}=1, {-5,1,2}=1, {2,0,1}=1
        assert_eq!(Aggregation::Median.combine(&updates), vec![1.0, 1.0, 1.0]);
        // even count: the two middle values average
        let even = vec![vec![1.0f32], vec![2.0f32], vec![10.0f32], vec![0.0f32]];
        assert_eq!(Aggregation::Median.combine(&even), vec![1.5]);
    }

    #[test]
    fn trimmed_mean_discards_extremes() {
        let updates = vec![
            vec![100.0f32],
            vec![1.0f32],
            vec![2.0f32],
            vec![3.0f32],
            vec![-50.0f32],
        ];
        // k=1 drops -50 and 100, leaving mean(1,2,3) = 2
        assert_eq!(Aggregation::TrimmedMean(1).combine(&updates), vec![2.0]);
        // oversized k clamps so one value (the median) survives
        assert_eq!(Aggregation::TrimmedMean(9).combine(&updates), vec![2.0]);
    }

    #[test]
    fn norm_threshold_excludes_inflated_workers() {
        let honest = vec![1.0f32, 1.0, 1.0, 1.0];
        let updates = vec![
            honest.clone(),
            honest.clone(),
            honest.iter().map(|x| x * 100.0).collect::<Vec<f32>>(),
            honest.clone(),
        ];
        // median norm = the honest norm, the 100x worker is excluded
        assert_eq!(Aggregation::NormThreshold.combine(&updates), honest);
        // a sign-flipped worker keeps its norm: norm-thresholding alone
        // does NOT filter it (that is what median/trimmed-mean are for)
        let flipped = vec![
            honest.clone(),
            honest.clone(),
            honest.iter().map(|x| -x).collect::<Vec<f32>>(),
            honest.clone(),
        ];
        assert_eq!(
            Aggregation::NormThreshold.combine(&flipped),
            vec![0.5f32, 0.5, 0.5, 0.5]
        );
    }

    /// `TrimmedMean(0)` replays Mean's per-worker sum order exactly, so
    /// for n ≤ DECODE_LANES the two are bit-identical on real frames.
    #[test]
    fn trim_zero_is_bitwise_mean() {
        use crate::compress::wire;
        use crate::util::Pcg64;
        let d = 57;
        let n = 6;
        let pool = spawn_pool(n, d, 2);
        let mut rng = Pcg64::seeded(123);
        let frames: Vec<wire::Encoded> = (0..n)
            .map(|_| {
                let mut p = vec![0.0f32; d];
                rng.fill_normal(&mut p, 0.0, 1.0);
                wire::encode_scaled_sign(&p)
            })
            .collect();
        let mean = Aggregation::Mean.combine_frames(frames.clone(), d, &pool);
        let trim0 = Aggregation::TrimmedMean(0).combine_frames(frames, d, &pool);
        assert_eq!(mean, trim0);
    }

    /// The robust fused path equals the dense-combine reference, and the
    /// robust rules actually defend: with 2 of 6 workers sign-flipped the
    /// median/trimmed aggregate matches the honest-only aggregate.
    #[test]
    fn robust_combine_frames_matches_dense_and_filters() {
        use crate::compress::wire;
        use crate::util::Pcg64;
        let d = 33;
        let n = 6;
        let pool = spawn_pool(n, d, 3);
        let mut rng = Pcg64::seeded(321);
        let mut payloads: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut p = vec![0.0f32; d];
                rng.fill_normal(&mut p, 0.0, 1.0);
                p
            })
            .collect();
        // workers 1 and 4 are Byzantine: exact sign flip
        for w in [1usize, 4] {
            for x in payloads[w].iter_mut() {
                *x = -*x;
            }
        }
        let frames: Vec<wire::Encoded> = payloads
            .iter()
            .map(|p| wire::encode_scaled_sign(p))
            .collect();
        let updates: Vec<Vec<f32>> = frames
            .iter()
            .map(|e| wire::decode_any(e).unwrap())
            .collect();
        for agg in [
            Aggregation::Median,
            Aggregation::TrimmedMean(2),
            Aggregation::NormThreshold,
        ] {
            let fused = agg.combine_frames(frames.clone(), d, &pool);
            assert_eq!(fused, agg.combine(&updates), "{}", agg.name());
        }
        // scaled-sign frames share one scale magnitude per worker; with 4
        // honest copies of sign s and 2 flipped, the coordinate-wise
        // median recovers the honest sign's value exactly
        let median = Aggregation::Median.combine(&updates);
        for j in 0..d {
            let honest: Vec<f32> = [0usize, 2, 3, 5].iter().map(|w| updates[*w][j]).collect();
            // all honest workers agree in sign direction per coordinate?
            // not necessarily — instead check the median lies within the
            // honest values' range (the Byzantine pair cannot drag it out)
            let lo = honest.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = honest.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            assert!(median[j] >= lo && median[j] <= hi, "coord {j}");
        }
    }

    /// The COL_BLOCK-blocked gather in `robust_reduce_into` is bitwise
    /// identical to a naive one-coordinate-at-a-time reference, at d
    /// spanning block boundaries and with dropped workers in the mix.
    #[test]
    fn blocked_robust_reduce_matches_per_coordinate_reference() {
        use crate::util::Pcg64;
        let mut rng = Pcg64::seeded(71);
        for d in [1usize, 7, 8, 9, 15, 16, 17, 33] {
            let n = 6;
            let partials: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    let mut p = vec![0.0f32; d];
                    rng.fill_normal(&mut p, 0.0, 1.0);
                    p
                })
                .collect();
            // worker 2's frame "failed to decode"
            let decoded = [1usize, 1, 0, 1, 1, 1];
            for agg in [Aggregation::Median, Aggregation::TrimmedMean(1)] {
                let mut got = vec![0.0f32; d];
                robust_reduce_into(
                    agg,
                    &partials,
                    &decoded,
                    &mut got,
                    &mut Vec::new(),
                    &mut Vec::new(),
                    &mut Vec::new(),
                    &mut Vec::new(),
                    &mut Vec::new(),
                    &mut Vec::new(),
                    &mut Vec::new(),
                );
                for j in 0..d {
                    let mut col: Vec<f32> = (0..n)
                        .filter(|w| decoded[*w] > 0)
                        .map(|w| partials[w][j])
                        .collect();
                    let m = col.len();
                    let want = match agg {
                        Aggregation::Median => {
                            col.sort_unstable_by(|a, b| f32::total_cmp(a, b));
                            if m % 2 == 1 {
                                col[m / 2]
                            } else {
                                (col[m / 2 - 1] + col[m / 2]) * 0.5
                            }
                        }
                        Aggregation::TrimmedMean(k) => {
                            let k = k.min((m - 1) / 2);
                            let mut order: Vec<usize> = (0..m).collect();
                            order.sort_unstable_by(|a, b| {
                                f32::total_cmp(&col[*a], &col[*b]).then(a.cmp(b))
                            });
                            let mut trimmed = vec![false; m];
                            for &i in order[..k].iter().chain(order[m - k..].iter()) {
                                trimmed[i] = true;
                            }
                            let mut acc = 0.0f32;
                            for i in 0..m {
                                if !trimmed[i] {
                                    acc += col[i];
                                }
                            }
                            acc * (1.0 / (m - 2 * k) as f32)
                        }
                        _ => unreachable!(),
                    };
                    assert_eq!(
                        got[j].to_bits(),
                        want.to_bits(),
                        "{} d={d} j={j}",
                        agg.name()
                    );
                }
            }
        }
    }

    fn spawn_pool(n: usize, d: usize, threads: usize) -> WorkerPool {
        use crate::config::CompressorKind;
        use crate::coordinator::worker::{ObjectiveSource, Worker, WorkerMode};
        use crate::model::toy::SparseNoiseQuadratic;
        use crate::net::{Fabric, LinkModel};
        use crate::util::Pcg64;
        let workers: Vec<Worker> = (0..n)
            .map(|id| {
                Worker::new(
                    id,
                    Box::new(ObjectiveSource::new(
                        SparseNoiseQuadratic::new(d, 0.0),
                        Pcg64::seeded(id as u64),
                    )),
                    WorkerMode::ErrorFeedback,
                    CompressorKind::ScaledSign,
                    4,
                    4,
                    Pcg64::seeded(50 + id as u64),
                )
            })
            .collect();
        let fabric = Arc::new(Fabric::new(n + 1, LinkModel::default()));
        WorkerPool::spawn(workers, fabric, threads)
    }
}
