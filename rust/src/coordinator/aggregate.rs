//! Leader-side aggregation rules.

use crate::collectives::majority_vote;

/// How the leader combines per-worker updates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Aggregation {
    /// Element-wise mean of the decoded deltas — the EF-SGD rule (each
    /// worker's residual absorbs its own compression error).
    Mean,
    /// Coordinate-wise majority vote of signs, scaled by the mean of the
    /// senders' scales (the multi-worker SIGNSGD of Bernstein et al. 2019).
    MajorityVote,
}

impl Aggregation {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "mean" => Some(Aggregation::Mean),
            "majority_vote" | "majority" => Some(Aggregation::MajorityVote),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Aggregation::Mean => "mean",
            Aggregation::MajorityVote => "majority_vote",
        }
    }

    /// Combine decoded dense updates (one per worker).
    pub fn combine(&self, updates: &[Vec<f32>]) -> Vec<f32> {
        assert!(!updates.is_empty());
        let d = updates[0].len();
        match self {
            Aggregation::Mean => {
                let refs: Vec<&[f32]> = updates.iter().map(|u| u.as_slice()).collect();
                let mut out = vec![0.0f32; d];
                crate::tensor::mean_of(&refs, &mut out);
                out
            }
            Aggregation::MajorityVote => {
                // vote over signs; magnitude = mean per-worker L1 scale
                let vote = majority_vote(updates);
                let mean_scale: f64 = updates
                    .iter()
                    .map(|u| crate::tensor::norm1(u) / d as f64)
                    .sum::<f64>()
                    / updates.len() as f64;
                vote.iter().map(|s| *s * mean_scale as f32).collect()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_combine() {
        let a = vec![1.0f32, 2.0];
        let b = vec![3.0f32, -2.0];
        assert_eq!(Aggregation::Mean.combine(&[a, b]), vec![2.0, 0.0]);
    }

    #[test]
    fn majority_combine_votes_and_scales() {
        let updates = vec![
            vec![1.0f32, -1.0, 1.0],  // scale 1
            vec![3.0f32, 3.0, -3.0],  // scale 3
            vec![2.0f32, -2.0, -2.0], // scale 2
        ];
        let out = Aggregation::MajorityVote.combine(&updates);
        // votes: +,-,- ; mean scale = 2
        assert_eq!(out, vec![2.0, -2.0, -2.0]);
    }

    #[test]
    fn parse_names() {
        assert_eq!(Aggregation::parse("mean"), Some(Aggregation::Mean));
        assert_eq!(
            Aggregation::parse("majority_vote"),
            Some(Aggregation::MajorityVote)
        );
        assert_eq!(Aggregation::parse("x"), None);
        assert_eq!(Aggregation::MajorityVote.name(), "majority_vote");
    }
}
