//! Calibrated leader decode-cost model: simulated seconds per frame as a
//! pure function of `(format, d)`.
//!
//! Both drivers price the leader's decode+aggregate on the virtual clock.
//! The historical source for that term is the *measured* wall-clock of the
//! actual decode ([`crate::coordinator::round::LeaderProfile`]), which is
//! honest but machine-dependent: the same seeded run reports a different
//! `sim_time_s` on different hardware. The S-sweeps in the comm experiment
//! need to separate the parallel-uplink gain from the leader-decode gain
//! as a *reproducible* number, so this model prices a frame analytically:
//!
//! ```text
//! frame_cost(format, d) = per_frame_s + d * per_coord_s[format]
//! ```
//!
//! With a cost model enabled, `sim_time_s` adds the modeled per-round
//! max-over-shards leader term instead of the measured one — making the
//! whole reported time a pure function of the seeded models, bit-exact
//! across machines and runs. The event schedule itself never sees either
//! term (leader cost is added to the reported total only), so traces and
//! trained bits are unaffected either way.

use crate::compress::wire::Format;

/// Per-frame leader decode cost model. `Default` (= [`none`](Self::none))
/// disables the model: drivers fall back to the measured profile.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct DecodeCostModel {
    /// Fixed per-frame overhead (header parse, dispatch, buffer return).
    pub per_frame_s: f64,
    /// Per-coordinate decode+accumulate cost, indexed by
    /// [`Format::index`].
    pub per_coord_s: [f64; Format::COUNT],
}

impl DecodeCostModel {
    /// The disabled model: every cost zero, [`is_enabled`](Self::is_enabled)
    /// false. Drivers charge the measured leader profile instead —
    /// byte-identical to the historical engine.
    pub fn none() -> Self {
        DecodeCostModel::default()
    }

    /// Nominal costs for the vectorized kernels on commodity hardware
    /// (order-of-magnitude from `bench_leader`): word-unpacked signs are
    /// cheapest, bit-serial Elias-gamma (QSGD) dearest. The absolute scale
    /// matters less than being a fixed, machine-independent function.
    pub fn calibrated() -> Self {
        let mut per_coord_s = [0.0; Format::COUNT];
        per_coord_s[Format::DenseF32.index()] = 0.2e-9;
        per_coord_s[Format::SignScaled.index()] = 0.15e-9;
        per_coord_s[Format::SparseIdxVal.index()] = 0.3e-9;
        per_coord_s[Format::Ternary.index()] = 0.8e-9;
        per_coord_s[Format::Qsgd.index()] = 1.2e-9;
        DecodeCostModel {
            per_frame_s: 200e-9,
            per_coord_s,
        }
    }

    /// Whether any cost is non-zero (i.e. the model, not the measured
    /// profile, should price the leader term).
    pub fn is_enabled(&self) -> bool {
        self.per_frame_s != 0.0 || self.per_coord_s.iter().any(|&c| c != 0.0)
    }

    /// Modeled decode+accumulate cost of one `d`-coordinate frame.
    pub fn frame_cost(&self, format: Format, d: usize) -> f64 {
        self.per_frame_s + d as f64 * self.per_coord_s[format.index()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_disabled_and_free() {
        let m = DecodeCostModel::none();
        assert!(!m.is_enabled());
        assert_eq!(m.frame_cost(Format::Qsgd, 1_000_000), 0.0);
        assert_eq!(m, DecodeCostModel::default());
    }

    #[test]
    fn calibrated_is_affine_in_d() {
        let m = DecodeCostModel::calibrated();
        assert!(m.is_enabled());
        for f in Format::ALL {
            let c0 = m.frame_cost(f, 0);
            let c1 = m.frame_cost(f, 1000);
            let c2 = m.frame_cost(f, 2000);
            assert_eq!(c0, m.per_frame_s);
            // affine: equal increments per coordinate block
            assert!(((c2 - c1) - (c1 - c0)).abs() < 1e-18, "{f:?}");
            assert!(c1 > c0, "{f:?} has zero per-coord cost");
        }
        // the ordering the comment promises: sign cheapest, qsgd dearest
        let d = 65_536;
        assert!(m.frame_cost(Format::SignScaled, d) < m.frame_cost(Format::DenseF32, d));
        assert!(m.frame_cost(Format::Ternary, d) < m.frame_cost(Format::Qsgd, d));
    }
}
