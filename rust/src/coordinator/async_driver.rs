//! Bounded-staleness asynchronous training driver.
//!
//! The synchronous [`TrainDriver`](super::driver::TrainDriver) is a
//! lock-step barrier: every round waits for every worker. This driver
//! replaces the barrier with a **quorum + bounded staleness** rule driven
//! by the virtual clock (see `docs/ASYNC.md` for the full semantics):
//!
//! * Workers always have exactly one frame in flight: on receiving
//!   parameters of leader round `r_w` they compute (consuming simulated
//!   time from the [`crate::net::StragglerSchedule`]) and push; the
//!   push's virtual arrival feeds the leader's [`crate::net::EventQueue`].
//! * The leader pops arrivals in deterministic `(time, node, seq)` order.
//!   Arrivals sharing one virtual timestamp form a single logical instant
//!   and are drained together before the trigger is evaluated.
//! * **Trigger:** fold as soon as (a) at least `quorum` frames are
//!   pending AND (b) advancing would leave every still-in-flight worker
//!   within `max_staleness` rounds (`r + 1 ≤ r_w + S`). Condition (b) is
//!   the SSP bound: the leader *blocks* on a straggler rather than let any
//!   frame exceed `S` rounds of staleness, so every folded frame satisfies
//!   `staleness ≤ S` by induction.
//! * **Fold:** ALL pending frames — fresh and stale alike — are combined
//!   (sorted by worker id, same fixed-group parallel decode as the sync
//!   leader), the update rule applies, the folded workers get fresh
//!   parameters, and the cycle continues. A late frame is therefore never
//!   dropped: its contribution (which, under EF, carries the worker's
//!   residual-corrected delta) always lands within the staleness bound.
//!
//! With `--quorum n --max-staleness 0` the trigger degenerates to "all
//! frames, all fresh": the driver replays the synchronous schedule and is
//! **bit-identical** to `TrainDriver` on the same seed (shared
//! [`apply_update`] and [`super::Aggregation::combine_frames`] paths;
//! asserted by `staleness_zero_matches_sync_driver`). Determinism across `--threads`
//! holds for any quorum: arrival times are pure functions of the straggler
//! schedule and link model, never of wall-clock thread interleaving.
//!
//! Under a sharded parameter server (`DriverConfig::shards > 1`, see
//! `docs/SHARDING.md`) each in-flight worker carries one frame per shard;
//! its logical arrival is the max over its shard frames, the quorum still
//! counts workers, and the fold aggregates each shard's slice with the
//! same fixed-group reduction. The shard leaders' measured decode cost is
//! added to the *reported* `sim_time_s` only — pricing it into the event
//! schedule would make the fold order depend on wall-clock decode speed
//! and break `--threads` bit-determinism.

use super::aggregate::DecodeScratch;
use super::driver::{apply_update, DriverConfig, TrainOutcome};
use super::pool::{RoundReport, WorkerPool};
use super::round::{LeaderProfile, StalenessStats};
use super::state::Snapshot;
use super::worker::Worker;
use crate::collectives::ShardedParameterServer;
use crate::compress::wire::Encoded;
use crate::metrics::Recorder;
use crate::net::{
    EventQueue, Fabric, MembershipEvent, MembershipEventKind, MembershipState, Payload, SimClock,
    TrafficStats,
};
use crate::obs::metrics::RunMetrics;
use crate::obs::trace::{DropReason, EventKind, TraceRecorder};
use std::collections::BTreeMap;
use std::sync::Arc;

/// One worker's round of frames travelling through virtual time (one wire
/// frame per parameter-server shard; a single frame when unsharded). The
/// worker's logical arrival at the distributed leader is the max over its
/// shard frames' arrivals.
struct Inflight {
    worker: usize,
    /// Leader round whose parameters the frames were computed on.
    round: u64,
    /// Membership epoch at dispatch time (always 0 without churn). A frame
    /// dispatched before its worker's revival is from a closed life of that
    /// worker and is discarded on arrival.
    epoch: u64,
    /// Per-shard frames in shard order.
    frames: Vec<Encoded>,
    report: RoundReport,
}

/// The bounded-staleness coordinator driver.
pub struct AsyncTrainDriver {
    cfg: DriverConfig,
    /// Fold as soon as this many frames are pending (clamped to 1..=n).
    quorum: usize,
    /// Maximum rounds a frame may lag when folded (SSP bound).
    max_staleness: u64,
    pool: WorkerPool,
    theta: Vec<f32>,
    fabric: Arc<Fabric>,
    sim_clock: Arc<SimClock>,
    ps: ShardedParameterServer,
    round: u64,
    momentum: Vec<f32>,
    wd_buf: Vec<f32>,
    profile: LeaderProfile,
    /// Accumulated measured leader decode+aggregate critical path. Charged
    /// on the reported total only, never on the event schedule: the
    /// schedule must stay a pure function of the seeded models so
    /// `--threads` remains bit-deterministic (wall-clock decode speed
    /// varies with the thread count).
    leader_time_s: f64,
    /// Accumulated *analytic* leader cost (Σ folds of max-over-shards
    /// modeled decode time); replaces `leader_time_s` in the reported
    /// total when `cfg.leader_cost.is_enabled()`.
    model_leader_s: f64,
    staleness: StalenessStats,
    /// Flight recorder (also reachable by the pool via the fabric).
    trace: Option<Arc<TraceRecorder>>,
    /// Metrics registry shared with the caller.
    metrics: Option<Arc<RunMetrics>>,
    /// Last sighting of the fabric's dropped-frame counter (decode drops
    /// happen on pool threads, surfaced as per-fold deltas here).
    last_dropped: u64,
    /// Elastic-membership state: live bitmap + epoch. Stays at "all live,
    /// epoch 0" when `cfg.membership` is inactive.
    membership: MembershipState,
    /// Quorum re-clamped to the live count at every epoch transition
    /// (identical to `quorum` while the fleet is full).
    effective_quorum: usize,
    /// Per worker: the membership epoch in which it departed (only
    /// meaningful while it is not live). A departed worker's in-flight
    /// frame folds while that epoch is still current and is dropped —
    /// counted in `TrafficStats::departed_frames`, traced as
    /// `frame_dropped_departed` — once a later epoch has begun.
    departed_at_epoch: Vec<u64>,
    /// Per worker: the membership epoch of its latest revival (0 = never
    /// departed). Frames dispatched before this epoch belong to a closed
    /// life of the worker and are dropped on arrival.
    revived_at_epoch: Vec<u64>,
    /// Per worker: the membership epoch of its latest dispatch (mirrors
    /// the `Inflight::epoch` stamp). A worker gates the staleness bound
    /// only while its in-flight frame is from its current life.
    dispatched_epoch: Vec<u64>,
    /// Per worker: true from dispatch until its frame folds or drops.
    /// Churn-free runs keep every worker permanently outstanding.
    outstanding: Vec<bool>,
    /// Dispatch-set scratch for churn-active folds (live ∧ ¬outstanding).
    dispatch_ids: Vec<usize>,
    /// Copy of the round's `events_at` slice (releases the borrow on
    /// `cfg.membership` before the events mutate driver state).
    event_scratch: Vec<MembershipEvent>,
    queue: EventQueue<Inflight>,
    pending: Vec<Inflight>,
    /// Per worker: leader round whose params it is computing on.
    worker_round: Vec<u64>,
    /// Per worker: number of compute steps taken (straggler cell index).
    worker_steps: Vec<u64>,
    /// Per worker: true while its frame sits in `pending`.
    in_pending: Vec<bool>,
    sim_time: f64,
    started: bool,
    // --- persistent fold scratch (the same zero-copy/recycled-buffer
    // treatment as the sync driver; see docs/PERF.md) ---
    /// Shared broadcast slices, refreshed in place per dispatch.
    bcast: Vec<Arc<[f32]>>,
    /// Per-shard frame collection reused across folds.
    frames_by_shard: Vec<Vec<Encoded>>,
    /// The fold's aggregate.
    agg: Vec<f32>,
    /// Fused-decode scratch (groups, recycled partials, shard timings).
    scratch: DecodeScratch,
}

impl AsyncTrainDriver {
    /// `quorum = 0` (or ≥ n) means "all workers"; `max_staleness = 0`
    /// forbids stale folds entirely (synchronous behaviour).
    pub fn new(
        cfg: DriverConfig,
        quorum: usize,
        max_staleness: u64,
        mut workers: Vec<Worker>,
        theta0: Vec<f32>,
    ) -> Self {
        assert!(!workers.is_empty());
        let n = workers.len();
        let d = workers[0].dim();
        assert!(workers.iter().all(|w| w.dim() == d));
        assert_eq!(theta0.len(), d);
        if quorum > n {
            // one-time: the configured quorum can never be met by a fleet
            // of n, so it silently degrades to "all workers" — say so
            log::warn!("quorum {quorum} exceeds the fleet size {n}; clamping to {n}");
        }
        let quorum = if quorum == 0 { n } else { quorum.min(n) };
        if cfg.membership.is_active() {
            if let Err(e) = cfg.membership.validate(n) {
                panic!("invalid membership schedule: {e}");
            }
        }
        let (sim_clock, fabric, ps, trace) = super::driver::build_topology(&cfg, &mut workers);
        let pool = WorkerPool::spawn_with_adversary(
            workers,
            fabric.clone(),
            cfg.threads.max(1),
            cfg.adversary.clone(),
        );
        let frames_by_shard = (0..ps.num_shards()).map(|_| Vec::new()).collect();
        let metrics = cfg.metrics.clone();
        AsyncTrainDriver {
            momentum: vec![0.0; d],
            wd_buf: vec![0.0; d],
            bcast: Vec::new(),
            frames_by_shard,
            agg: vec![0.0; d],
            scratch: DecodeScratch::default(),
            cfg,
            quorum,
            max_staleness,
            pool,
            theta: theta0,
            fabric,
            sim_clock,
            ps,
            round: 0,
            profile: LeaderProfile::default(),
            leader_time_s: 0.0,
            model_leader_s: 0.0,
            staleness: StalenessStats::default(),
            trace,
            metrics,
            last_dropped: 0,
            membership: MembershipState::new(n),
            effective_quorum: quorum,
            departed_at_epoch: vec![0; n],
            revived_at_epoch: vec![0; n],
            dispatched_epoch: vec![0; n],
            outstanding: vec![false; n],
            dispatch_ids: Vec::with_capacity(n),
            event_scratch: Vec::new(),
            queue: EventQueue::new(),
            pending: Vec::new(),
            worker_round: vec![0; n],
            worker_steps: vec![0; n],
            in_pending: vec![false; n],
            sim_time: 0.0,
            started: false,
        }
    }

    pub fn theta(&self) -> &[f32] {
        &self.theta
    }

    /// Completed folds (async rounds).
    pub fn rounds(&self) -> u64 {
        self.round
    }

    pub fn traffic(&self) -> TrafficStats {
        self.fabric.snapshot_stats()
    }

    pub fn profile(&self) -> &LeaderProfile {
        &self.profile
    }

    pub fn staleness(&self) -> &StalenessStats {
        &self.staleness
    }

    /// The leader's current virtual time (the event schedule's clock; the
    /// measured leader decode cost is reported separately via
    /// [`TrainOutcome::sim_time_s`] so the schedule stays bit-deterministic
    /// across thread counts).
    pub fn sim_time_s(&self) -> f64 {
        self.sim_time
    }

    /// Accumulated measured leader decode+aggregate critical path.
    pub fn leader_time_s(&self) -> f64 {
        self.leader_time_s
    }

    /// Full coordinator snapshot — same shape as the synchronous driver's,
    /// so `--max-staleness 0 --quorum n` runs can be compared byte for
    /// byte.
    pub fn snapshot(&self) -> Snapshot {
        let states = self.pool.export_states();
        Snapshot {
            round: self.round,
            shards: self.ps.num_shards(),
            epoch: self.membership.epoch(),
            theta: self.theta.clone(),
            worker_errors: states.iter().map(|s| s.error.clone()).collect(),
            worker_corrected: states.into_iter().map(|s| s.corrected).collect(),
        }
    }

    /// Send fresh parameters to `ids`, run their compute steps on the
    /// pool, and schedule the resulting frames' virtual arrivals.
    fn dispatch(&mut self, ids: &[usize]) {
        debug_assert!(!ids.is_empty());
        let r = self.round;
        let lr = self.cfg.schedule.lr(r as usize) as f32;
        if let Some(tr) = &self.trace {
            let t = self.sim_time;
            tr.record(tr.driver_track(), t, r, EventKind::RoundStart, ids.len() as u64);
            for s in 0..self.ps.num_shards() {
                tr.record(tr.leader_track(s), t, r, EventKind::BroadcastSent, s as u64);
            }
        }
        for &l in &self.ps.leaders {
            self.sim_clock.set_node_time(l, self.sim_time);
        }
        // θ is fixed for the whole dispatch batch: refresh the shared
        // slices once, then every recipient costs a refcount bump
        self.ps.make_broadcast(&self.theta, &mut self.bcast);
        for &w in ids {
            // params depart the leaders now; the worker's pushes depart
            // at params-arrival + compute-time, so pre-set its node time
            // before the pool thread issues the sends
            let params_arrival = self.ps.send_params_shared(&self.fabric, w, r, &self.bcast);
            let finish = params_arrival + self.cfg.straggler.compute_time(w, self.worker_steps[w]);
            self.sim_clock.set_node_time(w, finish);
            self.worker_round[w] = r;
            self.worker_steps[w] += 1;
            self.outstanding[w] = true;
            self.dispatched_epoch[w] = self.membership.epoch();
        }
        let mut reports = self.pool.step_workers(ids, r, lr);
        // collect each dispatched worker's per-shard frames from all the
        // shard-leader inboxes; the worker's logical arrival is the max
        // over its shard frames (the fold needs every slice). BTreeMap
        // iteration is src-ordered, so scheduling stays deterministic.
        let s_total = self.ps.num_shards();
        // src -> (round, per-shard frames, latest shard arrival)
        let mut per_worker = BTreeMap::new();
        for (s, &leader) in self.ps.leaders.iter().enumerate() {
            for (msg, arrival) in self.fabric.recv_all_timed(leader) {
                if let Payload::Grad(frame) = msg.payload {
                    let entry = per_worker
                        .entry(msg.src)
                        .or_insert_with(|| (msg.round, vec![None; s_total], 0.0));
                    assert_eq!(entry.0, msg.round, "worker pushed mixed rounds");
                    entry.1[s] = Some(frame);
                    entry.2 = entry.2.max(arrival);
                } else {
                    panic!("non-gradient message in async gather");
                }
            }
        }
        assert_eq!(per_worker.len(), ids.len(), "dispatched frame missing");
        for (src, (round, frames, arrival)) in per_worker {
            let idx = reports
                .iter()
                .position(|rep| rep.id == src)
                .expect("report missing for dispatched worker");
            let report = reports.swap_remove(idx);
            let frames: Vec<Encoded> = frames
                .into_iter()
                .map(|f| f.expect("missing shard frame for dispatched worker"))
                .collect();
            self.queue.schedule(
                arrival,
                src,
                Inflight {
                    worker: src,
                    round,
                    epoch: self.membership.epoch(),
                    frames,
                    report,
                },
            );
        }
    }

    fn arrive(&mut self, ev: crate::net::Event<Inflight>) {
        self.sim_time = self.sim_time.max(ev.time);
        let w = ev.payload.worker;
        // Departed-frame rule: a departed worker's in-flight frame folds
        // while the epoch it departed in is still current and is discarded
        // once a later epoch has begun; a frame dispatched before its
        // worker's latest revival belongs to a closed life of that worker
        // and is discarded too (its dispatch-time state was lost or
        // superseded). Every discard is counted in the traffic stats and
        // traced — never silently lost.
        if self.cfg.membership.is_active() {
            let discard = if self.membership.is_live(w) {
                ev.payload.epoch < self.revived_at_epoch[w]
            } else {
                self.membership.epoch() > self.departed_at_epoch[w]
            };
            if discard {
                self.outstanding[w] = false;
                self.fabric.note_departed_frame();
                if let Some(tr) = &self.trace {
                    tr.record(
                        tr.driver_track(),
                        ev.time,
                        ev.payload.round,
                        EventKind::FrameDropped(DropReason::Departed),
                        w as u64,
                    );
                }
                // a revived worker waits out its stale pre-revival frame
                // (dispatching a second frame would double-count it in a
                // fold); once that frame resolves here, the worker
                // re-enters the fleet immediately
                if self.membership.is_live(w) && self.round < self.cfg.steps as u64 {
                    let ids = [w];
                    self.dispatch(&ids);
                }
                return;
            }
        }
        if let Some(tr) = &self.trace {
            // the async leader observes arrivals on its event queue, so the
            // driver track carries them (the sync gather stamps leader
            // tracks instead)
            tr.record(
                tr.driver_track(),
                ev.time,
                ev.payload.round,
                EventKind::FrameArrived,
                w as u64,
            );
        }
        self.in_pending[w] = true;
        self.pending.push(ev.payload);
    }

    /// The quorum + bounded-staleness trigger (see module docs). Under
    /// churn the quorum is the epoch's `effective_quorum` and departed
    /// workers never gate the staleness bound: a dead worker will not push
    /// again, so blocking on it would deadlock the leader. A departed
    /// worker's frame already in `pending` still counts toward the quorum
    /// and folds with the batch.
    fn trigger(&self) -> bool {
        if self.pending.len() < self.effective_quorum {
            return false;
        }
        let churn = self.cfg.membership.is_active();
        self.worker_round.iter().enumerate().all(|(w, &rw)| {
            // a worker gates the bound only while a frame from its current
            // life is in flight: dead workers never push again, and a
            // revived worker whose only in-flight frame predates its
            // revival is waiting for that frame to resolve and drop
            self.in_pending[w]
                || (churn
                    && (!self.membership.is_live(w)
                        || !self.outstanding[w]
                        || self.dispatched_epoch[w] < self.revived_at_epoch[w]))
                || self.round + 1 <= rw + self.max_staleness
        })
    }

    /// Fold all pending frames into one parameter update.
    fn fold(&mut self, recorder: &mut Recorder) -> f64 {
        let step = self.round;
        let lr = self.cfg.schedule.lr(step as usize) as f32;
        let mut batch = std::mem::take(&mut self.pending);
        batch.sort_by_key(|b| b.worker);
        let m = batch.len();
        self.staleness.record_fold(m);
        if let Some(tr) = &self.trace {
            tr.record(tr.driver_track(), self.sim_time, step, EventKind::QuorumFold, m as u64);
        }
        for v in self.frames_by_shard.iter_mut() {
            v.clear();
        }
        let mut folded = Vec::with_capacity(m);
        let mut mean_loss = 0.0f64;
        let mut mean_err = 0.0f64;
        let mut mean_phi = 0.0f64;
        let mut mean_stale = 0.0f64;
        let churn = self.cfg.membership.is_active();
        for b in batch {
            let stale = step - b.round;
            // a departed worker's frame may fold arbitrarily late: the
            // trigger stops blocking on dead workers (they will never push
            // again), so only live workers are held to the SSP bound
            debug_assert!(
                stale <= self.max_staleness || (churn && !self.membership.is_live(b.worker)),
                "frame folded beyond the staleness bound"
            );
            self.staleness.record_frame(stale);
            if let Some(mtr) = &self.metrics {
                mtr.observe_staleness(stale);
                mtr.observe_residual(b.worker, b.report.error_norm);
            }
            mean_stale += stale as f64;
            mean_loss += b.report.loss;
            mean_err += b.report.error_norm;
            mean_phi += b.report.phi;
            self.in_pending[b.worker] = false;
            self.outstanding[b.worker] = false;
            folded.push(b.worker);
            for (s, f) in b.frames.into_iter().enumerate() {
                self.frames_by_shard[s].push(f);
            }
        }
        mean_loss /= m as f64;
        mean_err /= m as f64;
        mean_phi /= m as f64;
        mean_stale /= m as f64;

        // frame-size metrics must run before the combine drains the frames
        if let Some(mtr) = &self.metrics {
            for frames in &self.frames_by_shard {
                for f in frames {
                    mtr.observe_frame(f.format, f.bits);
                }
            }
        }
        // analytic leader pricing (same max-over-shards rule as the sync
        // driver): read (format, d) before the combine drains the frames
        if self.cfg.leader_cost.is_enabled() {
            let mut worst = 0.0f64;
            for frames in &self.frames_by_shard {
                let mut shard_cost = 0.0f64;
                for f in frames {
                    shard_cost += self.cfg.leader_cost.frame_cost(f.format, f.d);
                }
                worst = worst.max(shard_cost);
            }
            self.model_leader_s += worst;
        }
        if let Some(tr) = &self.trace {
            tr.record(tr.driver_track(), self.sim_time, step, EventKind::DecodeStart, m as u64);
        }
        self.cfg.aggregation.combine_frames_sharded_into(
            &mut self.frames_by_shard,
            &self.ps.plan,
            &self.pool,
            &mut self.agg,
            &mut self.scratch,
        );
        // price the shard leaders' decode on the reported total (critical
        // path = the slowest shard leader); see `leader_time_s` for why it
        // never feeds the event schedule
        let critical = self.profile.record_shards(&self.scratch.shard_times);
        self.leader_time_s += critical;
        self.note_dropped(step);
        if let Some(mtr) = &self.metrics {
            mtr.inc_folds();
            mtr.observe_decode_ns((critical * 1e9) as u64);
        }
        if let Some(tr) = &self.trace {
            tr.record(tr.driver_track(), self.sim_time, step, EventKind::DecodeDone, m as u64);
            tr.record(tr.driver_track(), self.sim_time, step, EventKind::AggregateDone, 0);
        }
        apply_update(
            self.cfg.update_rule,
            lr,
            self.cfg.weight_decay,
            &self.agg,
            &mut self.theta,
            &mut self.momentum,
            &mut self.wd_buf,
        );

        recorder.record("train_loss", step, mean_loss);
        recorder.record("lr", step, lr as f64);
        recorder.record("error_norm", step, mean_err);
        recorder.record("phi_corrected", step, mean_phi);
        recorder.record("batch_size", step, m as f64);
        recorder.record("staleness", step, mean_stale);
        recorder.record("sim_time_s", step, self.sim_time);

        self.round += 1;
        if churn {
            // membership events for the round the leader just advanced to
            // apply before the next dispatch, so revived workers join this
            // fold's dispatch set and departed ones leave it
            self.apply_membership(self.round);
        }
        if self.cfg.eval_every > 0 && self.round % self.cfg.eval_every as u64 == 0 {
            let (el, ea) = self.pool.eval(0, &self.theta);
            if el.is_finite() {
                recorder.record("eval_loss", step, el);
            }
            if ea.is_finite() {
                recorder.record("eval_acc", step, ea);
            }
        }
        if self.cfg.checkpoint_every > 0 && self.round % self.cfg.checkpoint_every as u64 == 0 {
            super::driver::save_checkpoint(self.cfg.checkpoint_dir.as_deref(), &self.snapshot());
            if let Some(tr) = &self.trace {
                tr.record(tr.driver_track(), self.sim_time, step, EventKind::CheckpointSaved, 0);
            }
        }
        // the folded workers pull fresh params and start their next step.
        // Under churn the next dispatch set is recomputed from scratch —
        // live workers with no frame in flight — which equals `folded`
        // exactly while the fleet is full, and additionally covers
        // revivals (no outstanding frame) while excluding departures.
        if self.round < self.cfg.steps as u64 {
            if churn {
                let mut ids = std::mem::take(&mut self.dispatch_ids);
                ids.clear();
                for w in 0..self.pool.n_workers() {
                    if self.membership.is_live(w) && !self.outstanding[w] {
                        ids.push(w);
                    }
                }
                // the set can be empty (e.g. the fold drained only a dead
                // worker's frame): every live worker already has a frame in
                // flight, so the next arrival re-evaluates the trigger
                if !ids.is_empty() {
                    self.dispatch(&ids);
                }
                self.dispatch_ids = ids;
            } else {
                self.dispatch(&folded);
            }
        }
        mean_loss
    }

    /// Apply membership events for `round` (leave/crash/rejoin/join):
    /// trace them, stamp departure epochs, advance the epoch, re-clamp the
    /// effective quorum to the live count, and cold-start revived workers
    /// whose EF state was lost (a crash, or a brand-new join). Graceful
    /// leavers keep their residual parked in their pool actor for a warm
    /// rejoin. Only called when the schedule is active.
    fn apply_membership(&mut self, round: u64) {
        let evs = self.cfg.membership.events_at(round);
        if evs.is_empty() {
            return;
        }
        // copy the (Copy) events out: the slice borrows `cfg.membership`,
        // and applying them mutates driver state
        let mut events = std::mem::take(&mut self.event_scratch);
        events.clear();
        events.extend_from_slice(evs);
        // the epoch these events open: departures stamped with it keep
        // folding until a later epoch begins
        let new_epoch = self.membership.epoch() + 1;
        for &ev in &events {
            let cold = self.membership.apply(&ev);
            if let Some(tr) = &self.trace {
                let kind = match ev.kind {
                    MembershipEventKind::Leave | MembershipEventKind::Crash => {
                        EventKind::MemberLeave
                    }
                    MembershipEventKind::Rejoin | MembershipEventKind::Join => {
                        EventKind::MemberJoin
                    }
                };
                tr.record(tr.driver_track(), self.sim_time, round, kind, ev.worker as u64);
            }
            match ev.kind {
                MembershipEventKind::Leave | MembershipEventKind::Crash => {
                    self.departed_at_epoch[ev.worker] = new_epoch;
                }
                MembershipEventKind::Rejoin | MembershipEventKind::Join => {
                    self.revived_at_epoch[ev.worker] = new_epoch;
                    if cold {
                        // fail-stop lost the residual (or a join never had
                        // one): revive with zeroed EF state
                        let d = self.theta.len();
                        self.pool.restore_states(vec![super::pool::WorkerState {
                            id: ev.worker,
                            steps: round,
                            error: vec![0.0; d],
                            corrected: vec![0.0; d],
                        }]);
                    }
                }
            }
        }
        self.event_scratch = events;
        self.membership.bump_epoch();
        self.effective_quorum = self.quorum.min(self.membership.live_count()).max(1);
        debug_assert!(
            self.effective_quorum <= self.membership.live_count(),
            "effective quorum {} exceeds the live count {}",
            self.effective_quorum,
            self.membership.live_count()
        );
    }

    /// Count newly dropped frames (decode pool threads bump the fabric's
    /// counter) into the metrics and the driver track — same single-writer
    /// ring discipline as the sync driver's `note_dropped`.
    fn note_dropped(&mut self, round: u64) {
        if self.trace.is_none() && self.metrics.is_none() {
            return;
        }
        let seen = self.fabric.with_stats(|s| s.dropped());
        let delta = seen - self.last_dropped;
        self.last_dropped = seen;
        if delta == 0 {
            return;
        }
        if let Some(mtr) = &self.metrics {
            mtr.add_dropped(delta);
        }
        if let Some(tr) = &self.trace {
            tr.record(
                tr.driver_track(),
                self.sim_time,
                round,
                EventKind::FrameDropped(DropReason::Undecodable),
                delta,
            );
        }
    }

    /// Advance the simulation until exactly one fold completes; returns
    /// the fold's mean worker loss. (The benches drive this directly.)
    pub fn step_round(&mut self, recorder: &mut Recorder) -> f64 {
        if !self.started {
            self.started = true;
            if self.cfg.membership.is_active() {
                // round-0 events (a worker can depart before the first
                // dispatch) apply before any wire traffic
                self.apply_membership(0);
                let mut all = Vec::new();
                self.membership.live_ids_into(&mut all);
                self.dispatch(&all);
            } else {
                let all: Vec<usize> = (0..self.pool.n_workers()).collect();
                self.dispatch(&all);
            }
        }
        loop {
            let ev = self
                .queue
                .pop()
                .expect("async event queue empty with rounds remaining");
            let instant = ev.time;
            self.arrive(ev);
            // drain the whole tie group: frames landing at the identical
            // virtual time form one logical instant (with a constant
            // straggler model this is what recovers the synchronous
            // schedule instead of an artificial staleness-1 resonance)
            while self.queue.peek_time() == Some(instant) {
                let tied = self.queue.pop().expect("peeked event vanished");
                self.arrive(tied);
            }
            if self.trigger() {
                return self.fold(recorder);
            }
        }
    }

    /// Run the configured number of rounds (folds).
    pub fn run(mut self) -> TrainOutcome {
        let mut recorder = Recorder::new();
        let steps = self.cfg.steps as u64;
        while self.round < steps {
            let loss = self.step_round(&mut recorder);
            let done = self.round;
            if self.cfg.log_every > 0 && (done - 1) % self.cfg.log_every as u64 == 0 {
                log::info!(
                    "async round {}: loss {loss:.4}  sim {:.3}s  stale {:.0}%",
                    done - 1,
                    self.sim_time,
                    100.0 * self.staleness.stale_fraction()
                );
            }
        }
        recorder.record("final_loss", self.round, recorder.last("train_loss"));
        let bits = self.fabric.total_bits();
        recorder.record("total_bits", self.round, bits as f64);
        // schedule time + the leaders' decode cost (the "leader compute is
        // no longer free" pricing; kept out of the event schedule for
        // thread-count determinism). Modeled when a DecodeCostModel is
        // configured, measured wall-clock otherwise.
        let leader = if self.cfg.leader_cost.is_enabled() {
            self.model_leader_s
        } else {
            self.leader_time_s
        };
        let sim_time_s = self.sim_time + leader;
        TrainOutcome {
            theta: self.theta,
            recorder,
            traffic: self.fabric.snapshot_stats(),
            rounds: self.round,
            profile: self.profile,
            sim_time_s,
            staleness: self.staleness,
            trace: self.trace,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CompressorKind;
    use crate::coordinator::driver::TrainDriver;
    use crate::coordinator::round::LrSchedule;
    use crate::coordinator::worker::{ObjectiveSource, WorkerMode};
    use crate::model::toy::SparseNoiseQuadratic;
    use crate::net::{StragglerModel, StragglerSchedule};
    use crate::util::Pcg64;

    fn quadratic_workers(n: usize, d: usize) -> Vec<Worker> {
        (0..n)
            .map(|id| {
                Worker::new(
                    id,
                    Box::new(ObjectiveSource::new(
                        SparseNoiseQuadratic::new(d, 0.5),
                        Pcg64::seeded(100 + id as u64),
                    )),
                    WorkerMode::ErrorFeedback,
                    CompressorKind::ScaledSign,
                    4,
                    4,
                    Pcg64::seeded(id as u64),
                )
            })
            .collect()
    }

    fn lognormal(sigma: f64) -> StragglerSchedule {
        StragglerSchedule::new(1e-3, StragglerModel::LogNormal { sigma }, 42)
    }

    #[test]
    fn full_quorum_zero_staleness_equals_sync() {
        let d = 32;
        let steps = 25;
        let cfg = || DriverConfig {
            steps,
            schedule: LrSchedule::new(0.1, steps, vec![0.5]),
            straggler: lognormal(1.0),
            ..Default::default()
        };
        let mut sync = TrainDriver::new(cfg(), quadratic_workers(4, d), vec![1.0f32; d]);
        let mut rec = Recorder::new();
        for _ in 0..steps {
            sync.round(&mut rec);
        }
        let mut asynch = AsyncTrainDriver::new(cfg(), 4, 0, quadratic_workers(4, d), vec![1.0f32; d]);
        let mut rec2 = Recorder::new();
        for _ in 0..steps {
            asynch.step_round(&mut rec2);
        }
        let a = sync.snapshot();
        let b = asynch.snapshot();
        assert_eq!(a.round, b.round);
        assert_eq!(a.theta, b.theta);
        assert_eq!(a.worker_errors, b.worker_errors);
        assert_eq!(a.worker_corrected, b.worker_corrected);
        // with S = 0 nothing stale was ever folded, in full batches
        assert_eq!(asynch.staleness().stale_frames, 0);
        assert_eq!(asynch.staleness().max_batch, 4);
    }

    #[test]
    fn quorum_runs_make_progress_and_respect_bound() {
        let d = 32;
        let steps = 60;
        let cfg = DriverConfig {
            steps,
            schedule: LrSchedule::constant(0.1),
            straggler: lognormal(1.5),
            ..Default::default()
        };
        let out = AsyncTrainDriver::new(cfg, 2, 3, quadratic_workers(5, d), vec![1.0f32; d]).run();
        assert_eq!(out.rounds, steps as u64);
        assert_eq!(out.staleness.folds, steps as u64);
        // the SSP bound held at every fold
        assert!(out.staleness.max_staleness_seen <= 3);
        // heavy-tail stragglers + partial quorum actually produced
        // staleness (otherwise this test tests nothing)
        assert!(out.staleness.stale_frames > 0, "no staleness exercised");
        // virtual time advanced monotonically and is positive
        assert!(out.sim_time_s > 0.0);
        // descent happened despite stale folds
        let losses = &out.recorder.get("train_loss").unwrap().values;
        assert!(losses.last().unwrap() < &(losses.first().unwrap() * 0.5));
    }

    #[test]
    fn constant_stragglers_fold_full_batches() {
        // equal compute times ⇒ every fold is one logical instant with all
        // n frames, regardless of quorum: the tie-group drain recovers the
        // synchronous schedule
        let d = 16;
        let cfg = DriverConfig {
            steps: 10,
            schedule: LrSchedule::constant(0.1),
            straggler: StragglerSchedule::new(1e-3, StragglerModel::Constant, 0),
            ..Default::default()
        };
        let out = AsyncTrainDriver::new(cfg, 2, 4, quadratic_workers(4, d), vec![1.0f32; d]).run();
        assert_eq!(out.staleness.max_batch, 4);
        assert_eq!(out.staleness.stale_frames, 0);
        assert!((out.staleness.mean_batch() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn sharded_async_quorum_descends_and_respects_bound() {
        let d = 48;
        let steps = 40;
        let cfg = DriverConfig {
            steps,
            schedule: LrSchedule::constant(0.1),
            straggler: lognormal(1.5),
            shards: 3,
            ..Default::default()
        };
        let out = AsyncTrainDriver::new(cfg, 2, 3, quadratic_workers(5, d), vec![1.0f32; d]).run();
        assert_eq!(out.rounds, steps as u64);
        assert!(out.staleness.max_staleness_seen <= 3);
        // every fold priced all three shard leaders
        assert_eq!(out.profile.per_shard_s.len(), 3);
        // reported time = schedule + measured leader cost
        assert!(out.sim_time_s > 0.0);
        let losses = &out.recorder.get("train_loss").unwrap().values;
        assert!(losses.last().unwrap() < &(losses.first().unwrap() * 0.5));
    }

    #[test]
    fn churn_crash_rejoin_completes_and_drops_closed_epoch_frames() {
        use crate::net::MembershipSchedule;
        let d = 16;
        let n = 4;
        let steps = 30;
        let cfg = DriverConfig {
            steps,
            schedule: LrSchedule::constant(0.05),
            // worker 1 is two hundred times slower than the fleet: its
            // first frame is still on the wire long after its crash epoch
            // has closed, forcing the departed-drop path
            straggler: StragglerSchedule::new(
                1e-3,
                StragglerModel::FailSlow {
                    node: 1,
                    factor: 200.0,
                },
                0,
            ),
            membership: MembershipSchedule::parse("crash:1@2,leave:2@4,rejoin:2@8,rejoin:1@10")
                .unwrap(),
            ..Default::default()
        };
        let out = AsyncTrainDriver::new(cfg, 2, 3, quadratic_workers(n, d), vec![1.0f32; d]).run();
        assert_eq!(out.rounds, steps as u64);
        // the crashed worker's in-flight frame arrived after a later
        // membership epoch began, so it was discarded and accounted
        assert!(
            out.traffic.departed() >= 1,
            "expected at least one departed-frame drop, saw {}",
            out.traffic.departed()
        );
        // training still descended through the churn
        let losses = &out.recorder.get("train_loss").unwrap().values;
        assert!(losses.last().unwrap() < losses.first().unwrap());
    }

    #[test]
    fn failslow_node_is_bounded_not_dropped() {
        let d = 16;
        let n = 4;
        let steps = 40;
        let cfg = DriverConfig {
            steps,
            schedule: LrSchedule::constant(0.05),
            straggler: StragglerSchedule::new(
                1e-3,
                StragglerModel::FailSlow {
                    node: 1,
                    factor: 16.0,
                },
                0,
            ),
            ..Default::default()
        };
        let out =
            AsyncTrainDriver::new(cfg, n - 1, 2, quadratic_workers(n, d), vec![1.0f32; d]).run();
        // the slow node stayed within the staleness bound...
        assert!(out.staleness.max_staleness_seen <= 2);
        // ...and still contributed frames (bounded staleness blocks the
        // leader rather than abandoning the straggler)
        assert!(out.staleness.stale_frames > 0);
        assert_eq!(out.rounds, steps as u64);
    }
}
