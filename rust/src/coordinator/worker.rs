//! The worker: owns a data shard (through its [`GradSource`]), its
//! error-feedback state, and the wire encoding of its updates.

use crate::compress::wire::{self, Encoded};
use crate::compress::{self, ErrorFeedback};
use crate::config::CompressorKind;
use crate::model::StochasticObjective;
use crate::util::Pcg64;

/// Where a worker's gradients come from: a native objective or the PJRT
/// transformer session. Implementations own their data shard and RNG.
///
/// `Send` is required so workers can be moved onto the coordinator's
/// worker-pool threads ([`crate::coordinator::pool`]); shared pieces
/// (model, corpus, compiled session) go behind `Arc`.
pub trait GradSource: Send {
    fn dim(&self) -> usize;

    /// Compute a stochastic gradient of the shard loss at `theta` into
    /// `out`; returns the minibatch loss.
    fn grad(&mut self, theta: &[f32], out: &mut [f32]) -> f64;

    /// Held-out loss (NaN if not supported).
    fn eval_loss(&mut self, _theta: &[f32]) -> f64 {
        f64::NAN
    }

    /// Held-out accuracy (NaN if not supported).
    fn eval_acc(&mut self, _theta: &[f32]) -> f64 {
        f64::NAN
    }
}

/// Adapts any [`StochasticObjective`] (native models) into a GradSource.
pub struct ObjectiveSource<O: StochasticObjective> {
    pub obj: O,
    pub rng: Pcg64,
}

impl<O: StochasticObjective> ObjectiveSource<O> {
    pub fn new(obj: O, rng: Pcg64) -> Self {
        ObjectiveSource { obj, rng }
    }
}

impl<O: StochasticObjective + Send> GradSource for ObjectiveSource<O> {
    fn dim(&self) -> usize {
        self.obj.dim()
    }

    fn grad(&mut self, theta: &[f32], out: &mut [f32]) -> f64 {
        self.obj.stoch_grad(theta, &mut self.rng, out)
    }

    fn eval_loss(&mut self, theta: &[f32]) -> f64 {
        self.obj.loss(theta)
    }
}

/// How the worker participates in a round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkerMode {
    /// EF compression of γg (Algorithm 2): push Δ, keep residual.
    ErrorFeedback,
    /// Plain compression of γg, residual discarded (the non-EF baselines).
    PlainCompress,
    /// Push the raw gradient g (γ applied at the leader) — the dense
    /// SGD/SGDM baseline.
    DenseGrad,
    /// Push sign(g) for leader-side majority vote (multi-worker SIGNSGD).
    SignVote,
}

/// One worker's full per-round pipeline.
pub struct Worker {
    pub id: usize,
    pub mode: WorkerMode,
    source: Box<dyn GradSource>,
    ef: ErrorFeedback,
    kind: CompressorKind,
    qsgd_levels: u32,
    rng: Pcg64,
    grad_buf: Vec<f32>,
    delta_buf: Vec<f32>,
    /// Instrumentation from the last step.
    pub last_loss: f64,
    pub last_phi: f64,
    pub last_grad_density: f64,
}

impl Worker {
    pub fn new(
        id: usize,
        source: Box<dyn GradSource>,
        mode: WorkerMode,
        kind: CompressorKind,
        k_frac: usize,
        qsgd_levels: u32,
        mut rng: Pcg64,
    ) -> Self {
        let d = source.dim();
        let compressor = match mode {
            WorkerMode::DenseGrad => compress::build(CompressorKind::None, d, k_frac, qsgd_levels),
            WorkerMode::SignVote => compress::build(CompressorKind::Sign, d, k_frac, qsgd_levels),
            _ => compress::build(kind, d, k_frac, qsgd_levels),
        };
        let ef = if mode == WorkerMode::ErrorFeedback {
            ErrorFeedback::new(d, compressor)
        } else {
            ErrorFeedback::disabled(d, compressor)
        };
        let _ = rng.next_u64(); // decorrelate stream from the id-seed
        Worker {
            id,
            mode,
            source,
            ef,
            kind,
            qsgd_levels,
            rng,
            grad_buf: vec![0.0; d],
            delta_buf: vec![0.0; d],
            last_loss: f64::NAN,
            last_phi: f64::NAN,
            last_grad_density: f64::NAN,
        }
    }

    pub fn dim(&self) -> usize {
        self.grad_buf.len()
    }

    pub fn error_norm(&self) -> f64 {
        self.ef.error_norm()
    }

    pub fn ef_state(&self) -> &ErrorFeedback {
        &self.ef
    }

    pub fn ef_state_mut(&mut self) -> &mut ErrorFeedback {
        &mut self.ef
    }

    pub fn source_mut(&mut self) -> &mut dyn GradSource {
        self.source.as_mut()
    }

    /// Run one round: compute gradient at `theta`, compress (per mode),
    /// return the encoded wire message.
    pub fn step_encode(&mut self, theta: &[f32], gamma: f32) -> Encoded {
        self.last_loss = self.source.grad(theta, &mut self.grad_buf);
        self.last_grad_density = crate::tensor::density(&self.grad_buf);
        // DenseGrad/SignVote push the raw (γ-free) transform of g.
        let step_gamma = match self.mode {
            WorkerMode::DenseGrad | WorkerMode::SignVote => 1.0,
            _ => gamma,
        };
        self.last_phi =
            self.ef
                .step_into(step_gamma, &self.grad_buf, &mut self.delta_buf, &mut self.rng);
        self.encode()
    }

    /// Pick the wire format matching the compressor semantics.
    fn encode(&self) -> Encoded {
        match self.mode {
            WorkerMode::DenseGrad => wire::encode_dense(&self.delta_buf),
            WorkerMode::SignVote => wire::encode_scaled_sign(&self.delta_buf),
            _ => match self.kind {
                CompressorKind::ScaledSign => wire::encode_scaled_sign(self.ef.corrected()),
                CompressorKind::Sign => wire::encode_scaled_sign(&self.delta_buf),
                CompressorKind::TopK | CompressorKind::RandomK => {
                    wire::encode_sparse(&self.delta_buf)
                }
                CompressorKind::TernGrad => wire::encode_ternary(&self.delta_buf),
                // QSGD travels as the Elias-gamma level pack. The codec
                // needs the exact f32 norm the quantizer used; that is
                // ‖p‖₂ of the error-corrected gradient the compressor saw
                // (`corrected()` is valid in both EF and plain modes).
                CompressorKind::Qsgd => {
                    let norm = crate::tensor::norm2(self.ef.corrected()) as f32;
                    let enc = wire::encode_qsgd(&self.delta_buf, norm, self.qsgd_levels);
                    // The pack reconstructs levels by dividing the delta
                    // back out by `norm`, which is only exact because the
                    // quantizer computed the identical `norm2(p) as f32`
                    // over `corrected()`. Guard that contract (e.g. against
                    // a future blocked/SIMD norm2 or a rescaling wrapper)
                    // where drift would otherwise corrupt training silently.
                    debug_assert!(
                        wire::decode_qsgd(&enc)
                            .map(|dec| dec == self.delta_buf)
                            .unwrap_or(false),
                        "qsgd wire pack is not bit-faithful to the quantized delta"
                    );
                    enc
                }
                CompressorKind::None => wire::encode_dense(&self.delta_buf),
            },
        }
    }

    pub fn eval_loss(&mut self, theta: &[f32]) -> f64 {
        self.source.eval_loss(theta)
    }

    pub fn eval_acc(&mut self, theta: &[f32]) -> f64 {
        self.source.eval_acc(theta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::toy::SparseNoiseQuadratic;

    fn make_worker(mode: WorkerMode, kind: CompressorKind) -> Worker {
        let obj = SparseNoiseQuadratic::new(32, 0.0);
        Worker::new(
            0,
            Box::new(ObjectiveSource::new(obj, Pcg64::seeded(1))),
            mode,
            kind,
            4,
            4,
            Pcg64::seeded(2),
        )
    }

    #[test]
    fn ef_worker_roundtrip_decodes_to_delta() {
        let mut w = make_worker(WorkerMode::ErrorFeedback, CompressorKind::ScaledSign);
        // non-constant magnitudes so the scaled sign is lossy (phi < 1)
        let theta: Vec<f32> = (0..32).map(|i| 0.1 + i as f32 * 0.2).collect();
        let enc = w.step_encode(&theta, 0.1);
        let decoded = wire::decode_any(&enc).unwrap();
        // decoded == compressed delta (zero-free gaussian-ish p)
        for (d, e) in decoded.iter().zip(&w.delta_buf) {
            assert!((d - e).abs() < 1e-6);
        }
        assert!(w.error_norm() > 0.0); // residual retained
        assert!(w.last_phi > 0.0 && w.last_phi <= 1.0);
    }

    #[test]
    fn plain_worker_has_zero_error() {
        let mut w = make_worker(WorkerMode::PlainCompress, CompressorKind::ScaledSign);
        let theta = vec![1.0f32; 32];
        let _ = w.step_encode(&theta, 0.1);
        assert_eq!(w.error_norm(), 0.0);
    }

    #[test]
    fn dense_worker_sends_raw_gradient() {
        let mut w = make_worker(WorkerMode::DenseGrad, CompressorKind::None);
        let theta = vec![2.0f32; 32];
        let enc = w.step_encode(&theta, 0.1);
        let decoded = wire::decode_any(&enc).unwrap();
        // gradient of 1/2||x||^2 is x (noise std 0)
        for (d, t) in decoded.iter().zip(&theta) {
            assert!((d - t).abs() < 1e-6);
        }
        assert_eq!(enc.bits, 32 * 32);
    }

    #[test]
    fn sign_vote_worker_sends_unit_signs() {
        let mut w = make_worker(WorkerMode::SignVote, CompressorKind::Sign);
        let theta = vec![3.0f32; 32];
        let enc = w.step_encode(&theta, 0.1);
        assert_eq!(enc.bits, 32 + 32); // d sign bits + scale
        let decoded = wire::decode_any(&enc).unwrap();
        // all-positive grad: decode ≈ +1 each
        for d in &decoded {
            assert!((d - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn qsgd_worker_encodes_elias_pack_exactly() {
        let mut w = make_worker(WorkerMode::ErrorFeedback, CompressorKind::Qsgd);
        let theta: Vec<f32> = (0..32).map(|i| 0.3 + (i as f32 * 0.17).sin()).collect();
        let enc = w.step_encode(&theta, 0.1);
        assert_eq!(enc.format, wire::Format::Qsgd);
        // far below the 32*d dense payload
        assert!(enc.bits < 32 * 32);
        // the decode is bit-faithful to the quantized delta the EF state saw
        let decoded = wire::decode_any(&enc).unwrap();
        for (d, e) in decoded.iter().zip(&w.delta_buf) {
            assert_eq!(*d, *e);
        }
    }

    #[test]
    fn sparse_worker_encodes_sparse() {
        let mut w = make_worker(WorkerMode::ErrorFeedback, CompressorKind::TopK);
        let theta: Vec<f32> = (0..32).map(|i| i as f32).collect();
        let enc = w.step_encode(&theta, 0.1);
        assert_eq!(enc.format, wire::Format::SparseIdxVal);
        let decoded = wire::decode_any(&enc).unwrap();
        assert_eq!(decoded.iter().filter(|v| **v != 0.0).count(), 8); // d/k_frac
    }
}
