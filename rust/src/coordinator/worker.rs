//! The worker: owns a data shard (through its [`GradSource`]), its
//! error-feedback state, and the wire encoding of its updates.
//!
//! Under a sharded parameter server (`collectives::shard`) the worker's
//! compression pipeline partitions by coordinate blocks: one compressor +
//! EF residual per shard, per-shard scales/norms, and one tagged wire
//! frame per shard (blockwise error feedback, Zheng et al. 2019). The
//! single-shard plan reproduces the historical full-vector pipeline byte
//! for byte.

use crate::collectives::ShardPlan;
use crate::compress::wire::{self, Encoded};
use crate::compress::{self, ErrorFeedback};
use crate::config::CompressorKind;
use crate::model::StochasticObjective;
use crate::net::FramePool;
use crate::util::Pcg64;

/// Where a worker's gradients come from: a native objective or the PJRT
/// transformer session. Implementations own their data shard and RNG.
///
/// `Send` is required so workers can be moved onto the coordinator's
/// worker-pool threads ([`crate::coordinator::pool`]); shared pieces
/// (model, corpus, compiled session) go behind `Arc`.
pub trait GradSource: Send {
    fn dim(&self) -> usize;

    /// Compute a stochastic gradient of the shard loss at `theta` into
    /// `out`; returns the minibatch loss.
    fn grad(&mut self, theta: &[f32], out: &mut [f32]) -> f64;

    /// Held-out loss (NaN if not supported).
    fn eval_loss(&mut self, _theta: &[f32]) -> f64 {
        f64::NAN
    }

    /// Held-out accuracy (NaN if not supported).
    fn eval_acc(&mut self, _theta: &[f32]) -> f64 {
        f64::NAN
    }
}

/// Adapts any [`StochasticObjective`] (native models) into a GradSource.
pub struct ObjectiveSource<O: StochasticObjective> {
    pub obj: O,
    pub rng: Pcg64,
}

impl<O: StochasticObjective> ObjectiveSource<O> {
    pub fn new(obj: O, rng: Pcg64) -> Self {
        ObjectiveSource { obj, rng }
    }
}

impl<O: StochasticObjective + Send> GradSource for ObjectiveSource<O> {
    fn dim(&self) -> usize {
        self.obj.dim()
    }

    fn grad(&mut self, theta: &[f32], out: &mut [f32]) -> f64 {
        self.obj.stoch_grad(theta, &mut self.rng, out)
    }

    fn eval_loss(&mut self, theta: &[f32]) -> f64 {
        self.obj.loss(theta)
    }
}

/// How the worker participates in a round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkerMode {
    /// EF compression of γg (Algorithm 2): push Δ, keep residual.
    ErrorFeedback,
    /// Plain compression of γg, residual discarded (the non-EF baselines).
    PlainCompress,
    /// Push the raw gradient g (γ applied at the leader) — the dense
    /// SGD/SGDM baseline.
    DenseGrad,
    /// Push sign(g) for leader-side majority vote (multi-worker SIGNSGD).
    SignVote,
}

/// Build the EF state (compressor + residual) for one coordinate block.
fn build_ef(
    mode: WorkerMode,
    kind: CompressorKind,
    d: usize,
    k_frac: usize,
    qsgd_levels: u32,
) -> ErrorFeedback {
    let compressor = match mode {
        WorkerMode::DenseGrad => compress::build(CompressorKind::None, d, k_frac, qsgd_levels),
        WorkerMode::SignVote => compress::build(CompressorKind::Sign, d, k_frac, qsgd_levels),
        _ => compress::build(kind, d, k_frac, qsgd_levels),
    };
    if mode == WorkerMode::ErrorFeedback {
        ErrorFeedback::new(d, compressor)
    } else {
        ErrorFeedback::disabled(d, compressor)
    }
}

/// One worker's full per-round pipeline.
pub struct Worker {
    pub id: usize,
    pub mode: WorkerMode,
    source: Box<dyn GradSource>,
    /// One EF state per parameter-server shard (a single entry when
    /// unsharded); entry `s` covers `plan.range(s)` of the model vector.
    efs: Vec<ErrorFeedback>,
    plan: ShardPlan,
    kind: CompressorKind,
    k_frac: usize,
    qsgd_levels: u32,
    rng: Pcg64,
    grad_buf: Vec<f32>,
    delta_buf: Vec<f32>,
    /// Instrumentation from the last step.
    pub last_loss: f64,
    pub last_phi: f64,
    pub last_grad_density: f64,
}

impl Worker {
    pub fn new(
        id: usize,
        source: Box<dyn GradSource>,
        mode: WorkerMode,
        kind: CompressorKind,
        k_frac: usize,
        qsgd_levels: u32,
        mut rng: Pcg64,
    ) -> Self {
        let d = source.dim();
        let ef = build_ef(mode, kind, d, k_frac, qsgd_levels);
        let _ = rng.next_u64(); // decorrelate stream from the id-seed
        Worker {
            id,
            mode,
            source,
            efs: vec![ef],
            plan: ShardPlan::single(d),
            kind,
            k_frac,
            qsgd_levels,
            rng,
            grad_buf: vec![0.0; d],
            delta_buf: vec![0.0; d],
            last_loss: f64::NAN,
            last_phi: f64::NAN,
            last_grad_density: f64::NAN,
        }
    }

    pub fn dim(&self) -> usize {
        self.grad_buf.len()
    }

    /// The shard plan this worker's compression pipeline is partitioned on.
    pub fn shard_plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// Re-partition the compressor + EF state onto `plan`'s coordinate
    /// shards (blockwise error feedback). Only valid before the first
    /// step: residuals are all-zero then, so no state is lost by
    /// re-slicing. Top-k/random-k keep counts and QSGD/sign scales become
    /// per-shard quantities from here on.
    pub fn set_shard_plan(&mut self, plan: ShardPlan) {
        assert_eq!(plan.dim(), self.dim(), "shard plan dim mismatch");
        assert!(
            self.efs.iter().all(|ef| ef.steps() == 0),
            "cannot re-shard a worker that has already stepped"
        );
        let mut efs = Vec::with_capacity(plan.num_shards());
        for s in 0..plan.num_shards() {
            let mut ef = build_ef(
                self.mode,
                self.kind,
                plan.len_of(s),
                self.k_frac,
                self.qsgd_levels,
            );
            // phi(p) is recombined across shards by step_compress; skip
            // the per-shard density pass inside each EF step
            if plan.num_shards() > 1 {
                ef.set_track_density(false);
            }
            efs.push(ef);
        }
        self.efs = efs;
        self.plan = plan;
    }

    /// ℓ₂ norm of the full EF residual (recombined across shards).
    pub fn error_norm(&self) -> f64 {
        if self.efs.len() == 1 {
            return self.efs[0].error_norm();
        }
        self.efs
            .iter()
            .map(|ef| crate::tensor::norm2_sq(ef.error()))
            .sum::<f64>()
            .sqrt()
    }

    /// EF steps taken (identical across this worker's shard states).
    pub fn steps(&self) -> u64 {
        self.efs[0].steps()
    }

    /// Full-length EF residual `e` — shards are contiguous, so per-shard
    /// residuals concatenate to the model-length vector.
    pub fn export_error(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.dim());
        for ef in &self.efs {
            out.extend_from_slice(ef.error());
        }
        out
    }

    /// Full-length corrected gradient `p` of the last completed step.
    pub fn export_corrected(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.dim());
        for ef in &self.efs {
            out.extend_from_slice(ef.corrected());
        }
        out
    }

    /// Restore EF state from full-length vectors (the checkpoint path);
    /// each shard takes its slice.
    pub fn restore_ef_state(&mut self, steps: u64, error: &[f32], corrected: &[f32]) {
        assert_eq!(error.len(), self.dim(), "residual dim mismatch");
        assert_eq!(corrected.len(), self.dim(), "corrected dim mismatch");
        for s in 0..self.efs.len() {
            let r = self.plan.range(s);
            self.efs[s].set_state(steps, &error[r.clone()], &corrected[r]);
        }
    }

    /// The single-shard EF state (panics when sharded — use the
    /// export/restore helpers, which work for any plan).
    pub fn ef_state(&self) -> &ErrorFeedback {
        assert_eq!(self.efs.len(), 1, "ef_state() on a sharded worker");
        &self.efs[0]
    }

    pub fn ef_state_mut(&mut self) -> &mut ErrorFeedback {
        assert_eq!(self.efs.len(), 1, "ef_state_mut() on a sharded worker");
        &mut self.efs[0]
    }

    pub fn source_mut(&mut self) -> &mut dyn GradSource {
        self.source.as_mut()
    }

    /// Run one round: compute gradient at `theta`, compress (per mode),
    /// return the encoded wire message. Single-shard workers only; the
    /// sharded pipeline is [`step_encode_sharded`](Self::step_encode_sharded).
    pub fn step_encode(&mut self, theta: &[f32], gamma: f32) -> Encoded {
        assert_eq!(
            self.plan.num_shards(),
            1,
            "sharded workers push one frame per shard: use step_encode_sharded"
        );
        self.step_compress(theta, gamma);
        self.encode_shard(0)
    }

    /// Run one round under the sharded parameter server: compute the
    /// gradient once, then per shard run Algorithm 2 on the slice and
    /// encode one (tagged) wire frame into `out` (cleared first), in shard
    /// order. Frame byte buffers come from `bufs` — the fabric's recycling
    /// pool — so the steady-state encode path allocates nothing: the
    /// leader returns every decoded frame's buffer to the pool and this
    /// takes them back. With a single-shard plan the frames are exactly
    /// [`step_encode`]'s, byte for byte.
    // detlint: hot
    pub fn step_encode_sharded_into(
        &mut self,
        theta: &[f32],
        gamma: f32,
        bufs: &FramePool,
        out: &mut Vec<Encoded>,
    ) {
        self.step_compress(theta, gamma);
        out.clear();
        for s in 0..self.plan.num_shards() {
            let mut enc = Encoded::recycled(bufs.take());
            self.encode_shard_into(s, &mut enc);
            out.push(enc);
        }
    }

    /// Allocating wrapper around
    /// [`step_encode_sharded_into`](Self::step_encode_sharded_into).
    pub fn step_encode_sharded(&mut self, theta: &[f32], gamma: f32) -> Vec<Encoded> {
        let bufs = FramePool::default();
        let mut out = Vec::new();
        self.step_encode_sharded_into(theta, gamma, &bufs, &mut out);
        out
    }

    /// Gradient + per-shard EF compression for one round (shared by the
    /// sharded and unsharded encode paths).
    fn step_compress(&mut self, theta: &[f32], gamma: f32) {
        self.last_loss = self.source.grad(theta, &mut self.grad_buf);
        self.last_grad_density = crate::tensor::density(&self.grad_buf);
        // DenseGrad/SignVote push the raw (γ-free) transform of g.
        let step_gamma = match self.mode {
            WorkerMode::DenseGrad | WorkerMode::SignVote => 1.0,
            _ => gamma,
        };
        if self.efs.len() == 1 {
            // single-shard fast path: byte-identical to the historical
            // full-vector step
            self.last_phi = self.efs[0].step_into(
                step_gamma,
                &self.grad_buf,
                &mut self.delta_buf,
                &mut self.rng,
            );
            return;
        }
        // blockwise EF: each shard runs Algorithm 2 lines 5-8 on its own
        // coordinate slice (per-shard scales and norms). The worker RNG is
        // consumed in shard order, so the stream is a pure function of the
        // plan. phi(p) is recombined from the per-shard L1/L2 sums so it
        // still describes the full corrected gradient.
        let mut l1 = 0.0f64;
        let mut l2 = 0.0f64;
        for s in 0..self.efs.len() {
            let r = self.plan.range(s);
            let _ = self.efs[s].step_into(
                step_gamma,
                &self.grad_buf[r.clone()],
                &mut self.delta_buf[r],
                &mut self.rng,
            );
            let (sl1, sl2) = crate::tensor::norm1_norm2_sq(self.efs[s].corrected());
            l1 += sl1;
            l2 += sl2;
        }
        self.last_phi = if l2 == 0.0 {
            1.0
        } else {
            l1 * l1 / (self.dim() as f64 * l2)
        };
    }

    /// Encode shard `s`'s delta with the wire format matching the
    /// compressor semantics, into a caller-owned frame (its byte buffer is
    /// reused); sharded frames carry the 48-bit shard tag, single-shard
    /// frames stay untagged (the historical wire format).
    fn encode_shard_into(&self, s: usize, enc: &mut Encoded) {
        let r = self.plan.range(s);
        let delta = &self.delta_buf[r.clone()];
        let ef = &self.efs[s];
        match self.mode {
            WorkerMode::DenseGrad => wire::encode_dense_into(delta, enc),
            WorkerMode::SignVote => wire::encode_scaled_sign_into(delta, enc),
            _ => match self.kind {
                CompressorKind::ScaledSign => wire::encode_scaled_sign_into(ef.corrected(), enc),
                CompressorKind::Sign => wire::encode_scaled_sign_into(delta, enc),
                CompressorKind::TopK | CompressorKind::RandomK => {
                    wire::encode_sparse_into(delta, enc)
                }
                CompressorKind::TernGrad => wire::encode_ternary_into(delta, enc),
                // QSGD travels as the Elias-gamma level pack. The codec
                // needs the exact f32 norm the quantizer used; that is
                // ‖p‖₂ of the error-corrected gradient the compressor saw
                // (`corrected()` is valid in both EF and plain modes) —
                // per shard, because the shard's quantizer only ever saw
                // its own slice.
                CompressorKind::Qsgd => {
                    let norm = crate::tensor::norm2(ef.corrected()) as f32;
                    wire::encode_qsgd_into(delta, norm, self.qsgd_levels, enc);
                    // The pack reconstructs levels by dividing the delta
                    // back out by `norm`, which is only exact because the
                    // quantizer computed the identical `norm2(p) as f32`
                    // over `corrected()`. Guard that contract (e.g. against
                    // a future blocked/SIMD norm2 or a rescaling wrapper)
                    // where drift would otherwise corrupt training silently.
                    debug_assert!(
                        wire::decode_qsgd(enc)
                            .map(|dec| dec == delta)
                            .unwrap_or(false),
                        "qsgd wire pack is not bit-faithful to the quantized delta"
                    );
                }
                CompressorKind::None => wire::encode_dense_into(delta, enc),
            },
        }
        if self.plan.num_shards() > 1 {
            enc.set_shard(s as u16, r.start as u32);
        }
    }

    /// Allocating wrapper around [`encode_shard_into`](Self::encode_shard_into).
    fn encode_shard(&self, s: usize) -> Encoded {
        let mut enc = Encoded::recycled(Vec::new());
        self.encode_shard_into(s, &mut enc);
        enc
    }

    pub fn eval_loss(&mut self, theta: &[f32]) -> f64 {
        self.source.eval_loss(theta)
    }

    pub fn eval_acc(&mut self, theta: &[f32]) -> f64 {
        self.source.eval_acc(theta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::toy::SparseNoiseQuadratic;

    fn make_worker(mode: WorkerMode, kind: CompressorKind) -> Worker {
        let obj = SparseNoiseQuadratic::new(32, 0.0);
        Worker::new(
            0,
            Box::new(ObjectiveSource::new(obj, Pcg64::seeded(1))),
            mode,
            kind,
            4,
            4,
            Pcg64::seeded(2),
        )
    }

    #[test]
    fn ef_worker_roundtrip_decodes_to_delta() {
        let mut w = make_worker(WorkerMode::ErrorFeedback, CompressorKind::ScaledSign);
        // non-constant magnitudes so the scaled sign is lossy (phi < 1)
        let theta: Vec<f32> = (0..32).map(|i| 0.1 + i as f32 * 0.2).collect();
        let enc = w.step_encode(&theta, 0.1);
        let decoded = wire::decode_any(&enc).unwrap();
        // decoded == compressed delta (zero-free gaussian-ish p)
        for (d, e) in decoded.iter().zip(&w.delta_buf) {
            assert!((d - e).abs() < 1e-6);
        }
        assert!(w.error_norm() > 0.0); // residual retained
        assert!(w.last_phi > 0.0 && w.last_phi <= 1.0);
    }

    #[test]
    fn plain_worker_has_zero_error() {
        let mut w = make_worker(WorkerMode::PlainCompress, CompressorKind::ScaledSign);
        let theta = vec![1.0f32; 32];
        let _ = w.step_encode(&theta, 0.1);
        assert_eq!(w.error_norm(), 0.0);
    }

    #[test]
    fn dense_worker_sends_raw_gradient() {
        let mut w = make_worker(WorkerMode::DenseGrad, CompressorKind::None);
        let theta = vec![2.0f32; 32];
        let enc = w.step_encode(&theta, 0.1);
        let decoded = wire::decode_any(&enc).unwrap();
        // gradient of 1/2||x||^2 is x (noise std 0)
        for (d, t) in decoded.iter().zip(&theta) {
            assert!((d - t).abs() < 1e-6);
        }
        assert_eq!(enc.bits, 32 * 32);
    }

    #[test]
    fn sign_vote_worker_sends_unit_signs() {
        let mut w = make_worker(WorkerMode::SignVote, CompressorKind::Sign);
        let theta = vec![3.0f32; 32];
        let enc = w.step_encode(&theta, 0.1);
        assert_eq!(enc.bits, 32 + 32); // d sign bits + scale
        let decoded = wire::decode_any(&enc).unwrap();
        // all-positive grad: decode ≈ +1 each
        for d in &decoded {
            assert!((d - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn qsgd_worker_encodes_elias_pack_exactly() {
        let mut w = make_worker(WorkerMode::ErrorFeedback, CompressorKind::Qsgd);
        let theta: Vec<f32> = (0..32).map(|i| 0.3 + (i as f32 * 0.17).sin()).collect();
        let enc = w.step_encode(&theta, 0.1);
        assert_eq!(enc.format, wire::Format::Qsgd);
        // far below the 32*d dense payload
        assert!(enc.bits < 32 * 32);
        // the decode is bit-faithful to the quantized delta the EF state saw
        let decoded = wire::decode_any(&enc).unwrap();
        for (d, e) in decoded.iter().zip(&w.delta_buf) {
            assert_eq!(*d, *e);
        }
    }

    #[test]
    fn sparse_worker_encodes_sparse() {
        let mut w = make_worker(WorkerMode::ErrorFeedback, CompressorKind::TopK);
        let theta: Vec<f32> = (0..32).map(|i| i as f32).collect();
        let enc = w.step_encode(&theta, 0.1);
        assert_eq!(enc.format, wire::Format::SparseIdxVal);
        let decoded = wire::decode_any(&enc).unwrap();
        assert_eq!(decoded.iter().filter(|v| **v != 0.0).count(), 8); // d/k_frac
    }
}
