//! Round bookkeeping and the learning-rate schedule.
//!
//! The paper's schedule: the LR is divided by 10 at fixed fractions of the
//! run (epochs 100 and 150 of 200 → fractions 0.5 and 0.75), and scaled
//! proportionally to batch size for small-batch runs (Goyal et al. 2017).

/// Step-decay schedule: lr(t) = base / 10^{#decay points passed}.
#[derive(Clone, Debug)]
pub struct LrSchedule {
    pub base: f64,
    pub total_steps: usize,
    /// Fractions of total_steps at which to decimate.
    pub decay_at: Vec<f64>,
    pub decay_factor: f64,
}

impl LrSchedule {
    pub fn new(base: f64, total_steps: usize, decay_at: Vec<f64>) -> Self {
        LrSchedule {
            base,
            total_steps,
            decay_at,
            decay_factor: 10.0,
        }
    }

    /// Constant schedule.
    pub fn constant(base: f64) -> Self {
        LrSchedule::new(base, usize::MAX, vec![])
    }

    pub fn lr(&self, step: usize) -> f64 {
        let frac = step as f64 / self.total_steps as f64;
        let passed = self.decay_at.iter().filter(|&&f| frac >= f).count();
        self.base / self.decay_factor.powi(passed as i32)
    }
}

/// Round counter with monotonicity checks — the leader uses this to detect
/// stale gradient pushes (the gather asserts all messages carry the current
/// round).
#[derive(Clone, Debug, Default)]
pub struct RoundClock {
    round: u64,
}

impl RoundClock {
    pub fn current(&self) -> u64 {
        self.round
    }

    pub fn advance(&mut self) -> u64 {
        self.round += 1;
        self.round
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_schedule() {
        let s = LrSchedule::new(0.056, 200, vec![0.5, 0.75]);
        assert!((s.lr(0) - 0.056).abs() < 1e-12);
        assert!((s.lr(99) - 0.056).abs() < 1e-12);
        assert!((s.lr(100) - 0.0056).abs() < 1e-12);
        assert!((s.lr(150) - 0.00056).abs() < 1e-12);
        assert!((s.lr(199) - 0.00056).abs() < 1e-12);
    }

    #[test]
    fn constant_schedule() {
        let s = LrSchedule::constant(0.1);
        assert_eq!(s.lr(0), 0.1);
        assert_eq!(s.lr(1_000_000), 0.1);
    }

    #[test]
    fn clock_monotone() {
        let mut c = RoundClock::default();
        assert_eq!(c.current(), 0);
        assert_eq!(c.advance(), 1);
        assert_eq!(c.advance(), 2);
        assert_eq!(c.current(), 2);
    }
}
