//! Round bookkeeping and the learning-rate schedule.
//!
//! The paper's schedule: the LR is divided by 10 at fixed fractions of the
//! run (epochs 100 and 150 of 200 → fractions 0.5 and 0.75), and scaled
//! proportionally to batch size for small-batch runs (Goyal et al. 2017).

/// Step-decay schedule: lr(t) = base / 10^{#decay points passed}.
#[derive(Clone, Debug)]
pub struct LrSchedule {
    pub base: f64,
    pub total_steps: usize,
    /// Fractions of total_steps at which to decimate.
    pub decay_at: Vec<f64>,
    pub decay_factor: f64,
}

impl LrSchedule {
    pub fn new(base: f64, total_steps: usize, decay_at: Vec<f64>) -> Self {
        LrSchedule {
            base,
            total_steps,
            decay_at,
            decay_factor: 10.0,
        }
    }

    /// Constant schedule.
    pub fn constant(base: f64) -> Self {
        LrSchedule::new(base, usize::MAX, vec![])
    }

    pub fn lr(&self, step: usize) -> f64 {
        let frac = step as f64 / self.total_steps as f64;
        let passed = self.decay_at.iter().filter(|&&f| frac >= f).count();
        self.base / self.decay_factor.powi(passed as i32)
    }
}

/// Leader hot-path profile: wall-clock spent in the gather → decode →
/// aggregate section, accumulated across rounds. This is the serial
/// chokepoint the parallel decode fan-out and the sharded parameter
/// server attack, so the driver keeps an exact running account of it;
/// `bench_leader` / `bench_shard` serialize it into `results/BENCH_*.json`
/// to track the perf trajectory across PRs.
///
/// Under a sharded parameter server each shard leader is profiled
/// separately: `decode_agg_s` stays the *total* CPU cost over all shard
/// leaders, while `critical_s` is the simulated-deployment critical path
/// (the slowest shard leader per round, summed over rounds) — the
/// quantity the driver charges on the virtual clock. For a single shard
/// the two are identical.
#[derive(Clone, Debug, Default)]
pub struct LeaderProfile {
    /// Total seconds spent decoding + aggregating worker frames, summed
    /// over every shard leader.
    pub decode_agg_s: f64,
    /// Per-round max-over-shard-leaders decode+aggregate time, summed
    /// over rounds (== `decode_agg_s` when there is one shard).
    pub critical_s: f64,
    /// Total decode+aggregate seconds per shard leader (one entry per
    /// shard; a single entry when unsharded).
    pub per_shard_s: Vec<f64>,
    /// Rounds accounted.
    pub rounds: u64,
}

impl LeaderProfile {
    /// Account one unsharded round.
    pub fn record(&mut self, seconds: f64) {
        self.record_shards(&[seconds]);
    }

    /// Account one round's per-shard-leader decode+aggregate times.
    /// Returns the round's critical path (the slowest shard leader) — the
    /// quantity the drivers charge on the virtual clock, computed here
    /// once so the clock and the profile can never disagree.
    pub fn record_shards(&mut self, times: &[f64]) -> f64 {
        debug_assert!(!times.is_empty());
        if self.per_shard_s.len() < times.len() {
            self.per_shard_s.resize(times.len(), 0.0);
        }
        let mut slowest = 0.0f64;
        for (s, t) in times.iter().enumerate() {
            self.decode_agg_s += *t;
            self.per_shard_s[s] += *t;
            slowest = slowest.max(*t);
        }
        self.critical_s += slowest;
        self.rounds += 1;
        slowest
    }

    /// Mean decode+aggregate seconds per round (total over shard leaders).
    pub fn mean_round_s(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.decode_agg_s / self.rounds as f64
        }
    }

    /// Mean per-round critical path — the slowest shard leader's
    /// decode+aggregate time — in seconds.
    pub fn mean_critical_s(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.critical_s / self.rounds as f64
        }
    }

    /// Leader aggregation throughput in rounds/sec (0 before any round).
    pub fn rounds_per_sec(&self) -> f64 {
        if self.decode_agg_s > 0.0 {
            self.rounds as f64 / self.decode_agg_s
        } else {
            0.0
        }
    }
}

/// Bounded-staleness accounting for the async driver: how many frames
/// folded, how late they were, and how big the quorum batches ran. The
/// invariant `max_staleness_seen ≤ --max-staleness` is asserted by the
/// async integration tests; the staleness experiment reports the mean.
#[derive(Clone, Debug, Default)]
pub struct StalenessStats {
    /// Number of aggregate applications (async rounds).
    pub folds: u64,
    /// Total worker frames folded.
    pub frames: u64,
    /// Frames folded with staleness ≥ 1 round.
    pub stale_frames: u64,
    /// Sum of per-frame staleness (rounds late), for the mean.
    pub staleness_sum: u64,
    /// Largest staleness observed at fold time.
    pub max_staleness_seen: u64,
    /// Largest fold batch.
    pub max_batch: u64,
}

impl StalenessStats {
    pub fn record_frame(&mut self, staleness: u64) {
        self.frames += 1;
        self.staleness_sum += staleness;
        if staleness > 0 {
            self.stale_frames += 1;
        }
        if staleness > self.max_staleness_seen {
            self.max_staleness_seen = staleness;
        }
    }

    pub fn record_fold(&mut self, batch: usize) {
        self.folds += 1;
        if batch as u64 > self.max_batch {
            self.max_batch = batch as u64;
        }
    }

    /// Mean staleness over folded frames (0 before any fold).
    pub fn mean_staleness(&self) -> f64 {
        if self.frames == 0 {
            0.0
        } else {
            self.staleness_sum as f64 / self.frames as f64
        }
    }

    /// Fraction of folded frames that were stale.
    pub fn stale_fraction(&self) -> f64 {
        if self.frames == 0 {
            0.0
        } else {
            self.stale_frames as f64 / self.frames as f64
        }
    }

    /// Mean fold batch size (0 before any fold).
    pub fn mean_batch(&self) -> f64 {
        if self.folds == 0 {
            0.0
        } else {
            self.frames as f64 / self.folds as f64
        }
    }
}

/// Round counter with monotonicity checks — the leader uses this to detect
/// stale gradient pushes (the gather asserts all messages carry the current
/// round).
#[derive(Clone, Debug, Default)]
pub struct RoundClock {
    round: u64,
}

impl RoundClock {
    pub fn current(&self) -> u64 {
        self.round
    }

    pub fn advance(&mut self) -> u64 {
        self.round += 1;
        self.round
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_schedule() {
        let s = LrSchedule::new(0.056, 200, vec![0.5, 0.75]);
        assert!((s.lr(0) - 0.056).abs() < 1e-12);
        assert!((s.lr(99) - 0.056).abs() < 1e-12);
        assert!((s.lr(100) - 0.0056).abs() < 1e-12);
        assert!((s.lr(150) - 0.00056).abs() < 1e-12);
        assert!((s.lr(199) - 0.00056).abs() < 1e-12);
    }

    #[test]
    fn constant_schedule() {
        let s = LrSchedule::constant(0.1);
        assert_eq!(s.lr(0), 0.1);
        assert_eq!(s.lr(1_000_000), 0.1);
    }

    #[test]
    fn leader_profile_accumulates() {
        let mut p = LeaderProfile::default();
        assert_eq!(p.rounds_per_sec(), 0.0);
        assert_eq!(p.mean_round_s(), 0.0);
        p.record(0.5);
        p.record(0.5);
        assert_eq!(p.rounds, 2);
        assert!((p.mean_round_s() - 0.5).abs() < 1e-12);
        assert!((p.rounds_per_sec() - 2.0).abs() < 1e-12);
        // unsharded rounds: critical path == total
        assert!((p.critical_s - p.decode_agg_s).abs() < 1e-12);
        assert_eq!(p.per_shard_s.len(), 1);
    }

    #[test]
    fn leader_profile_sharded_tracks_critical_path() {
        let mut p = LeaderProfile::default();
        // record_shards hands back each round's critical path
        assert!((p.record_shards(&[0.1, 0.4, 0.2]) - 0.4).abs() < 1e-12);
        assert!((p.record_shards(&[0.3, 0.1, 0.2]) - 0.3).abs() < 1e-12);
        assert_eq!(p.rounds, 2);
        // total CPU = sum over all shard leaders
        assert!((p.decode_agg_s - 1.3).abs() < 1e-12);
        // critical path = per-round max, summed: 0.4 + 0.3
        assert!((p.critical_s - 0.7).abs() < 1e-12);
        assert!((p.mean_critical_s() - 0.35).abs() < 1e-12);
        assert_eq!(p.per_shard_s.len(), 3);
        assert!((p.per_shard_s[0] - 0.4).abs() < 1e-12);
        assert!((p.per_shard_s[1] - 0.5).abs() < 1e-12);
        assert!((p.per_shard_s[2] - 0.4).abs() < 1e-12);
    }

    #[test]
    fn staleness_stats_aggregate() {
        let mut s = StalenessStats::default();
        assert_eq!(s.mean_staleness(), 0.0);
        assert_eq!(s.stale_fraction(), 0.0);
        assert_eq!(s.mean_batch(), 0.0);
        s.record_frame(0);
        s.record_frame(2);
        s.record_frame(1);
        s.record_fold(3);
        s.record_frame(0);
        s.record_fold(1);
        assert_eq!(s.folds, 2);
        assert_eq!(s.frames, 4);
        assert_eq!(s.stale_frames, 2);
        assert_eq!(s.max_staleness_seen, 2);
        assert_eq!(s.max_batch, 3);
        assert!((s.mean_staleness() - 0.75).abs() < 1e-12);
        assert!((s.stale_fraction() - 0.5).abs() < 1e-12);
        assert!((s.mean_batch() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn clock_monotone() {
        let mut c = RoundClock::default();
        assert_eq!(c.current(), 0);
        assert_eq!(c.advance(), 1);
        assert_eq!(c.advance(), 2);
        assert_eq!(c.current(), 2);
    }
}
