//! Layer-3 coordinator: the distributed data-parallel training runtime.
//!
//! Topology: `w` workers + 1 leader over the simulated [`crate::net`]
//! fabric. Each round:
//!
//! 1. the leader broadcasts the current parameters (accounted),
//! 2. every worker computes a stochastic gradient on its own data shard
//!    (natively or through the PJRT artifacts),
//! 3. the worker runs its **error-feedback compression state** (Algorithm 2
//!    lines 5–8) and pushes the encoded delta,
//! 4. the leader decodes, aggregates (mean or majority vote), and applies
//!    the update.
//!
//! The per-worker residual `e_t` is first-class coordinator state: it is
//! owned by [`worker::Worker`], checkpointed by [`state::CheckpointStore`]
//! together with the corrected gradient `p_t`, and its norm is exported as
//! a metric (Lemma 3 instrumentation).
//!
//! # Threading model
//!
//! Worker compute runs on a persistent [`pool::WorkerPool`] of actor
//! threads (`DriverConfig::threads`, CLI `--threads`). Workers are moved
//! onto the pool at driver construction and stay there for the run; the
//! leader's event loop talks to them over channels and never touches a
//! `Worker` directly. All communication still flows through the shared
//! [`crate::net::Fabric`], whose mutex-guarded queues and accounting make
//! interleaved sends/recvs from many threads safe and exact.
//!
//! The leader's own hot path — decoding every worker's wire frame and
//! aggregating — also fans out over the same pool threads between rounds:
//! frames are partitioned into fixed worker-id groups
//! ([`aggregate::decode_groups`]) and each group is decoded straight into
//! one partial-sum buffer (`wire::decode_any_add`), so aggregation never
//! materializes a dense `Vec<f32>` per worker.
//!
//! # Sharded parameter server
//!
//! With `DriverConfig::shards = S > 1` (CLI `--shards`) the model vector
//! splits into `S` contiguous coordinate blocks
//! ([`crate::collectives::ShardPlan`]), each with its own leader node on
//! the fabric. Workers run blockwise error feedback (one compressor + EF
//! residual per shard, per-shard scales/norms) and push one tagged wire
//! frame per shard; each shard leader decodes and aggregates only its
//! slice, and the broadcast returns per-shard parameter slices the
//! workers reassemble. The leaders' measured decode+aggregate time is
//! charged on the virtual clock as the max over shards — the critical
//! path sharding shrinks. `--shards 1` is byte-identical to the
//! historical single-leader engine; any `(shards, threads)` combination
//! is bit-deterministic. Full topology + timing model: `docs/SHARDING.md`.
//!
//! # Determinism guarantee
//!
//! For a fixed seed, the trained parameters, every worker's EF residual,
//! and the fabric's bit totals are **identical for any `--threads` value**:
//!
//! * each worker owns its RNG and data shard, so per-worker compute does
//!   not depend on which thread hosts it;
//! * every pool reply carries the worker id and the leader sorts gathers
//!   and reports by id before aggregating, so f32 reduction order is
//!   schedule-independent;
//! * the parallel decode's partial-sum partition is a function of the
//!   worker count only (never of the thread count), and partials merge in
//!   worker-id order, so the f32 reduction tree is fixed;
//! * bit accounting is a commutative sum of exact per-message counts.
//!
//! (Simulated *time* aggregates are f64 sums whose addition order may vary
//! across thread counts; bit counts never do.) The guarantee is enforced
//! by the `threads_are_bit_deterministic` integration test.
//!
//! When the gradient source wraps non-`Send` device handles (real PJRT),
//! share the session behind the usual `Arc` facade only if the bindings
//! allow it; otherwise run `--threads 1`, which keeps all workers on a
//! single pool thread.
//!
//! # Asynchronous mode
//!
//! [`async_driver::AsyncTrainDriver`] replaces the lock-step barrier with
//! quorum + bounded-staleness rounds over the fabric's discrete-event
//! virtual clock ([`crate::net::simclock`]): the leader folds an update as
//! soon as `--quorum K` frames are pending, late frames (≤ `--max-staleness
//! S` rounds) still fold in, and per-worker compute time comes from a
//! seeded [`crate::net::StragglerSchedule`]. Event-queue semantics, the
//! staleness bound, and how EF residuals interact with late frames are
//! documented in `docs/ASYNC.md`. `--quorum n --max-staleness 0`
//! reproduces [`TrainDriver`] bit for bit.

pub mod aggregate;
pub mod async_driver;
pub mod cost;
pub mod driver;
pub mod pool;
pub mod round;
pub mod state;
pub mod worker;

pub use aggregate::{Aggregation, DecodeScratch};
pub use async_driver::AsyncTrainDriver;
pub use cost::DecodeCostModel;
pub use driver::{TrainDriver, TrainOutcome};
pub use pool::{RoundReport, WorkerPool, WorkerState};
pub use round::{LrSchedule, StalenessStats};
pub use worker::{GradSource, ObjectiveSource, Worker, WorkerMode};
