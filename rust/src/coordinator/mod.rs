//! Layer-3 coordinator: the distributed data-parallel training runtime.
//!
//! Topology: `w` workers + 1 leader over the simulated [`crate::net`]
//! fabric. Each round:
//!
//! 1. the leader broadcasts the current parameters (accounted),
//! 2. every worker computes a stochastic gradient on its own data shard
//!    (natively or through the PJRT artifacts),
//! 3. the worker runs its **error-feedback compression state** (Algorithm 2
//!    lines 5–8) and pushes the encoded delta,
//! 4. the leader decodes, aggregates (mean or majority vote), and applies
//!    the update.
//!
//! The per-worker residual `e_t` is first-class coordinator state: it is
//! owned by [`worker::Worker`], checkpointed by [`state::CheckpointStore`],
//! and its norm is exported as a metric (Lemma 3 instrumentation).
//!
//! PJRT handles are not `Send`, so the event loop is single-threaded and
//! deterministic; worker compute "parallelism" and all communication costs
//! live in the fabric's simulated clock.

pub mod aggregate;
pub mod driver;
pub mod round;
pub mod state;
pub mod worker;

pub use aggregate::Aggregation;
pub use driver::{TrainDriver, TrainOutcome};
pub use round::LrSchedule;
pub use worker::{GradSource, ObjectiveSource, Worker, WorkerMode};
