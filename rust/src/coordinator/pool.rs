//! The worker pool: a persistent set of actor threads that own the
//! [`Worker`]s for the lifetime of a training run.
//!
//! The driver (leader) talks to the pool over channels:
//!
//! * [`WorkerPool::round_into`] — dispatch one training round; every actor
//!   drains its workers' parameter broadcasts from the fabric, runs the
//!   gradient + EF-compress step, pushes the encoded frame to the leader
//!   through the shared [`Fabric`] (so bit accounting is exact and
//!   centralized), and reports per-worker instrumentation back.
//! * [`WorkerPool::eval`] — run held-out eval on one worker's data shard.
//! * [`WorkerPool::export_states`] — snapshot every worker's EF state
//!   (steps, residual `e`, corrected `p`) for checkpointing.
//! * [`WorkerPool::restore_states`] — load those states back after a
//!   restart.
//!
//! Workers are assigned to threads in contiguous id blocks; every reply
//! carries the worker id, and the pool sorts collected replies by id, so
//! the driver's view is independent of thread scheduling. Each worker owns
//! its RNG and data shard, which makes per-worker computation identical
//! across any thread count — determinism is asserted by the
//! `threads_are_bit_deterministic` integration test.
//!
//! # Allocation-free steady state
//!
//! The channels are ring-buffer queues ([`Chan`]), not `std::sync::mpsc`
//! (whose linked blocks allocate as the stream advances): once round 1 has
//! sized the rings, command/reply traffic allocates nothing. The decode
//! fan-out ([`WorkerPool::decode_partials_pooled`]) moves frame groups and
//! recycled partial-sum buffers *through* the commands and gets them back
//! in the replies, and each decoded frame's byte buffer returns to the
//! fabric's [`crate::net::FramePool`] for the next round's encoders — the
//! full architecture is documented in docs/PERF.md and enforced by the
//! `alloc_regression` integration test.

use super::worker::Worker;
use crate::collectives::ShardedParameterServer;
use crate::compress::wire::{self, Encoded};
use crate::net::{AdversarySchedule, Fabric};
use crate::obs::trace::EventKind;
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// A tiny blocking MPSC queue over `Mutex<VecDeque>` + `Condvar`. The
/// VecDeque's ring reuses its allocation, so steady-state traffic is
/// allocation-free once the queue has grown to its per-round peak. There
/// is no disconnect signalling: the pool shuts its actors down with an
/// explicit [`Command::Shutdown`], and reply-side liveness is covered by
/// [`WorkerPool::recv_reply`]'s thread-death check.
struct Chan<T> {
    q: Mutex<VecDeque<T>>,
    ready: Condvar,
}

impl<T> Chan<T> {
    fn new() -> Arc<Self> {
        Arc::new(Chan {
            q: Mutex::new(VecDeque::with_capacity(32)),
            ready: Condvar::new(),
        })
    }

    fn send(&self, t: T) {
        self.q.lock().unwrap().push_back(t);
        self.ready.notify_one();
    }

    fn recv(&self) -> T {
        let mut q = self.q.lock().unwrap();
        loop {
            if let Some(t) = q.pop_front() {
                return t;
            }
            q = self.ready.wait(q).unwrap();
        }
    }

    // detlint: profiling — the timeout deadline is real wall time (thread
    // liveness), never simulated time
    fn recv_timeout(&self, timeout: Duration) -> Option<T> {
        let deadline = std::time::Instant::now() + timeout;
        let mut q = self.q.lock().unwrap();
        loop {
            if let Some(t) = q.pop_front() {
                return Some(t);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _timed_out) = self.ready.wait_timeout(q, deadline - now).unwrap();
            q = guard;
        }
    }
}

/// Per-worker instrumentation from one round.
#[derive(Clone, Debug)]
pub struct RoundReport {
    pub id: usize,
    pub loss: f64,
    pub phi: f64,
    pub grad_density: f64,
    pub error_norm: f64,
}

/// One worker's serializable EF state (see `ErrorFeedback::set_state`).
#[derive(Clone, Debug)]
pub struct WorkerState {
    pub id: usize,
    pub steps: u64,
    pub error: Vec<f32>,
    pub corrected: Vec<f32>,
}

enum Command {
    Round {
        round: u64,
        lr: f32,
    },
    /// Run one gradient + compress + push step for a single worker (the
    /// async driver's dispatch unit: only the quorum's workers recompute
    /// after a fold, the rest stay in flight).
    StepOne {
        worker: usize,
        round: u64,
        lr: f32,
    },
    Eval {
        worker: usize,
        theta: Arc<Vec<f32>>,
    },
    Export,
    Restore {
        states: Arc<Vec<WorkerState>>,
    },
    /// Leader decode fan-out: decode this group of worker frames — in
    /// index order — fused into the provided accumulator (zeroed on the
    /// thread). Both the frames' containers and the accumulator round-trip
    /// back through [`Reply::Partial`] for reuse; the frames' byte buffers
    /// go to the fabric's frame pool.
    DecodeAccum {
        frames: Vec<Encoded>,
        group: usize,
        acc: Vec<f32>,
    },
    /// Leader decode fan-out, dense flavour: decode each frame to its own
    /// dense vector (majority vote needs the per-worker updates, not their
    /// sum). `start` is the index of the first frame in the caller's
    /// order.
    DecodeDense {
        frames: Vec<Encoded>,
        start: usize,
    },
    Shutdown,
}

enum Reply {
    Round(RoundReport),
    Eval {
        loss: f64,
        acc: f64,
    },
    Export(WorkerState),
    Restored,
    Partial {
        group: usize,
        acc: Vec<f32>,
        /// The group's (now empty) frame container, returned for reuse.
        frames: Vec<Encoded>,
        /// Frames that decoded successfully into `acc`; anything short of
        /// the group size means undecodable frames were dropped.
        ok: usize,
    },
    Decoded {
        idx: usize,
        /// `None` when the frame was undecodable and dropped.
        v: Option<Vec<f32>>,
    },
}

/// Persistent thread pool owning the workers of one training run.
pub struct WorkerPool {
    command_txs: Vec<Arc<Chan<Command>>>,
    reply_rx: Arc<Chan<Reply>>,
    handles: Vec<JoinHandle<()>>,
    n_workers: usize,
    /// worker id -> thread index (for routing eval requests).
    owner: Vec<usize>,
}

impl WorkerPool {
    /// Move `workers` onto `threads` actor threads (clamped to
    /// `1..=workers.len()`), all sharing `fabric` for communication. The
    /// parameter-server topology (including the shard count) is derived
    /// from the workers' shared [`crate::collectives::ShardPlan`]; the
    /// fabric must be sized `workers + shards`.
    pub fn spawn(workers: Vec<Worker>, fabric: Arc<Fabric>, threads: usize) -> WorkerPool {
        WorkerPool::spawn_with_adversary(workers, fabric, threads, AdversarySchedule::none())
    }

    /// [`spawn`](Self::spawn) with a Byzantine adversary schedule: each
    /// actor corrupts a worker's outgoing frames per the schedule's
    /// `(worker, round)` cells just before they hit the fabric — the
    /// corruption is a pure per-cell function, so any thread assignment
    /// produces identical wire bytes. [`AdversarySchedule::none()`]
    /// leaves every frame untouched (byte-identical to the honest pool).
    pub fn spawn_with_adversary(
        workers: Vec<Worker>,
        fabric: Arc<Fabric>,
        threads: usize,
        adversary: AdversarySchedule,
    ) -> WorkerPool {
        let n_workers = workers.len();
        assert!(n_workers > 0, "pool needs at least one worker");
        let plan = workers[0].shard_plan().clone();
        assert!(
            workers.iter().all(|w| w.shard_plan() == &plan),
            "workers disagree on the shard plan"
        );
        let threads = threads.clamp(1, n_workers);
        let ps = ShardedParameterServer::new(&fabric, plan);
        assert_eq!(
            ps.workers.len(),
            n_workers,
            "fabric sized for a different worker count (need workers + shards nodes)"
        );
        let reply_rx: Arc<Chan<Reply>> = Chan::new();

        // Contiguous block assignment: thread t owns workers
        // [t*⌈n/threads⌉ .. ), ascending by id within a thread.
        let per_thread = n_workers.div_ceil(threads);
        let mut owner = vec![0usize; n_workers];
        let mut command_txs = Vec::with_capacity(threads);
        let mut handles = Vec::with_capacity(threads);
        let mut workers = workers.into_iter();
        for t in 0..threads {
            let block: Vec<Worker> = workers.by_ref().take(per_thread).collect();
            for w in &block {
                owner[w.id] = t;
            }
            let tx: Arc<Chan<Command>> = Chan::new();
            let rx = tx.clone();
            command_txs.push(tx);
            let fabric = fabric.clone();
            let ps = ps.clone();
            let reply_tx = reply_rx.clone();
            let adversary = adversary.clone();
            handles.push(std::thread::spawn(move || {
                actor_loop(block, fabric, ps, rx, reply_tx, adversary);
            }));
        }
        debug_assert_eq!(workers.len(), 0);
        WorkerPool {
            command_txs,
            reply_rx,
            handles,
            n_workers,
            owner,
        }
    }

    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    pub fn threads(&self) -> usize {
        self.command_txs.len()
    }

    /// Wait for one reply, surfacing actor-thread death as a panic instead
    /// of blocking forever. (During normal operation no actor returns, so
    /// a finished handle means one panicked — with ≥2 threads the survivors
    /// keep replying and a plain blocking `recv` would hang.)
    fn recv_reply(&self) -> Reply {
        loop {
            match self.reply_rx.recv_timeout(Duration::from_millis(50)) {
                Some(reply) => return reply,
                None => {
                    assert!(
                        !self.handles.iter().any(|h| h.is_finished()),
                        "worker pool thread died while replies were pending"
                    );
                }
            }
        }
    }

    /// Run one round on every worker; fills `reports` (cleared first) with
    /// per-worker reports sorted by worker id. The caller must have
    /// broadcast the round's parameters on the fabric first; on return
    /// every worker's gradient push is on the leader's queue.
    /// Allocation-free once `reports` is warm.
    // detlint: hot
    pub fn round_into(&self, round: u64, lr: f32, reports: &mut Vec<RoundReport>) {
        reports.clear();
        for tx in &self.command_txs {
            tx.send(Command::Round { round, lr });
        }
        for _ in 0..self.n_workers {
            match self.recv_reply() {
                Reply::Round(r) => reports.push(r),
                _ => unreachable!("unexpected pool reply during round"),
            }
        }
        // worker ids are unique: the unstable sort is deterministic
        reports.sort_unstable_by_key(|r| r.id);
    }

    /// Allocating wrapper around [`round_into`](Self::round_into).
    pub fn round(&self, round: u64, lr: f32) -> Vec<RoundReport> {
        let mut reports = Vec::with_capacity(self.n_workers);
        self.round_into(round, lr, &mut reports);
        reports
    }

    /// Run one step on a subset of workers (each drains its parameter
    /// message from the fabric, computes, EF-compresses, and pushes its
    /// frame to the leader); returns their reports sorted by worker id.
    /// The caller must have sent each listed worker its parameters first.
    pub fn step_workers(&self, ids: &[usize], round: u64, lr: f32) -> Vec<RoundReport> {
        for &w in ids {
            self.command_txs[self.owner[w]].send(Command::StepOne {
                worker: w,
                round,
                lr,
            });
        }
        let mut reports = Vec::with_capacity(ids.len());
        for _ in 0..ids.len() {
            match self.recv_reply() {
                Reply::Round(r) => reports.push(r),
                _ => unreachable!("unexpected pool reply during step"),
            }
        }
        reports.sort_unstable_by_key(|r| r.id);
        reports
    }

    /// Held-out eval (loss, accuracy) through one worker's grad source.
    pub fn eval(&self, worker: usize, theta: &[f32]) -> (f64, f64) {
        self.command_txs[self.owner[worker]].send(Command::Eval {
            worker,
            theta: Arc::new(theta.to_vec()),
        });
        match self.recv_reply() {
            Reply::Eval { loss, acc } => (loss, acc),
            _ => unreachable!("unexpected pool reply during eval"),
        }
    }

    /// Snapshot every worker's EF state, sorted by worker id.
    pub fn export_states(&self) -> Vec<WorkerState> {
        for tx in &self.command_txs {
            tx.send(Command::Export);
        }
        let mut states = Vec::with_capacity(self.n_workers);
        for _ in 0..self.n_workers {
            match self.recv_reply() {
                Reply::Export(s) => states.push(s),
                _ => unreachable!("unexpected pool reply during export"),
            }
        }
        states.sort_unstable_by_key(|s| s.id);
        states
    }

    /// Fan frame decoding out over the pool threads, fused with
    /// accumulation, recycling every buffer involved:
    ///
    /// * `groups[g]` holds group `g`'s frames (in worker-id order); each
    ///   group is decoded — in index order — straight into one partial-sum
    ///   buffer via [`wire::decode_any_add`]. On return every `groups[g]`
    ///   is empty again (same container, capacity kept) and the decoded
    ///   frames' byte buffers are back in the fabric's frame pool.
    /// * `partials` (cleared first) receives the group partial sums in
    ///   group order; the buffers come from `spare`, the caller's recycle
    ///   stack (falling back to fresh allocations when it runs dry).
    /// * `decoded` (cleared first) receives, per group, how many frames
    ///   actually decoded into the partial — undecodable (adversarial)
    ///   frames are dropped and counted in the fabric's `TrafficStats`
    ///   rather than aborting the round, so `decoded[g]` can fall short
    ///   of the group size. The aggregator uses these counts to average
    ///   over the frames that arrived intact.
    ///
    /// Groups are distributed round-robin over the threads; since every
    /// partial depends only on its own group's frames, the results are
    /// bit-identical for any thread count.
    // detlint: hot
    pub fn decode_partials_pooled(
        &self,
        groups: &mut [Vec<Encoded>],
        d: usize,
        partials: &mut Vec<Vec<f32>>,
        decoded: &mut Vec<usize>,
        spare: &mut Vec<Vec<f32>>,
    ) {
        let threads = self.command_txs.len();
        partials.clear();
        // detlint: allow(H1) — fills only while the partial stack grows to
        // the group count; allocation-free once warm
        partials.resize_with(groups.len(), Vec::new);
        decoded.clear();
        decoded.resize(groups.len(), 0);
        for (g, slot) in groups.iter_mut().enumerate() {
            let frames = std::mem::take(slot);
            let mut acc = spare.pop().unwrap_or_default();
            acc.resize(d, 0.0);
            self.command_txs[g % threads].send(Command::DecodeAccum {
                frames,
                group: g,
                acc,
            });
        }
        for _ in 0..groups.len() {
            match self.recv_reply() {
                Reply::Partial {
                    group,
                    acc,
                    frames,
                    ok,
                } => {
                    partials[group] = acc;
                    groups[group] = frames;
                    decoded[group] = ok;
                }
                _ => unreachable!("unexpected pool reply during decode"),
            }
        }
    }

    /// Fan frame decoding out with fused accumulation, one partial per
    /// `(start, end)` group of `frames`. Allocating wrapper around
    /// [`decode_partials_pooled`](Self::decode_partials_pooled); `groups`
    /// must be a contiguous ascending partition of `0..frames.len()`
    /// (asserted — handing a group the wrong frames would silently corrupt
    /// the partial sums).
    pub fn decode_partials(
        &self,
        frames: Vec<Encoded>,
        d: usize,
        groups: &[(usize, usize)],
    ) -> Vec<Vec<f32>> {
        let n = frames.len();
        let mut it = frames.into_iter();
        let mut group_vecs: Vec<Vec<Encoded>> = Vec::with_capacity(groups.len());
        let mut expect = 0usize;
        for &(start, end) in groups {
            assert!(
                start == expect && start < end && end <= n,
                "decode groups must be a contiguous ascending partition of 0..{n}"
            );
            expect = end;
            group_vecs.push(it.by_ref().take(end - start).collect());
        }
        assert_eq!(expect, n, "decode groups must cover every frame");
        let mut partials = Vec::new();
        let mut decoded = Vec::new();
        let mut spare = Vec::new();
        self.decode_partials_pooled(&mut group_vecs, d, &mut partials, &mut decoded, &mut spare);
        partials
    }

    /// Fan frame decoding out over the pool threads, one dense vector per
    /// frame (contiguous blocks per thread); returns the decoded updates
    /// sorted by frame index. Undecodable (adversarial) frames are dropped
    /// — counted in the fabric's `TrafficStats` — so the result can be
    /// shorter than the input; the surviving updates keep their relative
    /// index order, which is what keeps the downstream combine
    /// deterministic. The frames' byte buffers are recycled into the
    /// fabric's frame pool.
    pub fn decode_dense(&self, frames: Vec<Encoded>) -> Vec<Vec<f32>> {
        let n = frames.len();
        let threads = self.command_txs.len();
        let per = n.div_ceil(threads);
        let mut it = frames.into_iter();
        let mut start = 0usize;
        let mut t = 0usize;
        while start < n {
            let end = (start + per).min(n);
            let chunk: Vec<Encoded> = it.by_ref().take(end - start).collect();
            self.command_txs[t].send(Command::DecodeDense {
                frames: chunk,
                start,
            });
            start = end;
            t += 1;
        }
        let mut out: Vec<Option<Vec<f32>>> = vec![None; n];
        for _ in 0..n {
            match self.recv_reply() {
                Reply::Decoded { idx, v } => out[idx] = v,
                _ => unreachable!("unexpected pool reply during decode"),
            }
        }
        out.into_iter().flatten().collect()
    }

    /// Restore worker EF states (each thread applies the entries for the
    /// workers it owns).
    pub fn restore_states(&self, states: Vec<WorkerState>) {
        let states = Arc::new(states);
        for tx in &self.command_txs {
            tx.send(Command::Restore {
                states: states.clone(),
            });
        }
        for _ in 0..self.command_txs.len() {
            match self.recv_reply() {
                Reply::Restored => {}
                _ => unreachable!("unexpected pool reply during restore"),
            }
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        for tx in &self.command_txs {
            tx.send(Command::Shutdown);
        }
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

/// The actor body: owns a block of workers until shutdown. The parameter
/// assembly buffer and per-round frame list are persistent scratch — warm
/// after round 1, so the steady-state round path allocates nothing.
fn actor_loop(
    mut workers: Vec<Worker>,
    fabric: Arc<Fabric>,
    ps: ShardedParameterServer,
    rx: Arc<Chan<Command>>,
    tx: Arc<Chan<Reply>>,
    adversary: AdversarySchedule,
) {
    let n_workers = ps.workers.len();
    // reused parameter assembly buffer (per-shard slices scatter into it)
    let mut params: Vec<f32> = Vec::new();
    // reused per-round frame list; the frames' byte buffers cycle through
    // the fabric's frame pool
    let mut frames: Vec<Encoded> = Vec::new();
    loop {
        match rx.recv() {
            Command::Round { round, lr } => {
                for w in workers.iter_mut() {
                    assert!(
                        ps.recv_params_into(&fabric, w.id, &mut params),
                        "parameter broadcast missing for worker"
                    );
                    w.step_encode_sharded_into(&params, lr, fabric.frame_pool(), &mut frames);
                    adversary.corrupt_frames(w.id, round, n_workers, &mut frames);
                    trace_worker_frames(&fabric, w.id, round, n_workers, &adversary, &frames);
                    ps.push_frames(&fabric, w.id, round, &mut frames);
                    let report = RoundReport {
                        id: w.id,
                        loss: w.last_loss,
                        phi: w.last_phi,
                        grad_density: w.last_grad_density,
                        error_norm: w.error_norm(),
                    };
                    tx.send(Reply::Round(report));
                }
            }
            Command::StepOne { worker, round, lr } => {
                let w = workers
                    .iter_mut()
                    .find(|w| w.id == worker)
                    .expect("step routed to wrong pool thread");
                assert!(
                    ps.recv_params_into(&fabric, w.id, &mut params),
                    "parameter message missing for stepped worker"
                );
                w.step_encode_sharded_into(&params, lr, fabric.frame_pool(), &mut frames);
                adversary.corrupt_frames(w.id, round, n_workers, &mut frames);
                trace_worker_frames(&fabric, w.id, round, n_workers, &adversary, &frames);
                ps.push_frames(&fabric, w.id, round, &mut frames);
                let report = RoundReport {
                    id: w.id,
                    loss: w.last_loss,
                    phi: w.last_phi,
                    grad_density: w.last_grad_density,
                    error_norm: w.error_norm(),
                };
                tx.send(Reply::Round(report));
            }
            Command::Eval { worker, theta } => {
                let w = workers
                    .iter_mut()
                    .find(|w| w.id == worker)
                    .expect("eval routed to wrong pool thread");
                let loss = w.eval_loss(&theta);
                let acc = w.eval_acc(&theta);
                tx.send(Reply::Eval { loss, acc });
            }
            Command::Export => {
                for w in &workers {
                    // full-length tensors regardless of the shard plan:
                    // contiguous shards concatenate, so the checkpoint
                    // layout is plan-independent
                    let state = WorkerState {
                        id: w.id,
                        steps: w.steps(),
                        error: w.export_error(),
                        corrected: w.export_corrected(),
                    };
                    tx.send(Reply::Export(state));
                }
            }
            Command::DecodeAccum {
                mut frames,
                group,
                mut acc,
            } => {
                acc.fill(0.0);
                // Optimistic fused pass: every honest frame decodes, so
                // the hot path stays the allocation-free fused kernel. A
                // fused add is not transactional — coordinates may have
                // landed before the error — so on the first undecodable
                // frame, restart frame-by-frame, dropping the bad ones.
                let mut ok = frames.len();
                for e in &frames {
                    if wire::decode_any_add(e, &mut acc).is_err() {
                        acc.fill(0.0);
                        ok = 0;
                        for e in &frames {
                            match wire::decode_any(e) {
                                Ok(v) => {
                                    crate::tensor::add_assign(&mut acc, &v);
                                    ok += 1;
                                }
                                Err(_) => fabric.note_dropped_frame(),
                            }
                        }
                        break;
                    }
                }
                // spent push frames hand their byte buffers back for the
                // next round's encoders
                for e in frames.drain(..) {
                    fabric.frame_pool().put(e.bytes);
                }
                tx.send(Reply::Partial {
                    group,
                    acc,
                    frames,
                    ok,
                });
            }
            Command::DecodeDense { mut frames, start } => {
                for (i, e) in frames.drain(..).enumerate() {
                    let v = wire::decode_any(&e).ok();
                    if v.is_none() {
                        fabric.note_dropped_frame();
                    }
                    fabric.frame_pool().put(e.bytes);
                    tx.send(Reply::Decoded { idx: start + i, v });
                }
            }
            Command::Restore { states } => {
                for w in workers.iter_mut() {
                    if let Some(s) = states.iter().find(|s| s.id == w.id) {
                        w.restore_ef_state(s.steps, &s.error, &s.corrected);
                    }
                }
                tx.send(Reply::Restored);
            }
            Command::Shutdown => return,
        }
    }
}

/// Trace a worker's freshly encoded (and possibly corrupted) frames on its
/// own ring. Safe for determinism: each worker's ring is written only by
/// the one actor thread that owns that worker, the stamp is the worker's
/// virtual compute-finish time (pre-set by the driver), and frame sizes
/// are pure functions of the seeded models. Allocation-free — one ring
/// write per frame into preallocated slots.
// detlint: hot
fn trace_worker_frames(
    fabric: &Fabric,
    worker: usize,
    round: u64,
    n_workers: usize,
    adversary: &AdversarySchedule,
    frames: &[Encoded],
) {
    let Some(tr) = fabric.trace() else {
        return;
    };
    let t = fabric.clock().map_or(0.0, |c| c.node_time(worker));
    for f in frames {
        tr.record(worker, t, round, EventKind::FrameEncoded, f.bits);
    }
    if adversary.is_active() && adversary.is_adversary(worker, n_workers) {
        tr.record(worker, t, round, EventKind::AdversaryCorrupt, frames.len() as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::ParameterServer;
    use crate::config::CompressorKind;
    use crate::coordinator::worker::{ObjectiveSource, WorkerMode};
    use crate::model::toy::SparseNoiseQuadratic;
    use crate::net::LinkModel;
    use crate::util::Pcg64;

    fn make_workers(n: usize, d: usize) -> Vec<Worker> {
        (0..n)
            .map(|id| {
                Worker::new(
                    id,
                    Box::new(ObjectiveSource::new(
                        SparseNoiseQuadratic::new(d, 0.0),
                        Pcg64::seeded(100 + id as u64),
                    )),
                    WorkerMode::ErrorFeedback,
                    CompressorKind::ScaledSign,
                    4,
                    4,
                    Pcg64::seeded(id as u64),
                )
            })
            .collect()
    }

    fn run_round(pool: &WorkerPool, fabric: &Fabric, theta: &[f32]) -> Vec<RoundReport> {
        let ps = ParameterServer::new(fabric);
        ps.broadcast_params(fabric, 0, theta);
        let reports = pool.round(0, 0.1);
        // drain the leader queue so the fabric ends the round empty
        let msgs = fabric.recv_all(ps.leader);
        assert_eq!(msgs.len(), pool.n_workers());
        reports
    }

    #[test]
    fn round_reports_sorted_and_complete() {
        let d = 32;
        let n = 5;
        for threads in [1usize, 2, 3, 8] {
            let fabric = Arc::new(Fabric::new(n + 1, LinkModel::default()));
            let pool = WorkerPool::spawn(make_workers(n, d), fabric.clone(), threads);
            assert_eq!(pool.threads(), threads.min(n));
            let reports = run_round(&pool, &fabric, &vec![1.0f32; d]);
            let ids: Vec<usize> = reports.iter().map(|r| r.id).collect();
            assert_eq!(ids, (0..n).collect::<Vec<_>>());
            assert!(reports.iter().all(|r| r.loss.is_finite()));
        }
    }

    #[test]
    fn export_restore_roundtrip() {
        let d = 16;
        let n = 4;
        let fabric = Arc::new(Fabric::new(n + 1, LinkModel::default()));
        let pool = WorkerPool::spawn(make_workers(n, d), fabric.clone(), 2);
        run_round(&pool, &fabric, &vec![1.0f32; d]);
        let states = pool.export_states();
        assert_eq!(states.len(), n);
        assert!(states.iter().all(|s| s.steps == 1));
        assert!(states.iter().all(|s| s.corrected.iter().any(|v| *v != 0.0)));

        // restore into a fresh pool; exported states must match exactly
        let fabric2 = Arc::new(Fabric::new(n + 1, LinkModel::default()));
        let pool2 = WorkerPool::spawn(make_workers(n, d), fabric2, 3);
        pool2.restore_states(states.clone());
        let restored = pool2.export_states();
        for (a, b) in states.iter().zip(&restored) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.steps, b.steps);
            assert_eq!(a.error, b.error);
            assert_eq!(a.corrected, b.corrected);
        }
    }

    /// Decode fan-out is bit-deterministic: the same fixed groups produce
    /// byte-identical partials regardless of how many threads decode them.
    #[test]
    fn decode_partials_identical_across_thread_counts() {
        let d = 97; // ragged on purpose
        let n = 6;
        let mut rng = Pcg64::seeded(31);
        let frames: Vec<Encoded> = (0..n)
            .map(|_| {
                let mut p = vec![0.0f32; d];
                rng.fill_normal(&mut p, 0.0, 1.0);
                crate::compress::wire::encode_scaled_sign(&p)
            })
            .collect();
        let groups = [(0usize, 2usize), (2, 4), (4, 6)];
        let mut runs = Vec::new();
        for threads in [1usize, 2, 3] {
            let fabric = Arc::new(Fabric::new(n + 1, LinkModel::default()));
            let pool = WorkerPool::spawn(make_workers(n, d), fabric, threads);
            runs.push(pool.decode_partials(frames.clone(), d, &groups));
        }
        for r in &runs[1..] {
            assert_eq!(&runs[0], r);
        }
        // each partial equals the in-order fused sum of its group
        for (g, &(s, e)) in groups.iter().enumerate() {
            let mut want = vec![0.0f32; d];
            for f in &frames[s..e] {
                crate::compress::wire::decode_any_add(f, &mut want).unwrap();
            }
            assert_eq!(runs[0][g], want);
        }
    }

    /// The pooled decode recycles everything it touches: the group
    /// containers come back empty (capacity kept), the partial buffers
    /// cycle through the spare stack, and the frames' byte buffers land in
    /// the fabric's frame pool.
    #[test]
    fn decode_partials_pooled_recycles_buffers() {
        let d = 64;
        let n = 4;
        let fabric = Arc::new(Fabric::new(n + 1, LinkModel::default()));
        let pool = WorkerPool::spawn(make_workers(n, d), fabric.clone(), 2);
        let mut partials: Vec<Vec<f32>> = Vec::new();
        let mut decoded: Vec<usize> = Vec::new();
        let mut spare: Vec<Vec<f32>> = Vec::new();
        let mut rng = Pcg64::seeded(5);
        for round in 0..3 {
            let mut groups: Vec<Vec<Encoded>> = (0..2)
                .map(|_| {
                    (0..2)
                        .map(|_| {
                            let mut p = vec![0.0f32; d];
                            rng.fill_normal(&mut p, 0.0, 1.0);
                            crate::compress::wire::encode_scaled_sign(&p)
                        })
                        .collect()
                })
                .collect();
            pool.decode_partials_pooled(&mut groups, d, &mut partials, &mut decoded, &mut spare);
            assert_eq!(partials.len(), 2);
            assert_eq!(decoded, vec![2, 2]);
            assert!(partials.iter().all(|p| p.len() == d));
            assert!(groups.iter().all(|g| g.is_empty()), "round {round}");
            // recycle the partial buffers the way the driver does
            spare.append(&mut partials);
        }
        // every decoded frame's byte buffer was returned to the pool
        assert_eq!(fabric.frame_pool().pooled(), 3 * 4);
        assert_eq!(spare.len(), 2);
    }

    /// An undecodable frame degrades gracefully: the fused pass falls
    /// back to frame-by-frame decode, the bad frame is dropped (and
    /// counted in the fabric's stats), and the partial equals the sum of
    /// the surviving frames.
    #[test]
    fn undecodable_frames_are_dropped_not_fatal() {
        let d = 41;
        let n = 3;
        let fabric = Arc::new(Fabric::new(n + 1, LinkModel::default()));
        let pool = WorkerPool::spawn(make_workers(n, d), fabric.clone(), 2);
        let mut rng = Pcg64::seeded(11);
        let mut payloads: Vec<Vec<f32>> = Vec::new();
        let mut frames: Vec<Encoded> = Vec::new();
        for _ in 0..n {
            let mut p = vec![0.0f32; d];
            rng.fill_normal(&mut p, 0.0, 1.0);
            frames.push(crate::compress::wire::encode_scaled_sign(&p));
            payloads.push(p);
        }
        // truncate the middle frame below its header: undecodable
        frames[1].bytes.truncate(2);
        let mut groups = vec![frames];
        let mut partials = Vec::new();
        let mut decoded = Vec::new();
        let mut spare = Vec::new();
        pool.decode_partials_pooled(&mut groups, d, &mut partials, &mut decoded, &mut spare);
        assert_eq!(decoded, vec![2]);
        let mut want = vec![0.0f32; d];
        for i in [0usize, 2] {
            crate::compress::wire::decode_any_add(
                &crate::compress::wire::encode_scaled_sign(&payloads[i]),
                &mut want,
            )
            .unwrap();
        }
        assert_eq!(partials[0], want);
        assert_eq!(fabric.with_stats(|s| s.dropped()), 1);

        // dense flavour: the bad frame vanishes from the result
        let mut frames2: Vec<Encoded> = payloads
            .iter()
            .map(|p| crate::compress::wire::encode_scaled_sign(p))
            .collect();
        frames2[0].bytes.clear();
        let decoded2 = pool.decode_dense(frames2);
        assert_eq!(decoded2.len(), n - 1);
        assert_eq!(fabric.with_stats(|s| s.dropped()), 2);
    }

    #[test]
    fn decode_dense_returns_frames_in_index_order() {
        let d = 16;
        let n = 5;
        let mut rng = Pcg64::seeded(37);
        let frames: Vec<Encoded> = (0..n)
            .map(|_| {
                let mut p = vec![0.0f32; d];
                rng.fill_normal(&mut p, 0.0, 1.0);
                crate::compress::wire::encode_dense(&p)
            })
            .collect();
        let fabric = Arc::new(Fabric::new(n + 1, LinkModel::default()));
        let pool = WorkerPool::spawn(make_workers(n, d), fabric.clone(), 3);
        let decoded = pool.decode_dense(frames.clone());
        assert_eq!(decoded.len(), n);
        for (v, f) in decoded.iter().zip(frames.iter()) {
            assert_eq!(v, &crate::compress::wire::decode_any(f).unwrap());
        }
        // dense decode also recycles the spent frame buffers
        assert_eq!(fabric.frame_pool().pooled(), n);
    }

    #[test]
    fn step_workers_runs_only_the_subset() {
        let d = 16;
        let n = 5;
        let fabric = Arc::new(Fabric::new(n + 1, LinkModel::default()));
        let pool = WorkerPool::spawn(make_workers(n, d), fabric.clone(), 2);
        let ps = ParameterServer::new(&fabric);
        let subset = [3usize, 0, 4];
        let theta = vec![1.0f32; d];
        for &w in &subset {
            ps.send_params(&fabric, w, 0, &theta);
        }
        let reports = pool.step_workers(&subset, 0, 0.1);
        let ids: Vec<usize> = reports.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 3, 4]); // sorted by worker id
        assert!(reports.iter().all(|r| r.loss.is_finite()));
        // exactly the subset's frames are on the leader queue
        let msgs = fabric.recv_all(ps.leader);
        let mut srcs: Vec<usize> = msgs.iter().map(|m| m.src).collect();
        srcs.sort_unstable();
        assert_eq!(srcs, vec![0, 3, 4]);
    }

    #[test]
    fn sharded_round_pushes_one_frame_per_shard() {
        use crate::collectives::{ShardPlan, ShardedParameterServer};
        let d = 33;
        let n = 3;
        let shards = 2;
        let mut workers = make_workers(n, d);
        let plan = ShardPlan::new(d, shards);
        for w in workers.iter_mut() {
            w.set_shard_plan(plan.clone());
        }
        let fabric = Arc::new(Fabric::new(n + shards, LinkModel::default()));
        let pool = WorkerPool::spawn(workers, fabric.clone(), 2);
        let ps = ShardedParameterServer::new(&fabric, plan.clone());
        ps.broadcast_params(&fabric, 0, &vec![1.0f32; d]);
        let reports = pool.round(0, 0.1);
        assert_eq!(reports.len(), n);
        for s in 0..shards {
            let (frames, _latest) = ps.gather_shard_timed(&fabric, 0, s).unwrap();
            assert_eq!(frames.len(), n);
            assert!(frames.iter().all(|e| e.d == plan.len_of(s)));
            assert!(frames
                .iter()
                .all(|e| e.shard.map(|t| t.shard as usize) == Some(s)));
        }
        // exported EF state is full-length regardless of the shard plan
        let states = pool.export_states();
        assert!(states
            .iter()
            .all(|st| st.error.len() == d && st.corrected.len() == d));
        assert!(states.iter().all(|st| st.steps == 1));
    }

    #[test]
    fn eval_routes_to_owning_thread() {
        let d = 8;
        let n = 4;
        let fabric = Arc::new(Fabric::new(n + 1, LinkModel::default()));
        let pool = WorkerPool::spawn(make_workers(n, d), fabric, 2);
        let theta = vec![0.5f32; d];
        for w in 0..n {
            let (loss, _acc) = pool.eval(w, &theta);
            // quadratic loss of 0.5*||x||^2 at x = 0.5·1 is d/8
            assert!(loss.is_finite());
        }
    }
}
