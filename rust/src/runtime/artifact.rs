//! The artifact manifest: what `aot.py` built, with shapes and hashes.

use crate::util::json::Json;
use std::path::{Path, PathBuf};

#[derive(Debug)]
pub enum ManifestError {
    Io(std::io::Error),
    Json(crate::util::json::JsonError),
    Malformed(String),
}

impl std::fmt::Display for ManifestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ManifestError::Io(e) => write!(f, "io error reading manifest: {e}"),
            ManifestError::Json(e) => write!(f, "json error: {e}"),
            ManifestError::Malformed(msg) => write!(f, "manifest malformed: {msg}"),
        }
    }
}

impl std::error::Error for ManifestError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ManifestError::Io(e) => Some(e),
            ManifestError::Json(e) => Some(e),
            ManifestError::Malformed(_) => None,
        }
    }
}

impl From<std::io::Error> for ManifestError {
    fn from(e: std::io::Error) -> Self {
        ManifestError::Io(e)
    }
}

impl From<crate::util::json::JsonError> for ManifestError {
    fn from(e: crate::util::json::JsonError) -> Self {
        ManifestError::Json(e)
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self, ManifestError> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => Err(ManifestError::Malformed(format!("dtype {other}"))),
        }
    }
}

/// Shape+dtype of one artifact input/output.
#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl ArgSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One compiled computation.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub sha256: String,
    pub inputs: Vec<ArgSpec>,
    pub outputs: Vec<ArgSpec>,
}

/// One model configuration's artifact set.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub name: String,
    pub d: usize,
    pub vocab: usize,
    pub dim: usize,
    pub layers: usize,
    pub heads: usize,
    pub seq: usize,
    pub batch: usize,
    pub topk_k: usize,
    pub init_params_file: String,
    pub artifacts: Vec<ArtifactSpec>,
}

impl ModelEntry {
    pub fn artifact(&self, name: &str) -> Option<&ArtifactSpec> {
        let full = format!("{name}_{}", self.name);
        self.artifacts.iter().find(|a| a.name == full)
    }

    /// Tokens-per-batch shape (batch, seq+1).
    pub fn token_shape(&self) -> (usize, usize) {
        (self.batch, self.seq + 1)
    }
}

/// The parsed manifest.json.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub configs: Vec<ModelEntry>,
}

fn parse_args(j: &Json) -> Result<Vec<ArgSpec>, ManifestError> {
    let arr = j
        .as_arr()
        .ok_or_else(|| ManifestError::Malformed("args not array".into()))?;
    arr.iter()
        .map(|a| {
            let shape = a
                .get("shape")
                .and_then(|s| s.as_arr())
                .ok_or_else(|| ManifestError::Malformed("missing shape".into()))?
                .iter()
                .map(|v| v.as_usize().unwrap_or(0))
                .collect();
            let dtype = DType::parse(a.get("dtype").and_then(|d| d.as_str()).unwrap_or("f32"))?;
            Ok(ArgSpec { shape, dtype })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest, ManifestError> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        let j = Json::parse(&text)?;
        let configs = j
            .get("configs")
            .and_then(|c| c.as_arr())
            .ok_or_else(|| ManifestError::Malformed("missing configs".into()))?
            .iter()
            .map(|c| {
                let get_usize = |k: &str| c.get(k).and_then(|v| v.as_usize()).unwrap_or(0);
                let artifacts = c
                    .get("artifacts")
                    .and_then(|a| a.as_arr())
                    .ok_or_else(|| ManifestError::Malformed("missing artifacts".into()))?
                    .iter()
                    .map(|a| {
                        Ok(ArtifactSpec {
                            name: a
                                .get("name")
                                .and_then(|v| v.as_str())
                                .ok_or_else(|| {
                                    ManifestError::Malformed("artifact name".into())
                                })?
                                .to_string(),
                            file: a
                                .get("file")
                                .and_then(|v| v.as_str())
                                .unwrap_or_default()
                                .to_string(),
                            sha256: a
                                .get("sha256")
                                .and_then(|v| v.as_str())
                                .unwrap_or_default()
                                .to_string(),
                            inputs: parse_args(a.get("inputs").unwrap_or(&Json::Null))?,
                            outputs: parse_args(a.get("outputs").unwrap_or(&Json::Null))?,
                        })
                    })
                    .collect::<Result<Vec<_>, ManifestError>>()?;
                Ok(ModelEntry {
                    name: c
                        .get("name")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| ManifestError::Malformed("config name".into()))?
                        .to_string(),
                    d: get_usize("d"),
                    vocab: get_usize("vocab"),
                    dim: get_usize("dim"),
                    layers: get_usize("layers"),
                    heads: get_usize("heads"),
                    seq: get_usize("seq"),
                    batch: get_usize("batch"),
                    topk_k: get_usize("topk_k"),
                    init_params_file: c
                        .get("init_params")
                        .and_then(|v| v.as_str())
                        .unwrap_or_default()
                        .to_string(),
                    artifacts,
                })
            })
            .collect::<Result<Vec<_>, ManifestError>>()?;
        Ok(Manifest {
            dir: dir.to_path_buf(),
            configs,
        })
    }

    pub fn model(&self, name: &str) -> Option<&ModelEntry> {
        self.configs.iter().find(|c| c.name == name)
    }

    /// Load a model's initial parameters (raw LE f32).
    pub fn init_params(&self, entry: &ModelEntry) -> Result<Vec<f32>, ManifestError> {
        let bytes = std::fs::read(self.dir.join(&entry.init_params_file))?;
        if bytes.len() != entry.d * 4 {
            return Err(ManifestError::Malformed(format!(
                "init params size {} != 4*d ({})",
                bytes.len(),
                entry.d * 4
            )));
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

/// Default artifact directory: $EF_SGD_ARTIFACTS or ./artifacts.
pub fn default_dir() -> PathBuf {
    std::env::var("EF_SGD_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_available() -> Option<Manifest> {
        let dir = default_dir();
        Manifest::load(&dir).ok()
    }

    #[test]
    fn parses_built_manifest_if_present() {
        let Some(m) = artifacts_available() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let tiny = m.model("tiny").expect("tiny config");
        assert!(tiny.d > 0);
        assert!(tiny.artifact("lm_step").is_some());
        assert!(tiny.artifact("ef_sign").is_some());
        let ef = tiny.artifact("ef_sign").unwrap();
        assert_eq!(ef.inputs.len(), 3);
        assert_eq!(ef.inputs[0].shape, vec![tiny.d]);
        let params = m.init_params(tiny).unwrap();
        assert_eq!(params.len(), tiny.d);
    }

    #[test]
    fn parses_inline_manifest() {
        let dir = std::env::temp_dir().join(format!("efsgd_manifest_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version":1,"configs":[{"name":"x","d":4,"vocab":2,"dim":2,"layers":1,
                "heads":1,"seq":2,"batch":1,"topk_k":1,"init_params":"x.bin",
                "artifacts":[{"name":"lm_step_x","file":"lm_step_x.hlo.txt","sha256":"ab",
                  "bytes":10,"inputs":[{"shape":[4],"dtype":"f32"}],
                  "outputs":[{"shape":[],"dtype":"f32"}]}]}]}"#,
        )
        .unwrap();
        std::fs::write(dir.join("x.bin"), [0u8; 16]).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let e = m.model("x").unwrap();
        assert_eq!(e.d, 4);
        assert_eq!(e.artifact("lm_step").unwrap().inputs[0].dtype, DType::F32);
        assert_eq!(m.init_params(e).unwrap(), vec![0.0; 4]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_error() {
        assert!(Manifest::load(Path::new("/nonexistent/dir")).is_err());
    }
}
