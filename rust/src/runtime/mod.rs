//! PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client via the
//! `xla` crate. This is the only bridge between the Rust coordinator and
//! the JAX/Pallas compute layers — Python never runs here.
//!
//! Interchange is HLO *text* (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see DESIGN.md and /opt/xla-example/README.md).
//!
//! Sessions are shared across the coordinator's worker-pool threads via
//! `Arc<LmSession>` with a mutex-guarded compile cache. With the real
//! PJRT bindings the handle types are not `Send`; in that configuration
//! run the coordinator with `--threads 1`, which keeps every worker on a
//! single pool thread (communication is still accounted by the fabric).

pub mod artifact;
pub mod client;
pub mod executable;
pub mod lm;

pub use artifact::{ArgSpec, ArtifactSpec, DType, Manifest, ModelEntry};
pub use client::Runtime;
pub use executable::{ArgValue, Execution};
pub use lm::LmSession;
