//! PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client via the
//! `xla` crate. This is the only bridge between the Rust coordinator and
//! the JAX/Pallas compute layers — Python never runs here.
//!
//! Interchange is HLO *text* (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see DESIGN.md and /opt/xla-example/README.md).
//!
//! PJRT handle types are not `Send`; the runtime is used from the
//! single-threaded coordinator event loop (worker parallelism is simulated;
//! communication is accounted by the fabric).

pub mod artifact;
pub mod client;
pub mod executable;
pub mod lm;

pub use artifact::{ArgSpec, ArtifactSpec, DType, Manifest, ModelEntry};
pub use client::Runtime;
pub use executable::{ArgValue, Execution};
pub use lm::LmSession;
