//! The runtime: one PJRT CPU client + a compile cache keyed by artifact
//! name. Compilation happens once per artifact per process; the coordinator
//! hot loop only executes.

use super::artifact::{Manifest, ManifestError, ModelEntry};
use super::executable::Execution;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    // BTreeMap, not HashMap: iteration order never matters here today, but
    // detlint rule D1 keeps every collection in a determinism-critical
    // module ordered so it can never start mattering silently.
    cache: Mutex<BTreeMap<String, Arc<Execution>>>,
}

impl Runtime {
    /// Load the manifest and start the CPU PJRT client.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir).map_err(|e| anyhow!("{e}"))?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        log::info!(
            "runtime: platform={} devices={} artifacts={}",
            client.platform_name(),
            client.device_count(),
            manifest.configs.iter().map(|c| c.artifacts.len()).sum::<usize>()
        );
        Ok(Runtime {
            client,
            manifest,
            cache: Mutex::new(BTreeMap::new()),
        })
    }

    /// Load from the default directory (EF_SGD_ARTIFACTS or ./artifacts).
    pub fn load_default() -> Result<Runtime> {
        Self::load(&super::artifact::default_dir())
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.manifest
            .model(name)
            .ok_or_else(|| anyhow!("model config '{name}' not in manifest"))
    }

    pub fn init_params(&self, entry: &ModelEntry) -> Result<Vec<f32>, ManifestError> {
        self.manifest.init_params(entry)
    }

    /// Get (compiling and caching on first use) the executable for
    /// `<artifact>_<model>`.
    pub fn executable(&self, model: &ModelEntry, artifact: &str) -> Result<Arc<Execution>> {
        let spec = model
            .artifact(artifact)
            .ok_or_else(|| anyhow!("artifact '{artifact}' not in config '{}'", model.name))?;
        if let Some(hit) = self.cache.lock().unwrap().get(&spec.name) {
            return Ok(hit.clone());
        }
        let path = self.manifest.dir.join(&spec.file);
        let t = std::time::Instant::now(); // detlint: allow(D2) — compile-time log stamp, never sim time
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parse HLO text {}", spec.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", spec.name))?;
        log::info!(
            "runtime: compiled {} in {:.2}s",
            spec.name,
            t.elapsed().as_secs_f64()
        );
        let execution = Arc::new(Execution {
            spec: spec.clone(),
            exe,
        });
        self.cache
            .lock()
            .unwrap()
            .insert(spec.name.clone(), execution.clone());
        Ok(execution)
    }

    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

// Integration coverage for the runtime lives in
// rust/tests/runtime_integration.rs (requires `make artifacts`).
