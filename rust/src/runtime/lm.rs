//! LmSession: the typed facade over one model config's artifact set —
//! train step (loss+grad), the fused train+EF-compress step, the standalone
//! Pallas EF-sign kernel, eval, parameter update, and gradient density.

use super::client::Runtime;
use super::executable::{ArgValue, Execution};
use anyhow::Result;
use std::sync::Arc;

pub struct LmSession {
    pub model: super::artifact::ModelEntry,
    lm_step: Arc<Execution>,
    lm_eval: Arc<Execution>,
    lm_step_ef: Arc<Execution>,
    ef_sign: Arc<Execution>,
    ef_topk: Arc<Execution>,
    apply_update: Arc<Execution>,
    density: Arc<Execution>,
}

impl LmSession {
    /// Compile (or fetch cached) all artifacts for `model_name`.
    pub fn open(rt: &Runtime, model_name: &str) -> Result<LmSession> {
        let model = rt.model(model_name)?.clone();
        Ok(LmSession {
            lm_step: rt.executable(&model, "lm_step")?,
            lm_eval: rt.executable(&model, "lm_eval")?,
            lm_step_ef: rt.executable(&model, "lm_step_ef")?,
            ef_sign: rt.executable(&model, "ef_sign")?,
            ef_topk: rt.executable(&model, "ef_topk")?,
            apply_update: rt.executable(&model, "apply_update")?,
            density: rt.executable(&model, "density")?,
            model,
        })
    }

    pub fn d(&self) -> usize {
        self.model.d
    }

    /// Expected token buffer length (batch * (seq+1)).
    pub fn token_len(&self) -> usize {
        let (b, s) = self.model.token_shape();
        b * s
    }

    /// (loss, grad) at theta on a token batch.
    pub fn train_step(&self, theta: &[f32], tokens: &[i32]) -> Result<(f64, Vec<f32>)> {
        let outs = self
            .lm_step
            .call_f32(&[ArgValue::F32(theta), ArgValue::I32(tokens)])?;
        Ok((outs[0][0] as f64, outs[1].clone()))
    }

    /// Fused train + EF-scaled-sign compression (one PJRT dispatch):
    /// returns (loss, delta, new_error).
    pub fn train_step_ef(
        &self,
        theta: &[f32],
        e: &[f32],
        tokens: &[i32],
        gamma: f32,
    ) -> Result<(f64, Vec<f32>, Vec<f32>)> {
        let g = [gamma];
        let mut outs = self.lm_step_ef.call_f32(&[
            ArgValue::F32(theta),
            ArgValue::F32(e),
            ArgValue::I32(tokens),
            ArgValue::F32(&g),
        ])?;
        let e_new = outs.pop().unwrap();
        let delta = outs.pop().unwrap();
        Ok((outs[0][0] as f64, delta, e_new))
    }

    /// The standalone Pallas kernel: (delta, e_new) = EF-sign(g, e, gamma).
    pub fn ef_sign(&self, g: &[f32], e: &[f32], gamma: f32) -> Result<(Vec<f32>, Vec<f32>)> {
        let ga = [gamma];
        let mut outs = self.ef_sign.call_f32(&[
            ArgValue::F32(g),
            ArgValue::F32(e),
            ArgValue::F32(&ga),
        ])?;
        let e_new = outs.pop().unwrap();
        let delta = outs.pop().unwrap();
        Ok((delta, e_new))
    }

    /// The Pallas top-k variant (k fixed at AOT time, see manifest).
    pub fn ef_topk(&self, g: &[f32], e: &[f32], gamma: f32) -> Result<(Vec<f32>, Vec<f32>)> {
        let ga = [gamma];
        let mut outs = self.ef_topk.call_f32(&[
            ArgValue::F32(g),
            ArgValue::F32(e),
            ArgValue::F32(&ga),
        ])?;
        let e_new = outs.pop().unwrap();
        let delta = outs.pop().unwrap();
        Ok((delta, e_new))
    }

    /// Eval loss on a token batch.
    pub fn eval(&self, theta: &[f32], tokens: &[i32]) -> Result<f64> {
        let outs = self
            .lm_eval
            .call_f32(&[ArgValue::F32(theta), ArgValue::I32(tokens)])?;
        Ok(outs[0][0] as f64)
    }

    /// theta' = theta − delta (device-side).
    pub fn apply_update(&self, theta: &[f32], delta: &[f32]) -> Result<Vec<f32>> {
        let outs = self
            .apply_update
            .call_f32(&[ArgValue::F32(theta), ArgValue::F32(delta)])?;
        Ok(outs.into_iter().next().unwrap())
    }

    /// Gradient density phi(v) via the Pallas reduction kernel.
    pub fn density(&self, v: &[f32]) -> Result<f64> {
        let outs = self.density.call_f32(&[ArgValue::F32(v)])?;
        Ok(outs[0][0] as f64)
    }
}

// Numeric validation against the Rust-native reference implementations is
// in rust/tests/runtime_integration.rs (requires built artifacts).
