//! Execution helpers: typed argument marshalling to/from `xla::Literal`,
//! tuple unpacking, and a thin wrapper that pairs a compiled executable
//! with its manifest spec for shape checking.

use super::artifact::{ArgSpec, ArtifactSpec, DType};
use anyhow::{bail, Context, Result};
use xla::{Literal, PjRtLoadedExecutable};

/// A typed argument for an artifact call.
pub enum ArgValue<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl<'a> ArgValue<'a> {
    fn len(&self) -> usize {
        match self {
            ArgValue::F32(v) => v.len(),
            ArgValue::I32(v) => v.len(),
        }
    }

    fn to_literal(&self, spec: &ArgSpec) -> Result<Literal> {
        if self.len() != spec.elements() {
            bail!(
                "argument has {} elements, spec wants {:?}",
                self.len(),
                spec.shape
            );
        }
        let dims: Vec<i64> = spec.shape.iter().map(|&x| x as i64).collect();
        let lit = match (self, spec.dtype) {
            (ArgValue::F32(v), DType::F32) => Literal::vec1(v),
            (ArgValue::I32(v), DType::I32) => Literal::vec1(v),
            _ => bail!("dtype mismatch for arg with shape {:?}", spec.shape),
        };
        if spec.shape.len() == 1 {
            Ok(lit)
        } else {
            lit.reshape(&dims).context("reshape literal")
        }
    }
}

/// A compiled artifact plus its interface spec.
pub struct Execution {
    pub spec: ArtifactSpec,
    pub exe: PjRtLoadedExecutable,
}

impl Execution {
    /// Execute with shape-checked arguments; returns the output tuple parts.
    pub fn call(&self, args: &[ArgValue]) -> Result<Vec<Literal>> {
        if args.len() != self.spec.inputs.len() {
            bail!(
                "{}: got {} args, expected {}",
                self.spec.name,
                args.len(),
                self.spec.inputs.len()
            );
        }
        let literals: Vec<Literal> = args
            .iter()
            .zip(&self.spec.inputs)
            .map(|(a, s)| a.to_literal(s))
            .collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<Literal>(&literals)
            .with_context(|| format!("execute {}", self.spec.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetch result literal")?;
        // aot.py lowers with return_tuple=True: output is always a tuple.
        tuple.to_tuple().context("untuple result")
    }

    /// Convenience: call and convert every output to f32 vectors
    /// (scalars become length-1).
    pub fn call_f32(&self, args: &[ArgValue]) -> Result<Vec<Vec<f32>>> {
        let outs = self.call(args)?;
        outs.iter().map(lit_to_f32).collect()
    }
}

/// Literal (f32 array or scalar) to Vec<f32>.
pub fn lit_to_f32(lit: &Literal) -> Result<Vec<f32>> {
    let n = lit.element_count();
    if n == 1 {
        // covers rank-0 scalars, where to_vec can be touchy
        let v: f32 = lit.get_first_element()?;
        return Ok(vec![v]);
    }
    lit.to_vec::<f32>().context("literal to f32 vec")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argvalue_shape_checks() {
        let spec = ArgSpec {
            shape: vec![2, 2],
            dtype: DType::F32,
        };
        let ok = ArgValue::F32(&[1.0, 2.0, 3.0, 4.0]).to_literal(&spec);
        assert!(ok.is_ok());
        let bad_len = ArgValue::F32(&[1.0]).to_literal(&spec);
        assert!(bad_len.is_err());
        let bad_ty = ArgValue::I32(&[1, 2, 3, 4]).to_literal(&spec);
        assert!(bad_ty.is_err());
    }

    #[test]
    fn literal_roundtrip_f32() {
        let lit = Literal::vec1(&[1.0f32, -2.0, 3.5]);
        assert_eq!(lit_to_f32(&lit).unwrap(), vec![1.0, -2.0, 3.5]);
        let scalar = Literal::scalar(7.25f32);
        assert_eq!(lit_to_f32(&scalar).unwrap(), vec![7.25]);
    }
}
