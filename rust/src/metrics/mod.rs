//! Metric recording for experiments and training runs.
//!
//! A [`Recorder`] collects named scalar series keyed by step; writers dump
//! them as CSV (one column per series) or JSON for the experiment index in
//! EXPERIMENTS.md. Multi-seed runs aggregate through [`SeriesBundle`]
//! (mean ± std across repetitions, the paper's shaded-region plots).

use crate::util::json::{arr, num, obj, s, Json};
use crate::util::stats;
use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

/// A named scalar time-series.
#[derive(Clone, Debug, Default)]
pub struct Series {
    pub steps: Vec<u64>,
    pub values: Vec<f64>,
}

impl Series {
    pub fn push(&mut self, step: u64, value: f64) {
        self.steps.push(step);
        self.values.push(value);
    }

    pub fn last(&self) -> Option<f64> {
        self.values.last().copied()
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Minimum value in the series.
    pub fn min(&self) -> Option<f64> {
        self.values
            .iter()
            .cloned()
            .fold(None, |m: Option<f64>, v| Some(m.map_or(v, |m| m.min(v))))
    }

    pub fn max(&self) -> Option<f64> {
        self.values
            .iter()
            .cloned()
            .fold(None, |m: Option<f64>, v| Some(m.map_or(v, |m| m.max(v))))
    }
}

/// Collects many named series for one run.
#[derive(Clone, Debug, Default)]
pub struct Recorder {
    pub series: BTreeMap<String, Series>,
    pub tags: BTreeMap<String, String>,
}

impl Recorder {
    pub fn new() -> Self {
        Recorder::default()
    }

    pub fn tag(&mut self, key: &str, value: &str) {
        self.tags.insert(key.to_string(), value.to_string());
    }

    pub fn record(&mut self, name: &str, step: u64, value: f64) {
        // fast path first: the training loop records a fixed set of names
        // every round, and `entry` would allocate a String per call just
        // to look one up
        if let Some(series) = self.series.get_mut(name) {
            series.push(step, value);
            return;
        }
        let mut series = Series::default();
        series.push(step, value);
        self.series.insert(name.to_string(), series);
    }

    /// Reserve room for `extra` more points in every existing series.
    /// Callers that need an allocation-free measurement window (the
    /// steady-state alloc-regression test) pre-size the recording buffers
    /// with this after a warm-up round has created the series.
    pub fn reserve_all(&mut self, extra: usize) {
        for series in self.series.values_mut() {
            series.steps.reserve(extra);
            series.values.reserve(extra);
        }
    }

    pub fn get(&self, name: &str) -> Option<&Series> {
        self.series.get(name)
    }

    /// Last value of a series, or NaN.
    pub fn last(&self, name: &str) -> f64 {
        self.get(name).and_then(|s| s.last()).unwrap_or(f64::NAN)
    }

    /// CSV with a `step` column and one column per series (union of steps;
    /// missing values are empty cells).
    pub fn to_csv(&self) -> String {
        let mut steps: Vec<u64> = self
            .series
            .values()
            .flat_map(|s| s.steps.iter().copied())
            .collect();
        steps.sort_unstable();
        steps.dedup();
        let names: Vec<&String> = self.series.keys().collect();
        let mut out = String::from("step");
        for n in &names {
            out.push(',');
            out.push_str(n);
        }
        out.push('\n');
        // per-series step -> value maps
        let maps: Vec<BTreeMap<u64, f64>> = names
            .iter()
            .map(|n| {
                let s = &self.series[*n];
                s.steps.iter().copied().zip(s.values.iter().copied()).collect()
            })
            .collect();
        for step in steps {
            out.push_str(&step.to_string());
            for m in &maps {
                out.push(',');
                if let Some(v) = m.get(&step) {
                    out.push_str(&format!("{v}"));
                }
            }
            out.push('\n');
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let series = Json::Obj(
            self.series
                .iter()
                .map(|(k, v)| {
                    (
                        k.clone(),
                        obj(vec![
                            (
                                "steps",
                                arr(v.steps.iter().map(|&x| num(x as f64)).collect()),
                            ),
                            ("values", arr(v.values.iter().map(|&x| num(x)).collect())),
                        ]),
                    )
                })
                .collect(),
        );
        let tags = Json::Obj(
            self.tags
                .iter()
                .map(|(k, v)| (k.clone(), s(v)))
                .collect(),
        );
        obj(vec![("tags", tags), ("series", series)])
    }

    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())
    }

    pub fn write_json(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_json().to_string_compact().as_bytes())
    }
}

/// Aggregates the same series across repetitions (seeds): mean ± std at
/// each recorded step — the paper's "solid curve + shaded region".
#[derive(Clone, Debug, Default)]
pub struct SeriesBundle {
    pub runs: Vec<Series>,
}

impl SeriesBundle {
    pub fn push(&mut self, s: Series) {
        self.runs.push(s);
    }

    /// (steps, mean, std) truncated to the shortest run.
    pub fn aggregate(&self) -> (Vec<u64>, Vec<f64>, Vec<f64>) {
        if self.runs.is_empty() {
            return (vec![], vec![], vec![]);
        }
        let n = self.runs.iter().map(|r| r.len()).min().unwrap();
        let steps = self.runs[0].steps[..n].to_vec();
        let mut means = Vec::with_capacity(n);
        let mut stds = Vec::with_capacity(n);
        for i in 0..n {
            let vals: Vec<f64> = self.runs.iter().map(|r| r.values[i]).collect();
            means.push(stats::mean(&vals));
            stds.push(stats::std(&vals));
        }
        (steps, means, stds)
    }

    /// Mean and std of the final value across runs.
    pub fn final_stats(&self) -> (f64, f64) {
        let finals: Vec<f64> = self.runs.iter().filter_map(|r| r.last()).collect();
        (stats::mean(&finals), stats::std(&finals))
    }

    /// Mean of the per-run maxima (e.g. "best test accuracy", Table 1).
    pub fn best_stats(&self) -> (f64, f64) {
        let bests: Vec<f64> = self.runs.iter().filter_map(|r| r.max()).collect();
        (stats::mean(&bests), stats::std(&bests))
    }
}

/// Render an ASCII sparkline of a series — experiment drivers print these so
/// the loss curves are visible in terminal output / EXPERIMENTS.md.
///
/// Emits exactly `min(width, values.len())` glyphs. NaN values are skipped
/// when finding the lo/hi range (a single NaN used to poison both folds and
/// render the whole line as `█`); NaN cells themselves draw as the lowest
/// glyph.
pub fn sparkline(values: &[f64], width: usize) -> String {
    if values.is_empty() || width == 0 {
        return String::new();
    }
    const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let (lo, hi) = values
        .iter()
        .filter(|v| !v.is_nan())
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &v| {
            (lo.min(v), hi.max(v))
        });
    let span = (hi - lo).max(1e-12);
    let n = values.len();
    let cells = width.min(n);
    let mut out = String::with_capacity(cells * GLYPHS[0].len_utf8());
    for i in 0..cells {
        // integer bucketing: cell i samples values[i*n/cells], which is
        // strictly increasing in i and always in range
        let v = values[i * n / cells];
        let idx = if v.is_nan() || !lo.is_finite() {
            0
        } else {
            ((((v - lo) / span) * 7.0).round() as usize).min(7)
        };
        out.push(GLYPHS[idx]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_csv() {
        let mut r = Recorder::new();
        r.record("loss", 0, 2.0);
        r.record("loss", 1, 1.5);
        r.record("acc", 1, 0.4);
        let csv = r.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "step,acc,loss");
        assert_eq!(lines[1], "0,,2");
        assert_eq!(lines[2], "1,0.4,1.5");
    }

    #[test]
    fn json_roundtrip() {
        let mut r = Recorder::new();
        r.tag("algo", "ef-signsgd");
        r.record("loss", 0, 1.0);
        let j = r.to_json();
        let parsed = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(
            parsed.at(&["tags", "algo"]).unwrap().as_str(),
            Some("ef-signsgd")
        );
        assert_eq!(
            parsed
                .at(&["series", "loss", "values", "0"])
                .unwrap()
                .as_f64(),
            Some(1.0)
        );
    }

    #[test]
    fn bundle_aggregates() {
        let mut b = SeriesBundle::default();
        for off in 0..3 {
            let mut s = Series::default();
            for t in 0..5 {
                s.push(t, t as f64 + off as f64);
            }
            b.push(s);
        }
        let (steps, mean, std) = b.aggregate();
        assert_eq!(steps.len(), 5);
        assert!((mean[0] - 1.0).abs() < 1e-12);
        assert!((std[0] - 1.0).abs() < 1e-12);
        let (fm, _) = b.final_stats();
        assert!((fm - 5.0).abs() < 1e-12);
    }

    #[test]
    fn sparkline_has_width() {
        let vals: Vec<f64> = (0..100).map(|i| (i as f64 / 10.0).sin()).collect();
        let sl = sparkline(&vals, 20);
        assert_eq!(sl.chars().count(), 20);
    }

    #[test]
    fn sparkline_emits_exactly_min_width_len_glyphs() {
        for n in 1..=120usize {
            let vals: Vec<f64> = (0..n).map(|i| i as f64).collect();
            for width in 1..=60usize {
                let sl = sparkline(&vals, width);
                assert_eq!(
                    sl.chars().count(),
                    width.min(n),
                    "n={n} width={width} got '{sl}'"
                );
            }
        }
    }

    #[test]
    fn sparkline_skips_nan_in_range() {
        // a single NaN used to poison the min/max folds (min(NaN, x) = NaN)
        // and flatten the whole line; the range must come from finite values
        let vals = vec![0.0, f64::NAN, 1.0, 0.5];
        let sl = sparkline(&vals, 4);
        assert_eq!(sl.chars().count(), 4);
        let glyphs: Vec<char> = sl.chars().collect();
        assert_eq!(glyphs[0], '▁'); // 0.0 is the low end
        assert_eq!(glyphs[1], '▁'); // NaN cell draws as the lowest glyph
        assert_eq!(glyphs[2], '█'); // 1.0 is the high end
        // all-NaN input still emits the right number of glyphs
        let all_nan = sparkline(&[f64::NAN, f64::NAN], 5);
        assert_eq!(all_nan.chars().count(), 2);
    }
}
