//! Run configuration: a TOML-subset parser and the typed configs.
//!
//! The `toml` crate is unavailable offline; this parser covers the subset
//! used by `configs/*.toml`: `[section]` and `[section.sub]` headers,
//! `key = value` with string/int/float/bool/array values, `#` comments.
//! Values are flattened into a dotted-key map (`training.batch_size`), which
//! the typed config structs read with defaults.

use std::collections::BTreeMap;
use std::path::Path;

#[derive(Debug)]
pub enum ConfigError {
    Parse(usize, String),
    Missing(String),
    Type(String, &'static str),
    Io(std::io::Error),
    BadValue(String, String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Parse(line, msg) => write!(f, "config parse error at line {line}: {msg}"),
            ConfigError::Missing(key) => write!(f, "missing required key '{key}'"),
            ConfigError::Type(key, want) => write!(f, "key '{key}' has wrong type (expected {want})"),
            ConfigError::Io(e) => write!(f, "io error: {e}"),
            ConfigError::BadValue(key, value) => write!(f, "unknown value '{value}' for '{key}'"),
        }
    }
}

impl std::error::Error for ConfigError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ConfigError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for ConfigError {
    fn from(e: std::io::Error) -> Self {
        ConfigError::Io(e)
    }
}

/// A parsed scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Flat dotted-key config map.
#[derive(Clone, Debug, Default)]
pub struct ConfigMap {
    pub values: BTreeMap<String, Value>,
}

impl ConfigMap {
    pub fn parse(text: &str) -> Result<Self, ConfigError> {
        let mut map = BTreeMap::new();
        let mut prefix = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    return Err(ConfigError::Parse(lineno + 1, "unterminated section".into()));
                }
                prefix = line[1..line.len() - 1].trim().to_string();
                if prefix.is_empty() {
                    return Err(ConfigError::Parse(lineno + 1, "empty section".into()));
                }
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| ConfigError::Parse(lineno + 1, "expected key = value".into()))?;
            let key = line[..eq].trim();
            let vtext = line[eq + 1..].trim();
            if key.is_empty() || vtext.is_empty() {
                return Err(ConfigError::Parse(lineno + 1, "empty key or value".into()));
            }
            let full = if prefix.is_empty() {
                key.to_string()
            } else {
                format!("{prefix}.{key}")
            };
            let value = parse_value(vtext)
                .ok_or_else(|| ConfigError::Parse(lineno + 1, format!("bad value: {vtext}")))?;
            map.insert(full, value);
        }
        Ok(ConfigMap { values: map })
    }

    pub fn load(path: &Path) -> Result<Self, ConfigError> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// Merge `other` over `self` (other wins). Used for CLI overrides.
    pub fn merge(&mut self, other: ConfigMap) {
        self.values.extend(other.values);
    }

    /// Set a single dotted key from a `key=value` string (CLI `--set`).
    pub fn set_kv(&mut self, kv: &str) -> Result<(), ConfigError> {
        let eq = kv
            .find('=')
            .ok_or_else(|| ConfigError::Parse(0, format!("--set expects key=value, got {kv}")))?;
        let key = kv[..eq].trim().to_string();
        let value = parse_value(kv[eq + 1..].trim())
            .ok_or_else(|| ConfigError::Parse(0, format!("bad value in {kv}")))?;
        self.values.insert(key, value);
        Ok(())
    }

    // ---- typed getters with defaults ------------------------------------

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.values
            .get(key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.values
            .get(key)
            .and_then(|v| v.as_i64())
            .map(|v| v as usize)
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.values
            .get(key)
            .and_then(|v| v.as_f64())
            .unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.values
            .get(key)
            .and_then(|v| v.as_bool())
            .unwrap_or(default)
    }

    pub fn require_str(&self, key: &str) -> Result<String, ConfigError> {
        self.values
            .get(key)
            .ok_or_else(|| ConfigError::Missing(key.into()))?
            .as_str()
            .map(|s| s.to_string())
            .ok_or(ConfigError::Type(key.into(), "string"))
    }
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> Option<Value> {
    if text.starts_with('"') && text.ends_with('"') && text.len() >= 2 {
        return Some(Value::Str(text[1..text.len() - 1].to_string()));
    }
    if text == "true" {
        return Some(Value::Bool(true));
    }
    if text == "false" {
        return Some(Value::Bool(false));
    }
    if text.starts_with('[') && text.ends_with(']') {
        let inner = &text[1..text.len() - 1];
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in inner.split(',') {
                items.push(parse_value(part.trim())?);
            }
        }
        return Some(Value::Arr(items));
    }
    if let Ok(i) = text.parse::<i64>() {
        return Some(Value::Int(i));
    }
    if let Ok(f) = text.parse::<f64>() {
        return Some(Value::Float(f));
    }
    None
}

// --------------------------------------------------------------------------
// Typed run configs

/// Which compressor the coordinator applies to worker gradients.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CompressorKind {
    /// No compression (plain SGD path).
    None,
    /// Unscaled sign (1 bit/coord, the divergent baseline).
    Sign,
    /// (||p||_1/d) sign(p) — the paper's scaled sign (Lemma 8).
    ScaledSign,
    /// Top-k by magnitude.
    TopK,
    /// Random-k sparsification.
    RandomK,
    /// QSGD stochastic quantization (unbiased).
    Qsgd,
    /// TernGrad {-1, 0, +1} (unbiased).
    TernGrad,
}

impl CompressorKind {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "none" | "identity" => CompressorKind::None,
            "sign" => CompressorKind::Sign,
            "scaled_sign" | "scaled-sign" => CompressorKind::ScaledSign,
            "topk" | "top-k" => CompressorKind::TopK,
            "randomk" | "random-k" => CompressorKind::RandomK,
            "qsgd" => CompressorKind::Qsgd,
            "terngrad" => CompressorKind::TernGrad,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            CompressorKind::None => "none",
            CompressorKind::Sign => "sign",
            CompressorKind::ScaledSign => "scaled_sign",
            CompressorKind::TopK => "topk",
            CompressorKind::RandomK => "randomk",
            CompressorKind::Qsgd => "qsgd",
            CompressorKind::TernGrad => "terngrad",
        }
    }
}

/// Training-run configuration (the distributed driver and the e2e example).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Model config name in the artifact manifest ("tiny", "small").
    pub model: String,
    pub workers: usize,
    /// Worker-pool threads for the coordinator (1 = sequential).
    pub threads: usize,
    /// Parameter-server shards (1 = the single-leader topology).
    pub shards: usize,
    pub steps: usize,
    pub lr: f64,
    pub momentum: f64,
    pub weight_decay: f64,
    pub compressor: CompressorKind,
    pub error_feedback: bool,
    /// top-k / random-k keep fraction denominator (keep d/k_frac coords).
    pub k_frac: usize,
    /// QSGD quantization levels.
    pub qsgd_levels: u32,
    pub seed: u64,
    /// Aggregation: "mean" or "majority_vote".
    pub aggregation: String,
    /// LR decay: divide by 10 at these step fractions (paper: 0.5, 0.75).
    pub lr_decay_at: Vec<f64>,
    pub eval_every: usize,
    pub log_every: usize,
    pub artifacts_dir: String,
    /// Bounded-staleness async rounds instead of lock-step (CLI `--async`).
    pub async_mode: bool,
    /// Async quorum: fold once this many frames arrive (0 = all workers).
    pub quorum: usize,
    /// Async staleness bound in rounds (0 = no stale folds ≡ synchronous).
    pub max_staleness: u64,
    /// Straggler model spec (`constant`, `uniform[:J]`, `lognormal[:S]`,
    /// `failslow:NODE[:F]`) — parsed by `net::StragglerModel::parse`.
    pub straggler: String,
    /// Byzantine worker model spec (`none`, `signflip:F`,
    /// `norminflate:F[:X]`, `collude:F`, `randombytes:F`) — parsed by
    /// `net::AdversaryModel::parse`.
    pub adversary: String,
    /// Elastic-membership churn spec (`none` or a comma-separated list of
    /// `leave:W@R`/`crash:W@R`/`rejoin:W@R`/`join:W@R`) — parsed by
    /// `net::MembershipSchedule::parse`.
    pub churn: String,
    /// Base worker compute time per step in milliseconds (virtual clock).
    pub compute_ms: f64,
    /// Link preset for the fabric (`10gbe`, `1gbe`, `ib`, `wan`).
    pub link: String,
    /// Serialize each sender's uplink (CLI `--link-serialized`): frames
    /// from one node queue FIFO on its link instead of overlapping.
    pub link_serialized: bool,
    /// Leader decode-cost pricing: `measured` (wall-clock profile, the
    /// historical default) or `calibrated` (the analytic
    /// `DecodeCostModel`, machine-independent `sim_time_s`).
    pub leader_cost: String,
    /// Flight-recorder ring capacity per node when `--trace` is given
    /// (events kept per track; the ring overwrites its oldest entries).
    pub trace_ring: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "tiny".into(),
            workers: 1,
            threads: 1,
            shards: 1,
            steps: 100,
            lr: 0.1,
            momentum: 0.0,
            weight_decay: 0.0,
            compressor: CompressorKind::ScaledSign,
            error_feedback: true,
            k_frac: 64,
            qsgd_levels: 4,
            seed: 0,
            aggregation: "mean".into(),
            lr_decay_at: vec![0.5, 0.75],
            eval_every: 0,
            log_every: 10,
            artifacts_dir: "artifacts".into(),
            async_mode: false,
            quorum: 0,
            max_staleness: 0,
            straggler: "constant".into(),
            adversary: "none".into(),
            churn: "none".into(),
            compute_ms: 1.0,
            link: "10gbe".into(),
            link_serialized: false,
            leader_cost: "measured".into(),
            trace_ring: crate::obs::trace::DEFAULT_RING_CAPACITY,
        }
    }
}

impl TrainConfig {
    pub fn from_map(m: &ConfigMap) -> Result<Self, ConfigError> {
        let d = TrainConfig::default();
        let comp_name = m.str_or("training.compressor", d.compressor.name());
        let compressor = CompressorKind::parse(&comp_name)
            .ok_or_else(|| ConfigError::BadValue("training.compressor".into(), comp_name))?;
        let lr_decay_at = match m.values.get("training.lr_decay_at") {
            Some(Value::Arr(items)) => items.iter().filter_map(|v| v.as_f64()).collect(),
            _ => d.lr_decay_at.clone(),
        };
        // The QSGD wire pack stores the level count in a u8; reject bad
        // settings at load time instead of panicking mid-training.
        let qsgd_levels = m.usize_or("training.qsgd_levels", d.qsgd_levels as usize);
        if !(1..=u8::MAX as usize).contains(&qsgd_levels) {
            return Err(ConfigError::BadValue(
                "training.qsgd_levels".into(),
                format!("{qsgd_levels} (must be 1..=255: the wire format's level count is a u8)"),
            ));
        }
        // straggler / link specs are validated here so a typo fails at
        // config load, not mid-run
        let straggler = m.str_or("training.straggler", &d.straggler);
        if let Err(e) = crate::net::StragglerModel::parse(&straggler) {
            return Err(ConfigError::BadValue(
                "training.straggler".into(),
                e.to_string(),
            ));
        }
        // churn specs likewise fail at load time, with the parser's typed
        // error (offending token + grammar) forwarded verbatim
        let churn = m.str_or("training.churn", &d.churn);
        if let Err(e) = crate::net::MembershipSchedule::parse(&churn) {
            return Err(ConfigError::BadValue("training.churn".into(), e.to_string()));
        }
        let link = m.str_or("training.link", &d.link);
        if crate::net::LinkModel::preset(&link).is_none() {
            return Err(ConfigError::BadValue("training.link".into(), link));
        }
        // leader-cost pricing mode is a closed two-value set; a typo here
        // would silently fall back to measured timing, so validate it
        let leader_cost = m.str_or("training.leader_cost", &d.leader_cost);
        if !matches!(leader_cost.as_str(), "measured" | "calibrated") {
            return Err(ConfigError::BadValue(
                "training.leader_cost".into(),
                format!("{leader_cost} (must be 'measured' or 'calibrated')"),
            ));
        }
        // adversary and aggregation specs likewise fail at load time
        let adversary = m.str_or("training.adversary", &d.adversary);
        if crate::net::AdversaryModel::parse(&adversary).is_none() {
            return Err(ConfigError::BadValue("training.adversary".into(), adversary));
        }
        let aggregation = m.str_or("training.aggregation", &d.aggregation);
        if crate::coordinator::Aggregation::parse(&aggregation).is_none() {
            return Err(ConfigError::BadValue(
                "training.aggregation".into(),
                aggregation,
            ));
        }
        // shards = 0 is meaningless (the driver clamps to 1..=d, but a
        // zero in the config is a typo worth failing loudly on)
        let shards = m.usize_or("training.shards", d.shards);
        if shards == 0 {
            return Err(ConfigError::BadValue(
                "training.shards".into(),
                "0 (must be >= 1)".into(),
            ));
        }
        // trace_ring = 0 would mean a zero-capacity flight recorder; tracing
        // is switched off by omitting --trace, so a zero here is a typo
        let trace_ring = m.usize_or("training.trace_ring", d.trace_ring);
        if trace_ring == 0 {
            return Err(ConfigError::BadValue(
                "training.trace_ring".into(),
                "0 (must be >= 1; omit --trace to disable tracing)".into(),
            ));
        }
        Ok(TrainConfig {
            model: m.str_or("model.name", &d.model),
            workers: m.usize_or("training.workers", d.workers),
            threads: m.usize_or("training.threads", d.threads),
            shards,
            steps: m.usize_or("training.steps", d.steps),
            lr: m.f64_or("training.lr", d.lr),
            momentum: m.f64_or("training.momentum", d.momentum),
            weight_decay: m.f64_or("training.weight_decay", d.weight_decay),
            compressor,
            error_feedback: m.bool_or("training.error_feedback", d.error_feedback),
            k_frac: m.usize_or("training.k_frac", d.k_frac),
            qsgd_levels: qsgd_levels as u32,
            seed: m.usize_or("training.seed", d.seed as usize) as u64,
            aggregation,
            lr_decay_at,
            eval_every: m.usize_or("training.eval_every", d.eval_every),
            log_every: m.usize_or("training.log_every", d.log_every),
            artifacts_dir: m.str_or("paths.artifacts", &d.artifacts_dir),
            async_mode: m.bool_or("training.async", d.async_mode),
            quorum: m.usize_or("training.quorum", d.quorum),
            max_staleness: m.usize_or("training.max_staleness", d.max_staleness as usize) as u64,
            straggler,
            adversary,
            churn,
            compute_ms: m.f64_or("training.compute_ms", d.compute_ms),
            link,
            link_serialized: m.bool_or("training.link_serialized", d.link_serialized),
            leader_cost,
            trace_ring,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# a run config
[model]
name = "small"   # which artifact config

[training]
workers = 4
steps = 300
lr = 0.056
compressor = "scaled_sign"
error_feedback = true
lr_decay_at = [0.5, 0.75]

[paths]
artifacts = "artifacts"
"#;

    #[test]
    fn parses_sample() {
        let m = ConfigMap::parse(SAMPLE).unwrap();
        assert_eq!(m.str_or("model.name", "x"), "small");
        assert_eq!(m.usize_or("training.workers", 0), 4);
        assert!((m.f64_or("training.lr", 0.0) - 0.056).abs() < 1e-12);
        assert!(m.bool_or("training.error_feedback", false));
    }

    #[test]
    fn typed_config() {
        let m = ConfigMap::parse(SAMPLE).unwrap();
        let tc = TrainConfig::from_map(&m).unwrap();
        assert_eq!(tc.model, "small");
        assert_eq!(tc.workers, 4);
        assert_eq!(tc.compressor, CompressorKind::ScaledSign);
        assert_eq!(tc.lr_decay_at, vec![0.5, 0.75]);
    }

    #[test]
    fn comments_and_strings() {
        let m = ConfigMap::parse("a = \"x # not a comment\" # comment\n").unwrap();
        assert_eq!(m.str_or("a", ""), "x # not a comment");
    }

    #[test]
    fn set_kv_overrides() {
        let mut m = ConfigMap::parse(SAMPLE).unwrap();
        m.set_kv("training.workers=8").unwrap();
        m.set_kv("training.compressor=\"topk\"").unwrap();
        let tc = TrainConfig::from_map(&m).unwrap();
        assert_eq!(tc.workers, 8);
        assert_eq!(tc.compressor, CompressorKind::TopK);
    }

    #[test]
    fn rejects_qsgd_levels_beyond_u8() {
        // the QSGD wire pack's level count travels as a u8 — bad settings
        // must fail at config load, not panic mid-training in the encoder
        let mut m = ConfigMap::parse(SAMPLE).unwrap();
        m.set_kv("training.qsgd_levels=256").unwrap();
        assert!(matches!(
            TrainConfig::from_map(&m),
            Err(ConfigError::BadValue(..))
        ));
        m.set_kv("training.qsgd_levels=0").unwrap();
        assert!(TrainConfig::from_map(&m).is_err());
        m.set_kv("training.qsgd_levels=255").unwrap();
        assert_eq!(TrainConfig::from_map(&m).unwrap().qsgd_levels, 255);
    }

    #[test]
    fn async_keys_parse_and_validate() {
        let mut m = ConfigMap::parse(SAMPLE).unwrap();
        m.set_kv("training.async=true").unwrap();
        m.set_kv("training.quorum=3").unwrap();
        m.set_kv("training.max_staleness=2").unwrap();
        m.set_kv("training.straggler=\"lognormal:1.5\"").unwrap();
        m.set_kv("training.link=\"wan\"").unwrap();
        let tc = TrainConfig::from_map(&m).unwrap();
        assert!(tc.async_mode);
        assert_eq!(tc.quorum, 3);
        assert_eq!(tc.max_staleness, 2);
        assert_eq!(tc.straggler, "lognormal:1.5");
        assert_eq!(tc.link, "wan");
        // bad straggler / link specs fail at load time
        m.set_kv("training.straggler=\"bogus\"").unwrap();
        assert!(matches!(
            TrainConfig::from_map(&m),
            Err(ConfigError::BadValue(..))
        ));
        m.set_kv("training.straggler=\"constant\"").unwrap();
        m.set_kv("training.link=\"dialup\"").unwrap();
        assert!(TrainConfig::from_map(&m).is_err());
    }

    #[test]
    fn robustness_keys_parse_and_validate() {
        let mut m = ConfigMap::parse(SAMPLE).unwrap();
        let tc = TrainConfig::from_map(&m).unwrap();
        assert_eq!(tc.adversary, "none");
        assert_eq!(tc.aggregation, "mean");
        m.set_kv("training.adversary=\"signflip:0.25\"").unwrap();
        m.set_kv("training.aggregation=\"median\"").unwrap();
        let tc = TrainConfig::from_map(&m).unwrap();
        assert_eq!(tc.adversary, "signflip:0.25");
        assert_eq!(tc.aggregation, "median");
        m.set_kv("training.aggregation=\"trimmed:2\"").unwrap();
        assert_eq!(TrainConfig::from_map(&m).unwrap().aggregation, "trimmed:2");
        // bad specs fail at config load, not mid-run
        m.set_kv("training.adversary=\"signflip\"").unwrap();
        assert!(matches!(
            TrainConfig::from_map(&m),
            Err(ConfigError::BadValue(..))
        ));
        m.set_kv("training.adversary=\"none\"").unwrap();
        m.set_kv("training.aggregation=\"mode\"").unwrap();
        assert!(TrainConfig::from_map(&m).is_err());
    }

    #[test]
    fn churn_key_parses_and_validates() {
        let mut m = ConfigMap::parse(SAMPLE).unwrap();
        assert_eq!(TrainConfig::from_map(&m).unwrap().churn, "none");
        m.set_kv("training.churn=\"crash:1@3,rejoin:1@6\"").unwrap();
        assert_eq!(
            TrainConfig::from_map(&m).unwrap().churn,
            "crash:1@3,rejoin:1@6"
        );
        // a malformed spec fails at load time with the parser's message
        m.set_kv("training.churn=\"vanish:1@3\"").unwrap();
        match TrainConfig::from_map(&m) {
            Err(ConfigError::BadValue(key, msg)) => {
                assert_eq!(key, "training.churn");
                assert!(msg.contains("vanish:1@3"), "{msg}");
                assert!(msg.contains("accepted grammar"), "{msg}");
            }
            other => panic!("expected BadValue, got {other:?}"),
        }
    }

    #[test]
    fn shards_parse_and_validate() {
        let mut m = ConfigMap::parse(SAMPLE).unwrap();
        assert_eq!(TrainConfig::from_map(&m).unwrap().shards, 1);
        m.set_kv("training.shards=4").unwrap();
        assert_eq!(TrainConfig::from_map(&m).unwrap().shards, 4);
        m.set_kv("training.shards=0").unwrap();
        assert!(matches!(
            TrainConfig::from_map(&m),
            Err(ConfigError::BadValue(..))
        ));
    }

    #[test]
    fn trace_ring_parses_and_validates() {
        let mut m = ConfigMap::parse(SAMPLE).unwrap();
        assert_eq!(
            TrainConfig::from_map(&m).unwrap().trace_ring,
            crate::obs::trace::DEFAULT_RING_CAPACITY
        );
        m.set_kv("training.trace_ring=128").unwrap();
        assert_eq!(TrainConfig::from_map(&m).unwrap().trace_ring, 128);
        m.set_kv("training.trace_ring=0").unwrap();
        assert!(matches!(
            TrainConfig::from_map(&m),
            Err(ConfigError::BadValue(..))
        ));
    }

    #[test]
    fn rejects_bad_syntax() {
        assert!(ConfigMap::parse("[unterminated\n").is_err());
        assert!(ConfigMap::parse("novalue =\n").is_err());
        assert!(ConfigMap::parse("bad value\n").is_err());
    }

    #[test]
    fn compressor_kind_roundtrip() {
        for k in [
            CompressorKind::None,
            CompressorKind::Sign,
            CompressorKind::ScaledSign,
            CompressorKind::TopK,
            CompressorKind::RandomK,
            CompressorKind::Qsgd,
            CompressorKind::TernGrad,
        ] {
            assert_eq!(CompressorKind::parse(k.name()), Some(k));
        }
        assert_eq!(CompressorKind::parse("bogus"), None);
    }
}
