//! Native Rust models: the counterexample problems of §3, the
//! over-parameterized least-squares of §5, the sparse-noise quadratic of
//! Appendix A.1, and an MLP classifier with manual backprop used by the
//! CIFAR-simulation sweeps (running hundreds of training runs through the
//! PJRT transformer would be wallclock-prohibitive; the phenomena are
//! optimizer-level — see DESIGN.md substitutions).

pub mod least_squares;
pub mod mlp;
pub mod toy;

pub use least_squares::LeastSquares;
pub use mlp::{Mlp, MlpConfig};

use crate::util::Pcg64;

/// A differentiable objective with stochastic gradients over a flat
/// parameter vector — the native counterpart of the L2 artifact interface.
pub trait StochasticObjective {
    /// Parameter dimension.
    fn dim(&self) -> usize;

    /// Full-batch objective value.
    fn loss(&self, x: &[f32]) -> f64;

    /// Sample a stochastic gradient at `x` into `out`; returns the
    /// minibatch loss.
    fn stoch_grad(&self, x: &[f32], rng: &mut Pcg64, out: &mut [f32]) -> f64;

    /// Full-batch (deterministic) gradient, if cheap. Default: average many
    /// stochastic draws (used only by tests).
    fn full_grad(&self, x: &[f32], out: &mut [f32]) {
        let mut rng = Pcg64::seeded(0);
        let mut tmp = vec![0.0f32; self.dim()];
        crate::tensor::zero(out);
        let n = 256;
        for _ in 0..n {
            self.stoch_grad(x, &mut rng, &mut tmp);
            crate::tensor::add_assign(out, &tmp);
        }
        crate::tensor::scale(1.0 / n as f32, out);
    }
}
