//! A fully-connected ReLU classifier with manual backprop over a flat
//! parameter vector — the native workhorse of the CIFAR-simulation sweeps
//! (Fig. 4/6/7, Tables 1/3/4) where hundreds of runs are needed.

use super::StochasticObjective;
use crate::data::synth_class::Dataset;
use crate::tensor;
use crate::util::Pcg64;

/// Architecture: in_dim -> hidden[0] -> ... -> classes, ReLU activations.
#[derive(Clone, Debug)]
pub struct MlpConfig {
    pub in_dim: usize,
    pub hidden: Vec<usize>,
    pub classes: usize,
}

impl MlpConfig {
    /// (in, out) per layer.
    pub fn layer_dims(&self) -> Vec<(usize, usize)> {
        let mut dims = Vec::new();
        let mut prev = self.in_dim;
        for &h in &self.hidden {
            dims.push((prev, h));
            prev = h;
        }
        dims.push((prev, self.classes));
        dims
    }

    pub fn num_params(&self) -> usize {
        self.layer_dims().iter().map(|(i, o)| i * o + o).sum()
    }
}

/// The model itself: stateless apart from the config; parameters live in a
/// caller-owned flat vector (matching the coordinator's view).
#[derive(Clone, Debug)]
pub struct Mlp {
    pub cfg: MlpConfig,
}

impl Mlp {
    pub fn new(cfg: MlpConfig) -> Self {
        Mlp { cfg }
    }

    /// He-initialized flat parameter vector.
    pub fn init_params(&self, rng: &mut Pcg64) -> Vec<f32> {
        let mut theta = vec![0.0f32; self.cfg.num_params()];
        let mut off = 0;
        for (fan_in, fan_out) in self.cfg.layer_dims() {
            let std = (2.0 / fan_in as f64).sqrt();
            rng.fill_normal(&mut theta[off..off + fan_in * fan_out], 0.0, std);
            off += fan_in * fan_out + fan_out; // biases stay zero
        }
        theta
    }

    /// Forward pass for one example; returns per-layer pre-activations and
    /// activations (needed by backprop) and the logits.
    fn forward_cache(&self, theta: &[f32], x: &[f32]) -> (Vec<Vec<f32>>, Vec<f32>) {
        let mut acts: Vec<Vec<f32>> = vec![x.to_vec()];
        let mut off = 0;
        let dims = self.cfg.layer_dims();
        for (li, (fan_in, fan_out)) in dims.iter().enumerate() {
            let w = &theta[off..off + fan_in * fan_out];
            let b = &theta[off + fan_in * fan_out..off + fan_in * fan_out + fan_out];
            off += fan_in * fan_out + fan_out;
            let input = acts.last().unwrap();
            let mut z = vec![0.0f32; *fan_out];
            for i in 0..*fan_in {
                let xi = input[i];
                if xi == 0.0 {
                    continue;
                }
                let row = &w[i * fan_out..(i + 1) * fan_out];
                tensor::axpy(xi, row, &mut z);
            }
            tensor::add_assign(&mut z, b);
            if li + 1 < dims.len() {
                for v in z.iter_mut() {
                    *v = v.max(0.0); // ReLU
                }
            }
            acts.push(z);
        }
        let logits = acts.last().unwrap().clone();
        (acts, logits)
    }

    /// Softmax cross-entropy loss of logits vs label.
    fn ce_loss(logits: &[f32], label: usize) -> (f64, Vec<f32>) {
        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f64> = logits.iter().map(|v| ((v - max) as f64).exp()).collect();
        let sum: f64 = exps.iter().sum();
        let loss = -(exps[label] / sum).ln();
        let mut dlogits: Vec<f32> = exps.iter().map(|e| (e / sum) as f32).collect();
        dlogits[label] -= 1.0;
        (loss, dlogits)
    }

    /// Mean loss + gradient over a batch of examples; returns mean loss.
    pub fn grad_batch(
        &self,
        theta: &[f32],
        xs: &[&[f32]],
        ys: &[usize],
        grad: &mut [f32],
    ) -> f64 {
        assert_eq!(xs.len(), ys.len());
        assert_eq!(grad.len(), theta.len());
        tensor::zero(grad);
        let mut total = 0.0f64;
        let dims = self.cfg.layer_dims();
        // parameter offsets per layer
        let mut offsets = Vec::with_capacity(dims.len());
        let mut off = 0;
        for (fi, fo) in &dims {
            offsets.push(off);
            off += fi * fo + fo;
        }
        let scale = 1.0 / xs.len() as f32;
        for (x, &label) in xs.iter().zip(ys) {
            let (acts, logits) = self.forward_cache(theta, x);
            let (loss, mut delta) = Self::ce_loss(&logits, label);
            total += loss;
            // backward
            for li in (0..dims.len()).rev() {
                let (fan_in, fan_out) = dims[li];
                let w_off = offsets[li];
                let input = &acts[li];
                // dW += input^T delta ; db += delta
                for i in 0..fan_in {
                    let xi = input[i];
                    if xi != 0.0 {
                        let row = &mut grad[w_off + i * fan_out..w_off + (i + 1) * fan_out];
                        tensor::axpy(scale * xi, &delta, row);
                    }
                }
                let b_off = w_off + fan_in * fan_out;
                tensor::axpy(scale, &delta, &mut grad[b_off..b_off + fan_out]);
                if li == 0 {
                    break;
                }
                // dInput = W delta, masked by ReLU'
                let w = &theta[w_off..w_off + fan_in * fan_out];
                let mut dinput = vec![0.0f32; fan_in];
                for i in 0..fan_in {
                    if input[i] > 0.0 {
                        dinput[i] =
                            tensor::dot(&w[i * fan_out..(i + 1) * fan_out], &delta) as f32;
                    }
                }
                delta = dinput;
            }
        }
        total / xs.len() as f64
    }

    /// Mean loss over a batch (no gradient).
    pub fn loss_batch(&self, theta: &[f32], xs: &[&[f32]], ys: &[usize]) -> f64 {
        let mut total = 0.0f64;
        for (x, &label) in xs.iter().zip(ys) {
            let (_, logits) = self.forward_cache(theta, x);
            total += Self::ce_loss(&logits, label).0;
        }
        total / xs.len() as f64
    }

    /// Classification accuracy over a dataset.
    pub fn accuracy(&self, theta: &[f32], data: &Dataset) -> f64 {
        let mut correct = 0usize;
        for i in 0..data.len() {
            let (_, logits) = self.forward_cache(theta, data.x.row(i));
            // diverged runs produce NaN logits; count those as wrong
            let pred = crate::util::stats::argmax(
                &logits.iter().map(|v| *v as f64).collect::<Vec<_>>(),
            )
            .unwrap_or(usize::MAX);
            if pred == data.y[i] {
                correct += 1;
            }
        }
        correct as f64 / data.len() as f64
    }

    /// Mean loss over a dataset.
    pub fn dataset_loss(&self, theta: &[f32], data: &Dataset) -> f64 {
        let xs: Vec<&[f32]> = (0..data.len()).map(|i| data.x.row(i)).collect();
        self.loss_batch(theta, &xs, &data.y)
    }
}

/// Minibatch objective over a dataset (the GradSource for the CIFAR sims).
pub struct MlpObjective {
    pub mlp: Mlp,
    pub data: Dataset,
    pub batch_size: usize,
}

impl MlpObjective {
    pub fn new(mlp: Mlp, data: Dataset, batch_size: usize) -> Self {
        assert!(batch_size >= 1);
        MlpObjective {
            mlp,
            data,
            batch_size,
        }
    }
}

impl StochasticObjective for MlpObjective {
    fn dim(&self) -> usize {
        self.mlp.cfg.num_params()
    }

    fn loss(&self, theta: &[f32]) -> f64 {
        self.mlp.dataset_loss(theta, &self.data)
    }

    fn stoch_grad(&self, theta: &[f32], rng: &mut Pcg64, out: &mut [f32]) -> f64 {
        let b = self.batch_size.min(self.data.len());
        let idxs = rng.sample_indices(self.data.len(), b);
        let xs: Vec<&[f32]> = idxs.iter().map(|&i| self.data.x.row(i)).collect();
        let ys: Vec<usize> = idxs.iter().map(|&i| self.data.y[i]).collect();
        self.mlp.grad_batch(theta, &xs, &ys, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_class::Dataset;
    use crate::tensor::Matrix;

    fn tiny_mlp() -> Mlp {
        Mlp::new(MlpConfig {
            in_dim: 4,
            hidden: vec![8],
            classes: 3,
        })
    }

    #[test]
    fn param_count() {
        let m = tiny_mlp();
        assert_eq!(m.cfg.num_params(), 4 * 8 + 8 + 8 * 3 + 3);
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let m = tiny_mlp();
        let mut rng = Pcg64::seeded(0);
        let theta = m.init_params(&mut rng);
        let x: Vec<f32> = (0..4).map(|i| 0.3 * (i as f32 + 1.0)).collect();
        let xs = [x.as_slice()];
        let ys = [1usize];
        let mut grad = vec![0.0f32; theta.len()];
        m.grad_batch(&theta, &xs, &ys, &mut grad);
        let eps = 1e-3f32;
        let mut checked = 0;
        for i in (0..theta.len()).step_by(7) {
            let mut tp = theta.clone();
            tp[i] += eps;
            let mut tm = theta.clone();
            tm[i] -= eps;
            let fd =
                (m.loss_batch(&tp, &xs, &ys) - m.loss_batch(&tm, &xs, &ys)) / (2.0 * eps as f64);
            assert!(
                (fd - grad[i] as f64).abs() < 1e-3 + 0.05 * fd.abs(),
                "coord {i}: fd {fd} vs ad {}",
                grad[i]
            );
            checked += 1;
        }
        assert!(checked > 5);
    }

    #[test]
    fn batch_grad_is_mean_of_singles() {
        let m = tiny_mlp();
        let mut rng = Pcg64::seeded(1);
        let theta = m.init_params(&mut rng);
        let x1: Vec<f32> = vec![1.0, -0.5, 0.2, 0.0];
        let x2: Vec<f32> = vec![-1.0, 0.5, 0.4, 1.0];
        let mut g1 = vec![0.0f32; theta.len()];
        let mut g2 = vec![0.0f32; theta.len()];
        let mut gb = vec![0.0f32; theta.len()];
        m.grad_batch(&theta, &[&x1], &[0], &mut g1);
        m.grad_batch(&theta, &[&x2], &[2], &mut g2);
        m.grad_batch(&theta, &[&x1, &x2], &[0, 2], &mut gb);
        for i in 0..theta.len() {
            let mean = 0.5 * (g1[i] + g2[i]);
            assert!((gb[i] - mean).abs() < 1e-5);
        }
    }

    #[test]
    fn trains_on_separable_data() {
        // Linearly separable 2-class problem: accuracy should reach ~100%.
        let mut rng = Pcg64::seeded(2);
        let n = 60;
        let mut rows = Vec::new();
        let mut labels = Vec::new();
        for i in 0..n {
            let label = i % 2;
            let center = if label == 0 { -2.0f32 } else { 2.0 };
            rows.push(vec![
                center + rng.normal() as f32 * 0.3,
                center + rng.normal() as f32 * 0.3,
            ]);
            labels.push(label);
        }
        let data = Dataset::new(Matrix::from_rows(rows), labels, 2);
        let mlp = Mlp::new(MlpConfig {
            in_dim: 2,
            hidden: vec![8],
            classes: 2,
        });
        let mut theta = mlp.init_params(&mut rng);
        let obj = MlpObjective::new(mlp.clone(), data.clone(), 16);
        let mut g = vec![0.0f32; theta.len()];
        for _ in 0..300 {
            obj.stoch_grad(&theta, &mut rng, &mut g);
            tensor::axpy(-0.1, &g, &mut theta);
        }
        assert!(mlp.accuracy(&theta, &data) > 0.95);
        assert!(mlp.dataset_loss(&theta, &data) < 0.2);
    }
}
