//! Over-parameterized least squares, §5.1: f(x) = ‖Ax − y‖² with
//! A ∈ R^{n×d}, d > n. Used by the Fig. 3 generalization simulation: we
//! track train loss, test loss, and the distance of the iterate to the
//! span of the observed gradients.

use super::StochasticObjective;
use crate::tensor::Matrix;
use crate::util::Pcg64;

pub struct LeastSquares {
    pub a: Matrix,
    pub y: Vec<f32>,
}

impl LeastSquares {
    pub fn new(a: Matrix, y: Vec<f32>) -> Self {
        assert_eq!(a.rows, y.len());
        LeastSquares { a, y }
    }

    pub fn n(&self) -> usize {
        self.a.rows
    }

    /// Residual r = Ax − y.
    pub fn residual(&self, x: &[f32]) -> Vec<f32> {
        let mut r = self.a.matvec(x);
        for (ri, yi) in r.iter_mut().zip(&self.y) {
            *ri -= yi;
        }
        r
    }

    /// Loss on another (test) dataset.
    pub fn loss_on(a: &Matrix, y: &[f32], x: &[f32]) -> f64 {
        let pred = a.matvec(x);
        pred.iter()
            .zip(y)
            .map(|(p, t)| ((p - t) as f64).powi(2))
            .sum::<f64>()
            / y.len() as f64
    }

    /// The max-margin (minimum-norm) interpolating solution (Lemma 9).
    pub fn min_norm_solution(&self) -> Vec<f32> {
        crate::linalg::min_norm_solution(&self.a, &self.y, 1e-6).expect("gram solve")
    }
}

impl StochasticObjective for LeastSquares {
    fn dim(&self) -> usize {
        self.a.cols
    }

    /// Mean squared residual (normalizing makes losses comparable across n).
    fn loss(&self, x: &[f32]) -> f64 {
        let r = self.residual(x);
        crate::tensor::norm2_sq(&r) / self.n() as f64
    }

    /// Single-row stochastic gradient: n · 2·rᵢ·aᵢ / n = 2·rᵢ·aᵢ for the
    /// mean-normalized loss (unbiased).
    fn stoch_grad(&self, x: &[f32], rng: &mut Pcg64, out: &mut [f32]) -> f64 {
        let i = rng.below(self.n());
        let ri = crate::tensor::dot(self.a.row(i), x) as f32 - self.y[i];
        for (o, aij) in out.iter_mut().zip(self.a.row(i)) {
            *o = 2.0 * ri * aij;
        }
        self.loss(x)
    }

    /// Full-batch gradient: (2/n) Aᵀ(Ax − y).
    fn full_grad(&self, x: &[f32], out: &mut [f32]) {
        let r = self.residual(x);
        let g = self.a.matvec_t(&r);
        let scale = 2.0 / self.n() as f32;
        for (o, gi) in out.iter_mut().zip(&g) {
            *o = scale * gi;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor;

    fn small_problem() -> LeastSquares {
        let mut rng = Pcg64::seeded(0);
        let a = Matrix::randn(5, 20, 1.0, &mut rng);
        let y: Vec<f32> = (0..5).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        LeastSquares::new(a, y)
    }

    #[test]
    fn zero_loss_at_min_norm_solution() {
        let p = small_problem();
        let x = p.min_norm_solution();
        assert!(p.loss(&x) < 1e-6);
    }

    #[test]
    fn full_grad_matches_stochastic_mean() {
        let p = small_problem();
        let mut rng = Pcg64::seeded(1);
        let mut x = vec![0.0f32; p.dim()];
        rng.fill_normal(&mut x, 0.0, 0.5);
        let mut fg = vec![0.0f32; p.dim()];
        p.full_grad(&x, &mut fg);
        let mut acc = vec![0.0f64; p.dim()];
        let n = 50_000;
        let mut g = vec![0.0f32; p.dim()];
        for _ in 0..n {
            p.stoch_grad(&x, &mut rng, &mut g);
            for (a, gi) in acc.iter_mut().zip(&g) {
                // stochastic grad is 2 r_i a_i = per-example grad of the
                // SUM loss; the mean-loss full grad is its mean... the
                // stochastic estimate targets (2/n)sum = full_grad * ...
                *a += *gi as f64 / n as f64;
            }
        }
        // E[stoch] = (1/n) sum_i 2 r_i a_i = full_grad of mean loss * 1
        for (a, f) in acc.iter().zip(&fg) {
            assert!((a - *f as f64).abs() < 0.05, "{a} vs {f}");
        }
    }

    #[test]
    fn gradient_descent_interpolates() {
        let p = small_problem();
        let mut x = vec![0.0f32; p.dim()];
        let mut g = vec![0.0f32; p.dim()];
        for _ in 0..2000 {
            p.full_grad(&x, &mut g);
            tensor::axpy(-0.05, &g, &mut x);
        }
        assert!(p.loss(&x) < 1e-8, "loss={}", p.loss(&x));
        // GD from 0 converges to the min-norm solution (Lemma 9)
        let mn = p.min_norm_solution();
        assert!(tensor::rel_l2(&x, &mn) < 1e-2);
    }
}
