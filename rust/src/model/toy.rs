//! The paper's pedagogic problems, §3 and Appendix A.1.
//!
//! * [`Ce1Linear`] — Counterexample 1: f(x) = x/4 on [−1,1] with the
//!   bimodal stochastic gradient g ∈ {4 w.p. ¼, −1 w.p. ¾} (E[g] = ¼).
//! * [`Ce2NonSmooth`] — Counterexample 2 / Fig. 1: f(x) = ε|x₁+x₂| +
//!   |x₁−x₂| with subgradient oracle; SIGNSGD is trapped on x₁+x₂ = const.
//! * [`Ce3LeastSquares`] — Counterexample 3: the smooth 2-D least-squares
//!   version with stochastic row sampling.
//! * [`SharedSignTheorem1`] — Theorem I's construction for general d:
//!   rows aᵢ = ±s ⊙ |rᵢ| share the sign pattern s.
//! * [`SparseNoiseQuadratic`] — Appendix A.1 / Fig. 5: f(x) = ½‖x‖² with
//!   N(0, 100²) noise on the first coordinate only.

use super::StochasticObjective;
use crate::util::Pcg64;

// ---------------------------------------------------------------- CE 1

/// Counterexample 1: minimize f(x) = x/4 over [−1, 1].
pub struct Ce1Linear;

impl Ce1Linear {
    /// Projection onto the feasible box.
    pub fn project(x: &mut [f32]) {
        x[0] = x[0].clamp(-1.0, 1.0);
    }

    pub const OPT: f64 = -0.25; // f(-1)
}

impl StochasticObjective for Ce1Linear {
    fn dim(&self) -> usize {
        1
    }

    fn loss(&self, x: &[f32]) -> f64 {
        0.25 * x[0] as f64
    }

    fn stoch_grad(&self, _x: &[f32], rng: &mut Pcg64, out: &mut [f32]) -> f64 {
        // g = 4 w.p. 1/4, −1 w.p. 3/4; E[g] = 1/4 = f'(x).
        out[0] = if rng.uniform() < 0.25 { 4.0 } else { -1.0 };
        f64::NAN
    }

    fn full_grad(&self, _x: &[f32], out: &mut [f32]) {
        out[0] = 0.25;
    }
}

// ---------------------------------------------------------------- CE 2

/// Counterexample 2: f(x) = ε|x₁+x₂| + |x₁−x₂| (non-smooth, convex,
/// minimum at the origin). The full subgradient is available.
pub struct Ce2NonSmooth {
    pub eps: f32,
}

impl Ce2NonSmooth {
    pub fn new(eps: f32) -> Self {
        assert!(eps > 0.0 && eps < 1.0);
        Ce2NonSmooth { eps }
    }

    /// The subgradient of §3: sign(x₁+x₂)·ε·(1,1) + sign(x₁−x₂)·(1,−1).
    /// At ties (x₁ = x₂) we select the subgradient with sign = +1 — a valid
    /// element of the subdifferential, and the selection under which the
    /// paper's claim "sign(g) = ±(1,−1) whenever x₁+x₂ > 0" holds at every
    /// point (so the SIGNSGD trap is exact, not just almost-sure).
    pub fn subgrad(&self, x: &[f32], out: &mut [f32]) {
        let s = (x[0] + x[1]).signum_or_zero();
        let t = if x[0] >= x[1] { 1.0 } else { -1.0 };
        out[0] = self.eps * s + t;
        out[1] = self.eps * s - t;
    }
}

trait SignumOrZero {
    fn signum_or_zero(self) -> f32;
}

impl SignumOrZero for f32 {
    fn signum_or_zero(self) -> f32 {
        if self > 0.0 {
            1.0
        } else if self < 0.0 {
            -1.0
        } else {
            0.0
        }
    }
}

impl StochasticObjective for Ce2NonSmooth {
    fn dim(&self) -> usize {
        2
    }

    fn loss(&self, x: &[f32]) -> f64 {
        (self.eps * (x[0] + x[1]).abs() + (x[0] - x[1]).abs()) as f64
    }

    fn stoch_grad(&self, x: &[f32], _rng: &mut Pcg64, out: &mut [f32]) -> f64 {
        self.subgrad(x, out);
        self.loss(x)
    }

    fn full_grad(&self, x: &[f32], out: &mut [f32]) {
        self.subgrad(x, out);
    }
}

// ---------------------------------------------------------------- CE 3

/// Counterexample 3: f(x) = ⟨a₁,x⟩² + ⟨a₂,x⟩² with
/// a₁,₂ = ±(1,−1) + ε(1,1); stochastic gradient picks one row.
pub struct Ce3LeastSquares {
    pub a1: [f32; 2],
    pub a2: [f32; 2],
}

impl Ce3LeastSquares {
    pub fn new(eps: f32) -> Self {
        assert!(eps > 0.0 && eps < 1.0);
        Ce3LeastSquares {
            a1: [1.0 + eps, -1.0 + eps],
            a2: [-1.0 + eps, 1.0 + eps],
        }
    }
}

impl StochasticObjective for Ce3LeastSquares {
    fn dim(&self) -> usize {
        2
    }

    fn loss(&self, x: &[f32]) -> f64 {
        let d1 = (self.a1[0] * x[0] + self.a1[1] * x[1]) as f64;
        let d2 = (self.a2[0] * x[0] + self.a2[1] * x[1]) as f64;
        d1 * d1 + d2 * d2
    }

    fn stoch_grad(&self, x: &[f32], rng: &mut Pcg64, out: &mut [f32]) -> f64 {
        // With prob 1/2, grad of 2*<a_i,x>^2: note the paper's f has no 1/2
        // factor, and each term is sampled w.p. 1/2, so the unbiased
        // stochastic gradient is 2 * 2 <a_i, x> a_i * (1/2 normalization
        // folded in): g = 4<a_i,x> a_i would be E-correct for sum sampling
        // with p=1/2 each — we sample i and return the gradient of
        // 2*(<a_i,x>)^2 so E[g] = grad f.
        let a = if rng.bernoulli(0.5) { &self.a1 } else { &self.a2 };
        let inner = a[0] * x[0] + a[1] * x[1];
        out[0] = 4.0 * inner * a[0];
        out[1] = 4.0 * inner * a[1];
        self.loss(x)
    }

    fn full_grad(&self, x: &[f32], out: &mut [f32]) {
        let i1 = self.a1[0] * x[0] + self.a1[1] * x[1];
        let i2 = self.a2[0] * x[0] + self.a2[1] * x[1];
        out[0] = 2.0 * (i1 * self.a1[0] + i2 * self.a2[0]);
        out[1] = 2.0 * (i1 * self.a1[1] + i2 * self.a2[1]);
    }
}

// ------------------------------------------------------------ Theorem I

/// Theorem I's family: f(x) = Σᵢ ⟨aᵢ,x⟩² where sign(aᵢ) = ±s for a shared
/// pattern s ∈ {−1,1}^d. SIGNSGD's iterates can only move along ±s, so it
/// almost surely never reaches the optimum from a random start.
pub struct SharedSignTheorem1 {
    pub rows: Vec<Vec<f32>>,
    d: usize,
}

impl SharedSignTheorem1 {
    /// Build n rows over dimension d with shared sign pattern.
    pub fn new(n: usize, d: usize, rng: &mut Pcg64) -> Self {
        assert!(d >= 2 && n >= d, "need n >= d for a unique optimum");
        let s: Vec<f32> = (0..d).map(|_| rng.sign() as f32).collect();
        let rows = (0..n)
            .map(|_| {
                let flip = rng.sign() as f32;
                (0..d)
                    .map(|j| flip * s[j] * (0.2 + rng.uniform() as f32))
                    .collect()
            })
            .collect();
        SharedSignTheorem1 { rows, d }
    }
}

impl StochasticObjective for SharedSignTheorem1 {
    fn dim(&self) -> usize {
        self.d
    }

    fn loss(&self, x: &[f32]) -> f64 {
        self.rows
            .iter()
            .map(|a| {
                let inner: f64 = a.iter().zip(x).map(|(ai, xi)| (*ai * *xi) as f64).sum();
                inner * inner
            })
            .sum()
    }

    fn stoch_grad(&self, x: &[f32], rng: &mut Pcg64, out: &mut [f32]) -> f64 {
        let n = self.rows.len();
        let a = &self.rows[rng.below(n)];
        // detlint: allow(D3) — worker-local dot product in the row's fixed
        // iteration order; not a cross-worker reduction
        let inner: f32 = a.iter().zip(x).map(|(ai, xi)| ai * xi).sum();
        // grad of n * <a_i, x>^2 (importance-weighted so E[g] = grad f)
        for (o, ai) in out.iter_mut().zip(a) {
            *o = 2.0 * n as f32 * inner * ai;
        }
        self.loss(x)
    }
}

// -------------------------------------------------- sparse-noise toy

/// Appendix A.1 / Fig. 5: f(x) = ½‖x‖², ∇f = x, stochastic gradient adds
/// N(0, noise_std²) to the FIRST coordinate only.
pub struct SparseNoiseQuadratic {
    pub d: usize,
    pub noise_std: f64,
}

impl SparseNoiseQuadratic {
    pub fn new(d: usize, noise_std: f64) -> Self {
        SparseNoiseQuadratic { d, noise_std }
    }
}

impl StochasticObjective for SparseNoiseQuadratic {
    fn dim(&self) -> usize {
        self.d
    }

    fn loss(&self, x: &[f32]) -> f64 {
        0.5 * crate::tensor::norm2_sq(x)
    }

    fn stoch_grad(&self, x: &[f32], rng: &mut Pcg64, out: &mut [f32]) -> f64 {
        out.copy_from_slice(x);
        out[0] += rng.normal_ms(0.0, self.noise_std) as f32;
        self.loss(x)
    }

    fn full_grad(&self, x: &[f32], out: &mut [f32]) {
        out.copy_from_slice(x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ce1_gradient_is_unbiased() {
        let mut rng = Pcg64::seeded(0);
        let mut g = [0.0f32];
        let n = 200_000;
        let mean: f64 = (0..n)
            .map(|_| {
                Ce1Linear.stoch_grad(&[0.0], &mut rng, &mut g);
                g[0] as f64
            })
            .sum::<f64>()
            / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn ce1_sign_of_gradient_is_usually_negative() {
        // E[sign(g)] = 1/4 - 3/4 = -1/2: signSGD moves x UP toward +1,
        // increasing f — the crux of the counterexample.
        let mut rng = Pcg64::seeded(1);
        let mut g = [0.0f32];
        let n = 100_000;
        let mean_sign: f64 = (0..n)
            .map(|_| {
                Ce1Linear.stoch_grad(&[0.0], &mut rng, &mut g);
                g[0].signum() as f64
            })
            .sum::<f64>()
            / n as f64;
        assert!((mean_sign + 0.5).abs() < 0.01, "{mean_sign}");
    }

    #[test]
    fn ce2_subgradient_matches_paper() {
        let p = Ce2NonSmooth::new(0.5);
        let mut g = [0.0f32; 2];
        p.subgrad(&[1.0, 1.0], &mut g); // tie: subgradient choice t=+1
        assert_eq!(g, [1.5, -0.5]);
        p.subgrad(&[2.0, 0.0], &mut g); // both positive
        assert_eq!(g, [1.5, -0.5]);
        assert!((p.loss(&[0.0, 0.0])).abs() < 1e-12);
        assert!(p.loss(&[1.0, 1.0]) > 0.0);
    }

    #[test]
    fn ce2_sign_trap() {
        // For x with x1+x2 > 0 and x1 != x2, sign(g) = ±(1,-1): the signSGD
        // update never changes x1+x2.
        let p = Ce2NonSmooth::new(0.5);
        let mut g = [0.0f32; 2];
        for x in [[2.0f32, 0.0], [0.0, 2.0], [1.5, 0.5], [1.0, 1.0]] {
            p.subgrad(&x, &mut g);
            let s = [g[0].signum(), g[1].signum()];
            assert_eq!(s[0] + s[1], 0.0, "sign pattern must be (±1, ∓1)");
        }
    }

    #[test]
    fn ce3_full_grad_consistent_with_stochastic_mean() {
        let p = Ce3LeastSquares::new(0.3);
        let x = [0.7f32, -0.2];
        let mut fg = [0.0f32; 2];
        p.full_grad(&x, &mut fg);
        let mut rng = Pcg64::seeded(2);
        let mut acc = [0.0f64; 2];
        let n = 100_000;
        let mut g = [0.0f32; 2];
        for _ in 0..n {
            p.stoch_grad(&x, &mut rng, &mut g);
            acc[0] += g[0] as f64 / n as f64;
            acc[1] += g[1] as f64 / n as f64;
        }
        assert!((acc[0] - fg[0] as f64).abs() < 0.05, "{acc:?} vs {fg:?}");
        assert!((acc[1] - fg[1] as f64).abs() < 0.05);
    }

    #[test]
    fn thm1_rows_share_sign_pattern() {
        let mut rng = Pcg64::seeded(3);
        let p = SharedSignTheorem1::new(8, 4, &mut rng);
        let s0: Vec<f32> = p.rows[0].iter().map(|v| v.signum()).collect();
        for row in &p.rows {
            let s: Vec<f32> = row.iter().map(|v| v.signum()).collect();
            let same = s.iter().zip(&s0).all(|(a, b)| a == b);
            let flipped = s.iter().zip(&s0).all(|(a, b)| *a == -*b);
            assert!(same || flipped);
        }
    }

    #[test]
    fn thm1_unique_optimum_at_zero() {
        let mut rng = Pcg64::seeded(4);
        let p = SharedSignTheorem1::new(10, 3, &mut rng);
        assert!(p.loss(&[0.0, 0.0, 0.0]) < 1e-12);
        assert!(p.loss(&[0.1, 0.0, 0.0]) > 0.0);
    }

    #[test]
    fn sparse_noise_only_first_coordinate() {
        let p = SparseNoiseQuadratic::new(10, 100.0);
        let x = vec![1.0f32; 10];
        let mut rng = Pcg64::seeded(5);
        let mut g = vec![0.0f32; 10];
        p.stoch_grad(&x, &mut rng, &mut g);
        for v in &g[1..] {
            assert_eq!(*v, 1.0);
        }
        assert!((g[0] - 1.0).abs() > 1.0); // noise almost surely large
    }
}
