//! Exact traffic accounting: bits per (src → dst) link, per message kind,
//! plus a simulated clock per node integrating link transfer times.

use super::message::MessageKind;
use std::collections::BTreeMap;

/// Aggregated traffic statistics for one fabric.
#[derive(Clone, Debug, Default)]
pub struct TrafficStats {
    /// Total bits per (src, dst) pair.
    pub per_link: BTreeMap<(usize, usize), u64>,
    /// Total bits per message kind.
    pub per_kind: BTreeMap<MessageKind, u64>,
    /// Message count per kind.
    pub msg_count: BTreeMap<MessageKind, u64>,
    /// Total bits per parameter-server shard (messages whose payload
    /// carries a shard id: sharded grad pushes and parameter slices).
    /// Empty for unsharded runs.
    pub per_shard: BTreeMap<u32, u64>,
    /// Simulated busy-time per node (seconds of link occupancy).
    pub node_time_s: BTreeMap<usize, f64>,
    /// Total simulated transfer time per message kind (seconds).
    pub sim_time_per_kind: BTreeMap<MessageKind, f64>,
    /// Latest simulated arrival timestamp seen per message kind (seconds
    /// on the fabric's virtual clock; equals the transfer time when no
    /// clock is attached).
    pub last_arrival_per_kind: BTreeMap<MessageKind, f64>,
    /// Total bits over all links.
    pub total_bits: u64,
    /// Total simulated communication time if all transfers were serial.
    pub serial_time_s: f64,
    /// Frames the leader could not decode (truncated/garbage payloads,
    /// mis-routed shard tags) and excluded from aggregation instead of
    /// aborting on. Nonzero only under adversarial or corrupted traffic.
    pub dropped_frames: u64,
    /// Frames discarded because their sender departed the membership and
    /// the epoch they were dispatched in has closed (elastic churn; see
    /// `docs/ASYNC.md`). Nonzero only for runs with an active
    /// `MembershipSchedule`.
    pub departed_frames: u64,
}

impl TrafficStats {
    #[allow(clippy::too_many_arguments)]
    pub fn record(
        &mut self,
        src: usize,
        dst: usize,
        kind: MessageKind,
        shard: Option<u32>,
        bits: u64,
        time_s: f64,
        arrival_s: f64,
    ) {
        *self.per_link.entry((src, dst)).or_default() += bits;
        *self.per_kind.entry(kind).or_default() += bits;
        *self.msg_count.entry(kind).or_default() += 1;
        if let Some(s) = shard {
            *self.per_shard.entry(s).or_default() += bits;
        }
        *self.node_time_s.entry(src).or_default() += time_s;
        *self.node_time_s.entry(dst).or_default() += time_s;
        *self.sim_time_per_kind.entry(kind).or_default() += time_s;
        let last = self.last_arrival_per_kind.entry(kind).or_default();
        if arrival_s > *last {
            *last = arrival_s;
        }
        self.total_bits += bits;
        self.serial_time_s += time_s;
    }

    /// Bits sent from a node (upload).
    pub fn sent_by(&self, node: usize) -> u64 {
        self.per_link
            .iter()
            .filter(|((s, _), _)| *s == node)
            .map(|(_, b)| *b)
            .sum()
    }

    /// Bits received by a node (download).
    pub fn received_by(&self, node: usize) -> u64 {
        self.per_link
            .iter()
            .filter(|((_, d), _)| *d == node)
            .map(|(_, b)| *b)
            .sum()
    }

    pub fn bits_of_kind(&self, kind: MessageKind) -> u64 {
        self.per_kind.get(&kind).copied().unwrap_or(0)
    }

    /// Total bits attributed to one parameter-server shard (0 if the run
    /// was unsharded or the shard saw no traffic).
    pub fn bits_of_shard(&self, shard: u32) -> u64 {
        self.per_shard.get(&shard).copied().unwrap_or(0)
    }

    /// Number of messages of `kind` seen so far.
    pub fn count_of_kind(&self, kind: MessageKind) -> u64 {
        self.msg_count.get(&kind).copied().unwrap_or(0)
    }

    /// Total simulated transfer time spent on messages of `kind` — the
    /// virtual seconds the link model charged them, integrated. The comm
    /// experiment asserts its reported per-round time against this total,
    /// so wire-time accounting can never silently drift from the link
    /// model.
    pub fn sim_time_of_kind(&self, kind: MessageKind) -> f64 {
        self.sim_time_per_kind.get(&kind).copied().unwrap_or(0.0)
    }

    /// Latest simulated arrival timestamp among messages of `kind`.
    pub fn last_arrival_of_kind(&self, kind: MessageKind) -> f64 {
        self.last_arrival_per_kind.get(&kind).copied().unwrap_or(0.0)
    }

    /// Mean on-wire bits per message of `kind` (0 if none were sent) —
    /// with variable-length codecs (QSGD's Elias pack) the per-frame cost
    /// is data-dependent, so benchmarks report this measured mean rather
    /// than an analytic constant.
    pub fn mean_msg_bits(&self, kind: MessageKind) -> f64 {
        let n = self.count_of_kind(kind);
        if n == 0 {
            0.0
        } else {
            self.bits_of_kind(kind) as f64 / n as f64
        }
    }

    /// Max simulated busy-time over nodes — a lower bound on the wall-clock
    /// communication time of the round set.
    pub fn critical_path_s(&self) -> f64 {
        self.node_time_s.values().cloned().fold(0.0, f64::max)
    }

    /// Count one undecodable (dropped) frame.
    pub fn record_dropped(&mut self) {
        self.dropped_frames += 1;
    }

    /// Frames dropped as undecodable so far.
    pub fn dropped(&self) -> u64 {
        self.dropped_frames
    }

    /// Count one frame discarded because its sender departed.
    pub fn record_departed(&mut self) {
        self.departed_frames += 1;
    }

    /// Frames discarded from departed workers so far.
    pub fn departed(&self) -> u64 {
        self.departed_frames
    }

    pub fn summary(&self) -> String {
        let mut out = format!(
            "total {:.3} Mbit over {} links; critical path {:.3} ms\n",
            self.total_bits as f64 / 1e6,
            self.per_link.len(),
            self.critical_path_s() * 1e3
        );
        for (kind, bits) in &self.per_kind {
            out.push_str(&format!(
                "  {:<16} {:>12.3} Mbit in {:>6} msgs\n",
                kind.name(),
                *bits as f64 / 1e6,
                self.msg_count.get(kind).unwrap_or(&0)
            ));
        }
        if self.dropped_frames > 0 {
            out.push_str(&format!(
                "  {} frames dropped as undecodable\n",
                self.dropped_frames
            ));
        }
        if self.departed_frames > 0 {
            out.push_str(&format!(
                "  {} frames dropped from departed workers\n",
                self.departed_frames
            ));
        }
        out
    }

    pub fn reset(&mut self) {
        *self = TrafficStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_aggregates() {
        let mut t = TrafficStats::default();
        t.record(0, 1, MessageKind::GradPush, None, 1000, 0.5, 0.5);
        t.record(1, 0, MessageKind::ParamBroadcast, None, 2000, 0.25, 0.25);
        t.record(0, 2, MessageKind::GradPush, None, 500, 0.1, 0.6);
        assert_eq!(t.total_bits, 3500);
        assert_eq!(t.sent_by(0), 1500);
        assert_eq!(t.received_by(0), 2000);
        assert_eq!(t.bits_of_kind(MessageKind::GradPush), 1500);
        assert_eq!(t.msg_count[&MessageKind::GradPush], 2);
        assert_eq!(t.count_of_kind(MessageKind::GradPush), 2);
        assert!((t.mean_msg_bits(MessageKind::GradPush) - 750.0).abs() < 1e-12);
        assert_eq!(t.mean_msg_bits(MessageKind::Control), 0.0);
        assert!((t.critical_path_s() - 0.85).abs() < 1e-12);
        assert!(t.summary().contains("grad_push"));
    }

    #[test]
    fn sim_time_and_arrival_per_kind() {
        let mut t = TrafficStats::default();
        t.record(0, 2, MessageKind::GradPush, None, 100, 0.5, 1.5);
        t.record(1, 2, MessageKind::GradPush, None, 100, 0.25, 0.75);
        t.record(2, 0, MessageKind::ParamBroadcast, None, 400, 0.1, 2.0);
        assert!((t.sim_time_of_kind(MessageKind::GradPush) - 0.75).abs() < 1e-12);
        assert!((t.sim_time_of_kind(MessageKind::ParamBroadcast) - 0.1).abs() < 1e-12);
        assert_eq!(t.sim_time_of_kind(MessageKind::Control), 0.0);
        // latest arrival per kind is a max, not a sum
        assert!((t.last_arrival_of_kind(MessageKind::GradPush) - 1.5).abs() < 1e-12);
        assert!((t.last_arrival_of_kind(MessageKind::ParamBroadcast) - 2.0).abs() < 1e-12);
        assert_eq!(t.last_arrival_of_kind(MessageKind::Control), 0.0);
        // per-kind sim times partition the serial total
        let split = t.sim_time_of_kind(MessageKind::GradPush)
            + t.sim_time_of_kind(MessageKind::ParamBroadcast);
        assert!((split - t.serial_time_s).abs() < 1e-12);
    }

    #[test]
    fn reset_clears() {
        let mut t = TrafficStats::default();
        t.record(0, 1, MessageKind::Control, None, 10, 0.1, 0.1);
        t.record_dropped();
        assert_eq!(t.dropped(), 1);
        assert!(t.summary().contains("dropped as undecodable"));
        t.record_departed();
        assert_eq!(t.departed(), 1);
        assert!(t.summary().contains("departed workers"));
        t.reset();
        assert_eq!(t.total_bits, 0);
        assert!(t.per_link.is_empty());
        assert!(t.sim_time_per_kind.is_empty());
        assert!(t.per_shard.is_empty());
        assert_eq!(t.dropped(), 0);
        assert_eq!(t.departed(), 0);
        assert!(!t.summary().contains("dropped"));
    }

    #[test]
    fn per_shard_bits_partition_tagged_traffic() {
        let mut t = TrafficStats::default();
        t.record(0, 4, MessageKind::GradPush, Some(0), 100, 0.1, 0.1);
        t.record(0, 5, MessageKind::GradPush, Some(1), 150, 0.1, 0.1);
        t.record(1, 4, MessageKind::GradPush, Some(0), 100, 0.1, 0.1);
        t.record(4, 0, MessageKind::ParamBroadcast, Some(0), 400, 0.1, 0.1);
        t.record(2, 3, MessageKind::Control, None, 8, 0.1, 0.1);
        assert_eq!(t.bits_of_shard(0), 600);
        assert_eq!(t.bits_of_shard(1), 150);
        assert_eq!(t.bits_of_shard(7), 0);
        // tagged traffic partitions exactly; untagged stays out
        let tagged: u64 = t.per_shard.values().sum();
        assert_eq!(tagged, t.total_bits - 8);
    }
}
