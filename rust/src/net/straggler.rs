//! Straggler models: how long a worker's compute step takes in virtual
//! time.
//!
//! The async engine's whole point is measuring EF-SGD's robustness to
//! *when* frames arrive, so compute time is a first-class model, not a
//! constant. Four scenarios cover the systems literature:
//!
//! * `constant` — every step costs the base time (the homogeneous cluster).
//! * `uniform:J` — base · (1 + U[0, J]) jitter (OS noise, co-tenancy).
//! * `lognormal:σ` — base · exp(σ·N(0,1)), the heavy-tail regime reported
//!   for large clusters; σ is the severity knob of the staleness sweep.
//! * `failslow:K:F` — node K runs F× slower than everyone (the classic
//!   fail-slow fault: a degraded disk/NIC on one host).
//!
//! Sampling is a pure function of `(seed, worker, step)`: every cell gets
//! its own [`Pcg64`] stream, so the drawn times do not depend on the order
//! in which the engine asks for them — a prerequisite for the async
//! engine's bit-determinism across `--threads` values.

use crate::util::Pcg64;
use std::fmt;

/// The accepted straggler spec grammar, quoted by parse errors and the
/// CLI.
pub const STRAGGLER_GRAMMAR: &str =
    "constant | none | uniform[:JITTER] | lognormal[:SIGMA] | failslow:NODE[:FACTOR]";

/// A malformed straggler spec: the offending token plus what went wrong.
/// `Display` includes the accepted grammar so the CLI error is
/// self-describing.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StragglerParseError {
    /// The part of the spec that failed to parse.
    pub token: String,
    /// Why it was rejected.
    pub reason: String,
}

impl fmt::Display for StragglerParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "bad straggler spec token '{}': {}; accepted grammar: {}",
            self.token, self.reason, STRAGGLER_GRAMMAR
        )
    }
}

impl std::error::Error for StragglerParseError {}

fn straggler_err(token: &str, reason: &str) -> StragglerParseError {
    StragglerParseError {
        token: token.to_string(),
        reason: reason.to_string(),
    }
}

/// The compute-time distribution (see module docs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StragglerModel {
    Constant,
    UniformJitter { jitter: f64 },
    LogNormal { sigma: f64 },
    FailSlow { node: usize, factor: f64 },
}

impl StragglerModel {
    /// Parse a CLI spec: `constant`, `uniform[:J]`, `lognormal[:SIGMA]`,
    /// `failslow:NODE[:FACTOR]`.
    pub fn parse(s: &str) -> Result<StragglerModel, StragglerParseError> {
        let mut parts = s.split(':');
        let name = parts
            .next()
            .ok_or_else(|| straggler_err(s, "empty spec"))?;
        let model = match name {
            "constant" | "none" => StragglerModel::Constant,
            "uniform" => {
                let jitter = match parts.next() {
                    Some(p) => p
                        .parse()
                        .map_err(|_| straggler_err(p, "JITTER is not a number"))?,
                    None => 0.5,
                };
                StragglerModel::UniformJitter { jitter }
            }
            "lognormal" => {
                let sigma = match parts.next() {
                    Some(p) => p
                        .parse()
                        .map_err(|_| straggler_err(p, "SIGMA is not a number"))?,
                    None => 1.0,
                };
                StragglerModel::LogNormal { sigma }
            }
            "failslow" => {
                let node_s = parts
                    .next()
                    .ok_or_else(|| straggler_err(s, "failslow requires a NODE id"))?;
                let node = node_s
                    .parse()
                    .map_err(|_| straggler_err(node_s, "NODE is not a non-negative integer"))?;
                let factor = match parts.next() {
                    Some(p) => p
                        .parse()
                        .map_err(|_| straggler_err(p, "FACTOR is not a number"))?,
                    None => 8.0,
                };
                StragglerModel::FailSlow { node, factor }
            }
            _ => return Err(straggler_err(name, "unknown straggler model")),
        };
        if let Some(extra) = parts.next() {
            return Err(straggler_err(extra, "unexpected trailing part"));
        }
        Ok(model)
    }

    pub fn name(&self) -> &'static str {
        match self {
            StragglerModel::Constant => "constant",
            StragglerModel::UniformJitter { .. } => "uniform",
            StragglerModel::LogNormal { .. } => "lognormal",
            StragglerModel::FailSlow { .. } => "failslow",
        }
    }
}

/// A seeded straggler model with a base compute time: the driver's
/// per-(worker, step) compute-time oracle.
#[derive(Clone, Debug)]
pub struct StragglerSchedule {
    /// Base compute time per step in seconds (0 = compute is free, the
    /// historical synchronous-engine behaviour).
    pub base_s: f64,
    pub model: StragglerModel,
    pub seed: u64,
}

impl StragglerSchedule {
    pub fn new(base_s: f64, model: StragglerModel, seed: u64) -> Self {
        assert!(base_s >= 0.0 && base_s.is_finite());
        StragglerSchedule {
            base_s,
            model,
            seed,
        }
    }

    /// Free compute: every step takes zero simulated time.
    pub fn none() -> Self {
        StragglerSchedule::new(0.0, StragglerModel::Constant, 0)
    }

    /// Compute time of `worker`'s `step`-th gradient step, in seconds.
    /// Deterministic in `(seed, worker, step)` — never in call order.
    pub fn compute_time(&self, worker: usize, step: u64) -> f64 {
        if self.base_s == 0.0 {
            return 0.0;
        }
        match self.model {
            StragglerModel::Constant => self.base_s,
            StragglerModel::UniformJitter { jitter } => {
                self.base_s * (1.0 + jitter * self.cell_rng(worker, step).uniform())
            }
            StragglerModel::LogNormal { sigma } => {
                self.base_s * (sigma * self.cell_rng(worker, step).normal()).exp()
            }
            StragglerModel::FailSlow { node, factor } => {
                if worker == node {
                    self.base_s * factor
                } else {
                    self.base_s
                }
            }
        }
    }

    fn cell_rng(&self, worker: usize, step: u64) -> Pcg64 {
        // one independent stream per (worker, step) cell
        let mix = (worker as u64 + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        Pcg64::new(
            self.seed ^ step.wrapping_mul(0xd1b5_4a32_d192_ed03),
            mix ^ step,
        )
    }
}

impl Default for StragglerSchedule {
    fn default() -> Self {
        StragglerSchedule::none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_specs() {
        assert_eq!(StragglerModel::parse("constant"), Ok(StragglerModel::Constant));
        assert_eq!(
            StragglerModel::parse("uniform:0.25"),
            Ok(StragglerModel::UniformJitter { jitter: 0.25 })
        );
        assert_eq!(
            StragglerModel::parse("lognormal:1.5"),
            Ok(StragglerModel::LogNormal { sigma: 1.5 })
        );
        assert_eq!(
            StragglerModel::parse("lognormal"),
            Ok(StragglerModel::LogNormal { sigma: 1.0 })
        );
        assert_eq!(
            StragglerModel::parse("failslow:2:16"),
            Ok(StragglerModel::FailSlow {
                node: 2,
                factor: 16.0
            })
        );
        assert_eq!(
            StragglerModel::parse("failslow:3"),
            Ok(StragglerModel::FailSlow {
                node: 3,
                factor: 8.0
            })
        );
        assert!(StragglerModel::parse("failslow").is_err());
        assert!(StragglerModel::parse("bogus").is_err());
        assert!(StragglerModel::parse("constant:1:2").is_err());
    }

    #[test]
    fn parse_errors_carry_token_and_grammar() {
        let err = StragglerModel::parse("lognormal:abc").unwrap_err();
        assert_eq!(err.token, "abc");
        assert!(err.to_string().contains("accepted grammar"), "{err}");
        let err = StragglerModel::parse("failslow").unwrap_err();
        assert!(err.reason.contains("NODE"), "{err}");
        let err = StragglerModel::parse("bogus").unwrap_err();
        assert_eq!(err.token, "bogus");
        let err = StragglerModel::parse("constant:1:2").unwrap_err();
        assert_eq!(err.token, "1");
    }

    #[test]
    fn deterministic_per_cell_not_per_call_order() {
        let s = StragglerSchedule::new(1e-3, StragglerModel::LogNormal { sigma: 1.0 }, 7);
        let a = s.compute_time(3, 10);
        let _ = s.compute_time(0, 0); // interleave another cell
        let b = s.compute_time(3, 10);
        assert_eq!(a, b);
        // different cells draw different times
        assert_ne!(s.compute_time(3, 10), s.compute_time(3, 11));
        assert_ne!(s.compute_time(3, 10), s.compute_time(4, 10));
    }

    #[test]
    fn constant_and_none() {
        let z = StragglerSchedule::none();
        assert_eq!(z.compute_time(0, 0), 0.0);
        let c = StragglerSchedule::new(2e-3, StragglerModel::Constant, 0);
        assert_eq!(c.compute_time(5, 9), 2e-3);
    }

    #[test]
    fn failslow_slows_one_node() {
        let s = StragglerSchedule::new(
            1e-3,
            StragglerModel::FailSlow {
                node: 1,
                factor: 10.0,
            },
            0,
        );
        assert_eq!(s.compute_time(0, 0), 1e-3);
        assert_eq!(s.compute_time(1, 0), 1e-2);
    }

    #[test]
    fn lognormal_zero_sigma_is_constant() {
        let s = StragglerSchedule::new(1e-3, StragglerModel::LogNormal { sigma: 0.0 }, 3);
        for w in 0..4 {
            assert_eq!(s.compute_time(w, 5), 1e-3);
        }
    }

    #[test]
    fn uniform_jitter_within_bounds() {
        let s = StragglerSchedule::new(1e-3, StragglerModel::UniformJitter { jitter: 0.5 }, 11);
        for w in 0..8 {
            for k in 0..8 {
                let t = s.compute_time(w, k);
                assert!((1e-3..1.5e-3).contains(&t), "t={t}");
            }
        }
    }
}
