//! Message framing for the simulated fabric.

use crate::compress::wire::Encoded;

/// What a message carries.
#[derive(Clone, Debug)]
pub enum Payload {
    /// An encoded (possibly compressed) gradient/update.
    Grad(Encoded),
    /// A dense parameter broadcast (raw f32).
    Params(Vec<f32>),
    /// Control traffic (round barriers etc.) with a nominal size.
    Control(u64),
}

impl Payload {
    /// Exact payload size in bits.
    pub fn bits(&self) -> u64 {
        match self {
            Payload::Grad(e) => e.bits,
            Payload::Params(v) => 32 * v.len() as u64,
            Payload::Control(bits) => *bits,
        }
    }
}

/// Traffic classification for the accounting breakdowns.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum MessageKind {
    GradPush,
    ParamBroadcast,
    Control,
}

impl MessageKind {
    pub fn name(&self) -> &'static str {
        match self {
            MessageKind::GradPush => "grad_push",
            MessageKind::ParamBroadcast => "param_broadcast",
            MessageKind::Control => "control",
        }
    }
}

/// A routed message. Framing overhead (headers) is a fixed 64 bytes,
/// matching a TCP/IP+Ethernet header budget.
#[derive(Clone, Debug)]
pub struct Message {
    pub src: usize,
    pub dst: usize,
    pub round: u64,
    pub kind: MessageKind,
    pub payload: Payload,
}

/// Fixed per-message framing overhead in bits.
pub const FRAME_OVERHEAD_BITS: u64 = 64 * 8;

impl Message {
    /// Total on-wire size: payload + framing.
    pub fn wire_bits(&self) -> u64 {
        self.payload.bits() + FRAME_OVERHEAD_BITS
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::wire::encode_scaled_sign;

    #[test]
    fn payload_bits() {
        assert_eq!(Payload::Params(vec![0.0; 10]).bits(), 320);
        assert_eq!(Payload::Control(100).bits(), 100);
        let e = encode_scaled_sign(&vec![1.0f32; 64]);
        assert_eq!(Payload::Grad(e).bits(), 64 + 32);
    }

    #[test]
    fn wire_bits_include_framing() {
        let m = Message {
            src: 0,
            dst: 1,
            round: 0,
            kind: MessageKind::Control,
            payload: Payload::Control(8),
        };
        assert_eq!(m.wire_bits(), 8 + FRAME_OVERHEAD_BITS);
    }
}
