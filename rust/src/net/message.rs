//! Message framing for the simulated fabric.

use crate::compress::wire::{Encoded, SHARD_TAG_BITS};
use std::sync::Arc;

/// What a message carries.
///
/// Parameter broadcasts are **`Arc`-shared**: the leader encodes its slice
/// once and every recipient's message bumps a refcount instead of cloning
/// the dense vector — broadcasting to `n` workers costs one copy of θ
/// total, not `n` (see docs/PERF.md). On-wire accounting is unchanged:
/// the simulated network still charges every message its full dense size;
/// the sharing only removes *host* memory traffic the real deployment's
/// NIC scatter wouldn't pay either.
#[derive(Clone, Debug)]
pub enum Payload {
    /// An encoded (possibly compressed) gradient/update.
    Grad(Encoded),
    /// A dense parameter broadcast (raw f32), shared by refcount across
    /// the broadcast's recipients.
    Params(Arc<[f32]>),
    /// One shard leader's slice of the parameter vector: the shard id, the
    /// slice's start coordinate in the full model vector, and the raw f32
    /// values (shared across the slice broadcast's recipients). Workers
    /// reassemble the slices before computing.
    ParamSlice {
        shard: u16,
        start: u32,
        vals: Arc<[f32]>,
    },
    /// A dense chunk owned by exactly one node at a time — the ring
    /// collectives move these hop to hop, so the buffer's allocation
    /// travels with the message instead of being cloned.
    Chunk(Vec<f32>),
    /// Control traffic (round barriers etc.) with a nominal size.
    Control(u64),
}

impl Payload {
    /// Exact payload size in bits.
    pub fn bits(&self) -> u64 {
        match self {
            Payload::Grad(e) => e.bits,
            Payload::Params(v) => 32 * v.len() as u64,
            // slice values + the same 48-bit shard header the grad frames pay
            Payload::ParamSlice { vals, .. } => 32 * vals.len() as u64 + SHARD_TAG_BITS,
            Payload::Chunk(v) => 32 * v.len() as u64,
            Payload::Control(bits) => *bits,
        }
    }

    /// Shard id this payload is routed for, if any (grad frames carry it
    /// in their wire tag, parameter slices in their header). Drives the
    /// per-shard traffic accounting.
    pub fn shard(&self) -> Option<u32> {
        match self {
            Payload::Grad(e) => e.shard.map(|t| u32::from(t.shard)),
            Payload::ParamSlice { shard, .. } => Some(u32::from(*shard)),
            _ => None,
        }
    }
}

/// Traffic classification for the accounting breakdowns.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum MessageKind {
    GradPush,
    ParamBroadcast,
    Control,
}

impl MessageKind {
    pub fn name(&self) -> &'static str {
        match self {
            MessageKind::GradPush => "grad_push",
            MessageKind::ParamBroadcast => "param_broadcast",
            MessageKind::Control => "control",
        }
    }
}

/// A routed message. Framing overhead (headers) is a fixed 64 bytes,
/// matching a TCP/IP+Ethernet header budget.
#[derive(Clone, Debug)]
pub struct Message {
    pub src: usize,
    pub dst: usize,
    pub round: u64,
    pub kind: MessageKind,
    pub payload: Payload,
}

/// Fixed per-message framing overhead in bits.
pub const FRAME_OVERHEAD_BITS: u64 = 64 * 8;

impl Message {
    /// Total on-wire size: payload + framing.
    pub fn wire_bits(&self) -> u64 {
        self.payload.bits() + FRAME_OVERHEAD_BITS
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::wire::encode_scaled_sign;

    #[test]
    fn payload_bits() {
        assert_eq!(Payload::Params(vec![0.0f32; 10].into()).bits(), 320);
        assert_eq!(Payload::Chunk(vec![0.0f32; 10]).bits(), 320);
        assert_eq!(Payload::Control(100).bits(), 100);
        let e = encode_scaled_sign(&vec![1.0f32; 64]);
        assert_eq!(Payload::Grad(e).bits(), 64 + 32);
    }

    #[test]
    fn params_broadcast_shares_one_allocation() {
        let shared: Arc<[f32]> = vec![1.0f32; 8].into();
        let a = Payload::Params(shared.clone());
        let b = Payload::Params(shared.clone());
        // both payloads alias the same buffer: refcount bump, no copy
        match (&a, &b) {
            (Payload::Params(x), Payload::Params(y)) => {
                assert!(Arc::ptr_eq(x, y));
            }
            _ => unreachable!(),
        }
        assert_eq!(Arc::strong_count(&shared), 3);
    }

    #[test]
    fn sharded_payloads_carry_shard_ids_and_header_bits() {
        use crate::compress::wire::SHARD_TAG_BITS;
        let slice = Payload::ParamSlice {
            shard: 2,
            start: 512,
            vals: vec![0.0f32; 10].into(),
        };
        assert_eq!(slice.bits(), 320 + SHARD_TAG_BITS);
        assert_eq!(slice.shard(), Some(2));
        let tagged = Payload::Grad(encode_scaled_sign(&[1.0f32; 64]).with_shard(5, 0));
        assert_eq!(tagged.bits(), 64 + 32 + SHARD_TAG_BITS);
        assert_eq!(tagged.shard(), Some(5));
        // unsharded payloads attribute to no shard
        assert_eq!(Payload::Params(vec![0.0f32; 4].into()).shard(), None);
        assert_eq!(Payload::Grad(encode_scaled_sign(&[1.0f32; 8])).shard(), None);
        assert_eq!(Payload::Chunk(vec![0.0f32; 4]).shard(), None);
        assert_eq!(Payload::Control(8).shard(), None);
    }

    #[test]
    fn wire_bits_include_framing() {
        let m = Message {
            src: 0,
            dst: 1,
            round: 0,
            kind: MessageKind::Control,
            payload: Payload::Control(8),
        };
        assert_eq!(m.wire_bits(), 8 + FRAME_OVERHEAD_BITS);
    }
}
