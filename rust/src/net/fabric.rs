//! The in-process fabric: N nodes, per-message routing with exact bit
//! accounting and link-model timing. Concurrency-safe: every queue is a
//! `Mutex<VecDeque>` with a `Condvar`, so sends and receives may be issued
//! from any thread (the coordinator's worker pool and the threaded
//! collectives interleave through the same accounting layer). Delivery is
//! per-destination FIFO, which — together with each node's messages being
//! produced by a single peer per collective step — keeps threaded runs
//! bit-deterministic.

use super::accounting::TrafficStats;
use super::link::LinkModel;
use super::message::Message;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// One node's inbox.
#[derive(Default)]
struct Inbox {
    queue: Mutex<VecDeque<Message>>,
    ready: Condvar,
}

/// The shared fabric connecting `n` nodes.
pub struct Fabric {
    n: usize,
    link: LinkModel,
    inboxes: Vec<Inbox>,
    stats: Mutex<TrafficStats>,
}

impl Fabric {
    pub fn new(n: usize, link: LinkModel) -> Self {
        Fabric {
            n,
            link,
            inboxes: (0..n).map(|_| Inbox::default()).collect(),
            stats: Mutex::new(TrafficStats::default()),
        }
    }

    pub fn nodes(&self) -> usize {
        self.n
    }

    pub fn link(&self) -> LinkModel {
        self.link
    }

    /// Send a message: accounts bits + simulated time, enqueues at `dst`.
    pub fn send(&self, msg: Message) {
        assert!(msg.src < self.n && msg.dst < self.n, "bad route");
        assert_ne!(msg.src, msg.dst, "self-send not allowed");
        let bits = msg.wire_bits();
        let time = self.link.transfer_time(bits);
        self.stats
            .lock()
            .unwrap()
            .record(msg.src, msg.dst, msg.kind, bits, time);
        let inbox = &self.inboxes[msg.dst];
        inbox.queue.lock().unwrap().push_back(msg);
        inbox.ready.notify_one();
    }

    /// Receive the next message queued at `node` (FIFO), if any.
    pub fn recv(&self, node: usize) -> Option<Message> {
        self.inboxes[node].queue.lock().unwrap().pop_front()
    }

    /// Receive the next message queued at `node`, blocking until one
    /// arrives (used by the threaded collectives, where the matching send
    /// happens on another worker thread).
    pub fn recv_blocking(&self, node: usize) -> Message {
        let inbox = &self.inboxes[node];
        let mut q = inbox.queue.lock().unwrap();
        loop {
            if let Some(msg) = q.pop_front() {
                return msg;
            }
            q = inbox.ready.wait(q).unwrap();
        }
    }

    /// Like [`recv_blocking`](Self::recv_blocking) but gives up after
    /// `timeout`, returning `None`. Lets threaded callers interleave the
    /// wait with liveness checks on their peers instead of parking forever
    /// when a peer died.
    pub fn recv_timeout(&self, node: usize, timeout: std::time::Duration) -> Option<Message> {
        let inbox = &self.inboxes[node];
        let deadline = std::time::Instant::now() + timeout;
        let mut q = inbox.queue.lock().unwrap();
        loop {
            if let Some(msg) = q.pop_front() {
                return Some(msg);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _timed_out) = inbox.ready.wait_timeout(q, deadline - now).unwrap();
            q = guard;
        }
    }

    /// Receive all currently queued messages at `node`.
    pub fn recv_all(&self, node: usize) -> Vec<Message> {
        let mut q = self.inboxes[node].queue.lock().unwrap();
        q.drain(..).collect()
    }

    /// Number of undelivered messages across the fabric.
    pub fn in_flight(&self) -> usize {
        self.inboxes
            .iter()
            .map(|i| i.queue.lock().unwrap().len())
            .sum()
    }

    /// Snapshot of the traffic statistics.
    pub fn stats(&self) -> TrafficStats {
        self.stats.lock().unwrap().clone()
    }

    pub fn reset_stats(&self) {
        self.stats.lock().unwrap().reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::message::{MessageKind, Payload, FRAME_OVERHEAD_BITS};

    fn ctrl(src: usize, dst: usize, bits: u64) -> Message {
        Message {
            src,
            dst,
            round: 0,
            kind: MessageKind::Control,
            payload: Payload::Control(bits),
        }
    }

    #[test]
    fn send_recv_fifo() {
        let f = Fabric::new(3, LinkModel::default());
        f.send(ctrl(0, 2, 8));
        f.send(ctrl(1, 2, 16));
        assert_eq!(f.in_flight(), 2);
        let a = f.recv(2).unwrap();
        assert_eq!(a.src, 0);
        let b = f.recv(2).unwrap();
        assert_eq!(b.src, 1);
        assert!(f.recv(2).is_none());
    }

    #[test]
    fn accounting_includes_framing() {
        let f = Fabric::new(2, LinkModel::default());
        f.send(ctrl(0, 1, 100));
        let s = f.stats();
        assert_eq!(s.total_bits, 100 + FRAME_OVERHEAD_BITS);
    }

    #[test]
    #[should_panic(expected = "self-send")]
    fn self_send_rejected() {
        let f = Fabric::new(2, LinkModel::default());
        f.send(ctrl(0, 0, 8));
    }

    #[test]
    fn recv_all_drains() {
        let f = Fabric::new(2, LinkModel::default());
        for _ in 0..5 {
            f.send(ctrl(0, 1, 8));
        }
        assert_eq!(f.recv_all(1).len(), 5);
        assert_eq!(f.in_flight(), 0);
    }

    #[test]
    fn recv_blocking_wakes_on_cross_thread_send() {
        let f = Fabric::new(2, LinkModel::default());
        std::thread::scope(|scope| {
            let handle = scope.spawn(|| f.recv_blocking(1));
            // give the receiver a moment to block first
            std::thread::sleep(std::time::Duration::from_millis(10));
            f.send(ctrl(0, 1, 8));
            let msg = handle.join().unwrap();
            assert_eq!(msg.src, 0);
        });
        assert_eq!(f.in_flight(), 0);
    }

    #[test]
    fn concurrent_sends_account_exactly() {
        let f = Fabric::new(5, LinkModel::default());
        std::thread::scope(|scope| {
            for src in 0..4usize {
                let f = &f;
                scope.spawn(move || {
                    for _ in 0..100 {
                        f.send(ctrl(src, 4, 8));
                    }
                });
            }
        });
        let s = f.stats();
        assert_eq!(s.total_bits, 400 * (8 + FRAME_OVERHEAD_BITS));
        assert_eq!(f.recv_all(4).len(), 400);
    }
}
