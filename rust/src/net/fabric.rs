//! The in-process fabric: N nodes, per-message routing with exact bit
//! accounting and link-model timing. Concurrency-safe: every queue is a
//! `Mutex<VecDeque>` with a `Condvar`, so sends and receives may be issued
//! from any thread (the coordinator's worker pool and the threaded
//! collectives interleave through the same accounting layer). Delivery is
//! per-destination FIFO, which — together with each node's messages being
//! produced by a single peer per collective step — keeps threaded runs
//! bit-deterministic.
//!
//! # Virtual time
//!
//! A fabric can carry a [`SimClock`] (see [`Fabric::with_clock`]). Every
//! send then stamps the message's **simulated arrival time**:
//!
//! ```text
//! arrival = clock.node_time(src) + link.transfer_time(wire_bits)
//! ```
//!
//! The driver is responsible for setting each node's local time before
//! that node sends (leader: at fold time; worker: at its compute-finish
//! time), so delivery *consumes* simulated time instead of being
//! implicitly free. Without a clock, the stamp degenerates to the bare
//! transfer time (departure 0), which keeps the synchronous collectives'
//! accounting unchanged. Arrival stamps ride alongside the messages
//! ([`Fabric::recv_all_timed`]) and feed the async driver's event queue.
//!
//! Under [`LinkDiscipline::Serialized`] ([`Fabric::set_discipline`]) each
//! sender's transmissions additionally serialize on its uplink FIFO: the
//! send *starts* at `max(node_time(src), link_free_time(src))`, occupies
//! the link for the bandwidth term (`link.serialization_time`), and
//!
//! ```text
//! arrival = start + link.transfer_time(wire_bits)
//! ```
//!
//! so a worker's S per-shard pushes queue on its uplink instead of
//! overlapping for free, while propagation latency still pipelines. The
//! default stays `Overlapped` — the historical pricing, which every
//! analytic timing identity in the tests assumes — and serialization
//! requires an attached clock (clockless fabrics have no notion of a
//! departure time to queue behind). Semantics: `docs/ASYNC.md`.
//!
//! # Buffer recycling
//!
//! The fabric also owns a [`FramePool`]: spent push-frame byte buffers
//! return here after the leader decodes them, and the workers' encoders
//! take them back for the next round — in steady state no frame buffer is
//! ever allocated or freed (see docs/PERF.md).

use super::accounting::TrafficStats;
use super::link::{LinkDiscipline, LinkModel};
use super::message::Message;
use super::simclock::SimClock;
use crate::obs::trace::TraceRecorder;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Recycling pool for wire-frame byte buffers. A worker `take()`s a spent
/// buffer when encoding a push frame; the leader `put()`s each frame's
/// bytes back after decoding it. After round 1 the pool holds one buffer
/// per in-flight frame and the steady-state encode path stops allocating
/// (each `encode_*_into` reserves its format's worst case once, so the
/// recycled capacities only ever grow).
#[derive(Default)]
pub struct FramePool {
    bufs: Mutex<Vec<Vec<u8>>>,
}

impl FramePool {
    /// Upper bound on pooled buffers: beyond this, `put` drops the buffer
    /// instead of hoarding it (bounds memory if a caller gathers far more
    /// frames than it re-encodes).
    const MAX_POOLED: usize = 4096;

    /// Pop a recycled buffer (empty, capacity intact), or a fresh one.
    // detlint: hot
    pub fn take(&self) -> Vec<u8> {
        self.bufs.lock().unwrap().pop().unwrap_or_default()
    }

    /// Return a spent buffer to the pool.
    // detlint: hot
    pub fn put(&self, mut buf: Vec<u8>) {
        buf.clear();
        let mut bufs = self.bufs.lock().unwrap();
        if bufs.len() < Self::MAX_POOLED {
            bufs.push(buf);
        }
    }

    /// Number of buffers currently pooled.
    pub fn pooled(&self) -> usize {
        self.bufs.lock().unwrap().len()
    }
}

/// One node's inbox; each entry carries its simulated arrival stamp.
#[derive(Default)]
struct Inbox {
    queue: Mutex<VecDeque<(Message, f64)>>,
    ready: Condvar,
}

/// The shared fabric connecting `n` nodes.
pub struct Fabric {
    n: usize,
    link: LinkModel,
    inboxes: Vec<Inbox>,
    stats: Mutex<TrafficStats>,
    /// Running total of on-wire bits, mirrored outside the stats lock so
    /// per-round progress logging never touches (let alone deep-clones)
    /// the accounting maps.
    total_bits: AtomicU64,
    frames: FramePool,
    clock: Option<Arc<SimClock>>,
    discipline: LinkDiscipline,
    trace: Option<Arc<TraceRecorder>>,
}

impl Fabric {
    pub fn new(n: usize, link: LinkModel) -> Self {
        Fabric {
            n,
            link,
            inboxes: (0..n).map(|_| Inbox::default()).collect(),
            stats: Mutex::new(TrafficStats::default()),
            total_bits: AtomicU64::new(0),
            frames: FramePool::default(),
            clock: None,
            discipline: LinkDiscipline::Overlapped,
            trace: None,
        }
    }

    /// A fabric whose sends stamp arrivals against `clock` (see module
    /// docs). `clock` must cover at least `n` nodes.
    pub fn with_clock(n: usize, link: LinkModel, clock: Arc<SimClock>) -> Self {
        assert!(clock.nodes() >= n, "clock smaller than fabric");
        let mut f = Fabric::new(n, link);
        f.clock = Some(clock);
        f
    }

    pub fn nodes(&self) -> usize {
        self.n
    }

    pub fn link(&self) -> LinkModel {
        self.link
    }

    /// The attached virtual clock, if any.
    pub fn clock(&self) -> Option<&Arc<SimClock>> {
        self.clock.as_ref()
    }

    /// Select the uplink sharing discipline (before the fabric is shared,
    /// same builder pattern as [`set_trace`](Self::set_trace)). Serialized
    /// pricing only takes effect on a clocked fabric — see module docs.
    pub fn set_discipline(&mut self, discipline: LinkDiscipline) {
        self.discipline = discipline;
    }

    /// The uplink sharing discipline in effect.
    pub fn discipline(&self) -> LinkDiscipline {
        self.discipline
    }

    /// Attach a flight recorder (before the fabric is shared). Instrumented
    /// call sites reach it through [`trace`](Self::trace); the fabric itself
    /// never records — `send` runs concurrently on pool threads, and ring
    /// writes must stay single-writer per node so the trace is deterministic
    /// (see `docs/OBSERVABILITY.md`).
    pub fn set_trace(&mut self, trace: Arc<TraceRecorder>) {
        self.trace = Some(trace);
    }

    /// The attached flight recorder, if any.
    pub fn trace(&self) -> Option<&Arc<TraceRecorder>> {
        self.trace.as_ref()
    }

    /// The shared frame-buffer recycling pool (see module docs).
    pub fn frame_pool(&self) -> &FramePool {
        &self.frames
    }

    /// Send a message: accounts bits + simulated time, enqueues at `dst`.
    /// Returns the message's simulated arrival time (departure = the
    /// sender's clock time — queued behind the sender's earlier
    /// transmissions under [`LinkDiscipline::Serialized`] — or 0 when no
    /// clock is attached).
    // detlint: hot
    pub fn send(&self, msg: Message) -> f64 {
        assert!(msg.src < self.n && msg.dst < self.n, "bad route");
        assert_ne!(msg.src, msg.dst, "self-send not allowed");
        let bits = msg.wire_bits();
        let time = self.link.transfer_time(bits);
        let arrival = match &self.clock {
            Some(c) if self.discipline == LinkDiscipline::Serialized => {
                // FIFO uplink: start at max(node_time, link_free); only
                // the bandwidth term occupies the link (latency pipelines)
                let occupancy = self.link.serialization_time(bits);
                c.reserve_link(msg.src, c.node_time(msg.src), occupancy) + time
            }
            Some(c) => c.node_time(msg.src) + time,
            None => time,
        };
        self.total_bits.fetch_add(bits, Ordering::Relaxed);
        self.stats
            .lock()
            .unwrap()
            .record(msg.src, msg.dst, msg.kind, msg.payload.shard(), bits, time, arrival);
        let inbox = &self.inboxes[msg.dst];
        inbox.queue.lock().unwrap().push_back((msg, arrival));
        inbox.ready.notify_one();
        arrival
    }

    /// Receive the next message queued at `node` (FIFO), if any.
    // detlint: hot
    pub fn recv(&self, node: usize) -> Option<Message> {
        self.inboxes[node]
            .queue
            .lock()
            .unwrap()
            .pop_front()
            .map(|(m, _)| m)
    }

    /// Receive the next message queued at `node`, blocking until one
    /// arrives (used by the threaded collectives, where the matching send
    /// happens on another worker thread).
    // detlint: hot
    pub fn recv_blocking(&self, node: usize) -> Message {
        let inbox = &self.inboxes[node];
        let mut q = inbox.queue.lock().unwrap();
        loop {
            if let Some((msg, _)) = q.pop_front() {
                return msg;
            }
            q = inbox.ready.wait(q).unwrap();
        }
    }

    /// Like [`recv_blocking`](Self::recv_blocking) but gives up after
    /// `timeout`, returning `None`. Lets threaded callers interleave the
    /// wait with liveness checks on their peers instead of parking forever
    /// when a peer died.
    // detlint: profiling — the timeout deadline is real wall time (peer
    // liveness), never simulated time
    pub fn recv_timeout(&self, node: usize, timeout: std::time::Duration) -> Option<Message> {
        let inbox = &self.inboxes[node];
        let deadline = std::time::Instant::now() + timeout;
        let mut q = inbox.queue.lock().unwrap();
        loop {
            if let Some((msg, _)) = q.pop_front() {
                return Some(msg);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _timed_out) = inbox.ready.wait_timeout(q, deadline - now).unwrap();
            q = guard;
        }
    }

    /// Receive all currently queued messages at `node`.
    pub fn recv_all(&self, node: usize) -> Vec<Message> {
        let mut q = self.inboxes[node].queue.lock().unwrap();
        q.drain(..).map(|(m, _)| m).collect()
    }

    /// Receive all currently queued messages at `node` together with their
    /// simulated arrival stamps (the async driver's gather primitive).
    pub fn recv_all_timed(&self, node: usize) -> Vec<(Message, f64)> {
        let mut q = self.inboxes[node].queue.lock().unwrap();
        q.drain(..).collect()
    }

    /// Drain all currently queued messages at `node` into `out` (cleared
    /// first) — the allocation-free gather primitive: the caller's scratch
    /// vector keeps its capacity across rounds.
    // detlint: hot
    pub fn recv_all_timed_into(&self, node: usize, out: &mut Vec<(Message, f64)>) {
        out.clear();
        let mut q = self.inboxes[node].queue.lock().unwrap();
        out.extend(q.drain(..));
    }

    /// Number of undelivered messages across the fabric.
    pub fn in_flight(&self) -> usize {
        self.inboxes
            .iter()
            .map(|i| i.queue.lock().unwrap().len())
            .sum()
    }

    /// Total on-wire bits so far — a single atomic read: the per-round
    /// logging hot path, with no lock and no clone of the stats maps.
    pub fn total_bits(&self) -> u64 {
        self.total_bits.load(Ordering::Relaxed)
    }

    /// Run `f` against the live traffic statistics under the lock —
    /// borrow-based access for callers that need one number, without
    /// deep-cloning every accounting map the way a snapshot would.
    pub fn with_stats<R>(&self, f: impl FnOnce(&TrafficStats) -> R) -> R {
        f(&self.stats.lock().unwrap())
    }

    /// Owned snapshot of the traffic statistics. This deep-clones the
    /// accounting maps and is meant for end-of-run reporting; hot paths
    /// should use [`total_bits`](Self::total_bits) or
    /// [`with_stats`](Self::with_stats) instead.
    pub fn snapshot_stats(&self) -> TrafficStats {
        self.stats.lock().unwrap().clone()
    }

    pub fn reset_stats(&self) {
        self.stats.lock().unwrap().reset();
        self.total_bits.store(0, Ordering::Relaxed);
    }

    /// Count a frame the leader dropped as undecodable (truncated or
    /// garbage payload, mis-routed shard tag). Rare by construction —
    /// only adversarial/corrupted traffic takes this path — so a stats
    /// lock here never contends on honest rounds.
    pub fn note_dropped_frame(&self) {
        self.stats.lock().unwrap().record_dropped();
    }

    /// Count a frame discarded because its sender departed the membership
    /// and the epoch it was dispatched in has closed (elastic churn; only
    /// runs with an active `MembershipSchedule` take this path).
    pub fn note_departed_frame(&self) {
        self.stats.lock().unwrap().record_departed();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::message::{MessageKind, Payload, FRAME_OVERHEAD_BITS};

    fn ctrl(src: usize, dst: usize, bits: u64) -> Message {
        Message {
            src,
            dst,
            round: 0,
            kind: MessageKind::Control,
            payload: Payload::Control(bits),
        }
    }

    #[test]
    fn send_recv_fifo() {
        let f = Fabric::new(3, LinkModel::default());
        f.send(ctrl(0, 2, 8));
        f.send(ctrl(1, 2, 16));
        assert_eq!(f.in_flight(), 2);
        let a = f.recv(2).unwrap();
        assert_eq!(a.src, 0);
        let b = f.recv(2).unwrap();
        assert_eq!(b.src, 1);
        assert!(f.recv(2).is_none());
    }

    #[test]
    fn accounting_includes_framing() {
        let f = Fabric::new(2, LinkModel::default());
        f.send(ctrl(0, 1, 100));
        let s = f.snapshot_stats();
        assert_eq!(s.total_bits, 100 + FRAME_OVERHEAD_BITS);
        // the lock-free mirror agrees with the locked accounting
        assert_eq!(f.total_bits(), s.total_bits);
        assert_eq!(f.with_stats(|s| s.total_bits), s.total_bits);
        f.reset_stats();
        assert_eq!(f.total_bits(), 0);
    }

    #[test]
    #[should_panic(expected = "self-send")]
    fn self_send_rejected() {
        let f = Fabric::new(2, LinkModel::default());
        f.send(ctrl(0, 0, 8));
    }

    #[test]
    fn recv_all_drains() {
        let f = Fabric::new(2, LinkModel::default());
        for _ in 0..5 {
            f.send(ctrl(0, 1, 8));
        }
        assert_eq!(f.recv_all(1).len(), 5);
        assert_eq!(f.in_flight(), 0);
    }

    #[test]
    fn recv_all_timed_into_reuses_scratch() {
        let f = Fabric::new(2, LinkModel::default());
        let mut scratch: Vec<(Message, f64)> = Vec::new();
        for round in 0..3 {
            for _ in 0..4 {
                f.send(ctrl(0, 1, 8));
            }
            f.recv_all_timed_into(1, &mut scratch);
            assert_eq!(scratch.len(), 4, "round {round}");
        }
        assert!(scratch.capacity() >= 4);
        assert_eq!(f.in_flight(), 0);
    }

    #[test]
    fn frame_pool_recycles_buffers() {
        let pool = FramePool::default();
        assert_eq!(pool.pooled(), 0);
        let fresh = pool.take();
        assert!(fresh.is_empty() && fresh.capacity() == 0);
        let mut buf = Vec::with_capacity(256);
        buf.extend_from_slice(&[1u8, 2, 3]);
        pool.put(buf);
        assert_eq!(pool.pooled(), 1);
        let back = pool.take();
        // cleared but with its allocation intact
        assert!(back.is_empty());
        assert!(back.capacity() >= 256);
        assert_eq!(pool.pooled(), 0);
    }

    #[test]
    fn clockless_send_stamps_bare_transfer_time() {
        let link = LinkModel::new(1e6, 1e-3);
        let f = Fabric::new(2, link);
        let arrival = f.send(ctrl(0, 1, 1000));
        let expect = link.transfer_time(1000 + FRAME_OVERHEAD_BITS);
        assert!((arrival - expect).abs() < 1e-15);
        let timed = f.recv_all_timed(1);
        assert_eq!(timed.len(), 1);
        assert!((timed[0].1 - expect).abs() < 1e-15);
    }

    #[test]
    fn clocked_send_stamps_departure_plus_transfer() {
        let link = LinkModel::new(1e6, 1e-3);
        let clock = Arc::new(SimClock::new(2));
        let f = Fabric::with_clock(2, link, clock.clone());
        clock.set_node_time(0, 5.0);
        let arrival = f.send(ctrl(0, 1, 1000));
        let expect = 5.0 + link.transfer_time(1000 + FRAME_OVERHEAD_BITS);
        assert!((arrival - expect).abs() < 1e-12);
        // the stamp rides with the message and into the stats
        let timed = f.recv_all_timed(1);
        assert!((timed[0].1 - expect).abs() < 1e-12);
        let stats = f.snapshot_stats();
        assert!((stats.last_arrival_of_kind(MessageKind::Control) - expect).abs() < 1e-12);
    }

    #[test]
    fn serialized_sends_queue_on_the_senders_uplink() {
        let link = LinkModel::new(1e6, 1e-3);
        let clock = Arc::new(SimClock::new(3));
        let mut f = Fabric::with_clock(3, link, clock.clone());
        f.set_discipline(LinkDiscipline::Serialized);
        assert_eq!(f.discipline(), LinkDiscipline::Serialized);
        clock.set_node_time(0, 5.0);
        let bits = 1000 + FRAME_OVERHEAD_BITS;
        let ser = link.serialization_time(bits);
        // first send: idle uplink, identical to the overlapped stamp
        let a1 = f.send(ctrl(0, 1, 1000));
        assert_eq!(a1, 5.0 + link.transfer_time(bits));
        // second send at the same node time: starts once the uplink frees
        let a2 = f.send(ctrl(0, 2, 1000));
        assert_eq!(a2, (5.0 + ser) + link.transfer_time(bits));
        // a different sender's uplink is independent
        clock.set_node_time(1, 5.0);
        let a3 = f.send(ctrl(1, 2, 1000));
        assert_eq!(a3, 5.0 + link.transfer_time(bits));
        // per-message accounting still records the bare transfer time
        let stats = f.snapshot_stats();
        let total = stats.sim_time_of_kind(MessageKind::Control);
        assert!((total - 3.0 * link.transfer_time(bits)).abs() < 1e-12);
    }

    #[test]
    fn overlapped_sends_ignore_the_uplink_queue() {
        // the historical default: back-to-back sends from one node carry
        // identical stamps (infinite fan-out)
        let link = LinkModel::new(1e6, 1e-3);
        let clock = Arc::new(SimClock::new(3));
        let f = Fabric::with_clock(3, link, clock.clone());
        clock.set_node_time(0, 2.0);
        let a1 = f.send(ctrl(0, 1, 1000));
        let a2 = f.send(ctrl(0, 2, 1000));
        assert_eq!(a1, a2);
        assert_eq!(clock.link_free_time(0), 0.0);
    }

    #[test]
    fn serialized_never_arrives_before_overlapped() {
        let link = LinkModel::wan();
        let clock_o = Arc::new(SimClock::new(4));
        let fab_o = Fabric::with_clock(4, link, clock_o.clone());
        let clock_s = Arc::new(SimClock::new(4));
        let mut fab_s = Fabric::with_clock(4, link, clock_s.clone());
        fab_s.set_discipline(LinkDiscipline::Serialized);
        clock_o.set_node_time(0, 1.0);
        clock_s.set_node_time(0, 1.0);
        for dst in [1usize, 2, 3, 1, 2, 3] {
            let o = fab_o.send(ctrl(0, dst, 4096));
            let s = fab_s.send(ctrl(0, dst, 4096));
            assert!(s >= o, "serialized {s} earlier than overlapped {o}");
        }
    }

    #[test]
    fn recv_blocking_wakes_on_cross_thread_send() {
        let f = Fabric::new(2, LinkModel::default());
        std::thread::scope(|scope| {
            let handle = scope.spawn(|| f.recv_blocking(1));
            // give the receiver a moment to block first
            std::thread::sleep(std::time::Duration::from_millis(10));
            f.send(ctrl(0, 1, 8));
            let msg = handle.join().unwrap();
            assert_eq!(msg.src, 0);
        });
        assert_eq!(f.in_flight(), 0);
    }

    #[test]
    fn concurrent_sends_account_exactly() {
        let f = Fabric::new(5, LinkModel::default());
        std::thread::scope(|scope| {
            for src in 0..4usize {
                let f = &f;
                scope.spawn(move || {
                    for _ in 0..100 {
                        f.send(ctrl(src, 4, 8));
                    }
                });
            }
        });
        let s = f.snapshot_stats();
        assert_eq!(s.total_bits, 400 * (8 + FRAME_OVERHEAD_BITS));
        assert_eq!(f.total_bits(), s.total_bits);
        assert_eq!(f.recv_all(4).len(), 400);
    }
}
