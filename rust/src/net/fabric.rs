//! The in-process fabric: N nodes, per-message routing with exact bit
//! accounting and link-model timing. Deterministic (single-threaded
//! simulation): messages are delivered through per-destination FIFO queues.

use super::accounting::TrafficStats;
use super::link::LinkModel;
use super::message::Message;
use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

/// The shared fabric connecting `n` nodes.
pub struct Fabric {
    n: usize,
    link: LinkModel,
    queues: Vec<Mutex<VecDeque<Message>>>,
    stats: Arc<Mutex<TrafficStats>>,
}

impl Fabric {
    pub fn new(n: usize, link: LinkModel) -> Self {
        Fabric {
            n,
            link,
            queues: (0..n).map(|_| Mutex::new(VecDeque::new())).collect(),
            stats: Arc::new(Mutex::new(TrafficStats::default())),
        }
    }

    pub fn nodes(&self) -> usize {
        self.n
    }

    pub fn link(&self) -> LinkModel {
        self.link
    }

    /// Send a message: accounts bits + simulated time, enqueues at `dst`.
    pub fn send(&self, msg: Message) {
        assert!(msg.src < self.n && msg.dst < self.n, "bad route");
        assert_ne!(msg.src, msg.dst, "self-send not allowed");
        let bits = msg.wire_bits();
        let time = self.link.transfer_time(bits);
        self.stats
            .lock()
            .unwrap()
            .record(msg.src, msg.dst, msg.kind, bits, time);
        self.queues[msg.dst].lock().unwrap().push_back(msg);
    }

    /// Receive the next message queued at `node` (FIFO), if any.
    pub fn recv(&self, node: usize) -> Option<Message> {
        self.queues[node].lock().unwrap().pop_front()
    }

    /// Receive all currently queued messages at `node`.
    pub fn recv_all(&self, node: usize) -> Vec<Message> {
        let mut q = self.queues[node].lock().unwrap();
        q.drain(..).collect()
    }

    /// Number of undelivered messages across the fabric.
    pub fn in_flight(&self) -> usize {
        self.queues.iter().map(|q| q.lock().unwrap().len()).sum()
    }

    /// Snapshot of the traffic statistics.
    pub fn stats(&self) -> TrafficStats {
        self.stats.lock().unwrap().clone()
    }

    pub fn reset_stats(&self) {
        self.stats.lock().unwrap().reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::message::{MessageKind, Payload, FRAME_OVERHEAD_BITS};

    fn ctrl(src: usize, dst: usize, bits: u64) -> Message {
        Message {
            src,
            dst,
            round: 0,
            kind: MessageKind::Control,
            payload: Payload::Control(bits),
        }
    }

    #[test]
    fn send_recv_fifo() {
        let f = Fabric::new(3, LinkModel::default());
        f.send(ctrl(0, 2, 8));
        f.send(ctrl(1, 2, 16));
        assert_eq!(f.in_flight(), 2);
        let a = f.recv(2).unwrap();
        assert_eq!(a.src, 0);
        let b = f.recv(2).unwrap();
        assert_eq!(b.src, 1);
        assert!(f.recv(2).is_none());
    }

    #[test]
    fn accounting_includes_framing() {
        let f = Fabric::new(2, LinkModel::default());
        f.send(ctrl(0, 1, 100));
        let s = f.stats();
        assert_eq!(s.total_bits, 100 + FRAME_OVERHEAD_BITS);
    }

    #[test]
    #[should_panic(expected = "self-send")]
    fn self_send_rejected() {
        let f = Fabric::new(2, LinkModel::default());
        f.send(ctrl(0, 0, 8));
    }

    #[test]
    fn recv_all_drains() {
        let f = Fabric::new(2, LinkModel::default());
        for _ in 0..5 {
            f.send(ctrl(0, 1, 8));
        }
        assert_eq!(f.recv_all(1).len(), 5);
        assert_eq!(f.in_flight(), 0);
    }
}
