//! Simulated network fabric.
//!
//! The paper's communication claims (the ~64× reduction, and the shape of
//! the time-to-accuracy tradeoff) are properties of *what goes on the
//! wire*. Real NICs are not available here, so the fabric models them:
//! every message carries an exact bit count (from the wire codecs in
//! [`crate::compress::wire`]), every link has a bandwidth/latency model,
//! and the accounting layer integrates transfer times into a simulated
//! clock per node. The collectives and the coordinator route all gradient
//! traffic through this fabric — nothing is exchanged "for free".

//!
//! Time is simulated, not just priced: [`simclock::SimClock`] tracks a
//! virtual timestamp per node and [`Fabric::with_clock`] stamps every
//! message's arrival as `sender_time + transfer_time`, so the async
//! coordinator can consume link and compute time through a deterministic
//! discrete-event queue ([`simclock::EventQueue`]). Worker compute cost
//! comes from the seeded [`straggler::StragglerSchedule`] models, and
//! hostile traffic from the seeded [`adversary::AdversarySchedule`]
//! Byzantine worker models.

pub mod accounting;
pub mod adversary;
pub mod fabric;
pub mod link;
pub mod membership;
pub mod message;
pub mod simclock;
pub mod straggler;

pub use accounting::TrafficStats;
pub use adversary::{AdversaryModel, AdversarySchedule};
pub use membership::{
    MembershipEvent, MembershipEventKind, MembershipParseError, MembershipSchedule,
    MembershipState,
};
pub use fabric::{Fabric, FramePool};
pub use link::{LinkDiscipline, LinkModel};
pub use message::{Message, MessageKind, Payload};
pub use simclock::{Event, EventQueue, SimClock};
pub use straggler::{StragglerModel, StragglerParseError, StragglerSchedule};
