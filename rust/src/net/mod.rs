//! Simulated network fabric.
//!
//! The paper's communication claims (the ~64× reduction, and the shape of
//! the time-to-accuracy tradeoff) are properties of *what goes on the
//! wire*. Real NICs are not available here, so the fabric models them:
//! every message carries an exact bit count (from the wire codecs in
//! [`crate::compress::wire`]), every link has a bandwidth/latency model,
//! and the accounting layer integrates transfer times into a simulated
//! clock per node. The collectives and the coordinator route all gradient
//! traffic through this fabric — nothing is exchanged "for free".

pub mod accounting;
pub mod fabric;
pub mod link;
pub mod message;

pub use accounting::TrafficStats;
pub use fabric::Fabric;
pub use link::LinkModel;
pub use message::{Message, MessageKind, Payload};
