//! Seeded, virtual-clock-scheduled membership churn.
//!
//! A production fleet is never a fixed `n`: workers crash, rejoin, and
//! scale out mid-run. [`MembershipSchedule`] models that as a list of
//! events — `leave:W@R` (graceful departure, EF residual parked in the
//! worker actor), `crash:W@R` (fail-stop, the residual is lost),
//! `rejoin:W@R` (revive; cold after a crash, warm after a leave) and
//! `join:W@R` (cold revival regardless of history) — applied at the
//! *start* of round `R`. A schedule is either written explicitly
//! ([`MembershipSchedule::parse`]) or drawn from seeded per-`(worker,
//! round)` PCG cells ([`MembershipSchedule::random_churn`]), so the event
//! list is a pure function of `(seed, n, round)` and every churn run is
//! bit-deterministic across `(shards, threads)`.
//!
//! The drivers consume the schedule through [`MembershipState`]: a live
//! bitmap plus a monotone *membership epoch* that advances once per round
//! that applies at least one event. The epoch is what the async driver
//! keys departed-frame semantics on (a frame from a departed worker folds
//! while the epoch it was dispatched in is still open, and drops once a
//! later epoch begins) and what [`crate::coordinator::state::Snapshot`]
//! records so checkpoint restore can replay membership exactly.

use crate::util::rng::Pcg64;
use std::fmt;

/// What happens to a worker at a scheduled membership event.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum MembershipEventKind {
    /// Graceful departure: the worker stops participating but its EF
    /// residual stays parked in its actor, so a later `rejoin` is warm.
    Leave,
    /// Fail-stop: the worker disappears and its EF residual is lost; a
    /// later `rejoin` restores cold (zeroed) state.
    Crash,
    /// Revival of a departed worker: warm after `leave`, cold after
    /// `crash`.
    Rejoin,
    /// Cold revival: zeroed EF state regardless of how the worker left.
    Join,
}

impl MembershipEventKind {
    /// The spec keyword for this kind.
    pub fn name(self) -> &'static str {
        match self {
            MembershipEventKind::Leave => "leave",
            MembershipEventKind::Crash => "crash",
            MembershipEventKind::Rejoin => "rejoin",
            MembershipEventKind::Join => "join",
        }
    }
}

/// One scheduled membership transition, applied at the start of `round`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MembershipEvent {
    pub kind: MembershipEventKind,
    pub worker: usize,
    pub round: u64,
}

impl fmt::Display for MembershipEvent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}@{}", self.kind.name(), self.worker, self.round)
    }
}

/// The accepted spec grammar, quoted by parse errors and the CLI.
pub const MEMBERSHIP_GRAMMAR: &str = "'none' or a comma-separated list of \
leave:W@R | crash:W@R | rejoin:W@R | join:W@R \
(worker W transitions at the start of round R)";

/// A malformed membership spec: the offending token plus what went wrong.
/// `Display` includes the accepted grammar so the CLI error is
/// self-describing.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MembershipParseError {
    /// The token (one comma-separated element) that failed to parse.
    pub token: String,
    /// Why it was rejected.
    pub reason: String,
}

impl fmt::Display for MembershipParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "bad membership spec token '{}': {}; accepted grammar: {}",
            self.token, self.reason, MEMBERSHIP_GRAMMAR
        )
    }
}

impl std::error::Error for MembershipParseError {}

/// A full churn schedule: membership events sorted by `(round, worker)`.
///
/// The empty schedule (`none`) is inert: drivers guard every churn code
/// path behind [`MembershipSchedule::is_active`], so an empty schedule is
/// byte-identical to the churn-free engine.
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub struct MembershipSchedule {
    events: Vec<MembershipEvent>,
}

impl MembershipSchedule {
    /// The empty (inert) schedule.
    pub fn none() -> Self {
        MembershipSchedule { events: Vec::new() }
    }

    /// Build from an explicit event list (sorted internally).
    pub fn from_events(mut events: Vec<MembershipEvent>) -> Self {
        events.sort_by_key(|e| (e.round, e.worker, e.kind));
        MembershipSchedule { events }
    }

    /// True when the schedule contains at least one event. Drivers take
    /// the churn-aware code paths only when this holds.
    pub fn is_active(&self) -> bool {
        !self.events.is_empty()
    }

    /// All events, sorted by `(round, worker)`.
    pub fn events(&self) -> &[MembershipEvent] {
        &self.events
    }

    /// The events applying at the start of `round`, as a sorted subslice
    /// (allocation-free: binary search into the sorted event list).
    pub fn events_at(&self, round: u64) -> &[MembershipEvent] {
        let lo = self.events.partition_point(|e| e.round < round);
        let hi = self.events.partition_point(|e| e.round <= round);
        &self.events[lo..hi]
    }

    /// Parse a spec: `none` or a comma-separated list of
    /// `leave:W@R`/`crash:W@R`/`rejoin:W@R`/`join:W@R`.
    pub fn parse(spec: &str) -> Result<MembershipSchedule, MembershipParseError> {
        let spec = spec.trim();
        if spec.is_empty() || spec == "none" {
            return Ok(MembershipSchedule::none());
        }
        let mut events = Vec::new();
        for token in spec.split(',') {
            let token = token.trim();
            let err = |reason: &str| MembershipParseError {
                token: token.to_string(),
                reason: reason.to_string(),
            };
            if token.is_empty() {
                return Err(err("empty element"));
            }
            let (kind_s, rest) = token
                .split_once(':')
                .ok_or_else(|| err("missing ':' (expected kind:W@R)"))?;
            let kind = match kind_s {
                "leave" => MembershipEventKind::Leave,
                "crash" => MembershipEventKind::Crash,
                "rejoin" => MembershipEventKind::Rejoin,
                "join" => MembershipEventKind::Join,
                _ => {
                    return Err(err(
                        "unknown event kind (expected leave, crash, rejoin or join)",
                    ))
                }
            };
            let (worker_s, round_s) = rest
                .split_once('@')
                .ok_or_else(|| err("missing '@' (expected kind:W@R)"))?;
            let worker: usize = worker_s
                .parse()
                .map_err(|_| err("worker id W is not a non-negative integer"))?;
            let round: u64 = round_s
                .parse()
                .map_err(|_| err("round R is not a non-negative integer"))?;
            events.push(MembershipEvent {
                kind,
                worker,
                round,
            });
        }
        // Reject two transitions of the same worker in the same round: the
        // outcome would depend on intra-round event order.
        let mut keys: Vec<(u64, usize)> = events.iter().map(|e| (e.round, e.worker)).collect();
        keys.sort_unstable();
        if let Some(w) = keys.windows(2).find(|w| w[0] == w[1]) {
            return Err(MembershipParseError {
                token: format!("worker {} at round {}", w[0].1, w[0].0),
                reason: "duplicate event for the same worker in the same round".to_string(),
            });
        }
        Ok(MembershipSchedule::from_events(events))
    }

    /// Seeded random churn: each worker other than worker 0 (pinned live
    /// so the fleet never empties) departs with probability `rate` per
    /// live round and revives with probability 0.3 per departed round.
    /// `crash` selects fail-stop departures (cold rejoin) instead of
    /// graceful leaves. Every draw comes from a per-`(worker, round)` PCG
    /// cell, so the schedule is a pure function of `(seed, n, rounds,
    /// rate, crash)` — independent of call order, shards and threads.
    pub fn random_churn(seed: u64, n: usize, rounds: u64, rate: f64, crash: bool) -> Self {
        let mut events = Vec::new();
        let depart = if crash {
            MembershipEventKind::Crash
        } else {
            MembershipEventKind::Leave
        };
        for w in 1..n {
            let mut live = true;
            for r in 1..rounds {
                let mut rng = Self::cell_rng(seed, w, r);
                if live {
                    if rng.bernoulli(rate) {
                        events.push(MembershipEvent {
                            kind: depart,
                            worker: w,
                            round: r,
                        });
                        live = false;
                    }
                } else if rng.bernoulli(0.3) {
                    events.push(MembershipEvent {
                        kind: MembershipEventKind::Rejoin,
                        worker: w,
                        round: r,
                    });
                    live = true;
                }
            }
        }
        MembershipSchedule::from_events(events)
    }

    /// One PCG cell per `(worker, round)` — the same idiom as the
    /// straggler and adversary models, so sampling never depends on call
    /// order.
    fn cell_rng(seed: u64, worker: usize, round: u64) -> Pcg64 {
        let mix = (worker as u64 + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        Pcg64::new(seed ^ round.wrapping_mul(0xd1b5_4a32_d192_ed03), mix ^ round)
    }

    /// Check the schedule is consistent for a fleet of `n` workers:
    /// worker ids in range, departures only of live workers, revivals
    /// only of departed ones, and the live set never empties.
    pub fn validate(&self, n: usize) -> Result<(), String> {
        let mut state = MembershipState::new(n);
        for ev in &self.events {
            if ev.worker >= n {
                return Err(format!(
                    "membership event '{ev}' names worker {} but the fleet has {n} workers (ids 0..{n})",
                    ev.worker
                ));
            }
            let live = state.is_live(ev.worker);
            match ev.kind {
                MembershipEventKind::Leave | MembershipEventKind::Crash if !live => {
                    return Err(format!(
                        "membership event '{ev}' departs worker {} which is not live at round {}",
                        ev.worker, ev.round
                    ));
                }
                MembershipEventKind::Rejoin | MembershipEventKind::Join if live => {
                    return Err(format!(
                        "membership event '{ev}' revives worker {} which is already live at round {}",
                        ev.worker, ev.round
                    ));
                }
                _ => {}
            }
            state.apply(ev);
            if state.live_count() == 0 {
                return Err(format!(
                    "membership event '{ev}' empties the fleet at round {}",
                    ev.round
                ));
            }
        }
        Ok(())
    }
}

impl fmt::Display for MembershipSchedule {
    /// The canonical spec string (`none` for the empty schedule).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.events.is_empty() {
            return write!(f, "none");
        }
        for (i, ev) in self.events.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{ev}")?;
        }
        Ok(())
    }
}

/// Live-set tracker the drivers carry: which workers participate this
/// round, whether a departed worker's residual was lost (crash) or parked
/// (leave), and the membership epoch — incremented once per round that
/// applies at least one event.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MembershipState {
    live: Vec<bool>,
    crashed: Vec<bool>,
    epoch: u64,
}

impl MembershipState {
    /// All `n` workers live, epoch 0.
    pub fn new(n: usize) -> Self {
        MembershipState {
            live: vec![true; n],
            crashed: vec![false; n],
            epoch: 0,
        }
    }

    /// Replay `schedule` for every round strictly before `upto`: the state
    /// a driver that applied events at the start of each round holds just
    /// before running round `upto`. Used by checkpoint restore.
    pub fn replay(schedule: &MembershipSchedule, n: usize, upto: u64) -> Self {
        let mut state = MembershipState::new(n);
        let mut last_round = None;
        for ev in schedule.events().iter().filter(|e| e.round < upto) {
            state.apply(ev);
            if last_round != Some(ev.round) {
                last_round = Some(ev.round);
                state.bump_epoch();
            }
        }
        state
    }

    /// Apply one event. Returns `true` when the event revives a worker
    /// whose EF state must be cold (zeroed): a `join`, or a `rejoin` after
    /// a crash.
    pub fn apply(&mut self, ev: &MembershipEvent) -> bool {
        let w = ev.worker;
        match ev.kind {
            MembershipEventKind::Leave => {
                self.live[w] = false;
                self.crashed[w] = false;
                false
            }
            MembershipEventKind::Crash => {
                self.live[w] = false;
                self.crashed[w] = true;
                false
            }
            MembershipEventKind::Rejoin => {
                let cold = self.crashed[w];
                self.live[w] = true;
                self.crashed[w] = false;
                cold
            }
            MembershipEventKind::Join => {
                self.live[w] = true;
                self.crashed[w] = false;
                true
            }
        }
    }

    /// Advance the membership epoch (once per round that applied events).
    pub fn bump_epoch(&mut self) {
        self.epoch += 1;
    }

    /// The current membership epoch.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Whether worker `w` participates in the current round.
    pub fn is_live(&self, w: usize) -> bool {
        self.live[w]
    }

    /// Number of live workers.
    pub fn live_count(&self) -> usize {
        self.live.iter().filter(|&&l| l).count()
    }

    /// Fleet size (live or not).
    pub fn len(&self) -> usize {
        self.live.len()
    }

    /// True when the fleet is empty (never the case for validated runs).
    pub fn is_empty(&self) -> bool {
        self.live.is_empty()
    }

    /// Fill `out` with the live worker ids in ascending order (reuses the
    /// caller's buffer so epoch transitions stay allocation-light).
    pub fn live_ids_into(&self, out: &mut Vec<usize>) {
        out.clear();
        for (w, &l) in self.live.iter().enumerate() {
            if l {
                out.push(w);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_none_and_empty() {
        assert_eq!(
            MembershipSchedule::parse("none").unwrap(),
            MembershipSchedule::none()
        );
        assert_eq!(
            MembershipSchedule::parse("  ").unwrap(),
            MembershipSchedule::none()
        );
        assert!(!MembershipSchedule::none().is_active());
    }

    #[test]
    fn parse_roundtrips_canonical_spec() {
        let spec = "crash:1@3,rejoin:1@6,leave:2@4,join:2@9";
        let sched = MembershipSchedule::parse(spec).unwrap();
        assert!(sched.is_active());
        assert_eq!(sched.events().len(), 4);
        // Display is the canonical (round, worker)-sorted spec.
        assert_eq!(sched.to_string(), "crash:1@3,leave:2@4,rejoin:1@6,join:2@9");
        assert_eq!(
            MembershipSchedule::parse(&sched.to_string()).unwrap(),
            sched
        );
    }

    #[test]
    fn parse_errors_carry_token_and_grammar() {
        for (spec, bad_token) in [
            ("leave", "leave"),
            ("leave:1", "leave:1"),
            ("vanish:1@3", "vanish:1@3"),
            ("leave:x@3", "leave:x@3"),
            ("leave:1@y", "leave:1@y"),
            ("crash:1@3,,rejoin:1@6", ""),
        ] {
            let err = MembershipSchedule::parse(spec).unwrap_err();
            assert_eq!(err.token, bad_token, "spec {spec:?}");
            let msg = err.to_string();
            assert!(msg.contains("accepted grammar"), "spec {spec:?}: {msg}");
        }
    }

    #[test]
    fn parse_rejects_same_worker_same_round() {
        let err = MembershipSchedule::parse("crash:1@3,rejoin:1@3").unwrap_err();
        assert!(err.reason.contains("duplicate"), "{err}");
    }

    #[test]
    fn events_at_is_the_sorted_round_slice() {
        let sched = MembershipSchedule::parse("crash:2@3,leave:1@3,rejoin:2@6").unwrap();
        let at3 = sched.events_at(3);
        assert_eq!(at3.len(), 2);
        assert_eq!((at3[0].worker, at3[1].worker), (1, 2));
        assert_eq!(sched.events_at(4), &[]);
        assert_eq!(sched.events_at(6).len(), 1);
    }

    #[test]
    fn validate_accepts_consistent_and_rejects_inconsistent() {
        let ok = MembershipSchedule::parse("crash:1@3,rejoin:1@6").unwrap();
        ok.validate(4).unwrap();
        // Worker id out of range.
        assert!(MembershipSchedule::parse("crash:9@3")
            .unwrap()
            .validate(4)
            .is_err());
        // Departing a worker that is not live.
        assert!(MembershipSchedule::parse("crash:1@3,leave:1@5")
            .unwrap()
            .validate(4)
            .is_err());
        // Reviving a live worker.
        assert!(MembershipSchedule::parse("rejoin:1@3")
            .unwrap()
            .validate(4)
            .is_err());
        // Emptying the fleet.
        assert!(MembershipSchedule::parse("leave:0@1,leave:1@2")
            .unwrap()
            .validate(2)
            .is_err());
    }

    #[test]
    fn state_tracks_cold_vs_warm_revivals() {
        let mut st = MembershipState::new(4);
        assert_eq!(st.live_count(), 4);
        let crash = MembershipEvent {
            kind: MembershipEventKind::Crash,
            worker: 1,
            round: 3,
        };
        assert!(!st.apply(&crash));
        assert!(!st.is_live(1));
        let rejoin = MembershipEvent {
            kind: MembershipEventKind::Rejoin,
            worker: 1,
            round: 6,
        };
        // Rejoin after crash is cold.
        assert!(st.apply(&rejoin));
        let leave = MembershipEvent {
            kind: MembershipEventKind::Leave,
            worker: 2,
            round: 7,
        };
        st.apply(&leave);
        let rejoin2 = MembershipEvent {
            kind: MembershipEventKind::Rejoin,
            worker: 2,
            round: 9,
        };
        // Rejoin after graceful leave is warm.
        assert!(!st.apply(&rejoin2));
        let join = MembershipEvent {
            kind: MembershipEventKind::Join,
            worker: 2,
            round: 11,
        };
        st.apply(&leave);
        // Join is always cold.
        assert!(st.apply(&join));
    }

    #[test]
    fn replay_counts_epochs_per_event_round() {
        let sched = MembershipSchedule::parse("crash:1@3,leave:2@3,rejoin:1@6").unwrap();
        let st = MembershipState::replay(&sched, 4, 0);
        assert_eq!(st.epoch(), 0);
        assert_eq!(st.live_count(), 4);
        // Events at round 3 apply at the start of round 3, so they are
        // included when restoring to run round 4.
        let st = MembershipState::replay(&sched, 4, 4);
        assert_eq!(st.epoch(), 1);
        assert_eq!(st.live_count(), 2);
        let st = MembershipState::replay(&sched, 4, 7);
        assert_eq!(st.epoch(), 2);
        assert_eq!(st.live_count(), 3);
        assert!(st.is_live(1));
        assert!(!st.is_live(2));
    }

    #[test]
    fn random_churn_is_deterministic_and_valid() {
        let a = MembershipSchedule::random_churn(7, 8, 50, 0.2, false);
        let b = MembershipSchedule::random_churn(7, 8, 50, 0.2, false);
        assert_eq!(a, b);
        assert!(a.is_active(), "rate 0.2 over 50 rounds should churn");
        a.validate(8).unwrap();
        // Worker 0 is pinned live.
        assert!(a.events().iter().all(|e| e.worker != 0));
        // Crash flavour yields the same event pattern with crash kinds.
        let c = MembershipSchedule::random_churn(7, 8, 50, 0.2, true);
        c.validate(8).unwrap();
        assert!(c
            .events()
            .iter()
            .all(|e| e.kind != MembershipEventKind::Leave));
        // Rate 0 is inert.
        let z = MembershipSchedule::random_churn(7, 8, 50, 0.0, false);
        assert!(!z.is_active());
        // Different seeds differ.
        let d = MembershipSchedule::random_churn(8, 8, 50, 0.2, false);
        assert_ne!(a, d);
    }

    #[test]
    fn live_ids_into_reuses_buffer() {
        let mut st = MembershipState::new(4);
        st.apply(&MembershipEvent {
            kind: MembershipEventKind::Leave,
            worker: 2,
            round: 1,
        });
        let mut ids = Vec::new();
        st.live_ids_into(&mut ids);
        assert_eq!(ids, vec![0, 1, 3]);
    }
}
