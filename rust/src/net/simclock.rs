//! Discrete-event virtual clock: per-node local times plus a deterministic
//! event queue.
//!
//! The fabric's link model prices every message in simulated seconds, but
//! until now nothing *consumed* that time — rounds were implicitly free and
//! the engine could only run lock-step. This module supplies the two
//! primitives the asynchronous coordinator needs:
//!
//! * [`SimClock`] — one virtual timestamp per fabric node. The driver sets
//!   a node's local time before the node sends (leader: at fold time;
//!   worker: at compute-finish time), and [`crate::net::Fabric::send`]
//!   stamps each message's arrival as `local_time(src) + transfer_time`.
//! * [`EventQueue`] — a priority queue of scheduled events ordered by the
//!   total key `(time, node, seq)`. Times are compared with
//!   `f64::total_cmp`, `node` breaks time ties, and the monotone sequence
//!   number breaks the (never observed in practice) remainder, so the pop
//!   order is a pure function of what was scheduled — never of thread
//!   scheduling or hash state. This is what makes the async engine
//!   bit-deterministic for any `--threads` value.
//!
//! Simultaneity is meaningful: with a constant straggler model every
//! worker's frame lands on the leader at the *identical* f64 timestamp.
//! The async driver treats equal timestamps as one logical instant (it
//! drains the whole tie group before evaluating its quorum trigger), which
//! is what makes `--quorum n --max-staleness 0` degenerate to the exact
//! synchronous schedule. [`EventQueue::peek_time`] exists for that drain.

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::sync::Mutex;

/// Per-node virtual times, shared between the driver and the fabric.
///
/// Thread-safe: the worker-pool threads read their node's time through
/// `Fabric::send` while the driver owns the schedule. The driver only
/// mutates a node's entry when no send from that node can be in flight
/// (times are set *before* the pool round is dispatched), so readers
/// always observe the intended timestamp.
#[derive(Debug)]
pub struct SimClock {
    node_time: Mutex<Vec<f64>>,
    /// Per-node uplink FIFO state: the virtual time at which the node's
    /// link finishes its last reserved transmission. Only consulted under
    /// [`crate::net::LinkDiscipline::Serialized`]; stays all-zero (and
    /// harmless) otherwise.
    link_free: Mutex<Vec<f64>>,
}

impl SimClock {
    pub fn new(nodes: usize) -> Self {
        SimClock {
            node_time: Mutex::new(vec![0.0; nodes]),
            link_free: Mutex::new(vec![0.0; nodes]),
        }
    }

    pub fn nodes(&self) -> usize {
        self.node_time.lock().unwrap().len()
    }

    /// The node's current local time.
    pub fn node_time(&self, node: usize) -> f64 {
        self.node_time.lock().unwrap()[node]
    }

    /// Set a node's local time (the driver's scheduling hook).
    pub fn set_node_time(&self, node: usize, t: f64) {
        self.node_time.lock().unwrap()[node] = t;
    }

    /// Advance a node's local time to at least `t` (no-op if already past).
    pub fn advance_node(&self, node: usize, t: f64) {
        let mut times = self.node_time.lock().unwrap();
        if t > times[node] {
            times[node] = t;
        }
    }

    /// Virtual time at which `node`'s uplink becomes idle (0 until the
    /// first [`reserve_link`](Self::reserve_link)).
    pub fn link_free_time(&self, node: usize) -> f64 {
        self.link_free.lock().unwrap()[node]
    }

    /// Reserve `node`'s uplink FIFO for a transmission requested at
    /// virtual time `at` that occupies the link for `occupancy` seconds.
    /// The transmission starts at `max(at, link_free_time)` — the link
    /// serializes, it never preempts — and the link is then busy until
    /// `start + occupancy`. Returns the start time.
    ///
    /// Determinism: each node's sends are issued by a single thread in a
    /// fixed program order (workers push their shard frames in shard
    /// order; leaders broadcast in worker-id order), so the FIFO state —
    /// and every arrival derived from it — is a pure function of the
    /// seeded models, never of thread scheduling.
    // detlint: hot
    pub fn reserve_link(&self, node: usize, at: f64, occupancy: f64) -> f64 {
        debug_assert!(occupancy >= 0.0);
        let mut free = self.link_free.lock().unwrap();
        let start = at.max(free[node]);
        free[node] = start + occupancy;
        start
    }

    /// Latest local time over all nodes.
    pub fn max_time(&self) -> f64 {
        self.node_time
            .lock()
            .unwrap()
            .iter()
            .cloned()
            .fold(0.0, f64::max)
    }
}

/// One scheduled event.
#[derive(Clone, Debug)]
pub struct Event<T> {
    /// Virtual time at which the event fires.
    pub time: f64,
    /// Node the event belongs to (tie-break after time).
    pub node: usize,
    /// Monotone schedule order (final tie-break).
    pub seq: u64,
    pub payload: T,
}

impl<T> Event<T> {
    fn key_cmp(&self, other: &Self) -> Ordering {
        self.time
            .total_cmp(&other.time)
            .then(self.node.cmp(&other.node))
            .then(self.seq.cmp(&other.seq))
    }
}

impl<T> PartialEq for Event<T> {
    fn eq(&self, other: &Self) -> bool {
        self.key_cmp(other) == Ordering::Equal
    }
}

impl<T> Eq for Event<T> {}

impl<T> PartialOrd for Event<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for Event<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed so the std max-heap pops the EARLIEST event first
        other.key_cmp(self)
    }
}

/// Deterministic discrete-event queue: pops strictly in `(time, node, seq)`
/// order, independent of insertion interleaving.
#[derive(Debug, Default)]
pub struct EventQueue<T> {
    heap: BinaryHeap<Event<T>>,
    seq: u64,
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }

    /// Schedule `payload` at virtual `time` on `node`; returns the assigned
    /// sequence number.
    pub fn schedule(&mut self, time: f64, node: usize, payload: T) -> u64 {
        assert!(time.is_finite(), "scheduled event at non-finite time");
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Event {
            time,
            node,
            seq,
            payload,
        });
        seq
    }

    /// Pop the earliest event, if any.
    pub fn pop(&mut self) -> Option<Event<T>> {
        self.heap.pop()
    }

    /// Fire time of the earliest pending event.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_set_advance_max() {
        let c = SimClock::new(3);
        assert_eq!(c.node_time(1), 0.0);
        c.set_node_time(1, 2.5);
        assert_eq!(c.node_time(1), 2.5);
        c.advance_node(1, 1.0); // no-op: behind
        assert_eq!(c.node_time(1), 2.5);
        c.advance_node(2, 4.0);
        assert_eq!(c.max_time(), 4.0);
        assert_eq!(c.nodes(), 3);
    }

    #[test]
    fn reserve_link_serializes_back_to_back_sends() {
        let c = SimClock::new(2);
        assert_eq!(c.link_free_time(0), 0.0);
        // idle link: transmission starts at the requested time
        let s1 = c.reserve_link(0, 1.0, 0.5);
        assert_eq!(s1, 1.0);
        assert_eq!(c.link_free_time(0), 1.5);
        // second send at the same node time queues behind the first
        let s2 = c.reserve_link(0, 1.0, 0.25);
        assert_eq!(s2, 1.5);
        assert_eq!(c.link_free_time(0), 1.75);
        // a later request on an idle link does not wait
        let s3 = c.reserve_link(0, 3.0, 0.1);
        assert_eq!(s3, 3.0);
        // other nodes' links are independent
        assert_eq!(c.link_free_time(1), 0.0);
        assert_eq!(c.reserve_link(1, 0.0, 1.0), 0.0);
    }

    #[test]
    fn queue_pops_in_time_order() {
        let mut q: EventQueue<&'static str> = EventQueue::new();
        q.schedule(3.0, 0, "c");
        q.schedule(1.0, 5, "a");
        q.schedule(2.0, 1, "b");
        assert_eq!(q.len(), 3);
        assert_eq!(q.peek_time(), Some(1.0));
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|e| e.payload)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
        assert!(q.is_empty());
    }

    #[test]
    fn ties_break_by_node_then_seq() {
        let mut q: EventQueue<u32> = EventQueue::new();
        q.schedule(1.0, 2, 20);
        q.schedule(1.0, 0, 0);
        q.schedule(1.0, 1, 11);
        q.schedule(1.0, 1, 12); // same time+node: seq decides
        let order: Vec<u32> = std::iter::from_fn(|| q.pop().map(|e| e.payload)).collect();
        assert_eq!(order, vec![0, 11, 12, 20]);
    }

    #[test]
    fn pop_order_independent_of_insertion_order() {
        let events = [(2.0, 1usize), (1.0, 3), (1.0, 0), (5.0, 2), (2.0, 0)];
        let mut forward: EventQueue<usize> = EventQueue::new();
        for (i, &(t, n)) in events.iter().enumerate() {
            forward.schedule(t, n, i);
        }
        let mut backward: EventQueue<usize> = EventQueue::new();
        for (i, &(t, n)) in events.iter().enumerate().rev() {
            backward.schedule(t, n, i);
        }
        let a: Vec<(usize, f64)> =
            std::iter::from_fn(|| forward.pop().map(|e| (e.node, e.time))).collect();
        let b: Vec<(usize, f64)> =
            std::iter::from_fn(|| backward.pop().map(|e| (e.node, e.time))).collect();
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn rejects_nan_times() {
        let mut q: EventQueue<()> = EventQueue::new();
        q.schedule(f64::NAN, 0, ());
    }
}
