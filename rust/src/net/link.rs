//! Link performance model: fixed latency + bandwidth-limited serialization,
//! `t(bits) = latency + bits / bandwidth`.
//!
//! Defaults model a 10 GbE datacenter link (the regime of Seide et al. and
//! the paper's motivation); presets for faster/slower fabrics let the
//! comm experiment sweep the crossover where compression stops mattering.

/// Per-link performance model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkModel {
    /// Bits per second.
    pub bandwidth_bps: f64,
    /// One-way latency in seconds.
    pub latency_s: f64,
}

/// How a node's outgoing messages share its physical link.
///
/// The historical fabric priced every send independently of the sender's
/// other sends — an infinite-fan-out NIC where a worker's S per-shard
/// pushes all overlap for free. [`Serialized`](LinkDiscipline::Serialized)
/// models the real constraint: one uplink per sender, transmissions
/// serialize FIFO, and a send begins at
/// `max(node_time, link_free_time)` (see `SimClock::reserve_link`).
/// Only the bandwidth term occupies the link — propagation latency
/// pipelines, so back-to-back frames pay it concurrently.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum LinkDiscipline {
    /// Every send departs at the sender's node time regardless of what
    /// else the sender has on the wire (the historical model, and the
    /// default: every existing timing identity holds under it).
    #[default]
    Overlapped,
    /// Sends from one node serialize on its uplink FIFO.
    Serialized,
}

impl LinkModel {
    pub fn new(bandwidth_bps: f64, latency_s: f64) -> Self {
        assert!(bandwidth_bps > 0.0);
        assert!(latency_s >= 0.0);
        LinkModel {
            bandwidth_bps,
            latency_s,
        }
    }

    /// 10 GbE with 50 µs latency (commodity datacenter, the paper's regime).
    pub fn ten_gbe() -> Self {
        LinkModel::new(10e9, 50e-6)
    }

    /// 1 GbE with 100 µs latency (the Strom-2015 commodity-cloud regime).
    pub fn one_gbe() -> Self {
        LinkModel::new(1e9, 100e-6)
    }

    /// 100 Gb InfiniBand-class link with 2 µs latency.
    pub fn infiniband() -> Self {
        LinkModel::new(100e9, 2e-6)
    }

    /// 100 Mbps WAN with 20 ms latency (geo-distributed / federated
    /// regime): latency dominates small frames, so this is where the
    /// compression × latency crossover of the staleness experiment lives.
    pub fn wan() -> Self {
        LinkModel::new(100e6, 20e-3)
    }

    /// Preset by name (the CLI's `--link` values).
    pub fn preset(name: &str) -> Option<Self> {
        Some(match name {
            "10gbe" | "ten_gbe" => LinkModel::ten_gbe(),
            "1gbe" | "one_gbe" => LinkModel::one_gbe(),
            "infiniband" | "ib" => LinkModel::infiniband(),
            "wan" => LinkModel::wan(),
            _ => return None,
        })
    }

    /// Transfer time for a message of `bits`.
    pub fn transfer_time(&self, bits: u64) -> f64 {
        self.latency_s + bits as f64 / self.bandwidth_bps
    }

    /// Time the sender's uplink is *occupied* transmitting `bits`: the
    /// bandwidth term only. Propagation latency pipelines — the next frame
    /// may start serializing while the previous one is still in flight —
    /// so under [`LinkDiscipline::Serialized`] this, not
    /// [`transfer_time`](Self::transfer_time), is what reserves the link.
    pub fn serialization_time(&self, bits: u64) -> f64 {
        bits as f64 / self.bandwidth_bps
    }
}

impl Default for LinkModel {
    fn default() -> Self {
        LinkModel::ten_gbe()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_formula() {
        let l = LinkModel::new(1e9, 1e-4);
        let t = l.transfer_time(1_000_000);
        assert!((t - (1e-4 + 1e-3)).abs() < 1e-12);
    }

    #[test]
    fn zero_bits_costs_latency() {
        let l = LinkModel::ten_gbe();
        assert_eq!(l.transfer_time(0), l.latency_s);
    }

    #[test]
    fn faster_link_is_faster() {
        let bits = 32 * 25_000_000u64; // 100 MB of gradients
        assert!(
            LinkModel::infiniband().transfer_time(bits) < LinkModel::ten_gbe().transfer_time(bits)
        );
        assert!(LinkModel::ten_gbe().transfer_time(bits) < LinkModel::one_gbe().transfer_time(bits));
        assert!(LinkModel::one_gbe().transfer_time(bits) < LinkModel::wan().transfer_time(bits));
    }

    #[test]
    fn wan_is_latency_dominated_for_small_frames() {
        // a scaled-sign frame of d=4096 is ~4 kbit: on the WAN preset the
        // 20 ms latency is >99% of the cost
        let l = LinkModel::wan();
        let t = l.transfer_time(4128);
        assert!(l.latency_s / t > 0.99, "latency share {}", l.latency_s / t);
    }

    #[test]
    fn serialization_time_is_the_bandwidth_term() {
        let l = LinkModel::new(1e9, 1e-4);
        assert!((l.serialization_time(1_000_000) - 1e-3).abs() < 1e-15);
        // transfer = latency + serialization, exactly
        assert_eq!(
            l.transfer_time(12345),
            l.latency_s + l.serialization_time(12345)
        );
        assert_eq!(l.serialization_time(0), 0.0);
    }

    #[test]
    fn discipline_defaults_to_overlapped() {
        assert_eq!(LinkDiscipline::default(), LinkDiscipline::Overlapped);
    }

    #[test]
    fn presets_resolve_by_name() {
        assert_eq!(LinkModel::preset("wan"), Some(LinkModel::wan()));
        assert_eq!(LinkModel::preset("10gbe"), Some(LinkModel::ten_gbe()));
        assert_eq!(LinkModel::preset("1gbe"), Some(LinkModel::one_gbe()));
        assert_eq!(LinkModel::preset("ib"), Some(LinkModel::infiniband()));
        assert_eq!(LinkModel::preset("dialup"), None);
    }
}
