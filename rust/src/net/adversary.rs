//! Adversarial (Byzantine) worker models: what a hostile worker puts on
//! the wire instead of its honest gradient frame.
//!
//! Ghosh et al. 2019 show the paper's error-feedback mechanism composes
//! with Byzantine-robust aggregation — the "millions of untrusted
//! clients" regime. These models live next to [`super::straggler`] and
//! follow the same determinism contract: which workers are Byzantine is
//! a pure function of `(seed, worker, n)`, and what a Byzantine worker
//! sends in a round is a pure function of `(seed, worker, round)` — one
//! independent [`Pcg64`] stream per cell, never call order — so any
//! `(shards, threads)` run of an adversarial schedule stays
//! bit-deterministic.
//!
//! Four models cover the Byzantine literature:
//!
//! * `signflip:F` — negate the frame's scale/norm field (dense/sparse:
//!   every value), so the worker pushes the exact opposite of its honest
//!   update. The classic sign-flip attack.
//! * `norminflate:F[:X]` — multiply the frame's norm/scale field by X
//!   (default 100): an honest *direction* at a hostile magnitude, the
//!   attack norm-thresholding exists for.
//! * `collude:F` — every Byzantine worker replaces its payload with the
//!   identical fixed-vector frame (same format, same shard slice), the
//!   coordinated attack that defeats naive outlier removal at high F.
//! * `randombytes:F` — overwrite the payload with arbitrary bytes from
//!   the cell RNG: garbage on the wire. Exercises the hardened decoders
//!   ([`crate::compress::wire::DecodeError`]); the leader must drop, not
//!   crash.
//!
//! `F` is the Byzantine fraction: `round(F · n)` of the `n` workers are
//! Byzantine, chosen by a seeded rank so membership is unbiased in the
//! worker id but still exact in count.

use crate::compress::wire::{self, Encoded, Format};
use crate::util::Pcg64;

/// Magnitude of every coordinate of the colluders' fixed vector.
const COLLUDE_MAG: f32 = 1.0;

/// What a Byzantine worker does to its frames (see module docs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AdversaryModel {
    /// No adversary (the honest engine, byte-identical to the pre-
    /// adversary wire path).
    None,
    SignFlip,
    NormInflate { factor: f64 },
    Collude,
    RandomBytes,
}

impl AdversaryModel {
    /// Parse a CLI spec `MODEL:FRACTION` into (model, fraction):
    /// `none`, `signflip:F`, `norminflate:F[:FACTOR]`, `collude:F`,
    /// `randombytes:F`.
    pub fn parse(s: &str) -> Option<(AdversaryModel, f64)> {
        let mut parts = s.split(':');
        let name = parts.next()?;
        if name == "none" {
            if parts.next().is_some() {
                return None;
            }
            return Some((AdversaryModel::None, 0.0));
        }
        let fraction: f64 = parts.next()?.parse().ok()?;
        if !(0.0..=1.0).contains(&fraction) {
            return None;
        }
        let model = match name {
            "signflip" => AdversaryModel::SignFlip,
            "norminflate" => {
                let factor = match parts.next() {
                    Some(p) => p.parse().ok()?,
                    None => 100.0,
                };
                AdversaryModel::NormInflate { factor }
            }
            "collude" => AdversaryModel::Collude,
            "randombytes" => AdversaryModel::RandomBytes,
            _ => return None,
        };
        if parts.next().is_some() {
            return None;
        }
        Some((model, fraction))
    }

    pub fn name(&self) -> &'static str {
        match self {
            AdversaryModel::None => "none",
            AdversaryModel::SignFlip => "signflip",
            AdversaryModel::NormInflate { .. } => "norminflate",
            AdversaryModel::Collude => "collude",
            AdversaryModel::RandomBytes => "randombytes",
        }
    }
}

/// A seeded adversary model with its Byzantine fraction: the engine's
/// per-(worker, round) corruption oracle.
#[derive(Clone, Debug)]
pub struct AdversarySchedule {
    pub model: AdversaryModel,
    /// Fraction of the `n` workers that are Byzantine (`round(F · n)`).
    pub fraction: f64,
    pub seed: u64,
}

impl AdversarySchedule {
    pub fn new(model: AdversaryModel, fraction: f64, seed: u64) -> Self {
        assert!(
            (0.0..=1.0).contains(&fraction),
            "adversary fraction must be in [0, 1]"
        );
        AdversarySchedule {
            model,
            fraction,
            seed,
        }
    }

    /// No adversaries: every frame passes through untouched.
    pub fn none() -> Self {
        AdversarySchedule::new(AdversaryModel::None, 0.0, 0)
    }

    /// Parse a full `MODEL:FRACTION` spec (see [`AdversaryModel::parse`]).
    pub fn parse_spec(s: &str, seed: u64) -> Option<Self> {
        let (model, fraction) = AdversaryModel::parse(s)?;
        Some(AdversarySchedule::new(model, fraction, seed))
    }

    /// Whether any corruption can happen under this schedule.
    pub fn is_active(&self) -> bool {
        self.model != AdversaryModel::None && self.fraction > 0.0
    }

    /// How many of `n` workers are Byzantine: `round(fraction · n)`.
    pub fn num_adversaries(&self, n: usize) -> usize {
        if !self.is_active() {
            return 0;
        }
        ((self.fraction * n as f64).round() as usize).min(n)
    }

    /// Whether `worker` (of `n`) is Byzantine. Membership is the seeded
    /// rank of the worker's draw — a pure function of `(seed, worker, n)`,
    /// unbiased in the id, exact in count, independent of call order.
    pub fn is_adversary(&self, worker: usize, n: usize) -> bool {
        let k = self.num_adversaries(n);
        if k == 0 || worker >= n {
            return false;
        }
        if k >= n {
            return true;
        }
        let mine = (self.member_draw(worker), worker);
        let rank = (0..n).filter(|&w| (self.member_draw(w), w) < mine).count();
        rank < k
    }

    /// Corrupt the frames `worker` is about to push in `round` (one per
    /// shard, in shard order), in place. A no-op for honest workers and
    /// under `none` — the bytes are untouched, which is what keeps
    /// `--adversary none` byte-identical to the pre-adversary engine.
    pub fn corrupt_frames(&self, worker: usize, round: u64, n: usize, frames: &mut [Encoded]) {
        if !self.is_active() || !self.is_adversary(worker, n) {
            return;
        }
        match self.model {
            AdversaryModel::None => {}
            AdversaryModel::SignFlip => {
                for e in frames.iter_mut() {
                    flip_frame_sign(e);
                }
            }
            AdversaryModel::NormInflate { factor } => {
                for e in frames.iter_mut() {
                    inflate_frame(e, factor as f32);
                }
            }
            AdversaryModel::Collude => {
                for e in frames.iter_mut() {
                    collude_frame(e);
                }
            }
            AdversaryModel::RandomBytes => {
                // one stream per (worker, round) cell; the frames are
                // scribbled in shard order, so the bytes are a pure
                // function of the cell, never of scheduling
                let mut rng = self.cell_rng(worker, round);
                for e in frames.iter_mut() {
                    for b in e.bytes.iter_mut() {
                        *b = rng.next_u32() as u8;
                    }
                }
            }
        }
    }

    /// Membership draw for one worker (round-independent).
    fn member_draw(&self, worker: usize) -> u64 {
        let mix = (worker as u64 + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        Pcg64::new(self.seed ^ 0xbad0_cab1_e5ca_1ab5, mix).next_u64()
    }

    fn cell_rng(&self, worker: usize, round: u64) -> Pcg64 {
        // one independent stream per (worker, round) cell, salted apart
        // from the straggler schedule's cells
        let mix = (worker as u64 + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        Pcg64::new(
            self.seed ^ 0xbad0_cab1_e5ca_1ab5 ^ round.wrapping_mul(0xd1b5_4a32_d192_ed03),
            mix ^ round,
        )
    }
}

impl Default for AdversarySchedule {
    fn default() -> Self {
        AdversarySchedule::none()
    }
}

/// Toggle the IEEE-754 sign bit of the little-endian f32 at `off`.
fn flip_f32_sign_at(bytes: &mut [u8], off: usize) {
    if let Some(b) = bytes.get_mut(off + 3) {
        *b ^= 0x80;
    }
}

/// Multiply the little-endian f32 at `off` by `factor`.
fn mul_f32_at(bytes: &mut [u8], off: usize, factor: f32) {
    if off + 4 > bytes.len() {
        return;
    }
    let v = f32::from_le_bytes([bytes[off], bytes[off + 1], bytes[off + 2], bytes[off + 3]]);
    bytes[off..off + 4].copy_from_slice(&(v * factor).to_le_bytes());
}

/// Byte offset of every sparse-pair value field: count u32, then
/// (u32 idx, f32 val) pairs — all byte-aligned.
fn sparse_value_offsets(bytes: &[u8]) -> impl Iterator<Item = usize> {
    let count = if bytes.len() >= 4 {
        u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]) as usize
    } else {
        0
    };
    (0..count).map(|p| 8 + 8 * p)
}

/// Sign-flip: negate the frame's scale/norm field (scaled-sign, ternary,
/// QSGD all lead with one f32), or every value (dense, sparse). The
/// decoded update is exactly the negation of the honest one.
fn flip_frame_sign(e: &mut Encoded) {
    match e.format {
        Format::DenseF32 => {
            for c in e.bytes.chunks_exact_mut(4) {
                c[3] ^= 0x80;
            }
        }
        Format::SignScaled | Format::Ternary | Format::Qsgd => flip_f32_sign_at(&mut e.bytes, 0),
        Format::SparseIdxVal => {
            for off in sparse_value_offsets(&e.bytes).collect::<Vec<_>>() {
                flip_f32_sign_at(&mut e.bytes, off);
            }
        }
    }
}

/// Norm-inflation: scale the frame's norm/scale field (or every value)
/// by `factor` — honest direction, hostile magnitude.
fn inflate_frame(e: &mut Encoded, factor: f32) {
    match e.format {
        Format::DenseF32 => {
            let n = e.bytes.len() / 4;
            for i in 0..n {
                mul_f32_at(&mut e.bytes, 4 * i, factor);
            }
        }
        Format::SignScaled | Format::Ternary | Format::Qsgd => mul_f32_at(&mut e.bytes, 0, factor),
        Format::SparseIdxVal => {
            for off in sparse_value_offsets(&e.bytes).collect::<Vec<_>>() {
                mul_f32_at(&mut e.bytes, off, factor);
            }
        }
    }
}

/// Collusion: re-encode the frame as the fixed all-[`COLLUDE_MAG`] vector
/// in the frame's own format and length, preserving the shard tag (the
/// routing header is in-process and stays honest — only the payload
/// lies). Every colluding worker pushes the identical frame.
fn collude_frame(e: &mut Encoded) {
    let tag = e.shard.take();
    let d = e.d;
    let v = vec![COLLUDE_MAG; d];
    match e.format {
        Format::DenseF32 => wire::encode_dense_into(&v, e),
        Format::SignScaled => wire::encode_scaled_sign_into(&v, e),
        Format::SparseIdxVal => wire::encode_sparse_into(&v, e),
        Format::Ternary => wire::encode_ternary_into(&v, e),
        Format::Qsgd => {
            // keep the frame's own level count (byte 4, byte-aligned
            // after the f32 norm; clamp a corrupt zero to 1) and quote
            // the coordinate magnitude as the norm so every level
            // saturates and the frame decodes to the vector exactly
            let s = e.bytes.get(4).copied().filter(|&s| s > 0).unwrap_or(4);
            wire::encode_qsgd_into(&v, COLLUDE_MAG, u32::from(s), e);
        }
    }
    if let Some(t) = tag {
        e.set_shard(t.shard, t.start);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_specs() {
        type M = AdversaryModel;
        let p = AdversaryModel::parse;
        assert_eq!(p("none"), Some((M::None, 0.0)));
        assert_eq!(p("signflip:0.25"), Some((M::SignFlip, 0.25)));
        assert_eq!(p("norminflate:0.125"), Some((M::NormInflate { factor: 100.0 }, 0.125)));
        assert_eq!(p("norminflate:0.5:8"), Some((M::NormInflate { factor: 8.0 }, 0.5)));
        assert_eq!(p("collude:0.375"), Some((M::Collude, 0.375)));
        assert_eq!(p("randombytes:1.0"), Some((M::RandomBytes, 1.0)));
        // missing fraction, out-of-range fraction, trailing junk, unknown
        assert_eq!(p("signflip"), None);
        assert_eq!(p("signflip:1.5"), None);
        assert_eq!(p("signflip:0.25:9"), None);
        assert_eq!(p("none:0.5"), None);
        assert_eq!(p("bogus:0.5"), None);
    }

    #[test]
    fn membership_is_exact_deterministic_and_order_free() {
        let s = AdversarySchedule::new(AdversaryModel::SignFlip, 0.25, 7);
        let n = 8;
        assert_eq!(s.num_adversaries(n), 2);
        let members: Vec<usize> = (0..n).filter(|&w| s.is_adversary(w, n)).collect();
        assert_eq!(members.len(), 2);
        // pure per-worker: re-query in any order, same answer
        for &w in members.iter().rev() {
            assert!(s.is_adversary(w, n));
        }
        // a different seed picks a (generally) different set, same count
        let s2 = AdversarySchedule::new(AdversaryModel::SignFlip, 0.25, 8);
        assert_eq!((0..n).filter(|&w| s2.is_adversary(w, n)).count(), 2);
        // inactive schedules have no members
        assert!(!AdversarySchedule::none().is_adversary(0, n));
        let zero = AdversarySchedule::new(AdversaryModel::SignFlip, 0.0, 7);
        assert!((0..n).all(|w| !zero.is_adversary(w, n)));
    }

    fn frame_of(format: Format) -> Encoded {
        let mut rng = Pcg64::seeded(3);
        let d = 67;
        let mut p = vec![0.0f32; d];
        rng.fill_normal(&mut p, 0.0, 1.0);
        match format {
            Format::DenseF32 => wire::encode_dense(&p),
            Format::SignScaled => wire::encode_scaled_sign(&p),
            Format::SparseIdxVal => {
                let mut v = vec![0.0f32; d];
                for i in (0..d).step_by(5) {
                    v[i] = p[i];
                }
                wire::encode_sparse(&v)
            }
            Format::Ternary => {
                let t: Vec<f32> = p
                    .iter()
                    .map(|x| {
                        if *x > 0.3 {
                            1.0
                        } else if *x < -0.3 {
                            -1.0
                        } else {
                            0.0
                        }
                    })
                    .collect();
                wire::encode_ternary(&t)
            }
            Format::Qsgd => {
                let norm = crate::tensor::norm2(&p) as f32;
                let q: Vec<f32> = p
                    .iter()
                    .map(|x| {
                        let l = (x.abs() / norm * 4.0).round().min(4.0);
                        x.signum() * norm * l / 4.0
                    })
                    .collect();
                wire::encode_qsgd(&q, norm, 4)
            }
        }
    }

    #[test]
    fn signflip_negates_the_decoded_update() {
        for format in [
            Format::DenseF32,
            Format::SignScaled,
            Format::SparseIdxVal,
            Format::Ternary,
            Format::Qsgd,
        ] {
            let honest = frame_of(format);
            let want = wire::decode_any(&honest).unwrap();
            let mut evil = honest.clone();
            flip_frame_sign(&mut evil);
            let got = wire::decode_any(&evil).unwrap();
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(*g, -*w, "{format:?}");
            }
        }
    }

    #[test]
    fn norminflate_scales_the_decoded_update() {
        for format in [
            Format::DenseF32,
            Format::SignScaled,
            Format::SparseIdxVal,
            Format::Ternary,
        ] {
            let honest = frame_of(format);
            let want = wire::decode_any(&honest).unwrap();
            let mut evil = honest.clone();
            inflate_frame(&mut evil, 4.0);
            let got = wire::decode_any(&evil).unwrap();
            for (g, w) in got.iter().zip(&want) {
                assert!((g - 4.0 * w).abs() <= 4.0 * w.abs() * 1e-6, "{format:?}");
            }
        }
    }

    #[test]
    fn colluders_send_identical_decodable_frames() {
        let mut a = frame_of(Format::SignScaled).with_shard(2, 64);
        let mut b = frame_of(Format::Qsgd);
        // mutate b so the honest frames differ, then collude both
        b.bytes[6] ^= 0xff;
        collude_frame(&mut a);
        let mut a2 = frame_of(Format::SignScaled).with_shard(2, 64);
        collude_frame(&mut a2);
        assert_eq!(a.bytes, a2.bytes, "collusion is frame-independent");
        assert_eq!(a.shard, a2.shard, "shard tag preserved");
        collude_frame(&mut b);
        let dec = wire::decode_any(&b).unwrap();
        assert_eq!(dec.len(), b.d);
        for x in &dec {
            assert!((x - COLLUDE_MAG).abs() < 1e-6);
        }
    }

    #[test]
    fn corruption_is_per_cell_deterministic_and_none_is_identity() {
        let s = AdversarySchedule::new(AdversaryModel::RandomBytes, 1.0, 5);
        let n = 4;
        let mut f1 = vec![frame_of(Format::SignScaled), frame_of(Format::Ternary)];
        let mut f2 = f1.clone();
        s.corrupt_frames(1, 9, n, &mut f1);
        // interleave another cell, then repeat the first — same bytes
        let mut other = vec![frame_of(Format::SignScaled)];
        s.corrupt_frames(0, 3, n, &mut other);
        s.corrupt_frames(1, 9, n, &mut f2);
        assert_eq!(f1[0].bytes, f2[0].bytes);
        assert_eq!(f1[1].bytes, f2[1].bytes);
        // none / honest workers leave bytes untouched
        let honest = frame_of(Format::SignScaled);
        let mut passthrough = vec![honest.clone()];
        AdversarySchedule::none().corrupt_frames(0, 0, n, &mut passthrough);
        assert_eq!(passthrough[0].bytes, honest.bytes);
    }
}
