//! Command-line parsing for the `repro` binary.
//!
//! clap is unavailable offline; this is a small positional+flag parser with
//! subcommands, `--key value` / `--key=value` options, and generated help.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, positional args, and options.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, Vec<String>>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some(eq) = rest.find('=') {
                    let (k, v) = rest.split_at(eq);
                    out.options
                        .entry(k.to_string())
                        .or_default()
                        .push(v[1..].to_string());
                } else if iter
                    .peek()
                    .map_or(false, |n| !n.starts_with("--"))
                    && takes_value(rest)
                {
                    let v = iter.next().unwrap();
                    out.options.entry(rest.to_string()).or_default().push(v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn opt_all(&self, key: &str) -> &[String] {
        self.options.get(key).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn opt_usize(&self, key: &str) -> Option<usize> {
        self.opt(key).and_then(|v| v.parse().ok())
    }

    pub fn opt_f64(&self, key: &str) -> Option<f64> {
        self.opt(key).and_then(|v| v.parse().ok())
    }
}

/// Options that take a following value (everything else with no `=` is a
/// boolean flag). Kept as an explicit list so `repro exp fig3 --quick` works.
fn takes_value(key: &str) -> bool {
    matches!(
        key,
        "config"
            | "set"
            | "out"
            | "model"
            | "workers"
            | "threads"
            | "steps"
            | "lr"
            | "seed"
            | "seeds"
            | "compressor"
            | "batch"
            | "artifacts"
            | "k-frac"
            | "levels"
            | "repeats"
            | "filter"
            | "quorum"
            | "max-staleness"
            | "straggler"
            | "compute-ms"
            | "link"
            | "leader-cost"
            | "shards"
            | "aggregation"
            | "adversary"
            | "churn"
            | "trace"
            | "metrics-out"
    )
}

pub const USAGE: &str = "\
repro — Error Feedback Fixes SignSGD (ICML 2019) reproduction

USAGE:
    repro <SUBCOMMAND> [OPTIONS]

SUBCOMMANDS:
    train        Run distributed training via the PJRT runtime
                 (--config configs/<f>.toml, --set k=v overrides, --quick)
    exp <id>     Run a paper experiment: ce1 ce2 ce3 thm1 fig2 fig3 fig4
                 fig5 fig7 table2 rem5 comm lemma3 ablation staleness
                 byzantine churn all
                 (--quick for reduced sizes, --out results/ for CSV/JSON)
    artifacts    Print the artifact manifest summary
    list         List available experiments
    help         Show this help

COMMON OPTIONS:
    --quick              Reduced problem sizes (CI)
    --out <dir>          Write CSV/JSON results (default: results/)
    --seed <n>           Base RNG seed
    --threads <n>        Worker-pool threads for `train` (default 1;
                         results are bit-identical for any value)
    --shards <s>         Parameter-server shards: the model vector splits
                         into s contiguous blocks, each with its own
                         leader node (default 1 = the single-leader
                         engine, byte-identical to the unsharded driver)
    --artifacts <dir>    Artifact directory (default: artifacts)

ASYNC TRAINING (train):
    --async              Bounded-staleness rounds over the virtual clock
    --quorum <k>         Fold once k worker frames arrive (default: all)
    --max-staleness <s>  Frames may fold up to s rounds late (default 0;
                         with --quorum n this reproduces sync bit-for-bit)
    --straggler <m>      constant | uniform[:J] | lognormal[:SIGMA] |
                         failslow:NODE[:FACTOR]   (default constant)
    --compute-ms <t>     Base per-step compute time on the virtual clock
    --link <preset>      Fabric link: 10gbe | 1gbe | ib | wan
    --link-serialized    Serialize each sender's uplink: frames from one
                         node queue FIFO on its link (transmission starts
                         at max(node time, link free time)) instead of
                         overlapping; trained bits are unchanged, only
                         sim_time_s moves. See docs/WIRE.md
    --leader-cost <m>    Leader decode pricing: measured (wall-clock
                         profile, default) | calibrated (analytic
                         per-coordinate model — sim_time_s becomes a pure
                         function of the seeded models, machine-independent)
    --toy                Train on the toy quadratic (no PJRT artifacts)

ROBUSTNESS (train):
    --adversary <m>      Byzantine worker model: none |
                         signflip:FRAC | norminflate:FRAC[:FACTOR] |
                         collude:FRAC | randombytes:FRAC
                         (round(FRAC·n) seeded hostile workers; default none)
    --aggregation <a>    Leader combine rule: mean | majority_vote |
                         median | trimmed[:K] | norm_threshold
                         (default mean; the robust rules tolerate
                         Byzantine frames, see docs/ROBUSTNESS.md)
    --churn <spec>       Elastic-membership schedule: none, or a
                         comma-separated list of leave:W@R | crash:W@R |
                         rejoin:W@R | join:W@R — worker W transitions at
                         the start of round R (crash loses the EF
                         residual, leave parks it for a warm rejoin;
                         default none). See docs/ASYNC.md

OBSERVABILITY (train):
    --trace <file>       Record the run's flight-recorder events (sim-time
                         stamped, one track per worker / shard leader /
                         driver) and export Chrome trace-event JSON; open
                         in Perfetto or chrome://tracing. Also prints a
                         compact text timeline. See docs/OBSERVABILITY.md
    --metrics-out <file> Write the end-of-run RunReport JSON (traffic,
                         staleness, leader profile + the metrics registry:
                         frame bits by format, decode latency, staleness,
                         drops, EF residual norms); Prometheus text lands
                         alongside with a .prom extension
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_positional() {
        let a = parse("exp fig3 --quick");
        assert_eq!(a.subcommand.as_deref(), Some("exp"));
        assert_eq!(a.positional, vec!["fig3"]);
        assert!(a.flag("quick"));
    }

    #[test]
    fn options_with_equals_and_space() {
        let a = parse("train --config=configs/a.toml --workers 8 --set training.lr=0.1");
        assert_eq!(a.opt("config"), Some("configs/a.toml"));
        assert_eq!(a.opt_usize("workers"), Some(8));
        assert_eq!(a.opt_all("set"), &["training.lr=0.1".to_string()]);
    }

    #[test]
    fn repeated_set() {
        let a = parse("train --set a=1 --set b=2");
        assert_eq!(a.opt_all("set").len(), 2);
    }

    #[test]
    fn unknown_dashed_is_flag() {
        let a = parse("bench --verbose next");
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["next"]);
    }
}
