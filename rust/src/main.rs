//! `repro`: the leader entrypoint. Subcommands: train (PJRT-backed
//! distributed training), exp (paper experiments), artifacts, list.

use anyhow::{anyhow, bail, Context, Result};
use ef_sgd::cli::{Args, USAGE};
use ef_sgd::config::{CompressorKind, ConfigMap, TrainConfig};
use ef_sgd::coordinator::driver::{DriverConfig, TrainDriver, UpdateRule};
use ef_sgd::coordinator::worker::{GradSource, ObjectiveSource, Worker, WorkerMode};
use ef_sgd::coordinator::{
    Aggregation, AsyncTrainDriver, DecodeCostModel, LrSchedule, TrainOutcome,
};
use ef_sgd::data::tokens::MarkovCorpus;
use ef_sgd::experiments::{self, ExpContext};
use ef_sgd::metrics::sparkline;
use ef_sgd::model::toy::SparseNoiseQuadratic;
use ef_sgd::net::{
    AdversarySchedule, LinkDiscipline, LinkModel, MembershipSchedule, StragglerModel,
    StragglerSchedule,
};
use ef_sgd::obs::RunMetrics;
use ef_sgd::runtime::{LmSession, Runtime};
use ef_sgd::util::Pcg64;
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn main() {
    ef_sgd::logging::init();
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(args),
        Some("exp") => cmd_exp(args),
        Some("artifacts") => cmd_artifacts(args),
        Some("list") => {
            println!("experiments: {}", experiments::ALL.join(" "));
            Ok(())
        }
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown subcommand '{other}'\n{USAGE}"),
    }
}

fn exp_context(args: &Args) -> ExpContext {
    ExpContext {
        quick: args.flag("quick"),
        seed: args.opt_usize("seed").unwrap_or(0) as u64,
        out_dir: PathBuf::from(args.opt("out").unwrap_or("results")),
        artifacts_dir: PathBuf::from(args.opt("artifacts").unwrap_or("artifacts")),
    }
}

fn cmd_exp(args: &Args) -> Result<()> {
    let ctx = exp_context(args);
    let id = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    if id == "all" {
        for id in experiments::ALL {
            let t = std::time::Instant::now(); // detlint: allow(D2) — CLI wall-time report
            experiments::run(id, &ctx)?;
            log::info!("experiment {id} done in {:.1}s", t.elapsed().as_secs_f64());
        }
    } else {
        experiments::run(id, &ctx)?;
    }
    println!("\nresults written to {}", ctx.out_dir.display());
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.opt("artifacts").unwrap_or("artifacts"));
    let manifest = ef_sgd::runtime::Manifest::load(&dir).map_err(|e| anyhow!("{e}"))?;
    for cfg in &manifest.configs {
        println!(
            "config {:<8} d={:<9} vocab={:<6} dim={:<5} layers={} seq={} batch={}",
            cfg.name, cfg.d, cfg.vocab, cfg.dim, cfg.layers, cfg.seq, cfg.batch
        );
        for a in &cfg.artifacts {
            println!(
                "  {:<24} {:<28} in:{} out:{}",
                a.name,
                a.file,
                a.inputs.len(),
                a.outputs.len()
            );
        }
    }
    Ok(())
}

/// A GradSource backed by the PJRT LM session. Each worker shares the
/// compiled session (Arc, so workers can live on pool threads) but owns
/// its token stream (its data shard).
struct LmWorkerSource {
    session: Arc<LmSession>,
    corpus: Arc<MarkovCorpus>,
    rng: Pcg64,
    eval_rng: Pcg64,
}

impl GradSource for LmWorkerSource {
    fn dim(&self) -> usize {
        self.session.d()
    }

    fn grad(&mut self, theta: &[f32], out: &mut [f32]) -> f64 {
        let (b, s) = self.session.model.token_shape();
        let tokens = self.corpus.sample_batch(b, s, &mut self.rng);
        let (loss, grad) = self.session.train_step(theta, &tokens).expect("lm step");
        out.copy_from_slice(&grad);
        loss
    }

    fn eval_loss(&mut self, theta: &[f32]) -> f64 {
        let (b, s) = self.session.model.token_shape();
        let tokens = self.corpus.sample_batch(b, s, &mut self.eval_rng);
        self.session.eval(theta, &tokens).unwrap_or(f64::NAN)
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    // config file + --set overrides + a few direct flags
    let mut map = if let Some(path) = args.opt("config") {
        ConfigMap::load(Path::new(path)).context("load config")?
    } else {
        ConfigMap::default()
    };
    for kv in args.opt_all("set") {
        map.set_kv(kv).map_err(|e| anyhow!("{e}"))?;
    }
    let mut cfg = TrainConfig::from_map(&map).map_err(|e| anyhow!("{e}"))?;
    if let Some(m) = args.opt("model") {
        cfg.model = m.to_string();
    }
    if let Some(w) = args.opt_usize("workers") {
        cfg.workers = w;
    }
    if let Some(t) = args.opt_usize("threads") {
        cfg.threads = t;
    }
    if let Some(s) = args.opt_usize("shards") {
        if s == 0 {
            bail!("--shards must be >= 1");
        }
        cfg.shards = s;
    }
    if let Some(s) = args.opt_usize("steps") {
        cfg.steps = s;
    }
    if let Some(lr) = args.opt_f64("lr") {
        cfg.lr = lr;
    }
    if let Some(c) = args.opt("compressor") {
        cfg.compressor =
            CompressorKind::parse(c).ok_or_else(|| anyhow!("bad compressor '{c}'"))?;
    }
    if args.flag("async") {
        cfg.async_mode = true;
    }
    if let Some(q) = args.opt_usize("quorum") {
        cfg.quorum = q;
    }
    if let Some(s) = args.opt_usize("max-staleness") {
        cfg.max_staleness = s as u64;
    }
    if let Some(m) = args.opt("straggler") {
        cfg.straggler = m.to_string();
    }
    if let Some(a) = args.opt("adversary") {
        cfg.adversary = a.to_string();
    }
    if let Some(c) = args.opt("churn") {
        cfg.churn = c.to_string();
    }
    if let Some(a) = args.opt("aggregation") {
        cfg.aggregation = a.to_string();
    }
    if let Some(c) = args.opt_f64("compute-ms") {
        cfg.compute_ms = c;
    }
    if let Some(l) = args.opt("link") {
        cfg.link = l.to_string();
    }
    if args.flag("link-serialized") {
        cfg.link_serialized = true;
    }
    if let Some(c) = args.opt("leader-cost") {
        cfg.leader_cost = c.to_string();
    }
    if args.flag("quick") {
        cfg.steps = cfg.steps.min(20);
    }
    // flight recorder + metrics registry: both off unless requested, so
    // untraced runs carry zero observability cost
    let trace_path = args.opt("trace").map(|s| s.to_string());
    let metrics_path = args.opt("metrics-out").map(|s| s.to_string());
    let metrics = metrics_path
        .as_ref()
        .map(|_| Arc::new(RunMetrics::new(cfg.workers)));

    log::info!(
        "train: model={} workers={} threads={} shards={} steps={} lr={} compressor={} ef={} async={}",
        cfg.model,
        cfg.workers,
        cfg.threads,
        cfg.shards,
        cfg.steps,
        cfg.lr,
        cfg.compressor.name(),
        cfg.error_feedback,
        cfg.async_mode
    );

    let mode = match (cfg.compressor, cfg.error_feedback) {
        (CompressorKind::None, _) => WorkerMode::DenseGrad,
        (_, true) => WorkerMode::ErrorFeedback,
        (_, false) => WorkerMode::PlainCompress,
    };
    let mk_worker = |id: usize, source: Box<dyn GradSource>, cfg: &TrainConfig| {
        Worker::new(
            id,
            source,
            mode,
            cfg.compressor,
            cfg.k_frac,
            cfg.qsgd_levels,
            Pcg64::new(cfg.seed, id as u64),
        )
    };
    // --toy trains on the Appendix A.1 quadratic: no PJRT artifacts
    // needed, which is what the CI smoke invocations use
    let (workers, theta0): (Vec<Worker>, Vec<f32>) = if args.flag("toy") {
        let d = 4096;
        let workers = (0..cfg.workers)
            .map(|id| {
                let src = Box::new(ObjectiveSource::new(
                    SparseNoiseQuadratic::new(d, 1.0),
                    Pcg64::new(cfg.seed, 1000 + id as u64),
                ));
                mk_worker(id, src, &cfg)
            })
            .collect();
        (workers, vec![1.0f32; d])
    } else {
        let rt = Runtime::load(Path::new(&cfg.artifacts_dir)).context(
            "loading artifacts (run `make artifacts` first, pass --artifacts <dir>, \
             or use --toy for the artifact-free quadratic)",
        )?;
        let session = Arc::new(LmSession::open(&rt, &cfg.model)?);
        let theta0 = rt.init_params(&session.model).map_err(|e| anyhow!("{e}"))?;
        let corpus = Arc::new(MarkovCorpus::new(session.model.vocab, 4, cfg.seed));
        let workers = (0..cfg.workers)
            .map(|id| {
                let src = Box::new(LmWorkerSource {
                    session: session.clone(),
                    corpus: corpus.clone(),
                    rng: Pcg64::new(cfg.seed, 1000 + id as u64),
                    eval_rng: Pcg64::new(cfg.seed, 5000 + id as u64),
                });
                mk_worker(id, src, &cfg)
            })
            .collect();
        (workers, theta0)
    };

    let update_rule = if mode == WorkerMode::DenseGrad {
        UpdateRule::ServerMomentum {
            beta_millis: (cfg.momentum * 1000.0) as u32,
        }
    } else {
        UpdateRule::ApplyAggregate
    };
    // the typed parse errors print the offending token plus the accepted
    // grammar, so a CLI typo is self-explaining
    let straggler_model = StragglerModel::parse(&cfg.straggler).map_err(|e| anyhow!("{e}"))?;
    let adversary = AdversarySchedule::parse_spec(&cfg.adversary, cfg.seed)
        .ok_or_else(|| anyhow!("bad adversary spec '{}'", cfg.adversary))?;
    if adversary.is_active() {
        log::info!(
            "adversary: {}:{} — {} of {} workers Byzantine",
            adversary.model.name(),
            adversary.fraction,
            adversary.num_adversaries(cfg.workers),
            cfg.workers
        );
    }
    let membership = MembershipSchedule::parse(&cfg.churn).map_err(|e| anyhow!("{e}"))?;
    if membership.is_active() {
        membership
            .validate(cfg.workers)
            .map_err(|e| anyhow!("bad churn schedule: {e}"))?;
        log::info!(
            "churn: {membership} — {} membership event(s) over a fleet of {}",
            membership.events().len(),
            cfg.workers
        );
    }
    let link = LinkModel::preset(&cfg.link)
        .ok_or_else(|| anyhow!("unknown link preset '{}'", cfg.link))?;
    let leader_cost = match cfg.leader_cost.as_str() {
        "measured" => DecodeCostModel::none(),
        "calibrated" => DecodeCostModel::calibrated(),
        other => bail!("bad leader-cost '{other}' (expected 'measured' or 'calibrated')"),
    };
    let dcfg = DriverConfig {
        steps: cfg.steps,
        schedule: LrSchedule::new(cfg.lr, cfg.steps, cfg.lr_decay_at.clone()),
        aggregation: Aggregation::parse(&cfg.aggregation)
            .ok_or_else(|| anyhow!("bad aggregation '{}'", cfg.aggregation))?,
        update_rule,
        weight_decay: cfg.weight_decay as f32,
        link,
        discipline: if cfg.link_serialized {
            LinkDiscipline::Serialized
        } else {
            LinkDiscipline::Overlapped
        },
        leader_cost,
        straggler: StragglerSchedule::new(cfg.compute_ms * 1e-3, straggler_model, cfg.seed),
        adversary,
        membership,
        threads: cfg.threads.max(1),
        shards: cfg.shards.max(1),
        log_every: cfg.log_every.max(1),
        eval_every: cfg.eval_every,
        trace_capacity: if trace_path.is_some() { cfg.trace_ring } else { 0 },
        metrics: metrics.clone(),
        ..Default::default()
    };
    let outcome: TrainOutcome = if cfg.async_mode {
        AsyncTrainDriver::new(dcfg, cfg.quorum, cfg.max_staleness, workers, theta0).run()
    } else {
        TrainDriver::new(dcfg, workers, theta0).run()
    };

    let losses = &outcome.recorder.get("train_loss").unwrap().values;
    println!("\n== training summary ==");
    println!("  rounds:        {}", outcome.rounds);
    println!("  sim time:      {:.4} s (virtual clock)", outcome.sim_time_s);
    // report the *effective* shard count (the plan clamps --shards to
    // 1..=min(d, 65535)), read back from the per-shard profile
    println!(
        "  leader:        {:.4} ms/round decode+agg critical path over {} shard(s)",
        outcome.profile.mean_critical_s() * 1e3,
        outcome.profile.per_shard_s.len().max(1)
    );
    if cfg.async_mode {
        println!(
            "  staleness:     mean {:.2} rounds, {:.1}% stale frames, mean batch {:.1}/{} (quorum {}, bound {})",
            outcome.staleness.mean_staleness(),
            100.0 * outcome.staleness.stale_fraction(),
            outcome.staleness.mean_batch(),
            cfg.workers,
            if cfg.quorum == 0 { cfg.workers } else { cfg.quorum },
            cfg.max_staleness
        );
    }
    println!(
        "  loss:          {:.4} -> {:.4}   {}",
        losses.first().unwrap(),
        losses.last().unwrap(),
        sparkline(losses, 50)
    );
    println!(
        "  gradient push: {:.3} Mbit ({} compression)",
        outcome.traffic.bits_of_kind(ef_sgd::net::MessageKind::GradPush) as f64 / 1e6,
        cfg.compressor.name()
    );
    // per-kind bit totals and the drop counter, always printed (the
    // traffic summary below only lists kinds that carried traffic)
    println!("  dropped:       {} frame(s)", outcome.traffic.dropped());
    println!("{}", outcome.traffic.summary());

    if let Some(path) = &trace_path {
        let recorder = outcome
            .trace
            .as_ref()
            .expect("trace_capacity > 0 must produce a recorder");
        std::fs::write(path, recorder.to_chrome_json(false).to_string_compact())
            .with_context(|| format!("write trace {path}"))?;
        println!("\n== flight recorder ==");
        println!(
            "  {} event(s) on {} track(s) -> {} (Perfetto / chrome://tracing)",
            recorder.total_events(),
            recorder.num_tracks(),
            path
        );
        println!("{}", recorder.text_timeline(16));
    }
    if let Some(path) = &metrics_path {
        let report = ef_sgd::obs::run_report(&outcome, metrics.as_deref());
        std::fs::write(path, report.to_string_compact())
            .with_context(|| format!("write metrics {path}"))?;
        let prom_path = Path::new(path).with_extension("prom");
        if let Some(m) = &metrics {
            std::fs::write(&prom_path, m.to_prometheus())
                .with_context(|| format!("write {}", prom_path.display()))?;
        }
        println!(
            "run report written to {path} (Prometheus text: {})",
            prom_path.display()
        );
    }

    // persist the run
    let out = PathBuf::from(args.opt("out").unwrap_or("results"));
    std::fs::create_dir_all(&out)?;
    outcome
        .recorder
        .write_csv(&out.join(format!("train_{}_{}.csv", cfg.model, cfg.compressor.name())))?;
    println!("metrics written to {}", out.display());
    Ok(())
}
