//! Minimal `log` backend writing timestamped lines to stderr.
//! Level comes from `RUST_LOG` (error|warn|info|debug|trace), default info.

use log::{Level, LevelFilter, Metadata, Record};
use std::time::Instant;

struct StderrLogger {
    start: Instant,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:9.3}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

/// Install the logger once; safe to call multiple times.
pub fn init() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        let level = match std::env::var("RUST_LOG").as_deref() {
            Ok("error") => LevelFilter::Error,
            Ok("warn") => LevelFilter::Warn,
            Ok("info") => LevelFilter::Info,
            Ok("debug") => LevelFilter::Debug,
            Ok("trace") => LevelFilter::Trace,
            Ok(other) => {
                // one-time (we're inside the Once): a typo'd level should
                // not silently read as "info"
                eprintln!("warning: unrecognized RUST_LOG level '{other}', defaulting to info");
                LevelFilter::Info
            }
            Err(_) => LevelFilter::Info,
        };
        let logger = Box::leak(Box::new(StderrLogger {
            start: Instant::now(), // detlint: allow(D2) — log timestamps are wall-clock by design
        }));
        let _ = log::set_logger(logger);
        log::set_max_level(level);
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging test line");
    }
}
