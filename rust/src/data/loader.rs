//! Batching and per-worker sharding: each worker sees a disjoint shard of
//! the training set (data parallelism); batch order is a seeded shuffle so
//! runs are exactly reproducible and each worker's stream is independent.

use super::synth_class::Dataset;
use crate::tensor::Matrix;
use crate::util::Pcg64;

/// Splits a dataset into `w` contiguous shards after a seeded shuffle.
pub struct Sharder {
    pub shards: Vec<Dataset>,
}

impl Sharder {
    pub fn new(data: &Dataset, workers: usize, rng: &mut Pcg64) -> Self {
        assert!(workers >= 1);
        let perm = rng.permutation(data.len());
        let per = data.len() / workers;
        assert!(per >= 1, "more workers than examples");
        let mut shards = Vec::with_capacity(workers);
        for w in 0..workers {
            let lo = w * per;
            let hi = if w + 1 == workers { data.len() } else { lo + per };
            let rows: Vec<Vec<f32>> = perm[lo..hi]
                .iter()
                .map(|&i| data.x.row(i).to_vec())
                .collect();
            let y: Vec<usize> = perm[lo..hi].iter().map(|&i| data.y[i]).collect();
            shards.push(Dataset::new(Matrix::from_rows(rows), y, data.classes));
        }
        Sharder { shards }
    }

    pub fn workers(&self) -> usize {
        self.shards.len()
    }
}

/// An epoch-shuffling minibatch index iterator over one shard.
pub struct BatchIter {
    order: Vec<usize>,
    pos: usize,
    batch: usize,
    rng: Pcg64,
}

impl BatchIter {
    pub fn new(n: usize, batch: usize, rng: Pcg64) -> Self {
        assert!(batch >= 1 && n >= 1);
        let mut it = BatchIter {
            order: (0..n).collect(),
            pos: 0,
            batch,
            rng,
        };
        it.reshuffle();
        it
    }

    fn reshuffle(&mut self) {
        self.rng.shuffle(&mut self.order);
        self.pos = 0;
    }

    /// Next minibatch of indices; reshuffles at epoch boundaries.
    pub fn next_batch(&mut self) -> Vec<usize> {
        if self.pos + self.batch > self.order.len() {
            self.reshuffle();
        }
        let b = self.order[self.pos..self.pos + self.batch.min(self.order.len())].to_vec();
        self.pos += self.batch;
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_class::{generate, SynthSpec};

    #[test]
    fn shards_partition_dataset() {
        let mut rng = Pcg64::seeded(0);
        let (train, _) = generate(&SynthSpec::tiny(), &mut rng);
        let sharder = Sharder::new(&train, 4, &mut rng);
        assert_eq!(sharder.workers(), 4);
        let total: usize = sharder.shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, train.len());
        // classes preserved
        for s in &sharder.shards {
            assert_eq!(s.classes, train.classes);
        }
    }

    #[test]
    fn batch_iter_covers_epoch() {
        let mut it = BatchIter::new(10, 3, Pcg64::seeded(1));
        let mut seen = std::collections::HashSet::new();
        for _ in 0..3 {
            for i in it.next_batch() {
                seen.insert(i);
            }
        }
        assert!(seen.len() >= 9); // 3 batches of 3 from a 10-elem epoch
        for i in &seen {
            assert!(*i < 10);
        }
    }

    #[test]
    fn batch_iter_deterministic() {
        let mut a = BatchIter::new(20, 4, Pcg64::seeded(2));
        let mut b = BatchIter::new(20, 4, Pcg64::seeded(2));
        for _ in 0..10 {
            assert_eq!(a.next_batch(), b.next_batch());
        }
    }

    #[test]
    #[should_panic(expected = "more workers than examples")]
    fn too_many_workers_panics() {
        let mut rng = Pcg64::seeded(3);
        let (train, _) = generate(&SynthSpec::tiny(), &mut rng);
        let _ = Sharder::new(&train, train.len() + 1, &mut rng);
    }
}
