//! The Wilson et al. (2017) §3.3 construction, as specified in the paper's
//! Appendix A.6: n = 200 examples of dimension d = 6n, labels y ∈ {−1,1}
//! uniform, and
//!
//! ```text
//! A[i, 1] = y_i,  A[i, 2] = A[i, 3] = 1,
//! A[i, 4+5(i-1) .. 4+5(i-1)+2(1-y_i)] = 1,   all else 0    (1-indexed)
//! ```
//!
//! so each example has a label-revealing first coordinate, two shared
//! coordinates, and 1 or 5 unique "memorization" coordinates depending on
//! the label. Gradient-span methods provably generalize here; methods that
//! leave the span (SIGNSGD) memorize via the unique coordinates and fail on
//! the test split.

use crate::tensor::Matrix;
use crate::util::Pcg64;

/// A generated problem, split into train and test halves.
pub struct WilsonData {
    pub train_a: Matrix,
    pub train_y: Vec<f32>,
    pub test_a: Matrix,
    pub test_y: Vec<f32>,
    pub d: usize,
}

/// Generate with the paper's sizes by default: n = 200, d = 6n.
pub fn generate(n: usize, rng: &mut Pcg64) -> WilsonData {
    let d = 6 * n;
    let mut rows = Vec::with_capacity(n);
    let mut ys = Vec::with_capacity(n);
    for i in 0..n {
        let y: f32 = if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
        let mut row = vec![0.0f32; d];
        // paper indices are 1-based; translate to 0-based.
        row[0] = y;
        row[1] = 1.0;
        row[2] = 1.0;
        let start = 3 + 5 * i;
        let count = 1 + 2 * (1 - y as i32) as usize; // y=+1 -> 1, y=-1 -> 5
        for j in 0..count {
            if start + j < d {
                row[start + j] = 1.0;
            }
        }
        rows.push(row);
        ys.push(y);
    }
    // random equal split into train/test
    let perm = rng.permutation(n);
    let half = n / 2;
    let take = |idx: &[usize]| {
        let a = Matrix::from_rows(idx.iter().map(|&i| rows[i].clone()).collect());
        let y: Vec<f32> = idx.iter().map(|&i| ys[i]).collect();
        (a, y)
    };
    let (train_a, train_y) = take(&perm[..half]);
    let (test_a, test_y) = take(&perm[half..]);
    WilsonData {
        train_a,
        train_y,
        test_a,
        test_y,
        d,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_paper() {
        let mut rng = Pcg64::seeded(0);
        let w = generate(200, &mut rng);
        assert_eq!(w.d, 1200);
        assert_eq!(w.train_a.rows, 100);
        assert_eq!(w.test_a.rows, 100);
        assert_eq!(w.train_a.cols, 1200);
    }

    #[test]
    fn row_structure() {
        let mut rng = Pcg64::seeded(1);
        let n = 20;
        let w = generate(n, &mut rng);
        for (r, &y) in (0..w.train_a.rows).zip(&w.train_y) {
            let row = w.train_a.row(r);
            assert_eq!(row[0], y);
            assert_eq!(row[1], 1.0);
            assert_eq!(row[2], 1.0);
            let unique: usize = row[3..].iter().map(|v| *v as usize).sum();
            if y > 0.0 {
                assert_eq!(unique, 1, "positive label has 1 unique coord");
            } else {
                assert_eq!(unique, 5, "negative label has 5 unique coords");
            }
        }
    }

    #[test]
    fn unique_blocks_disjoint() {
        let mut rng = Pcg64::seeded(2);
        let n = 50;
        let w = generate(n, &mut rng);
        // Across ALL examples (train+test), each column beyond 2 is used by
        // at most one example.
        let mut col_use = vec![0usize; w.d];
        for a in [&w.train_a, &w.test_a] {
            for r in 0..a.rows {
                for (c, v) in a.row(r).iter().enumerate().skip(3) {
                    if *v != 0.0 {
                        col_use[c] += 1;
                    }
                }
            }
        }
        assert!(col_use.iter().all(|&u| u <= 1));
    }

    #[test]
    fn labels_are_plus_minus_one() {
        let mut rng = Pcg64::seeded(3);
        let w = generate(30, &mut rng);
        for y in w.train_y.iter().chain(&w.test_y) {
            assert!(*y == 1.0 || *y == -1.0);
        }
    }
}
