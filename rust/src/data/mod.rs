//! Data generation and loading.
//!
//! * [`wilson`] — the Wilson et al. (2017) over-parameterized construction
//!   the paper uses for its §5.2 generalization simulation (Appendix A.6).
//! * [`synth_class`] — the synthetic "CIFAR-like" classification substitute
//!   for the §6 deep-net experiments (teacher-MLP labels + noise).
//! * [`tokens`] — synthetic token streams for the end-to-end transformer
//!   run (Markov-chain corpus with learnable structure).
//! * [`loader`] — batching and per-worker sharding.

pub mod loader;
pub mod synth_class;
pub mod tokens;
pub mod wilson;

pub use loader::Sharder;
pub use synth_class::Dataset;
