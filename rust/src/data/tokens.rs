//! Token streams for the end-to-end LM run: a synthetic corpus with
//! learnable structure (an order-2 Markov chain over the byte vocabulary,
//! seeded deterministically), so the transformer's loss curve has headroom
//! to drop well below the uniform log(V) baseline.

use crate::util::Pcg64;

/// A deterministic order-2 Markov token source.
pub struct MarkovCorpus {
    vocab: usize,
    /// transition[a*vocab + b] = distribution over next token (CDF form).
    cdf: Vec<Vec<f64>>,
}

impl MarkovCorpus {
    /// Build a random sparse transition structure: each (a,b) context
    /// concentrates mass on a few successor tokens (entropy well below
    /// log2(vocab)), so a 2-layer transformer can learn it.
    pub fn new(vocab: usize, branching: usize, seed: u64) -> Self {
        assert!(vocab >= 2);
        let branching = branching.clamp(1, vocab);
        let mut rng = Pcg64::seeded(seed);
        let mut cdf = Vec::with_capacity(vocab * vocab);
        for _ in 0..vocab * vocab {
            let succs = rng.sample_indices(vocab, branching);
            let mut weights = vec![0.02f64; vocab]; // smoothing mass
            for (rank, &s) in succs.iter().enumerate() {
                weights[s] += 1.0 / (1.0 + rank as f64) * branching as f64;
            }
            // to CDF
            let total: f64 = weights.iter().sum();
            let mut acc = 0.0;
            let c: Vec<f64> = weights
                .iter()
                .map(|w| {
                    acc += w / total;
                    acc
                })
                .collect();
            cdf.push(c);
        }
        MarkovCorpus { vocab, cdf }
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    fn next_token(&self, a: usize, b: usize, rng: &mut Pcg64) -> usize {
        let c = &self.cdf[a * self.vocab + b];
        let u = rng.uniform();
        match c.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => (i + 1).min(self.vocab - 1),
            Err(i) => i.min(self.vocab - 1),
        }
    }

    /// Sample a (batch, seq_plus_1) token block. Each row is an independent
    /// chain started from a random context.
    pub fn sample_batch(&self, batch: usize, seq_plus_1: usize, rng: &mut Pcg64) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * seq_plus_1);
        for _ in 0..batch {
            let mut a = rng.below(self.vocab);
            let mut b = rng.below(self.vocab);
            out.push(a as i32);
            if seq_plus_1 > 1 {
                out.push(b as i32);
            }
            for _ in 2..seq_plus_1 {
                let c = self.next_token(a, b, rng);
                out.push(c as i32);
                a = b;
                b = c;
            }
        }
        out
    }

    /// Empirical per-token entropy (nats) of the chain, estimated from the
    /// stationary behaviour — the floor the LM loss should approach.
    pub fn entropy_estimate(&self, samples: usize, rng: &mut Pcg64) -> f64 {
        let mut total = 0.0f64;
        let mut count = 0usize;
        let mut a = rng.below(self.vocab);
        let mut b = rng.below(self.vocab);
        for _ in 0..samples {
            let c = &self.cdf[a * self.vocab + b];
            let nxt = self.next_token(a, b, rng);
            let p = if nxt == 0 { c[0] } else { c[nxt] - c[nxt - 1] };
            total -= p.max(1e-12).ln();
            count += 1;
            a = b;
            b = nxt;
        }
        total / count as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shape_and_range() {
        let corpus = MarkovCorpus::new(64, 3, 0);
        let mut rng = Pcg64::seeded(1);
        let batch = corpus.sample_batch(4, 33, &mut rng);
        assert_eq!(batch.len(), 4 * 33);
        assert!(batch.iter().all(|&t| (0..64).contains(&t)));
    }

    #[test]
    fn entropy_below_uniform() {
        let vocab = 64;
        let corpus = MarkovCorpus::new(vocab, 3, 0);
        let mut rng = Pcg64::seeded(2);
        let h = corpus.entropy_estimate(20_000, &mut rng);
        let uniform = (vocab as f64).ln();
        assert!(h < 0.75 * uniform, "H={h} vs uniform {uniform}");
        assert!(h > 0.1, "chain should not be deterministic, H={h}");
    }

    #[test]
    fn deterministic_structure_per_seed() {
        let a = MarkovCorpus::new(16, 2, 5);
        let b = MarkovCorpus::new(16, 2, 5);
        let mut r1 = Pcg64::seeded(9);
        let mut r2 = Pcg64::seeded(9);
        assert_eq!(a.sample_batch(2, 10, &mut r1), b.sample_batch(2, 10, &mut r2));
    }

    #[test]
    fn different_contexts_differ() {
        // sanity: the transition table is not constant
        let corpus = MarkovCorpus::new(16, 2, 3);
        let distinct: std::collections::HashSet<String> = (0..16 * 16)
            .map(|i| format!("{:?}", corpus.cdf[i].iter().map(|v| (v * 100.0) as i64).collect::<Vec<_>>()))
            .collect();
        assert!(distinct.len() > 50);
    }
}
