//! Synthetic "CIFAR-like" classification data: the §6 substitute.
//!
//! A fixed random teacher MLP assigns labels to gaussian inputs drawn from
//! class-dependent cluster mixtures; a label-noise fraction makes the task
//! non-separable so that over-fitting is possible and generalization gaps
//! are measurable (the phenomenon Tables 1/3/4 quantify). Two presets
//! mirror the paper's two settings: `cifar100_like` (harder: more classes,
//! lower accuracy scale, like Resnet18/CIFAR-100) and `cifar10_like`
//! (easier, higher accuracy scale, like VGG19/CIFAR-10).

use crate::tensor::Matrix;
use crate::util::Pcg64;

/// An in-memory classification dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub x: Matrix,
    pub y: Vec<usize>,
    pub classes: usize,
}

impl Dataset {
    pub fn new(x: Matrix, y: Vec<usize>, classes: usize) -> Self {
        assert_eq!(x.rows, y.len());
        assert!(y.iter().all(|&c| c < classes));
        Dataset { x, y, classes }
    }

    pub fn len(&self) -> usize {
        self.x.rows
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dim(&self) -> usize {
        self.x.cols
    }
}

/// Generation parameters.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub dim: usize,
    pub classes: usize,
    pub train_n: usize,
    pub test_n: usize,
    /// Clusters per class (input structure).
    pub clusters_per_class: usize,
    /// Within-cluster noise std.
    pub spread: f64,
    /// Fraction of labels resampled uniformly (task noise).
    pub label_noise: f64,
}

impl SynthSpec {
    /// Harder setting (the CIFAR-100/Resnet18 analog): 20 classes, tighter
    /// margins, 10% label noise → accuracy scale ~70-80%.
    pub fn cifar100_like() -> Self {
        SynthSpec {
            dim: 32,
            classes: 20,
            train_n: 2000,
            test_n: 1000,
            clusters_per_class: 2,
            spread: 0.85,
            label_noise: 0.10,
        }
    }

    /// Easier setting (the CIFAR-10/VGG19 analog): 10 classes, wider
    /// margins, 2% label noise → accuracy scale ~90%+.
    pub fn cifar10_like() -> Self {
        SynthSpec {
            dim: 32,
            classes: 10,
            train_n: 2000,
            test_n: 1000,
            clusters_per_class: 2,
            spread: 0.55,
            label_noise: 0.02,
        }
    }

    /// Tiny setting for unit tests.
    pub fn tiny() -> Self {
        SynthSpec {
            dim: 8,
            classes: 4,
            train_n: 120,
            test_n: 60,
            clusters_per_class: 1,
            spread: 0.4,
            label_noise: 0.0,
        }
    }
}

/// Generate (train, test) with a shared cluster structure.
pub fn generate(spec: &SynthSpec, rng: &mut Pcg64) -> (Dataset, Dataset) {
    // class-cluster centers on a shell of radius ~sqrt(dim)*0.5
    let ncenters = spec.classes * spec.clusters_per_class;
    let mut centers = Vec::with_capacity(ncenters);
    for _ in 0..ncenters {
        let mut c = vec![0.0f32; spec.dim];
        rng.fill_normal(&mut c, 0.0, 1.0);
        let norm = crate::tensor::norm2(&c).max(1e-9);
        let radius = 0.5 * (spec.dim as f64).sqrt();
        for v in c.iter_mut() {
            *v = (*v as f64 / norm * radius) as f32;
        }
        centers.push(c);
    }

    let mut make = |n: usize| {
        let mut rows = Vec::with_capacity(n);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let class = rng.below(spec.classes);
            let cluster = rng.below(spec.clusters_per_class);
            let center = &centers[class * spec.clusters_per_class + cluster];
            let mut x = vec![0.0f32; spec.dim];
            rng.fill_normal(&mut x, 0.0, spec.spread);
            crate::tensor::add_assign(&mut x, center);
            let label = if rng.bernoulli(spec.label_noise) {
                rng.below(spec.classes)
            } else {
                class
            };
            rows.push(x);
            labels.push(label);
        }
        Dataset::new(Matrix::from_rows(rows), labels, spec.classes)
    };

    let train = make(spec.train_n);
    let test = make(spec.test_n);
    (train, test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_label_ranges() {
        let mut rng = Pcg64::seeded(0);
        let spec = SynthSpec::tiny();
        let (train, test) = generate(&spec, &mut rng);
        assert_eq!(train.len(), 120);
        assert_eq!(test.len(), 60);
        assert_eq!(train.dim(), 8);
        assert!(train.y.iter().all(|&c| c < 4));
    }

    #[test]
    fn deterministic_for_seed() {
        let spec = SynthSpec::tiny();
        let (a, _) = generate(&spec, &mut Pcg64::seeded(7));
        let (b, _) = generate(&spec, &mut Pcg64::seeded(7));
        assert_eq!(a.x.data, b.x.data);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn classes_are_distinguishable() {
        // A nearest-centroid rule on the training set should beat chance
        // comfortably on the test set (structure exists to be learned).
        let mut rng = Pcg64::seeded(1);
        let spec = SynthSpec::tiny();
        let (train, test) = generate(&spec, &mut rng);
        // class centroids from train
        let mut centroids = vec![vec![0.0f64; spec.dim]; spec.classes];
        let mut counts = vec![0usize; spec.classes];
        for i in 0..train.len() {
            counts[train.y[i]] += 1;
            for (c, v) in centroids[train.y[i]].iter_mut().zip(train.x.row(i)) {
                *c += *v as f64;
            }
        }
        for (c, n) in centroids.iter_mut().zip(&counts) {
            for v in c.iter_mut() {
                *v /= (*n).max(1) as f64;
            }
        }
        let mut correct = 0;
        for i in 0..test.len() {
            let x = test.x.row(i);
            let mut best = (f64::INFINITY, 0usize);
            for (k, c) in centroids.iter().enumerate() {
                let d: f64 = x
                    .iter()
                    .zip(c)
                    .map(|(a, b)| (*a as f64 - b).powi(2))
                    .sum();
                if d < best.0 {
                    best = (d, k);
                }
            }
            if best.1 == test.y[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.len() as f64;
        assert!(acc > 0.6, "nearest-centroid acc {acc} should beat chance 0.25");
    }

    #[test]
    fn cifar100_like_is_harder_than_cifar10_like() {
        let a = SynthSpec::cifar100_like();
        let b = SynthSpec::cifar10_like();
        assert!(a.classes > b.classes);
        assert!(a.spread > b.spread);
        assert!(a.label_noise > b.label_noise);
    }
}
