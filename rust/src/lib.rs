//! `ef-sgd`: a full-system reproduction of *Error Feedback Fixes SignSGD and
//! other Gradient Compression Schemes* (Karimireddy, Rebjock, Stich, Jaggi —
//! ICML 2019).
//!
//! The crate is the Layer-3 Rust coordinator of a three-layer stack:
//!
//! * **L3 (this crate)** — distributed data-parallel training coordinator:
//!   leader/worker topology over a simulated network fabric with exact bit
//!   accounting, collectives (ring all-reduce, parameter server, majority
//!   vote), per-worker error-feedback state, compression codecs, native
//!   reference models, and the paper's full experiment suite.
//! * **L2** — a JAX transformer LM (`python/compile/model.py`), AOT-lowered
//!   to HLO-text artifacts executed through [`runtime`] (PJRT CPU client).
//! * **L1** — Pallas kernels for the fused EF-sign compression step
//!   (`python/compile/kernels/`), lowered into the same artifacts.
//!
//! Python never runs on the training path: after `make artifacts`, the
//! `repro` binary is self-contained.
//!
//! Quickstart (see `examples/quickstart.rs`):
//!
//! ```no_run
//! use ef_sgd::compress::{Compressor, ScaledSign};
//! use ef_sgd::optim::{EfSgd, Optimizer};
//!
//! let mut opt = EfSgd::new(2, 0.1, Box::new(ScaledSign));
//! let mut x = vec![1.0f32, -2.0];
//! let g = vec![0.3f32, 0.1];
//! opt.step(&mut x, &g);
//! ```

pub mod bench;
pub mod cli;
pub mod collectives;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod linalg;
pub mod logging;
pub mod metrics;
pub mod model;
pub mod net;
pub mod obs;
pub mod optim;
pub mod propcheck;
pub mod runtime;
pub mod tensor;
pub mod util;
