//! Sharded parameter server: the model vector is partitioned into `S`
//! contiguous coordinate shards, each owned by its own leader node on the
//! fabric. Workers push one wire frame **per shard** (tagged with the
//! shard id + start coordinate, see `compress::wire::ShardTag`), shard
//! leaders decode and aggregate only their slice, and the broadcast comes
//! back as per-shard parameter slices that workers reassemble.
//!
//! This breaks the single-aggregator bottleneck of the classic
//! majority-vote/EF parameter server (Bernstein et al. 2018; Seide et al.
//! 2014): the leader-side decode+aggregate cost becomes
//! `max`-over-shards instead of the full-vector total. Blockwise error
//! feedback (Zheng et al. 2019) makes the worker side partition cleanly —
//! each shard carries its own compressor state, EF residual, and norms.
//!
//! # Determinism contract
//!
//! * The split points of [`ShardPlan`] are a pure function of `(d, S)`.
//! * Shard leaders sort their gathers by worker id and reduce with the
//!   same fixed worker-id grouping as the unsharded leader, so any
//!   `(shards, threads)` combination is bit-deterministic.
//! * With `S = 1` the topology, payloads, and bit accounting are exactly
//!   the historical single-leader parameter server: frames carry no shard
//!   tag and the broadcast is one dense `Params` message per worker.
//!
//! See `docs/SHARDING.md` for the full topology and timing model.

use crate::compress::wire::Encoded;
use crate::net::{Fabric, Message, MessageKind, Payload};
use std::ops::Range;

/// Deterministic partition of `d` coordinates into `S` contiguous shards.
/// Split points are balanced: the first `d % S` shards get `⌈d/S⌉`
/// coordinates, the rest `⌊d/S⌋` — a pure function of `(d, S)`, so every
/// node (and every restart) derives the identical plan.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    d: usize,
    /// `S + 1` monotone split points; `bounds[0] = 0`, `bounds[S] = d`.
    bounds: Vec<usize>,
}

impl ShardPlan {
    /// Build the plan for `d` coordinates over `shards` leaders. Clamped
    /// to `1..=min(d, u16::MAX)`: every shard owns at least one
    /// coordinate, and every shard id fits the wire tag's 16-bit field
    /// (so per-shard accounting can never alias through truncation).
    pub fn new(d: usize, shards: usize) -> Self {
        assert!(d > 0, "empty model vector");
        let s = shards.clamp(1, d).min(u16::MAX as usize);
        let base = d / s;
        let rem = d % s;
        let mut bounds = Vec::with_capacity(s + 1);
        bounds.push(0);
        let mut at = 0usize;
        for i in 0..s {
            at += base + usize::from(i < rem);
            bounds.push(at);
        }
        debug_assert_eq!(*bounds.last().unwrap(), d);
        ShardPlan { d, bounds }
    }

    /// The degenerate single-shard plan (the unsharded topology).
    pub fn single(d: usize) -> Self {
        ShardPlan::new(d, 1)
    }

    /// Total model dimension.
    pub fn dim(&self) -> usize {
        self.d
    }

    pub fn num_shards(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Coordinate range of shard `s` in the full model vector.
    pub fn range(&self, s: usize) -> Range<usize> {
        self.bounds[s]..self.bounds[s + 1]
    }

    /// Number of coordinates owned by shard `s`.
    pub fn len_of(&self, s: usize) -> usize {
        self.bounds[s + 1] - self.bounds[s]
    }

    /// Start coordinate of shard `s`.
    pub fn start(&self, s: usize) -> usize {
        self.bounds[s]
    }
}

/// Typed gather failure: which shard saw what, instead of an
/// `assert_eq!` abort deep in the hot path. Async and sharded callers can
/// surface (or recover from) the exact mismatch.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum GatherError {
    /// A frame from `src` carried round `got` instead of `expected`.
    Stale {
        shard: usize,
        src: usize,
        expected: u64,
        got: u64,
    },
    /// Fewer gradient frames than workers arrived for this shard's round.
    Missing {
        shard: usize,
        expected: usize,
        got: usize,
    },
}

impl std::fmt::Display for GatherError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GatherError::Stale {
                shard,
                src,
                expected,
                got,
            } => write!(
                f,
                "stale message in PS gather: shard {shard} expected round {expected}, \
                 got round {got} from worker {src}"
            ),
            GatherError::Missing {
                shard,
                expected,
                got,
            } => write!(
                f,
                "missing worker gradients: shard {shard} gathered {got} of {expected} frames"
            ),
        }
    }
}

impl std::error::Error for GatherError {}

/// The multi-leader parameter-server topology: workers `0..n`, one leader
/// node per shard at `n..n+S` (ascending by shard id; with `S = 1` the
/// leader is node `n`, exactly the historical convention). `Clone` so each
/// worker-pool thread can hold its own copy of the (cheap) topology.
#[derive(Clone, Debug)]
pub struct ShardedParameterServer {
    pub plan: ShardPlan,
    /// Fabric node id of each shard's leader, indexed by shard.
    pub leaders: Vec<usize>,
    pub workers: Vec<usize>,
}

impl ShardedParameterServer {
    /// Derive the topology from the fabric size: the last
    /// `plan.num_shards()` nodes are the shard leaders, the rest workers.
    pub fn new(fabric: &Fabric, plan: ShardPlan) -> Self {
        let s = plan.num_shards();
        let n = fabric.nodes();
        assert!(n >= s + 1, "need at least 1 worker + {s} shard leaders");
        ShardedParameterServer {
            leaders: (n - s..n).collect(),
            workers: (0..n - s).collect(),
            plan,
        }
    }

    pub fn num_shards(&self) -> usize {
        self.plan.num_shards()
    }

    /// Worker side: push one round's per-shard frames (in shard order) to
    /// their shard leaders. With `S = 1` this is a single untagged frame
    /// to the single leader — byte-identical to the unsharded push.
    pub fn push_frames(&self, fabric: &Fabric, worker: usize, round: u64, frames: Vec<Encoded>) {
        assert_eq!(frames.len(), self.num_shards(), "one frame per shard");
        for (s, frame) in frames.into_iter().enumerate() {
            fabric.send(Message {
                src: worker,
                dst: self.leaders[s],
                round,
                kind: MessageKind::GradPush,
                payload: Payload::Grad(frame),
            });
        }
    }

    /// Leader side: send one worker its parameters — a single dense
    /// `Params` message when unsharded (byte-identical to the historical
    /// driver), one `ParamSlice` per shard leader otherwise. Returns the
    /// latest simulated arrival over the slices.
    pub fn send_params(&self, fabric: &Fabric, worker: usize, round: u64, params: &[f32]) -> f64 {
        assert_eq!(params.len(), self.plan.dim());
        if self.num_shards() == 1 {
            return fabric.send(Message {
                src: self.leaders[0],
                dst: worker,
                round,
                kind: MessageKind::ParamBroadcast,
                payload: Payload::Params(params.to_vec()),
            });
        }
        let mut latest = 0.0f64;
        for s in 0..self.num_shards() {
            let r = self.plan.range(s);
            let arrival = fabric.send(Message {
                src: self.leaders[s],
                dst: worker,
                round,
                kind: MessageKind::ParamBroadcast,
                payload: Payload::ParamSlice {
                    shard: s as u16,
                    start: r.start as u32,
                    vals: params[r].to_vec(),
                },
            });
            latest = latest.max(arrival);
        }
        latest
    }

    /// Leader side: broadcast the parameters to every worker. Returns the
    /// latest simulated arrival over all recipients and slices.
    pub fn broadcast_params(&self, fabric: &Fabric, round: u64, params: &[f32]) -> f64 {
        let mut latest = 0.0f64;
        for &w in &self.workers {
            latest = latest.max(self.send_params(fabric, w, round, params));
        }
        latest
    }

    /// Worker side: receive one round's parameters into `buf`, assembling
    /// per-shard slices when sharded. Returns `false` if the broadcast is
    /// missing from the worker's inbox.
    pub fn recv_params_into(&self, fabric: &Fabric, worker: usize, buf: &mut Vec<f32>) -> bool {
        let s_total = self.num_shards();
        if s_total == 1 {
            while let Some(msg) = fabric.recv(worker) {
                if let Payload::Params(p) = msg.payload {
                    *buf = p;
                    return true;
                }
            }
            return false;
        }
        buf.resize(self.plan.dim(), 0.0);
        // track distinct shards, not message counts: a duplicated slice
        // must not mask a missing one (the hole would silently keep the
        // previous round's values in a reused buffer)
        let mut seen = vec![false; s_total];
        let mut got = 0usize;
        while got < s_total {
            let Some(msg) = fabric.recv(worker) else {
                return false;
            };
            if let Payload::ParamSlice { shard, start, vals } = msg.payload {
                let shard = shard as usize;
                assert!(
                    shard < s_total && !seen[shard],
                    "duplicate or out-of-range parameter slice for shard {shard}"
                );
                seen[shard] = true;
                let start = start as usize;
                buf[start..start + vals.len()].copy_from_slice(&vals);
                got += 1;
            }
        }
        true
    }

    /// Leader side: drain shard `s`'s inbox for `round`. Returns the
    /// gathered frames sorted by worker id together with the latest
    /// simulated arrival, or a typed [`GatherError`] naming the shard and
    /// the mismatched round/count.
    pub fn gather_shard_timed(
        &self,
        fabric: &Fabric,
        round: u64,
        s: usize,
    ) -> Result<(Vec<Encoded>, f64), GatherError> {
        let mut msgs = fabric.recv_all_timed(self.leaders[s]);
        msgs.sort_by_key(|(m, _)| m.src);
        let mut frames = Vec::with_capacity(self.workers.len());
        let mut latest = 0.0f64;
        for (msg, arrival) in msgs {
            if msg.round != round {
                return Err(GatherError::Stale {
                    shard: s,
                    src: msg.src,
                    expected: round,
                    got: msg.round,
                });
            }
            if let Payload::Grad(e) = msg.payload {
                // tagged frames must agree with the leader they landed on
                // (untagged single-shard frames carry no tag to check)
                if let Some(tag) = e.shard {
                    assert_eq!(
                        tag.shard as usize, s,
                        "frame routed to the wrong shard leader"
                    );
                }
                frames.push(e);
                latest = latest.max(arrival);
            }
        }
        if frames.len() != self.workers.len() {
            return Err(GatherError::Missing {
                shard: s,
                expected: self.workers.len(),
                got: frames.len(),
            });
        }
        Ok((frames, latest))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::wire::{encode_dense, encode_scaled_sign};
    use crate::net::LinkModel;

    #[test]
    fn plan_partition_is_contiguous_complete_and_balanced() {
        for (d, s) in [(10, 1), (10, 3), (97, 4), (64, 8), (5, 8), (1, 1)] {
            let plan = ShardPlan::new(d, s);
            let eff = plan.num_shards();
            assert!(eff <= s && eff >= 1 && eff <= d);
            assert_eq!(plan.start(0), 0);
            assert_eq!(plan.range(eff - 1).end, d);
            let mut total = 0usize;
            for i in 0..eff {
                let r = plan.range(i);
                assert_eq!(r.start, plan.start(i));
                assert_eq!(r.len(), plan.len_of(i));
                total += r.len();
                if i > 0 {
                    assert_eq!(plan.range(i - 1).end, r.start, "gap at shard {i}");
                }
                // balanced: sizes differ by at most one
                assert!(r.len() >= d / eff && r.len() <= d / eff + 1);
            }
            assert_eq!(total, d);
        }
        // same (d, S) always derives the same plan
        assert_eq!(ShardPlan::new(97, 4), ShardPlan::new(97, 4));
        assert_eq!(ShardPlan::single(12), ShardPlan::new(12, 1));
        // shard ids must fit the 16-bit wire tag: the plan clamps there
        let wide = ShardPlan::new(100_000, 70_000);
        assert_eq!(wide.num_shards(), u16::MAX as usize);
    }

    #[test]
    fn sharded_roundtrip_push_gather_broadcast() {
        let plan = ShardPlan::new(6, 2);
        // 2 workers + 2 shard leaders
        let fabric = Fabric::new(4, LinkModel::default());
        let ps = ShardedParameterServer::new(&fabric, plan);
        assert_eq!(ps.workers, vec![0, 1]);
        assert_eq!(ps.leaders, vec![2, 3]);

        // broadcast slices reassemble on the worker
        let params: Vec<f32> = (0..6).map(|i| i as f32).collect();
        ps.broadcast_params(&fabric, 0, &params);
        for w in 0..2 {
            let mut buf = Vec::new();
            assert!(ps.recv_params_into(&fabric, w, &mut buf));
            assert_eq!(buf, params);
        }

        // per-shard push lands on the right leader, sorted gather works
        for w in 0..2usize {
            let v: Vec<f32> = (0..6).map(|i| (w * 10 + i) as f32).collect();
            let frames: Vec<Encoded> = (0..2)
                .map(|s| {
                    let r = ps.plan.range(s);
                    encode_dense(&v[r.clone()]).with_shard(s as u16, r.start as u32)
                })
                .collect();
            ps.push_frames(&fabric, w, 3, frames);
        }
        for s in 0..2 {
            let (frames, _latest) = ps.gather_shard_timed(&fabric, 3, s).unwrap();
            assert_eq!(frames.len(), 2);
            assert!(frames.iter().all(|e| e.d == 3));
            assert!(frames
                .iter()
                .all(|e| e.shard.map(|t| t.shard as usize) == Some(s)));
        }
    }

    #[test]
    fn gather_reports_stale_and_missing_with_shard_context() {
        let plan = ShardPlan::new(4, 2);
        let fabric = Fabric::new(3, LinkModel::default()); // 1 worker + 2 leaders
        let ps = ShardedParameterServer::new(&fabric, plan);
        // wrong round on shard 1
        ps.push_frames(
            &fabric,
            0,
            7,
            vec![
                encode_scaled_sign(&[1.0, -1.0]).with_shard(0, 0),
                encode_scaled_sign(&[1.0, -1.0]).with_shard(1, 2),
            ],
        );
        let err = ps.gather_shard_timed(&fabric, 8, 1).unwrap_err();
        assert_eq!(
            err,
            GatherError::Stale {
                shard: 1,
                src: 0,
                expected: 8,
                got: 7
            }
        );
        assert!(err.to_string().contains("shard 1"));
        // nothing pushed on a fresh fabric => Missing with counts
        let fabric2 = Fabric::new(3, LinkModel::default());
        let ps2 = ShardedParameterServer::new(&fabric2, ShardPlan::new(4, 2));
        let err = ps2.gather_shard_timed(&fabric2, 8, 0).unwrap_err();
        assert_eq!(
            err,
            GatherError::Missing {
                shard: 0,
                expected: 1,
                got: 0
            }
        );
        assert!(err.to_string().contains("0 of 1"));
    }

    #[test]
    fn single_shard_degenerates_to_the_classic_topology() {
        let plan = ShardPlan::single(8);
        let fabric = Fabric::new(4, LinkModel::default()); // 3 workers + leader
        let ps = ShardedParameterServer::new(&fabric, plan);
        assert_eq!(ps.leaders, vec![3]);
        assert_eq!(ps.workers, vec![0, 1, 2]);
        let params = vec![0.5f32; 8];
        ps.send_params(&fabric, 1, 0, &params);
        // the unsharded broadcast is a plain dense Params payload
        let msg = fabric.recv(1).unwrap();
        match msg.payload {
            Payload::Params(p) => assert_eq!(p, params),
            other => panic!("expected Params, got {other:?}"),
        }
    }
}
