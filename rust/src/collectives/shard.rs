//! Sharded parameter server: the model vector is partitioned into `S`
//! contiguous coordinate shards, each owned by its own leader node on the
//! fabric. Workers push one wire frame **per shard** (tagged with the
//! shard id + start coordinate, see `compress::wire::ShardTag`), shard
//! leaders decode and aggregate only their slice, and the broadcast comes
//! back as per-shard parameter slices that workers reassemble.
//!
//! This breaks the single-aggregator bottleneck of the classic
//! majority-vote/EF parameter server (Bernstein et al. 2018; Seide et al.
//! 2014): the leader-side decode+aggregate cost becomes
//! `max`-over-shards instead of the full-vector total. Blockwise error
//! feedback (Zheng et al. 2019) makes the worker side partition cleanly —
//! each shard carries its own compressor state, EF residual, and norms.
//!
//! # Determinism contract
//!
//! * The split points of [`ShardPlan`] are a pure function of `(d, S)`.
//! * Shard leaders sort their gathers by worker id and reduce with the
//!   same fixed worker-id grouping as the unsharded leader, so any
//!   `(shards, threads)` combination is bit-deterministic.
//! * With `S = 1` the topology, payloads, and bit accounting are exactly
//!   the historical single-leader parameter server: frames carry no shard
//!   tag and the broadcast is one dense `Params` message per worker.
//!
//! See `docs/SHARDING.md` for the full topology and timing model.

use crate::compress::wire::Encoded;
use crate::net::{Fabric, Message, MessageKind, Payload};
use crate::obs::trace::{DropReason, EventKind};
use std::ops::Range;
use std::sync::Arc;

/// Deterministic partition of `d` coordinates into `S` contiguous shards.
/// Split points are balanced: the first `d % S` shards get `⌈d/S⌉`
/// coordinates, the rest `⌊d/S⌋` — a pure function of `(d, S)`, so every
/// node (and every restart) derives the identical plan.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    d: usize,
    /// `S + 1` monotone split points; `bounds[0] = 0`, `bounds[S] = d`.
    bounds: Vec<usize>,
}

impl ShardPlan {
    /// Build the plan for `d` coordinates over `shards` leaders. Clamped
    /// to `1..=min(d, u16::MAX)`: every shard owns at least one
    /// coordinate, and every shard id fits the wire tag's 16-bit field
    /// (so per-shard accounting can never alias through truncation).
    pub fn new(d: usize, shards: usize) -> Self {
        assert!(d > 0, "empty model vector");
        let s = shards.clamp(1, d).min(u16::MAX as usize);
        let base = d / s;
        let rem = d % s;
        let mut bounds = Vec::with_capacity(s + 1);
        bounds.push(0);
        let mut at = 0usize;
        for i in 0..s {
            at += base + usize::from(i < rem);
            bounds.push(at);
        }
        debug_assert_eq!(*bounds.last().unwrap(), d);
        ShardPlan { d, bounds }
    }

    /// The degenerate single-shard plan (the unsharded topology).
    pub fn single(d: usize) -> Self {
        ShardPlan::new(d, 1)
    }

    /// Total model dimension.
    pub fn dim(&self) -> usize {
        self.d
    }

    pub fn num_shards(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Coordinate range of shard `s` in the full model vector.
    pub fn range(&self, s: usize) -> Range<usize> {
        self.bounds[s]..self.bounds[s + 1]
    }

    /// Number of coordinates owned by shard `s`.
    pub fn len_of(&self, s: usize) -> usize {
        self.bounds[s + 1] - self.bounds[s]
    }

    /// Start coordinate of shard `s`.
    pub fn start(&self, s: usize) -> usize {
        self.bounds[s]
    }
}

/// Typed gather failure: which shard saw what, instead of an
/// `assert_eq!` abort deep in the hot path. Async and sharded callers can
/// surface (or recover from) the exact mismatch.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum GatherError {
    /// A frame from `src` carried round `got` instead of `expected`.
    Stale {
        shard: usize,
        src: usize,
        expected: u64,
        got: u64,
    },
    /// Fewer gradient frames than workers arrived for this shard's round.
    Missing {
        shard: usize,
        expected: usize,
        got: usize,
    },
}

impl std::fmt::Display for GatherError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GatherError::Stale {
                shard,
                src,
                expected,
                got,
            } => write!(
                f,
                "stale message in PS gather: shard {shard} expected round {expected}, \
                 got round {got} from worker {src}"
            ),
            GatherError::Missing {
                shard,
                expected,
                got,
            } => write!(
                f,
                "missing worker gradients: shard {shard} gathered {got} of {expected} frames"
            ),
        }
    }
}

impl std::error::Error for GatherError {}

/// The multi-leader parameter-server topology: workers `0..n`, one leader
/// node per shard at `n..n+S` (ascending by shard id; with `S = 1` the
/// leader is node `n`, exactly the historical convention). `Clone` so each
/// worker-pool thread can hold its own copy of the (cheap) topology.
#[derive(Clone, Debug)]
pub struct ShardedParameterServer {
    pub plan: ShardPlan,
    /// Fabric node id of each shard's leader, indexed by shard.
    pub leaders: Vec<usize>,
    pub workers: Vec<usize>,
}

impl ShardedParameterServer {
    /// Derive the topology from the fabric size: the last
    /// `plan.num_shards()` nodes are the shard leaders, the rest workers.
    pub fn new(fabric: &Fabric, plan: ShardPlan) -> Self {
        let s = plan.num_shards();
        let n = fabric.nodes();
        assert!(n >= s + 1, "need at least 1 worker + {s} shard leaders");
        ShardedParameterServer {
            leaders: (n - s..n).collect(),
            workers: (0..n - s).collect(),
            plan,
        }
    }

    pub fn num_shards(&self) -> usize {
        self.plan.num_shards()
    }

    /// Worker side: push one round's per-shard frames (in shard order) to
    /// their shard leaders, draining `frames` (the caller's scratch vector
    /// keeps its capacity for the next round). With `S = 1` this is a
    /// single untagged frame to the single leader — byte-identical to the
    /// unsharded push.
    pub fn push_frames(&self, fabric: &Fabric, worker: usize, round: u64, frames: &mut Vec<Encoded>) {
        assert_eq!(frames.len(), self.num_shards(), "one frame per shard");
        for (s, frame) in frames.drain(..).enumerate() {
            fabric.send(Message {
                src: worker,
                dst: self.leaders[s],
                round,
                kind: MessageKind::GradPush,
                payload: Payload::Grad(frame),
            });
        }
    }

    /// Refresh the shared broadcast slices from `params` **in place**: one
    /// `Arc<[f32]>` per shard (the full vector when unsharded). In steady
    /// state every receiver has dropped its reference from the previous
    /// round by the time the leader folds, so `Arc::get_mut` succeeds and
    /// the refresh is a plain `copy_from_slice` — no allocation; if a
    /// reference is still live (or the plan changed), a fresh buffer is
    /// allocated instead, which is always correct, just slower.
    pub fn make_broadcast(&self, params: &[f32], slices: &mut Vec<Arc<[f32]>>) {
        assert_eq!(params.len(), self.plan.dim());
        let s_total = self.num_shards();
        if slices.len() != s_total {
            slices.clear();
            for s in 0..s_total {
                slices.push(Arc::from(&params[self.plan.range(s)]));
            }
            return;
        }
        for s in 0..s_total {
            let r = self.plan.range(s);
            match Arc::get_mut(&mut slices[s]) {
                Some(dst) if dst.len() == r.len() => dst.copy_from_slice(&params[r]),
                _ => slices[s] = Arc::from(&params[r]),
            }
        }
    }

    /// Leader side: send one worker its parameters from already-shared
    /// slices (see [`make_broadcast`](Self::make_broadcast)) — one
    /// refcount bump per shard, no dense copy. A single `Params` message
    /// when unsharded (byte-identical accounting to the historical
    /// driver), one `ParamSlice` per shard leader otherwise. Returns the
    /// latest simulated arrival over the slices.
    pub fn send_params_shared(
        &self,
        fabric: &Fabric,
        worker: usize,
        round: u64,
        slices: &[Arc<[f32]>],
    ) -> f64 {
        assert_eq!(slices.len(), self.num_shards(), "one slice per shard");
        if self.num_shards() == 1 {
            debug_assert_eq!(slices[0].len(), self.plan.dim());
            return fabric.send(Message {
                src: self.leaders[0],
                dst: worker,
                round,
                kind: MessageKind::ParamBroadcast,
                payload: Payload::Params(slices[0].clone()),
            });
        }
        let mut latest = 0.0f64;
        for (s, vals) in slices.iter().enumerate() {
            debug_assert_eq!(vals.len(), self.plan.len_of(s));
            let arrival = fabric.send(Message {
                src: self.leaders[s],
                dst: worker,
                round,
                kind: MessageKind::ParamBroadcast,
                payload: Payload::ParamSlice {
                    shard: s as u16,
                    start: self.plan.start(s) as u32,
                    vals: vals.clone(),
                },
            });
            latest = latest.max(arrival);
        }
        latest
    }

    /// Leader side: send one worker its parameters, copying `params` into
    /// fresh shared slices. One-shot convenience; round loops should
    /// refresh a persistent slice set with
    /// [`make_broadcast`](Self::make_broadcast) and dispatch through
    /// [`send_params_shared`](Self::send_params_shared).
    pub fn send_params(&self, fabric: &Fabric, worker: usize, round: u64, params: &[f32]) -> f64 {
        let mut slices = Vec::new();
        self.make_broadcast(params, &mut slices);
        self.send_params_shared(fabric, worker, round, &slices)
    }

    /// Leader side: broadcast already-shared slices to every worker — `n`
    /// refcount bumps per shard instead of `n` dense clones. Returns the
    /// latest simulated arrival over all recipients and slices.
    pub fn broadcast_shared(&self, fabric: &Fabric, round: u64, slices: &[Arc<[f32]>]) -> f64 {
        let mut latest = 0.0f64;
        for &w in &self.workers {
            latest = latest.max(self.send_params_shared(fabric, w, round, slices));
        }
        latest
    }

    /// Leader side: broadcast the parameters to every worker (one copy of
    /// `params` total, then refcount bumps). Returns the latest simulated
    /// arrival over all recipients and slices.
    pub fn broadcast_params(&self, fabric: &Fabric, round: u64, params: &[f32]) -> f64 {
        let mut slices = Vec::new();
        self.make_broadcast(params, &mut slices);
        self.broadcast_shared(fabric, round, slices.as_slice())
    }

    /// Worker side: receive one round's parameters into `buf`, assembling
    /// per-shard slices when sharded. Copies out of the shared broadcast
    /// buffers into the worker's persistent scratch (and drops the
    /// refcount, which is what lets the leader refresh the shared slices
    /// in place next round). Returns `false` if the broadcast is missing
    /// from the worker's inbox. Allocation-free once `buf` is warm.
    pub fn recv_params_into(&self, fabric: &Fabric, worker: usize, buf: &mut Vec<f32>) -> bool {
        let s_total = self.num_shards();
        if s_total == 1 {
            while let Some(msg) = fabric.recv(worker) {
                if let Payload::Params(p) = msg.payload {
                    buf.clear();
                    buf.extend_from_slice(&p);
                    return true;
                }
            }
            return false;
        }
        buf.resize(self.plan.dim(), 0.0);
        // Track distinct shards, not message counts: a duplicated slice
        // must not mask a missing one (the hole would silently keep the
        // previous round's values in a reused buffer). A stack bitmask
        // covers up to 128 shards without allocating; wider (exotic) plans
        // fall back to a heap mask.
        let mut mask = [0u64; 2];
        let mut wide = if s_total > 128 {
            vec![false; s_total]
        } else {
            Vec::new()
        };
        let mut got = 0usize;
        while got < s_total {
            let Some(msg) = fabric.recv(worker) else {
                return false;
            };
            if let Payload::ParamSlice { shard, start, vals } = msg.payload {
                let shard = shard as usize;
                assert!(shard < s_total, "out-of-range parameter slice for shard {shard}");
                let dup = if s_total > 128 {
                    std::mem::replace(&mut wide[shard], true)
                } else {
                    let bit = 1u64 << (shard % 64);
                    let cell = &mut mask[shard / 64];
                    let d = (*cell & bit) != 0;
                    *cell |= bit;
                    d
                };
                assert!(!dup, "duplicate parameter slice for shard {shard}");
                let start = start as usize;
                buf[start..start + vals.len()].copy_from_slice(&vals);
                got += 1;
            }
        }
        true
    }

    /// Leader side: drain shard `s`'s inbox for `round` into the caller's
    /// persistent scratch: `msgs` is the raw drain buffer, `frames`
    /// receives the gathered frames sorted by worker id. Returns the
    /// latest simulated arrival, or a typed [`GatherError`] naming the
    /// shard and the mismatched round/count. Allocation-free once the
    /// scratch vectors are warm.
    pub fn gather_shard_into(
        &self,
        fabric: &Fabric,
        round: u64,
        s: usize,
        msgs: &mut Vec<(Message, f64)>,
        frames: &mut Vec<Encoded>,
    ) -> Result<f64, GatherError> {
        self.gather_shard_expecting(fabric, round, s, msgs, frames, self.workers.len())
    }

    /// Leader side: like [`gather_shard_into`](Self::gather_shard_into)
    /// but expecting frames from `expected` workers instead of the full
    /// fleet — the membership-aware gather used by churn-active rounds,
    /// where only live workers pushed this round.
    pub fn gather_shard_expecting(
        &self,
        fabric: &Fabric,
        round: u64,
        s: usize,
        msgs: &mut Vec<(Message, f64)>,
        frames: &mut Vec<Encoded>,
        expected: usize,
    ) -> Result<f64, GatherError> {
        frames.clear();
        fabric.recv_all_timed_into(self.leaders[s], msgs);
        // worker ids are unique within a shard's round, so the unstable
        // (allocation-free) sort is deterministic
        msgs.sort_unstable_by_key(|(m, _)| m.src);
        let mut latest = 0.0f64;
        for (msg, arrival) in msgs.drain(..) {
            if msg.round != round {
                return Err(GatherError::Stale {
                    shard: s,
                    src: msg.src,
                    expected: round,
                    got: msg.round,
                });
            }
            if let Payload::Grad(e) = msg.payload {
                // The shard tag is untrusted input: a frame whose tag
                // disagrees with the leader it landed on is dropped and
                // counted, never aggregated into the wrong slice (the
                // round then reports `Missing` with honest counts instead
                // of aborting). Untagged single-shard frames carry no tag
                // to check.
                if let Some(tag) = e.shard {
                    if tag.shard as usize != s {
                        fabric.note_dropped_frame();
                        if let Some(tr) = fabric.trace() {
                            // leader-track event; the caller (driver thread)
                            // is this ring's only writer
                            tr.record(
                                self.leaders[s],
                                arrival,
                                round,
                                EventKind::FrameDropped(DropReason::ShardMismatch),
                                msg.src as u64,
                            );
                        }
                        continue;
                    }
                }
                if let Some(tr) = fabric.trace() {
                    tr.record(
                        self.leaders[s],
                        arrival,
                        round,
                        EventKind::FrameArrived,
                        msg.src as u64,
                    );
                }
                frames.push(e);
                latest = latest.max(arrival);
            }
        }
        if frames.len() != expected {
            return Err(GatherError::Missing {
                shard: s,
                expected,
                got: frames.len(),
            });
        }
        Ok(latest)
    }

    /// Leader side: drain shard `s`'s inbox for `round`. Allocating
    /// wrapper around [`gather_shard_into`](Self::gather_shard_into).
    pub fn gather_shard_timed(
        &self,
        fabric: &Fabric,
        round: u64,
        s: usize,
    ) -> Result<(Vec<Encoded>, f64), GatherError> {
        let mut msgs = Vec::new();
        let mut frames = Vec::new();
        let latest = self.gather_shard_into(fabric, round, s, &mut msgs, &mut frames)?;
        Ok((frames, latest))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::wire::{encode_dense, encode_scaled_sign};
    use crate::net::LinkModel;

    #[test]
    fn plan_partition_is_contiguous_complete_and_balanced() {
        for (d, s) in [(10, 1), (10, 3), (97, 4), (64, 8), (5, 8), (1, 1)] {
            let plan = ShardPlan::new(d, s);
            let eff = plan.num_shards();
            assert!(eff <= s && eff >= 1 && eff <= d);
            assert_eq!(plan.start(0), 0);
            assert_eq!(plan.range(eff - 1).end, d);
            let mut total = 0usize;
            for i in 0..eff {
                let r = plan.range(i);
                assert_eq!(r.start, plan.start(i));
                assert_eq!(r.len(), plan.len_of(i));
                total += r.len();
                if i > 0 {
                    assert_eq!(plan.range(i - 1).end, r.start, "gap at shard {i}");
                }
                // balanced: sizes differ by at most one
                assert!(r.len() >= d / eff && r.len() <= d / eff + 1);
            }
            assert_eq!(total, d);
        }
        // same (d, S) always derives the same plan
        assert_eq!(ShardPlan::new(97, 4), ShardPlan::new(97, 4));
        assert_eq!(ShardPlan::single(12), ShardPlan::new(12, 1));
        // shard ids must fit the 16-bit wire tag: the plan clamps there
        let wide = ShardPlan::new(100_000, 70_000);
        assert_eq!(wide.num_shards(), u16::MAX as usize);
    }

    #[test]
    fn sharded_roundtrip_push_gather_broadcast() {
        let plan = ShardPlan::new(6, 2);
        // 2 workers + 2 shard leaders
        let fabric = Fabric::new(4, LinkModel::default());
        let ps = ShardedParameterServer::new(&fabric, plan);
        assert_eq!(ps.workers, vec![0, 1]);
        assert_eq!(ps.leaders, vec![2, 3]);

        // broadcast slices reassemble on the worker
        let params: Vec<f32> = (0..6).map(|i| i as f32).collect();
        ps.broadcast_params(&fabric, 0, &params);
        for w in 0..2 {
            let mut buf = Vec::new();
            assert!(ps.recv_params_into(&fabric, w, &mut buf));
            assert_eq!(buf, params);
        }

        // per-shard push lands on the right leader, sorted gather works
        for w in 0..2usize {
            let v: Vec<f32> = (0..6).map(|i| (w * 10 + i) as f32).collect();
            let mut frames: Vec<Encoded> = (0..2)
                .map(|s| {
                    let r = ps.plan.range(s);
                    encode_dense(&v[r.clone()]).with_shard(s as u16, r.start as u32)
                })
                .collect();
            ps.push_frames(&fabric, w, 3, &mut frames);
            // the scratch drains but keeps its capacity for the next round
            assert!(frames.is_empty());
        }
        for s in 0..2 {
            let (frames, _latest) = ps.gather_shard_timed(&fabric, 3, s).unwrap();
            assert_eq!(frames.len(), 2);
            assert!(frames.iter().all(|e| e.d == 3));
            assert!(frames
                .iter()
                .all(|e| e.shard.map(|t| t.shard as usize) == Some(s)));
        }
    }

    #[test]
    fn gather_reports_stale_and_missing_with_shard_context() {
        let plan = ShardPlan::new(4, 2);
        let fabric = Fabric::new(3, LinkModel::default()); // 1 worker + 2 leaders
        let ps = ShardedParameterServer::new(&fabric, plan);
        // wrong round on shard 1
        ps.push_frames(
            &fabric,
            0,
            7,
            &mut vec![
                encode_scaled_sign(&[1.0, -1.0]).with_shard(0, 0),
                encode_scaled_sign(&[1.0, -1.0]).with_shard(1, 2),
            ],
        );
        let err = ps.gather_shard_timed(&fabric, 8, 1).unwrap_err();
        assert_eq!(
            err,
            GatherError::Stale {
                shard: 1,
                src: 0,
                expected: 8,
                got: 7
            }
        );
        assert!(err.to_string().contains("shard 1"));
        // nothing pushed on a fresh fabric => Missing with counts
        let fabric2 = Fabric::new(3, LinkModel::default());
        let ps2 = ShardedParameterServer::new(&fabric2, ShardPlan::new(4, 2));
        let err = ps2.gather_shard_timed(&fabric2, 8, 0).unwrap_err();
        assert_eq!(
            err,
            GatherError::Missing {
                shard: 0,
                expected: 1,
                got: 0
            }
        );
        assert!(err.to_string().contains("0 of 1"));
    }

    /// A frame whose (untrusted) shard tag disagrees with the leader it
    /// landed on is dropped and counted — the gather reports an honest
    /// `Missing` instead of panicking or folding the frame into the wrong
    /// slice.
    #[test]
    fn wrong_shard_tag_is_dropped_and_counted_not_fatal() {
        let plan = ShardPlan::new(4, 2);
        let fabric = Fabric::new(3, LinkModel::default()); // 1 worker + 2 leaders
        let ps = ShardedParameterServer::new(&fabric, plan);
        // shard 0's frame lies: it claims to belong to shard 1
        ps.push_frames(
            &fabric,
            0,
            2,
            &mut vec![
                encode_scaled_sign(&[1.0, -1.0]).with_shard(1, 2),
                encode_scaled_sign(&[1.0, -1.0]).with_shard(1, 2),
            ],
        );
        let err = ps.gather_shard_timed(&fabric, 2, 0).unwrap_err();
        assert_eq!(
            err,
            GatherError::Missing {
                shard: 0,
                expected: 1,
                got: 0
            }
        );
        assert_eq!(fabric.with_stats(|st| st.dropped()), 1);
        // the honestly-tagged frame on shard 1 still gathers fine
        let (frames, _) = ps.gather_shard_timed(&fabric, 2, 1).unwrap();
        assert_eq!(frames.len(), 1);
    }

    #[test]
    fn single_shard_degenerates_to_the_classic_topology() {
        let plan = ShardPlan::single(8);
        let fabric = Fabric::new(4, LinkModel::default()); // 3 workers + leader
        let ps = ShardedParameterServer::new(&fabric, plan);
        assert_eq!(ps.leaders, vec![3]);
        assert_eq!(ps.workers, vec![0, 1, 2]);
        let params = vec![0.5f32; 8];
        ps.send_params(&fabric, 1, 0, &params);
        // the unsharded broadcast is a plain dense Params payload
        let msg = fabric.recv(1).unwrap();
        match msg.payload {
            Payload::Params(p) => assert_eq!(&p[..], params.as_slice()),
            other => panic!("expected Params, got {other:?}"),
        }
    }

    /// The steady-state broadcast refresh reuses the shared slice
    /// allocations: once every receiver has dropped its reference,
    /// `make_broadcast` updates the same buffers in place (same pointers),
    /// and the recipients see the fresh values.
    #[test]
    fn make_broadcast_refreshes_slices_in_place() {
        for shards in [1usize, 3] {
            let plan = ShardPlan::new(9, shards);
            let s_total = plan.num_shards();
            let fabric = Fabric::new(2 + s_total, LinkModel::default()); // 2 workers
            let ps = ShardedParameterServer::new(&fabric, plan);
            let mut slices = Vec::new();
            let round0: Vec<f32> = (0..9).map(|i| i as f32).collect();
            ps.make_broadcast(&round0, &mut slices);
            let ptrs: Vec<*const f32> = slices.iter().map(|a| a.as_ptr()).collect();
            ps.broadcast_shared(&fabric, 0, &slices);
            let mut buf = Vec::new();
            for w in 0..2 {
                assert!(ps.recv_params_into(&fabric, w, &mut buf));
                assert_eq!(buf, round0);
            }
            // all receivers dropped their refs => in-place refresh
            let round1: Vec<f32> = (0..9).map(|i| -(i as f32)).collect();
            ps.make_broadcast(&round1, &mut slices);
            let ptrs1: Vec<*const f32> = slices.iter().map(|a| a.as_ptr()).collect();
            assert_eq!(ptrs, ptrs1, "shards={shards}: slice buffers were reallocated");
            ps.broadcast_shared(&fabric, 1, &slices);
            for w in 0..2 {
                assert!(ps.recv_params_into(&fabric, w, &mut buf));
                assert_eq!(buf, round1, "shards={shards}");
            }
            // a still-live reference forces (correct) reallocation instead
            let hold = slices[0].clone();
            let round2 = vec![7.0f32; 9];
            ps.make_broadcast(&round2, &mut slices);
            assert!(!Arc::ptr_eq(&hold, &slices[0]));
            assert_eq!(&slices[0][..], &round2[ps.plan.range(0)]);
        }
    }
}
