//! Parameter-server collective: compressed gradient push from workers to
//! the leader, aggregation at the leader, dense (or compressed) broadcast
//! back. This is the communication pattern of the paper's experiments and
//! of 1-bit SGD (Seide et al. 2014).

use super::shard::GatherError;
use crate::compress::wire::{self, Encoded};
use crate::net::{Fabric, Message, MessageKind, Payload};
use std::sync::Arc;

/// The leader endpoint of a parameter-server round. `Clone` so each
/// worker-pool thread can hold its own copy of the (cheap) topology.
#[derive(Clone, Debug)]
pub struct ParameterServer {
    /// Node id of the leader on the fabric (convention: last node).
    pub leader: usize,
    pub workers: Vec<usize>,
}

impl ParameterServer {
    /// Leader = node n−1, workers = 0..n−1.
    pub fn new(fabric: &Fabric) -> Self {
        let n = fabric.nodes();
        assert!(n >= 2, "need at least 1 worker + leader");
        ParameterServer {
            leader: n - 1,
            workers: (0..n - 1).collect(),
        }
    }

    /// Worker side: push an encoded gradient to the leader.
    pub fn push_grad(&self, fabric: &Fabric, worker: usize, round: u64, encoded: Encoded) {
        fabric.send(Message {
            src: worker,
            dst: self.leader,
            round,
            kind: MessageKind::GradPush,
            payload: Payload::Grad(encoded),
        });
    }

    /// Leader side: collect one pushed gradient per worker for `round`,
    /// decode, and return the *mean* as a dense vector. A stale or missing
    /// frame comes back as a typed [`GatherError`] (naming the round and
    /// source that mismatched) instead of an `assert_eq!` abort, so async
    /// and sharded callers can surface or recover from the exact fault.
    ///
    /// Messages are accumulated in worker order regardless of arrival
    /// order, so the f32 sum is bit-identical whether the pushes came from
    /// one thread or many.
    pub fn gather_mean(&self, fabric: &Fabric, round: u64, d: usize) -> Result<Vec<f32>, GatherError> {
        let mut acc = vec![0.0f32; d];
        let mut msgs = fabric.recv_all(self.leader);
        msgs.sort_by_key(|m| m.src);
        let mut got = 0usize;
        for msg in msgs {
            if msg.round != round {
                return Err(GatherError::Stale {
                    shard: 0,
                    src: msg.src,
                    expected: round,
                    got: msg.round,
                });
            }
            if let Payload::Grad(e) = msg.payload {
                // fused decode-into-accumulator for every wire format: no
                // per-worker dense materialization on the leader
                wire::decode_any_add(&e, &mut acc).expect("decode");
                got += 1;
            }
        }
        if got != self.workers.len() {
            return Err(GatherError::Missing {
                shard: 0,
                expected: self.workers.len(),
                got,
            });
        }
        crate::tensor::scale(1.0 / got as f32, &mut acc);
        Ok(acc)
    }

    /// Leader side: send an already-shared parameter vector to one worker —
    /// a refcount bump, not a dense clone. The async driver and the
    /// broadcast below dispatch through this.
    pub fn send_params_shared(
        &self,
        fabric: &Fabric,
        worker: usize,
        round: u64,
        params: &Arc<[f32]>,
    ) -> f64 {
        fabric.send(Message {
            src: self.leader,
            dst: worker,
            round,
            kind: MessageKind::ParamBroadcast,
            payload: Payload::Params(params.clone()),
        })
    }

    /// Leader side: send the parameter vector (dense) to one worker.
    /// Returns the simulated arrival time at the worker. Copies `params`
    /// into a fresh shared buffer; batch callers should share one
    /// `Arc<[f32]>` via [`send_params_shared`](Self::send_params_shared).
    pub fn send_params(&self, fabric: &Fabric, worker: usize, round: u64, params: &[f32]) -> f64 {
        self.send_params_shared(fabric, worker, round, &Arc::from(params))
    }

    /// Leader side: broadcast the parameter vector (dense) to all workers:
    /// one copy of `params` into a shared buffer, then one refcount bump
    /// per recipient. Returns the latest simulated arrival time.
    pub fn broadcast_params(&self, fabric: &Fabric, round: u64, params: &[f32]) -> f64 {
        let shared: Arc<[f32]> = Arc::from(params);
        let mut latest = 0.0f64;
        for &w in &self.workers {
            latest = latest.max(self.send_params_shared(fabric, w, round, &shared));
        }
        latest
    }

    /// Worker side: receive the broadcast parameters (a view of the shared
    /// broadcast buffer — no copy).
    pub fn recv_params(&self, fabric: &Fabric, worker: usize) -> Option<Arc<[f32]>> {
        while let Some(msg) = fabric.recv(worker) {
            if let Payload::Params(p) = msg.payload {
                return Some(p);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::wire::{encode_dense, encode_scaled_sign, encode_sparse};
    use crate::net::LinkModel;
    use crate::util::Pcg64;

    #[test]
    fn gather_mean_dense() {
        let fabric = Fabric::new(3, LinkModel::default()); // 2 workers + leader
        let ps = ParameterServer::new(&fabric);
        ps.push_grad(&fabric, 0, 0, encode_dense(&[1.0, 2.0]));
        ps.push_grad(&fabric, 1, 0, encode_dense(&[3.0, -2.0]));
        let mean = ps.gather_mean(&fabric, 0, 2).unwrap();
        assert_eq!(mean, vec![2.0, 0.0]);
    }

    #[test]
    fn gather_mean_mixed_formats() {
        let fabric = Fabric::new(3, LinkModel::default());
        let ps = ParameterServer::new(&fabric);
        let p = [4.0f32, -2.0, 1.0, 1.0]; // scale 2.0
        ps.push_grad(&fabric, 0, 0, encode_scaled_sign(&p));
        ps.push_grad(&fabric, 1, 0, encode_sparse(&[0.0, 0.0, 5.0, 0.0]));
        let mean = ps.gather_mean(&fabric, 0, 4).unwrap();
        assert_eq!(mean, vec![1.0, -1.0, 3.5, 1.0]);
    }

    #[test]
    fn gather_mean_qsgd_frames() {
        use crate::compress::{Compressor, Qsgd};
        let d = 64;
        let mut rng = Pcg64::seeded(5);
        let mut p = vec![0.0f32; d];
        rng.fill_normal(&mut p, 0.0, 1.0);
        let q = Qsgd::new(4).compress_vec(&p, &mut rng);
        let norm = crate::tensor::norm2(&p) as f32;
        let fabric = Fabric::new(3, LinkModel::default());
        let ps = ParameterServer::new(&fabric);
        ps.push_grad(&fabric, 0, 0, crate::compress::wire::encode_qsgd(&q, norm, 4));
        ps.push_grad(&fabric, 1, 0, encode_dense(&vec![0.0f32; d]));
        let mean = ps.gather_mean(&fabric, 0, d).unwrap();
        for i in 0..d {
            assert!((mean[i] - q[i] / 2.0).abs() < 1e-6, "i={i}");
        }
    }

    #[test]
    fn broadcast_roundtrip() {
        let fabric = Fabric::new(4, LinkModel::default());
        let ps = ParameterServer::new(&fabric);
        let params = vec![1.0f32, -1.0, 0.5];
        ps.broadcast_params(&fabric, 7, &params);
        let first = ps.recv_params(&fabric, 0).unwrap();
        assert_eq!(&first[..], params.as_slice());
        for w in 1..3 {
            let got = ps.recv_params(&fabric, w).unwrap();
            assert_eq!(&got[..], params.as_slice());
            // every recipient aliases the one shared broadcast buffer
            assert!(std::sync::Arc::ptr_eq(&got, &first));
        }
    }

    #[test]
    fn gather_detects_missing_worker_as_typed_error() {
        let fabric = Fabric::new(3, LinkModel::default());
        let ps = ParameterServer::new(&fabric);
        ps.push_grad(&fabric, 0, 0, encode_dense(&[1.0]));
        let err = ps.gather_mean(&fabric, 0, 1).unwrap_err();
        assert_eq!(
            err,
            GatherError::Missing {
                shard: 0,
                expected: 2,
                got: 1
            }
        );
        assert!(err.to_string().contains("1 of 2"));
    }

    #[test]
    fn gather_detects_stale_round_as_typed_error() {
        let fabric = Fabric::new(3, LinkModel::default());
        let ps = ParameterServer::new(&fabric);
        ps.push_grad(&fabric, 0, 4, encode_dense(&[1.0]));
        ps.push_grad(&fabric, 1, 5, encode_dense(&[2.0]));
        let err = ps.gather_mean(&fabric, 5, 1).unwrap_err();
        assert_eq!(
            err,
            GatherError::Stale {
                shard: 0,
                src: 0,
                expected: 5,
                got: 4
            }
        );
        assert!(err.to_string().contains("round 5"));
    }

    #[test]
    fn traffic_accounting_separates_directions() {
        let d = 1024;
        let mut rng = Pcg64::seeded(0);
        let mut g = vec![0.0f32; d];
        rng.fill_normal(&mut g, 0.0, 1.0);
        let fabric = Fabric::new(2, LinkModel::default());
        let ps = ParameterServer::new(&fabric);
        ps.push_grad(&fabric, 0, 0, encode_scaled_sign(&g));
        let _ = ps.gather_mean(&fabric, 0, d).unwrap();
        ps.broadcast_params(&fabric, 0, &g);
        let stats = fabric.snapshot_stats();
        use crate::net::MessageKind::*;
        // push = d+32 bits (+frame), broadcast = 32d (+frame)
        assert!(stats.bits_of_kind(GradPush) < stats.bits_of_kind(ParamBroadcast) / 20);
    }
}
