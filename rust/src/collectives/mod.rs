//! Collectives over the simulated fabric.
//!
//! * [`ps`]: parameter-server push/aggregate/broadcast — the topology the
//!   paper's experiments use (compressed gradient push, dense broadcast).
//! * [`shard`]: the sharded parameter server — the model vector split into
//!   `S` contiguous coordinate shards, each with its own leader node, so
//!   leader decode+aggregate stops being a single-node bottleneck
//!   (`docs/SHARDING.md`).
//! * [`ring`]: ring all-reduce (reduce-scatter + all-gather) of dense
//!   vectors — the uncompressed baseline collective.
//! * [`majority`]: coordinate-wise majority vote over sign vectors
//!   (Bernstein et al. 2019's multi-worker SIGNSGD aggregation).
//!
//! All routes go through [`crate::net::Fabric::send`], so traffic and
//! simulated time are accounted exactly — including from the threaded
//! variants, whose sends/recvs interleave through the same mutex-guarded
//! accounting layer.

pub mod majority;
pub mod ps;
pub mod ring;
pub mod shard;

pub use majority::majority_vote;
pub use ps::ParameterServer;
pub use ring::{ring_allgather, ring_allreduce, ring_allreduce_parallel};
pub use shard::{GatherError, ShardPlan, ShardedParameterServer};
