//! Coordinate-wise majority vote over sign vectors: the multi-worker
//! aggregation of signSGD-with-majority-vote (Bernstein et al. 2019). The
//! paper's counterexamples extend to this setting; we implement it as the
//! multi-worker sign baseline.

/// Majority vote of sign vectors: out_i = sign(Σ_w sign(g_w_i)).
/// Ties (possible for even worker counts) resolve to 0.
pub fn majority_vote(signs: &[Vec<f32>]) -> Vec<f32> {
    assert!(!signs.is_empty());
    let d = signs[0].len();
    assert!(signs.iter().all(|s| s.len() == d));
    let mut out = vec![0.0f32; d];
    for (i, o) in out.iter_mut().enumerate() {
        let mut tally = 0i64;
        for s in signs {
            let v = s[i];
            tally += if v > 0.0 {
                1
            } else if v < 0.0 {
                -1
            } else {
                0
            };
        }
        *o = if tally > 0 {
            1.0
        } else if tally < 0 {
            -1.0
        } else {
            0.0
        };
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::propcheck::{self, Pair, UsizeRange};
    use crate::util::Pcg64;

    #[test]
    fn basic_vote() {
        let signs = vec![
            vec![1.0, -1.0, 1.0],
            vec![1.0, 1.0, -1.0],
            vec![-1.0, -1.0, -1.0],
        ];
        assert_eq!(majority_vote(&signs), vec![1.0, -1.0, -1.0]);
    }

    #[test]
    fn even_tie_is_zero() {
        let signs = vec![vec![1.0], vec![-1.0]];
        assert_eq!(majority_vote(&signs), vec![0.0]);
    }

    #[test]
    fn prop_vote_equals_sign_of_sign_sum() {
        propcheck::check(&Pair(UsizeRange(1, 9), UsizeRange(1, 40)), |&(n, d)| {
            let mut rng = Pcg64::seeded((n * 31 + d) as u64);
            let signs: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..d).map(|_| rng.sign() as f32).collect())
                .collect();
            let vote = majority_vote(&signs);
            (0..d).all(|i| {
                let sum: f32 = signs.iter().map(|s| s[i]).sum();
                let expect = if sum > 0.0 {
                    1.0
                } else if sum < 0.0 {
                    -1.0
                } else {
                    0.0
                };
                vote[i] == expect
            })
        });
    }

    #[test]
    fn single_worker_identity_on_signs() {
        let signs = vec![vec![1.0, -1.0, 0.0]];
        assert_eq!(majority_vote(&signs), vec![1.0, -1.0, 0.0]);
    }
}
