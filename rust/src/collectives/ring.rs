//! Ring all-reduce (reduce-scatter followed by all-gather), the
//! bandwidth-optimal dense collective — the uncompressed baseline the
//! paper's compression schemes are measured against.
//!
//! Each of the `n` workers holds a dense vector; after the call every
//! worker holds the element-wise sum. 2(n−1) message rounds, each moving
//! d/n values: total traffic 2·(n−1)/n·d·32 bits per worker.
//!
//! In the all-reduce, payload buffers are **moved** through the fabric,
//! not cloned: each worker seeds one chunk copy, then every forwarding hop
//! takes ownership of the received `Vec`, accumulates (or copies out) in
//! place, and sends the same allocation onward. That turns the per-step
//! O(n²) chunk clones of the naive implementation into O(n) total
//! allocations. (The all-gather keeps one copy per hop — inherent, since
//! every worker retains what it forwards.)
//!
//! [`ring_allreduce`] runs the schedule lock-step on the calling thread;
//! [`ring_allreduce_parallel`] runs one scoped thread per worker with
//! blocking receives. Both produce identical buffers and identical
//! accounting: each node's inbox is fed by a single peer (its ring
//! predecessor) in program order, so the per-chunk accumulation order is
//! fixed by the ring schedule, not by thread timing.

use crate::net::{Fabric, Message, MessageKind, Payload};
use std::sync::atomic::{AtomicBool, Ordering};

/// Chunk boundaries: chunk c covers [offsets[c], offsets[c+1]).
fn chunk_offsets(d: usize, n: usize) -> Vec<usize> {
    let base = d / n;
    let rem = d % n;
    let mut offs = vec![0usize];
    for c in 0..n {
        let len = base + usize::from(c < rem);
        offs.push(offs[c] + len);
    }
    offs
}

fn send_chunk(fabric: &Fabric, src: usize, dst: usize, round: u64, chunk: Vec<f32>) {
    fabric.send(Message {
        src,
        dst,
        round,
        kind: MessageKind::GradPush,
        // Chunk, not Params: ownership moves hop to hop (Params is the
        // Arc-shared broadcast payload, which cannot be mutated in place)
        payload: Payload::Chunk(chunk),
    });
}

fn take_chunk(msg: Message) -> Vec<f32> {
    match msg.payload {
        Payload::Chunk(chunk) => chunk,
        other => panic!("ring collective got non-chunk payload: {other:?}"),
    }
}

/// Sets the shared poison flag if its thread unwinds, so ring peers
/// blocked on a receive from the dead thread bail out instead of parking
/// forever (a panic anywhere would otherwise deadlock `thread::scope`).
struct PoisonOnPanic<'a>(&'a AtomicBool);

impl Drop for PoisonOnPanic<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.store(true, Ordering::SeqCst);
        }
    }
}

/// Blocking receive that aborts (panics) if a ring peer has panicked.
fn recv_checked(fabric: &Fabric, node: usize, poisoned: &AtomicBool) -> Message {
    loop {
        if let Some(msg) = fabric.recv_timeout(node, std::time::Duration::from_millis(50)) {
            return msg;
        }
        assert!(
            !poisoned.load(Ordering::SeqCst),
            "ring peer thread panicked; aborting collective on node {node}"
        );
    }
}

/// In-place ring all-reduce over `buffers` (one per worker), routing every
/// transfer through the fabric for accounting. After return, every buffer
/// contains the element-wise sum of the inputs.
pub fn ring_allreduce(fabric: &Fabric, buffers: &mut [Vec<f32>], round: u64) {
    let n = buffers.len();
    assert!(n >= 1);
    assert_eq!(fabric.nodes(), n, "fabric size mismatch");
    if n == 1 {
        return;
    }
    let d = buffers[0].len();
    assert!(buffers.iter().all(|b| b.len() == d), "ragged buffers");
    let offs = chunk_offsets(d, n);

    // Reduce-scatter. `cur[w]` is the chunk worker w sends next: seeded
    // with its own chunk w, thereafter the chunk received (and accumulated
    // into) on the previous step. After step s, worker w has contributed
    // to the partial sum of chunk (w − s − 1) mod n.
    let mut cur: Vec<Vec<f32>> = buffers
        .iter()
        .enumerate()
        .map(|(w, b)| b[offs[w]..offs[w + 1]].to_vec())
        .collect();
    for s in 0..n - 1 {
        for (w, chunk) in cur.iter_mut().enumerate() {
            send_chunk(fabric, w, (w + 1) % n, round, std::mem::take(chunk));
        }
        for (dst, slot) in cur.iter_mut().enumerate() {
            let mut chunk = take_chunk(fabric.recv(dst).expect("ring message missing"));
            let c = (dst + n - s - 1) % n;
            for (acc, v) in chunk.iter_mut().zip(&buffers[dst][offs[c]..offs[c + 1]]) {
                *acc += *v;
            }
            *slot = chunk;
        }
    }

    // After n−1 steps, cur[w] is the fully reduced chunk (w+1) mod n.
    for (w, chunk) in cur.iter().enumerate() {
        let c = (w + 1) % n;
        buffers[w][offs[c]..offs[c + 1]].copy_from_slice(chunk);
    }

    // All-gather: circulate the reduced chunks, still by moving the same
    // allocations around the ring.
    for s in 0..n - 1 {
        for (w, chunk) in cur.iter_mut().enumerate() {
            send_chunk(fabric, w, (w + 1) % n, round, std::mem::take(chunk));
        }
        for (dst, slot) in cur.iter_mut().enumerate() {
            let chunk = take_chunk(fabric.recv(dst).expect("ring message missing"));
            let c = (dst + n - s) % n;
            buffers[dst][offs[c]..offs[c + 1]].copy_from_slice(&chunk);
            *slot = chunk;
        }
    }
}

/// Threaded ring all-reduce: one scoped thread per worker, blocking
/// receives, sends/recvs interleaving through the shared (mutex-guarded)
/// fabric accounting. Bit totals and resulting buffers are identical to
/// [`ring_allreduce`]; wall-clock scales with cores since the per-chunk
/// accumulate/copy work runs concurrently.
pub fn ring_allreduce_parallel(fabric: &Fabric, buffers: &mut [Vec<f32>], round: u64) {
    let n = buffers.len();
    assert!(n >= 1);
    assert_eq!(fabric.nodes(), n, "fabric size mismatch");
    if n == 1 {
        return;
    }
    let d = buffers[0].len();
    assert!(buffers.iter().all(|b| b.len() == d), "ragged buffers");
    let offs = chunk_offsets(d, n);
    let offs = &offs;
    let poisoned = AtomicBool::new(false);
    let poisoned = &poisoned;

    std::thread::scope(|scope| {
        for (w, buf) in buffers.iter_mut().enumerate() {
            scope.spawn(move || {
                let _poison_guard = PoisonOnPanic(poisoned);
                // Reduce-scatter: forward-and-accumulate around the ring.
                let mut cur = buf[offs[w]..offs[w + 1]].to_vec();
                for s in 0..n - 1 {
                    send_chunk(fabric, w, (w + 1) % n, round, std::mem::take(&mut cur));
                    let mut chunk = take_chunk(recv_checked(fabric, w, poisoned));
                    let c = (w + n - s - 1) % n;
                    for (acc, v) in chunk.iter_mut().zip(&buf[offs[c]..offs[c + 1]]) {
                        *acc += *v;
                    }
                    cur = chunk;
                }
                let own = (w + 1) % n;
                buf[offs[own]..offs[own + 1]].copy_from_slice(&cur);
                // All-gather: circulate the reduced chunks.
                for s in 0..n - 1 {
                    send_chunk(fabric, w, (w + 1) % n, round, std::mem::take(&mut cur));
                    let chunk = take_chunk(recv_checked(fabric, w, poisoned));
                    let c = (w + n - s) % n;
                    buf[offs[c]..offs[c + 1]].copy_from_slice(&chunk);
                    cur = chunk;
                }
            });
        }
    });
}

/// Ring all-gather: each worker contributes its vector; afterwards every
/// worker holds the concatenation (by worker index). One copy per hop is
/// inherent here (every worker keeps the vector it forwards), so the send
/// clones from the stored slot and the receive moves into place.
pub fn ring_allgather(fabric: &Fabric, inputs: &[Vec<f32>], round: u64) -> Vec<Vec<f32>> {
    let n = inputs.len();
    assert_eq!(fabric.nodes(), n);
    let mut gathered: Vec<Vec<Vec<f32>>> = (0..n)
        .map(|w| {
            let mut v = vec![Vec::new(); n];
            v[w] = inputs[w].clone();
            v
        })
        .collect();
    for s in 0..n.saturating_sub(1) {
        for w in 0..n {
            let c = (w + n - s) % n;
            send_chunk(fabric, w, (w + 1) % n, round, gathered[w][c].clone());
        }
        for dst in 0..n {
            let chunk = take_chunk(fabric.recv(dst).expect("allgather message missing"));
            let c = (dst + n - s - 1) % n;
            gathered[dst][c] = chunk;
        }
    }
    gathered
        .into_iter()
        .map(|chunks| chunks.into_iter().flatten().collect())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::LinkModel;
    use crate::propcheck::{self, Pair, UsizeRange};
    use crate::util::Pcg64;

    fn serial_sum(buffers: &[Vec<f32>]) -> Vec<f32> {
        let d = buffers[0].len();
        let mut out = vec![0.0f32; d];
        for b in buffers {
            for (o, v) in out.iter_mut().zip(b) {
                *o += v;
            }
        }
        out
    }

    fn random_buffers(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg64::seeded(seed);
        (0..n)
            .map(|_| {
                let mut v = vec![0.0f32; d];
                rng.fill_normal(&mut v, 0.0, 1.0);
                v
            })
            .collect()
    }

    #[test]
    fn allreduce_matches_serial_sum() {
        let n = 4;
        let d = 37; // not divisible by n
        let mut buffers = random_buffers(n, d, 0);
        let expect = serial_sum(&buffers);
        let fabric = Fabric::new(n, LinkModel::default());
        ring_allreduce(&fabric, &mut buffers, 0);
        for b in &buffers {
            for (x, e) in b.iter().zip(&expect) {
                assert!((x - e).abs() < 1e-4, "{x} vs {e}");
            }
        }
        assert_eq!(fabric.in_flight(), 0);
    }

    #[test]
    fn prop_allreduce_any_n_d() {
        propcheck::check_with(
            &propcheck::Config {
                cases: 25,
                ..Default::default()
            },
            &Pair(UsizeRange(1, 8), UsizeRange(1, 64)),
            |&(n, d)| {
                let mut buffers = random_buffers(n, d, (n * 1000 + d) as u64);
                let expect = serial_sum(&buffers);
                let fabric = Fabric::new(n, LinkModel::default());
                ring_allreduce(&fabric, &mut buffers, 0);
                buffers
                    .iter()
                    .all(|b| b.iter().zip(&expect).all(|(x, e)| (x - e).abs() < 1e-3))
            },
        );
    }

    /// The threaded variant is bit-identical to the sequential one: same
    /// buffers (exactly, not within tolerance) and same accounted traffic.
    #[test]
    fn parallel_allreduce_bit_identical_to_sequential() {
        for (n, d) in [(2usize, 64usize), (3, 37), (4, 100), (8, 129)] {
            let mut seq = random_buffers(n, d, 42 + n as u64);
            let mut par = seq.clone();
            let fabric_seq = Fabric::new(n, LinkModel::default());
            let fabric_par = Fabric::new(n, LinkModel::default());
            ring_allreduce(&fabric_seq, &mut seq, 0);
            ring_allreduce_parallel(&fabric_par, &mut par, 0);
            assert_eq!(seq, par, "n={n} d={d}");
            assert_eq!(
                fabric_seq.snapshot_stats().total_bits,
                fabric_par.snapshot_stats().total_bits,
                "n={n} d={d}"
            );
            assert_eq!(fabric_par.in_flight(), 0);
        }
    }

    #[test]
    fn allreduce_traffic_is_bandwidth_optimal() {
        // Each worker sends 2*(n-1)/n*d values (+ framing).
        let n = 4;
        let d = 1000;
        let mut buffers: Vec<Vec<f32>> = (0..n).map(|_| vec![1.0f32; d]).collect();
        let fabric = Fabric::new(n, LinkModel::default());
        ring_allreduce(&fabric, &mut buffers, 0);
        let stats = fabric.snapshot_stats();
        let per_worker_payload = stats.sent_by(0) as f64
            - 2.0 * (n - 1) as f64 * crate::net::message::FRAME_OVERHEAD_BITS as f64;
        let expect = 2.0 * (n as f64 - 1.0) / n as f64 * d as f64 * 32.0;
        assert!(
            (per_worker_payload - expect).abs() / expect < 0.01,
            "{per_worker_payload} vs {expect}"
        );
    }

    #[test]
    fn allgather_concatenates() {
        let n = 3;
        let inputs: Vec<Vec<f32>> = (0..n).map(|w| vec![w as f32; 2]).collect();
        let fabric = Fabric::new(n, LinkModel::default());
        let out = ring_allgather(&fabric, &inputs, 0);
        for g in &out {
            assert_eq!(g, &vec![0.0, 0.0, 1.0, 1.0, 2.0, 2.0]);
        }
    }

    #[test]
    fn single_worker_noop() {
        let fabric = Fabric::new(1, LinkModel::default());
        let mut buffers = vec![vec![1.0f32, 2.0]];
        ring_allreduce(&fabric, &mut buffers, 0);
        ring_allreduce_parallel(&fabric, &mut buffers, 0);
        assert_eq!(buffers[0], vec![1.0, 2.0]);
        assert_eq!(fabric.snapshot_stats().total_bits, 0);
    }
}
