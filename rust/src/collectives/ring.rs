//! Ring all-reduce (reduce-scatter followed by all-gather), the
//! bandwidth-optimal dense collective — the uncompressed baseline the
//! paper's compression schemes are measured against.
//!
//! Each of the `n` workers holds a dense vector; after the call every
//! worker holds the element-wise sum. 2(n−1) message rounds, each moving
//! d/n values: total traffic 2·(n−1)/n·d·32 bits per worker.

use crate::net::{Fabric, Message, MessageKind, Payload};

/// Chunk boundaries: chunk c covers [offsets[c], offsets[c+1]).
fn chunk_offsets(d: usize, n: usize) -> Vec<usize> {
    let base = d / n;
    let rem = d % n;
    let mut offs = vec![0usize];
    for c in 0..n {
        let len = base + usize::from(c < rem);
        offs.push(offs[c] + len);
    }
    offs
}

/// In-place ring all-reduce over `buffers` (one per worker), routing every
/// transfer through the fabric for accounting. After return, every buffer
/// contains the element-wise sum of the inputs.
pub fn ring_allreduce(fabric: &Fabric, buffers: &mut [Vec<f32>], round: u64) {
    let n = buffers.len();
    assert!(n >= 1);
    assert_eq!(fabric.nodes(), n, "fabric size mismatch");
    if n == 1 {
        return;
    }
    let d = buffers[0].len();
    assert!(buffers.iter().all(|b| b.len() == d), "ragged buffers");
    let offs = chunk_offsets(d, n);

    // Reduce-scatter: after step s, worker w owns the partial sum of chunk
    // (w - s - 1) mod n over workers {w-s-1, ..., w}.
    for s in 0..n - 1 {
        for w in 0..n {
            let dst = (w + 1) % n;
            let c = (w + n - s) % n;
            let chunk = buffers[w][offs[c]..offs[c + 1]].to_vec();
            fabric.send(Message {
                src: w,
                dst,
                round,
                kind: MessageKind::GradPush,
                payload: Payload::Params(chunk),
            });
        }
        for dst in 0..n {
            let msg = fabric.recv(dst).expect("ring message missing");
            let c = (dst + n - s - 1) % n;
            if let Payload::Params(chunk) = msg.payload {
                for (acc, v) in buffers[dst][offs[c]..offs[c + 1]].iter_mut().zip(&chunk) {
                    *acc += v;
                }
            }
        }
    }

    // All-gather: circulate the fully reduced chunks.
    for s in 0..n - 1 {
        for w in 0..n {
            let dst = (w + 1) % n;
            let c = (w + 1 + n - s) % n;
            let chunk = buffers[w][offs[c]..offs[c + 1]].to_vec();
            fabric.send(Message {
                src: w,
                dst,
                round,
                kind: MessageKind::GradPush,
                payload: Payload::Params(chunk),
            });
        }
        for dst in 0..n {
            let msg = fabric.recv(dst).expect("ring message missing");
            let c = (dst + n - s) % n;
            if let Payload::Params(chunk) = msg.payload {
                buffers[dst][offs[c]..offs[c + 1]].copy_from_slice(&chunk);
            }
        }
    }
}

/// Ring all-gather: each worker contributes its vector; afterwards every
/// worker holds the concatenation (by worker index).
pub fn ring_allgather(fabric: &Fabric, inputs: &[Vec<f32>], round: u64) -> Vec<Vec<f32>> {
    let n = inputs.len();
    assert_eq!(fabric.nodes(), n);
    let mut gathered: Vec<Vec<Vec<f32>>> = (0..n)
        .map(|w| {
            let mut v = vec![Vec::new(); n];
            v[w] = inputs[w].clone();
            v
        })
        .collect();
    for s in 0..n.saturating_sub(1) {
        for w in 0..n {
            let dst = (w + 1) % n;
            let c = (w + n - s) % n;
            fabric.send(Message {
                src: w,
                dst,
                round,
                kind: MessageKind::GradPush,
                payload: Payload::Params(gathered[w][c].clone()),
            });
        }
        for dst in 0..n {
            let msg = fabric.recv(dst).expect("allgather message missing");
            let c = (dst + n - s - 1) % n;
            if let Payload::Params(chunk) = msg.payload {
                gathered[dst][c] = chunk;
            }
        }
    }
    gathered
        .into_iter()
        .map(|chunks| chunks.into_iter().flatten().collect())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::LinkModel;
    use crate::propcheck::{self, Pair, UsizeRange};
    use crate::util::Pcg64;

    fn serial_sum(buffers: &[Vec<f32>]) -> Vec<f32> {
        let d = buffers[0].len();
        let mut out = vec![0.0f32; d];
        for b in buffers {
            for (o, v) in out.iter_mut().zip(b) {
                *o += v;
            }
        }
        out
    }

    #[test]
    fn allreduce_matches_serial_sum() {
        let n = 4;
        let d = 37; // not divisible by n
        let mut rng = Pcg64::seeded(0);
        let mut buffers: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut v = vec![0.0f32; d];
                rng.fill_normal(&mut v, 0.0, 1.0);
                v
            })
            .collect();
        let expect = serial_sum(&buffers);
        let fabric = Fabric::new(n, LinkModel::default());
        ring_allreduce(&fabric, &mut buffers, 0);
        for b in &buffers {
            for (x, e) in b.iter().zip(&expect) {
                assert!((x - e).abs() < 1e-4, "{x} vs {e}");
            }
        }
        assert_eq!(fabric.in_flight(), 0);
    }

    #[test]
    fn prop_allreduce_any_n_d() {
        propcheck::check_with(
            &propcheck::Config {
                cases: 25,
                ..Default::default()
            },
            &Pair(UsizeRange(1, 8), UsizeRange(1, 64)),
            |&(n, d)| {
                let mut rng = Pcg64::seeded((n * 1000 + d) as u64);
                let mut buffers: Vec<Vec<f32>> = (0..n)
                    .map(|_| {
                        let mut v = vec![0.0f32; d];
                        rng.fill_normal(&mut v, 0.0, 1.0);
                        v
                    })
                    .collect();
                let expect = serial_sum(&buffers);
                let fabric = Fabric::new(n, LinkModel::default());
                ring_allreduce(&fabric, &mut buffers, 0);
                buffers
                    .iter()
                    .all(|b| b.iter().zip(&expect).all(|(x, e)| (x - e).abs() < 1e-3))
            },
        );
    }

    #[test]
    fn allreduce_traffic_is_bandwidth_optimal() {
        // Each worker sends 2*(n-1)/n*d values (+ framing).
        let n = 4;
        let d = 1000;
        let mut buffers: Vec<Vec<f32>> = (0..n).map(|_| vec![1.0f32; d]).collect();
        let fabric = Fabric::new(n, LinkModel::default());
        ring_allreduce(&fabric, &mut buffers, 0);
        let stats = fabric.stats();
        let per_worker_payload = stats.sent_by(0) as f64
            - 2.0 * (n - 1) as f64 * crate::net::message::FRAME_OVERHEAD_BITS as f64;
        let expect = 2.0 * (n as f64 - 1.0) / n as f64 * d as f64 * 32.0;
        assert!(
            (per_worker_payload - expect).abs() / expect < 0.01,
            "{per_worker_payload} vs {expect}"
        );
    }

    #[test]
    fn allgather_concatenates() {
        let n = 3;
        let inputs: Vec<Vec<f32>> = (0..n).map(|w| vec![w as f32; 2]).collect();
        let fabric = Fabric::new(n, LinkModel::default());
        let out = ring_allgather(&fabric, &inputs, 0);
        for g in &out {
            assert_eq!(g, &vec![0.0, 0.0, 1.0, 1.0, 2.0, 2.0]);
        }
    }

    #[test]
    fn single_worker_noop() {
        let fabric = Fabric::new(1, LinkModel::default());
        let mut buffers = vec![vec![1.0f32, 2.0]];
        ring_allreduce(&fabric, &mut buffers, 0);
        assert_eq!(buffers[0], vec![1.0, 2.0]);
        assert_eq!(fabric.stats().total_bits, 0);
    }
}
