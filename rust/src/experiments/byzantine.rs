//! Byzantine sweep: adversary model x compressor x aggregation rule.
//!
//! Ghosh et al.-style composition test for this engine: error feedback
//! fixes what *compression* throws away, but a plain mean is still a
//! single hostile frame away from ruin. This experiment runs the
//! noise-free quadratic (every honest worker agrees on the gradient, so
//! any damage is attributable to the adversary alone) under the seeded
//! worker models of [`crate::net::adversary`], and sweeps the leader's
//! combine rule across the robust aggregators of PR 7.
//!
//! Shape to observe (asserted by the `#[cfg(test)]` module under the
//! same fixed seed the CI run uses):
//!
//! * `mean` + 25% sign-flippers: the flipped frames cancel half the
//!   honest mass, the contraction rate halves, and the tail loss lands
//!   orders of magnitude above the clean run (>= 10x asserted).
//! * `median` / `trimmed:2` + the same adversary: the hostile frames are
//!   outliers in every coordinate, the robust rules ignore them, and the
//!   tail loss stays within 2x of that rule's own clean run (or below an
//!   absolute convergence floor two orders under the initial loss).
//! * `norm_threshold` + norm-inflators: the inflated frames trip the
//!   2x-median-norm gate and are excluded; the same inflators push the
//!   plain mean to overflow (non-finite loss).
//! * `randombytes` scribbling is mostly absorbed by the hardened wire
//!   path: undecodable frames are dropped and counted, the survivors are
//!   averaged (reported, not asserted — a scribbled frame that happens to
//!   parse is still poison for `mean`, which is the point of the column).

use super::{ExpContext, ExpResult};
use crate::config::CompressorKind;
use crate::coordinator::driver::{DriverConfig, UpdateRule};
use crate::coordinator::worker::{ObjectiveSource, Worker, WorkerMode};
use crate::coordinator::{Aggregation, LrSchedule, TrainDriver};
use crate::metrics::Recorder;
use crate::model::toy::SparseNoiseQuadratic;
use crate::net::AdversarySchedule;
use crate::util::Pcg64;
use anyhow::Result;

const D: usize = 128;
const WORKERS: usize = 8;
const GAMMA: f64 = 5e-2;
/// f(theta0) = 1/2 * ||1||^2 = d/2.
const L0: f64 = D as f64 * 0.5;
/// Absolute "this run converged" floor: two orders below the initial
/// loss. The 2x-of-clean comparisons compound over a geometric decay, so
/// a run that is already deep in the basin gets an absolute pass.
const CONVERGED: f64 = L0 / 100.0;

const AGGREGATORS: [(&str, Aggregation); 4] = [
    ("mean", Aggregation::Mean),
    ("median", Aggregation::Median),
    ("trimmed2", Aggregation::TrimmedMean(2)),
    ("normthresh", Aggregation::NormThreshold),
];

const COMPRESSORS: [(&str, CompressorKind); 2] = [
    ("scaled_sign", CompressorKind::ScaledSign),
    ("qsgd", CompressorKind::Qsgd),
];

/// (column label, `--adversary` spec) — the spec strings go through the
/// same `AdversarySchedule::parse_spec` path the CLI uses.
const ADVERSARIES: [(&str, &str); 4] = [
    ("clean", "none"),
    ("flip25", "signflip:0.25"),
    ("inflate25", "norminflate:0.25:1000"),
    ("bytes25", "randombytes:0.25"),
];

pub const FLIP_FRACTIONS: [f64; 4] = [0.0, 0.125, 0.25, 0.375];

/// One synchronous EF run; returns the tail-mean loss (last quarter of
/// the trajectory), with any non-finite trajectory collapsed to +inf so
/// divergence compares cleanly.
fn run_one(
    kind: CompressorKind,
    aggregation: Aggregation,
    adversary_spec: &str,
    steps: usize,
    seed: u64,
) -> f64 {
    let workers: Vec<Worker> = (0..WORKERS)
        .map(|id| {
            Worker::new(
                id,
                Box::new(ObjectiveSource::new(
                    SparseNoiseQuadratic::new(D, 0.0),
                    Pcg64::new(seed, 1000 + id as u64),
                )),
                WorkerMode::ErrorFeedback,
                kind,
                4,
                4,
                Pcg64::new(seed, id as u64),
            )
        })
        .collect();
    let cfg = DriverConfig {
        steps,
        schedule: LrSchedule::constant(GAMMA),
        aggregation,
        update_rule: UpdateRule::ApplyAggregate,
        adversary: AdversarySchedule::parse_spec(adversary_spec, seed)
            .expect("experiment adversary specs are valid"),
        ..Default::default()
    };
    let out = TrainDriver::new(cfg, workers, vec![1.0f32; D]).run();
    let losses = &out.recorder.get("train_loss").unwrap().values;
    let tail = &losses[losses.len() * 3 / 4..];
    let mean = tail.iter().sum::<f64>() / tail.len() as f64;
    if mean.is_finite() {
        mean
    } else {
        f64::INFINITY
    }
}

fn cell(v: f64) -> String {
    if v.is_finite() {
        format!("{v:>11.3e}")
    } else {
        format!("{:>11}", "diverged")
    }
}

pub fn byzantine(ctx: &ExpContext) -> Result<ExpResult> {
    let steps = if ctx.quick { 120 } else { 240 };

    let mut rec = Recorder::new();
    rec.tag("experiment", "byzantine");
    let mut lines = vec![format!(
        "== Byzantine sweep: {WORKERS} workers (EF), f(x)=0.5*||x||^2 d={D}, \
         gamma={GAMMA}, {steps} rounds =="
    )];
    lines.push(format!(
        "  {:<12} {:<11} {:>11} {:>11} {:>11} {:>11}",
        "compressor", "aggregation", "clean", "flip:0.25", "inflate:0.25", "bytes:0.25"
    ));

    for &(kname, kind) in &COMPRESSORS {
        for &(aname, agg) in &AGGREGATORS {
            let mut row = Vec::with_capacity(ADVERSARIES.len());
            for (ai, &(alabel, spec)) in ADVERSARIES.iter().enumerate() {
                let loss = run_one(kind, agg, spec, steps, ctx.seed);
                rec.record(&format!("tail_{kname}_{aname}_{alabel}"), ai as u64, loss);
                row.push(loss);
            }
            lines.push(format!(
                "  {:<12} {:<11} {} {} {} {}",
                kname, aname, cell(row[0]), cell(row[1]), cell(row[2]), cell(row[3])
            ));
        }
    }

    lines.push(
        "  shape: mean loses half its contraction rate to 25% sign-flippers and lands\n  \
         orders of magnitude high (norm-inflators push it to overflow outright);\n  \
         median/trimmed track their own clean runs, and norm_threshold gates the\n  \
         inflated frames at 2x the median live norm. Sign-flips preserve frame norms,\n  \
         so norm_threshold is (by design) blind to them — rule choice matters."
            .into(),
    );

    // Sign-flip fraction sweep: where does each rule break? Median holds
    // up to (but not including) half the quorum; trimmed:2 tolerates
    // exactly its trim budget; mean degrades from the first flipped frame.
    lines.push(format!("  -- sign-flip fraction sweep (scaled_sign, EF, {steps} rounds) --"));
    lines.push(format!(
        "  {:<12} {:>11} {:>11} {:>11} {:>11}",
        "aggregation", "f=0", "f=0.125", "f=0.25", "f=0.375"
    ));
    for &(aname, agg) in &AGGREGATORS[..3] {
        let mut row = Vec::with_capacity(FLIP_FRACTIONS.len());
        for (fi, &f) in FLIP_FRACTIONS.iter().enumerate() {
            let spec = format!("signflip:{f}");
            let loss = run_one(CompressorKind::ScaledSign, agg, &spec, steps, ctx.seed);
            rec.record(&format!("flipsweep_{aname}"), fi as u64, loss);
            row.push(loss);
        }
        lines.push(format!(
            "  {:<12} {} {} {} {}",
            aname, cell(row[0]), cell(row[1]), cell(row[2]), cell(row[3])
        ));
    }

    Ok(ExpResult {
        id: "byzantine",
        summary: lines.join("\n"),
        recorders: vec![("sweep".into(), rec)],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const STEPS: usize = 120;
    const SEED: u64 = 7;

    /// The acceptance shape, per compressor: plain mean at 25% sign-flip
    /// diverges or lands >= 10x its clean loss; EF + median / trimmed:2
    /// stay within 2x of their own clean runs (or below the absolute
    /// convergence floor).
    #[test]
    fn ef_plus_robust_aggregation_survives_sign_flips() {
        for &(kname, kind) in &COMPRESSORS {
            let clean_mean = run_one(kind, Aggregation::Mean, "none", STEPS, SEED);
            assert!(
                clean_mean.is_finite() && clean_mean < CONVERGED,
                "{kname}: clean mean baseline did not converge: {clean_mean}"
            );
            let adv_mean = run_one(kind, Aggregation::Mean, "signflip:0.25", STEPS, SEED);
            assert!(
                !adv_mean.is_finite() || adv_mean >= 10.0 * clean_mean,
                "{kname}: mean should be wrecked by 25% sign-flips: \
                 adversarial {adv_mean} vs clean {clean_mean}"
            );
            for (aname, agg) in [
                ("median", Aggregation::Median),
                ("trimmed2", Aggregation::TrimmedMean(2)),
            ] {
                let clean = run_one(kind, agg, "none", STEPS, SEED);
                let adv = run_one(kind, agg, "signflip:0.25", STEPS, SEED);
                assert!(
                    adv.is_finite() && (adv <= 2.0 * clean || adv <= CONVERGED),
                    "{kname}+{aname}: robust rule should shrug off 25% sign-flips: \
                     adversarial {adv} vs clean {clean}"
                );
            }
        }
    }

    /// Norm inflation x1000 overflows the plain mean but is gated by
    /// norm_threshold's 2x-median-norm filter.
    #[test]
    fn norm_threshold_survives_inflation_that_kills_the_mean() {
        let kind = CompressorKind::ScaledSign;
        let clean_mean = run_one(kind, Aggregation::Mean, "none", STEPS, SEED);
        let adv_mean = run_one(kind, Aggregation::Mean, "norminflate:0.25:1000", STEPS, SEED);
        assert!(
            !adv_mean.is_finite() || adv_mean >= 10.0 * clean_mean,
            "mean should be wrecked by x1000 norm inflation: {adv_mean} vs {clean_mean}"
        );
        let clean = run_one(kind, Aggregation::NormThreshold, "none", STEPS, SEED);
        let adv = run_one(kind, Aggregation::NormThreshold, "norminflate:0.25:1000", STEPS, SEED);
        assert!(
            adv.is_finite() && (adv <= 2.0 * clean || adv <= CONVERGED),
            "norm_threshold should gate inflated frames: adversarial {adv} vs clean {clean}"
        );
    }

    /// Trim-0 routes through the robust kernel but must replay the mean
    /// trajectory bit-for-bit (same worker-id summation order, same
    /// 1/live scaling); the other robust rules converge on their own.
    #[test]
    fn trim_zero_clean_replays_the_mean_bit_for_bit() {
        let kind = CompressorKind::ScaledSign;
        let mean = run_one(kind, Aggregation::Mean, "none", STEPS, SEED);
        let trim0 = run_one(kind, Aggregation::TrimmedMean(0), "none", STEPS, SEED);
        assert_eq!(
            trim0.to_bits(), mean.to_bits(),
            "trim-0 must replay the mean bit-for-bit: {trim0} vs {mean}"
        );
        for agg in [Aggregation::Median, Aggregation::NormThreshold] {
            let v = run_one(kind, agg, "none", STEPS, SEED);
            assert!(
                v.is_finite() && v < CONVERGED,
                "{agg:?} failed to converge on the clean problem: {v}"
            );
        }
    }
}
