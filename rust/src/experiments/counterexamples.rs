//! §3: the convex counterexamples where SIGNSGD provably fails, and the
//! demonstration that error feedback fixes each of them.
//!
//! Expected shapes (paper):
//! * CE1 — E[f] *increases* under SIGNSGD at rate +γ/8 per step while SGD
//!   decreases at −γ/16; EF-SIGNSGD decreases.
//! * CE2/Fig1 — SIGNSGD iterates stay on the line x₁+x₂ = 2 (f never drops
//!   below f(x₀)); SGD and EF-SIGNSGD reach f → 0.
//! * CE3 — same trap in the smooth stochastic setting, almost surely.
//! * Thm I — over random inits, SIGNSGD's final distance to x* stays
//!   bounded away from 0 while EF-SIGNSGD's goes to ~0.

use super::{ExpContext, ExpResult};
use crate::metrics::{sparkline, Recorder};
use crate::model::toy::{Ce1Linear, Ce2NonSmooth, Ce3LeastSquares, SharedSignTheorem1};
use crate::model::StochasticObjective;
use crate::optim;
use crate::util::Pcg64;
use anyhow::Result;

fn run_algo(
    obj: &dyn StochasticObjective,
    algo: &str,
    lr: f32,
    steps: usize,
    x0: &[f32],
    seed: u64,
    project: Option<fn(&mut [f32])>,
    rec: &mut Recorder,
    prefix: &str,
) -> f64 {
    let d = obj.dim();
    let mut opt = optim::build(algo, d, lr, 0.9, seed).unwrap();
    let mut x = x0.to_vec();
    let mut g = vec![0.0f32; d];
    let mut rng = Pcg64::seeded(seed);
    let record_every = (steps / 200).max(1);
    for t in 0..steps {
        obj.stoch_grad(&x, &mut rng, &mut g);
        opt.step(&mut x, &g);
        if let Some(p) = project {
            p(&mut x);
        }
        if t % record_every == 0 {
            rec.record(&format!("{prefix}_{algo}"), t as u64, obj.loss(&x));
        }
    }
    obj.loss(&x)
}

/// Counterexample 1: 1-D linear with bimodal noise, constrained to [−1,1].
pub fn ce1(ctx: &ExpContext) -> Result<ExpResult> {
    let steps = if ctx.quick { 2_000 } else { 20_000 };
    let gamma = 0.01f32;
    let obj = Ce1Linear;
    let mut rec = Recorder::new();
    rec.tag("experiment", "ce1");
    let mut lines = vec![format!(
        "== CE1: f(x)=x/4 on [-1,1], bimodal g (gamma={gamma}, {steps} steps) =="
    )];
    let mut finals = Vec::new();
    for algo in ["sgd", "signsgd_unscaled", "ef_signsgd"] {
        let f = run_algo(
            &obj,
            algo,
            gamma,
            steps,
            &[0.0],
            ctx.seed + 1,
            Some(Ce1Linear::project),
            &mut rec,
            "f",
        );
        let series = rec.get(&format!("f_{algo}")).unwrap().values.clone();
        lines.push(format!(
            "  {algo:<18} final f = {f:+.4}   {}",
            sparkline(&series, 40)
        ));
        finals.push((algo, f));
    }
    lines.push(format!(
        "  paper shape: signSGD climbs toward f(+1)=+0.25; SGD & EF reach f(-1)={:.2}",
        Ce1Linear::OPT
    ));
    let sign_f = finals.iter().find(|(a, _)| *a == "signsgd_unscaled").unwrap().1;
    let ef_f = finals.iter().find(|(a, _)| *a == "ef_signsgd").unwrap().1;
    lines.push(format!(
        "  check: signSGD stuck high ({}) , EF converged ({})",
        sign_f > 0.2,
        ef_f < -0.2
    ));
    Ok(ExpResult {
        id: "ce1",
        summary: lines.join("\n"),
        recorders: vec![("trajectories".into(), rec)],
    })
}

/// Counterexample 2 / Fig. 1: the non-smooth trap.
pub fn ce2(ctx: &ExpContext) -> Result<ExpResult> {
    let steps = if ctx.quick { 2_000 } else { 20_000 };
    let obj = Ce2NonSmooth::new(0.5);
    // Start a hair off the diagonal: at exactly x1 = x2 the subgradient of
    // |x1-x2| is set-valued and the paper's sign(g) = ±(1,-1) claim is the
    // generic (a.s.) case. The invariant x1+x2 = 2 is unaffected.
    let x0 = [1.017f32, 0.983];
    let mut rec = Recorder::new();
    rec.tag("experiment", "ce2");
    let mut lines = vec![format!(
        "== CE2 (Fig 1): f = 0.5|x1+x2| + |x1-x2|, x0=(1.017,0.983), full subgradient =="
    )];
    // For signSGD also track the invariant x1+x2.
    for algo in ["sgd", "signsgd_unscaled", "ef_signsgd"] {
        let d = obj.dim();
        // decaying step-size (the paper says *any* schedule fails for sign)
        let mut x = x0.to_vec();
        let mut g = vec![0.0f32; d];
        let mut rng = Pcg64::seeded(ctx.seed + 2);
        let mut opt = optim::build(algo, d, 0.05, 0.9, ctx.seed).unwrap();
        let record_every = (steps / 200).max(1);
        for t in 0..steps {
            opt.set_lr(0.05 / (1.0 + t as f32 / 100.0).sqrt());
            obj.stoch_grad(&x, &mut rng, &mut g);
            opt.step(&mut x, &g);
            if t % record_every == 0 {
                rec.record(&format!("f_{algo}"), t as u64, obj.loss(&x));
                rec.record(&format!("sum_{algo}"), t as u64, (x[0] + x[1]) as f64);
            }
        }
        let series = rec.get(&format!("f_{algo}")).unwrap().values.clone();
        lines.push(format!(
            "  {algo:<18} final f = {:.4}  x1+x2 = {:+.4}   {}",
            obj.loss(&x),
            x[0] + x[1],
            sparkline(&series, 40)
        ));
    }
    lines.push(
        "  paper shape: signSGD keeps x1+x2 = 2 exactly (f >= f(x0) = 1.0); EF escapes to 0"
            .into(),
    );
    Ok(ExpResult {
        id: "ce2",
        summary: lines.join("\n"),
        recorders: vec![("trajectories".into(), rec)],
    })
}

/// Counterexample 3: smooth stochastic least squares, same trap.
pub fn ce3(ctx: &ExpContext) -> Result<ExpResult> {
    let steps = if ctx.quick { 3_000 } else { 30_000 };
    let obj = Ce3LeastSquares::new(0.5);
    let x0 = [1.0f32, 1.0];
    let mut rec = Recorder::new();
    rec.tag("experiment", "ce3");
    let mut lines = vec![
        "== CE3: stochastic least squares a_{1,2} = ±(1,-1)+0.5(1,1), batch 1 ==".to_string(),
    ];
    for algo in ["sgd", "signsgd_unscaled", "ef_signsgd"] {
        let f = run_algo(
            &obj,
            algo,
            0.02,
            steps,
            &x0,
            ctx.seed + 3,
            None,
            &mut rec,
            "f",
        );
        let series = rec.get(&format!("f_{algo}")).unwrap().values.clone();
        lines.push(format!(
            "  {algo:<18} final f = {f:.6}   {}",
            sparkline(&series, 40)
        ));
    }
    lines.push("  paper shape: signSGD trapped at f >= f(x0) a.s.; SGD & EF -> 0".into());
    Ok(ExpResult {
        id: "ce3",
        summary: lines.join("\n"),
        recorders: vec![("trajectories".into(), rec)],
    })
}

/// Theorem I: shared-sign data rows in general dimension — SIGNSGD cannot
/// reach x* from (almost) any random init; EF-SIGNSGD can.
pub fn thm1(ctx: &ExpContext) -> Result<ExpResult> {
    let steps = if ctx.quick { 3_000 } else { 20_000 };
    let inits = if ctx.quick { 5 } else { 20 };
    let (n, d) = (12, 6);
    let mut rec = Recorder::new();
    rec.tag("experiment", "thm1");
    let mut lines = vec![format!(
        "== Theorem I: n={n} rows with shared sign pattern, d={d}, {inits} random inits =="
    )];
    let mut gen_rng = Pcg64::seeded(ctx.seed + 11);
    let obj = SharedSignTheorem1::new(n, d, &mut gen_rng);
    for algo in ["signsgd_unscaled", "ef_signsgd"] {
        let mut final_losses = Vec::new();
        for init in 0..inits {
            let mut init_rng = Pcg64::seeded(ctx.seed + 100 + init);
            let mut x0 = vec![0.0f32; d];
            init_rng.fill_normal(&mut x0, 0.0, 1.0);
            let mut x = x0.clone();
            let mut g = vec![0.0f32; d];
            let mut opt = optim::build(algo, d, 0.005, 0.9, ctx.seed + init).unwrap();
            let mut rng = Pcg64::seeded(ctx.seed + 200 + init);
            for t in 0..steps {
                // decaying schedule; Thm I says no schedule can save signSGD
                opt.set_lr(0.005 / (1.0 + t as f32 / 200.0).sqrt());
                obj.stoch_grad(&x, &mut rng, &mut g);
                opt.step(&mut x, &g);
            }
            final_losses.push(obj.loss(&x));
            rec.record(&format!("final_{algo}"), init, obj.loss(&x));
        }
        let mean = crate::util::stats::mean(&final_losses);
        let min = final_losses.iter().cloned().fold(f64::INFINITY, f64::min);
        lines.push(format!(
            "  {algo:<18} final loss over inits: mean {mean:.4e}  min {min:.4e}"
        ));
    }
    lines.push(
        "  paper shape: signSGD's loss floor stays >> 0 a.s. (iterates confined to x0 ± span(s));\n  EF-SIGNSGD reaches ~0 from every init"
            .into(),
    );
    Ok(ExpResult {
        id: "thm1",
        summary: lines.join("\n"),
        recorders: vec![("finals".into(), rec)],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ce1_shape_holds_quick() {
        let r = ce1(&ExpContext::quick()).unwrap();
        assert!(r.summary.contains("signSGD stuck high (true) , EF converged (true)"));
    }

    #[test]
    fn ce2_invariant_holds_quick() {
        let r = ce2(&ExpContext::quick()).unwrap();
        let rec = &r.recorders[0].1;
        // signSGD's x1+x2 stays 2 to machine precision
        let sum = rec.get("sum_signsgd_unscaled").unwrap();
        for v in &sum.values {
            assert!((v - 2.0).abs() < 1e-4, "invariant broken: {v}");
        }
        // EF escapes the line
        let ef_f = rec.get("f_ef_signsgd").unwrap().last().unwrap();
        assert!(ef_f < 0.1, "EF final loss {ef_f}");
        let sign_f = rec.get("f_signsgd_unscaled").unwrap().last().unwrap();
        assert!(sign_f >= 0.9, "sign final loss {sign_f}");
    }

    #[test]
    fn ce3_shape_quick() {
        let r = ce3(&ExpContext::quick()).unwrap();
        let rec = &r.recorders[0].1;
        assert!(rec.get("f_signsgd_unscaled").unwrap().last().unwrap() > &0.9 * &1.0);
        assert!(rec.get("f_ef_signsgd").unwrap().last().unwrap() < 0.05);
    }

    #[test]
    fn thm1_gap_quick() {
        let r = thm1(&ExpContext::quick()).unwrap();
        let rec = &r.recorders[0].1;
        let sign_min = rec
            .get("final_signsgd_unscaled")
            .unwrap()
            .min()
            .unwrap();
        let ef_max = rec.get("final_ef_signsgd").unwrap().max().unwrap();
        assert!(
            sign_min > 10.0 * ef_max.max(1e-9),
            "sign_min {sign_min} vs ef_max {ef_max}"
        );
    }
}
