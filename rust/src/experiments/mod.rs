//! Experiment drivers: one module per table/figure of the paper.
//!
//! Every experiment is runnable as `repro exp <id>` (see the table in
//! DESIGN.md §4), prints the paper-shaped summary to stdout, and writes its
//! raw series as CSV/JSON under `--out` (default `results/`). `--quick`
//! shrinks sizes for CI; the full settings regenerate EXPERIMENTS.md.

pub mod ablation;
pub mod byzantine;
pub mod churn;
pub mod cifar_sim;
pub mod comm;
pub mod counterexamples;
pub mod density;
pub mod error_bound;
pub mod genspan;
pub mod lr_tuning;
pub mod qsgd_ef;
pub mod sparse_noise;
pub mod staleness;

use crate::metrics::Recorder;
use anyhow::{bail, Result};
use std::path::PathBuf;

/// Shared experiment context.
#[derive(Clone, Debug)]
pub struct ExpContext {
    pub quick: bool,
    pub seed: u64,
    pub out_dir: PathBuf,
    pub artifacts_dir: PathBuf,
}

impl Default for ExpContext {
    fn default() -> Self {
        ExpContext {
            quick: false,
            seed: 0,
            out_dir: PathBuf::from("results"),
            artifacts_dir: PathBuf::from("artifacts"),
        }
    }
}

impl ExpContext {
    pub fn quick() -> Self {
        ExpContext {
            quick: true,
            ..Default::default()
        }
    }
}

/// The output of one experiment: a human summary (also printed) plus named
/// recorders whose series are written as `<id>_<name>.csv`.
pub struct ExpResult {
    pub id: &'static str,
    pub summary: String,
    pub recorders: Vec<(String, Recorder)>,
}

impl ExpResult {
    pub fn write(&self, ctx: &ExpContext) -> std::io::Result<()> {
        std::fs::create_dir_all(&ctx.out_dir)?;
        for (name, rec) in &self.recorders {
            rec.write_csv(&ctx.out_dir.join(format!("{}_{name}.csv", self.id)))?;
            rec.write_json(&ctx.out_dir.join(format!("{}_{name}.json", self.id)))?;
        }
        std::fs::write(
            ctx.out_dir.join(format!("{}_summary.txt", self.id)),
            &self.summary,
        )
    }
}

/// All experiment ids, in paper order.
pub const ALL: &[&str] = &[
    "ce1", "ce2", "ce3", "thm1", "fig2", "fig3", "fig4", "fig5", "fig7", "table2", "rem5",
    "comm", "lemma3", "ablation", "staleness", "byzantine", "churn",
];

/// Run an experiment by id (prints the summary and writes results).
pub fn run(id: &str, ctx: &ExpContext) -> Result<ExpResult> {
    let result = match id {
        "ce1" => counterexamples::ce1(ctx),
        "ce2" => counterexamples::ce2(ctx),
        "ce3" => counterexamples::ce3(ctx),
        "thm1" => counterexamples::thm1(ctx),
        "fig2" => density::fig2(ctx),
        "fig3" => genspan::fig3(ctx),
        "fig4" => cifar_sim::fig4(ctx),
        "fig5" => sparse_noise::fig5(ctx),
        "fig7" => cifar_sim::fig7(ctx),
        "table2" => lr_tuning::table2(ctx),
        "rem5" => qsgd_ef::rem5(ctx),
        "comm" => comm::comm(ctx),
        "lemma3" => error_bound::lemma3(ctx),
        "ablation" => ablation::ablation(ctx),
        "staleness" => staleness::staleness(ctx),
        "byzantine" => byzantine::byzantine(ctx),
        "churn" => churn::churn(ctx),
        other => bail!("unknown experiment '{other}'; known: {}", ALL.join(" ")),
    };
    let result = result?;
    println!("{}", result.summary);
    result.write(ctx)?;
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_id_errors() {
        assert!(run("nope", &ExpContext::quick()).is_err());
    }
}
