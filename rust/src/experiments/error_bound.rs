//! Lemma 3: the EF residual is bounded, E‖e_t‖² ≤ 4(1−δ)γ²σ²/δ².
//! We measure sup_t ‖e_t‖² over long runs for several compressors and
//! compare against the bound with the empirical δ and σ², and check the
//! γ² scaling (halving γ quarters the residual energy).

use super::{ExpContext, ExpResult};
use crate::compress::{self, Compressor, ErrorFeedback};
use crate::metrics::Recorder;
use crate::obs::HistSnapshot;
use crate::util::Pcg64;
use anyhow::Result;

/// Drive EF with unit-gaussian gradients; returns (sup ||e_t||², σ²) plus
/// the log2 histogram of ‖e_t‖ in milli-units (same encoding the run-time
/// metrics registry uses), so the report can show the residual
/// distribution, not just its supremum.
fn run_residual(
    comp: Box<dyn Compressor>,
    d: usize,
    gamma: f32,
    steps: usize,
    seed: u64,
) -> (f64, f64, HistSnapshot) {
    let mut ef = ErrorFeedback::new(d, comp);
    let mut rng = Pcg64::seeded(seed);
    let mut g = vec![0.0f32; d];
    let mut delta = vec![0.0f32; d];
    let mut sup = 0.0f64;
    let mut hist = HistSnapshot::new();
    let sigma_sq = d as f64; // E||g||^2 for unit gaussians
    for _ in 0..steps {
        rng.fill_normal(&mut g, 0.0, 1.0);
        ef.step_into(gamma, &g, &mut delta, &mut rng);
        let norm = ef.error_norm();
        sup = sup.max(norm.powi(2));
        hist.observe((norm * 1e3) as u64);
    }
    (sup, sigma_sq, hist)
}

pub fn lemma3(ctx: &ExpContext) -> Result<ExpResult> {
    let d = 512;
    let steps = if ctx.quick { 500 } else { 5_000 };
    let mut rec = Recorder::new();
    rec.tag("experiment", "lemma3");
    let mut lines = vec![format!(
        "== Lemma 3: sup_t ||e_t||^2 vs bound 4(1-d)g^2 s^2/d^2  (d={d}, {steps} steps) =="
    )];

    let cases: Vec<(&str, Box<dyn Compressor>, f64)> = vec![
        ("scaled_sign", Box::new(compress::ScaledSign), 0.55),
        ("topk_1/4", Box::new(compress::TopK::count(d / 4)), 0.25),
        ("topk_1/16", Box::new(compress::TopK::count(d / 16)), 1.0 / 16.0),
    ];

    let gamma = 0.05f32;
    for (name, comp, delta_lb) in cases {
        let (sup, sigma_sq, hist) = run_residual(comp, d, gamma, steps, ctx.seed);
        let bound =
            4.0 * (1.0 - delta_lb) * (gamma as f64).powi(2) * sigma_sq / (delta_lb * delta_lb);
        rec.record(&format!("sup_{name}"), 0, sup);
        rec.record(&format!("bound_{name}"), 0, bound);
        rec.record(&format!("mean_milli_{name}"), 0, hist.mean());
        lines.push(format!(
            "  {name:<12} delta>={delta_lb:<6.3} sup||e||^2 = {sup:10.4}  bound = {bound:10.4}  within: {}",
            sup <= bound
        ));
        lines.push(format!(
            "  {:<12} residual dist: mean ||e|| = {:.4}, top log2 bucket = {}  ({} samples)",
            "",
            hist.mean() / 1e3,
            hist.max_bucket().unwrap_or(0),
            hist.count
        ));
    }

    // gamma^2 scaling: sup||e||^2 at gamma vs gamma/2
    let (s1, _, _) = run_residual(Box::new(compress::ScaledSign), d, 0.05, steps, ctx.seed + 1);
    let (s2, _, _) = run_residual(Box::new(compress::ScaledSign), d, 0.025, steps, ctx.seed + 1);
    let ratio = s1 / s2;
    rec.record("gamma_scaling_ratio", 0, ratio);
    lines.push(format!(
        "  gamma-scaling: sup||e||^2(g)/(sup||e||^2(g/2)) = {ratio:.2} (Lemma 3 predicts 4)"
    ));
    lines.push("  paper shape: residual stays bounded and scales as gamma^2 — EF never lets\n  the compression error accumulate unboundedly.".into());
    Ok(ExpResult {
        id: "lemma3",
        summary: lines.join("\n"),
        recorders: vec![("bounds".into(), rec)],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn residuals_within_bounds_quick() {
        let r = lemma3(&ExpContext::quick()).unwrap();
        assert!(!r.summary.contains("within: false"), "{}", r.summary);
    }

    #[test]
    fn gamma_squared_scaling_quick() {
        let r = lemma3(&ExpContext::quick()).unwrap();
        let rec = &r.recorders[0].1;
        let ratio = rec.get("gamma_scaling_ratio").unwrap().last().unwrap();
        assert!((2.5..6.0).contains(&ratio), "ratio {ratio}");
    }
}
