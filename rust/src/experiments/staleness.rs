//! Staleness sweep: EF-SGD vs (plain) SIGNSGD vs (plain) QSGD under
//! bounded-staleness async rounds with increasingly severe stragglers.
//!
//! The paper argues the EF residual makes compressed SGD robust to
//! whatever the system drops or delays; the synchronous engine never
//! tested the "delays" half. This experiment runs the Theorem-1
//! shared-sign least-squares family — the regime where plain SIGNSGD is
//! structurally trapped on a line while EF escapes — on the async driver
//! (quorum 4 of 8, staleness bound 3) and sweeps the lognormal straggler
//! severity σ. Reported per method and severity: the tail-mean loss, its
//! degradation versus the σ = 0 (tie-broken, effectively synchronous)
//! baseline, the stale-frame fraction, and the virtual-clock runtime.
//!
//! Shape to observe (asserted by the `staleness_sweep_*` integration
//! test): EF-SGD's loss degrades strictly less than SIGNSGD's at every
//! severity — late frames still carry the residual-corrected delta, so
//! delayed application costs EF little, while the sign baseline's trap
//! oscillation grows with the injected staleness.

use super::{ExpContext, ExpResult};
use crate::config::CompressorKind;
use crate::coordinator::driver::{DriverConfig, UpdateRule};
use crate::coordinator::worker::{ObjectiveSource, Worker, WorkerMode};
use crate::coordinator::{AsyncTrainDriver, LrSchedule};
use crate::metrics::Recorder;
use crate::model::toy::SharedSignTheorem1;
use crate::net::message::FRAME_OVERHEAD_BITS;
use crate::net::{LinkModel, StragglerModel, StragglerSchedule};
use crate::util::Pcg64;
use anyhow::Result;

/// Problem + engine constants, pre-validated against a reference
/// simulation: EF's degradation measured ~10x below SIGNSGD's across
/// seeds (the integration test asserts the conservative >4x loss gap
/// plus strict degradation ordering).
const D: usize = 16;
const ROWS: usize = 32;
const WORKERS: usize = 8;
const QUORUM: usize = 4;
const MAX_STALENESS: u64 = 3;
const GAMMA: f64 = 1e-3;
const BASE_COMPUTE_S: f64 = 1e-3;

pub const SEVERITIES: [f64; 4] = [0.0, 0.5, 1.0, 1.5];

struct MethodSpec {
    name: &'static str,
    mode: WorkerMode,
    kind: CompressorKind,
}

const METHODS: [MethodSpec; 3] = [
    MethodSpec {
        name: "ef_sign",
        mode: WorkerMode::ErrorFeedback,
        kind: CompressorKind::ScaledSign,
    },
    MethodSpec {
        name: "signsgd",
        mode: WorkerMode::PlainCompress,
        kind: CompressorKind::ScaledSign,
    },
    MethodSpec {
        name: "qsgd",
        mode: WorkerMode::PlainCompress,
        kind: CompressorKind::Qsgd,
    },
];

struct RunStats {
    tail_loss: f64,
    stale_fraction: f64,
    sim_time_s: f64,
}

/// One async run; `rep` seeds both the problem instance and the RNG
/// streams so every (method, severity) cell sees identical data.
fn run_one(spec: &MethodSpec, sigma: f64, steps: usize, rep: u64, base_seed: u64) -> RunStats {
    let obj_seed = base_seed + 9000 + rep;
    let workers: Vec<Worker> = (0..WORKERS)
        .map(|id| {
            // identical rows for every worker/method/severity of this rep:
            // the constructor is deterministic in its RNG
            let obj = SharedSignTheorem1::new(ROWS, D, &mut Pcg64::seeded(obj_seed));
            Worker::new(
                id,
                Box::new(ObjectiveSource::new(
                    obj,
                    Pcg64::new(base_seed + rep, 1000 + id as u64),
                )),
                spec.mode,
                spec.kind,
                4,
                4,
                Pcg64::new(base_seed + rep, id as u64),
            )
        })
        .collect();
    let cfg = DriverConfig {
        steps,
        schedule: LrSchedule::constant(GAMMA),
        update_rule: UpdateRule::ApplyAggregate,
        straggler: StragglerSchedule::new(
            BASE_COMPUTE_S,
            StragglerModel::LogNormal { sigma },
            base_seed + rep,
        ),
        ..Default::default()
    };
    let out = AsyncTrainDriver::new(cfg, QUORUM, MAX_STALENESS, workers, vec![1.0f32; D]).run();
    let losses = &out.recorder.get("train_loss").unwrap().values;
    let tail = &losses[losses.len() * 3 / 4..];
    RunStats {
        tail_loss: tail.iter().sum::<f64>() / tail.len() as f64,
        stale_fraction: out.staleness.stale_fraction(),
        sim_time_s: out.sim_time_s,
    }
}

pub fn staleness(ctx: &ExpContext) -> Result<ExpResult> {
    let steps = if ctx.quick { 300 } else { 600 };
    let reps = if ctx.quick { 2 } else { 3 };

    let mut rec = Recorder::new();
    rec.tag("experiment", "staleness");
    let mut lines = vec![format!(
        "== Staleness sweep: async quorum {QUORUM}/{WORKERS}, bound S={MAX_STALENESS}, \
         shared-sign least squares d={D}, {steps} rounds x {reps} reps =="
    )];
    // the stale% / sim-time columns report the harshest severity only
    lines.push(format!(
        "  {:<9} {:>10} {:>10} {:>10} {:>10} {:>12} {:>14}",
        "method", "sigma=0", "sigma=.5", "sigma=1", "sigma=1.5", "stale%@1.5", "sim-time@1.5"
    ));

    for spec in &METHODS {
        let mut finals = Vec::with_capacity(SEVERITIES.len());
        let mut last_stats: Option<(f64, f64)> = None;
        for (si, &sigma) in SEVERITIES.iter().enumerate() {
            let mut loss = 0.0f64;
            let mut stale = 0.0f64;
            let mut sim = 0.0f64;
            for rep in 0..reps {
                let s = run_one(spec, sigma, steps, rep as u64, ctx.seed);
                loss += s.tail_loss;
                stale += s.stale_fraction;
                sim += s.sim_time_s;
            }
            loss /= reps as f64;
            stale /= reps as f64;
            sim /= reps as f64;
            rec.record(&format!("final_{}", spec.name), si as u64, loss);
            rec.record(&format!("stale_frac_{}", spec.name), si as u64, stale);
            rec.record(&format!("sim_time_{}", spec.name), si as u64, sim);
            finals.push(loss);
            last_stats = Some((stale, sim));
        }
        for (si, f) in finals.iter().enumerate().skip(1) {
            rec.record(&format!("deg_{}", spec.name), si as u64, f - finals[0]);
        }
        let (stale, sim) = last_stats.unwrap();
        lines.push(format!(
            "  {:<9} {:>10.3e} {:>10.3e} {:>10.3e} {:>10.3e} {:>11.1}% {:>13.2}s",
            spec.name, finals[0], finals[1], finals[2], finals[3], 100.0 * stale, sim
        ));
    }
    lines.push(
        "  shape: EF's degradation (loss vs sigma=0) stays ~10x below plain SIGNSGD's —\n  \
         late frames still deliver the residual-corrected delta, so bounded staleness\n  \
         costs error feedback almost nothing, while the sign baseline's trap\n  \
         oscillation is amplified by every stale fold (Theorem 1 vs Theorem II)."
            .into(),
    );

    // The compression x latency crossover the wan() preset exists for:
    // per-round push time, dense vs scaled-sign frames, on a datacenter
    // link vs the WAN. On the WAN the 20 ms latency floor swallows the
    // 32x bit reduction at small d — compression only pays once the dense
    // transfer itself dwarfs the latency.
    lines.push("  -- compression x latency (per-round gradient push) --".into());
    for (lname, link) in [("10gbe", LinkModel::ten_gbe()), ("wan", LinkModel::wan())] {
        for d in [4096usize, 262_144] {
            let dense = link.transfer_time(32 * d as u64 + FRAME_OVERHEAD_BITS);
            let sign = link.transfer_time(d as u64 + 32 + FRAME_OVERHEAD_BITS);
            rec.record(&format!("crossover_{lname}_d{d}"), 0, dense / sign);
            lines.push(format!(
                "    {lname:<6} d={d:<7} dense {:>9.3} ms  sign {:>9.3} ms  speedup {:>6.2}x",
                dense * 1e3,
                sign * 1e3,
                dense / sign
            ));
        }
    }

    Ok(ExpResult {
        id: "staleness",
        summary: lines.join("\n"),
        recorders: vec![("sweep".into(), rec)],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The WAN preset demonstrates the crossover: compression's speedup is
    /// latency-bound at small d (ratio ≈ 1 on the WAN) and grows toward
    /// the bit ratio once the dense transfer dwarfs the latency.
    #[test]
    fn wan_crossover_shape() {
        let wan = LinkModel::wan();
        let dc = LinkModel::ten_gbe();
        let small = 4096u64;
        let large = 262_144u64;
        let ratio = |l: &LinkModel, d: u64| {
            l.transfer_time(32 * d + FRAME_OVERHEAD_BITS)
                / l.transfer_time(d + 32 + FRAME_OVERHEAD_BITS)
        };
        // wan, d=4096: 21.3 ms vs 20.05 ms — compression buys ~nothing
        assert!(ratio(&wan, small) < 1.2, "wan small-d ratio {}", ratio(&wan, small));
        // 10gbe, d=262144: 889 µs vs 76 µs — ~11.7x (latency caps the 32x)
        assert!(ratio(&dc, large) > 10.0, "dc large-d ratio {}", ratio(&dc, large));
        // the crossover is monotone in both d and the latency share
        assert!(ratio(&wan, large) > 3.0);
        assert!(ratio(&wan, small) < ratio(&wan, large));
        assert!(ratio(&wan, large) < ratio(&dc, large));
    }
}
