//! §6 / Fig. 4, Fig. 6, Fig. 7 and Tables 1, 3, 4: the deep-learning
//! experiments, on the synthetic-CIFAR substitute (see DESIGN.md
//! substitution table — the phenomena are optimizer-level).
//!
//! Protocol mirrors the paper: 4 algorithms (SGDM, scaled SIGNSGD,
//! SIGNSGDM, EF-SIGNSGD), batch sizes {128, 32, 8} with LR scaled
//! proportionally to batch size (Goyal et al.), LR decimated at 50% and 75%
//! of training, weight decay 5e-4, 3 seeds; we report train/test accuracy
//! curves and the generalization-gap table (best test acc for SGDM,
//! difference to SGDM for the rest).
//!
//! Expected shapes: EF-SIGNSGD ≈ SGDM on test (smallest gap, shrinking with
//! batch size); plain SIGNSGD degrades sharply at batch 8; EF-SIGNSGD is
//! the fastest on train.

use super::lr_tuning::{train_once, tune};
use super::{ExpContext, ExpResult};
use crate::data::synth_class::SynthSpec;
use crate::metrics::{Recorder, SeriesBundle, Series};
use crate::optim::PAPER_ALGOS;
use anyhow::Result;

struct SimSettings {
    epochs: usize,
    seeds: u64,
    batches: Vec<usize>,
    tune_epochs: usize,
}

impl SimSettings {
    fn new(quick: bool) -> Self {
        if quick {
            SimSettings {
                epochs: 8,
                seeds: 2,
                batches: vec![128, 8],
                tune_epochs: 2,
            }
        } else {
            SimSettings {
                epochs: 40,
                seeds: 3,
                batches: vec![128, 32, 8],
                tune_epochs: 8,
            }
        }
    }
}

fn run_sim(
    id: &'static str,
    title: &str,
    spec: SynthSpec,
    ctx: &ExpContext,
) -> Result<ExpResult> {
    let s = SimSettings::new(ctx.quick);
    let mut rec = Recorder::new();
    rec.tag("experiment", id);

    let mut lines = vec![format!(
        "== {title}: {} classes, {} train, batches {:?}, {} epochs x {} seeds ==",
        spec.classes, spec.train_n, s.batches, s.epochs, s.seeds
    )];

    // 1. LR tuning at batch 128 (paper protocol), small grid.
    let grid = if ctx.quick {
        vec![1e-3, 1e-2, 1e-1]
    } else {
        vec![1e-4, 5.6e-4, 3.2e-3, 1e-2, 5.6e-2, 3.2e-1]
    };
    let mut base_lr = std::collections::BTreeMap::new();
    for algo in PAPER_ALGOS {
        let (best, _) = tune(algo, &spec, 128, s.tune_epochs, ctx.seed, &grid);
        base_lr.insert(algo.to_string(), best);
    }
    lines.push(format!("  tuned base LRs (batch 128): {base_lr:?}"));

    // 2. Full runs per batch size, LR scaled by batch/128.
    let mut table: Vec<String> = vec![format!(
        "  {:<8} {:<10} {:<16} {:<12} {:<12}",
        "batch", "SGDM", "scaledSIGNSGD", "SIGNSGDM", "EF-SIGNSGD"
    )];
    for &batch in &s.batches {
        let mut best_test: std::collections::BTreeMap<String, f64> = Default::default();
        for algo in PAPER_ALGOS {
            let lr = base_lr[&algo.to_string()] * batch as f64 / 128.0;
            let mut bundle_test = SeriesBundle::default();
            let mut bundle_train = SeriesBundle::default();
            for seed in 0..s.seeds {
                let mut te_series = Series::default();
                let mut tr_series = Series::default();
                train_once(
                    algo,
                    lr,
                    &spec,
                    batch,
                    s.epochs,
                    ctx.seed + 7919 * seed,
                    &[0.5, 0.75],
                    |epoch, _trl, tra, _tel, tea| {
                        tr_series.push(epoch as u64, tra * 100.0);
                        te_series.push(epoch as u64, tea * 100.0);
                    },
                );
                bundle_test.push(te_series);
                bundle_train.push(tr_series);
            }
            let (steps, te_mean, te_std) = bundle_test.aggregate();
            let (_, tr_mean, _) = bundle_train.aggregate();
            for ((e, m), sd) in steps.iter().zip(&te_mean).zip(&te_std) {
                rec.record(&format!("test_{algo}_b{batch}"), *e, *m);
                rec.record(&format!("teststd_{algo}_b{batch}"), *e, *sd);
            }
            for (e, m) in steps.iter().zip(&tr_mean) {
                rec.record(&format!("train_{algo}_b{batch}"), *e, *m);
            }
            let (best_mean, _) = bundle_test.best_stats();
            best_test.insert(algo.to_string(), best_mean);
        }
        // Table 1/3/4 row: absolute for SGDM, deltas for the rest.
        let sgdm = best_test["sgdm"];
        table.push(format!(
            "  {:<8} {:<10.2} {:<16.2} {:<12.2} {:<12.2}",
            batch,
            sgdm,
            best_test["signsgd"] - sgdm,
            best_test["signsgdm"] - sgdm,
            best_test["ef_signsgd"] - sgdm,
        ));
    }
    lines.push("  Generalization-gap table (best mean test acc %; deltas vs SGDM):".into());
    lines.extend(table);
    lines.push(
        "  paper shape: EF-SIGNSGD has the smallest |gap| at every batch size; plain\n  SIGNSGD collapses at batch 8; gaps of sign methods grow as batch shrinks."
            .into(),
    );
    Ok(ExpResult {
        id,
        summary: lines.join("\n"),
        recorders: vec![("curves".into(), rec)],
    })
}

/// Fig. 4/6 + Tables 1/3: the CIFAR-100/Resnet18 analog.
pub fn fig4(ctx: &ExpContext) -> Result<ExpResult> {
    run_sim(
        "fig4",
        "Fig 4/6 + Tables 1/3 (CIFAR-100-like)",
        SynthSpec::cifar100_like(),
        ctx,
    )
}

/// Fig. 7 + Table 4: the CIFAR-10/VGG19 analog (easier task).
pub fn fig7(ctx: &ExpContext) -> Result<ExpResult> {
    run_sim(
        "fig7",
        "Fig 7 + Table 4 (CIFAR-10-like)",
        SynthSpec::cifar10_like(),
        ctx,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One reduced end-to-end shape check (the full sweep runs via
    /// `repro exp fig4` / benches).
    #[test]
    fn ef_matches_sgdm_better_than_sign_on_tiny() {
        let spec = SynthSpec::tiny();
        let run = |algo: &str, lr: f64| {
            let (_, te, _) = train_once(algo, lr, &spec, 16, 10, 3, &[0.5, 0.75], |_, _, _, _, _| {});
            te
        };
        let sgdm = run("sgdm", 0.05);
        let ef = run("ef_signsgd", 0.05);
        let sign = run("signsgd", 0.05);
        assert!(sgdm > 0.5, "sgdm should learn ({sgdm})");
        // EF within striking distance of SGDM; at least as good as sign
        assert!(ef >= sign - 0.05, "ef {ef} vs sign {sign}");
        assert!(ef > 0.4, "ef acc {ef}");
    }
}
