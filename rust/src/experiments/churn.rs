//! Elastic-membership sweep: EF-SGD vs (plain) SIGNSGD under seeded
//! fail-stop churn of increasing rate.
//!
//! The paper's claim is that the error residual makes compressed SGD
//! robust to whatever the system loses; membership churn is the harshest
//! loss the fleet model supports — a crashed worker's residual is gone,
//! and a cold rejoin restarts its compressor from zero. This experiment
//! runs the Theorem-1 shared-sign least-squares family on the synchronous
//! engine and sweeps the per-round crash probability of
//! [`MembershipSchedule::random_churn`] (worker 0 pinned live, departed
//! workers revive with probability 0.3 per round). Reported per method
//! and rate: the tail-mean loss, its degradation versus the rate-0
//! (churn-free, byte-identical to the plain engine) baseline, and the
//! mean number of membership events.
//!
//! Shape to observe (asserted by the `churn_sweep_*` integration test):
//! EF-SGD degrades gracefully — cold restarts only discard a bounded
//! residual, which the feedback loop rebuilds in O(1/delta) rounds —
//! while plain SIGNSGD's loss gap versus EF is strictly larger at every
//! swept rate, because the sign baseline is structurally trapped with or
//! without churn and every crash re-randomizes its oscillation.

use super::{ExpContext, ExpResult};
use crate::config::CompressorKind;
use crate::coordinator::driver::{DriverConfig, TrainDriver, UpdateRule};
use crate::coordinator::worker::{ObjectiveSource, Worker, WorkerMode};
use crate::coordinator::LrSchedule;
use crate::metrics::Recorder;
use crate::model::toy::SharedSignTheorem1;
use crate::net::MembershipSchedule;
use crate::util::Pcg64;
use anyhow::Result;

/// Problem + engine constants: the same shared-sign family as the
/// staleness sweep, so the two robustness experiments are comparable.
const D: usize = 16;
const ROWS: usize = 32;
const WORKERS: usize = 8;
const GAMMA: f64 = 1e-3;

/// Per-round, per-worker crash probabilities. Rate 0 produces an
/// inactive schedule, so that column runs the churn-free engine.
pub const RATES: [f64; 4] = [0.0, 0.02, 0.05, 0.1];

struct MethodSpec {
    name: &'static str,
    mode: WorkerMode,
    kind: CompressorKind,
}

const METHODS: [MethodSpec; 2] = [
    MethodSpec {
        name: "ef_sign",
        mode: WorkerMode::ErrorFeedback,
        kind: CompressorKind::ScaledSign,
    },
    MethodSpec {
        name: "signsgd",
        mode: WorkerMode::PlainCompress,
        kind: CompressorKind::ScaledSign,
    },
];

struct RunStats {
    tail_loss: f64,
    events: usize,
}

/// One synchronous run under seeded crash churn; `rep` seeds the problem
/// instance, the RNG streams and the churn schedule together, so every
/// (method, rate) cell of a rep sees identical data and — rate permitting
/// — identical membership events.
fn run_one(spec: &MethodSpec, rate: f64, steps: usize, rep: u64, base_seed: u64) -> RunStats {
    let obj_seed = base_seed + 9000 + rep;
    let workers: Vec<Worker> = (0..WORKERS)
        .map(|id| {
            let obj = SharedSignTheorem1::new(ROWS, D, &mut Pcg64::seeded(obj_seed));
            Worker::new(
                id,
                Box::new(ObjectiveSource::new(
                    obj,
                    Pcg64::new(base_seed + rep, 1000 + id as u64),
                )),
                spec.mode,
                spec.kind,
                4,
                4,
                Pcg64::new(base_seed + rep, id as u64),
            )
        })
        .collect();
    let membership =
        MembershipSchedule::random_churn(base_seed + 77 + rep, WORKERS, steps as u64, rate, true);
    let events = membership.events().len();
    let cfg = DriverConfig {
        steps,
        schedule: LrSchedule::constant(GAMMA),
        update_rule: UpdateRule::ApplyAggregate,
        membership,
        ..Default::default()
    };
    let out = TrainDriver::new(cfg, workers, vec![1.0f32; D]).run();
    let losses = &out.recorder.get("train_loss").unwrap().values;
    let tail = &losses[losses.len() * 3 / 4..];
    RunStats {
        tail_loss: tail.iter().sum::<f64>() / tail.len() as f64,
        events,
    }
}

pub fn churn(ctx: &ExpContext) -> Result<ExpResult> {
    let steps = if ctx.quick { 300 } else { 600 };
    let reps = if ctx.quick { 2 } else { 3 };

    let mut rec = Recorder::new();
    rec.tag("experiment", "churn");
    let mut lines = vec![format!(
        "== Elastic-membership sweep: fail-stop churn, fleet of {WORKERS}, \
         shared-sign least squares d={D}, {steps} rounds x {reps} reps =="
    )];
    lines.push(format!(
        "  {:<9} {:>10} {:>10} {:>10} {:>10} {:>12}",
        "method", "rate=0", "rate=.02", "rate=.05", "rate=.1", "events@.1"
    ));

    for spec in &METHODS {
        let mut finals = Vec::with_capacity(RATES.len());
        let mut last_events = 0.0f64;
        for (ri, &rate) in RATES.iter().enumerate() {
            let mut loss = 0.0f64;
            let mut events = 0.0f64;
            for rep in 0..reps {
                let s = run_one(spec, rate, steps, rep as u64, ctx.seed);
                loss += s.tail_loss;
                events += s.events as f64;
            }
            loss /= reps as f64;
            events /= reps as f64;
            rec.record(&format!("final_{}", spec.name), ri as u64, loss);
            rec.record(&format!("events_{}", spec.name), ri as u64, events);
            finals.push(loss);
            last_events = events;
        }
        for (ri, f) in finals.iter().enumerate().skip(1) {
            rec.record(&format!("deg_{}", spec.name), ri as u64, f - finals[0]);
        }
        lines.push(format!(
            "  {:<9} {:>10.3e} {:>10.3e} {:>10.3e} {:>10.3e} {:>12.1}",
            spec.name, finals[0], finals[1], finals[2], finals[3], last_events
        ));
    }
    lines.push(
        "  shape: EF's loss stays near its churn-free floor at every crash rate —\n  \
         a cold restart discards one bounded residual, which the feedback loop\n  \
         rebuilds — while plain SIGNSGD sits an order of magnitude higher at\n  \
         every rate: the sign trap does not need churn to bite, and every\n  \
         crash re-randomizes its oscillation (Theorem 1 vs Theorem II)."
            .into(),
    );

    Ok(ExpResult {
        id: "churn",
        summary: lines.join("\n"),
        recorders: vec![("sweep".into(), rec)],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Rate 0 must be byte-identical to an explicit `none()` schedule:
    /// the rate-0 column of the sweep IS the churn-free engine.
    #[test]
    fn rate_zero_matches_membership_none() {
        let spec = &METHODS[0];
        let mk = || {
            (0..WORKERS)
                .map(|id| {
                    let obj = SharedSignTheorem1::new(ROWS, D, &mut Pcg64::seeded(42));
                    Worker::new(
                        id,
                        Box::new(ObjectiveSource::new(obj, Pcg64::new(7, 1000 + id as u64))),
                        spec.mode,
                        spec.kind,
                        4,
                        4,
                        Pcg64::new(7, id as u64),
                    )
                })
                .collect::<Vec<_>>()
        };
        let run = |membership: MembershipSchedule| {
            let cfg = DriverConfig {
                steps: 40,
                schedule: LrSchedule::constant(GAMMA),
                update_rule: UpdateRule::ApplyAggregate,
                membership,
                ..Default::default()
            };
            TrainDriver::new(cfg, mk(), vec![1.0f32; D]).run().theta
        };
        let zero_rate = MembershipSchedule::random_churn(3, WORKERS, 40, 0.0, true);
        assert!(!zero_rate.is_active());
        let a = run(zero_rate);
        let b = run(MembershipSchedule::none());
        assert_eq!(
            a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }
}
