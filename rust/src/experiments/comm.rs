//! The communication claim (§1, §6.1): sign compression reduces gradient
//! traffic by ~32× per direction (1 bit + one 32-bit scale per layer
//! versus 32 bits per coordinate), ~64× when both directions are
//! compressed. Measured, not asserted: we (a) evaluate the exact layer-wise
//! formula Σᵢ(dᵢ+32) on real network shapes and (b) run the coordinator on
//! the simulated fabric and read the bit counters.

use super::{ExpContext, ExpResult};
use crate::config::CompressorKind;
use crate::coordinator::driver::{DriverConfig, TrainDriver, UpdateRule};
use crate::coordinator::worker::{ObjectiveSource, Worker, WorkerMode};
use crate::coordinator::LrSchedule;
use crate::metrics::Recorder;
use crate::model::toy::SparseNoiseQuadratic;
use crate::net::MessageKind;
use crate::util::Pcg64;
use anyhow::Result;

/// Layer dimension tables for the paper's networks.
/// VGG19 conv/fc layer parameter counts (CIFAR-10 variant, conv = k*k*cin*cout).
fn vgg19_layers() -> Vec<usize> {
    let mut dims = Vec::new();
    let cfg: [(usize, usize); 16] = [
        (3, 64),
        (64, 64),
        (64, 128),
        (128, 128),
        (128, 256),
        (256, 256),
        (256, 256),
        (256, 256),
        (256, 512),
        (512, 512),
        (512, 512),
        (512, 512),
        (512, 512),
        (512, 512),
        (512, 512),
        (512, 512),
    ];
    for (cin, cout) in cfg {
        dims.push(3 * 3 * cin * cout);
    }
    dims.push(512 * 10); // classifier
    dims
}

/// Resnet18 layer parameter counts (CIFAR variant).
fn resnet18_layers() -> Vec<usize> {
    let mut dims = vec![3 * 3 * 3 * 64];
    let blocks: [(usize, usize, usize); 4] = [(64, 64, 2), (64, 128, 2), (128, 256, 2), (256, 512, 2)];
    for (cin, cout, n) in blocks {
        for b in 0..n {
            let c_in = if b == 0 { cin } else { cout };
            dims.push(3 * 3 * c_in * cout);
            dims.push(3 * 3 * cout * cout);
            if b == 0 && cin != cout {
                dims.push(cin * cout); // 1x1 downsample
            }
        }
    }
    dims.push(512 * 10);
    dims
}

/// The paper's accounting: layer-wise scaled sign = Σᵢ(dᵢ + 32) bits.
fn sign_bits(layers: &[usize]) -> u64 {
    layers.iter().map(|&d| d as u64 + 32).sum()
}

fn dense_bits(layers: &[usize]) -> u64 {
    layers.iter().map(|&d| 32 * d as u64).sum()
}

pub fn comm(ctx: &ExpContext) -> Result<ExpResult> {
    let mut rec = Recorder::new();
    rec.tag("experiment", "comm");
    let mut lines = vec!["== Communication accounting (the ~64x claim) ==".to_string()];

    // (a) analytic, on the paper's network shapes
    lines.push("  layer-wise formula  Sum_i (d_i + 32)  vs dense 32*d:".into());
    for (name, layers) in [("VGG19", vgg19_layers()), ("Resnet18", resnet18_layers())] {
        let d: usize = layers.iter().sum();
        let sb = sign_bits(&layers);
        let db = dense_bits(&layers);
        let one_way = db as f64 / sb as f64;
        // Paper's ~64x: both directions sign-compressed (worker push +
        // majority-vote/sign broadcast), vs dense both ways.
        let two_way = (2 * db) as f64 / (2 * sb) as f64;
        // and the deployed asymmetric variant: compressed push, dense pull
        let asym = (2 * db) as f64 / (sb + db) as f64;
        lines.push(format!(
            "    {name:<9} d={d:>9}  layers={:<3} sign {:>12} bits  dense {:>13} bits  ratio {:.2}x one-way ({:.2}x both-compressed, {:.2}x push-only)",
            layers.len(), sb, db, one_way, two_way, asym
        ));
        rec.record(&format!("ratio_{name}"), 0, one_way);
    }

    // (b) measured on the fabric: EF-sign vs dense push traffic
    let d = if ctx.quick { 4096 } else { 262_144 };
    let steps = 10;
    let run = |mode: WorkerMode, kind: CompressorKind| {
        let workers: Vec<Worker> = (0..4)
            .map(|id| {
                Worker::new(
                    id,
                    Box::new(ObjectiveSource::new(
                        SparseNoiseQuadratic::new(d, 1.0),
                        Pcg64::seeded(id as u64),
                    )),
                    mode,
                    kind,
                    64,
                    4,
                    Pcg64::seeded(100 + id as u64),
                )
            })
            .collect();
        let cfg = DriverConfig {
            steps,
            schedule: LrSchedule::constant(0.01),
            update_rule: if mode == WorkerMode::DenseGrad {
                UpdateRule::ScaleByLr
            } else {
                UpdateRule::ApplyAggregate
            },
            ..Default::default()
        };
        TrainDriver::new(cfg, workers, vec![1.0f32; d]).run()
    };
    let dense = run(WorkerMode::DenseGrad, CompressorKind::None);
    let signd = run(WorkerMode::ErrorFeedback, CompressorKind::ScaledSign);
    let topk = run(WorkerMode::ErrorFeedback, CompressorKind::TopK);
    let qsgd = run(WorkerMode::ErrorFeedback, CompressorKind::Qsgd);
    let push_dense = dense.traffic.bits_of_kind(MessageKind::GradPush);
    let push_sign = signd.traffic.bits_of_kind(MessageKind::GradPush);
    let push_topk = topk.traffic.bits_of_kind(MessageKind::GradPush);
    let push_qsgd = qsgd.traffic.bits_of_kind(MessageKind::GradPush);
    lines.push(format!(
        "  measured on fabric (d={d}, 4 workers, {steps} rounds): push traffic\n    dense {:>14} bits | ef-sign {:>14} bits ({:.2}x) | ef-top-k(1/64) {:>13} bits ({:.2}x)\n    ef-qsgd(s=4, Elias) {:>14} bits ({:.2}x) — measured on the real wire pack, not the old dense upper bound",
        push_dense,
        push_sign,
        push_dense as f64 / push_sign as f64,
        push_topk,
        push_dense as f64 / push_topk as f64,
        push_qsgd,
        push_dense as f64 / push_qsgd as f64,
    ));
    rec.record("measured_sign_ratio", 0, push_dense as f64 / push_sign as f64);
    rec.record("measured_qsgd_ratio", 0, push_dense as f64 / push_qsgd as f64);

    // (b') the reported round time must equal the simclock's totals: the
    // sign run's per-round wall time on the virtual clock is one dense
    // parameter broadcast, one (d + 32)-bit push, and the leader's
    // measured decode+aggregate critical path (leader compute is priced,
    // no longer free in simulated time). The comm terms are analytic; the
    // leader term is exactly the profiled critical path, so subtracting
    // it must recover the link-model arithmetic message by message.
    // Asserted, not just printed, so the timing model can never drift
    // from the link model.
    {
        use crate::net::message::FRAME_OVERHEAD_BITS;
        let link = crate::net::LinkModel::default();
        let t_params = link.transfer_time(32 * d as u64 + FRAME_OVERHEAD_BITS);
        let t_push = link.transfer_time(d as u64 + 32 + FRAME_OVERHEAD_BITS);
        let per_round = t_params + t_push; // compute is free in this run
        let expect_total = steps as f64 * per_round;
        let sign_sim_s = signd.sim_time_s;
        let leader_s = signd.profile.critical_s;
        assert!(
            leader_s > 0.0,
            "leader decode+aggregate charged no simulated time"
        );
        let comm_total = sign_sim_s - leader_s;
        assert!(
            (comm_total - expect_total).abs() <= 1e-9 * expect_total,
            "simclock total minus leader time {comm_total} != analytic round time x rounds {expect_total}"
        );
        let push_sim = signd.traffic.sim_time_of_kind(MessageKind::GradPush);
        let expect_push = steps as f64 * 4.0 * t_push; // 4 workers
        assert!(
            (push_sim - expect_push).abs() <= 1e-9 * expect_push,
            "per-kind sim time {push_sim} != analytic push time {expect_push}"
        );
        lines.push(format!(
            "  simclock: sign round = {:.4} ms comm (broadcast {:.4} + push {:.4}) + {:.4} ms measured leader decode, total {:.2} ms over {steps} rounds — matches TrafficStats::sim_time_of_kind exactly",
            per_round * 1e3,
            t_params * 1e3,
            t_push * 1e3,
            signd.profile.mean_critical_s() * 1e3,
            sign_sim_s * 1e3
        ));
        rec.record("sign_round_sim_ms", 0, per_round * 1e3);
        rec.record("leader_ms_per_round", 0, signd.profile.mean_critical_s() * 1e3);
    }

    // (b'') sharded parameter server on the wan() preset: as S grows the
    // measured leader decode+aggregate critical path (max over shard
    // leaders) shrinks ~linearly, while the wan round is latency-dominated
    // and barely moves — the crossover to leader-bound rounds needs
    // faster links or bigger worker fleets.
    //
    // Each S now runs under BOTH uplink disciplines, side by side: the
    // legacy Overlapped fabric (every frame transfers concurrently) and
    // the Serialized fabric (frames from one sender queue FIFO on its
    // uplink). The leader term uses the calibrated DecodeCostModel, so
    // both round times are pure functions of the seeded models — the gap
    // between the columns is exactly the uplink-serialization cost, and
    // what S buys back of it (more shard leaders = more parallel uplinks),
    // cleanly separated from the leader-decode gain. Serialized can never
    // beat Overlapped (a FIFO queue only delays transmissions), and the
    // sweep asserts that for every S rather than trusting the model.
    {
        use crate::coordinator::DecodeCostModel;
        use crate::net::LinkDiscipline;
        let d_s = if ctx.quick { 4096 } else { 65_536 };
        let steps_s = 5usize;
        lines.push(format!(
            "  sharded PS on wan (d={d_s}, 8 workers, ef-qsgd, calibrated leader cost):\n    S | leader crit ms/round | round ms overlapped | round ms serialized"
        ));
        for s in [1usize, 2, 4] {
            let run_s = |discipline: LinkDiscipline| {
                let workers: Vec<Worker> = (0..8)
                    .map(|id| {
                        Worker::new(
                            id,
                            Box::new(ObjectiveSource::new(
                                SparseNoiseQuadratic::new(d_s, 1.0),
                                Pcg64::seeded(id as u64),
                            )),
                            WorkerMode::ErrorFeedback,
                            CompressorKind::Qsgd,
                            64,
                            4,
                            Pcg64::seeded(100 + id as u64),
                        )
                    })
                    .collect();
                let cfg = DriverConfig {
                    steps: steps_s,
                    schedule: LrSchedule::constant(0.01),
                    link: crate::net::LinkModel::wan(),
                    discipline,
                    leader_cost: DecodeCostModel::calibrated(),
                    shards: s,
                    ..Default::default()
                };
                TrainDriver::new(cfg, workers, vec![1.0f32; d_s]).run()
            };
            let over = run_s(LinkDiscipline::Overlapped);
            let ser = run_s(LinkDiscipline::Serialized);
            // the discipline only moves simulated time, never the bits
            assert_eq!(
                over.theta, ser.theta,
                "S={s}: uplink discipline leaked into the trained parameters"
            );
            assert!(
                ser.sim_time_s >= over.sim_time_s,
                "S={s}: serialized uplinks finished before overlapped \
                 ({} vs {})",
                ser.sim_time_s,
                over.sim_time_s
            );
            let crit_ms = over.profile.mean_critical_s() * 1e3;
            let over_ms = over.sim_time_s / steps_s as f64 * 1e3;
            let ser_ms = ser.sim_time_s / steps_s as f64 * 1e3;
            lines.push(format!(
                "    S={s}: leader {crit_ms:.4} ms | overlapped {over_ms:.3} ms | serialized {ser_ms:.3} ms"
            ));
            rec.record(&format!("shard_crit_ms_S{s}"), 0, crit_ms);
            rec.record(&format!("shard_round_ms_S{s}"), 0, over_ms);
            rec.record(&format!("shard_round_serialized_ms_S{s}"), 0, ser_ms);
        }
    }

    // (c) simulated wall-clock effect of compression on a 1 GbE link
    let link = crate::net::LinkModel::one_gbe();
    let t_dense = link.transfer_time(dense_bits(&vgg19_layers()));
    let t_sign = link.transfer_time(sign_bits(&vgg19_layers()));
    lines.push(format!(
        "  1 GbE per-round gradient push (VGG19): dense {:.1} ms vs sign {:.2} ms",
        t_dense * 1e3,
        t_sign * 1e3
    ));
    lines.push(
        "  paper claim: ~32x per compressed direction, '~64x' counting both directions;\n  the extra 32 bits/layer are negligible when params >> layers (3 orders of magnitude)."
            .into(),
    );
    Ok(ExpResult {
        id: "comm",
        summary: lines.join("\n"),
        recorders: vec![("ratios".into(), rec)],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytic_ratio_near_32x() {
        for layers in [vgg19_layers(), resnet18_layers()] {
            let r = dense_bits(&layers) as f64 / sign_bits(&layers) as f64;
            assert!(r > 31.5 && r < 32.0, "ratio {r}");
        }
    }

    #[test]
    fn vgg19_param_count_plausible() {
        let d: usize = vgg19_layers().iter().sum();
        // VGG19 conv backbone ~20M params
        assert!(d > 15_000_000 && d < 25_000_000, "d={d}");
    }

    #[test]
    fn measured_matches_analytic_quick() {
        let r = comm(&ExpContext::quick()).unwrap();
        let rec = &r.recorders[0].1;
        let measured = rec.get("measured_sign_ratio").unwrap().last().unwrap();
        // framing overhead + scale make it slightly under 32
        assert!(measured > 25.0 && measured < 32.5, "measured {measured}");
        // the Elias-packed QSGD rows are now honest (no longer the dense
        // upper bound): worst case ~6 bits/coordinate at s=4, typically ~1
        let q = rec.get("measured_qsgd_ratio").unwrap().last().unwrap();
        assert!(q > 4.0, "qsgd measured ratio {q}");
        // the wan shard sweep ran, recorded every row, and actually
        // measured leader time (S-ordering is wall-clock dependent at
        // quick sizes, so bench_shard tracks the speedup instead)
        for s in [1, 2, 4] {
            let crit = rec
                .get(&format!("shard_crit_ms_S{s}"))
                .expect("missing shard row")
                .last()
                .unwrap();
            assert!(crit > 0.0, "S={s}: leader decode charged no time");
            let over = rec
                .get(&format!("shard_round_ms_S{s}"))
                .expect("missing overlapped row")
                .last()
                .unwrap();
            let ser = rec
                .get(&format!("shard_round_serialized_ms_S{s}"))
                .expect("missing serialized row")
                .last()
                .unwrap();
            // a FIFO uplink queue can only delay transmissions, and with
            // the calibrated leader model both columns are deterministic
            assert!(
                ser >= over,
                "S={s}: serialized {ser} ms beat overlapped {over} ms"
            );
        }
    }
}
