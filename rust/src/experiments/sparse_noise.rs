//! Appendix A.1 / Fig. 5: the sparse-noise toy problem where SIGNSGD is
//! *faster* than SGD and EF-SIGNSGD — the noise on the single bad
//! coordinate accumulates in the EF residual instead of being scaled away.
//!
//! Setup (paper): f(x) = ½‖x‖², x ∈ R^100, stochastic gradient = x + noise
//! with N(0, 100²) on coordinate 1 only. LRs: 0.001 for SGD/EF-SIGNSGD,
//! 0.01 for SIGNSGD/(scaled)SIGNSGD. 100 repetitions.

use super::{ExpContext, ExpResult};
use crate::metrics::{sparkline, Recorder, SeriesBundle, Series};
use crate::model::toy::SparseNoiseQuadratic;
use crate::model::StochasticObjective;
use crate::optim;
use crate::util::Pcg64;
use anyhow::Result;

pub fn fig5(ctx: &ExpContext) -> Result<ExpResult> {
    let d = 100;
    let steps = if ctx.quick { 300 } else { 1_000 };
    let repeats = if ctx.quick { 20 } else { 100 };
    let obj = SparseNoiseQuadratic::new(d, 100.0);

    let algos: [(&str, f32); 4] = [
        ("sgd", 0.001),
        ("ef_signsgd", 0.001),
        ("signsgd_unscaled", 0.01),
        ("signsgd", 0.01), // scaled
    ];

    let mut rec = Recorder::new();
    rec.tag("experiment", "fig5");
    let mut lines = vec![format!(
        "== Fig 5: sparse-noise quadratic d={d}, noise N(0,100^2) on coord 1, {repeats} repeats =="
    )];

    for (algo, lr) in algos {
        let mut bundle = SeriesBundle::default();
        for rep in 0..repeats {
            let mut series = Series::default();
            let mut opt = optim::build(algo, d, lr, 0.9, ctx.seed + rep as u64).unwrap();
            let mut x = vec![1.0f32; d];
            let mut g = vec![0.0f32; d];
            let mut rng = Pcg64::seeded(ctx.seed + 1000 + rep as u64);
            for t in 0..steps {
                obj.stoch_grad(&x, &mut rng, &mut g);
                opt.step(&mut x, &g);
                if t % (steps / 100).max(1) == 0 {
                    series.push(t as u64, obj.loss(&x));
                }
            }
            bundle.push(series);
        }
        let (stepsv, mean, std) = bundle.aggregate();
        for ((s, m), sd) in stepsv.iter().zip(&mean).zip(&std) {
            rec.record(&format!("loss_{algo}"), *s, *m);
            rec.record(&format!("std_{algo}"), *s, *sd);
        }
        // time-to-threshold: first recorded step with loss < 1.0
        let t_hit = stepsv
            .iter()
            .zip(&mean)
            .find(|(_, m)| **m < 1.0)
            .map(|(s, _)| *s as i64)
            .unwrap_or(-1);
        lines.push(format!(
            "  {algo:<18} lr {lr:<6} final {:.3e}  steps-to-loss<1: {t_hit:>5}  {}",
            mean.last().unwrap(),
            sparkline(&mean, 30)
        ));
    }
    lines.push(
        "  paper shape: SIGNSGD and scaled SIGNSGD reach low loss FASTER than SGD;\n  EF-SIGNSGD tracks SGD's (slower) rate — noise accumulates in e_t, contradicting the\n  coordinate-wise-variance explanation of sign methods' speed (paper's point)."
            .into(),
    );
    Ok(ExpResult {
        id: "fig5",
        summary: lines.join("\n"),
        recorders: vec![("series".into(), rec)],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_shape_quick() {
        let r = fig5(&ExpContext::quick()).unwrap();
        let rec = &r.recorders[0].1;
        // sign methods beat sgd at matched mid-training step
        let at = |name: &str, frac: f64| {
            let s = rec.get(name).unwrap();
            let i = ((s.values.len() - 1) as f64 * frac) as usize;
            s.values[i]
        };
        let mid_sign = at("loss_signsgd_unscaled", 0.5);
        let mid_sgd = at("loss_sgd", 0.5);
        assert!(
            mid_sign < mid_sgd,
            "sign {mid_sign} should lead sgd {mid_sgd} mid-run"
        );
        // EF behaves like SGD (same order of magnitude), not like signSGD
        let mid_ef = at("loss_ef_signsgd", 0.5);
        assert!(mid_ef > mid_sign, "EF should NOT enjoy the sign speedup");
    }
}
