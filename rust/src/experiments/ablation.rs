//! Ablation: Theorem II's generality — error feedback recovers near-SGD
//! convergence for EVERY compressor in the zoo, biased or unbiased, while
//! the same compressors without feedback stall or diverge.
//!
//! Grid: {scaled sign, top-k(d/16), random-k(d/16 biased), QSGD(s=1),
//! TernGrad} × {EF on, EF off} on a noisy quadratic, fixed LR. Reported:
//! loss floor (tail mean) relative to plain SGD's floor.

use super::{ExpContext, ExpResult};
use crate::compress::{self, Compressor, ErrorFeedback};
use crate::metrics::Recorder;
use crate::model::StochasticObjective;
use crate::util::Pcg64;
use anyhow::Result;

struct NoisyQuadratic {
    d: usize,
}

impl StochasticObjective for NoisyQuadratic {
    fn dim(&self) -> usize {
        self.d
    }

    fn loss(&self, x: &[f32]) -> f64 {
        0.5 * crate::tensor::norm2_sq(x)
    }

    fn stoch_grad(&self, x: &[f32], rng: &mut Pcg64, out: &mut [f32]) -> f64 {
        for (o, xi) in out.iter_mut().zip(x) {
            *o = xi + rng.normal_ms(0.0, 1.0) as f32;
        }
        self.loss(x)
    }
}

fn compressor(name: &str, d: usize) -> Box<dyn Compressor> {
    match name {
        "scaled_sign" => Box::new(compress::ScaledSign),
        "topk" => Box::new(compress::TopK::count((d / 16).max(1))),
        "randomk_biased" => Box::new(compress::RandomK::biased((d / 16).max(1))),
        "qsgd" => {
            let k = compress::Qsgd::new(1).expansion(d);
            Box::new(compress::ScaledUnbiased::new(Box::new(compress::Qsgd::new(1)), k))
        }
        "terngrad" => Box::new(compress::TernGrad),
        _ => unreachable!(),
    }
}

fn run_one(
    obj: &NoisyQuadratic,
    comp: Box<dyn Compressor>,
    feedback: bool,
    gamma: f32,
    steps: usize,
    seed: u64,
) -> f64 {
    let d = obj.dim();
    let mut ef = if feedback {
        ErrorFeedback::new(d, comp)
    } else {
        ErrorFeedback::disabled(d, comp)
    };
    ef.set_track_density(false);
    let mut x = vec![1.0f32; d];
    let mut g = vec![0.0f32; d];
    let mut delta = vec![0.0f32; d];
    let mut rng = Pcg64::seeded(seed);
    let mut tail = 0.0f64;
    let tail_start = steps * 3 / 4;
    for t in 0..steps {
        obj.stoch_grad(&x, &mut rng, &mut g);
        ef.step_into(gamma, &g, &mut delta, &mut rng);
        crate::tensor::sub_assign(&mut x, &delta);
        if t >= tail_start {
            tail += obj.loss(&x);
        }
    }
    tail / (steps - tail_start) as f64
}

pub fn ablation(ctx: &ExpContext) -> Result<ExpResult> {
    let d = 256;
    let steps = if ctx.quick { 1_500 } else { 8_000 };
    let gamma = 0.02f32;
    let obj = NoisyQuadratic { d };

    // SGD reference floor (identity compressor).
    let sgd_floor = run_one(&obj, Box::new(compress::Identity), true, gamma, steps, ctx.seed);

    let mut rec = Recorder::new();
    rec.tag("experiment", "ablation");
    let mut lines = vec![format!(
        "== Ablation: EF on/off x compressor zoo (noisy quadratic d={d}, {steps} steps) =="
    )];
    lines.push(format!("  SGD reference floor: {sgd_floor:.3e}"));
    lines.push(format!(
        "  {:<16} {:>12} {:>12} {:>9}",
        "compressor", "no feedback", "with EF", "EF/SGD"
    ));
    for name in ["scaled_sign", "topk", "randomk_biased", "qsgd", "terngrad"] {
        let off = run_one(&obj, compressor(name, d), false, gamma, steps, ctx.seed + 1);
        let on = run_one(&obj, compressor(name, d), true, gamma, steps, ctx.seed + 1);
        rec.record(&format!("floor_off_{name}"), 0, off);
        rec.record(&format!("floor_on_{name}"), 0, on);
        lines.push(format!(
            "  {name:<16} {off:>12.3e} {on:>12.3e} {:>8.2}x",
            on / sgd_floor
        ));
    }
    lines.push(
        "  shape (Thm II): with EF every compressor's floor lands within a small factor of\n  SGD's (the delta-dependent O(gamma^2) term of Lemma 3 explains the spread: the\n  weakly-contracting TernGrad pays the most). On this benign isotropic objective the\n  no-feedback column does not diverge - the failures of biased compression are\n  structural, not universal: see ce1-ce3/thm1 for where they break and rem5 for the\n  unbiased high-variance regime."
            .into(),
    );
    Ok(ExpResult {
        id: "ablation",
        summary: lines.join("\n"),
        recorders: vec![("floors".into(), rec)],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ef_never_much_worse_and_fixes_aggressive_schemes_quick() {
        let r = ablation(&ExpContext::quick()).unwrap();
        let rec = &r.recorders[0].1;
        for name in ["scaled_sign", "topk", "randomk_biased", "qsgd", "terngrad"] {
            let off = rec.get(&format!("floor_off_{name}")).unwrap().last().unwrap();
            let on = rec.get(&format!("floor_on_{name}")).unwrap().last().unwrap();
            // On this benign isotropic quadratic some biased schemes don't
            // diverge without feedback (the divergences live in ce1-ce3);
            // EF must still be in the same ballpark, never a blow-up.
            assert!(on <= off * 1.5, "{name}: EF {on} vs no-EF {off}");
        }
    }

    #[test]
    fn ef_floors_within_factor_of_sgd_quick() {
        let r = ablation(&ExpContext::quick()).unwrap();
        let rec = &r.recorders[0].1;
        // every EF floor within ~25x of SGD's (most are ~1-3x); aggressive
        // top-k/random-k at d/16 retain a delta-dependent gap per Lemma 3
        for name in ["scaled_sign", "topk", "randomk_biased", "qsgd", "terngrad"] {
            let on = rec.get(&format!("floor_on_{name}")).unwrap().last().unwrap();
            assert!(on.is_finite() && on < 50.0, "{name} floor {on}");
        }
    }
}
