//! Remark 5: error feedback helps even UNBIASED compressors. QSGD with
//! expansion factor k converges ~k× slower without feedback; wrapping
//! C(x) = U(x)/k with EF pushes the k-dependence into the O(1/T) term.
//!
//! We compare on a noisy quadratic: (a) SGD (upper baseline), (b) QSGD
//! without feedback, (c) QSGD/k with error feedback, all at the same LR.

use super::{ExpContext, ExpResult};
use crate::compress::{Compressor, Qsgd, ScaledUnbiased};
use crate::metrics::{sparkline, Recorder};
use crate::model::StochasticObjective;
use crate::optim::{EfSgd, Optimizer, Sgd};
use crate::util::Pcg64;
use anyhow::Result;

pub fn rem5(ctx: &ExpContext) -> Result<ExpResult> {
    let d = 256;
    let steps = if ctx.quick { 800 } else { 5_000 };
    let levels = 1; // aggressive quantization -> large expansion k
    let k = Qsgd::new(levels).expansion(d);
    // isotropic noise keeps the comparison clean (no sparse-noise effects)
    let obj = IsotropicQuadratic { d, noise: 1.0 };
    let lr = 0.02f32;

    let mut rec = Recorder::new();
    rec.tag("experiment", "rem5");
    let mut lines = vec![format!(
        "== Remark 5: QSGD(s={levels}) on a noisy quadratic, d={d}, expansion k={k:.1} =="
    )];

    let mut run = |name: &str, mut opt: Box<dyn Optimizer>| {
        let mut x = vec![1.0f32; d];
        let mut g = vec![0.0f32; d];
        let mut rng = Pcg64::seeded(ctx.seed + 5);
        for t in 0..steps {
            obj.stoch_grad(&x, &mut rng, &mut g);
            opt.step(&mut x, &g);
            if t % (steps / 200).max(1) == 0 {
                rec.record(&format!("loss_{name}"), t as u64, obj.loss(&x));
            }
        }
        let series = rec.get(&format!("loss_{name}")).unwrap().values.clone();
        lines.push(format!(
            "  {name:<22} final {:.4e}   {}",
            series.last().unwrap(),
            sparkline(&series, 36)
        ));
        *series.last().unwrap()
    };

    let f_sgd = run("sgd", Box::new(Sgd::new(lr)));
    // QSGD without feedback = EF machinery disabled (plain compressed step)
    let f_plain = run(
        "qsgd_no_feedback",
        Box::new(PlainCompressed::new(d, lr, Box::new(Qsgd::new(levels)), ctx.seed)),
    );
    let f_ef = run(
        "qsgd_over_k_ef",
        Box::new(EfSgd::with_rng(
            d,
            lr,
            Box::new(ScaledUnbiased::new(Box::new(Qsgd::new(levels)), k)),
            Pcg64::seeded(ctx.seed),
        )),
    );

    lines.push(format!(
        "  paper shape: plain QSGD's noise floor is ~k x SGD's; EF brings it back near SGD.\n  floors: sgd {f_sgd:.3e} | qsgd {f_plain:.3e} | qsgd/k+EF {f_ef:.3e}"
    ));
    Ok(ExpResult {
        id: "rem5",
        summary: lines.join("\n"),
        recorders: vec![("series".into(), rec)],
    })
}

/// Quadratic with isotropic gaussian gradient noise.
struct IsotropicQuadratic {
    d: usize,
    noise: f64,
}

impl StochasticObjective for IsotropicQuadratic {
    fn dim(&self) -> usize {
        self.d
    }

    fn loss(&self, x: &[f32]) -> f64 {
        0.5 * crate::tensor::norm2_sq(x)
    }

    fn stoch_grad(&self, x: &[f32], rng: &mut Pcg64, out: &mut [f32]) -> f64 {
        for (o, xi) in out.iter_mut().zip(x) {
            *o = xi + rng.normal_ms(0.0, self.noise) as f32;
        }
        self.loss(x)
    }
}

/// x ← x − C(γ g): compression without feedback (the Remark-5 baseline).
struct PlainCompressed {
    lr: f32,
    comp: Box<dyn Compressor>,
    rng: Pcg64,
    delta: Vec<f32>,
    p: Vec<f32>,
}

impl PlainCompressed {
    fn new(d: usize, lr: f32, comp: Box<dyn Compressor>, seed: u64) -> Self {
        PlainCompressed {
            lr,
            comp,
            rng: Pcg64::seeded(seed),
            delta: vec![0.0; d],
            p: vec![0.0; d],
        }
    }
}

impl Optimizer for PlainCompressed {
    fn name(&self) -> &'static str {
        "plain_compressed"
    }

    fn step(&mut self, x: &mut [f32], g: &[f32]) {
        for (p, gi) in self.p.iter_mut().zip(g) {
            *p = self.lr * *gi;
        }
        self.comp.compress(&self.p, &mut self.delta, &mut self.rng);
        crate::tensor::sub_assign(x, &self.delta);
    }

    fn lr(&self) -> f32 {
        self.lr
    }

    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ef_closes_most_of_the_qsgd_gap_quick() {
        let r = rem5(&ExpContext::quick()).unwrap();
        let rec = &r.recorders[0].1;
        // average the recorded tail (last 25%) for stable floors
        let floor = |name: &str| {
            let v = &rec.get(name).unwrap().values;
            let tail = &v[3 * v.len() / 4..];
            crate::util::stats::mean(tail)
        };
        let sgd = floor("loss_sgd");
        let plain = floor("loss_qsgd_no_feedback");
        let ef = floor("loss_qsgd_over_k_ef");
        assert!(plain > 2.0 * sgd, "plain {plain} should be >> sgd {sgd}");
        assert!(ef < plain, "ef {ef} should beat plain {plain}");
    }
}
