//! Appendix A.3 / Table 2: learning-rate tuning protocol.
//!
//! The paper's grid: 9 learning rates equally log-spaced over [1e-5, 1e1]
//! (1.0e-5, 5.6e-5, 3.2e-4, 1.8e-3, 1.0e-2, 5.6e-2, 3.2e-1, 1.8e0, 1.0e1),
//! run with a constant LR and the best *test loss* selected per algorithm.
//! We run it on the CIFAR-100 substitute with the native MLP.

use super::{ExpContext, ExpResult};
use crate::data::synth_class::{self, SynthSpec};
use crate::metrics::Recorder;
use crate::model::mlp::{Mlp, MlpConfig, MlpObjective};
use crate::model::StochasticObjective;
use crate::optim;
use crate::util::Pcg64;
use anyhow::Result;

/// The paper's 9-point grid.
pub fn paper_grid() -> Vec<f64> {
    (0..9)
        .map(|i| 10f64.powf(-5.0 + 6.0 * i as f64 / 8.0))
        .collect()
}

/// MLP architecture used by all §6-substitute experiments.
pub fn mlp_config(spec: &SynthSpec) -> MlpConfig {
    MlpConfig {
        in_dim: spec.dim,
        hidden: vec![64, 64],
        classes: spec.classes,
    }
}

/// Train `algo` at a constant `lr` for `epochs`; returns (test_loss,
/// test_acc, train_acc) at the end.
pub fn train_once(
    algo: &str,
    lr: f64,
    spec: &SynthSpec,
    batch: usize,
    epochs: usize,
    seed: u64,
    decay_at: &[f64],
    mut on_epoch: impl FnMut(usize, f64, f64, f64, f64),
) -> (f64, f64, f64) {
    let mut rng = Pcg64::seeded(seed);
    let (train, test) = synth_class::generate(spec, &mut rng);
    let mlp = Mlp::new(mlp_config(spec));
    let d = mlp.cfg.num_params();
    let mut theta = mlp.init_params(&mut rng);
    let obj = MlpObjective::new(mlp.clone(), train.clone(), batch);
    let mut opt = optim::build(algo, d, lr as f32, 0.9, seed).unwrap();
    let steps_per_epoch = (train.len() / batch).max(1);
    let total = epochs * steps_per_epoch;
    let mut g = vec![0.0f32; d];
    let mut data_rng = Pcg64::seeded(seed ^ 0xabcdef);
    for step in 0..total {
        let frac = step as f64 / total as f64;
        let passed = decay_at.iter().filter(|&&f| frac >= f).count();
        opt.set_lr((lr / 10f64.powi(passed as i32)) as f32);
        obj.stoch_grad(&theta, &mut data_rng, &mut g);
        // weight decay 5e-4 (paper default), decoupled
        let wd = 5e-4f32 * opt.lr();
        for (t, gi) in theta.iter_mut().zip(&g) {
            *t -= wd * *t;
            let _ = gi;
        }
        opt.step(&mut theta, &g);
        if (step + 1) % steps_per_epoch == 0 {
            let epoch = (step + 1) / steps_per_epoch;
            let tr_acc = mlp.accuracy(&theta, &train);
            let te_acc = mlp.accuracy(&theta, &test);
            let tr_loss = mlp.dataset_loss(&theta, &train);
            let te_loss = mlp.dataset_loss(&theta, &test);
            on_epoch(epoch, tr_loss, tr_acc, te_loss, te_acc);
        }
    }
    let te_loss = mlp.dataset_loss(&theta, &test);
    let te_acc = mlp.accuracy(&theta, &test);
    let tr_acc = mlp.accuracy(&theta, &train);
    (te_loss, te_acc, tr_acc)
}

/// Sweep the grid for one algorithm; returns (best_lr, per-lr test losses).
pub fn tune(
    algo: &str,
    spec: &SynthSpec,
    batch: usize,
    epochs: usize,
    seed: u64,
    grid: &[f64],
) -> (f64, Vec<(f64, f64)>) {
    let mut results = Vec::new();
    for &lr in grid {
        let (te_loss, _, _) = train_once(algo, lr, spec, batch, epochs, seed, &[], |_, _, _, _, _| {});
        let te = if te_loss.is_finite() { te_loss } else { f64::INFINITY };
        results.push((lr, te));
    }
    let best = results
        .iter()
        .cloned()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap()
        .0;
    (best, results)
}

pub fn table2(ctx: &ExpContext) -> Result<ExpResult> {
    let spec = SynthSpec::cifar100_like();
    let epochs = if ctx.quick { 3 } else { 15 };
    let grid = if ctx.quick {
        // 5-point sub-grid for CI
        vec![1e-4, 1e-3, 1e-2, 1e-1, 1.0]
    } else {
        paper_grid()
    };
    let mut rec = Recorder::new();
    rec.tag("experiment", "table2");
    let mut lines = vec![format!(
        "== Table 2: LR tuning grid ({} points over [1e-5,1e1]), batch 128, {epochs} epochs ==",
        grid.len()
    )];
    lines.push(format!("  grid: {:?}", grid.iter().map(|g| format!("{g:.1e}")).collect::<Vec<_>>()));
    for algo in crate::optim::PAPER_ALGOS {
        let (best, results) = tune(algo, &spec, 128, epochs, ctx.seed, &grid);
        for (i, (lr, te)) in results.iter().enumerate() {
            rec.record(&format!("testloss_{algo}"), i as u64, *te);
            rec.record(&format!("lr_{algo}"), i as u64, *lr);
        }
        lines.push(format!("  {algo:<12} best lr = {best:.1e}"));
    }
    lines.push(
        "  paper shape (Table 2): sign-based methods tune to ~5.6e-2-scale LRs, SGDM to\n  ~1e-2, SIGNSGDM orders of magnitude smaller (its effective step is the momentum sum)."
            .into(),
    );
    Ok(ExpResult {
        id: "table2",
        summary: lines.join("\n"),
        recorders: vec![("grid".into(), rec)],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_matches_paper() {
        let g = paper_grid();
        assert_eq!(g.len(), 9);
        assert!((g[0] - 1e-5).abs() < 1e-12);
        assert!((g[8] - 10.0).abs() < 1e-9);
        assert!((g[4] - 1e-2).abs() < 1e-5); // midpoint
        assert!((g[5] - 5.6e-2).abs() < 1e-3);
    }

    #[test]
    fn tune_picks_reasonable_lr_for_sgdm() {
        let spec = SynthSpec::tiny();
        let (best, results) = tune("sgdm", &spec, 32, 3, 0, &[1e-5, 1e-2, 10.0]);
        assert_eq!(results.len(), 3);
        // 1e-5 underfits, 10 diverges: 1e-2 must win
        assert!((best - 1e-2).abs() < 1e-9, "best={best}");
    }

    #[test]
    fn train_once_learns_tiny_task() {
        let spec = SynthSpec::tiny();
        let (_, te_acc, tr_acc) =
            train_once("sgdm", 0.05, &spec, 32, 8, 0, &[0.5], |_, _, _, _, _| {});
        assert!(tr_acc > 0.8, "train acc {tr_acc}");
        assert!(te_acc > 0.5, "test acc {te_acc}");
    }
}
