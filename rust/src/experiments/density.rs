//! Fig. 2: the density φ(v) = ‖v‖₁²/(d‖v‖₂²) of the stochastic gradients
//! g_t and of the error-corrected gradients g_t + e_t during training.
//!
//! The paper's point: the convergence rate depends on φ(g+e) (the density
//! of what actually gets compressed), and in practice it stays well above
//! the 1/d worst case (min > 0.13 for VGG19/CIFAR-10). We track both
//! densities while training the MLP substitute with EF-SIGNSGD; the
//! end-to-end transformer run (examples/e2e_transformer.rs) records the
//! same series through the Pallas density kernel.

use super::{ExpContext, ExpResult};
use crate::data::synth_class::{self, SynthSpec};
use crate::metrics::{sparkline, Recorder};
use crate::model::mlp::{Mlp, MlpObjective};
use crate::model::StochasticObjective;
use crate::optim::{EfSignSgd, Optimizer};
use crate::util::Pcg64;
use anyhow::Result;

pub fn fig2(ctx: &ExpContext) -> Result<ExpResult> {
    let spec = SynthSpec::cifar100_like();
    let steps = if ctx.quick { 300 } else { 3_000 };
    let batch = 128;
    let mut rng = Pcg64::seeded(ctx.seed + 41);
    let (train, _) = synth_class::generate(&spec, &mut rng);
    let mlp = Mlp::new(super::lr_tuning::mlp_config(&spec));
    let d = mlp.cfg.num_params();
    let mut theta = mlp.init_params(&mut rng);
    let obj = MlpObjective::new(mlp, train, batch);
    let mut opt = EfSignSgd::new(d, 0.05, Pcg64::seeded(ctx.seed));
    let mut g = vec![0.0f32; d];
    let mut data_rng = Pcg64::seeded(ctx.seed + 42);

    let mut rec = Recorder::new();
    rec.tag("experiment", "fig2");
    let mut phi_g_all = Vec::new();
    let mut phi_pe_all = Vec::new();
    for t in 0..steps {
        obj.stoch_grad(&theta, &mut data_rng, &mut g);
        // phi(g_t): raw gradient density
        let phi_g = crate::tensor::density(&g);
        opt.step(&mut theta, &g);
        // phi(g_t + e_t): density of the error-corrected vector, as
        // reported by the EF step itself (p = γg + e; φ is scale-free in γ
        // only when e=0, so this is the exact quantity Fig. 2 plots for the
        // compressed input).
        let phi_pe = opt.last_density();
        if t % (steps / 200).max(1) == 0 {
            rec.record("phi_grad", t as u64, phi_g);
            rec.record("phi_corrected", t as u64, phi_pe);
        }
        phi_g_all.push(phi_g);
        phi_pe_all.push(phi_pe);
    }
    let min_g = phi_g_all.iter().cloned().fold(f64::INFINITY, f64::min);
    let min_pe = phi_pe_all.iter().cloned().fold(f64::INFINITY, f64::min);
    let mean_g = crate::util::stats::mean(&phi_g_all);
    let mean_pe = crate::util::stats::mean(&phi_pe_all);
    let summary = format!(
        "== Fig 2: gradient density phi during EF-SIGNSGD training (d={d}, {steps} steps) ==\n  \
         phi(g_t):      mean {mean_g:.3}  min {min_g:.3}   {}\n  \
         phi(g_t+e_t):  mean {mean_pe:.3}  min {min_pe:.3}   {}\n  \
         worst case 1/d = {:.2e}\n  \
         paper shape: both densities sit far above 1/d (VGG19 paper min was ~0.13);\n  the corrected density is the one the rate depends on (Lemma 8 + Thm II).",
        sparkline(&phi_g_all, 40),
        sparkline(&phi_pe_all, 40),
        1.0 / d as f64
    );
    Ok(ExpResult {
        id: "fig2",
        summary,
        recorders: vec![("density".into(), rec)],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn densities_far_above_worst_case_quick() {
        let r = fig2(&ExpContext::quick()).unwrap();
        let rec = &r.recorders[0].1;
        let min_pe = rec.get("phi_corrected").unwrap().min().unwrap();
        let d = 1.0 / 7000.0; // ~1/d scale
        assert!(min_pe > 50.0 * d, "min phi(g+e) = {min_pe}");
        let min_g = rec.get("phi_grad").unwrap().min().unwrap();
        assert!(min_g > 0.0 && min_g <= 1.0);
    }
}
