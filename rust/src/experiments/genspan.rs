//! §5.2 / Fig. 3: generalization on the Wilson et al. over-parameterized
//! least-squares problem. Four full-batch algorithms; we track
//!   (a) the distance of the iterate to the span of observed gradients
//!       (Theorem IV's quantity),
//!   (b) train loss, (c) test loss.
//!
//! Expected shape: all four drive train loss → 0; SIGNSGD/SIGNSGDM keep a
//! large distance-to-span and test loss stays high (> 0.8 in the paper);
//! EF-SIGNSGD's distance rises then falls back toward 0 and its test loss
//! tracks SGD's toward ~0.

use super::{ExpContext, ExpResult};
use crate::data::wilson;
use crate::metrics::{sparkline, Recorder};
use crate::model::least_squares::LeastSquares;
use crate::model::StochasticObjective;
use crate::optim;
use crate::tensor::Matrix;
use crate::util::Pcg64;
use anyhow::Result;

pub fn fig3(ctx: &ExpContext) -> Result<ExpResult> {
    // Paper sizes: n = 200, d = 1200. Quick: n = 60.
    let n = if ctx.quick { 60 } else { 200 };
    let steps = if ctx.quick { 400 } else { 2_000 };
    let span_every = (steps / 40).max(1);
    let mut rng = Pcg64::seeded(ctx.seed + 31);
    let w = wilson::generate(n, &mut rng);
    let train = LeastSquares::new(w.train_a.clone(), w.train_y.clone());
    let d = train.dim();

    let mut rec = Recorder::new();
    rec.tag("experiment", "fig3");
    let mut lines = vec![format!(
        "== Fig 3: Wilson data n={n} d={d}, full-batch, {steps} steps =="
    )];

    // Stable GD step for the smooth methods: 0.9/L with L from power
    // iteration; sign methods get paper-style tuned constants with mild
    // decay (any constant keeps them oscillating at a γ√d floor).
    let lmax = crate::linalg::gram_lambda_max(&w.train_a, 50);
    let gd_lr = (0.9 * train.n() as f64 / (2.0 * lmax)) as f32;
    let algos: [(&str, f32, bool); 4] = [
        ("sgd", gd_lr, false),
        ("signsgd_unscaled", 0.002, true),
        ("signsgdm", 0.0005, true),
        ("ef_signsgd", gd_lr, false),
    ];

    for (algo, lr, decay) in algos {
        let mut opt = optim::build(algo, d, lr, 0.9, ctx.seed).unwrap();
        let mut x = vec![0.0f32; d];
        let mut g = vec![0.0f32; d];
        // gradient span accumulator: every observed full-batch gradient
        let mut grads: Vec<Vec<f32>> = Vec::new();
        for t in 0..steps {
            if decay {
                opt.set_lr(lr / (1.0 + t as f32 / 200.0).sqrt());
            }
            train.full_grad(&x, &mut g);
            // keep a bounded basis: the span of full-batch LS gradients has
            // rank <= n, so keep every k-th gradient up to 2n rows.
            if t % span_every == 0 && grads.len() < 2 * n {
                grads.push(g.clone());
            }
            opt.step(&mut x, &g);
            if t % span_every == 0 || t + 1 == steps {
                let gm = Matrix::from_rows(grads.clone());
                let dist = crate::linalg::distance_to_rowspace(&gm, &x, 1e-6)
                    .unwrap_or(f64::NAN);
                rec.record(&format!("dist_{algo}"), t as u64, dist);
                rec.record(&format!("train_{algo}"), t as u64, train.loss(&x));
                rec.record(
                    &format!("test_{algo}"),
                    t as u64,
                    LeastSquares::loss_on(&w.test_a, &w.test_y, &x),
                );
            }
        }
        let tr = rec.get(&format!("train_{algo}")).unwrap().last().unwrap();
        let te = rec.get(&format!("test_{algo}")).unwrap().last().unwrap();
        let di = rec.get(&format!("dist_{algo}")).unwrap().last().unwrap();
        let dist_series = rec.get(&format!("dist_{algo}")).unwrap().values.clone();
        lines.push(format!(
            "  {algo:<18} train {tr:9.2e}  test {te:7.3}  dist-to-span {di:8.3}  {}",
            sparkline(&dist_series, 30)
        ));
    }
    lines.push(
        "  paper shape: sign/signm keep large dist & test loss; EF's dist rises then -> 0,\n  test loss tracks SGD -> ~0"
            .into(),
    );
    Ok(ExpResult {
        id: "fig3",
        summary: lines.join("\n"),
        recorders: vec![("series".into(), rec)],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_shape_quick() {
        let r = fig3(&ExpContext::quick()).unwrap();
        let rec = &r.recorders[0].1;
        // every algorithm fits the train set reasonably
        for algo in ["sgd", "ef_signsgd"] {
            let tr = rec.get(&format!("train_{algo}")).unwrap().last().unwrap();
            assert!(tr < 1e-2, "{algo} train {tr}");
        }
        // EF generalizes: test loss near SGD's; sign methods do not
        let te_sgd = rec.get("test_sgd").unwrap().last().unwrap();
        let te_ef = rec.get("test_ef_signsgd").unwrap().last().unwrap();
        let te_sign = rec.get("test_signsgd_unscaled").unwrap().last().unwrap();
        assert!(te_ef < te_sign * 0.5, "ef {te_ef} vs sign {te_sign}");
        assert!(te_ef < te_sgd + 0.2);
        // distance-to-span ordering
        let d_ef = rec.get("dist_ef_signsgd").unwrap().last().unwrap();
        let d_sign = rec.get("dist_signsgd_unscaled").unwrap().last().unwrap();
        assert!(d_ef < d_sign, "dist ef {d_ef} vs sign {d_sign}");
    }
}
