//! `propcheck`: a small property-based testing framework.
//!
//! proptest/quickcheck are unavailable offline, so this module provides the
//! subset the test-suite needs: composable generators over a seeded
//! [`Pcg64`](crate::util::Pcg64), a configurable runner, and greedy
//! shrinking for failing cases (halving for numbers, prefix/element
//! shrinking for vectors). Failures report the seed so any case can be
//! replayed deterministically.

use crate::util::Pcg64;
use std::fmt::Debug;

/// A generator of random values with an attached shrinker.
pub trait Gen {
    type Item: Clone + Debug;

    fn generate(&self, rng: &mut Pcg64) -> Self::Item;

    /// Candidate smaller versions of a failing value, most aggressive first.
    fn shrink(&self, value: &Self::Item) -> Vec<Self::Item> {
        let _ = value;
        Vec::new()
    }
}

/// Configuration for the runner.
#[derive(Clone, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        // Env overrides let CI crank the case count up without recompiling.
        let cases = std::env::var("PROPCHECK_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(64);
        let seed = std::env::var("PROPCHECK_SEED")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(0x5eed);
        Config {
            cases,
            seed,
            max_shrink_steps: 200,
        }
    }
}

/// Run `prop` over `cfg.cases` generated values; panic with the (shrunk)
/// counterexample on failure.
pub fn check_with<G: Gen>(cfg: &Config, gen: &G, mut prop: impl FnMut(&G::Item) -> bool) {
    for case in 0..cfg.cases {
        let mut rng = Pcg64::new(cfg.seed.wrapping_add(case as u64), 0x9e3779b9);
        let value = gen.generate(&mut rng);
        if !prop(&value) {
            let shrunk = shrink_failure(cfg, gen, value, &mut prop);
            panic!(
                "propcheck: property failed (case {case}, seed {}).\n  counterexample: {:?}",
                cfg.seed, shrunk
            );
        }
    }
}

/// Run with the default config.
pub fn check<G: Gen>(gen: &G, prop: impl FnMut(&G::Item) -> bool) {
    check_with(&Config::default(), gen, prop)
}

fn shrink_failure<G: Gen>(
    cfg: &Config,
    gen: &G,
    mut value: G::Item,
    prop: &mut impl FnMut(&G::Item) -> bool,
) -> G::Item {
    let mut steps = 0;
    'outer: while steps < cfg.max_shrink_steps {
        for candidate in gen.shrink(&value) {
            steps += 1;
            if !prop(&candidate) {
                value = candidate;
                continue 'outer;
            }
            if steps >= cfg.max_shrink_steps {
                break;
            }
        }
        break;
    }
    value
}

// ---------------------------------------------------------------- basic gens

/// Uniform usize in [lo, hi].
pub struct UsizeRange(pub usize, pub usize);

impl Gen for UsizeRange {
    type Item = usize;

    fn generate(&self, rng: &mut Pcg64) -> usize {
        self.0 + rng.below(self.1 - self.0 + 1)
    }

    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.0 {
            let span = *v - self.0;
            out.push(self.0);
            // geometric ladder toward v gives binary-search-like shrinking
            for denom in [2usize, 4, 8, 16, 64, 256] {
                let step = span / denom;
                if step > 0 {
                    out.push(*v - step);
                }
            }
            out.push(v - 1);
        }
        out.dedup();
        out
    }
}

/// Uniform f64 in [lo, hi].
pub struct F64Range(pub f64, pub f64);

impl Gen for F64Range {
    type Item = f64;

    fn generate(&self, rng: &mut Pcg64) -> f64 {
        rng.uniform_in(self.0, self.1)
    }

    fn shrink(&self, v: &f64) -> Vec<f64> {
        let mid = (self.0 + self.1) / 2.0;
        if (*v - mid).abs() > 1e-9 {
            vec![mid, self.0 + (*v - self.0) / 2.0]
        } else {
            vec![]
        }
    }
}

/// Gaussian f32 vector with length drawn from [min_len, max_len].
pub struct VecF32 {
    pub min_len: usize,
    pub max_len: usize,
    pub std: f64,
}

impl VecF32 {
    pub fn new(min_len: usize, max_len: usize) -> Self {
        VecF32 {
            min_len,
            max_len,
            std: 1.0,
        }
    }
}

impl Gen for VecF32 {
    type Item = Vec<f32>;

    fn generate(&self, rng: &mut Pcg64) -> Vec<f32> {
        let len = self.min_len + rng.below(self.max_len - self.min_len + 1);
        let mut v = vec![0.0f32; len];
        rng.fill_normal(&mut v, 0.0, self.std);
        v
    }

    fn shrink(&self, v: &Vec<f32>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        // shorter prefixes
        if v.len() > self.min_len {
            let half = (v.len() / 2).max(self.min_len);
            out.push(v[..half].to_vec());
            out.push(v[..v.len() - 1].to_vec());
        }
        // zero out elements
        if let Some(i) = v.iter().position(|x| *x != 0.0) {
            let mut z = v.clone();
            z[i] = 0.0;
            out.push(z);
        }
        // halve magnitudes
        if v.iter().any(|x| x.abs() > 1e-3) {
            out.push(v.iter().map(|x| x / 2.0).collect());
        }
        out
    }
}

/// Pair of independently generated values.
pub struct Pair<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for Pair<A, B> {
    type Item = (A::Item, B::Item);

    fn generate(&self, rng: &mut Pcg64) -> Self::Item {
        (self.0.generate(rng), self.1.generate(rng))
    }

    fn shrink(&self, (a, b): &Self::Item) -> Vec<Self::Item> {
        let mut out: Vec<Self::Item> = self
            .0
            .shrink(a)
            .into_iter()
            .map(|a2| (a2, b.clone()))
            .collect();
        out.extend(self.1.shrink(b).into_iter().map(|b2| (a.clone(), b2)));
        out
    }
}

/// Map a generator through a function (no shrinking past the map).
pub struct Map<G, F> {
    pub gen: G,
    pub f: F,
}

impl<G: Gen, T: Clone + Debug, F: Fn(G::Item) -> T> Gen for Map<G, F> {
    type Item = T;

    fn generate(&self, rng: &mut Pcg64) -> T {
        (self.f)(self.gen.generate(rng))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(&UsizeRange(1, 100), |&n| n >= 1 && n <= 100);
    }

    #[test]
    #[should_panic(expected = "counterexample")]
    fn failing_property_panics_with_counterexample() {
        check(&UsizeRange(0, 1000), |&n| n < 500);
    }

    #[test]
    fn shrinking_reaches_small_case() {
        // Capture the panic message and check the counterexample shrank to
        // (near) the boundary 500.
        let result = std::panic::catch_unwind(|| {
            check_with(
                &Config {
                    cases: 200,
                    seed: 42,
                    max_shrink_steps: 500,
                },
                &UsizeRange(0, 1_000_000),
                |&n| n < 500,
            );
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().unwrap(),
            Ok(()) => panic!("property should have failed"),
        };
        let ce: usize = msg
            .rsplit("counterexample: ")
            .next()
            .unwrap()
            .trim()
            .parse()
            .unwrap();
        assert!((500..600).contains(&ce), "shrunk to {ce}");
    }

    #[test]
    fn vec_gen_respects_bounds() {
        check(&VecF32::new(2, 50), |v| v.len() >= 2 && v.len() <= 50);
    }

    #[test]
    fn pair_gen_works() {
        check(&Pair(UsizeRange(1, 8), VecF32::new(1, 16)), |(n, v)| {
            *n >= 1 && !v.is_empty()
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = Config {
            cases: 5,
            seed: 7,
            max_shrink_steps: 10,
        };
        let mut first: Vec<Vec<f32>> = Vec::new();
        check_with(&cfg, &VecF32::new(1, 10), |v| {
            first.push(v.clone());
            true
        });
        let mut second: Vec<Vec<f32>> = Vec::new();
        check_with(&cfg, &VecF32::new(1, 10), |v| {
            second.push(v.clone());
            true
        });
        assert_eq!(first, second);
    }
}
