//! Row-major f32 matrix with the operations the native models and the
//! max-margin computation need: matmul (cache-blocked), transpose products,
//! row/col views.

use crate::util::Pcg64;

/// Dense row-major matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_rows(rows: Vec<Vec<f32>>) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        assert!(rows.iter().all(|x| x.len() == c), "ragged rows");
        Matrix {
            rows: r,
            cols: c,
            data: rows.into_iter().flatten().collect(),
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Pcg64) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut m.data, 0.0, std as f64);
        m
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// out = self * other, cache-blocked i-k-j loop order.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        const BK: usize = 64;
        for kb in (0..k).step_by(BK) {
            let kend = (kb + BK).min(k);
            for i in 0..m {
                let arow = &self.data[i * k..(i + 1) * k];
                let orow = &mut out.data[i * n..(i + 1) * n];
                for kk in kb..kend {
                    let a = arow[kk];
                    if a == 0.0 {
                        continue;
                    }
                    let brow = &other.data[kk * n..(kk + 1) * n];
                    for (o, b) in orow.iter_mut().zip(brow) {
                        *o += a * *b;
                    }
                }
            }
        }
        out
    }

    /// y = self * x (matrix-vector).
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, x.len());
        (0..self.rows)
            .map(|r| super::dot(self.row(r), x) as f32)
            .collect()
    }

    /// y = self^T * x without materializing the transpose.
    pub fn matvec_t(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.rows, x.len());
        let mut y = vec![0.0f32; self.cols];
        for r in 0..self.rows {
            super::axpy(x[r], self.row(r), &mut y);
        }
        y
    }

    /// Gram matrix self * self^T (n x n for an n x d matrix).
    pub fn gram(&self) -> Matrix {
        let n = self.rows;
        let mut g = Matrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let v = super::dot(self.row(i), self.row(j)) as f32;
                g.data[i * n + j] = v;
                g.data[j * n + i] = v;
            }
        }
        g
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        super::norm2(&self.data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Matrix::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Matrix::from_rows(vec![vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Pcg64::seeded(3);
        let a = Matrix::randn(7, 7, 1.0, &mut rng);
        let i = Matrix::identity(7);
        assert_eq!(a.matmul(&i).data, a.data);
    }

    #[test]
    fn matmul_matches_naive_blocked_boundary() {
        // size > block to exercise the blocked path
        let mut rng = Pcg64::seeded(5);
        let a = Matrix::randn(9, 130, 1.0, &mut rng);
        let b = Matrix::randn(130, 11, 1.0, &mut rng);
        let c = a.matmul(&b);
        for i in 0..9 {
            for j in 0..11 {
                let mut acc = 0.0f64;
                for k in 0..130 {
                    acc += a.at(i, k) as f64 * b.at(k, j) as f64;
                }
                assert!((c.at(i, j) as f64 - acc).abs() < 1e-3, "({i},{j})");
            }
        }
    }

    #[test]
    fn matvec_t_matches_transpose() {
        let mut rng = Pcg64::seeded(7);
        let a = Matrix::randn(6, 9, 1.0, &mut rng);
        let x: Vec<f32> = (0..6).map(|i| i as f32 - 2.5).collect();
        let expect = a.transpose().matvec(&x);
        let got = a.matvec_t(&x);
        for (g, e) in got.iter().zip(&expect) {
            assert!((g - e).abs() < 1e-5);
        }
    }

    #[test]
    fn gram_is_symmetric_psd_diag() {
        let mut rng = Pcg64::seeded(9);
        let a = Matrix::randn(5, 20, 1.0, &mut rng);
        let g = a.gram();
        for i in 0..5 {
            assert!(g.at(i, i) > 0.0);
            for j in 0..5 {
                assert_eq!(g.at(i, j), g.at(j, i));
            }
        }
    }
}
