//! Dense f32 vector/matrix math.
//!
//! No BLAS in the offline build: everything the optimizers, the native MLP
//! and the collectives need is implemented here (axpy-style kernels, norms,
//! a cache-blocked matmul). Hot-path functions are written branch-free over
//! slices so LLVM auto-vectorizes them; the bench harness tracks their
//! throughput (benches/bench_compressors.rs covers the norm kernels).

pub mod matrix;

pub use matrix::Matrix;

/// Fixed lane width of the elementwise kernels below: `chunks_exact`
/// blocks of this size give the compiler a constant trip count (no
/// per-iteration bounds checks, clean SIMD codegen) while every output
/// coordinate keeps its exact scalar expression — elementwise ops have no
/// cross-lane f32 reduction, so chunking cannot change a single bit.
/// See docs/PERF.md ("Elementwise kernel shape").
const LANES: usize = 8;

/// y += alpha * x
// detlint: hot
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let mut ys = y.chunks_exact_mut(LANES);
    let mut xs = x.chunks_exact(LANES);
    for (yc, xc) in (&mut ys).zip(&mut xs) {
        for (yi, xi) in yc.iter_mut().zip(xc) {
            *yi += alpha * *xi;
        }
    }
    for (yi, xi) in ys.into_remainder().iter_mut().zip(xs.remainder()) {
        *yi += alpha * *xi;
    }
}

/// y = alpha * x + beta * y
// detlint: hot
pub fn axpby(alpha: f32, x: &[f32], beta: f32, y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let mut ys = y.chunks_exact_mut(LANES);
    let mut xs = x.chunks_exact(LANES);
    for (yc, xc) in (&mut ys).zip(&mut xs) {
        for (yi, xi) in yc.iter_mut().zip(xc) {
            *yi = alpha * *xi + beta * *yi;
        }
    }
    for (yi, xi) in ys.into_remainder().iter_mut().zip(xs.remainder()) {
        *yi = alpha * *xi + beta * *yi;
    }
}

/// Element-wise in-place scale.
// detlint: hot
pub fn scale(alpha: f32, x: &mut [f32]) {
    let mut chunks = x.chunks_exact_mut(LANES);
    for c in &mut chunks {
        for xi in c.iter_mut() {
            *xi *= alpha;
        }
    }
    for xi in chunks.into_remainder() {
        *xi *= alpha;
    }
}

/// out = alpha * x (scaled copy).
// detlint: hot
pub fn scale_into(alpha: f32, x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    let mut os = out.chunks_exact_mut(LANES);
    let mut xs = x.chunks_exact(LANES);
    for (oc, xc) in (&mut os).zip(&mut xs) {
        for (oi, xi) in oc.iter_mut().zip(xc) {
            *oi = alpha * *xi;
        }
    }
    for (oi, xi) in os.into_remainder().iter_mut().zip(xs.remainder()) {
        *oi = alpha * *xi;
    }
}

/// out = alpha * x + y — the error-feedback correction kernel
/// (`p = γg + e`); per-coordinate expression order matches the historical
/// inline loop exactly.
// detlint: hot
pub fn scaled_add_into(alpha: f32, x: &[f32], y: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len(), out.len());
    let mut os = out.chunks_exact_mut(LANES);
    let mut xs = x.chunks_exact(LANES);
    let mut ys = y.chunks_exact(LANES);
    for ((oc, xc), yc) in (&mut os).zip(&mut xs).zip(&mut ys) {
        for ((oi, xi), yi) in oc.iter_mut().zip(xc).zip(yc) {
            *oi = alpha * *xi + *yi;
        }
    }
    for ((oi, xi), yi) in os
        .into_remainder()
        .iter_mut()
        .zip(xs.remainder())
        .zip(ys.remainder())
    {
        *oi = alpha * *xi + *yi;
    }
}

/// Dot product, accumulated in f64 for stability.
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter()
        .zip(y)
        .map(|(a, b)| *a as f64 * *b as f64)
        .sum::<f64>()
}

/// L1 norm (f64 accumulation, 4-lane unrolled so the f64 adds pipeline).
pub fn norm1(x: &[f32]) -> f64 {
    let mut acc = [0.0f64; 4];
    let mut chunks = x.chunks_exact(4);
    for c in &mut chunks {
        acc[0] += c[0].abs() as f64;
        acc[1] += c[1].abs() as f64;
        acc[2] += c[2].abs() as f64;
        acc[3] += c[3].abs() as f64;
    }
    let mut total = acc[0] + acc[1] + acc[2] + acc[3];
    for v in chunks.remainder() {
        total += v.abs() as f64;
    }
    total
}

/// Squared L2 norm (f64 accumulation, 4-lane unrolled).
pub fn norm2_sq(x: &[f32]) -> f64 {
    let mut acc = [0.0f64; 4];
    let mut chunks = x.chunks_exact(4);
    for c in &mut chunks {
        acc[0] += c[0] as f64 * c[0] as f64;
        acc[1] += c[1] as f64 * c[1] as f64;
        acc[2] += c[2] as f64 * c[2] as f64;
        acc[3] += c[3] as f64 * c[3] as f64;
    }
    let mut total = acc[0] + acc[1] + acc[2] + acc[3];
    for v in chunks.remainder() {
        total += *v as f64 * *v as f64;
    }
    total
}

/// Single-pass L1 + squared-L2 (the density hot path reads x once).
pub fn norm1_norm2_sq(x: &[f32]) -> (f64, f64) {
    let mut a1 = [0.0f64; 4];
    let mut a2 = [0.0f64; 4];
    let mut chunks = x.chunks_exact(4);
    for c in &mut chunks {
        for i in 0..4 {
            let v = c[i] as f64;
            a1[i] += v.abs();
            a2[i] += v * v;
        }
    }
    let mut l1 = a1.iter().sum::<f64>();
    let mut l2 = a2.iter().sum::<f64>();
    for v in chunks.remainder() {
        let v = *v as f64;
        l1 += v.abs();
        l2 += v * v;
    }
    (l1, l2)
}

/// L2 norm.
pub fn norm2(x: &[f32]) -> f64 {
    norm2_sq(x).sqrt()
}

/// L-infinity norm.
pub fn norm_inf(x: &[f32]) -> f64 {
    x.iter().fold(0.0f64, |m, v| m.max(v.abs() as f64))
}

/// The paper's gradient density phi(v) = ||v||_1^2 / (d ||v||_2^2)
/// (Lemma 8: the scaled-sign operator is a phi(v)-approximate compressor).
/// Returns 1.0 for the zero vector (compression of 0 is exact).
pub fn density(v: &[f32]) -> f64 {
    let (l1, l2) = norm1_norm2_sq(v);
    if l2 == 0.0 {
        1.0
    } else {
        l1 * l1 / (v.len() as f64 * l2)
    }
}

/// out = x - y (also the EF residual update `e = p − δ`).
// detlint: hot
pub fn sub(x: &[f32], y: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len(), out.len());
    let mut os = out.chunks_exact_mut(LANES);
    let mut xs = x.chunks_exact(LANES);
    let mut ys = y.chunks_exact(LANES);
    for ((oc, xc), yc) in (&mut os).zip(&mut xs).zip(&mut ys) {
        for ((o, a), b) in oc.iter_mut().zip(xc).zip(yc) {
            *o = a - b;
        }
    }
    for ((o, a), b) in os
        .into_remainder()
        .iter_mut()
        .zip(xs.remainder())
        .zip(ys.remainder())
    {
        *o = a - b;
    }
}

/// out = x + y
// detlint: hot
pub fn add(x: &[f32], y: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let mut os = out.chunks_exact_mut(LANES);
    let mut xs = x.chunks_exact(LANES);
    let mut ys = y.chunks_exact(LANES);
    for ((oc, xc), yc) in (&mut os).zip(&mut xs).zip(&mut ys) {
        for ((o, a), b) in oc.iter_mut().zip(xc).zip(yc) {
            *o = a + b;
        }
    }
    for ((o, a), b) in os
        .into_remainder()
        .iter_mut()
        .zip(xs.remainder())
        .zip(ys.remainder())
    {
        *o = a + b;
    }
}

/// x -= y, in place.
// detlint: hot
pub fn sub_assign(x: &mut [f32], y: &[f32]) {
    debug_assert_eq!(x.len(), y.len());
    let mut xs = x.chunks_exact_mut(LANES);
    let mut ys = y.chunks_exact(LANES);
    for (xc, yc) in (&mut xs).zip(&mut ys) {
        for (a, b) in xc.iter_mut().zip(yc) {
            *a -= b;
        }
    }
    for (a, b) in xs.into_remainder().iter_mut().zip(ys.remainder()) {
        *a -= b;
    }
}

/// x += y, in place (the aggregation accumulate kernel).
// detlint: hot
pub fn add_assign(x: &mut [f32], y: &[f32]) {
    debug_assert_eq!(x.len(), y.len());
    let mut xs = x.chunks_exact_mut(LANES);
    let mut ys = y.chunks_exact(LANES);
    for (xc, yc) in (&mut xs).zip(&mut ys) {
        for (a, b) in xc.iter_mut().zip(yc) {
            *a += b;
        }
    }
    for (a, b) in xs.into_remainder().iter_mut().zip(ys.remainder()) {
        *a += b;
    }
}

/// Set all elements to zero.
pub fn zero(x: &mut [f32]) {
    x.iter_mut().for_each(|v| *v = 0.0);
}

/// Mean of several equal-length vectors into `out`.
pub fn mean_of(vectors: &[&[f32]], out: &mut [f32]) {
    assert!(!vectors.is_empty());
    zero(out);
    for v in vectors {
        add_assign(out, v);
    }
    scale(1.0 / vectors.len() as f32, out);
}

/// Coordinate-wise sign with sign(0) = 0 (matches `jnp.sign`).
pub fn sign_into(x: &[f32], out: &mut [f32]) {
    for (o, v) in out.iter_mut().zip(x) {
        *o = if *v > 0.0 {
            1.0
        } else if *v < 0.0 {
            -1.0
        } else {
            0.0
        };
    }
}

/// Maximum absolute difference between two vectors.
pub fn max_abs_diff(x: &[f32], y: &[f32]) -> f64 {
    x.iter()
        .zip(y)
        .fold(0.0f64, |m, (a, b)| m.max((a - b).abs() as f64))
}

/// Relative L2 distance ||x-y|| / max(||y||, eps).
pub fn rel_l2(x: &[f32], y: &[f32]) -> f64 {
    let mut num = 0.0f64;
    for (a, b) in x.iter().zip(y) {
        let d = (*a - *b) as f64;
        num += d * d;
    }
    let den = norm2(y).max(1e-12);
    num.sqrt() / den
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_basic() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [1.0, 1.0, 1.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [3.0, 5.0, 7.0]);
    }

    #[test]
    fn norms() {
        let x = [3.0, -4.0];
        assert!((norm1(&x) - 7.0).abs() < 1e-12);
        assert!((norm2(&x) - 5.0).abs() < 1e-12);
        assert!((norm_inf(&x) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn density_extremes() {
        let d = 128;
        let mut one_hot = vec![0.0f32; d];
        one_hot[7] = 3.0;
        assert!((density(&one_hot) - 1.0 / d as f64).abs() < 1e-9);
        let constant = vec![-0.5f32; d];
        assert!((density(&constant) - 1.0).abs() < 1e-9);
        assert_eq!(density(&vec![0.0f32; d]), 1.0);
    }

    #[test]
    fn density_in_unit_interval() {
        let mut rng = crate::util::Pcg64::seeded(1);
        for _ in 0..20 {
            let v: Vec<f32> = (0..500).map(|_| rng.normal() as f32).collect();
            let phi = density(&v);
            assert!(phi > 0.0 && phi <= 1.0 + 1e-9, "phi={phi}");
        }
    }

    #[test]
    fn sign_semantics() {
        let x = [2.5, -0.1, 0.0];
        let mut out = [9.0; 3];
        sign_into(&x, &mut out);
        assert_eq!(out, [1.0, -1.0, 0.0]);
    }

    #[test]
    fn mean_of_vectors() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32, 6.0];
        let mut out = [0.0f32; 2];
        mean_of(&[&a, &b], &mut out);
        assert_eq!(out, [2.0, 4.0]);
    }

    /// The 8-lane blocked kernels are bitwise identical to naive
    /// per-element loops at every alignment class around the lane width
    /// (elementwise ops must be — this pins the contract).
    #[test]
    fn lane_blocked_kernels_match_naive_bitwise() {
        let mut rng = crate::util::Pcg64::seeded(5);
        for n in [1usize, 7, 8, 9, 15, 16, 17, 100] {
            let mut x = vec![0.0f32; n];
            let mut y = vec![0.0f32; n];
            rng.fill_normal(&mut x, 0.0, 1.0);
            rng.fill_normal(&mut y, 0.0, 1.0);
            let (a, b) = (0.37f32, -1.21f32);

            let mut got = y.clone();
            axpy(a, &x, &mut got);
            for i in 0..n {
                assert_eq!(got[i].to_bits(), (y[i] + a * x[i]).to_bits(), "axpy n={n} i={i}");
            }

            let mut got = y.clone();
            axpby(a, &x, b, &mut got);
            for i in 0..n {
                assert_eq!(got[i].to_bits(), (a * x[i] + b * y[i]).to_bits(), "axpby");
            }

            let mut got = x.clone();
            scale(a, &mut got);
            let mut out = vec![0.0f32; n];
            scale_into(a, &x, &mut out);
            for i in 0..n {
                assert_eq!(got[i].to_bits(), (x[i] * a).to_bits(), "scale");
                assert_eq!(out[i].to_bits(), (a * x[i]).to_bits(), "scale_into");
            }

            scaled_add_into(a, &x, &y, &mut out);
            for i in 0..n {
                assert_eq!(out[i].to_bits(), (a * x[i] + y[i]).to_bits(), "scaled_add");
            }

            sub(&x, &y, &mut out);
            for i in 0..n {
                assert_eq!(out[i].to_bits(), (x[i] - y[i]).to_bits(), "sub");
            }
            add(&x, &y, &mut out);
            for i in 0..n {
                assert_eq!(out[i].to_bits(), (x[i] + y[i]).to_bits(), "add");
            }

            let mut got = x.clone();
            add_assign(&mut got, &y);
            for i in 0..n {
                assert_eq!(got[i].to_bits(), (x[i] + y[i]).to_bits(), "add_assign");
            }
            let mut got = x.clone();
            sub_assign(&mut got, &y);
            for i in 0..n {
                assert_eq!(got[i].to_bits(), (x[i] - y[i]).to_bits(), "sub_assign");
            }
        }
    }

    #[test]
    fn dot_f64_accumulation() {
        // Large cancellation that f32 accumulation would get wrong.
        let n = 100_000;
        let x: Vec<f32> = (0..n).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let y = vec![1.0f32; n];
        assert_eq!(dot(&x, &y), 0.0);
    }
}
