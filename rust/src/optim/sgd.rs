//! Plain SGD and SGD-with-momentum (the paper's SGDM baseline).

use super::Optimizer;
use crate::tensor;

/// `x ← x − γ g`.
pub struct Sgd {
    lr: f32,
}

impl Sgd {
    pub fn new(lr: f32) -> Self {
        Sgd { lr }
    }
}

impl Optimizer for Sgd {
    fn name(&self) -> &'static str {
        "sgd"
    }

    fn step(&mut self, x: &mut [f32], g: &[f32]) {
        tensor::axpy(-self.lr, g, x);
    }

    fn lr(&self) -> f32 {
        self.lr
    }

    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }
}

/// Heavy-ball momentum: `m ← g + β m; x ← x − γ m` (PyTorch convention,
/// matching the paper's SGDM with β = 0.9).
pub struct Sgdm {
    lr: f32,
    beta: f32,
    m: Vec<f32>,
}

impl Sgdm {
    pub fn new(d: usize, lr: f32, beta: f32) -> Self {
        Sgdm {
            lr,
            beta,
            m: vec![0.0; d],
        }
    }

    pub fn momentum(&self) -> &[f32] {
        &self.m
    }
}

impl Optimizer for Sgdm {
    fn name(&self) -> &'static str {
        "sgdm"
    }

    fn step(&mut self, x: &mut [f32], g: &[f32]) {
        assert_eq!(g.len(), self.m.len());
        for (m, gi) in self.m.iter_mut().zip(g) {
            *m = gi + self.beta * *m;
        }
        tensor::axpy(-self.lr, &self.m, x);
    }

    fn lr(&self) -> f32 {
        self.lr
    }

    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_step_math() {
        let mut x = vec![1.0f32, 2.0];
        Sgd::new(0.5).step(&mut x, &[2.0, -2.0]);
        assert_eq!(x, vec![0.0, 3.0]);
    }

    #[test]
    fn sgdm_first_step_equals_sgd() {
        let mut x1 = vec![1.0f32, -1.0];
        let mut x2 = x1.clone();
        let g = [0.5f32, 0.25];
        Sgd::new(0.1).step(&mut x1, &g);
        Sgdm::new(2, 0.1, 0.9).step(&mut x2, &g);
        assert_eq!(x1, x2);
    }

    #[test]
    fn sgdm_accumulates_momentum() {
        let mut opt = Sgdm::new(1, 1.0, 0.5);
        let mut x = vec![0.0f32];
        opt.step(&mut x, &[1.0]); // m=1, x=-1
        opt.step(&mut x, &[1.0]); // m=1.5, x=-2.5
        assert!((x[0] + 2.5).abs() < 1e-6);
        assert!((opt.momentum()[0] - 1.5).abs() < 1e-6);
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        let mut x = vec![5.0f32; 10];
        let mut opt = Sgd::new(0.1);
        for _ in 0..200 {
            let g = x.clone();
            opt.step(&mut x, &g);
        }
        assert!(crate::tensor::norm2(&x) < 1e-4);
    }
}
