//! The sign-based family: SIGNSGD, scaled SIGNSGD, SIGNSGDM (signum), the
//! generic EF-SGD (Algorithm 2) and EF-SIGNSGD (Algorithm 1).

use super::Optimizer;
use crate::compress::{Compressor, ErrorFeedback, ScaledSign};
use crate::tensor;
use crate::util::Pcg64;

/// SIGNSGD: `x ← x − γ sign(g)`. The paper's counterexamples show this
/// does not converge in general (§3).
pub struct SignSgd {
    lr: f32,
    scratch: Vec<f32>,
}

impl SignSgd {
    pub fn new(lr: f32) -> Self {
        SignSgd {
            lr,
            scratch: Vec::new(),
        }
    }
}

impl Optimizer for SignSgd {
    fn name(&self) -> &'static str {
        "signsgd"
    }

    fn step(&mut self, x: &mut [f32], g: &[f32]) {
        self.scratch.resize(g.len(), 0.0);
        tensor::sign_into(g, &mut self.scratch);
        tensor::axpy(-self.lr, &self.scratch, x);
    }

    fn lr(&self) -> f32 {
        self.lr
    }

    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }
}

/// Scaled SIGNSGD (§6.1): `x ← x − γ (‖g‖₁/d) sign(g)`. Isolates the effect
/// of scaling from that of error feedback.
pub struct ScaledSignSgd {
    lr: f32,
    scratch: Vec<f32>,
    last_density: f64,
}

impl ScaledSignSgd {
    pub fn new(lr: f32) -> Self {
        ScaledSignSgd {
            lr,
            scratch: Vec::new(),
            last_density: f64::NAN,
        }
    }
}

impl Optimizer for ScaledSignSgd {
    fn name(&self) -> &'static str {
        "scaled_signsgd"
    }

    fn step(&mut self, x: &mut [f32], g: &[f32]) {
        self.scratch.resize(g.len(), 0.0);
        let mut rng = Pcg64::seeded(0); // ScaledSign is deterministic
        ScaledSign.compress(g, &mut self.scratch, &mut rng);
        self.last_density = tensor::density(g);
        tensor::axpy(-self.lr, &self.scratch, x);
    }

    fn lr(&self) -> f32 {
        self.lr
    }

    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }

    fn last_density(&self) -> f64 {
        self.last_density
    }
}

/// SIGNSGDM / signum (Bernstein et al.): `m ← g + β m; x ← x − γ sign(m)`.
pub struct SignSgdm {
    lr: f32,
    beta: f32,
    m: Vec<f32>,
    scratch: Vec<f32>,
}

impl SignSgdm {
    pub fn new(d: usize, lr: f32, beta: f32) -> Self {
        SignSgdm {
            lr,
            beta,
            m: vec![0.0; d],
            scratch: vec![0.0; d],
        }
    }
}

impl Optimizer for SignSgdm {
    fn name(&self) -> &'static str {
        "signsgdm"
    }

    fn step(&mut self, x: &mut [f32], g: &[f32]) {
        assert_eq!(g.len(), self.m.len());
        for (m, gi) in self.m.iter_mut().zip(g) {
            *m = gi + self.beta * *m;
        }
        tensor::sign_into(&self.m, &mut self.scratch);
        tensor::axpy(-self.lr, &self.scratch, x);
    }

    fn lr(&self) -> f32 {
        self.lr
    }

    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }
}

/// EF-SGD (Algorithm 2): error feedback around an arbitrary compressor.
///
/// ```text
/// p ← γ g + e;   Δ ← C(p);   x ← x − Δ;   e ← p − Δ
/// ```
pub struct EfSgd {
    ef: ErrorFeedback,
    lr: f32,
    rng: Pcg64,
    delta: Vec<f32>,
    last_density: f64,
}

impl EfSgd {
    pub fn new(d: usize, lr: f32, compressor: Box<dyn Compressor>) -> Self {
        Self::with_rng(d, lr, compressor, Pcg64::seeded(0))
    }

    pub fn with_rng(d: usize, lr: f32, compressor: Box<dyn Compressor>, rng: Pcg64) -> Self {
        EfSgd {
            ef: ErrorFeedback::new(d, compressor),
            lr,
            rng,
            delta: vec![0.0; d],
            last_density: f64::NAN,
        }
    }

    pub fn error(&self) -> &[f32] {
        self.ef.error()
    }
}

impl Optimizer for EfSgd {
    fn name(&self) -> &'static str {
        "ef_sgd"
    }

    fn step(&mut self, x: &mut [f32], g: &[f32]) {
        self.last_density = self
            .ef
            .step_into(self.lr, g, &mut self.delta, &mut self.rng);
        tensor::sub_assign(x, &self.delta);
    }

    fn lr(&self) -> f32 {
        self.lr
    }

    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }

    fn error_norm(&self) -> f64 {
        self.ef.error_norm()
    }

    fn last_density(&self) -> f64 {
        self.last_density
    }
}

/// EF-SIGNSGD (Algorithm 1) = EF-SGD with the scaled sign compressor.
pub struct EfSignSgd {
    inner: EfSgd,
}

impl EfSignSgd {
    pub fn new(d: usize, lr: f32, rng: Pcg64) -> Self {
        EfSignSgd {
            inner: EfSgd::with_rng(d, lr, Box::new(ScaledSign), rng),
        }
    }

    pub fn error(&self) -> &[f32] {
        self.inner.error()
    }
}

impl Optimizer for EfSignSgd {
    fn name(&self) -> &'static str {
        "ef_signsgd"
    }

    fn step(&mut self, x: &mut [f32], g: &[f32]) {
        self.inner.step(x, g);
    }

    fn lr(&self) -> f32 {
        self.inner.lr()
    }

    fn set_lr(&mut self, lr: f32) {
        self.inner.set_lr(lr);
    }

    fn error_norm(&self) -> f64 {
        self.inner.error_norm()
    }

    fn last_density(&self) -> f64 {
        self.inner.last_density()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::TopK;

    #[test]
    fn signsgd_moves_by_lr_per_coordinate() {
        let mut x = vec![0.0f32, 0.0, 0.0];
        SignSgd::new(0.1).step(&mut x, &[5.0, -0.01, 0.0]);
        assert_eq!(x, vec![-0.1, 0.1, 0.0]);
    }

    #[test]
    fn scaled_signsgd_update_magnitude() {
        let mut x = vec![0.0f32; 4];
        let g = [4.0f32, -2.0, 1.0, 1.0]; // l1 = 8, scale = 2
        ScaledSignSgd::new(0.5).step(&mut x, &g);
        assert_eq!(x, vec![-1.0, 1.0, -1.0, -1.0]);
    }

    #[test]
    fn ef_signsgd_first_step_equals_scaled_signsgd() {
        // e_0 = 0 so the first updates coincide.
        let g = [3.0f32, -1.0, 0.5, 2.0];
        let mut x1 = vec![0.0f32; 4];
        let mut x2 = vec![0.0f32; 4];
        ScaledSignSgd::new(0.2).step(&mut x1, &g);
        EfSignSgd::new(4, 0.2, Pcg64::seeded(0)).step(&mut x2, &g);
        for (a, b) in x1.iter().zip(&x2) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn ef_sgd_with_identity_is_sgd() {
        use crate::compress::Identity;
        let d = 16;
        let mut rng = Pcg64::seeded(1);
        let mut g = vec![0.0f32; d];
        let mut x1 = vec![1.0f32; d];
        let mut x2 = vec![1.0f32; d];
        let mut sgd = crate::optim::Sgd::new(0.05);
        let mut ef = EfSgd::new(d, 0.05, Box::new(Identity));
        for _ in 0..20 {
            rng.fill_normal(&mut g, 0.0, 1.0);
            sgd.step(&mut x1, &g);
            ef.step(&mut x2, &g);
        }
        for (a, b) in x1.iter().zip(&x2) {
            assert!((a - b).abs() < 1e-5);
        }
        assert!(ef.error_norm() < 1e-7);
    }

    #[test]
    fn ef_topk_converges_quadratic_where_plain_topk_biased_lags() {
        // Greedy-coordinate EF (Remark 7): top-1 with EF still converges.
        let d = 10;
        let mut x = (0..d).map(|i| (i + 1) as f32 / 2.0).collect::<Vec<_>>();
        let mut opt = EfSgd::new(d, 0.2, Box::new(TopK::count(1)));
        for _ in 0..500 {
            let g = x.clone();
            opt.step(&mut x, &g);
        }
        assert!(tensor::norm2(&x) < 1e-2, "norm={}", tensor::norm2(&x));
    }

    #[test]
    fn error_norm_zero_before_steps() {
        let opt = EfSignSgd::new(8, 0.1, Pcg64::seeded(0));
        assert_eq!(opt.error_norm(), 0.0);
    }

    #[test]
    fn signsgdm_uses_momentum_sign() {
        let mut opt = SignSgdm::new(1, 0.1, 0.9);
        let mut x = vec![0.0f32];
        // First grad +1 builds m=+1; then a weak -0.5 grad leaves m positive.
        opt.step(&mut x, &[1.0]);
        opt.step(&mut x, &[-0.5]); // m = -0.5 + 0.9 = 0.4 > 0
        assert!((x[0] + 0.2).abs() < 1e-6); // moved -0.1 twice
    }
}
