//! The optimizer zoo from the paper's experiments (§6.1):
//!
//! * [`Sgd`] / [`Sgdm`] — plain and momentum SGD (the upper baseline),
//! * [`SignSgd`] — `x ← x − γ·sign(g)` (the divergent method),
//! * [`ScaledSignSgd`] — `x ← x − γ·(‖g‖₁/d)·sign(g)` (scaling alone),
//! * [`SignSgdm`] — signum: sign of the momentum buffer,
//! * [`EfSgd`] — Algorithm 2: error feedback around ANY compressor,
//! * [`EfSignSgd`] — Algorithm 1 = `EfSgd` with the scaled sign.
//!
//! All optimizers share the [`Optimizer`] trait over flat f32 parameter
//! vectors, matching the L2 artifact interface. Weight decay is decoupled
//! (added to the gradient before the optimizer-specific transform), as in
//! the PyTorch runs of the paper.

pub mod sgd;
pub mod signsgd;

pub use sgd::{Sgd, Sgdm};
pub use signsgd::{EfSgd, EfSignSgd, ScaledSignSgd, SignSgd, SignSgdm};

use crate::util::Pcg64;

/// A first-order optimizer over a flat parameter vector.
pub trait Optimizer: Send {
    fn name(&self) -> &'static str;

    /// Apply one update given the stochastic gradient `g`.
    fn step(&mut self, x: &mut [f32], g: &[f32]);

    /// Current learning rate.
    fn lr(&self) -> f32;

    /// Set the learning rate (schedules are driven externally).
    fn set_lr(&mut self, lr: f32);

    /// Norm of the internal residual error, 0 for non-EF methods.
    /// (Lemma 3 instrumentation.)
    fn error_norm(&self) -> f64 {
        0.0
    }

    /// Density φ(p) of the last compressed vector (Fig. 2 instrumentation);
    /// NaN if not applicable.
    fn last_density(&self) -> f64 {
        f64::NAN
    }
}

/// Decoupled weight decay helper: g_wd = g + wd * x.
pub fn apply_weight_decay(g: &[f32], x: &[f32], wd: f32, out: &mut [f32]) {
    debug_assert_eq!(g.len(), x.len());
    for ((o, gi), xi) in out.iter_mut().zip(g).zip(x) {
        *o = gi + wd * xi;
    }
}

/// Build the four paper algorithms by name (used by experiment drivers):
/// "sgdm", "signsgd" (scaled), "signsgdm", "ef_signsgd", plus "sgd" and
/// "signsgd_unscaled".
pub fn build(name: &str, d: usize, lr: f32, momentum: f32, seed: u64) -> Option<Box<dyn Optimizer>> {
    let rng = Pcg64::seeded(seed);
    Some(match name {
        "sgd" => Box::new(Sgd::new(lr)),
        "sgdm" => Box::new(Sgdm::new(d, lr, momentum)),
        "signsgd_unscaled" => Box::new(SignSgd::new(lr)),
        "signsgd" => Box::new(ScaledSignSgd::new(lr)),
        "signsgdm" => Box::new(SignSgdm::new(d, lr, momentum)),
        "ef_signsgd" => Box::new(EfSignSgd::new(d, lr, rng)),
        _ => return None,
    })
}

/// The canonical four-algorithm comparison set of §6 (display order).
pub const PAPER_ALGOS: [&str; 4] = ["sgdm", "signsgd", "signsgdm", "ef_signsgd"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_all_names() {
        for name in [
            "sgd",
            "sgdm",
            "signsgd",
            "signsgd_unscaled",
            "signsgdm",
            "ef_signsgd",
        ] {
            let opt = build(name, 8, 0.1, 0.9, 0).unwrap();
            assert_eq!(opt.lr(), 0.1);
        }
        assert!(build("bogus", 8, 0.1, 0.9, 0).is_none());
    }

    #[test]
    fn weight_decay_math() {
        let g = [1.0f32, 2.0];
        let x = [10.0f32, -10.0];
        let mut out = [0.0f32; 2];
        apply_weight_decay(&g, &x, 0.1, &mut out);
        assert_eq!(out, [2.0, 1.0]);
    }

    #[test]
    fn all_optimizers_descend_quadratic() {
        // f(x) = 0.5 ||x||^2, grad = x: every method must reduce ||x||
        // substantially from a deterministic start.
        let d = 20;
        for name in ["sgd", "sgdm", "signsgd", "signsgdm", "ef_signsgd"] {
            let mut opt = build(name, d, 0.05, 0.9, 1).unwrap();
            let mut x: Vec<f32> = (0..d).map(|i| 1.0 + (i as f32) / d as f32).collect();
            let start = crate::tensor::norm2(&x);
            for t in 0..300 {
                // decay schedule keeps sign methods from orbiting
                if t == 150 {
                    let lr = opt.lr();
                    opt.set_lr(lr * 0.1);
                }
                let g = x.clone();
                opt.step(&mut x, &g);
            }
            let end = crate::tensor::norm2(&x);
            assert!(end < 0.2 * start, "{name}: {start} -> {end}");
        }
    }
}
