//! Unified metrics registry: fixed-slot counters, gauges, and log2-bucket
//! histograms.
//!
//! Handles ([`CounterId`]/[`GaugeId`]/[`HistId`]) are resolved once at setup
//! via the `register_*` methods (`&mut self`, allocating); hot-path updates
//! go through `&self` and are index-based atomic operations — no allocation,
//! no locks — so `alloc_regression` stays at zero with metrics enabled.
//!
//! Histograms use log2 buckets: bucket 0 holds exactly `{0}`, bucket `b`
//! (1 ≤ b < 63) holds `[2^(b-1), 2^b)`, and bucket 63 is the open tail
//! `[2^62, ∞)`. Bucket boundaries are exact at powers of two and merging two
//! snapshots is element-wise addition (associative) — both are property
//! tested below.
//!
//! [`RunMetrics`] is the engine's standard bundle (frame bits by format,
//! decode latency, staleness, dropped frames, per-worker EF residual norms —
//! the quantity Lemma 3 of Karimireddy et al. 2019 bounds). Snapshots export
//! as JSON and Prometheus text format.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::compress::wire::Format;
use crate::util::json::{arr, num, obj, Json};

/// Handle to a registered counter.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CounterId(usize);

/// Handle to a registered gauge (stores an `f64` as raw bits).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GaugeId(usize);

/// Handle to a registered log2-bucket histogram.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HistId(usize);

/// Number of log2 buckets per histogram.
pub const HIST_BUCKETS: usize = 64;

/// The log2 bucket index for a value: 0 for 0, otherwise
/// `min(bit_length(v), 63)` so `2^k` lands exactly at bucket `k + 1`'s lower
/// edge and the top bucket absorbs the tail.
// detlint: hot
pub fn bucket_of(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        (64 - v.leading_zeros() as usize).min(HIST_BUCKETS - 1)
    }
}

struct Slot {
    name: String,
    v: AtomicU64,
}

struct HistSlot {
    name: String,
    buckets: Box<[AtomicU64; HIST_BUCKETS]>,
    count: AtomicU64,
    sum: AtomicU64,
}

/// Fixed-slot registry. Registration allocates; updates do not.
pub struct MetricsRegistry {
    counters: Vec<Slot>,
    gauges: Vec<Slot>,
    hists: Vec<HistSlot>,
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl MetricsRegistry {
    pub fn new() -> Self {
        MetricsRegistry {
            counters: Vec::new(),
            gauges: Vec::new(),
            hists: Vec::new(),
        }
    }

    /// Register a counter. `name` may embed Prometheus-style labels, e.g.
    /// `ef_frame_bits{format="sign_scaled"}`.
    pub fn register_counter(&mut self, name: &str) -> CounterId {
        self.counters.push(Slot {
            name: name.to_string(),
            v: AtomicU64::new(0),
        });
        CounterId(self.counters.len() - 1)
    }

    pub fn register_gauge(&mut self, name: &str) -> GaugeId {
        self.gauges.push(Slot {
            name: name.to_string(),
            v: AtomicU64::new(0f64.to_bits()),
        });
        GaugeId(self.gauges.len() - 1)
    }

    pub fn register_hist(&mut self, name: &str) -> HistId {
        self.hists.push(HistSlot {
            name: name.to_string(),
            buckets: Box::new(std::array::from_fn(|_| AtomicU64::new(0))),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        });
        HistId(self.hists.len() - 1)
    }

    /// Add `by` to a counter. Index-based atomic add; allocation-free.
    // detlint: hot
    pub fn inc(&self, c: CounterId, by: u64) {
        self.counters[c.0].v.fetch_add(by, Ordering::Relaxed);
    }

    /// Set a gauge to `v`. Allocation-free.
    // detlint: hot
    pub fn set_gauge(&self, g: GaugeId, v: f64) {
        self.gauges[g.0].v.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Record one observation into a histogram. Allocation-free.
    // detlint: hot
    pub fn observe(&self, h: HistId, v: u64) {
        let slot = &self.hists[h.0];
        slot.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        slot.count.fetch_add(1, Ordering::Relaxed);
        slot.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn counter(&self, c: CounterId) -> u64 {
        self.counters[c.0].v.load(Ordering::Relaxed)
    }

    pub fn gauge(&self, g: GaugeId) -> f64 {
        f64::from_bits(self.gauges[g.0].v.load(Ordering::Relaxed))
    }

    pub fn hist_snapshot(&self, h: HistId) -> HistSnapshot {
        let slot = &self.hists[h.0];
        let mut snap = HistSnapshot::new();
        for (b, a) in snap.buckets.iter_mut().zip(slot.buckets.iter()) {
            *b = a.load(Ordering::Relaxed);
        }
        snap.count = slot.count.load(Ordering::Relaxed);
        snap.sum = slot.sum.load(Ordering::Relaxed);
        snap
    }

    /// Export every metric as one JSON object (`counters` / `gauges` /
    /// `histograms` sections; histogram buckets are the raw 64 counts).
    pub fn to_json(&self) -> Json {
        let counters = Json::Obj(
            self.counters
                .iter()
                .map(|c| (c.name.clone(), num(c.v.load(Ordering::Relaxed) as f64)))
                .collect(),
        );
        let gauges = Json::Obj(
            self.gauges
                .iter()
                .map(|g| {
                    (
                        g.name.clone(),
                        num(f64::from_bits(g.v.load(Ordering::Relaxed))),
                    )
                })
                .collect(),
        );
        let hists = Json::Obj(
            self.hists
                .iter()
                .map(|h| {
                    let buckets = h
                        .buckets
                        .iter()
                        .map(|b| num(b.load(Ordering::Relaxed) as f64))
                        .collect();
                    (
                        h.name.clone(),
                        obj(vec![
                            ("count", num(h.count.load(Ordering::Relaxed) as f64)),
                            ("sum", num(h.sum.load(Ordering::Relaxed) as f64)),
                            ("buckets", arr(buckets)),
                        ]),
                    )
                })
                .collect(),
        );
        obj(vec![
            ("counters", counters),
            ("gauges", gauges),
            ("histograms", hists),
        ])
    }

    /// Export in Prometheus text exposition format. Histogram `le` bounds
    /// are the inclusive upper edges of the log2 buckets (`0`, `2^b − 1`,
    /// `+Inf`); bucket values are cumulative as the format requires.
    pub fn to_prometheus(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let mut last_family = "";
        for c in &self.counters {
            let fam = family(&c.name);
            if fam != last_family {
                let _ = writeln!(out, "# TYPE {fam} counter");
                last_family = fam;
            }
            let _ = writeln!(out, "{} {}", c.name, c.v.load(Ordering::Relaxed));
        }
        last_family = "";
        for g in &self.gauges {
            let fam = family(&g.name);
            if fam != last_family {
                let _ = writeln!(out, "# TYPE {fam} gauge");
                last_family = fam;
            }
            let _ = writeln!(
                out,
                "{} {}",
                g.name,
                f64::from_bits(g.v.load(Ordering::Relaxed))
            );
        }
        last_family = "";
        for h in &self.hists {
            let (fam, labels) = split_labels(&h.name);
            if fam != last_family {
                let _ = writeln!(out, "# TYPE {fam} histogram");
                last_family = fam;
            }
            let mut cum = 0u64;
            for (b, slot) in h.buckets.iter().enumerate() {
                cum += slot.load(Ordering::Relaxed);
                let le = le_bound(b);
                if labels.is_empty() {
                    let _ = writeln!(out, "{fam}_bucket{{le=\"{le}\"}} {cum}");
                } else {
                    let _ = writeln!(out, "{fam}_bucket{{{labels},le=\"{le}\"}} {cum}");
                }
            }
            let (sum, count) = (
                h.sum.load(Ordering::Relaxed),
                h.count.load(Ordering::Relaxed),
            );
            if labels.is_empty() {
                let _ = writeln!(out, "{fam}_sum {sum}");
                let _ = writeln!(out, "{fam}_count {count}");
            } else {
                let _ = writeln!(out, "{fam}_sum{{{labels}}} {sum}");
                let _ = writeln!(out, "{fam}_count{{{labels}}} {count}");
            }
        }
        out
    }
}

/// Metric family = the name with any `{label}` suffix stripped.
fn family(name: &str) -> &str {
    name.split('{').next().unwrap_or(name)
}

/// Split `name{a="b"}` into `("name", "a=\"b\"")`; labels are empty when the
/// name carries none.
fn split_labels(name: &str) -> (&str, &str) {
    match name.find('{') {
        Some(i) => (&name[..i], &name[i + 1..name.len() - 1]),
        None => (name, ""),
    }
}

/// Inclusive upper edge of log2 bucket `b`, as a Prometheus `le` string.
fn le_bound(b: usize) -> String {
    if b == 0 {
        "0".to_string()
    } else if b == HIST_BUCKETS - 1 {
        "+Inf".to_string()
    } else {
        format!("{}", (1u64 << b) - 1)
    }
}

/// An owned histogram snapshot — the value-semantics mirror of a registry
/// histogram, used for offline accumulation and for the merge/boundary
/// property tests.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistSnapshot {
    pub buckets: [u64; HIST_BUCKETS],
    pub count: u64,
    pub sum: u64,
}

impl Default for HistSnapshot {
    fn default() -> Self {
        Self::new()
    }
}

impl HistSnapshot {
    pub fn new() -> Self {
        HistSnapshot {
            buckets: [0; HIST_BUCKETS],
            count: 0,
            sum: 0,
        }
    }

    pub fn observe(&mut self, v: u64) {
        self.buckets[bucket_of(v)] += 1;
        self.count += 1;
        self.sum += v;
    }

    /// Element-wise merge. Associative and commutative by construction.
    pub fn merge(&self, other: &HistSnapshot) -> HistSnapshot {
        let mut out = self.clone();
        for (b, o) in out.buckets.iter_mut().zip(other.buckets.iter()) {
            *b += o;
        }
        out.count += other.count;
        out.sum += other.sum;
        out
    }

    /// Index of the highest non-empty bucket, if any observation was made.
    pub fn max_bucket(&self) -> Option<usize> {
        self.buckets.iter().rposition(|&b| b > 0)
    }

    /// Mean of the observed values (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
}

/// The engine's standard metric bundle, wired through both drivers.
///
/// Handles are resolved in [`RunMetrics::new`]; every `observe_*` /`inc_*`
/// method is an index-based atomic update, safe to call from `// detlint:
/// hot` round-path code.
pub struct RunMetrics {
    registry: MetricsRegistry,
    rounds: CounterId,
    folds: CounterId,
    frames: CounterId,
    dropped: CounterId,
    frame_bits: [HistId; Format::COUNT],
    decode_ns: HistId,
    staleness_rounds: HistId,
    residual_milli: HistId,
    residual_norm: Vec<GaugeId>,
}

impl RunMetrics {
    /// Register the standard slots for a run with `workers` workers.
    pub fn new(workers: usize) -> Self {
        let mut r = MetricsRegistry::new();
        let rounds = r.register_counter("ef_rounds_total");
        let folds = r.register_counter("ef_folds_total");
        let frames = r.register_counter("ef_frames_total");
        let dropped = r.register_counter("ef_dropped_frames_total");
        let frame_bits = std::array::from_fn(|i| {
            let fmt = Format::ALL[i];
            r.register_hist(&format!("ef_frame_bits{{format=\"{}\"}}", fmt.name()))
        });
        let decode_ns = r.register_hist("ef_decode_ns");
        let staleness_rounds = r.register_hist("ef_staleness_rounds");
        let residual_milli = r.register_hist("ef_residual_milli");
        let residual_norm = (0..workers)
            .map(|w| r.register_gauge(&format!("ef_residual_norm{{worker=\"{w}\"}}")))
            .collect();
        RunMetrics {
            registry: r,
            rounds,
            folds,
            frames,
            dropped,
            frame_bits,
            decode_ns,
            staleness_rounds,
            residual_milli,
            residual_norm,
        }
    }

    /// One encoded frame hit the wire: bump the frame counter and the
    /// per-format frame-bits histogram.
    // detlint: hot
    pub fn observe_frame(&self, format: Format, bits: u64) {
        self.registry.inc(self.frames, 1);
        self.registry.observe(self.frame_bits[format.index()], bits);
    }

    /// A worker's EF residual after a round: gauge carries the latest
    /// ‖e_t‖, the histogram accumulates ‖e_t‖ in milli-units (log2 buckets
    /// need integers; 1e-3 resolution is far below any Lemma-3 bound of
    /// interest).
    // detlint: hot
    pub fn observe_residual(&self, worker: usize, norm: f64) {
        self.registry.set_gauge(self.residual_norm[worker], norm);
        self.registry.observe(self.residual_milli, (norm * 1e3) as u64);
    }

    /// Measured leader decode+aggregate critical path for one round, in
    /// nanoseconds. Measured (wall) quantities live only in metrics — never
    /// in the trace — so the stripped trace stays deterministic.
    // detlint: hot
    pub fn observe_decode_ns(&self, ns: u64) {
        self.registry.observe(self.decode_ns, ns);
    }

    /// Staleness (rounds) of one folded frame.
    // detlint: hot
    pub fn observe_staleness(&self, rounds: u64) {
        self.registry.observe(self.staleness_rounds, rounds);
    }

    /// Count `n` dropped frames.
    // detlint: hot
    pub fn add_dropped(&self, n: u64) {
        self.registry.inc(self.dropped, n);
    }

    // detlint: hot
    pub fn inc_rounds(&self) {
        self.registry.inc(self.rounds, 1);
    }

    // detlint: hot
    pub fn inc_folds(&self) {
        self.registry.inc(self.folds, 1);
    }

    pub fn frames_total(&self) -> u64 {
        self.registry.counter(self.frames)
    }

    pub fn dropped_total(&self) -> u64 {
        self.registry.counter(self.dropped)
    }

    /// Latest recorded ‖e_t‖ for `worker`.
    pub fn residual_norm(&self, worker: usize) -> f64 {
        self.registry.gauge(self.residual_norm[worker])
    }

    /// Snapshot of the pooled residual histogram (milli-units).
    pub fn residual_hist(&self) -> HistSnapshot {
        self.registry.hist_snapshot(self.residual_milli)
    }

    /// Snapshot of the frame-bits histogram for one wire format.
    pub fn frame_bits_hist(&self, format: Format) -> HistSnapshot {
        self.registry.hist_snapshot(self.frame_bits[format.index()])
    }

    pub fn to_json(&self) -> Json {
        self.registry.to_json()
    }

    pub fn to_prometheus(&self) -> String {
        self.registry.to_prometheus()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Cheap deterministic PRNG for the property tests (no external deps).
    struct Lcg(u64);
    impl Lcg {
        fn next(&mut self) -> u64 {
            self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            self.0
        }
    }

    #[test]
    fn bucket_boundaries_exact_at_powers_of_two() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        for k in 1..62 {
            let p = 1u64 << k;
            // 2^k is the first value of bucket k+1; 2^k − 1 the last of k
            assert_eq!(bucket_of(p), k + 1, "2^{k}");
            assert_eq!(bucket_of(p - 1), k, "2^{k} - 1");
        }
        assert_eq!(bucket_of(u64::MAX), HIST_BUCKETS - 1);
        assert_eq!(bucket_of(1u64 << 62), HIST_BUCKETS - 1);
    }

    #[test]
    fn merge_is_associative_and_commutative() {
        let mut rng = Lcg(0x5eed);
        for _ in 0..50 {
            let mut snaps = [HistSnapshot::new(), HistSnapshot::new(), HistSnapshot::new()];
            for s in snaps.iter_mut() {
                for _ in 0..(rng.next() % 40) {
                    // bias towards small values but cover the full range
                    let v = rng.next() >> (rng.next() % 64);
                    s.observe(v);
                }
            }
            let [a, b, c] = snaps;
            assert_eq!(a.merge(&b).merge(&c), a.merge(&b.merge(&c)));
            assert_eq!(a.merge(&b), b.merge(&a));
        }
    }

    #[test]
    fn merge_matches_pooled_observation() {
        let mut a = HistSnapshot::new();
        let mut b = HistSnapshot::new();
        let mut pooled = HistSnapshot::new();
        for v in [0u64, 1, 2, 3, 512, 513, u64::MAX] {
            a.observe(v);
            pooled.observe(v);
        }
        for v in [7u64, 8, 1 << 40] {
            b.observe(v);
            pooled.observe(v);
        }
        assert_eq!(a.merge(&b), pooled);
        assert_eq!(pooled.max_bucket(), Some(HIST_BUCKETS - 1));
    }

    #[test]
    fn registry_counters_gauges_hists_roundtrip() {
        let mut r = MetricsRegistry::new();
        let c = r.register_counter("ef_test_total");
        let g = r.register_gauge("ef_test_gauge{worker=\"2\"}");
        let h = r.register_hist("ef_test_hist");
        r.inc(c, 3);
        r.inc(c, 4);
        r.set_gauge(g, -1.5);
        r.observe(h, 0);
        r.observe(h, 9);
        assert_eq!(r.counter(c), 7);
        assert_eq!(r.gauge(g), -1.5);
        let snap = r.hist_snapshot(h);
        assert_eq!(snap.count, 2);
        assert_eq!(snap.sum, 9);
        assert_eq!(snap.buckets[0], 1);
        assert_eq!(snap.buckets[bucket_of(9)], 1);
    }

    #[test]
    fn prometheus_export_shape() {
        let mut r = MetricsRegistry::new();
        let c = r.register_counter("ef_frames_total");
        let h = r.register_hist("ef_frame_bits{format=\"sign_scaled\"}");
        r.inc(c, 2);
        r.observe(h, 4);
        let text = r.to_prometheus();
        assert!(text.contains("# TYPE ef_frames_total counter"));
        assert!(text.contains("ef_frames_total 2"));
        assert!(text.contains("# TYPE ef_frame_bits histogram"));
        assert!(text.contains("ef_frame_bits_bucket{format=\"sign_scaled\",le=\"7\"} 1"));
        assert!(text.contains("ef_frame_bits_bucket{format=\"sign_scaled\",le=\"+Inf\"} 1"));
        assert!(text.contains("ef_frame_bits_sum{format=\"sign_scaled\"} 4"));
        assert!(text.contains("ef_frame_bits_count{format=\"sign_scaled\"} 1"));
        // cumulative counts: the le="3" bucket (below the observation) is 0
        assert!(text.contains("ef_frame_bits_bucket{format=\"sign_scaled\",le=\"3\"} 0"));
    }

    #[test]
    fn run_metrics_bundle_updates() {
        let m = RunMetrics::new(2);
        m.observe_frame(Format::SignScaled, 100);
        m.observe_frame(Format::DenseF32, 4096);
        m.observe_residual(1, 0.25);
        m.observe_staleness(3);
        m.add_dropped(2);
        m.inc_rounds();
        assert_eq!(m.frames_total(), 2);
        assert_eq!(m.dropped_total(), 2);
        assert_eq!(m.residual_norm(1), 0.25);
        assert_eq!(m.residual_norm(0), 0.0);
        assert_eq!(m.frame_bits_hist(Format::SignScaled).count, 1);
        assert_eq!(m.residual_hist().buckets[bucket_of(250)], 1);
        let json = Json::parse(&m.to_json().to_string_compact()).unwrap();
        assert!(json.at(&["counters", "ef_rounds_total"]).is_some());
        // the inner quotes of the label survive the JSON round trip
        assert_eq!(
            json.at(&["gauges", "ef_residual_norm{worker=\"1\"}"])
                .unwrap()
                .as_f64(),
            Some(0.25)
        );
    }
}
