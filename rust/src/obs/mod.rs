//! Observability: the flight recorder ([`trace`]) and the unified metrics
//! registry ([`metrics`]).
//!
//! Design constraints (see `docs/OBSERVABILITY.md`):
//!
//! * **Deterministic by default.** Trace timestamps come from the virtual
//!   clock, never the wall clock; the stripped Chrome export is
//!   byte-identical across `--threads`. Wall-clock data exists only in the
//!   opt-in side channel and in metrics (which are diagnostics, not part of
//!   the deterministic contract).
//! * **Allocation-free on the round path.** Ring slots and metric slots are
//!   allocated at setup; recording is indexed writes and atomics, so
//!   `alloc_regression` holds with telemetry enabled.
//!
//! [`run_report`] folds the engine's pre-existing per-run structs
//! (`TrafficStats`, `StalenessStats`, `LeaderProfile`) and the registry into
//! one end-of-run `RunReport` JSON document.

pub mod metrics;
pub mod trace;

pub use metrics::{
    bucket_of, CounterId, GaugeId, HistId, HistSnapshot, MetricsRegistry, RunMetrics,
};
pub use trace::{DropReason, EventKind, TraceEvent, TraceRecorder, DEFAULT_RING_CAPACITY};

use crate::coordinator::TrainOutcome;
use crate::util::json::{num, obj, Json};

/// Fold a finished run's traffic, leader-profile, and staleness accounting —
/// plus the metrics registry, when one was attached — into a single
/// `RunReport` JSON object (the `--metrics-out` payload).
pub fn run_report(outcome: &TrainOutcome, metrics: Option<&RunMetrics>) -> Json {
    let traffic = &outcome.traffic;
    let per_kind_bits = Json::Obj(
        traffic
            .per_kind
            .iter()
            .map(|(k, b)| (k.name().to_string(), num(*b as f64)))
            .collect(),
    );
    let per_kind_msgs = Json::Obj(
        traffic
            .msg_count
            .iter()
            .map(|(k, c)| (k.name().to_string(), num(*c as f64)))
            .collect(),
    );
    let mut report = vec![
        (
            "run",
            obj(vec![
                ("rounds", num(outcome.rounds as f64)),
                ("sim_time_s", num(outcome.sim_time_s)),
            ]),
        ),
        (
            "traffic",
            obj(vec![
                ("total_bits", num(traffic.total_bits as f64)),
                ("dropped_frames", num(traffic.dropped() as f64)),
                ("serial_time_s", num(traffic.serial_time_s)),
                ("per_kind_bits", per_kind_bits),
                ("per_kind_msgs", per_kind_msgs),
            ]),
        ),
        (
            "leader",
            obj(vec![
                ("decode_agg_s", num(outcome.profile.decode_agg_s)),
                ("critical_s", num(outcome.profile.critical_s)),
                ("mean_critical_s", num(outcome.profile.mean_critical_s())),
                ("shards", num(outcome.profile.per_shard_s.len() as f64)),
            ]),
        ),
        (
            "staleness",
            obj(vec![
                ("folds", num(outcome.staleness.folds as f64)),
                ("frames", num(outcome.staleness.frames as f64)),
                ("stale_frames", num(outcome.staleness.stale_frames as f64)),
                (
                    "max_staleness_seen",
                    num(outcome.staleness.max_staleness_seen as f64),
                ),
                ("mean_staleness", num(outcome.staleness.mean_staleness())),
                ("stale_fraction", num(outcome.staleness.stale_fraction())),
                ("mean_batch", num(outcome.staleness.mean_batch())),
            ]),
        ),
    ];
    if let Some(m) = metrics {
        report.push(("metrics", m.to_json()));
    }
    obj(report)
}
