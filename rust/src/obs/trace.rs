//! Flight recorder: deterministic sim-time event tracing.
//!
//! Every node of the simulated cluster (worker, shard leader, driver) owns a
//! fixed-capacity ring of [`TraceEvent`]s. Events are stamped with **virtual
//! time** from [`crate::net::SimClock`] — never the wall clock — so the
//! recorded trace is a pure function of the seeded models and stays
//! byte-identical for any `--threads` setting (see `docs/OBSERVABILITY.md`
//! for the exact determinism contract, including the cross-`--shards`
//! caveat). An optional wall-clock side channel can be enabled for local
//! profiling; it lives behind `// detlint: profiling` regions and is omitted
//! from the stripped export, so the deterministic view never depends on it.
//!
//! Ring writes are single-writer per node by construction: the driver thread
//! records driver- and leader-track events, and each worker's events are
//! recorded only by the pool actor that owns that worker. The fabric itself
//! never records (its `send` runs concurrently on pool threads).
//!
//! Exports: Chrome trace-event JSON (`to_chrome_json`, renderable in Perfetto
//! or `chrome://tracing`) and a compact text timeline for terminals.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::{arr, num, obj, s, Json};

/// Default per-node ring capacity (events). Chosen so a traced toy run keeps
/// every event while a long run degrades gracefully to "most recent window"
/// semantics instead of growing without bound.
pub const DEFAULT_RING_CAPACITY: usize = 4096;

/// Why a frame was dropped on the wire path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DropReason {
    /// The payload failed structural validation in the decoder
    /// (`DecodeError`); counted on the decode pool threads and surfaced as
    /// one lumped driver-track event per round.
    Undecodable,
    /// A frame carried a shard tag that does not match the leader it arrived
    /// at (mis-routed by an adversary or a topology bug).
    ShardMismatch,
    /// The frame's sender departed the membership and the epoch it was
    /// dispatched in has closed (see `docs/ASYNC.md`, "Membership epochs").
    Departed,
}

/// Typed trace event kinds, one per instrumented point of the round path.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum EventKind {
    /// Driver begins a (sync round | async dispatch); arg = workers involved.
    #[default]
    RoundStart,
    /// A shard leader's parameter broadcast is scheduled; arg = shard index.
    BroadcastSent,
    /// A worker finished encoding one frame; arg = frame bits on the wire.
    FrameEncoded,
    /// A gradient frame reached a leader (sync, leader track) or the driver's
    /// event queue popped an in-flight push (async, driver track); arg =
    /// source worker id.
    FrameArrived,
    /// Decode + aggregate pass begins; arg = frames (sync) / batch size.
    DecodeStart,
    /// Decode + aggregate pass finished; arg mirrors [`Self::DecodeStart`].
    DecodeDone,
    /// The round's model update has been applied.
    AggregateDone,
    /// Async driver folded a quorum; arg = batch size.
    QuorumFold,
    /// Frame(s) dropped; arg = source worker ([`DropReason::ShardMismatch`])
    /// or dropped-count delta ([`DropReason::Undecodable`]).
    FrameDropped(DropReason),
    /// A Byzantine worker corrupted its outgoing frames; arg = frame count.
    AdversaryCorrupt,
    /// Driver wrote a checkpoint; arg = 0.
    CheckpointSaved,
    /// A worker joined (or rejoined) the membership; arg = worker id.
    /// Recorded on the driver track at the epoch transition.
    MemberJoin,
    /// A worker left the membership (graceful leave or fail-stop crash);
    /// arg = worker id. Recorded on the driver track.
    MemberLeave,
}

impl EventKind {
    /// Stable snake_case name used in exports.
    pub fn name(self) -> &'static str {
        match self {
            EventKind::RoundStart => "round_start",
            EventKind::BroadcastSent => "broadcast_sent",
            EventKind::FrameEncoded => "frame_encoded",
            EventKind::FrameArrived => "frame_arrived",
            EventKind::DecodeStart => "decode_start",
            EventKind::DecodeDone => "decode_done",
            EventKind::AggregateDone => "aggregate_done",
            EventKind::QuorumFold => "quorum_fold",
            EventKind::FrameDropped(DropReason::Undecodable) => "frame_dropped_undecodable",
            EventKind::FrameDropped(DropReason::ShardMismatch) => "frame_dropped_shard_mismatch",
            EventKind::FrameDropped(DropReason::Departed) => "frame_dropped_departed",
            EventKind::AdversaryCorrupt => "adversary_corrupt",
            EventKind::CheckpointSaved => "checkpoint_saved",
            EventKind::MemberJoin => "member_join",
            EventKind::MemberLeave => "member_leave",
        }
    }
}

/// One recorded event. `t` is sim-time seconds; `wall_ns` is the optional
/// wall-clock side channel (always 0 unless [`TraceRecorder::enable_wall_clock`]
/// was called) and is excluded from the stripped export.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TraceEvent {
    pub t: f64,
    pub round: u64,
    pub kind: EventKind,
    pub arg: u64,
    pub wall_ns: u64,
}

/// Fixed-capacity overwrite-oldest ring. Storage is allocated once at
/// construction; pushes in the steady state never allocate.
struct NodeRing {
    buf: Vec<TraceEvent>,
    head: usize,
    len: usize,
    evicted: u64,
}

impl NodeRing {
    fn new(capacity: usize) -> Self {
        NodeRing {
            buf: vec![TraceEvent::default(); capacity],
            head: 0,
            len: 0,
            evicted: 0,
        }
    }

    // detlint: hot
    fn push(&mut self, ev: TraceEvent) {
        let cap = self.buf.len();
        self.buf[self.head] = ev;
        self.head = (self.head + 1) % cap;
        if self.len < cap {
            self.len += 1;
        } else {
            self.evicted += 1;
        }
    }

    /// Visit events oldest-first.
    fn for_each(&self, mut f: impl FnMut(&TraceEvent)) {
        let cap = self.buf.len();
        let start = (self.head + cap - self.len) % cap;
        for i in 0..self.len {
            f(&self.buf[(start + i) % cap]);
        }
    }
}

/// Per-node ring-buffer event recorder for the whole simulated cluster.
///
/// Track layout: nodes `0..workers` are worker tracks, `workers..workers +
/// shards` are shard-leader tracks, and the last track is the driver.
pub struct TraceRecorder {
    workers: usize,
    shards: usize,
    rings: Vec<Mutex<NodeRing>>,
    wall_epoch: Option<Instant>,
}

impl TraceRecorder {
    /// Build a recorder with `capacity` event slots per node. All ring
    /// storage is allocated here; recording is allocation-free.
    pub fn new(workers: usize, shards: usize, capacity: usize) -> Self {
        assert!(capacity > 0, "trace ring capacity must be >= 1");
        let tracks = workers + shards + 1;
        TraceRecorder {
            workers,
            shards,
            rings: (0..tracks).map(|_| Mutex::new(NodeRing::new(capacity))).collect(),
            wall_epoch: None,
        }
    }

    /// Convenience constructor that also wraps in an [`Arc`] for sharing
    /// across the fabric and the drivers.
    pub fn shared(workers: usize, shards: usize, capacity: usize) -> Arc<Self> {
        Arc::new(Self::new(workers, shards, capacity))
    }

    /// Enable the wall-clock side channel: subsequent events carry a
    /// nanosecond stamp relative to this call. Off by default — the sim-time
    /// view never depends on it, and `to_chrome_json(false)` omits it.
    // detlint: profiling — opt-in wall stamps; the sim-time view stays a pure
    // function of the seeded models
    pub fn enable_wall_clock(&mut self) {
        self.wall_epoch = Some(Instant::now());
    }

    // detlint: profiling — reads the optional wall epoch (zero when the side
    // channel is off, which is the deterministic default)
    fn wall_ns(&self) -> u64 {
        match &self.wall_epoch {
            Some(epoch) => epoch.elapsed().as_nanos() as u64,
            None => 0,
        }
    }

    /// Number of tracks (workers + shard leaders + driver).
    pub fn num_tracks(&self) -> usize {
        self.rings.len()
    }

    /// The driver's track id (last track).
    pub fn driver_track(&self) -> usize {
        self.rings.len() - 1
    }

    /// The track id of shard leader `s`.
    pub fn leader_track(&self, s: usize) -> usize {
        self.workers + s
    }

    /// Human-readable track name, mirrored into the Chrome trace metadata.
    pub fn track_name(&self, node: usize) -> String {
        if node < self.workers {
            format!("worker {node}")
        } else if node < self.workers + self.shards {
            format!("shard-leader {}", node - self.workers)
        } else {
            "driver".to_string()
        }
    }

    /// Record one event on `node`'s ring at sim-time `t`. Allocation-free:
    /// a mutex lock plus an indexed write into preallocated storage.
    // detlint: hot
    pub fn record(&self, node: usize, t: f64, round: u64, kind: EventKind, arg: u64) {
        let wall_ns = self.wall_ns();
        let ev = TraceEvent {
            t,
            round,
            kind,
            arg,
            wall_ns,
        };
        self.rings[node].lock().unwrap().push(ev);
    }

    /// Total events currently retained across all rings.
    pub fn total_events(&self) -> usize {
        self.rings.iter().map(|r| r.lock().unwrap().len).sum()
    }

    /// Total events overwritten because a ring wrapped.
    pub fn total_evicted(&self) -> u64 {
        self.rings.iter().map(|r| r.lock().unwrap().evicted).sum()
    }

    /// Copy out one node's retained events, oldest-first (test/export use).
    pub fn events(&self, node: usize) -> Vec<TraceEvent> {
        let ring = self.rings[node].lock().unwrap();
        let mut out = Vec::with_capacity(ring.len);
        ring.for_each(|ev| out.push(*ev));
        out
    }

    /// Export the trace as Chrome trace-event JSON on the virtual timeline:
    /// per-track `M` metadata, `i` instant events (ts in microseconds =
    /// sim-time × 1e6), and `X` spans synthesized from each driver-track
    /// `round_start`/`aggregate_done` pair. Load the file in Perfetto
    /// (<https://ui.perfetto.dev>) or `chrome://tracing`.
    ///
    /// With `include_wall = false` the export contains only sim-time fields
    /// and is byte-identical across thread counts (the "stripped" trace).
    pub fn to_chrome_json(&self, include_wall: bool) -> Json {
        let mut events: Vec<Json> = Vec::new();
        events.push(obj(vec![
            ("ph", s("M")),
            ("pid", num(0.0)),
            ("name", s("process_name")),
            ("args", obj(vec![("name", s("ef-sgd simulated cluster"))])),
        ]));
        for node in 0..self.num_tracks() {
            events.push(obj(vec![
                ("ph", s("M")),
                ("pid", num(0.0)),
                ("tid", num(node as f64)),
                ("name", s("thread_name")),
                ("args", obj(vec![("name", Json::Str(self.track_name(node)))])),
            ]));
        }
        for node in 0..self.num_tracks() {
            let ring = self.rings[node].lock().unwrap();
            ring.for_each(|ev| {
                let mut args = vec![("round", num(ev.round as f64)), ("arg", num(ev.arg as f64))];
                if include_wall {
                    args.push(("wall_ns", num(ev.wall_ns as f64)));
                }
                events.push(obj(vec![
                    ("ph", s("i")),
                    ("s", s("t")),
                    ("pid", num(0.0)),
                    ("tid", num(node as f64)),
                    ("ts", num(ev.t * 1e6)),
                    ("name", s(ev.kind.name())),
                    ("args", obj(args)),
                ]));
            });
        }
        // Synthesized round spans on the driver track so Perfetto shows the
        // run as a flamegraph, not just instants.
        let driver = self.driver_track();
        let ring = self.rings[driver].lock().unwrap();
        let mut open: Option<(u64, f64)> = None;
        ring.for_each(|ev| match ev.kind {
            EventKind::RoundStart => open = Some((ev.round, ev.t)),
            EventKind::AggregateDone => {
                if let Some((r, t0)) = open.take() {
                    if r == ev.round {
                        events.push(obj(vec![
                            ("ph", s("X")),
                            ("pid", num(0.0)),
                            ("tid", num(driver as f64)),
                            ("ts", num(t0 * 1e6)),
                            ("dur", num((ev.t - t0) * 1e6)),
                            ("name", Json::Str(format!("round {r}"))),
                            ("args", obj(vec![("round", num(r as f64))])),
                        ]));
                    }
                }
            }
            _ => {}
        });
        drop(ring);
        obj(vec![
            ("displayTimeUnit", s("ms")),
            ("traceEvents", arr(events)),
        ])
    }

    /// Compact chronological text timeline for terminals. Ties are broken by
    /// `(node, ring order)` so the output is deterministic.
    pub fn text_timeline(&self, max_lines: usize) -> String {
        let mut all: Vec<(f64, usize, usize, TraceEvent)> = Vec::new();
        for node in 0..self.num_tracks() {
            let ring = self.rings[node].lock().unwrap();
            let mut seq = 0usize;
            ring.for_each(|ev| {
                all.push((ev.t, node, seq, *ev));
                seq += 1;
            });
        }
        all.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2)));
        let total = all.len();
        let mut out = String::new();
        for (t, node, _seq, ev) in all.into_iter().take(max_lines) {
            use std::fmt::Write as _;
            let _ = writeln!(
                out,
                "  {t:>12.6}s  {:<16} r{:<5} {} ({})",
                self.track_name(node),
                ev.round,
                ev.kind.name(),
                ev.arg
            );
        }
        if total > max_lines {
            use std::fmt::Write as _;
            let _ = writeln!(out, "  … {} more events", total - max_lines);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_overwrites_oldest() {
        let tr = TraceRecorder::new(1, 1, 3);
        for i in 0..5u64 {
            tr.record(0, i as f64, i, EventKind::FrameEncoded, i);
        }
        let evs = tr.events(0);
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].arg, 2);
        assert_eq!(evs[2].arg, 4);
        assert_eq!(tr.total_evicted(), 2);
    }

    #[test]
    fn track_layout_and_names() {
        let tr = TraceRecorder::new(3, 2, 8);
        assert_eq!(tr.num_tracks(), 6);
        assert_eq!(tr.driver_track(), 5);
        assert_eq!(tr.leader_track(1), 4);
        assert_eq!(tr.track_name(0), "worker 0");
        assert_eq!(tr.track_name(3), "shard-leader 0");
        assert_eq!(tr.track_name(5), "driver");
    }

    #[test]
    fn chrome_export_parses_and_spans_rounds() {
        let tr = TraceRecorder::new(1, 1, 16);
        let d = tr.driver_track();
        tr.record(d, 0.0, 0, EventKind::RoundStart, 1);
        tr.record(0, 0.5, 0, EventKind::FrameEncoded, 64);
        tr.record(d, 1.0, 0, EventKind::AggregateDone, 0);
        let json = tr.to_chrome_json(false);
        let parsed = Json::parse(&json.to_string_compact()).unwrap();
        assert_eq!(parsed.at(&["displayTimeUnit"]).unwrap().as_str(), Some("ms"));
        let evs = parsed.at(&["traceEvents"]).unwrap().as_arr().unwrap();
        // 1 process_name + 3 thread_name metadata, 3 instants, 1 span
        assert_eq!(evs.len(), 8);
        let span = evs.last().unwrap();
        assert_eq!(span.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(span.get("dur").unwrap().as_f64(), Some(1e6));
        // stripped export carries no wall-clock field anywhere
        assert!(!json.to_string_compact().contains("wall_ns"));
    }

    #[test]
    fn wall_side_channel_only_in_unstripped_export() {
        let mut rec = TraceRecorder::new(1, 1, 4);
        rec.enable_wall_clock();
        rec.record(0, 0.0, 0, EventKind::FrameEncoded, 1);
        let full = rec.to_chrome_json(true).to_string_compact();
        assert!(full.contains("wall_ns"));
        assert!(!rec.to_chrome_json(false).to_string_compact().contains("wall_ns"));
    }

    #[test]
    fn text_timeline_is_sorted_and_truncates() {
        let tr = TraceRecorder::new(2, 1, 8);
        tr.record(1, 2.0, 0, EventKind::FrameEncoded, 1);
        tr.record(0, 1.0, 0, EventKind::FrameEncoded, 2);
        tr.record(tr.driver_track(), 3.0, 0, EventKind::AggregateDone, 0);
        let full = tr.text_timeline(10);
        let first = full.lines().next().unwrap();
        assert!(first.contains("worker 0"), "{first}");
        let short = tr.text_timeline(1);
        assert!(short.contains("… 2 more events"));
    }
}
