//! Gradient compression operators.
//!
//! The paper's Assumption A: `C` is a **δ-approximate compressor** if
//! `‖C(x) − x‖² ≤ (1 − δ)‖x‖²`. Biased examples: (scaled) sign, top-k.
//! Unbiased examples (satisfying it in expectation after scaling): QSGD,
//! TernGrad, random-k. [`measure_delta`] empirically estimates δ, and the
//! property tests check the contraction for every compressor in the
//! registry.
//!
//! These are the Rust mirrors of the L1 Pallas kernels; the integration
//! tests check both against each other through the PJRT runtime.

pub mod error_feedback;
pub mod qsgd;
pub mod randomk;
pub mod sign;
pub mod topk;
pub mod wire;

pub use error_feedback::ErrorFeedback;
pub use qsgd::{Qsgd, ScaledUnbiased, TernGrad};
pub use randomk::RandomK;
pub use sign::{ScaledSign, Sign};
pub use topk::TopK;

use crate::config::CompressorKind;
use crate::util::Pcg64;

/// A gradient compression operator `C: R^d -> R^d`.
///
/// Implementations must be pure given (`p`, `rng`): the coordinator relies
/// on replayability for checkpoint recovery.
pub trait Compressor: Send + Sync {
    fn name(&self) -> &'static str;

    /// Write `C(p)` into `out` (same length). `rng` is used only by
    /// randomized schemes.
    fn compress(&self, p: &[f32], out: &mut [f32], rng: &mut Pcg64);

    /// Wire size in bits for transmitting `C(p)` with this scheme's codec
    /// for a length-`d` vector (the paper's communication accounting, e.g.
    /// `d + 32` for scaled sign). Exact for fixed-length codecs; for
    /// data-dependent codecs (QSGD's Elias pack) this is the worst-case
    /// bound — the fabric always accounts the exact per-frame
    /// `wire::Encoded::bits`, and `wire::qsgd_wire_bits` gives the exact
    /// size of a concrete vector.
    fn wire_bits(&self, d: usize) -> u64;

    /// True if `E[C(p)] = p`.
    fn unbiased(&self) -> bool {
        false
    }

    /// Convenience allocating wrapper.
    fn compress_vec(&self, p: &[f32], rng: &mut Pcg64) -> Vec<f32> {
        let mut out = vec![0.0f32; p.len()];
        self.compress(p, &mut out, rng);
        out
    }
}

/// Identity "compressor" (δ = 1): the uncompressed SGD path.
pub struct Identity;

impl Compressor for Identity {
    fn name(&self) -> &'static str {
        "identity"
    }

    fn compress(&self, p: &[f32], out: &mut [f32], _rng: &mut Pcg64) {
        out.copy_from_slice(p);
    }

    fn wire_bits(&self, d: usize) -> u64 {
        32 * d as u64
    }

    fn unbiased(&self) -> bool {
        true
    }
}

/// Construct a compressor from a config enum.
/// `d` is needed by size-parameterized schemes (top-k/random-k).
pub fn build(kind: CompressorKind, d: usize, k_frac: usize, qsgd_levels: u32) -> Box<dyn Compressor> {
    match kind {
        CompressorKind::None => Box::new(Identity),
        CompressorKind::Sign => Box::new(Sign),
        CompressorKind::ScaledSign => Box::new(ScaledSign),
        CompressorKind::TopK => Box::new(TopK::count((d / k_frac).max(1))),
        CompressorKind::RandomK => Box::new(RandomK::count((d / k_frac).max(1))),
        CompressorKind::Qsgd => Box::new(Qsgd::new(qsgd_levels)),
        CompressorKind::TernGrad => Box::new(qsgd::TernGrad),
    }
}

/// Empirical compression quality: `1 − ‖C(p) − p‖²/‖p‖²` (the δ in
/// Assumption A for this particular input).
pub fn measure_delta(c: &dyn Compressor, p: &[f32], rng: &mut Pcg64) -> f64 {
    let out = c.compress_vec(p, rng);
    let mut err = 0.0f64;
    for (o, x) in out.iter().zip(p) {
        let d = (*o - *x) as f64;
        err += d * d;
    }
    let norm = crate::tensor::norm2_sq(p);
    if norm == 0.0 {
        1.0
    } else {
        1.0 - err / norm
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::propcheck::{self, VecF32};
    use crate::tensor;

    fn registry(d: usize) -> Vec<Box<dyn Compressor>> {
        vec![
            Box::new(Identity),
            Box::new(ScaledSign),
            Box::new(TopK::count((d / 4).max(1))),
            Box::new(RandomK::count((d / 4).max(1))),
            Box::new(Qsgd::new(4)),
            Box::new(qsgd::TernGrad),
        ]
    }

    #[test]
    fn identity_is_exact() {
        let mut rng = Pcg64::seeded(0);
        let p: Vec<f32> = (0..100).map(|i| i as f32 - 50.0).collect();
        let out = Identity.compress_vec(&p, &mut rng);
        assert_eq!(out, p);
        assert_eq!(measure_delta(&Identity, &p, &mut rng), 1.0);
    }

    /// Assumption A holds for every biased compressor in the registry, and
    /// for the unbiased ones after their variance-normalizing scaling, on
    /// random gaussian vectors (property test).
    #[test]
    fn prop_contraction_biased() {
        propcheck::check(&VecF32::new(4, 300), |p| {
            let mut rng = Pcg64::seeded(1);
            let biased: Vec<Box<dyn Compressor>> = vec![
                Box::new(ScaledSign),
                Box::new(TopK::count((p.len() / 4).max(1))),
            ];
            biased.iter().all(|c| {
                let delta = measure_delta(c.as_ref(), p, &mut rng);
                delta >= -1e-5 // error never exceeds the signal
            })
        });
    }

    #[test]
    fn prop_zero_maps_to_zero() {
        let d = 64;
        let zero = vec![0.0f32; d];
        for c in registry(d) {
            let mut rng = Pcg64::seeded(2);
            let out = c.compress_vec(&zero, &mut rng);
            assert!(
                out.iter().all(|v| *v == 0.0),
                "{} moved the zero vector",
                c.name()
            );
        }
    }

    /// Positive homogeneity C(a·p) = a·C(p) for a > 0 — holds for every
    /// deterministic scheme here and in distribution for randomized ones
    /// (checked with a fixed seed, which makes them deterministic too).
    #[test]
    fn prop_positive_homogeneity() {
        propcheck::check(&VecF32::new(4, 200), |p| {
            let a = 3.5f32;
            let scaled: Vec<f32> = p.iter().map(|x| a * x).collect();
            registry(p.len()).iter().all(|c| {
                let out1 = c.compress_vec(p, &mut Pcg64::seeded(3));
                let out2 = c.compress_vec(&scaled, &mut Pcg64::seeded(3));
                out1.iter()
                    .zip(&out2)
                    .all(|(x, y)| (a * x - y).abs() <= 1e-3 * (1.0 + y.abs()))
            })
        });
    }

    #[test]
    fn measured_delta_matches_density_for_scaled_sign() {
        // Lemma 8: scaled sign is a phi(p)-approximate compressor, with
        // equality (it's exactly phi).
        let mut rng = Pcg64::seeded(5);
        for _ in 0..10 {
            let mut p = vec![0.0f32; 500];
            rng.fill_normal(&mut p, 0.0, 1.0);
            let delta = measure_delta(&ScaledSign, &p, &mut rng);
            let phi = tensor::density(&p);
            assert!((delta - phi).abs() < 1e-6, "delta={delta} phi={phi}");
        }
    }

    #[test]
    fn unbiasedness_empirical() {
        // E[C(p)] ~= p for the unbiased schemes, averaged over many draws.
        let d = 64;
        let mut rng = Pcg64::seeded(6);
        let mut p = vec![0.0f32; d];
        rng.fill_normal(&mut p, 0.0, 1.0);
        let schemes: Vec<Box<dyn Compressor>> = vec![
            Box::new(RandomK::count(16)),
            Box::new(Qsgd::new(4)),
            Box::new(qsgd::TernGrad),
        ];
        for c in schemes {
            assert!(c.unbiased());
            let trials = 4000;
            let mut mean = vec![0.0f64; d];
            for t in 0..trials {
                let mut r = Pcg64::seeded(1000 + t);
                let out = c.compress_vec(&p, &mut r);
                for (m, o) in mean.iter_mut().zip(&out) {
                    *m += *o as f64 / trials as f64;
                }
            }
            let mut err = 0.0f64;
            for (m, x) in mean.iter().zip(&p) {
                err += (m - *x as f64).powi(2);
            }
            let rel = (err / tensor::norm2_sq(&p)).sqrt();
            assert!(rel < 0.1, "{}: relative bias {rel}", c.name());
        }
    }

    #[test]
    fn build_covers_all_kinds() {
        use crate::config::CompressorKind as K;
        for k in [
            K::None,
            K::Sign,
            K::ScaledSign,
            K::TopK,
            K::RandomK,
            K::Qsgd,
            K::TernGrad,
        ] {
            let c = build(k, 256, 4, 4);
            assert!(!c.name().is_empty());
            assert!(c.wire_bits(256) > 0);
        }
    }
}
