//! Error feedback (Algorithm 2 of the paper): the residual memory that
//! turns any δ-approximate compressor into one with SGD-rate convergence.
//!
//! Each worker owns one [`ErrorFeedback`] instance; the coordinator
//! checkpoints and restores its state (`e_t`) across failures — losing the
//! residual silently degrades the method back to plain compression, so the
//! state is treated as first-class.

use super::Compressor;
use crate::tensor;
use crate::util::Pcg64;

/// Per-worker error-feedback state wrapping a compressor.
pub struct ErrorFeedback {
    compressor: Box<dyn Compressor>,
    /// The residual e_t.
    e: Vec<f32>,
    /// Scratch for p_t = gamma*g + e (kept to avoid per-step allocation).
    p: Vec<f32>,
    /// Whether feedback is enabled; disabled = plain compression (the
    /// ablation baseline, e.g. scaled SIGNSGD).
    enabled: bool,
    /// Whether to compute phi(p) each step (Fig. 2 instrumentation): the
    /// density needs an extra L1+L2 pass over p, roughly half the cost of
    /// the whole EF step on large d — off by callers that don't chart it.
    track_density: bool,
    steps: u64,
}

impl ErrorFeedback {
    pub fn new(d: usize, compressor: Box<dyn Compressor>) -> Self {
        ErrorFeedback {
            compressor,
            e: vec![0.0; d],
            p: vec![0.0; d],
            enabled: true,
            track_density: true,
            steps: 0,
        }
    }

    /// Plain-compression variant (no residual): C(gamma*g).
    pub fn disabled(d: usize, compressor: Box<dyn Compressor>) -> Self {
        let mut ef = Self::new(d, compressor);
        ef.enabled = false;
        ef
    }

    pub fn dim(&self) -> usize {
        self.e.len()
    }

    pub fn error(&self) -> &[f32] {
        &self.e
    }

    /// The error-corrected gradient p = γg + e of the most recent step
    /// (valid after at least one `step_into`). The wire encoder for the
    /// scaled sign reads this (the scale is ‖p‖₁/d, not derivable from Δ
    /// alone when Δ has zeros).
    pub fn corrected(&self) -> &[f32] {
        &self.p
    }

    pub fn error_norm(&self) -> f64 {
        tensor::norm2(&self.e)
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Toggle the per-step phi(p) computation (NaN is returned when off).
    pub fn set_track_density(&mut self, on: bool) {
        self.track_density = on;
    }

    pub fn compressor_name(&self) -> &'static str {
        self.compressor.name()
    }

    pub fn wire_bits(&self) -> u64 {
        self.compressor.wire_bits(self.e.len())
    }

    /// One step of Algorithm 2 lines 5–8:
    ///   p = gamma*g + e;  delta = C(p);  e <- p − delta.
    /// Writes delta into `delta` and returns the density φ(p) of the
    /// error-corrected gradient (the quantity Fig. 2 tracks).
    ///
    /// The enabled/disabled branch is hoisted out of the per-coordinate
    /// loop, and both the correction (`p = γg + e`) and the residual
    /// update (`e = p − δ`) run through the lane-blocked elementwise
    /// kernels in [`crate::tensor`] — fixed-width `chunks_exact` blocks
    /// the compiler turns into straight SIMD, with per-coordinate values
    /// bit-identical to the historical inline loops (elementwise, no
    /// cross-lane reduction; see docs/PERF.md).
    // detlint: hot
    pub fn step_into(&mut self, gamma: f32, g: &[f32], delta: &mut [f32], rng: &mut Pcg64) -> f64 {
        assert_eq!(g.len(), self.e.len(), "gradient dim mismatch");
        assert_eq!(delta.len(), self.e.len());
        if self.enabled {
            tensor::scaled_add_into(gamma, g, &self.e, &mut self.p);
        } else {
            tensor::scale_into(gamma, g, &mut self.p);
        }
        let phi = if self.track_density {
            tensor::density(&self.p)
        } else {
            f64::NAN
        };
        self.compressor.compress(&self.p, delta, rng);
        if self.enabled {
            tensor::sub(&self.p, delta, &mut self.e);
        }
        self.steps += 1;
        phi
    }

    /// Set the state directly (used by the coordinator restore path):
    /// step counter, residual `e`, and the corrected gradient `p` of the
    /// last completed step (so [`corrected`](Self::corrected) stays valid
    /// across a restore instead of silently reading zeros).
    pub fn set_state(&mut self, steps: u64, e: &[f32], p: &[f32]) {
        assert_eq!(e.len(), self.e.len(), "residual dim mismatch");
        assert_eq!(p.len(), self.p.len(), "corrected dim mismatch");
        self.steps = steps;
        self.e.copy_from_slice(e);
        self.p.copy_from_slice(p);
    }

    /// Serialize the full state (checkpointing). Versioned format:
    /// `b"EFS2"` magic, steps (u64 LE), residual `e` (d raw LE f32), then
    /// the corrected gradient `p` (d raw LE f32). The pre-versioned format
    /// stored only (steps, e); restoring it left `corrected()` all-zero,
    /// so v1 blobs are rejected rather than half-restored.
    pub fn save_state(&self) -> Vec<u8> {
        let d = self.e.len();
        let mut out = Vec::with_capacity(Self::STATE_MAGIC.len() + 8 + d * 8);
        out.extend_from_slice(Self::STATE_MAGIC);
        out.extend_from_slice(&self.steps.to_le_bytes());
        for v in self.e.iter().chain(&self.p) {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    /// Magic header identifying the current (v2) state format.
    pub const STATE_MAGIC: &'static [u8; 4] = b"EFS2";

    /// Restore from [`save_state`](Self::save_state) bytes. Rejects
    /// unversioned (v1) blobs and size mismatches with a clear error.
    pub fn load_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        let d = self.e.len();
        if bytes.len() < 4 || &bytes[..4] != Self::STATE_MAGIC {
            return Err(format!(
                "unversioned or foreign error-feedback state (expected {:?} header): \
                 v1 blobs lack the corrected gradient p and cannot be restored; \
                 re-create the checkpoint",
                Self::STATE_MAGIC
            ));
        }
        let body = &bytes[4..];
        if body.len() != 8 + d * 8 {
            return Err(format!(
                "state body is {} bytes after the 4-byte header, but dim {} needs {}",
                body.len(),
                d,
                8 + d * 8
            ));
        }
        self.steps = u64::from_le_bytes(body[..8].try_into().unwrap());
        for (i, v) in self.e.iter_mut().enumerate() {
            let off = 8 + i * 4;
            *v = f32::from_le_bytes(body[off..off + 4].try_into().unwrap());
        }
        for (i, v) in self.p.iter_mut().enumerate() {
            let off = 8 + (d + i) * 4;
            *v = f32::from_le_bytes(body[off..off + 4].try_into().unwrap());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{ScaledSign, TopK};
    use crate::propcheck::{self, VecF32};

    #[test]
    fn residual_identity_per_step() {
        // delta + e_{t+1} == gamma*g + e_t exactly.
        let d = 100;
        let mut ef = ErrorFeedback::new(d, Box::new(ScaledSign));
        let mut rng = Pcg64::seeded(0);
        let mut g = vec![0.0f32; d];
        let mut delta = vec![0.0f32; d];
        for _ in 0..10 {
            rng.fill_normal(&mut g, 0.0, 1.0);
            let e_before = ef.error().to_vec();
            ef.step_into(0.3, &g, &mut delta, &mut rng);
            for i in 0..d {
                let p = 0.3 * g[i] + e_before[i];
                assert!((delta[i] + ef.error()[i] - p).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn prop_trajectory_identity() {
        // x_t - e_t == -sum_i gamma*g_i (f64 check of the proof-sketch
        // identity) for random gradient streams and compressors.
        propcheck::check(&VecF32::new(8, 64), |probe| {
            let d = probe.len();
            let mut ef = ErrorFeedback::new(d, Box::new(TopK::count((d / 4).max(1))));
            let mut rng = Pcg64::seeded(42);
            let mut x = vec![0.0f64; d];
            let mut acc = vec![0.0f64; d];
            let gamma = 0.1f32;
            let mut g = vec![0.0f32; d];
            let mut delta = vec![0.0f32; d];
            for _ in 0..15 {
                rng.fill_normal(&mut g, 0.0, 1.0);
                for (a, gi) in acc.iter_mut().zip(&g) {
                    *a += gamma as f64 * *gi as f64;
                }
                ef.step_into(gamma, &g, &mut delta, &mut rng);
                for (xi, di) in x.iter_mut().zip(&delta) {
                    *xi -= *di as f64;
                }
            }
            x.iter()
                .zip(ef.error())
                .zip(&acc)
                .all(|((xi, ei), ai)| (xi - *ei as f64 + ai).abs() < 1e-3)
        });
    }

    #[test]
    fn disabled_feedback_keeps_zero_error() {
        let d = 32;
        let mut ef = ErrorFeedback::disabled(d, Box::new(ScaledSign));
        let mut rng = Pcg64::seeded(1);
        let mut g = vec![0.0f32; d];
        let mut delta = vec![0.0f32; d];
        rng.fill_normal(&mut g, 0.0, 1.0);
        ef.step_into(0.1, &g, &mut delta, &mut rng);
        assert_eq!(ef.error_norm(), 0.0);
    }

    #[test]
    fn error_norm_bounded_lemma3() {
        // Lemma 3: E||e||^2 <= 4 (1-delta) gamma^2 sigma^2 / delta^2.
        // For the scaled sign on dense gaussians, phi ~ 2/pi (delta ~ 0.64),
        // so with sigma^2 = d and gamma = 0.01 the bound is concrete.
        let d = 512;
        let gamma = 0.01f32;
        let mut ef = ErrorFeedback::new(d, Box::new(ScaledSign));
        let mut rng = Pcg64::seeded(2);
        let mut g = vec![0.0f32; d];
        let delta_lb = 0.5; // conservative lower bound on phi for gaussians
        let sigma_sq = d as f64; // E||g||^2 = d for unit gaussians
        let bound = 4.0 * (1.0 - delta_lb) * (gamma as f64).powi(2) * sigma_sq
            / (delta_lb * delta_lb);
        let mut delta = vec![0.0f32; d];
        for _ in 0..200 {
            rng.fill_normal(&mut g, 0.0, 1.0);
            ef.step_into(gamma, &g, &mut delta, &mut rng);
            assert!(
                ef.error_norm().powi(2) <= bound * 3.0,
                "||e||^2 = {} vs bound {}",
                ef.error_norm().powi(2),
                bound
            );
        }
    }

    #[test]
    fn state_roundtrip() {
        let d = 64;
        let mut ef = ErrorFeedback::new(d, Box::new(ScaledSign));
        let mut rng = Pcg64::seeded(3);
        let mut g = vec![0.0f32; d];
        let mut delta = vec![0.0f32; d];
        for _ in 0..5 {
            rng.fill_normal(&mut g, 0.0, 1.0);
            ef.step_into(0.2, &g, &mut delta, &mut rng);
        }
        let saved = ef.save_state();
        let mut restored = ErrorFeedback::new(d, Box::new(ScaledSign));
        restored.load_state(&saved).unwrap();
        assert_eq!(restored.error(), ef.error());
        // the corrected gradient survives the round trip (checkpoint bug fix)
        assert_eq!(restored.corrected(), ef.corrected());
        assert!(restored.corrected().iter().any(|v| *v != 0.0));
        assert_eq!(restored.steps(), ef.steps());
        // wrong size rejected
        assert!(restored.load_state(&saved[..saved.len() - 4]).is_err());
    }

    #[test]
    fn legacy_v1_state_rejected_with_clear_error() {
        let d = 16;
        let mut ef = ErrorFeedback::new(d, Box::new(ScaledSign));
        // v1 layout: steps u64 + d raw f32 residuals, no magic header
        let mut v1 = Vec::new();
        v1.extend_from_slice(&3u64.to_le_bytes());
        v1.extend_from_slice(&vec![0u8; d * 4]);
        let err = ef.load_state(&v1).unwrap_err();
        assert!(err.contains("corrected gradient"), "got: {err}");
    }

    #[test]
    fn set_state_restores_corrected() {
        let d = 8;
        let mut ef = ErrorFeedback::new(d, Box::new(ScaledSign));
        let e: Vec<f32> = (0..d).map(|i| i as f32 * 0.1).collect();
        let p: Vec<f32> = (0..d).map(|i| -(i as f32) * 0.2).collect();
        ef.set_state(5, &e, &p);
        assert_eq!(ef.steps(), 5);
        assert_eq!(ef.error(), e.as_slice());
        assert_eq!(ef.corrected(), p.as_slice());
    }

    #[test]
    fn density_reported_is_of_corrected_gradient() {
        let d = 128;
        let mut ef = ErrorFeedback::new(d, Box::new(ScaledSign));
        let mut rng = Pcg64::seeded(4);
        let mut g = vec![0.0f32; d];
        rng.fill_normal(&mut g, 0.0, 1.0);
        let mut delta = vec![0.0f32; d];
        // First step: e = 0, so phi(p) == phi(gamma*g) == phi(g).
        let phi = ef.step_into(0.5, &g, &mut delta, &mut rng);
        assert!((phi - crate::tensor::density(&g)).abs() < 1e-9);
    }
}
