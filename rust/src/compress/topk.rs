//! Top-k sparsification: keep the k largest-magnitude coordinates.
//!
//! A (k/d)-approximate compressor (Stich et al. 2018, Lemma A.1); with k=1
//! and error feedback this is the greedy coordinate method of the paper's
//! Remark 7. At most k coordinates are kept (threshold ties resolve by
//! index); the Pallas kernel keeps all ties — identical on generic
//! (tie-free) inputs, which the runtime integration test checks.

use super::Compressor;
use crate::util::Pcg64;

/// Keep the k largest-|v| coordinates, zero the rest.
pub struct TopK {
    k: usize,
}

impl TopK {
    pub fn count(k: usize) -> Self {
        assert!(k >= 1);
        TopK { k }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// The k-th largest magnitude of `p` (the keep-threshold), via
    /// O(d) selection.
    pub fn threshold(&self, p: &[f32]) -> f32 {
        let k = self.k.min(p.len());
        if k == 0 || p.is_empty() {
            return f32::INFINITY;
        }
        let mut mags: Vec<f32> = p.iter().map(|v| v.abs()).collect();
        let idx = k - 1;
        mags.select_nth_unstable_by(idx, |a, b| b.partial_cmp(a).unwrap());
        mags[idx]
    }
}

impl Compressor for TopK {
    fn name(&self) -> &'static str {
        "topk"
    }

    fn compress(&self, p: &[f32], out: &mut [f32], _rng: &mut Pcg64) {
        if self.k >= p.len() {
            out.copy_from_slice(p);
            return;
        }
        let thr = self.threshold(p);
        // Keep strictly-above-threshold coordinates, then fill up to k with
        // threshold ties (first-index order). Without the cap a
        // constant-magnitude vector would tie on EVERY coordinate and the
        // "sparse" message would be dense — a real wire-size hazard.
        let mut budget = self.k;
        for (o, v) in out.iter_mut().zip(p) {
            if v.abs() > thr && budget > 0 {
                *o = *v;
                budget -= 1;
            } else {
                *o = 0.0;
            }
        }
        if budget > 0 && thr > 0.0 {
            for (o, v) in out.iter_mut().zip(p) {
                if *o == 0.0 && v.abs() == thr {
                    *o = *v;
                    budget -= 1;
                    if budget == 0 {
                        break;
                    }
                }
            }
        }
    }

    fn wire_bits(&self, d: usize) -> u64 {
        // (index, value) pairs + a 32-bit count header.
        let k = self.k.min(d) as u64;
        k * (32 + 32) + 32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::measure_delta;
    use crate::propcheck::{self, Pair, UsizeRange, VecF32};
    use crate::tensor;

    #[test]
    fn keeps_largest() {
        let p = [1.0, -5.0, 3.0, 0.5];
        let mut rng = Pcg64::seeded(0);
        let out = TopK::count(2).compress_vec(&p, &mut rng);
        assert_eq!(out, vec![0.0, -5.0, 3.0, 0.0]);
    }

    #[test]
    fn k_ge_d_is_identity() {
        let p = [1.0, 2.0, 3.0];
        let mut rng = Pcg64::seeded(0);
        assert_eq!(TopK::count(10).compress_vec(&p, &mut rng), p.to_vec());
    }

    #[test]
    fn ties_capped_at_k() {
        let p = [2.0, -2.0, 2.0, 1.0];
        let mut rng = Pcg64::seeded(0);
        let out = TopK::count(2).compress_vec(&p, &mut rng);
        // threshold is 2.0; only the first two tied coords are kept
        assert_eq!(out, vec![2.0, -2.0, 0.0, 0.0]);
    }

    #[test]
    fn constant_vector_keeps_exactly_k() {
        // The wire-size hazard: a constant-magnitude vector ties everywhere.
        let p = vec![0.5f32; 1000];
        let mut rng = Pcg64::seeded(0);
        let out = TopK::count(10).compress_vec(&p, &mut rng);
        assert_eq!(out.iter().filter(|v| **v != 0.0).count(), 10);
    }

    #[test]
    fn prop_contraction_at_least_k_over_d() {
        // ||C(v) - v||^2 <= (1 - k/d) ||v||^2
        propcheck::check(
            &Pair(UsizeRange(1, 16), VecF32::new(16, 300)),
            |(k, p)| {
                let c = TopK::count(*k);
                let mut rng = Pcg64::seeded(1);
                let delta = measure_delta(&c, p, &mut rng);
                delta >= *k as f64 / p.len() as f64 - 1e-6
            },
        );
    }

    #[test]
    fn prop_kept_coordinates_unchanged() {
        propcheck::check(&VecF32::new(8, 200), |p| {
            let c = TopK::count(p.len() / 4 + 1);
            let mut rng = Pcg64::seeded(2);
            let out = c.compress_vec(p, &mut rng);
            out.iter().zip(p).all(|(o, v)| *o == 0.0 || *o == *v)
        });
    }

    #[test]
    fn zero_vector_stays_zero() {
        let p = vec![0.0f32; 32];
        let mut rng = Pcg64::seeded(3);
        let out = TopK::count(4).compress_vec(&p, &mut rng);
        assert!(out.iter().all(|v| *v == 0.0));
    }

    #[test]
    fn top1_is_greedy_coordinate() {
        let p = [0.1, -0.9, 0.3];
        let mut rng = Pcg64::seeded(4);
        let out = TopK::count(1).compress_vec(&p, &mut rng);
        assert_eq!(out, vec![0.0, -0.9, 0.0]);
        // 1/d-approximate (Remark 7)
        let delta = measure_delta(&TopK::count(1), &p, &mut rng);
        assert!(delta >= 1.0 / 3.0 - 1e-7);
    }

    #[test]
    fn residual_energy_decreases_with_k() {
        let mut rng = Pcg64::seeded(5);
        let mut p = vec![0.0f32; 256];
        rng.fill_normal(&mut p, 0.0, 1.0);
        let mut prev = f64::NEG_INFINITY;
        for k in [1usize, 4, 16, 64, 256] {
            let d = measure_delta(&TopK::count(k), &p, &mut rng);
            assert!(d >= prev - 1e-9, "k={k}");
            prev = d;
        }
        assert!((prev - 1.0).abs() < 1e-9); // k=d exact
        let _ = tensor::norm2_sq(&p);
    }
}
