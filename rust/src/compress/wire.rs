//! Wire codecs: the exact bit-level encodings the workers put on the
//! simulated network. This is where the paper's communication claim is
//! grounded — the ~64× compression versus 32-bit floats (sign bit per
//! coordinate in each direction + one 32-bit scale per tensor) is measured
//! on these encoders by `repro exp comm`, not asserted.

use std::io::Write as _;

/// Bit-level writer (LSB-first within each byte).
#[derive(Default)]
pub struct BitWriter {
    bytes: Vec<u8>,
    /// Number of valid bits in the buffer.
    bits: u64,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push_bit(&mut self, bit: bool) {
        let idx = (self.bits / 8) as usize;
        if idx == self.bytes.len() {
            self.bytes.push(0);
        }
        if bit {
            self.bytes[idx] |= 1 << (self.bits % 8);
        }
        self.bits += 1;
    }

    /// Push the low `n` bits of `value`, LSB first.
    pub fn push_bits(&mut self, value: u32, n: u32) {
        debug_assert!(n <= 32);
        self.push_bits64(value as u64, n);
    }

    /// Push the low `n` bits of a 64-bit `value`, LSB first.
    /// Fast path: when the cursor is byte-aligned and n is a whole number
    /// of bytes, append bytes directly (the codecs below keep their fields
    /// byte-aligned so this is the common case).
    pub fn push_bits64(&mut self, value: u64, n: u32) {
        debug_assert!(n <= 64);
        if self.bits % 8 == 0 && n % 8 == 0 {
            for i in 0..(n / 8) {
                self.bytes.push((value >> (8 * i)) as u8);
            }
            self.bits += n as u64;
            return;
        }
        for i in 0..n {
            self.push_bit((value >> i) & 1 == 1);
        }
    }

    /// Append a whole byte (cursor must be byte-aligned).
    #[inline]
    pub fn push_byte_aligned(&mut self, byte: u8) {
        debug_assert_eq!(self.bits % 8, 0);
        self.bytes.push(byte);
        self.bits += 8;
    }

    pub fn push_f32(&mut self, v: f32) {
        self.push_bits(v.to_bits(), 32);
    }

    pub fn push_u32(&mut self, v: u32) {
        self.push_bits(v, 32);
    }

    pub fn bit_len(&self) -> u64 {
        self.bits
    }

    pub fn into_bytes(self) -> (Vec<u8>, u64) {
        (self.bytes, self.bits)
    }
}

/// Bit-level reader matching [`BitWriter`].
pub struct BitReader<'a> {
    bytes: &'a [u8],
    pos: u64,
}

impl<'a> BitReader<'a> {
    pub fn new(bytes: &'a [u8]) -> Self {
        BitReader { bytes, pos: 0 }
    }

    pub fn read_bit(&mut self) -> Option<bool> {
        let idx = (self.pos / 8) as usize;
        if idx >= self.bytes.len() {
            return None;
        }
        let bit = (self.bytes[idx] >> (self.pos % 8)) & 1 == 1;
        self.pos += 1;
        Some(bit)
    }

    pub fn read_bits(&mut self, n: u32) -> Option<u32> {
        debug_assert!(n <= 32);
        self.read_bits64(n).map(|v| v as u32)
    }

    /// Read `n` bits (LSB first) into a 64-bit word — the counterpart of
    /// [`BitWriter::push_bits64`].
    /// Fast path: byte-aligned whole-byte reads (the codecs keep their
    /// multi-bit fields byte-aligned).
    pub fn read_bits64(&mut self, n: u32) -> Option<u64> {
        debug_assert!(n <= 64);
        if self.pos % 8 == 0 && n % 8 == 0 {
            let start = (self.pos / 8) as usize;
            let nbytes = (n / 8) as usize;
            if start + nbytes > self.bytes.len() {
                return None;
            }
            let mut v = 0u64;
            for (i, b) in self.bytes[start..start + nbytes].iter().enumerate() {
                v |= (*b as u64) << (8 * i);
            }
            self.pos += n as u64;
            return Some(v);
        }
        let mut v = 0u64;
        for i in 0..n {
            if self.read_bit()? {
                v |= 1 << i;
            }
        }
        Some(v)
    }

    pub fn read_f32(&mut self) -> Option<f32> {
        self.read_bits(32).map(f32::from_bits)
    }

    pub fn read_u32(&mut self) -> Option<u32> {
        self.read_bits(32)
    }
}

/// An encoded gradient payload with exact size accounting.
#[derive(Clone, Debug)]
pub struct Encoded {
    pub bytes: Vec<u8>,
    /// Exact payload size in bits (may be less than bytes.len()*8).
    pub bits: u64,
    pub format: Format,
    /// Original vector length.
    pub d: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Format {
    DenseF32,
    SignScaled,
    SparseIdxVal,
    Ternary,
}

#[derive(Debug)]
pub enum WireError {
    Truncated,
    Format(Format, Format),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated => write!(f, "payload truncated"),
            WireError::Format(want, got) => {
                write!(f, "format mismatch: expected {want:?}, got {got:?}")
            }
        }
    }
}

impl std::error::Error for WireError {}

// ------------------------------------------------------------- dense f32

/// Baseline encoding: 32 bits per coordinate.
pub fn encode_dense(v: &[f32]) -> Encoded {
    let mut bytes = Vec::with_capacity(v.len() * 4);
    for x in v {
        bytes.write_all(&x.to_le_bytes()).unwrap();
    }
    Encoded {
        bits: 32 * v.len() as u64,
        bytes,
        format: Format::DenseF32,
        d: v.len(),
    }
}

pub fn decode_dense(e: &Encoded) -> Result<Vec<f32>, WireError> {
    if e.format != Format::DenseF32 {
        return Err(WireError::Format(Format::DenseF32, e.format));
    }
    if e.bytes.len() < e.d * 4 {
        return Err(WireError::Truncated);
    }
    Ok((0..e.d)
        .map(|i| f32::from_le_bytes(e.bytes[i * 4..i * 4 + 4].try_into().unwrap()))
        .collect())
}

// --------------------------------------------------------- scaled sign

/// The paper's wire format: one 32-bit scale (‖p‖₁/d) + d packed sign bits.
/// Exact zeros (measure-zero after error correction) encode as +.
/// `d + 32` bits total — the `Σ_i (d_i + 32)` accounting of §6.1.
pub fn encode_scaled_sign(p: &[f32]) -> Encoded {
    let scale = super::ScaledSign::scale(p);
    // Word-packed sign encoding (hot path): the scale occupies exactly 4
    // bytes, so sign bits start byte-aligned; 64 coordinates pack into one
    // u64 at a time, branch-free, with a byte-wise tail for d % 64.
    let d = p.len();
    let mut bytes = Vec::with_capacity(4 + d.div_ceil(8));
    bytes.extend_from_slice(&scale.to_bits().to_le_bytes());
    let mut chunks = p.chunks_exact(64);
    for c in &mut chunks {
        let mut word = 0u64;
        for (j, x) in c.iter().enumerate() {
            // bit = 1 for x >= 0 (and for -0.0, matching `*x >= 0.0`)
            word |= u64::from(*x >= 0.0) << j;
        }
        bytes.extend_from_slice(&word.to_le_bytes());
    }
    let rem = chunks.remainder();
    for sub in rem.chunks(8) {
        let mut byte = 0u8;
        for (j, x) in sub.iter().enumerate() {
            byte |= u8::from(*x >= 0.0) << j;
        }
        bytes.push(byte);
    }
    Encoded {
        bytes,
        bits: 32 + d as u64,
        format: Format::SignScaled,
        d,
    }
}

/// Parse header + validate size for the scaled-sign format.
fn sign_payload(e: &Encoded) -> Result<(f32, &[u8]), WireError> {
    if e.format != Format::SignScaled {
        return Err(WireError::Format(Format::SignScaled, e.format));
    }
    if e.bytes.len() < 4 + e.d.div_ceil(8) {
        return Err(WireError::Truncated);
    }
    let scale = f32::from_bits(u32::from_le_bytes(e.bytes[..4].try_into().unwrap()));
    Ok((scale, &e.bytes[4..]))
}

/// Decode to the dense update vector `scale * sign` (word-wise unpack into
/// a preallocated buffer; branch-free lane fill, 64 lanes per load).
pub fn decode_scaled_sign(e: &Encoded) -> Result<Vec<f32>, WireError> {
    let (scale, body) = sign_payload(e)?;
    let mut out = vec![0.0f32; e.d];
    let mut chunks = out.chunks_exact_mut(64);
    let mut bi = 0usize;
    for c in &mut chunks {
        let word = u64::from_le_bytes(body[bi..bi + 8].try_into().unwrap());
        bi += 8;
        for (j, o) in c.iter_mut().enumerate() {
            *o = if word >> j & 1 == 1 { scale } else { -scale };
        }
    }
    for (sub, byte) in chunks.into_remainder().chunks_mut(8).zip(&body[bi..]) {
        for (j, o) in sub.iter_mut().enumerate() {
            *o = if byte >> j & 1 == 1 { scale } else { -scale };
        }
    }
    Ok(out)
}

/// Decode straight into a sum accumulator (the parameter-server hot path:
/// no intermediate dense vector).
pub fn decode_scaled_sign_add(e: &Encoded, acc: &mut [f32]) -> Result<(), WireError> {
    let (scale, body) = sign_payload(e)?;
    if acc.len() != e.d {
        return Err(WireError::Truncated);
    }
    let mut chunks = acc.chunks_exact_mut(64);
    let mut bi = 0usize;
    for c in &mut chunks {
        let word = u64::from_le_bytes(body[bi..bi + 8].try_into().unwrap());
        bi += 8;
        for (j, a) in c.iter_mut().enumerate() {
            *a += if word >> j & 1 == 1 { scale } else { -scale };
        }
    }
    for (sub, byte) in chunks.into_remainder().chunks_mut(8).zip(&body[bi..]) {
        for (j, a) in sub.iter_mut().enumerate() {
            *a += if byte >> j & 1 == 1 { scale } else { -scale };
        }
    }
    Ok(())
}

// -------------------------------------------------------------- sparse

/// Sparse (top-k / random-k) encoding: u32 count + (u32 index, f32 value)
/// per non-zero.
pub fn encode_sparse(v: &[f32]) -> Encoded {
    let mut w = BitWriter::new();
    let nz: Vec<(u32, f32)> = v
        .iter()
        .enumerate()
        .filter(|(_, x)| **x != 0.0)
        .map(|(i, x)| (i as u32, *x))
        .collect();
    w.push_u32(nz.len() as u32);
    for (i, x) in &nz {
        w.push_u32(*i);
        w.push_f32(*x);
    }
    let (bytes, bits) = w.into_bytes();
    Encoded {
        bytes,
        bits,
        format: Format::SparseIdxVal,
        d: v.len(),
    }
}

pub fn decode_sparse(e: &Encoded) -> Result<Vec<f32>, WireError> {
    if e.format != Format::SparseIdxVal {
        return Err(WireError::Format(Format::SparseIdxVal, e.format));
    }
    let mut r = BitReader::new(&e.bytes);
    let count = r.read_u32().ok_or(WireError::Truncated)? as usize;
    let mut out = vec![0.0f32; e.d];
    for _ in 0..count {
        let i = r.read_u32().ok_or(WireError::Truncated)? as usize;
        let x = r.read_f32().ok_or(WireError::Truncated)?;
        if i >= e.d {
            return Err(WireError::Truncated);
        }
        out[i] = x;
    }
    Ok(out)
}

// ------------------------------------------------------------- ternary

/// TernGrad encoding: one 32-bit scale + 2 bits/coordinate
/// (00 = 0, 01 = +m, 10 = −m).
pub fn encode_ternary(v: &[f32]) -> Encoded {
    let m = crate::tensor::norm_inf(v) as f32;
    let mut w = BitWriter::new();
    w.push_f32(m);
    for x in v {
        let code: u32 = if *x == 0.0 {
            0
        } else if *x > 0.0 {
            1
        } else {
            2
        };
        w.push_bits(code, 2);
    }
    let (bytes, bits) = w.into_bytes();
    Encoded {
        bytes,
        bits,
        format: Format::Ternary,
        d: v.len(),
    }
}

pub fn decode_ternary(e: &Encoded) -> Result<Vec<f32>, WireError> {
    if e.format != Format::Ternary {
        return Err(WireError::Format(Format::Ternary, e.format));
    }
    let mut r = BitReader::new(&e.bytes);
    let m = r.read_f32().ok_or(WireError::Truncated)?;
    let mut out = Vec::with_capacity(e.d);
    for _ in 0..e.d {
        let code = r.read_bits(2).ok_or(WireError::Truncated)?;
        out.push(match code {
            0 => 0.0,
            1 => m,
            _ => -m,
        });
    }
    Ok(out)
}

/// Decode any payload format to a dense vector.
pub fn decode_any(e: &Encoded) -> Result<Vec<f32>, WireError> {
    match e.format {
        Format::DenseF32 => decode_dense(e),
        Format::SignScaled => decode_scaled_sign(e),
        Format::SparseIdxVal => decode_sparse(e),
        Format::Ternary => decode_ternary(e),
    }
}

/// Compression ratio of an encoding vs dense f32.
pub fn compression_ratio(e: &Encoded) -> f64 {
    (32.0 * e.d as f64) / e.bits as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor, ScaledSign, TernGrad, TopK};
    use crate::propcheck::{self, VecF32};
    use crate::util::Pcg64;

    #[test]
    fn bitio_roundtrip() {
        let mut w = BitWriter::new();
        w.push_bits(0b1011, 4);
        w.push_f32(-1.5);
        w.push_u32(12345);
        w.push_bit(true);
        let (bytes, bits) = w.into_bytes();
        assert_eq!(bits, 4 + 32 + 32 + 1);
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(4), Some(0b1011));
        assert_eq!(r.read_f32(), Some(-1.5));
        assert_eq!(r.read_u32(), Some(12345));
        assert_eq!(r.read_bit(), Some(true));
    }

    #[test]
    fn prop_dense_roundtrip() {
        propcheck::check(&VecF32::new(0, 200), |v| {
            decode_dense(&encode_dense(v)).unwrap() == *v
        });
    }

    #[test]
    fn prop_scaled_sign_wire_matches_compressor() {
        // decode(encode(p)) equals ScaledSign::compress(p) on zero-free
        // vectors (gaussian => zero-free a.s.).
        propcheck::check(&VecF32::new(1, 300), |p| {
            if p.iter().any(|x| *x == 0.0) {
                return true;
            }
            let e = encode_scaled_sign(p);
            assert_eq!(e.bits, p.len() as u64 + 32);
            let dec = decode_scaled_sign(&e).unwrap();
            let mut rng = Pcg64::seeded(0);
            let direct = ScaledSign.compress_vec(p, &mut rng);
            dec.iter().zip(&direct).all(|(a, b)| a == b)
        });
    }

    #[test]
    fn scaled_sign_zero_encodes_positive() {
        let p = [0.0f32, -1.0, 1.0];
        let dec = decode_scaled_sign(&encode_scaled_sign(&p)).unwrap();
        let scale = 2.0 / 3.0;
        assert!((dec[0] - scale).abs() < 1e-6); // documented zero behaviour
        assert!((dec[1] + scale).abs() < 1e-6);
        assert!((dec[2] - scale).abs() < 1e-6);
    }

    #[test]
    fn decode_add_accumulates() {
        let p = [1.0f32, -2.0, 3.0, -4.0];
        let e = encode_scaled_sign(&p);
        let mut acc = vec![10.0f32; 4];
        decode_scaled_sign_add(&e, &mut acc).unwrap();
        let dec = decode_scaled_sign(&e).unwrap();
        for i in 0..4 {
            assert!((acc[i] - (10.0 + dec[i])).abs() < 1e-6);
        }
    }

    #[test]
    fn prop_sparse_roundtrip_topk() {
        propcheck::check(&VecF32::new(4, 300), |p| {
            let mut rng = Pcg64::seeded(1);
            let delta = TopK::count((p.len() / 4).max(1)).compress_vec(p, &mut rng);
            let e = encode_sparse(&delta);
            decode_sparse(&e).unwrap() == delta
        });
    }

    #[test]
    fn prop_ternary_roundtrip() {
        propcheck::check(&VecF32::new(1, 200), |p| {
            let mut rng = Pcg64::seeded(2);
            let t = TernGrad.compress_vec(p, &mut rng);
            let e = encode_ternary(&t);
            assert_eq!(e.bits, 2 * p.len() as u64 + 32);
            let dec = decode_ternary(&e).unwrap();
            dec.iter().zip(&t).all(|(a, b)| (a - b).abs() < 1e-6)
        });
    }

    #[test]
    fn compression_ratios() {
        let d = 100_000;
        let mut rng = Pcg64::seeded(3);
        let mut p = vec![0.0f32; d];
        rng.fill_normal(&mut p, 0.0, 1.0);
        let sign = encode_scaled_sign(&p);
        let ratio = compression_ratio(&sign);
        // d*32 / (d + 32) -> just under 32x for a single tensor
        assert!(ratio > 31.9 && ratio < 32.0, "ratio={ratio}");
        let dense = encode_dense(&p);
        assert!((compression_ratio(&dense) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn format_mismatch_rejected() {
        let p = [1.0f32, 2.0];
        let e = encode_dense(&p);
        assert!(matches!(
            decode_scaled_sign(&e),
            Err(WireError::Format(..))
        ));
    }

    #[test]
    fn truncated_rejected() {
        let p = [1.0f32; 64];
        let mut e = encode_scaled_sign(&p);
        e.bytes.truncate(4);
        assert!(matches!(decode_scaled_sign(&e), Err(WireError::Truncated)));
    }

    /// Mixed push_bit / push_bits / push_bits64 sequences at non-byte-
    /// aligned cursors round-trip exactly (regression guard for the
    /// aligned fast paths taking over mid-stream).
    #[test]
    fn prop_bitio_roundtrip_unaligned_cursors() {
        use crate::propcheck::UsizeRange;
        propcheck::check_with(
            &propcheck::Config {
                cases: 200,
                ..Default::default()
            },
            &UsizeRange(1, 10_000),
            |&seed| {
                let mut rng = Pcg64::seeded(seed as u64);
                // Script a random mix of writes, remember (value, width).
                let mut script: Vec<(u64, u32)> = Vec::new();
                let mut w = BitWriter::new();
                for _ in 0..40 {
                    match rng.below(3) {
                        0 => {
                            let bit = rng.next_u32() & 1;
                            w.push_bit(bit == 1);
                            script.push((bit as u64, 1));
                        }
                        1 => {
                            let n = 1 + rng.below(32) as u32;
                            let v = rng.next_u32() & (u32::MAX >> (32 - n));
                            w.push_bits(v, n);
                            script.push((v as u64, n));
                        }
                        _ => {
                            let n = 1 + rng.below(64) as u32;
                            let v = rng.next_u64() & (u64::MAX >> (64 - n));
                            w.push_bits64(v, n);
                            script.push((v, n));
                        }
                    }
                }
                let expect_bits: u64 = script.iter().map(|(_, n)| *n as u64).sum();
                let (bytes, bits) = w.into_bytes();
                if bits != expect_bits {
                    return false;
                }
                let mut r = BitReader::new(&bytes);
                script.iter().all(|&(v, n)| match n {
                    1 => r.read_bit() == Some(v == 1),
                    n if n <= 32 && v <= u32::MAX as u64 => {
                        // read through the 64-bit path half the time to
                        // cross-check both readers
                        if n % 2 == 0 {
                            r.read_bits(n) == Some(v as u32)
                        } else {
                            r.read_bits64(n) == Some(v)
                        }
                    }
                    _ => r.read_bits64(n) == Some(v),
                })
            },
        );
    }

    /// The word-packed sign codec round-trips at every alignment class:
    /// d spanning multiples of 64, multiples of 8, and ragged tails.
    #[test]
    fn packed_sign_roundtrip_all_alignments() {
        let mut rng = Pcg64::seeded(7);
        for d in [1, 2, 7, 8, 9, 63, 64, 65, 127, 128, 129, 200, 1000] {
            let mut p = vec![0.0f32; d];
            rng.fill_normal(&mut p, 0.0, 1.0);
            let e = encode_scaled_sign(&p);
            assert_eq!(e.bits, d as u64 + 32);
            assert_eq!(e.bytes.len(), 4 + d.div_ceil(8));
            let scale = ScaledSign::scale(&p);
            let dec = decode_scaled_sign(&e).unwrap();
            let mut acc = vec![1.5f32; d];
            decode_scaled_sign_add(&e, &mut acc).unwrap();
            for i in 0..d {
                let want = if p[i] >= 0.0 { scale } else { -scale };
                assert_eq!(dec[i], want, "d={d} i={i}");
                assert!((acc[i] - (1.5 + want)).abs() < 1e-6, "d={d} i={i}");
            }
        }
    }
}
