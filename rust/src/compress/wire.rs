//! Wire codecs: the exact bit-level encodings the workers put on the
//! simulated network. This is where the paper's communication claim is
//! grounded — the ~64× compression versus 32-bit floats (sign bit per
//! coordinate in each direction + one 32-bit scale per tensor) is measured
//! on these encoders by `repro exp comm`, not asserted.

use std::io::Write as _;

/// Bit-level writer (LSB-first within each byte).
#[derive(Default)]
pub struct BitWriter {
    bytes: Vec<u8>,
    /// Number of valid bits in the buffer.
    bits: u64,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push_bit(&mut self, bit: bool) {
        let idx = (self.bits / 8) as usize;
        if idx == self.bytes.len() {
            self.bytes.push(0);
        }
        if bit {
            self.bytes[idx] |= 1 << (self.bits % 8);
        }
        self.bits += 1;
    }

    /// Push the low `n` bits of `value`, LSB first.
    pub fn push_bits(&mut self, value: u32, n: u32) {
        debug_assert!(n <= 32);
        self.push_bits64(value as u64, n);
    }

    /// Push the low `n` bits of a 64-bit `value`, LSB first.
    /// Fast path: when the cursor is byte-aligned and n is a whole number
    /// of bytes, append bytes directly (the codecs below keep their fields
    /// byte-aligned so this is the common case).
    pub fn push_bits64(&mut self, value: u64, n: u32) {
        debug_assert!(n <= 64);
        if self.bits % 8 == 0 && n % 8 == 0 {
            for i in 0..(n / 8) {
                self.bytes.push((value >> (8 * i)) as u8);
            }
            self.bits += n as u64;
            return;
        }
        for i in 0..n {
            self.push_bit((value >> i) & 1 == 1);
        }
    }

    /// Append a whole byte (cursor must be byte-aligned).
    #[inline]
    pub fn push_byte_aligned(&mut self, byte: u8) {
        debug_assert_eq!(self.bits % 8, 0);
        self.bytes.push(byte);
        self.bits += 8;
    }

    /// Push a positive integer in Elias-gamma code: `⌊log₂ x⌋` zeros, then
    /// the binary of `x` MSB-first — `2⌊log₂ x⌋ + 1` bits total. Small
    /// integers are cheap (1 → 1 bit, 2..3 → 3 bits, 4..7 → 5 bits), which
    /// is what makes the QSGD level stream compact: most levels are 0,
    /// coded as γ(1).
    pub fn push_elias_gamma(&mut self, x: u64) {
        debug_assert!(x >= 1, "Elias gamma codes integers >= 1");
        let nbits = 64 - x.leading_zeros();
        for _ in 0..nbits - 1 {
            self.push_bit(false);
        }
        for i in (0..nbits).rev() {
            self.push_bit((x >> i) & 1 == 1);
        }
    }

    pub fn push_f32(&mut self, v: f32) {
        self.push_bits(v.to_bits(), 32);
    }

    pub fn push_u32(&mut self, v: u32) {
        self.push_bits(v, 32);
    }

    pub fn bit_len(&self) -> u64 {
        self.bits
    }

    pub fn into_bytes(self) -> (Vec<u8>, u64) {
        (self.bytes, self.bits)
    }
}

/// Bit-level reader matching [`BitWriter`].
pub struct BitReader<'a> {
    bytes: &'a [u8],
    pos: u64,
}

impl<'a> BitReader<'a> {
    pub fn new(bytes: &'a [u8]) -> Self {
        BitReader { bytes, pos: 0 }
    }

    pub fn read_bit(&mut self) -> Option<bool> {
        let idx = (self.pos / 8) as usize;
        if idx >= self.bytes.len() {
            return None;
        }
        let bit = (self.bytes[idx] >> (self.pos % 8)) & 1 == 1;
        self.pos += 1;
        Some(bit)
    }

    pub fn read_bits(&mut self, n: u32) -> Option<u32> {
        debug_assert!(n <= 32);
        self.read_bits64(n).map(|v| v as u32)
    }

    /// Read `n` bits (LSB first) into a 64-bit word — the counterpart of
    /// [`BitWriter::push_bits64`].
    /// Fast path: byte-aligned whole-byte reads (the codecs keep their
    /// multi-bit fields byte-aligned).
    pub fn read_bits64(&mut self, n: u32) -> Option<u64> {
        debug_assert!(n <= 64);
        if self.pos % 8 == 0 && n % 8 == 0 {
            let start = (self.pos / 8) as usize;
            let nbytes = (n / 8) as usize;
            if start + nbytes > self.bytes.len() {
                return None;
            }
            let mut v = 0u64;
            for (i, b) in self.bytes[start..start + nbytes].iter().enumerate() {
                v |= (*b as u64) << (8 * i);
            }
            self.pos += n as u64;
            return Some(v);
        }
        let mut v = 0u64;
        for i in 0..n {
            if self.read_bit()? {
                v |= 1 << i;
            }
        }
        Some(v)
    }

    /// Read one Elias-gamma-coded positive integer — the counterpart of
    /// [`BitWriter::push_elias_gamma`].
    pub fn read_elias_gamma(&mut self) -> Option<u64> {
        let mut zeros = 0u32;
        while !self.read_bit()? {
            zeros += 1;
            if zeros > 63 {
                return None; // not a valid gamma code for a u64
            }
        }
        let mut x = 1u64;
        for _ in 0..zeros {
            x = (x << 1) | u64::from(self.read_bit()?);
        }
        Some(x)
    }

    pub fn read_f32(&mut self) -> Option<f32> {
        self.read_bits(32).map(f32::from_bits)
    }

    pub fn read_u32(&mut self) -> Option<u32> {
        self.read_bits(32)
    }
}

/// Shard routing header carried by sharded wire frames (see docs/WIRE.md):
/// a 16-bit shard id plus the 32-bit start coordinate of the slice in the
/// full model vector. The slice length is the frame's own `d`, so the
/// coordinate range is `start .. start + d`. Unsharded frames carry no tag
/// and cost no extra bits — the single-leader wire format is unchanged.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardTag {
    pub shard: u16,
    pub start: u32,
}

/// On-wire cost of a [`ShardTag`]: 16-bit shard id + 32-bit start.
pub const SHARD_TAG_BITS: u64 = 48;

/// An encoded gradient payload with exact size accounting.
#[derive(Clone, Debug)]
pub struct Encoded {
    pub bytes: Vec<u8>,
    /// Exact payload size in bits (may be less than bytes.len()*8; includes
    /// [`SHARD_TAG_BITS`] when a shard tag is attached).
    pub bits: u64,
    pub format: Format,
    /// Original vector length.
    pub d: usize,
    /// Shard routing header for sharded parameter-server frames
    /// (`None` = unsharded; the bytes/bits above are then exactly the
    /// historical single-leader frame).
    pub shard: Option<ShardTag>,
}

impl Encoded {
    /// Attach the shard routing header (id + start coordinate), charging
    /// its [`SHARD_TAG_BITS`] on the frame's exact size.
    pub fn with_shard(mut self, shard: u16, start: u32) -> Self {
        debug_assert!(self.shard.is_none(), "frame already shard-tagged");
        self.shard = Some(ShardTag { shard, start });
        self.bits += SHARD_TAG_BITS;
        self
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Format {
    DenseF32,
    SignScaled,
    SparseIdxVal,
    Ternary,
    /// QSGD: f32 ℓ₂-norm + u8 level count + Elias-gamma level stream.
    Qsgd,
}

#[derive(Debug)]
pub enum WireError {
    Truncated,
    Format(Format, Format),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated => write!(f, "payload truncated"),
            WireError::Format(want, got) => {
                write!(f, "format mismatch: expected {want:?}, got {got:?}")
            }
        }
    }
}

impl std::error::Error for WireError {}

// ------------------------------------------------------------- dense f32

/// Baseline encoding: 32 bits per coordinate.
pub fn encode_dense(v: &[f32]) -> Encoded {
    let mut bytes = Vec::with_capacity(v.len() * 4);
    for x in v {
        bytes.write_all(&x.to_le_bytes()).unwrap();
    }
    Encoded {
        bits: 32 * v.len() as u64,
        bytes,
        format: Format::DenseF32,
        d: v.len(),
        shard: None,
    }
}

pub fn decode_dense(e: &Encoded) -> Result<Vec<f32>, WireError> {
    if e.format != Format::DenseF32 {
        return Err(WireError::Format(Format::DenseF32, e.format));
    }
    if e.bytes.len() < e.d * 4 {
        return Err(WireError::Truncated);
    }
    Ok((0..e.d)
        .map(|i| f32::from_le_bytes(e.bytes[i * 4..i * 4 + 4].try_into().unwrap()))
        .collect())
}

/// Decode dense straight into a sum accumulator (fused leader hot path).
pub fn decode_dense_add(e: &Encoded, acc: &mut [f32]) -> Result<(), WireError> {
    if e.format != Format::DenseF32 {
        return Err(WireError::Format(Format::DenseF32, e.format));
    }
    if e.bytes.len() < e.d * 4 || acc.len() != e.d {
        return Err(WireError::Truncated);
    }
    for (a, chunk) in acc.iter_mut().zip(e.bytes.chunks_exact(4)) {
        *a += f32::from_le_bytes(chunk.try_into().unwrap());
    }
    Ok(())
}

// --------------------------------------------------------- scaled sign

/// The paper's wire format: one 32-bit scale (‖p‖₁/d) + d packed sign bits.
/// Exact zeros (measure-zero after error correction) encode as +.
/// `d + 32` bits total — the `Σ_i (d_i + 32)` accounting of §6.1.
pub fn encode_scaled_sign(p: &[f32]) -> Encoded {
    let scale = super::ScaledSign::scale(p);
    // Word-packed sign encoding (hot path): the scale occupies exactly 4
    // bytes, so sign bits start byte-aligned; 64 coordinates pack into one
    // u64 at a time, branch-free, with a byte-wise tail for d % 64.
    let d = p.len();
    let mut bytes = Vec::with_capacity(4 + d.div_ceil(8));
    bytes.extend_from_slice(&scale.to_bits().to_le_bytes());
    let mut chunks = p.chunks_exact(64);
    for c in &mut chunks {
        let mut word = 0u64;
        for (j, x) in c.iter().enumerate() {
            // bit = 1 for x >= 0 (and for -0.0, matching `*x >= 0.0`)
            word |= u64::from(*x >= 0.0) << j;
        }
        bytes.extend_from_slice(&word.to_le_bytes());
    }
    let rem = chunks.remainder();
    for sub in rem.chunks(8) {
        let mut byte = 0u8;
        for (j, x) in sub.iter().enumerate() {
            byte |= u8::from(*x >= 0.0) << j;
        }
        bytes.push(byte);
    }
    Encoded {
        bytes,
        bits: 32 + d as u64,
        format: Format::SignScaled,
        d,
        shard: None,
    }
}

/// Parse header + validate size for the scaled-sign format.
fn sign_payload(e: &Encoded) -> Result<(f32, &[u8]), WireError> {
    if e.format != Format::SignScaled {
        return Err(WireError::Format(Format::SignScaled, e.format));
    }
    if e.bytes.len() < 4 + e.d.div_ceil(8) {
        return Err(WireError::Truncated);
    }
    let scale = f32::from_bits(u32::from_le_bytes(e.bytes[..4].try_into().unwrap()));
    Ok((scale, &e.bytes[4..]))
}

/// Decode to the dense update vector `scale * sign` (word-wise unpack into
/// a preallocated buffer; branch-free lane fill, 64 lanes per load).
pub fn decode_scaled_sign(e: &Encoded) -> Result<Vec<f32>, WireError> {
    let (scale, body) = sign_payload(e)?;
    let mut out = vec![0.0f32; e.d];
    let mut chunks = out.chunks_exact_mut(64);
    let mut bi = 0usize;
    for c in &mut chunks {
        let word = u64::from_le_bytes(body[bi..bi + 8].try_into().unwrap());
        bi += 8;
        for (j, o) in c.iter_mut().enumerate() {
            *o = if word >> j & 1 == 1 { scale } else { -scale };
        }
    }
    for (sub, byte) in chunks.into_remainder().chunks_mut(8).zip(&body[bi..]) {
        for (j, o) in sub.iter_mut().enumerate() {
            *o = if byte >> j & 1 == 1 { scale } else { -scale };
        }
    }
    Ok(out)
}

/// Decode straight into a sum accumulator (the parameter-server hot path:
/// no intermediate dense vector).
pub fn decode_scaled_sign_add(e: &Encoded, acc: &mut [f32]) -> Result<(), WireError> {
    let (scale, body) = sign_payload(e)?;
    if acc.len() != e.d {
        return Err(WireError::Truncated);
    }
    let mut chunks = acc.chunks_exact_mut(64);
    let mut bi = 0usize;
    for c in &mut chunks {
        let word = u64::from_le_bytes(body[bi..bi + 8].try_into().unwrap());
        bi += 8;
        for (j, a) in c.iter_mut().enumerate() {
            *a += if word >> j & 1 == 1 { scale } else { -scale };
        }
    }
    for (sub, byte) in chunks.into_remainder().chunks_mut(8).zip(&body[bi..]) {
        for (j, a) in sub.iter_mut().enumerate() {
            *a += if byte >> j & 1 == 1 { scale } else { -scale };
        }
    }
    Ok(())
}

// -------------------------------------------------------------- sparse

/// Sparse (top-k / random-k) encoding: u32 count + (u32 index, f32 value)
/// per non-zero.
pub fn encode_sparse(v: &[f32]) -> Encoded {
    let mut w = BitWriter::new();
    let nz: Vec<(u32, f32)> = v
        .iter()
        .enumerate()
        .filter(|(_, x)| **x != 0.0)
        .map(|(i, x)| (i as u32, *x))
        .collect();
    w.push_u32(nz.len() as u32);
    for (i, x) in &nz {
        w.push_u32(*i);
        w.push_f32(*x);
    }
    let (bytes, bits) = w.into_bytes();
    Encoded {
        bytes,
        bits,
        format: Format::SparseIdxVal,
        d: v.len(),
        shard: None,
    }
}

pub fn decode_sparse(e: &Encoded) -> Result<Vec<f32>, WireError> {
    if e.format != Format::SparseIdxVal {
        return Err(WireError::Format(Format::SparseIdxVal, e.format));
    }
    let mut r = BitReader::new(&e.bytes);
    let count = r.read_u32().ok_or(WireError::Truncated)? as usize;
    let mut out = vec![0.0f32; e.d];
    for _ in 0..count {
        let i = r.read_u32().ok_or(WireError::Truncated)? as usize;
        let x = r.read_f32().ok_or(WireError::Truncated)?;
        if i >= e.d {
            return Err(WireError::Truncated);
        }
        out[i] = x;
    }
    Ok(out)
}

/// Decode sparse straight into a sum accumulator: only the stored non-zeros
/// are touched, so a top-k frame costs O(k), not O(d), to aggregate.
pub fn decode_sparse_add(e: &Encoded, acc: &mut [f32]) -> Result<(), WireError> {
    if e.format != Format::SparseIdxVal {
        return Err(WireError::Format(Format::SparseIdxVal, e.format));
    }
    if acc.len() != e.d {
        return Err(WireError::Truncated);
    }
    let mut r = BitReader::new(&e.bytes);
    let count = r.read_u32().ok_or(WireError::Truncated)? as usize;
    for _ in 0..count {
        let i = r.read_u32().ok_or(WireError::Truncated)? as usize;
        let x = r.read_f32().ok_or(WireError::Truncated)?;
        if i >= e.d {
            return Err(WireError::Truncated);
        }
        acc[i] += x;
    }
    Ok(())
}

// ------------------------------------------------------------- ternary

/// TernGrad encoding: one 32-bit scale + 2 bits/coordinate
/// (00 = 0, 01 = +m, 10 = −m).
pub fn encode_ternary(v: &[f32]) -> Encoded {
    let m = crate::tensor::norm_inf(v) as f32;
    let mut w = BitWriter::new();
    w.push_f32(m);
    for x in v {
        let code: u32 = if *x == 0.0 {
            0
        } else if *x > 0.0 {
            1
        } else {
            2
        };
        w.push_bits(code, 2);
    }
    let (bytes, bits) = w.into_bytes();
    Encoded {
        bytes,
        bits,
        format: Format::Ternary,
        d: v.len(),
        shard: None,
    }
}

pub fn decode_ternary(e: &Encoded) -> Result<Vec<f32>, WireError> {
    if e.format != Format::Ternary {
        return Err(WireError::Format(Format::Ternary, e.format));
    }
    let mut r = BitReader::new(&e.bytes);
    let m = r.read_f32().ok_or(WireError::Truncated)?;
    let mut out = Vec::with_capacity(e.d);
    for _ in 0..e.d {
        let code = r.read_bits(2).ok_or(WireError::Truncated)?;
        out.push(match code {
            0 => 0.0,
            1 => m,
            _ => -m,
        });
    }
    Ok(out)
}

/// Decode ternary straight into a sum accumulator (fused leader hot path).
pub fn decode_ternary_add(e: &Encoded, acc: &mut [f32]) -> Result<(), WireError> {
    if e.format != Format::Ternary {
        return Err(WireError::Format(Format::Ternary, e.format));
    }
    if acc.len() != e.d {
        return Err(WireError::Truncated);
    }
    let mut r = BitReader::new(&e.bytes);
    let m = r.read_f32().ok_or(WireError::Truncated)?;
    for a in acc.iter_mut() {
        let code = r.read_bits(2).ok_or(WireError::Truncated)?;
        match code {
            0 => {}
            1 => *a += m,
            _ => *a -= m,
        }
    }
    Ok(())
}

// ---------------------------------------------------------------- qsgd

/// Reconstruct the QSGD level integer of a quantized coordinate. The
/// quantizer stored `sign · norm · l / s`; dividing back out recovers `l`
/// exactly (the accumulated rounding error is ~2⁻²² relative, far below
/// the 0.5 needed to flip the nearest integer for `s ≤ 255`).
#[inline]
fn qsgd_level(x: f32, norm: f32, s: u32) -> u32 {
    if x == 0.0 || norm == 0.0 {
        0
    } else {
        ((x.abs() / norm * s as f32).round() as u32).min(s)
    }
}

/// Number of bits in the Elias-gamma code of `x` (= 2⌊log₂ x⌋ + 1).
#[inline]
fn elias_gamma_bits(x: u64) -> u64 {
    debug_assert!(x >= 1);
    2 * (63 - u64::from(x.leading_zeros())) + 1
}

/// QSGD wire format (the Elias-coded scheme of Alistarh et al. 2017):
/// one f32 ℓ₂-norm + one u8 level count `s`, then per coordinate the
/// Elias-gamma code of `level + 1` followed by a single sign bit for
/// non-zero levels. Gaussian-ish gradients have mostly level-0 coordinates
/// (1 bit each), so the frame is far below the dense 32 bits/coordinate —
/// exactly the regime where QSGD claims its communication advantage.
///
/// `v` must be a QSGD-quantized vector and `norm` the exact f32 norm the
/// quantizer used (`tensor::norm2(p) as f32` of the *pre-quantization*
/// vector): levels then reconstruct exactly and [`decode_qsgd`] is
/// bit-faithful to `v`.
pub fn encode_qsgd(v: &[f32], norm: f32, levels: u32) -> Encoded {
    assert!(
        (1..=u8::MAX as u32).contains(&levels),
        "qsgd level count must fit a u8"
    );
    let mut w = BitWriter::new();
    w.push_f32(norm);
    w.push_bits(levels, 8);
    for x in v {
        let l = qsgd_level(*x, norm, levels);
        w.push_elias_gamma(u64::from(l) + 1);
        if l > 0 {
            w.push_bit(*x < 0.0);
        }
    }
    let (bytes, bits) = w.into_bytes();
    Encoded {
        bytes,
        bits,
        format: Format::Qsgd,
        d: v.len(),
        shard: None,
    }
}

/// Exact wire size in bits of [`encode_qsgd`] for this vector, computed
/// without building the frame. Guaranteed (and tested) to equal the
/// encoder's actual `bit_len`.
pub fn qsgd_wire_bits(v: &[f32], norm: f32, levels: u32) -> u64 {
    let mut bits = 32 + 8u64;
    for x in v {
        let l = qsgd_level(*x, norm, levels);
        bits += elias_gamma_bits(u64::from(l) + 1) + u64::from(l > 0);
    }
    bits
}

/// Parse + validate the QSGD frame header; returns (norm, levels, reader
/// positioned at the level stream).
fn qsgd_header(e: &Encoded) -> Result<(f32, u32, BitReader<'_>), WireError> {
    if e.format != Format::Qsgd {
        return Err(WireError::Format(Format::Qsgd, e.format));
    }
    let mut r = BitReader::new(&e.bytes);
    let norm = r.read_f32().ok_or(WireError::Truncated)?;
    let s = r.read_bits(8).ok_or(WireError::Truncated)?;
    if s == 0 {
        return Err(WireError::Truncated);
    }
    Ok((norm, s, r))
}

/// Decode a QSGD frame to the dense quantized vector. Reconstruction uses
/// the quantizer's exact expression order (`±(norm · l) / s`), so the
/// output is bit-identical to the vector that was encoded.
pub fn decode_qsgd(e: &Encoded) -> Result<Vec<f32>, WireError> {
    let (norm, s, mut r) = qsgd_header(e)?;
    let s_f = s as f32;
    let mut out = vec![0.0f32; e.d];
    for o in out.iter_mut() {
        let l = r.read_elias_gamma().ok_or(WireError::Truncated)? - 1;
        if l > u64::from(s) {
            return Err(WireError::Truncated);
        }
        if l > 0 {
            let mag = norm * l as f32 / s_f;
            *o = if r.read_bit().ok_or(WireError::Truncated)? {
                -mag
            } else {
                mag
            };
        }
    }
    Ok(out)
}

/// Decode a QSGD frame straight into a sum accumulator: level-0
/// coordinates (the vast majority) cost one bit-read and no write.
pub fn decode_qsgd_add(e: &Encoded, acc: &mut [f32]) -> Result<(), WireError> {
    let (norm, s, mut r) = qsgd_header(e)?;
    if acc.len() != e.d {
        return Err(WireError::Truncated);
    }
    let s_f = s as f32;
    for a in acc.iter_mut() {
        let l = r.read_elias_gamma().ok_or(WireError::Truncated)? - 1;
        if l > u64::from(s) {
            return Err(WireError::Truncated);
        }
        if l > 0 {
            let mag = norm * l as f32 / s_f;
            if r.read_bit().ok_or(WireError::Truncated)? {
                *a -= mag;
            } else {
                *a += mag;
            }
        }
    }
    Ok(())
}

/// Decode any payload format to a dense vector.
pub fn decode_any(e: &Encoded) -> Result<Vec<f32>, WireError> {
    match e.format {
        Format::DenseF32 => decode_dense(e),
        Format::SignScaled => decode_scaled_sign(e),
        Format::SparseIdxVal => decode_sparse(e),
        Format::Ternary => decode_ternary(e),
        Format::Qsgd => decode_qsgd(e),
    }
}

/// Decode any payload straight into a sum accumulator — the leader's fused
/// aggregation path: one partial-sum buffer instead of a dense `Vec<f32>`
/// per worker frame.
pub fn decode_any_add(e: &Encoded, acc: &mut [f32]) -> Result<(), WireError> {
    match e.format {
        Format::DenseF32 => decode_dense_add(e, acc),
        Format::SignScaled => decode_scaled_sign_add(e, acc),
        Format::SparseIdxVal => decode_sparse_add(e, acc),
        Format::Ternary => decode_ternary_add(e, acc),
        Format::Qsgd => decode_qsgd_add(e, acc),
    }
}

/// Compression ratio of an encoding vs dense f32.
pub fn compression_ratio(e: &Encoded) -> f64 {
    (32.0 * e.d as f64) / e.bits as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor, Qsgd, ScaledSign, TernGrad, TopK};
    use crate::propcheck::{self, VecF32};
    use crate::util::Pcg64;

    #[test]
    fn bitio_roundtrip() {
        let mut w = BitWriter::new();
        w.push_bits(0b1011, 4);
        w.push_f32(-1.5);
        w.push_u32(12345);
        w.push_bit(true);
        let (bytes, bits) = w.into_bytes();
        assert_eq!(bits, 4 + 32 + 32 + 1);
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(4), Some(0b1011));
        assert_eq!(r.read_f32(), Some(-1.5));
        assert_eq!(r.read_u32(), Some(12345));
        assert_eq!(r.read_bit(), Some(true));
    }

    #[test]
    fn prop_dense_roundtrip() {
        propcheck::check(&VecF32::new(0, 200), |v| {
            decode_dense(&encode_dense(v)).unwrap() == *v
        });
    }

    #[test]
    fn prop_scaled_sign_wire_matches_compressor() {
        // decode(encode(p)) equals ScaledSign::compress(p) on zero-free
        // vectors (gaussian => zero-free a.s.).
        propcheck::check(&VecF32::new(1, 300), |p| {
            if p.iter().any(|x| *x == 0.0) {
                return true;
            }
            let e = encode_scaled_sign(p);
            assert_eq!(e.bits, p.len() as u64 + 32);
            let dec = decode_scaled_sign(&e).unwrap();
            let mut rng = Pcg64::seeded(0);
            let direct = ScaledSign.compress_vec(p, &mut rng);
            dec.iter().zip(&direct).all(|(a, b)| a == b)
        });
    }

    #[test]
    fn scaled_sign_zero_encodes_positive() {
        let p = [0.0f32, -1.0, 1.0];
        let dec = decode_scaled_sign(&encode_scaled_sign(&p)).unwrap();
        let scale = 2.0 / 3.0;
        assert!((dec[0] - scale).abs() < 1e-6); // documented zero behaviour
        assert!((dec[1] + scale).abs() < 1e-6);
        assert!((dec[2] - scale).abs() < 1e-6);
    }

    #[test]
    fn decode_add_accumulates() {
        let p = [1.0f32, -2.0, 3.0, -4.0];
        let e = encode_scaled_sign(&p);
        let mut acc = vec![10.0f32; 4];
        decode_scaled_sign_add(&e, &mut acc).unwrap();
        let dec = decode_scaled_sign(&e).unwrap();
        for i in 0..4 {
            assert!((acc[i] - (10.0 + dec[i])).abs() < 1e-6);
        }
    }

    #[test]
    fn prop_sparse_roundtrip_topk() {
        propcheck::check(&VecF32::new(4, 300), |p| {
            let mut rng = Pcg64::seeded(1);
            let delta = TopK::count((p.len() / 4).max(1)).compress_vec(p, &mut rng);
            let e = encode_sparse(&delta);
            decode_sparse(&e).unwrap() == delta
        });
    }

    #[test]
    fn prop_ternary_roundtrip() {
        propcheck::check(&VecF32::new(1, 200), |p| {
            let mut rng = Pcg64::seeded(2);
            let t = TernGrad.compress_vec(p, &mut rng);
            let e = encode_ternary(&t);
            assert_eq!(e.bits, 2 * p.len() as u64 + 32);
            let dec = decode_ternary(&e).unwrap();
            dec.iter().zip(&t).all(|(a, b)| (a - b).abs() < 1e-6)
        });
    }

    #[test]
    fn compression_ratios() {
        let d = 100_000;
        let mut rng = Pcg64::seeded(3);
        let mut p = vec![0.0f32; d];
        rng.fill_normal(&mut p, 0.0, 1.0);
        let sign = encode_scaled_sign(&p);
        let ratio = compression_ratio(&sign);
        // d*32 / (d + 32) -> just under 32x for a single tensor
        assert!(ratio > 31.9 && ratio < 32.0, "ratio={ratio}");
        let dense = encode_dense(&p);
        assert!((compression_ratio(&dense) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn format_mismatch_rejected() {
        let p = [1.0f32, 2.0];
        let e = encode_dense(&p);
        assert!(matches!(
            decode_scaled_sign(&e),
            Err(WireError::Format(..))
        ));
    }

    #[test]
    fn truncated_rejected() {
        let p = [1.0f32; 64];
        let mut e = encode_scaled_sign(&p);
        e.bytes.truncate(4);
        assert!(matches!(decode_scaled_sign(&e), Err(WireError::Truncated)));
    }

    /// Mixed push_bit / push_bits / push_bits64 sequences at non-byte-
    /// aligned cursors round-trip exactly (regression guard for the
    /// aligned fast paths taking over mid-stream).
    #[test]
    fn prop_bitio_roundtrip_unaligned_cursors() {
        use crate::propcheck::UsizeRange;
        propcheck::check_with(
            &propcheck::Config {
                cases: 200,
                ..Default::default()
            },
            &UsizeRange(1, 10_000),
            |&seed| {
                let mut rng = Pcg64::seeded(seed as u64);
                // Script a random mix of writes, remember (value, width).
                let mut script: Vec<(u64, u32)> = Vec::new();
                let mut w = BitWriter::new();
                for _ in 0..40 {
                    match rng.below(3) {
                        0 => {
                            let bit = rng.next_u32() & 1;
                            w.push_bit(bit == 1);
                            script.push((bit as u64, 1));
                        }
                        1 => {
                            let n = 1 + rng.below(32) as u32;
                            let v = rng.next_u32() & (u32::MAX >> (32 - n));
                            w.push_bits(v, n);
                            script.push((v as u64, n));
                        }
                        _ => {
                            let n = 1 + rng.below(64) as u32;
                            let v = rng.next_u64() & (u64::MAX >> (64 - n));
                            w.push_bits64(v, n);
                            script.push((v, n));
                        }
                    }
                }
                let expect_bits: u64 = script.iter().map(|(_, n)| *n as u64).sum();
                let (bytes, bits) = w.into_bytes();
                if bits != expect_bits {
                    return false;
                }
                let mut r = BitReader::new(&bytes);
                script.iter().all(|&(v, n)| match n {
                    1 => r.read_bit() == Some(v == 1),
                    n if n <= 32 && v <= u32::MAX as u64 => {
                        // read through the 64-bit path half the time to
                        // cross-check both readers
                        if n % 2 == 0 {
                            r.read_bits(n) == Some(v as u32)
                        } else {
                            r.read_bits64(n) == Some(v)
                        }
                    }
                    _ => r.read_bits64(n) == Some(v),
                })
            },
        );
    }

    /// Elias-gamma round-trips exact values at deliberately unaligned
    /// cursors (interleaved single bits shift every code off byte
    /// boundaries), and its bit cost matches the analytic 2⌊log₂x⌋+1.
    #[test]
    fn prop_elias_gamma_roundtrip_unaligned() {
        use crate::propcheck::UsizeRange;
        propcheck::check_with(
            &propcheck::Config {
                cases: 200,
                ..Default::default()
            },
            &UsizeRange(1, 1_000_000),
            |&seed| {
                let mut rng = Pcg64::seeded(seed as u64);
                let mut script: Vec<u64> = Vec::new();
                let mut w = BitWriter::new();
                for _ in 0..50 {
                    // skew small (the QSGD regime) but cover large too
                    let x: u64 = match rng.below(4) {
                        0 => 1 + rng.below(3) as u64,
                        1 => 1 + rng.below(64) as u64,
                        2 => 1 + rng.below(1 << 20) as u64,
                        _ => 1 + rng.next_u64() % (1 << 40),
                    };
                    let before = w.bit_len();
                    w.push_elias_gamma(x);
                    if w.bit_len() - before != elias_gamma_bits(x) {
                        return false;
                    }
                    // misalign the cursor between codes
                    let pad = rng.next_u32() & 1 == 1;
                    w.push_bit(pad);
                    script.push(x);
                    script.push(u64::from(pad));
                }
                let (bytes, _) = w.into_bytes();
                let mut r = BitReader::new(&bytes);
                script.chunks(2).all(|pair| {
                    r.read_elias_gamma() == Some(pair[0])
                        && r.read_bit() == Some(pair[1] == 1)
                })
            },
        );
    }

    #[test]
    fn elias_gamma_known_codewords() {
        // gamma(1) = "1", gamma(2) = "010", gamma(5) = "00101" (MSB first)
        let mut w = BitWriter::new();
        w.push_elias_gamma(1);
        w.push_elias_gamma(2);
        w.push_elias_gamma(5);
        let (bytes, bits) = w.into_bytes();
        assert_eq!(bits, 1 + 3 + 5);
        let expected_bits = [1, 0, 1, 0, 0, 0, 1, 0, 1]; // LSB-first stream
        for (i, want) in expected_bits.iter().enumerate() {
            assert_eq!((bytes[i / 8] >> (i % 8)) & 1, *want, "bit {i}");
        }
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_elias_gamma(), Some(1));
        assert_eq!(r.read_elias_gamma(), Some(2));
        assert_eq!(r.read_elias_gamma(), Some(5));
    }

    /// QSGD frames round-trip bit-exactly at every byte-alignment class
    /// (ragged d) and level count s ∈ {1, 4, 16}; `qsgd_wire_bits` always
    /// equals the encoder's actual bit length; decode_add fuses correctly.
    #[test]
    fn qsgd_roundtrip_all_alignments_and_levels() {
        let mut rng = Pcg64::seeded(11);
        for s in [1u32, 4, 16] {
            let q = Qsgd::new(s);
            for d in [1usize, 2, 7, 8, 9, 63, 64, 65, 127, 129, 200, 1000] {
                let mut p = vec![0.0f32; d];
                rng.fill_normal(&mut p, 0.0, 1.0);
                let v = q.compress_vec(&p, &mut Pcg64::seeded(d as u64));
                let norm = crate::tensor::norm2(&p) as f32;
                let e = encode_qsgd(&v, norm, s);
                assert_eq!(e.format, Format::Qsgd);
                assert_eq!(e.d, d);
                assert_eq!(
                    e.bits,
                    qsgd_wire_bits(&v, norm, s),
                    "size formula drifted from encoder at d={d} s={s}"
                );
                let dec = decode_qsgd(&e).unwrap();
                for i in 0..d {
                    assert_eq!(dec[i], v[i], "d={d} s={s} i={i}");
                }
                let mut acc = vec![1.5f32; d];
                decode_qsgd_add(&e, &mut acc).unwrap();
                for i in 0..d {
                    assert!((acc[i] - (1.5 + v[i])).abs() < 1e-6, "d={d} s={s} i={i}");
                }
                // decode_any dispatches to the qsgd decoder
                assert_eq!(decode_any(&e).unwrap(), dec);
            }
        }
    }

    /// Property test: on random gaussian inputs the analytic size formula
    /// equals the encoder exactly, for every levels setting.
    #[test]
    fn prop_qsgd_wire_bits_matches_encoder() {
        propcheck::check(&VecF32::new(1, 400), |p| {
            for s in [1u32, 4, 16] {
                let v = Qsgd::new(s).compress_vec(p, &mut Pcg64::seeded(9));
                let norm = crate::tensor::norm2(p) as f32;
                let e = encode_qsgd(&v, norm, s);
                if e.bits != qsgd_wire_bits(&v, norm, s) {
                    return false;
                }
                // frames are never wastefully padded beyond the last byte
                if e.bytes.len() as u64 != e.bits.div_ceil(8) {
                    return false;
                }
            }
            true
        });
    }

    /// The acceptance bar from the PR issue: at s=1 and d=65536 the QSGD
    /// frame must be at most a quarter of the dense f32 payload. (It is in
    /// fact ~1 bit/coordinate ≈ 1/32 of dense; 1/4 leaves slack for
    /// adversarial level distributions.)
    #[test]
    fn qsgd_frame_quarter_of_dense_at_s1() {
        let d = 65_536;
        let mut rng = Pcg64::seeded(13);
        let mut p = vec![0.0f32; d];
        rng.fill_normal(&mut p, 0.0, 1.0);
        let v = Qsgd::new(1).compress_vec(&p, &mut rng);
        let norm = crate::tensor::norm2(&p) as f32;
        let e = encode_qsgd(&v, norm, 1);
        let dense = encode_dense(&v);
        assert!(
            e.bytes.len() * 4 <= dense.bytes.len(),
            "qsgd frame {} bytes vs dense {} bytes",
            e.bytes.len(),
            dense.bytes.len()
        );
        assert!(e.bits * 4 <= dense.bits);
        // and it still decodes exactly
        let dec = decode_qsgd(&e).unwrap();
        for i in 0..d {
            assert_eq!(dec[i], v[i]);
        }
    }

    #[test]
    fn qsgd_zero_vector_and_degenerate_frames() {
        // all-zero vector: norm 0, every level 0, 1 bit per coordinate
        let v = vec![0.0f32; 100];
        let e = encode_qsgd(&v, 0.0, 4);
        assert_eq!(e.bits, 32 + 8 + 100);
        assert_eq!(decode_qsgd(&e).unwrap(), v);
        // truncation rejected
        let mut t = e.clone();
        t.bytes.truncate(4);
        assert!(matches!(decode_qsgd(&t), Err(WireError::Truncated)));
        // format mismatch rejected
        let dense = encode_dense(&v);
        assert!(matches!(decode_qsgd(&dense), Err(WireError::Format(..))));
        let mut acc = vec![0.0f32; 100];
        assert!(matches!(
            decode_qsgd_add(&dense, &mut acc),
            Err(WireError::Format(..))
        ));
    }

    /// Every fused `decode_*_add` matches decode-then-add for its format.
    #[test]
    fn fused_add_decoders_match_decode_then_add() {
        let d = 257; // ragged on purpose
        let mut rng = Pcg64::seeded(17);
        let mut p = vec![0.0f32; d];
        rng.fill_normal(&mut p, 0.0, 1.0);
        let sparse_v = TopK::count(d / 4).compress_vec(&p, &mut Pcg64::seeded(1));
        let tern_v = TernGrad.compress_vec(&p, &mut Pcg64::seeded(2));
        let qsgd_v = Qsgd::new(4).compress_vec(&p, &mut Pcg64::seeded(3));
        let norm = crate::tensor::norm2(&p) as f32;
        let frames = [
            encode_dense(&p),
            encode_scaled_sign(&p),
            encode_sparse(&sparse_v),
            encode_ternary(&tern_v),
            encode_qsgd(&qsgd_v, norm, 4),
        ];
        for e in &frames {
            let dec = decode_any(e).unwrap();
            let mut acc: Vec<f32> = (0..d).map(|i| (i as f32 * 0.13).cos()).collect();
            let mut want = acc.clone();
            decode_any_add(e, &mut acc).unwrap();
            for (w, x) in want.iter_mut().zip(&dec) {
                *w += x;
            }
            for i in 0..d {
                assert!(
                    (acc[i] - want[i]).abs() < 1e-6,
                    "{:?} i={i}: {} vs {}",
                    e.format,
                    acc[i],
                    want[i]
                );
            }
        }
    }

    /// The shard tag charges exactly `SHARD_TAG_BITS` on top of the payload
    /// and leaves the payload bytes (and hence the decode) untouched.
    #[test]
    fn shard_tag_costs_exactly_its_header() {
        let p = [1.0f32, -2.0, 3.0];
        let plain = encode_scaled_sign(&p);
        let tagged = encode_scaled_sign(&p).with_shard(3, 128);
        assert_eq!(tagged.bits, plain.bits + SHARD_TAG_BITS);
        assert_eq!(tagged.bytes, plain.bytes);
        assert_eq!(tagged.shard, Some(ShardTag { shard: 3, start: 128 }));
        assert_eq!(
            decode_scaled_sign(&tagged).unwrap(),
            decode_scaled_sign(&plain).unwrap()
        );
        assert!(plain.shard.is_none());
    }

    /// The word-packed sign codec round-trips at every alignment class:
    /// d spanning multiples of 64, multiples of 8, and ragged tails.
    #[test]
    fn packed_sign_roundtrip_all_alignments() {
        let mut rng = Pcg64::seeded(7);
        for d in [1, 2, 7, 8, 9, 63, 64, 65, 127, 128, 129, 200, 1000] {
            let mut p = vec![0.0f32; d];
            rng.fill_normal(&mut p, 0.0, 1.0);
            let e = encode_scaled_sign(&p);
            assert_eq!(e.bits, d as u64 + 32);
            assert_eq!(e.bytes.len(), 4 + d.div_ceil(8));
            let scale = ScaledSign::scale(&p);
            let dec = decode_scaled_sign(&e).unwrap();
            let mut acc = vec![1.5f32; d];
            decode_scaled_sign_add(&e, &mut acc).unwrap();
            for i in 0..d {
                let want = if p[i] >= 0.0 { scale } else { -scale };
                assert_eq!(dec[i], want, "d={d} i={i}");
                assert!((acc[i] - (1.5 + want)).abs() < 1e-6, "d={d} i={i}");
            }
        }
    }
}
