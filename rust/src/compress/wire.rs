//! Wire codecs: the exact bit-level encodings the workers put on the
//! simulated network. This is where the paper's communication claim is
//! grounded — the ~64× compression versus 32-bit floats (sign bit per
//! coordinate in each direction + one 32-bit scale per tensor) is measured
//! on these encoders by `repro exp comm`, not asserted.

/// Bit-level writer (LSB-first within each byte), built around a u64 word
/// accumulator: pushed bits collect in `cur` and flush to the byte buffer
/// eight bytes at a time, so a multi-bit push costs O(1) instead of a
/// per-bit loop. Because the stream is LSB-first within each byte and the
/// accumulator flushes little-endian, the emitted byte stream is identical
/// to the historical per-bit writer — asserted bit-for-bit by
/// `prop_word_writer_matches_reference` and the golden-frame tests below.
#[derive(Default)]
pub struct BitWriter {
    bytes: Vec<u8>,
    /// Pending bits, LSB-first; only the low `fill` bits are meaningful
    /// (everything above is zero).
    cur: u64,
    /// Number of pending bits in `cur` (always < 64).
    fill: u32,
    /// Total number of bits pushed.
    bits: u64,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// A writer that reuses `buf`'s allocation (cleared first) — the
    /// backbone of the zero-allocation `encode_*_into` paths.
    pub fn with_buf(mut buf: Vec<u8>) -> Self {
        buf.clear();
        BitWriter {
            bytes: buf,
            cur: 0,
            fill: 0,
            bits: 0,
        }
    }

    /// Pre-size the byte buffer for `bits` more bits (plus word-flush
    /// headroom), so a correctly bounded reservation makes every later
    /// push allocation-free.
    pub fn reserve_bits(&mut self, bits: u64) {
        self.bytes.reserve((bits as usize).div_ceil(8) + 8);
    }

    #[inline]
    pub fn push_bit(&mut self, bit: bool) {
        self.push_bits64(u64::from(bit), 1);
    }

    /// Push the low `n` bits of `value`, LSB first.
    #[inline]
    pub fn push_bits(&mut self, value: u32, n: u32) {
        debug_assert!(n <= 32);
        self.push_bits64(value as u64, n);
    }

    /// Push the low `n` bits of a 64-bit `value`, LSB first — word-at-a-
    /// time: the bits land in the accumulator and whole 64-bit words flush
    /// to the buffer little-endian (which preserves the LSB-first byte
    /// stream exactly).
    #[inline]
    pub fn push_bits64(&mut self, value: u64, n: u32) {
        debug_assert!(n <= 64);
        if n == 0 {
            return;
        }
        let v = if n == 64 {
            value
        } else {
            value & ((1u64 << n) - 1)
        };
        let fill = self.fill;
        // low part of v lands above the pending bits; overflow past bit 63
        // is recovered from `v` after the flush
        self.cur |= v << fill;
        if fill + n >= 64 {
            self.bytes.extend_from_slice(&self.cur.to_le_bytes());
            let consumed = 64 - fill;
            // consumed == 64 only when fill == 0, where the flushed word
            // was all of `v` (n == 64): nothing remains
            self.cur = if consumed == 64 { 0 } else { v >> consumed };
            self.fill = fill + n - 64;
        } else {
            self.fill = fill + n;
        }
        self.bits += n as u64;
    }

    /// Append a whole byte (cursor must be byte-aligned). The alignment
    /// contract is load-bearing for the codecs' byte-aligned fast paths,
    /// so it is a real check, not a debug assertion.
    #[inline]
    pub fn push_byte_aligned(&mut self, byte: u8) {
        assert_eq!(self.bits % 8, 0, "push_byte_aligned at unaligned cursor");
        self.push_bits64(u64::from(byte), 8);
    }

    /// Push a positive integer in Elias-gamma code: `⌊log₂ x⌋` zeros, then
    /// the binary of `x` MSB-first — `2⌊log₂ x⌋ + 1` bits total. Small
    /// integers are cheap (1 → 1 bit, 2..3 → 3 bits, 4..7 → 5 bits), which
    /// is what makes the QSGD level stream compact: most levels are 0,
    /// coded as γ(1). Two word pushes — no per-bit loop: MSB-first on an
    /// LSB-first stream is the bit-reversal of `x` within its width.
    #[inline]
    pub fn push_elias_gamma(&mut self, x: u64) {
        debug_assert!(x >= 1, "Elias gamma codes integers >= 1");
        let nbits = 64 - x.leading_zeros();
        self.push_bits64(0, nbits - 1);
        self.push_bits64(x.reverse_bits() >> (64 - nbits), nbits);
    }

    pub fn push_f32(&mut self, v: f32) {
        self.push_bits(v.to_bits(), 32);
    }

    pub fn push_u32(&mut self, v: u32) {
        self.push_bits(v, 32);
    }

    pub fn bit_len(&self) -> u64 {
        self.bits
    }

    /// Flush the pending bits and hand back `(bytes, exact bit length)`.
    /// The byte count is exactly `⌈bits / 8⌉`, as with the per-bit writer.
    pub fn into_bytes(mut self) -> (Vec<u8>, u64) {
        let tail = (self.fill as usize).div_ceil(8);
        self.bytes.extend_from_slice(&self.cur.to_le_bytes()[..tail]);
        (self.bytes, self.bits)
    }
}

/// Bit-level reader matching [`BitWriter`], built around a residual u64
/// window: bytes are fetched into `window` once and every read serves from
/// it, so a read that straddles a refill boundary never re-fetches (the
/// historical reader re-shifted per bit). Zero runs in the Elias-gamma
/// path are counted with one `trailing_zeros` (a count-zeros instruction)
/// instead of a bit-at-a-time loop. Byte stream semantics are unchanged
/// (LSB-first within each byte, truncation at byte granularity) —
/// asserted bit-for-bit against the retained per-bit reference reader by
/// `prop_windowed_reader_matches_scalar`.
pub struct BitReader<'a> {
    bytes: &'a [u8],
    /// Index of the next unfetched byte.
    byte_pos: usize,
    /// Fetched-but-unread stream bits, LSB-first (oldest bit = bit 0).
    /// Invariant: every bit at position ≥ `avail` is zero.
    window: u64,
    /// Number of valid bits in `window` (≤ 64).
    avail: u32,
}

impl<'a> BitReader<'a> {
    pub fn new(bytes: &'a [u8]) -> Self {
        BitReader {
            bytes,
            byte_pos: 0,
            window: 0,
            avail: 0,
        }
    }

    /// Top the window up to > 56 valid bits (or until the bytes run out):
    /// whole bytes land above the residual, preserving stream order.
    #[inline]
    fn refill(&mut self) {
        while self.avail <= 56 && self.byte_pos < self.bytes.len() {
            self.window |= u64::from(self.bytes[self.byte_pos]) << self.avail;
            self.avail += 8;
            self.byte_pos += 1;
        }
    }

    #[inline]
    pub fn read_bit(&mut self) -> Option<bool> {
        if self.avail == 0 {
            self.refill();
            if self.avail == 0 {
                return None;
            }
        }
        let bit = self.window & 1 == 1;
        self.window >>= 1;
        self.avail -= 1;
        Some(bit)
    }

    pub fn read_bits(&mut self, n: u32) -> Option<u32> {
        debug_assert!(n <= 32);
        self.read_bits64(n).map(|v| v as u32)
    }

    /// Read `n` bits (LSB first) into a 64-bit word — the counterpart of
    /// [`BitWriter::push_bits64`]. Served from the residual window; at
    /// most one refill per call.
    // detlint: hot
    pub fn read_bits64(&mut self, n: u32) -> Option<u64> {
        debug_assert!(n <= 64);
        if n == 0 {
            return Some(0);
        }
        if self.avail < n {
            self.refill();
        }
        if n <= self.avail {
            let v = if n == 64 {
                self.window
            } else {
                self.window & ((1u64 << n) - 1)
            };
            self.window = if n == 64 { 0 } else { self.window >> n };
            self.avail -= n;
            return Some(v);
        }
        // Straddle: the refill tops up to at most 63 residual bits when it
        // stops above 56 mid-stream, so n ∈ {58..=64} can still exceed it.
        // Take everything the window holds, refill, take the rest — the
        // already-taken bits are never re-fetched.
        let have = self.avail; // ≥ 1 unless the bytes are exhausted
        if have == 0 {
            return None;
        }
        let low = self.window;
        self.window = 0;
        self.avail = 0;
        self.refill();
        let need = n - have; // ≤ 63 because have ≥ 1
        if need > self.avail {
            return None; // truncated mid-read (callers bail on None)
        }
        let hi = self.window & ((1u64 << need) - 1);
        self.window >>= need;
        self.avail -= need;
        Some(low | (hi << have))
    }

    /// Read one Elias-gamma-coded positive integer — the counterpart of
    /// [`BitWriter::push_elias_gamma`]. The leading zero run is counted
    /// whole-window via `trailing_zeros` (the window invariant keeps junk
    /// bits zero, so a non-zero window locates its terminator in one
    /// instruction) and the suffix is one [`read_bits64`] — no per-bit
    /// loop anywhere.
    // detlint: hot
    pub fn read_elias_gamma(&mut self) -> Option<u64> {
        let mut zeros = 0u32;
        loop {
            if self.avail == 0 {
                self.refill();
                if self.avail == 0 {
                    return None;
                }
            }
            if self.window != 0 {
                // invariant: bits ≥ avail are zero, so the lowest set bit
                // is a real stream bit — the run below it is all zeros
                let run = self.window.trailing_zeros();
                zeros += run;
                if zeros > 63 {
                    return None; // not a valid gamma code for a u64
                }
                // consume the zero run and its 1-terminator (used == 64
                // exactly when a 63-zero run fills a fresh window)
                let used = run + 1;
                self.window = if used == 64 { 0 } else { self.window >> used };
                self.avail -= used;
                break;
            }
            // every valid bit in the window is zero: consume them all
            zeros += self.avail;
            if zeros > 63 {
                return None;
            }
            self.avail = 0;
        }
        if zeros == 0 {
            return Some(1);
        }
        // suffix: x's bits below the MSB, stream order MSB-first — an
        // LSB-first word read is the bit-reversal within its width
        let low = self.read_bits64(zeros)?;
        Some((1u64 << zeros) | (low.reverse_bits() >> (64 - zeros)))
    }

    pub fn read_f32(&mut self) -> Option<f32> {
        self.read_bits(32).map(f32::from_bits)
    }

    pub fn read_u32(&mut self) -> Option<u32> {
        self.read_bits(32)
    }
}

/// Shard routing header carried by sharded wire frames (see docs/WIRE.md):
/// a 16-bit shard id plus the 32-bit start coordinate of the slice in the
/// full model vector. The slice length is the frame's own `d`, so the
/// coordinate range is `start .. start + d`. Unsharded frames carry no tag
/// and cost no extra bits — the single-leader wire format is unchanged.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardTag {
    pub shard: u16,
    pub start: u32,
}

/// On-wire cost of a [`ShardTag`]: 16-bit shard id + 32-bit start.
pub const SHARD_TAG_BITS: u64 = 48;

/// An encoded gradient payload with exact size accounting.
#[derive(Clone, Debug)]
pub struct Encoded {
    pub bytes: Vec<u8>,
    /// Exact payload size in bits (may be less than bytes.len()*8; includes
    /// [`SHARD_TAG_BITS`] when a shard tag is attached).
    pub bits: u64,
    pub format: Format,
    /// Original vector length.
    pub d: usize,
    /// Shard routing header for sharded parameter-server frames
    /// (`None` = unsharded; the bytes/bits above are then exactly the
    /// historical single-leader frame).
    pub shard: Option<ShardTag>,
}

impl Encoded {
    /// An empty frame shell around a recycled byte buffer (cleared, its
    /// allocation kept): the `encode_*_into` encoders fill it without
    /// allocating. Pair with [`crate::net::FramePool`] to cycle push-frame
    /// buffers between the workers' encoders and the leader's decoders.
    pub fn recycled(mut bytes: Vec<u8>) -> Self {
        bytes.clear();
        Encoded {
            bytes,
            bits: 0,
            format: Format::DenseF32,
            d: 0,
            shard: None,
        }
    }

    /// Attach the shard routing header (id + start coordinate) in place,
    /// charging its [`SHARD_TAG_BITS`] on the frame's exact size.
    pub fn set_shard(&mut self, shard: u16, start: u32) {
        assert!(self.shard.is_none(), "frame already shard-tagged");
        self.shard = Some(ShardTag { shard, start });
        self.bits += SHARD_TAG_BITS;
    }

    /// Consuming variant of [`set_shard`](Self::set_shard).
    pub fn with_shard(mut self, shard: u16, start: u32) -> Self {
        self.set_shard(shard, start);
        self
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Format {
    DenseF32,
    SignScaled,
    SparseIdxVal,
    Ternary,
    /// QSGD: f32 ℓ₂-norm + u8 level count + Elias-gamma level stream.
    Qsgd,
}

impl Format {
    /// Number of wire formats (fixed metric-slot fan-out in `obs`).
    pub const COUNT: usize = 5;

    /// Every format, indexed by [`Format::index`].
    pub const ALL: [Format; Format::COUNT] = [
        Format::DenseF32,
        Format::SignScaled,
        Format::SparseIdxVal,
        Format::Ternary,
        Format::Qsgd,
    ];

    /// Dense per-format slot index, stable across runs.
    pub fn index(self) -> usize {
        match self {
            Format::DenseF32 => 0,
            Format::SignScaled => 1,
            Format::SparseIdxVal => 2,
            Format::Ternary => 3,
            Format::Qsgd => 4,
        }
    }

    /// Stable snake_case name used in metric labels and reports.
    pub fn name(self) -> &'static str {
        match self {
            Format::DenseF32 => "dense_f32",
            Format::SignScaled => "sign_scaled",
            Format::SparseIdxVal => "sparse_idx_val",
            Format::Ternary => "ternary",
            Format::Qsgd => "qsgd",
        }
    }
}

/// Typed decode failure. Frame bytes are untrusted input (a Byzantine
/// worker or a corrupted link can put anything on the wire), so every
/// `decode_*` path returns this instead of panicking; the drivers count
/// an undecodable frame as dropped and keep going.
#[derive(Debug)]
pub enum DecodeError {
    /// Payload ends before the `d` coordinates the frame claims.
    Truncated,
    /// Payload is the right size but semantically invalid: a sparse
    /// index or count out of range, a QSGD level above the advertised
    /// count, or a zero level count.
    Malformed,
    Format(Format, Format),
}

/// Historical name for [`DecodeError`].
pub type WireError = DecodeError;

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeError::Truncated => write!(f, "payload truncated"),
            DecodeError::Malformed => write!(f, "payload malformed"),
            DecodeError::Format(want, got) => {
                write!(f, "format mismatch: expected {want:?}, got {got:?}")
            }
        }
    }
}

impl std::error::Error for DecodeError {}

// ------------------------------------------------------------- dense f32

/// Baseline encoding: 32 bits per coordinate, into a caller-owned frame
/// (the byte buffer's allocation is reused).
// detlint: hot
pub fn encode_dense_into(v: &[f32], out: &mut Encoded) {
    out.bytes.clear();
    out.bytes.reserve(v.len() * 4);
    for x in v {
        out.bytes.extend_from_slice(&x.to_le_bytes());
    }
    out.bits = 32 * v.len() as u64;
    out.format = Format::DenseF32;
    out.d = v.len();
    out.shard = None;
}

/// Baseline encoding: 32 bits per coordinate.
pub fn encode_dense(v: &[f32]) -> Encoded {
    let mut e = Encoded::recycled(Vec::new());
    encode_dense_into(v, &mut e);
    e
}

pub fn decode_dense(e: &Encoded) -> Result<Vec<f32>, WireError> {
    if e.format != Format::DenseF32 {
        return Err(WireError::Format(Format::DenseF32, e.format));
    }
    if e.bytes.len() < e.d * 4 {
        return Err(WireError::Truncated);
    }
    // chunks_exact guarantees 4-byte chunks: no slice-index or unwrap on
    // the untrusted payload
    Ok(e.bytes
        .chunks_exact(4)
        .take(e.d)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Decode dense straight into a sum accumulator (fused leader hot path).
/// `chunks_exact(4)` fixes the lane shape so the byte-to-f32 loads and the
/// elementwise adds autovectorize; per-coordinate add order is unchanged.
// detlint: hot
pub fn decode_dense_add(e: &Encoded, acc: &mut [f32]) -> Result<(), WireError> {
    if e.format != Format::DenseF32 {
        return Err(WireError::Format(Format::DenseF32, e.format));
    }
    if e.bytes.len() < e.d * 4 || acc.len() != e.d {
        return Err(WireError::Truncated);
    }
    for (a, c) in acc.iter_mut().zip(e.bytes.chunks_exact(4)) {
        *a += f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
    }
    Ok(())
}

// --------------------------------------------------------- scaled sign

/// The paper's wire format: one 32-bit scale (‖p‖₁/d) + d packed sign
/// bits, into a caller-owned frame. Exact zeros (measure-zero after error
/// correction) encode as +. `d + 32` bits total — the `Σ_i (d_i + 32)`
/// accounting of §6.1.
// detlint: hot
pub fn encode_scaled_sign_into(p: &[f32], out: &mut Encoded) {
    let scale = super::ScaledSign::scale(p);
    // Word-packed sign encoding (hot path): the scale occupies exactly 4
    // bytes, so sign bits start byte-aligned; 64 coordinates pack into one
    // u64 at a time, branch-free, with a byte-wise tail for d % 64.
    let d = p.len();
    let bytes = &mut out.bytes;
    bytes.clear();
    bytes.reserve(4 + d.div_ceil(8));
    bytes.extend_from_slice(&scale.to_bits().to_le_bytes());
    let mut chunks = p.chunks_exact(64);
    for c in &mut chunks {
        let mut word = 0u64;
        for (j, x) in c.iter().enumerate() {
            // bit = 1 for x >= 0 (and for -0.0, matching `*x >= 0.0`)
            word |= u64::from(*x >= 0.0) << j;
        }
        bytes.extend_from_slice(&word.to_le_bytes());
    }
    let rem = chunks.remainder();
    for sub in rem.chunks(8) {
        let mut byte = 0u8;
        for (j, x) in sub.iter().enumerate() {
            byte |= u8::from(*x >= 0.0) << j;
        }
        bytes.push(byte);
    }
    out.bits = 32 + d as u64;
    out.format = Format::SignScaled;
    out.d = d;
    out.shard = None;
}

/// Allocating wrapper around [`encode_scaled_sign_into`].
pub fn encode_scaled_sign(p: &[f32]) -> Encoded {
    let mut e = Encoded::recycled(Vec::new());
    encode_scaled_sign_into(p, &mut e);
    e
}

/// Parse header + validate size for the scaled-sign format.
fn sign_payload(e: &Encoded) -> Result<(f32, &[u8]), WireError> {
    if e.format != Format::SignScaled {
        return Err(WireError::Format(Format::SignScaled, e.format));
    }
    if e.bytes.len() < 4 + e.d.div_ceil(8) {
        return Err(WireError::Truncated);
    }
    let b = &e.bytes;
    let scale = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
    // a non-finite scale would silently poison every coordinate of the
    // aggregate; honest encoders never produce one, so reject it here
    if !scale.is_finite() {
        return Err(WireError::Malformed);
    }
    Ok((scale, &b[4..]))
}

/// The ±scale of one packed sign bit, by xor-ing the f32 sign bit: a set
/// wire bit selects `+scale`, a clear one `-scale`. Produces the exact
/// bit pattern of the old `if bit { scale } else { -scale }` select
/// (unary f32 negation flips the sign bit and nothing else), but with no
/// per-bit branch — the sign unpack loops below compile to straight-line
/// lane arithmetic the autovectorizer can widen.
#[inline(always)]
fn sign_lane(pos_bits: u32, bit: u64) -> f32 {
    f32::from_bits(pos_bits ^ (((bit as u32) ^ 1) << 31))
}

/// Decode to the dense update vector `scale * sign` (word-wise unpack into
/// a preallocated buffer; branch-free lane fill, 64 lanes per load).
pub fn decode_scaled_sign(e: &Encoded) -> Result<Vec<f32>, WireError> {
    let (scale, body) = sign_payload(e)?;
    let pos = scale.to_bits();
    let mut out = vec![0.0f32; e.d];
    let full = e.d / 64; // sign_payload guarantees body.len() >= ceil(d/8)
    let mut chunks = out.chunks_exact_mut(64);
    for (c, w) in (&mut chunks).zip(body.chunks_exact(8).take(full)) {
        let word = u64::from_le_bytes([w[0], w[1], w[2], w[3], w[4], w[5], w[6], w[7]]);
        for (j, o) in c.iter_mut().enumerate() {
            *o = sign_lane(pos, word >> j & 1);
        }
    }
    for (sub, byte) in chunks.into_remainder().chunks_mut(8).zip(&body[full * 8..]) {
        for (j, o) in sub.iter_mut().enumerate() {
            *o = sign_lane(pos, u64::from(byte >> j) & 1);
        }
    }
    Ok(out)
}

/// Decode straight into a sum accumulator (the parameter-server hot path:
/// no intermediate dense vector). Elementwise `acc[i] += ±scale` in
/// coordinate order — per-output-coordinate summation order is identical
/// to the scalar reference, so the result is bitwise identical (asserted
/// by `prop_vectorized_decode_add_matches_scalar`).
// detlint: hot
pub fn decode_scaled_sign_add(e: &Encoded, acc: &mut [f32]) -> Result<(), WireError> {
    let (scale, body) = sign_payload(e)?;
    if acc.len() != e.d {
        return Err(WireError::Truncated);
    }
    let pos = scale.to_bits();
    let full = e.d / 64;
    let mut chunks = acc.chunks_exact_mut(64);
    for (c, w) in (&mut chunks).zip(body.chunks_exact(8).take(full)) {
        let word = u64::from_le_bytes([w[0], w[1], w[2], w[3], w[4], w[5], w[6], w[7]]);
        for (j, a) in c.iter_mut().enumerate() {
            *a += sign_lane(pos, word >> j & 1);
        }
    }
    for (sub, byte) in chunks.into_remainder().chunks_mut(8).zip(&body[full * 8..]) {
        for (j, a) in sub.iter_mut().enumerate() {
            *a += sign_lane(pos, u64::from(byte >> j) & 1);
        }
    }
    Ok(())
}

// -------------------------------------------------------------- sparse

/// Sparse (top-k / random-k) encoding: u32 count + (u32 index, f32 value)
/// per non-zero, into a caller-owned frame. Two passes over `v` (count,
/// then emit) instead of materializing an intermediate non-zero list.
// detlint: hot
pub fn encode_sparse_into(v: &[f32], out: &mut Encoded) {
    let nz = v.iter().filter(|x| **x != 0.0).count();
    let mut w = BitWriter::with_buf(std::mem::take(&mut out.bytes));
    w.reserve_bits(32 + 64 * nz as u64);
    w.push_u32(nz as u32);
    for (i, x) in v.iter().enumerate() {
        if *x != 0.0 {
            w.push_u32(i as u32);
            w.push_f32(*x);
        }
    }
    let (bytes, bits) = w.into_bytes();
    out.bytes = bytes;
    out.bits = bits;
    out.format = Format::SparseIdxVal;
    out.d = v.len();
    out.shard = None;
}

/// Allocating wrapper around [`encode_sparse_into`].
pub fn encode_sparse(v: &[f32]) -> Encoded {
    let mut e = Encoded::recycled(Vec::new());
    encode_sparse_into(v, &mut e);
    e
}

pub fn decode_sparse(e: &Encoded) -> Result<Vec<f32>, WireError> {
    if e.format != Format::SparseIdxVal {
        return Err(WireError::Format(Format::SparseIdxVal, e.format));
    }
    let mut r = BitReader::new(&e.bytes);
    let count = r.read_u32().ok_or(WireError::Truncated)? as usize;
    // reject a garbage count before trusting it as a loop bound: more
    // non-zeros than coordinates, or more pairs than the payload holds
    if count > e.d {
        return Err(WireError::Malformed);
    }
    if (e.bytes.len() as u64) * 8 < 32 + 64 * count as u64 {
        return Err(WireError::Truncated);
    }
    let mut out = vec![0.0f32; e.d];
    for _ in 0..count {
        let i = r.read_u32().ok_or(WireError::Truncated)? as usize;
        let x = r.read_f32().ok_or(WireError::Truncated)?;
        if i >= e.d || !x.is_finite() {
            return Err(WireError::Malformed);
        }
        out[i] = x;
    }
    Ok(out)
}

/// Decode sparse straight into a sum accumulator: only the stored non-zeros
/// are touched, so a top-k frame costs O(k), not O(d), to aggregate.
// detlint: hot
pub fn decode_sparse_add(e: &Encoded, acc: &mut [f32]) -> Result<(), WireError> {
    if e.format != Format::SparseIdxVal {
        return Err(WireError::Format(Format::SparseIdxVal, e.format));
    }
    if acc.len() != e.d {
        return Err(WireError::Truncated);
    }
    let mut r = BitReader::new(&e.bytes);
    let count = r.read_u32().ok_or(WireError::Truncated)? as usize;
    if count > e.d {
        return Err(WireError::Malformed);
    }
    if (e.bytes.len() as u64) * 8 < 32 + 64 * count as u64 {
        return Err(WireError::Truncated);
    }
    for _ in 0..count {
        let i = r.read_u32().ok_or(WireError::Truncated)? as usize;
        let x = r.read_f32().ok_or(WireError::Truncated)?;
        if i >= e.d || !x.is_finite() {
            return Err(WireError::Malformed);
        }
        acc[i] += x;
    }
    Ok(())
}

// ------------------------------------------------------------- ternary

/// TernGrad encoding: one 32-bit scale + 2 bits/coordinate
/// (00 = 0, 01 = +m, 10 = −m), into a caller-owned frame.
// detlint: hot
pub fn encode_ternary_into(v: &[f32], out: &mut Encoded) {
    let m = crate::tensor::norm_inf(v) as f32;
    let mut w = BitWriter::with_buf(std::mem::take(&mut out.bytes));
    w.reserve_bits(32 + 2 * v.len() as u64);
    w.push_f32(m);
    for x in v {
        let code: u32 = if *x == 0.0 {
            0
        } else if *x > 0.0 {
            1
        } else {
            2
        };
        w.push_bits(code, 2);
    }
    let (bytes, bits) = w.into_bytes();
    out.bytes = bytes;
    out.bits = bits;
    out.format = Format::Ternary;
    out.d = v.len();
    out.shard = None;
}

/// Allocating wrapper around [`encode_ternary_into`].
pub fn encode_ternary(v: &[f32]) -> Encoded {
    let mut e = Encoded::recycled(Vec::new());
    encode_ternary_into(v, &mut e);
    e
}

pub fn decode_ternary(e: &Encoded) -> Result<Vec<f32>, WireError> {
    if e.format != Format::Ternary {
        return Err(WireError::Format(Format::Ternary, e.format));
    }
    // a valid frame is exactly 32 + 2d bits; reject short payloads before
    // allocating the d-sized output
    if (e.bytes.len() as u64) * 8 < 32 + 2 * e.d as u64 {
        return Err(WireError::Truncated);
    }
    let mut r = BitReader::new(&e.bytes);
    let m = r.read_f32().ok_or(WireError::Truncated)?;
    if !m.is_finite() {
        return Err(WireError::Malformed);
    }
    let mut out = Vec::with_capacity(e.d);
    for _ in 0..e.d {
        let code = r.read_bits(2).ok_or(WireError::Truncated)?;
        out.push(match code {
            0 => 0.0,
            1 => m,
            _ => -m,
        });
    }
    Ok(out)
}

/// Decode ternary straight into a sum accumulator (fused leader hot path).
// detlint: hot
pub fn decode_ternary_add(e: &Encoded, acc: &mut [f32]) -> Result<(), WireError> {
    if e.format != Format::Ternary {
        return Err(WireError::Format(Format::Ternary, e.format));
    }
    if acc.len() != e.d {
        return Err(WireError::Truncated);
    }
    let mut r = BitReader::new(&e.bytes);
    let m = r.read_f32().ok_or(WireError::Truncated)?;
    if !m.is_finite() {
        return Err(WireError::Malformed);
    }
    for a in acc.iter_mut() {
        let code = r.read_bits(2).ok_or(WireError::Truncated)?;
        match code {
            0 => {}
            1 => *a += m,
            _ => *a -= m,
        }
    }
    Ok(())
}

// ---------------------------------------------------------------- qsgd

/// Reconstruct the QSGD level integer of a quantized coordinate. The
/// quantizer stored `sign · norm · l / s`; dividing back out recovers `l`
/// exactly (the accumulated rounding error is ~2⁻²² relative, far below
/// the 0.5 needed to flip the nearest integer for `s ≤ 255`).
#[inline]
fn qsgd_level(x: f32, norm: f32, s: u32) -> u32 {
    if x == 0.0 || norm == 0.0 {
        0
    } else {
        ((x.abs() / norm * s as f32).round() as u32).min(s)
    }
}

/// Number of bits in the Elias-gamma code of `x` (= 2⌊log₂ x⌋ + 1).
#[inline]
fn elias_gamma_bits(x: u64) -> u64 {
    debug_assert!(x >= 1);
    2 * (63 - u64::from(x.leading_zeros())) + 1
}

/// QSGD wire format (the Elias-coded scheme of Alistarh et al. 2017):
/// one f32 ℓ₂-norm + one u8 level count `s`, then per coordinate the
/// Elias-gamma code of `level + 1` followed by a single sign bit for
/// non-zero levels. Gaussian-ish gradients have mostly level-0 coordinates
/// (1 bit each), so the frame is far below the dense 32 bits/coordinate —
/// exactly the regime where QSGD claims its communication advantage.
///
/// `v` must be a QSGD-quantized vector and `norm` the exact f32 norm the
/// quantizer used (`tensor::norm2(p) as f32` of the *pre-quantization*
/// vector): levels then reconstruct exactly and [`decode_qsgd`] is
/// bit-faithful to `v`. Into-variant: the frame's byte buffer is reused,
/// reserved up front at the per-coordinate worst case
/// (`γ(levels + 1) + 1` bits) so the encode never reallocates mid-stream.
// detlint: hot
pub fn encode_qsgd_into(v: &[f32], norm: f32, levels: u32, out: &mut Encoded) {
    assert!(
        (1..=u8::MAX as u32).contains(&levels),
        "qsgd level count must fit a u8"
    );
    let mut w = BitWriter::with_buf(std::mem::take(&mut out.bytes));
    let worst_per_coord = elias_gamma_bits(u64::from(levels) + 1) + 1;
    w.reserve_bits(40 + v.len() as u64 * worst_per_coord);
    w.push_f32(norm);
    w.push_bits(levels, 8);
    for x in v {
        let l = qsgd_level(*x, norm, levels);
        w.push_elias_gamma(u64::from(l) + 1);
        if l > 0 {
            w.push_bit(*x < 0.0);
        }
    }
    let (bytes, bits) = w.into_bytes();
    out.bytes = bytes;
    out.bits = bits;
    out.format = Format::Qsgd;
    out.d = v.len();
    out.shard = None;
}

/// Allocating wrapper around [`encode_qsgd_into`].
pub fn encode_qsgd(v: &[f32], norm: f32, levels: u32) -> Encoded {
    let mut e = Encoded::recycled(Vec::new());
    encode_qsgd_into(v, norm, levels, &mut e);
    e
}

/// Exact wire size in bits of [`encode_qsgd`] for this vector, computed
/// without building the frame. Guaranteed (and tested) to equal the
/// encoder's actual `bit_len`.
pub fn qsgd_wire_bits(v: &[f32], norm: f32, levels: u32) -> u64 {
    let mut bits = 32 + 8u64;
    for x in v {
        let l = qsgd_level(*x, norm, levels);
        bits += elias_gamma_bits(u64::from(l) + 1) + u64::from(l > 0);
    }
    bits
}

/// Parse + validate the QSGD frame header; returns (norm, levels, reader
/// positioned at the level stream).
fn qsgd_header(e: &Encoded) -> Result<(f32, u32, BitReader<'_>), WireError> {
    if e.format != Format::Qsgd {
        return Err(WireError::Format(Format::Qsgd, e.format));
    }
    let mut r = BitReader::new(&e.bytes);
    let norm = r.read_f32().ok_or(WireError::Truncated)?;
    let s = r.read_bits(8).ok_or(WireError::Truncated)?;
    // s = 0 divides by zero downstream; a non-finite norm poisons the
    // aggregate — both are frame corruptions, never honest encodings
    if s == 0 || !norm.is_finite() {
        return Err(WireError::Malformed);
    }
    // every coordinate costs at least one bit (γ(1)), so a valid frame
    // has at least 40 + d bits — reject short payloads up front
    if (e.bytes.len() as u64) * 8 < 40 + e.d as u64 {
        return Err(WireError::Truncated);
    }
    Ok((norm, s, r))
}

/// Decode a QSGD frame to the dense quantized vector. Reconstruction uses
/// the quantizer's exact expression order (`±(norm · l) / s`), so the
/// output is bit-identical to the vector that was encoded.
pub fn decode_qsgd(e: &Encoded) -> Result<Vec<f32>, WireError> {
    let (norm, s, mut r) = qsgd_header(e)?;
    let s_f = s as f32;
    let mut out = vec![0.0f32; e.d];
    for o in out.iter_mut() {
        let l = r.read_elias_gamma().ok_or(WireError::Truncated)? - 1;
        if l > u64::from(s) {
            return Err(WireError::Malformed);
        }
        if l > 0 {
            let mag = norm * l as f32 / s_f;
            *o = if r.read_bit().ok_or(WireError::Truncated)? {
                -mag
            } else {
                mag
            };
        }
    }
    Ok(out)
}

/// Decode a QSGD frame straight into a sum accumulator: level-0
/// coordinates (the vast majority) cost one bit-read and no write. The
/// throughput win over the historical path comes from the windowed
/// [`BitReader`]: the gamma zero-run is one `trailing_zeros` and the
/// suffix one word read, instead of a per-bit loop.
// detlint: hot
pub fn decode_qsgd_add(e: &Encoded, acc: &mut [f32]) -> Result<(), WireError> {
    let (norm, s, mut r) = qsgd_header(e)?;
    if acc.len() != e.d {
        return Err(WireError::Truncated);
    }
    let s_f = s as f32;
    for a in acc.iter_mut() {
        let l = r.read_elias_gamma().ok_or(WireError::Truncated)? - 1;
        if l > u64::from(s) {
            return Err(WireError::Malformed);
        }
        if l > 0 {
            let mag = norm * l as f32 / s_f;
            if r.read_bit().ok_or(WireError::Truncated)? {
                *a -= mag;
            } else {
                *a += mag;
            }
        }
    }
    Ok(())
}

/// Decode any payload format to a dense vector.
pub fn decode_any(e: &Encoded) -> Result<Vec<f32>, WireError> {
    match e.format {
        Format::DenseF32 => decode_dense(e),
        Format::SignScaled => decode_scaled_sign(e),
        Format::SparseIdxVal => decode_sparse(e),
        Format::Ternary => decode_ternary(e),
        Format::Qsgd => decode_qsgd(e),
    }
}

/// Decode any payload straight into a sum accumulator — the leader's fused
/// aggregation path: one partial-sum buffer instead of a dense `Vec<f32>`
/// per worker frame.
pub fn decode_any_add(e: &Encoded, acc: &mut [f32]) -> Result<(), WireError> {
    match e.format {
        Format::DenseF32 => decode_dense_add(e, acc),
        Format::SignScaled => decode_scaled_sign_add(e, acc),
        Format::SparseIdxVal => decode_sparse_add(e, acc),
        Format::Ternary => decode_ternary_add(e, acc),
        Format::Qsgd => decode_qsgd_add(e, acc),
    }
}

/// Compression ratio of an encoding vs dense f32.
pub fn compression_ratio(e: &Encoded) -> f64 {
    (32.0 * e.d as f64) / e.bits as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor, Qsgd, ScaledSign, TernGrad, TopK};
    use crate::propcheck::{self, VecF32};
    use crate::util::Pcg64;

    #[test]
    fn bitio_roundtrip() {
        let mut w = BitWriter::new();
        w.push_bits(0b1011, 4);
        w.push_f32(-1.5);
        w.push_u32(12345);
        w.push_bit(true);
        let (bytes, bits) = w.into_bytes();
        assert_eq!(bits, 4 + 32 + 32 + 1);
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(4), Some(0b1011));
        assert_eq!(r.read_f32(), Some(-1.5));
        assert_eq!(r.read_u32(), Some(12345));
        assert_eq!(r.read_bit(), Some(true));
    }

    #[test]
    fn prop_dense_roundtrip() {
        propcheck::check(&VecF32::new(0, 200), |v| {
            decode_dense(&encode_dense(v)).unwrap() == *v
        });
    }

    #[test]
    fn prop_scaled_sign_wire_matches_compressor() {
        // decode(encode(p)) equals ScaledSign::compress(p) on zero-free
        // vectors (gaussian => zero-free a.s.).
        propcheck::check(&VecF32::new(1, 300), |p| {
            if p.iter().any(|x| *x == 0.0) {
                return true;
            }
            let e = encode_scaled_sign(p);
            assert_eq!(e.bits, p.len() as u64 + 32);
            let dec = decode_scaled_sign(&e).unwrap();
            let mut rng = Pcg64::seeded(0);
            let direct = ScaledSign.compress_vec(p, &mut rng);
            dec.iter().zip(&direct).all(|(a, b)| a == b)
        });
    }

    #[test]
    fn scaled_sign_zero_encodes_positive() {
        let p = [0.0f32, -1.0, 1.0];
        let dec = decode_scaled_sign(&encode_scaled_sign(&p)).unwrap();
        let scale = 2.0 / 3.0;
        assert!((dec[0] - scale).abs() < 1e-6); // documented zero behaviour
        assert!((dec[1] + scale).abs() < 1e-6);
        assert!((dec[2] - scale).abs() < 1e-6);
    }

    #[test]
    fn decode_add_accumulates() {
        let p = [1.0f32, -2.0, 3.0, -4.0];
        let e = encode_scaled_sign(&p);
        let mut acc = vec![10.0f32; 4];
        decode_scaled_sign_add(&e, &mut acc).unwrap();
        let dec = decode_scaled_sign(&e).unwrap();
        for i in 0..4 {
            assert!((acc[i] - (10.0 + dec[i])).abs() < 1e-6);
        }
    }

    #[test]
    fn prop_sparse_roundtrip_topk() {
        propcheck::check(&VecF32::new(4, 300), |p| {
            let mut rng = Pcg64::seeded(1);
            let delta = TopK::count((p.len() / 4).max(1)).compress_vec(p, &mut rng);
            let e = encode_sparse(&delta);
            decode_sparse(&e).unwrap() == delta
        });
    }

    #[test]
    fn prop_ternary_roundtrip() {
        propcheck::check(&VecF32::new(1, 200), |p| {
            let mut rng = Pcg64::seeded(2);
            let t = TernGrad.compress_vec(p, &mut rng);
            let e = encode_ternary(&t);
            assert_eq!(e.bits, 2 * p.len() as u64 + 32);
            let dec = decode_ternary(&e).unwrap();
            dec.iter().zip(&t).all(|(a, b)| (a - b).abs() < 1e-6)
        });
    }

    #[test]
    fn compression_ratios() {
        let d = 100_000;
        let mut rng = Pcg64::seeded(3);
        let mut p = vec![0.0f32; d];
        rng.fill_normal(&mut p, 0.0, 1.0);
        let sign = encode_scaled_sign(&p);
        let ratio = compression_ratio(&sign);
        // d*32 / (d + 32) -> just under 32x for a single tensor
        assert!(ratio > 31.9 && ratio < 32.0, "ratio={ratio}");
        let dense = encode_dense(&p);
        assert!((compression_ratio(&dense) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn format_mismatch_rejected() {
        let p = [1.0f32, 2.0];
        let e = encode_dense(&p);
        assert!(matches!(
            decode_scaled_sign(&e),
            Err(WireError::Format(..))
        ));
    }

    #[test]
    fn truncated_rejected() {
        let p = [1.0f32; 64];
        let mut e = encode_scaled_sign(&p);
        e.bytes.truncate(4);
        assert!(matches!(decode_scaled_sign(&e), Err(WireError::Truncated)));
    }

    /// Mixed push_bit / push_bits / push_bits64 sequences at non-byte-
    /// aligned cursors round-trip exactly (regression guard for the
    /// aligned fast paths taking over mid-stream).
    #[test]
    fn prop_bitio_roundtrip_unaligned_cursors() {
        use crate::propcheck::UsizeRange;
        propcheck::check_with(
            &propcheck::Config {
                cases: 200,
                ..Default::default()
            },
            &UsizeRange(1, 10_000),
            |&seed| {
                let mut rng = Pcg64::seeded(seed as u64);
                // Script a random mix of writes, remember (value, width).
                let mut script: Vec<(u64, u32)> = Vec::new();
                let mut w = BitWriter::new();
                for _ in 0..40 {
                    match rng.below(3) {
                        0 => {
                            let bit = rng.next_u32() & 1;
                            w.push_bit(bit == 1);
                            script.push((bit as u64, 1));
                        }
                        1 => {
                            let n = 1 + rng.below(32) as u32;
                            let v = rng.next_u32() & (u32::MAX >> (32 - n));
                            w.push_bits(v, n);
                            script.push((v as u64, n));
                        }
                        _ => {
                            let n = 1 + rng.below(64) as u32;
                            let v = rng.next_u64() & (u64::MAX >> (64 - n));
                            w.push_bits64(v, n);
                            script.push((v, n));
                        }
                    }
                }
                let expect_bits: u64 = script.iter().map(|(_, n)| *n as u64).sum();
                let (bytes, bits) = w.into_bytes();
                if bits != expect_bits {
                    return false;
                }
                let mut r = BitReader::new(&bytes);
                script.iter().all(|&(v, n)| match n {
                    1 => r.read_bit() == Some(v == 1),
                    n if n <= 32 && v <= u32::MAX as u64 => {
                        // read through the 64-bit path half the time to
                        // cross-check both readers
                        if n % 2 == 0 {
                            r.read_bits(n) == Some(v as u32)
                        } else {
                            r.read_bits64(n) == Some(v)
                        }
                    }
                    _ => r.read_bits64(n) == Some(v),
                })
            },
        );
    }

    /// Elias-gamma round-trips exact values at deliberately unaligned
    /// cursors (interleaved single bits shift every code off byte
    /// boundaries), and its bit cost matches the analytic 2⌊log₂x⌋+1.
    #[test]
    fn prop_elias_gamma_roundtrip_unaligned() {
        use crate::propcheck::UsizeRange;
        propcheck::check_with(
            &propcheck::Config {
                cases: 200,
                ..Default::default()
            },
            &UsizeRange(1, 1_000_000),
            |&seed| {
                let mut rng = Pcg64::seeded(seed as u64);
                let mut script: Vec<u64> = Vec::new();
                let mut w = BitWriter::new();
                for _ in 0..50 {
                    // skew small (the QSGD regime) but cover large too
                    let x: u64 = match rng.below(4) {
                        0 => 1 + rng.below(3) as u64,
                        1 => 1 + rng.below(64) as u64,
                        2 => 1 + rng.below(1 << 20) as u64,
                        _ => 1 + rng.next_u64() % (1 << 40),
                    };
                    let before = w.bit_len();
                    w.push_elias_gamma(x);
                    if w.bit_len() - before != elias_gamma_bits(x) {
                        return false;
                    }
                    // misalign the cursor between codes
                    let pad = rng.next_u32() & 1 == 1;
                    w.push_bit(pad);
                    script.push(x);
                    script.push(u64::from(pad));
                }
                let (bytes, _) = w.into_bytes();
                let mut r = BitReader::new(&bytes);
                script.chunks(2).all(|pair| {
                    r.read_elias_gamma() == Some(pair[0])
                        && r.read_bit() == Some(pair[1] == 1)
                })
            },
        );
    }

    #[test]
    fn elias_gamma_known_codewords() {
        // gamma(1) = "1", gamma(2) = "010", gamma(5) = "00101" (MSB first)
        let mut w = BitWriter::new();
        w.push_elias_gamma(1);
        w.push_elias_gamma(2);
        w.push_elias_gamma(5);
        let (bytes, bits) = w.into_bytes();
        assert_eq!(bits, 1 + 3 + 5);
        let expected_bits = [1, 0, 1, 0, 0, 0, 1, 0, 1]; // LSB-first stream
        for (i, want) in expected_bits.iter().enumerate() {
            assert_eq!((bytes[i / 8] >> (i % 8)) & 1, *want, "bit {i}");
        }
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_elias_gamma(), Some(1));
        assert_eq!(r.read_elias_gamma(), Some(2));
        assert_eq!(r.read_elias_gamma(), Some(5));
    }

    /// QSGD frames round-trip bit-exactly at every byte-alignment class
    /// (ragged d) and level count s ∈ {1, 4, 16}; `qsgd_wire_bits` always
    /// equals the encoder's actual bit length; decode_add fuses correctly.
    #[test]
    fn qsgd_roundtrip_all_alignments_and_levels() {
        let mut rng = Pcg64::seeded(11);
        for s in [1u32, 4, 16] {
            let q = Qsgd::new(s);
            for d in [1usize, 2, 7, 8, 9, 63, 64, 65, 127, 129, 200, 1000] {
                let mut p = vec![0.0f32; d];
                rng.fill_normal(&mut p, 0.0, 1.0);
                let v = q.compress_vec(&p, &mut Pcg64::seeded(d as u64));
                let norm = crate::tensor::norm2(&p) as f32;
                let e = encode_qsgd(&v, norm, s);
                assert_eq!(e.format, Format::Qsgd);
                assert_eq!(e.d, d);
                assert_eq!(
                    e.bits,
                    qsgd_wire_bits(&v, norm, s),
                    "size formula drifted from encoder at d={d} s={s}"
                );
                let dec = decode_qsgd(&e).unwrap();
                for i in 0..d {
                    assert_eq!(dec[i], v[i], "d={d} s={s} i={i}");
                }
                let mut acc = vec![1.5f32; d];
                decode_qsgd_add(&e, &mut acc).unwrap();
                for i in 0..d {
                    assert!((acc[i] - (1.5 + v[i])).abs() < 1e-6, "d={d} s={s} i={i}");
                }
                // decode_any dispatches to the qsgd decoder
                assert_eq!(decode_any(&e).unwrap(), dec);
            }
        }
    }

    /// Property test: on random gaussian inputs the analytic size formula
    /// equals the encoder exactly, for every levels setting.
    #[test]
    fn prop_qsgd_wire_bits_matches_encoder() {
        propcheck::check(&VecF32::new(1, 400), |p| {
            for s in [1u32, 4, 16] {
                let v = Qsgd::new(s).compress_vec(p, &mut Pcg64::seeded(9));
                let norm = crate::tensor::norm2(p) as f32;
                let e = encode_qsgd(&v, norm, s);
                if e.bits != qsgd_wire_bits(&v, norm, s) {
                    return false;
                }
                // frames are never wastefully padded beyond the last byte
                if e.bytes.len() as u64 != e.bits.div_ceil(8) {
                    return false;
                }
            }
            true
        });
    }

    /// The acceptance bar from the PR issue: at s=1 and d=65536 the QSGD
    /// frame must be at most a quarter of the dense f32 payload. (It is in
    /// fact ~1 bit/coordinate ≈ 1/32 of dense; 1/4 leaves slack for
    /// adversarial level distributions.)
    #[test]
    fn qsgd_frame_quarter_of_dense_at_s1() {
        let d = 65_536;
        let mut rng = Pcg64::seeded(13);
        let mut p = vec![0.0f32; d];
        rng.fill_normal(&mut p, 0.0, 1.0);
        let v = Qsgd::new(1).compress_vec(&p, &mut rng);
        let norm = crate::tensor::norm2(&p) as f32;
        let e = encode_qsgd(&v, norm, 1);
        let dense = encode_dense(&v);
        assert!(
            e.bytes.len() * 4 <= dense.bytes.len(),
            "qsgd frame {} bytes vs dense {} bytes",
            e.bytes.len(),
            dense.bytes.len()
        );
        assert!(e.bits * 4 <= dense.bits);
        // and it still decodes exactly
        let dec = decode_qsgd(&e).unwrap();
        for i in 0..d {
            assert_eq!(dec[i], v[i]);
        }
    }

    #[test]
    fn qsgd_zero_vector_and_degenerate_frames() {
        // all-zero vector: norm 0, every level 0, 1 bit per coordinate
        let v = vec![0.0f32; 100];
        let e = encode_qsgd(&v, 0.0, 4);
        assert_eq!(e.bits, 32 + 8 + 100);
        assert_eq!(decode_qsgd(&e).unwrap(), v);
        // truncation rejected
        let mut t = e.clone();
        t.bytes.truncate(4);
        assert!(matches!(decode_qsgd(&t), Err(WireError::Truncated)));
        // format mismatch rejected
        let dense = encode_dense(&v);
        assert!(matches!(decode_qsgd(&dense), Err(WireError::Format(..))));
        let mut acc = vec![0.0f32; 100];
        assert!(matches!(
            decode_qsgd_add(&dense, &mut acc),
            Err(WireError::Format(..))
        ));
    }

    /// Every fused `decode_*_add` matches decode-then-add for its format.
    #[test]
    fn fused_add_decoders_match_decode_then_add() {
        let d = 257; // ragged on purpose
        let mut rng = Pcg64::seeded(17);
        let mut p = vec![0.0f32; d];
        rng.fill_normal(&mut p, 0.0, 1.0);
        let sparse_v = TopK::count(d / 4).compress_vec(&p, &mut Pcg64::seeded(1));
        let tern_v = TernGrad.compress_vec(&p, &mut Pcg64::seeded(2));
        let qsgd_v = Qsgd::new(4).compress_vec(&p, &mut Pcg64::seeded(3));
        let norm = crate::tensor::norm2(&p) as f32;
        let frames = [
            encode_dense(&p),
            encode_scaled_sign(&p),
            encode_sparse(&sparse_v),
            encode_ternary(&tern_v),
            encode_qsgd(&qsgd_v, norm, 4),
        ];
        for e in &frames {
            let dec = decode_any(e).unwrap();
            let mut acc: Vec<f32> = (0..d).map(|i| (i as f32 * 0.13).cos()).collect();
            let mut want = acc.clone();
            decode_any_add(e, &mut acc).unwrap();
            for (w, x) in want.iter_mut().zip(&dec) {
                *w += x;
            }
            for i in 0..d {
                assert!(
                    (acc[i] - want[i]).abs() < 1e-6,
                    "{:?} i={i}: {} vs {}",
                    e.format,
                    acc[i],
                    want[i]
                );
            }
        }
    }

    /// The shard tag charges exactly `SHARD_TAG_BITS` on top of the payload
    /// and leaves the payload bytes (and hence the decode) untouched.
    #[test]
    fn shard_tag_costs_exactly_its_header() {
        let p = [1.0f32, -2.0, 3.0];
        let plain = encode_scaled_sign(&p);
        let tagged = encode_scaled_sign(&p).with_shard(3, 128);
        assert_eq!(tagged.bits, plain.bits + SHARD_TAG_BITS);
        assert_eq!(tagged.bytes, plain.bytes);
        assert_eq!(tagged.shard, Some(ShardTag { shard: 3, start: 128 }));
        assert_eq!(
            decode_scaled_sign(&tagged).unwrap(),
            decode_scaled_sign(&plain).unwrap()
        );
        assert!(plain.shard.is_none());
    }

    /// The word-packed sign codec round-trips at every alignment class:
    /// d spanning multiples of 64, multiples of 8, and ragged tails.
    #[test]
    fn packed_sign_roundtrip_all_alignments() {
        let mut rng = Pcg64::seeded(7);
        for d in [1, 2, 7, 8, 9, 63, 64, 65, 127, 128, 129, 200, 1000] {
            let mut p = vec![0.0f32; d];
            rng.fill_normal(&mut p, 0.0, 1.0);
            let e = encode_scaled_sign(&p);
            assert_eq!(e.bits, d as u64 + 32);
            assert_eq!(e.bytes.len(), 4 + d.div_ceil(8));
            let scale = ScaledSign::scale(&p);
            let dec = decode_scaled_sign(&e).unwrap();
            let mut acc = vec![1.5f32; d];
            decode_scaled_sign_add(&e, &mut acc).unwrap();
            for i in 0..d {
                let want = if p[i] >= 0.0 { scale } else { -scale };
                assert_eq!(dec[i], want, "d={d} i={i}");
                assert!((acc[i] - (1.5 + want)).abs() < 1e-6, "d={d} i={i}");
            }
        }
    }

    /// Reference bit-pusher replaying the historical per-bit writer: one
    /// bit at a time, LSB-first within each byte. The golden tests build
    /// expected frames through this independent implementation so the
    /// word-based [`BitWriter`] can never silently drift from the
    /// documented stream layout.
    struct RefBits {
        bytes: Vec<u8>,
        bits: u64,
    }

    impl RefBits {
        fn new() -> Self {
            RefBits {
                bytes: Vec::new(),
                bits: 0,
            }
        }

        fn bit(&mut self, b: bool) {
            let idx = (self.bits / 8) as usize;
            if idx == self.bytes.len() {
                self.bytes.push(0);
            }
            if b {
                self.bytes[idx] |= 1 << (self.bits % 8);
            }
            self.bits += 1;
        }

        fn bits_lsb(&mut self, v: u64, n: u32) {
            for i in 0..n {
                self.bit((v >> i) & 1 == 1);
            }
        }

        fn f32(&mut self, v: f32) {
            self.bits_lsb(u64::from(v.to_bits()), 32);
        }

        fn gamma(&mut self, x: u64) {
            let nb = 64 - x.leading_zeros();
            for _ in 0..nb - 1 {
                self.bit(false);
            }
            for i in (0..nb).rev() {
                self.bit((x >> i) & 1 == 1);
            }
        }
    }

    /// The word-based writer is bit-for-bit identical to the per-bit
    /// reference on random push scripts (bits, multi-bit words, gamma
    /// codes, at every alignment).
    #[test]
    fn prop_word_writer_matches_reference() {
        use crate::propcheck::UsizeRange;
        propcheck::check_with(
            &propcheck::Config {
                cases: 300,
                ..Default::default()
            },
            &UsizeRange(1, 100_000),
            |&seed| {
                let mut rng = Pcg64::seeded(seed as u64);
                let mut w = BitWriter::new();
                let mut r = RefBits::new();
                for _ in 0..60 {
                    match rng.below(4) {
                        0 => {
                            let b = rng.next_u32() & 1 == 1;
                            w.push_bit(b);
                            r.bit(b);
                        }
                        1 => {
                            let n = 1 + rng.below(32) as u32;
                            let v = rng.next_u32();
                            w.push_bits(v, n);
                            r.bits_lsb(u64::from(v) & (u64::MAX >> (64 - n)), n);
                        }
                        2 => {
                            let n = 1 + rng.below(64) as u32;
                            let v = rng.next_u64();
                            w.push_bits64(v, n);
                            r.bits_lsb(if n == 64 { v } else { v & ((1 << n) - 1) }, n);
                        }
                        _ => {
                            let x = 1 + rng.next_u64() % (1 << 40);
                            w.push_elias_gamma(x);
                            r.gamma(x);
                        }
                    }
                }
                let (bytes, bits) = w.into_bytes();
                bits == r.bits && bytes == r.bytes
            },
        );
    }

    /// Golden scaled-sign frame: scale = ‖p‖₁/d, then packed sign bits.
    /// Expected bytes constructed by hand — the on-wire layout is pinned.
    #[test]
    fn golden_scaled_sign_frame() {
        let p = [1.0f32, -2.0, 3.0, -4.0, 5.0]; // scale = 15/5 = 3.0
        let mut want = Vec::new();
        want.extend_from_slice(&3.0f32.to_bits().to_le_bytes());
        want.push(0b0001_0101); // signs +,-,+,-,+ LSB-first
        let e = encode_scaled_sign(&p);
        assert_eq!(e.bytes, want);
        assert_eq!(e.bits, 32 + 5);
        // into-variant produces the identical frame in a reused buffer
        let mut e2 = Encoded::recycled(Vec::with_capacity(64));
        encode_scaled_sign_into(&p, &mut e2);
        assert_eq!(e2.bytes, want);
        assert_eq!((e2.bits, e2.format, e2.d), (e.bits, e.format, e.d));
        assert!(e2.bytes.capacity() >= 64, "buffer was not reused");
    }

    /// Golden ternary frame: f32 scale then 2-bit codes, LSB-first.
    #[test]
    fn golden_ternary_frame() {
        let t = [0.0f32, 2.0, -2.0, 2.0]; // m = 2.0; codes 00,01,10,01
        let mut r = RefBits::new();
        r.f32(2.0);
        for code in [0u64, 1, 2, 1] {
            r.bits_lsb(code, 2);
        }
        let e = encode_ternary(&t);
        assert_eq!(e.bytes, r.bytes);
        assert_eq!(e.bits, r.bits);
        let mut e2 = Encoded::recycled(e.bytes.clone());
        encode_ternary_into(&t, &mut e2);
        assert_eq!(e2.bytes, e.bytes);
    }

    /// Golden sparse frame: u32 count + (u32 idx, f32 val) pairs.
    #[test]
    fn golden_sparse_frame() {
        let v = [0.0f32, 1.5, 0.0, -2.5];
        let mut r = RefBits::new();
        r.bits_lsb(2, 32); // count
        r.bits_lsb(1, 32);
        r.f32(1.5);
        r.bits_lsb(3, 32);
        r.f32(-2.5);
        let e = encode_sparse(&v);
        assert_eq!(e.bytes, r.bytes);
        assert_eq!(e.bits, r.bits);
        let mut e2 = Encoded::recycled(Vec::new());
        encode_sparse_into(&v, &mut e2);
        assert_eq!(e2.bytes, e.bytes);
        assert_eq!(e2.bits, e.bits);
    }

    /// Golden QSGD frame: f32 norm, u8 level count, then per coordinate
    /// γ(level + 1) and a sign bit for non-zero levels. Levels chosen so
    /// the quantizer arithmetic is exact.
    #[test]
    fn golden_qsgd_frame() {
        let norm = 2.0f32;
        let s = 4u32;
        // levels: 0, 1 (0.5/2*4), 2 (1/2*4), 4 (2/2*4), 0
        let v = [0.0f32, 0.5, -1.0, 2.0, 0.0];
        let mut r = RefBits::new();
        r.f32(norm);
        r.bits_lsb(u64::from(s), 8);
        r.gamma(1); // level 0
        r.gamma(2); // level 1
        r.bit(false); // sign +
        r.gamma(3); // level 2
        r.bit(true); // sign -
        r.gamma(5); // level 4
        r.bit(false); // sign +
        r.gamma(1); // level 0
        let e = encode_qsgd(&v, norm, s);
        assert_eq!(e.bytes, r.bytes);
        assert_eq!(e.bits, r.bits);
        assert_eq!(e.bits, qsgd_wire_bits(&v, norm, s));
        // decodes back to the exact quantized vector
        assert_eq!(decode_qsgd(&e).unwrap(), v);
        let mut e2 = Encoded::recycled(Vec::with_capacity(32));
        encode_qsgd_into(&v, norm, s, &mut e2);
        assert_eq!(e2.bytes, e.bytes);
        assert_eq!(e2.bits, e.bits);
    }

    /// Golden dense frame: raw little-endian f32s.
    #[test]
    fn golden_dense_frame() {
        let v = [1.0f32, -0.5];
        let mut want = Vec::new();
        want.extend_from_slice(&1.0f32.to_le_bytes());
        want.extend_from_slice(&(-0.5f32).to_le_bytes());
        let e = encode_dense(&v);
        assert_eq!(e.bytes, want);
        let mut e2 = Encoded::recycled(Vec::new());
        encode_dense_into(&v, &mut e2);
        assert_eq!(e2.bytes, want);
        assert_eq!(e2.bits, 64);
    }

    /// Run every decoder (dispatch, per-format, and fused-add) over a
    /// frame of arbitrary bytes. A clean decode must be `d`-sized and an
    /// error is fine — a panic is the bug this guards against.
    fn exercise_all_decoders(e: &Encoded) {
        if let Ok(v) = decode_any(e) {
            assert_eq!(v.len(), e.d, "{:?} decoded to the wrong length", e.format);
        }
        let mut acc = vec![0.0f32; e.d];
        let _ = decode_any_add(e, &mut acc);
        let _ = decode_dense(e);
        let _ = decode_scaled_sign(e);
        let _ = decode_sparse(e);
        let _ = decode_ternary(e);
        let _ = decode_qsgd(e);
        let _ = decode_dense_add(e, &mut acc);
        let _ = decode_scaled_sign_add(e, &mut acc);
        let _ = decode_sparse_add(e, &mut acc);
        let _ = decode_ternary_add(e, &mut acc);
        let _ = decode_qsgd_add(e, &mut acc);
    }

    /// Byzantine-input property: no `decode_*` path may panic on
    /// arbitrary bytes. Valid frames of every format are truncated at
    /// random byte boundaries, bit-flipped, and replaced wholesale with
    /// random bytes; every decoder must return Err or a clean d-sized
    /// decode. This is the wire half of the graceful-degradation
    /// contract the drivers rely on (docs/ROBUSTNESS.md).
    #[test]
    fn prop_decoders_never_panic_on_adversarial_bytes() {
        use crate::propcheck::UsizeRange;
        propcheck::check_with(
            &propcheck::Config {
                cases: 120,
                ..Default::default()
            },
            &UsizeRange(1, 1_000_000),
            |&seed| {
                let mut rng = Pcg64::seeded(seed as u64);
                let d = 1 + rng.below(300);
                let mut p = vec![0.0f32; d];
                rng.fill_normal(&mut p, 0.0, 1.0);
                let sparse_v =
                    TopK::count((d / 4).max(1)).compress_vec(&p, &mut Pcg64::seeded(1));
                let tern_v = TernGrad.compress_vec(&p, &mut Pcg64::seeded(2));
                let qsgd_v = Qsgd::new(4).compress_vec(&p, &mut Pcg64::seeded(3));
                let norm = crate::tensor::norm2(&p) as f32;
                let frames = [
                    encode_dense(&p),
                    encode_scaled_sign(&p),
                    encode_sparse(&sparse_v),
                    encode_ternary(&tern_v),
                    encode_qsgd(&qsgd_v, norm, 4),
                ];
                for e in &frames {
                    // truncated at a random byte boundary
                    let mut t = e.clone();
                    let keep = rng.below(t.bytes.len() + 1);
                    t.bytes.truncate(keep);
                    exercise_all_decoders(&t);
                    // one random bit flipped
                    let mut f = e.clone();
                    if !f.bytes.is_empty() {
                        let i = rng.below(f.bytes.len());
                        f.bytes[i] ^= 1 << rng.below(8);
                    }
                    exercise_all_decoders(&f);
                    // payload replaced with arbitrary bytes, random length
                    let mut g = e.clone();
                    let len = rng.below(2 * e.bytes.len().max(4));
                    g.bytes.clear();
                    g.bytes.extend((0..len).map(|_| rng.next_u32() as u8));
                    exercise_all_decoders(&g);
                }
                true
            },
        );
    }

    /// Every `encode_*_into` leaves the frame byte-identical to its
    /// allocating counterpart even when the recycled buffer held a larger
    /// stale frame (clearing, not just overwriting, is required).
    #[test]
    fn encode_into_clears_stale_buffers() {
        let mut rng = Pcg64::seeded(23);
        let mut p = vec![0.0f32; 97];
        rng.fill_normal(&mut p, 0.0, 1.0);
        let stale = vec![0xAAu8; 4096];
        let q = Qsgd::new(4).compress_vec(&p, &mut Pcg64::seeded(4));
        let norm = crate::tensor::norm2(&p) as f32;
        let topk = TopK::count(24).compress_vec(&p, &mut Pcg64::seeded(5));
        let tern = TernGrad.compress_vec(&p, &mut Pcg64::seeded(6));

        let mut e = Encoded::recycled(stale.clone());
        encode_scaled_sign_into(&p, &mut e);
        assert_eq!(e.bytes, encode_scaled_sign(&p).bytes);

        let mut e = Encoded::recycled(stale.clone());
        encode_dense_into(&p, &mut e);
        assert_eq!(e.bytes, encode_dense(&p).bytes);

        let mut e = Encoded::recycled(stale.clone());
        encode_sparse_into(&topk, &mut e);
        assert_eq!(e.bytes, encode_sparse(&topk).bytes);

        let mut e = Encoded::recycled(stale.clone());
        encode_ternary_into(&tern, &mut e);
        assert_eq!(e.bytes, encode_ternary(&tern).bytes);

        let mut e = Encoded::recycled(stale);
        encode_qsgd_into(&q, norm, 4, &mut e);
        assert_eq!(e.bytes, encode_qsgd(&q, norm, 4).bytes);
        assert!(e.shard.is_none());
    }

    // ----------------------------------------------- scalar reference path
    //
    // The historical per-bit reader and per-coordinate decoders, retained
    // verbatim as the bitwise-parity oracle for the windowed/vectorized
    // kernels. Slow on purpose: one bit (or one coordinate) at a time, no
    // word windows, no branchless lanes.

    /// The pre-windowing [`BitReader`]: a bare bit cursor over the byte
    /// slice, one shift-and-mask per bit.
    struct ScalarBitReader<'a> {
        bytes: &'a [u8],
        pos: u64,
    }

    impl<'a> ScalarBitReader<'a> {
        fn new(bytes: &'a [u8]) -> Self {
            ScalarBitReader { bytes, pos: 0 }
        }

        fn read_bit(&mut self) -> Option<bool> {
            let idx = (self.pos / 8) as usize;
            if idx >= self.bytes.len() {
                return None;
            }
            let bit = (self.bytes[idx] >> (self.pos % 8)) & 1;
            self.pos += 1;
            Some(bit == 1)
        }

        fn read_bits64(&mut self, n: u32) -> Option<u64> {
            let mut v = 0u64;
            for i in 0..n {
                v |= u64::from(self.read_bit()?) << i;
            }
            Some(v)
        }

        fn read_bits(&mut self, n: u32) -> Option<u32> {
            self.read_bits64(n).map(|v| v as u32)
        }

        fn read_f32(&mut self) -> Option<f32> {
            self.read_bits(32).map(f32::from_bits)
        }

        fn read_elias_gamma(&mut self) -> Option<u64> {
            let mut zeros = 0u32;
            while !self.read_bit()? {
                zeros += 1;
                if zeros > 63 {
                    return None;
                }
            }
            let mut x = 1u64;
            for _ in 0..zeros {
                x = (x << 1) | u64::from(self.read_bit()?);
            }
            Some(x)
        }
    }

    /// The windowed reader is call-for-call identical to the per-bit
    /// reference on random mixed read scripts over random write scripts —
    /// including the reads that run off the end of the stream (both
    /// readers expose the same byte-granularity truncation semantics).
    #[test]
    fn prop_windowed_reader_matches_scalar() {
        use crate::propcheck::UsizeRange;
        propcheck::check_with(
            &propcheck::Config {
                cases: 300,
                ..Default::default()
            },
            &UsizeRange(1, 100_000),
            |&seed| {
                let mut rng = Pcg64::seeded(seed as u64);
                let mut w = BitWriter::new();
                for _ in 0..rng.below(50) {
                    match rng.below(3) {
                        0 => w.push_bit(rng.next_u32() & 1 == 1),
                        1 => {
                            let n = 1 + rng.below(64) as u32;
                            w.push_bits64(rng.next_u64(), n);
                        }
                        _ => w.push_elias_gamma(1 + rng.next_u64() % (1 << 40)),
                    }
                }
                let (bytes, _) = w.into_bytes();
                let mut fast = BitReader::new(&bytes);
                let mut slow = ScalarBitReader::new(&bytes);
                // read with an unrelated random script: alignments, widths
                // and gamma probes all land at arbitrary cursor offsets,
                // and the tail read exercises end-of-stream behaviour.
                // Stop at the first None: decoders abandon a reader on
                // None, so post-failure cursor state is out of contract
                // (a >63-zero gamma probe may consume different amounts).
                for _ in 0..80 {
                    let (a, b) = match rng.below(4) {
                        0 => (
                            fast.read_bit().map(u64::from),
                            slow.read_bit().map(u64::from),
                        ),
                        1 => {
                            let n = rng.below(65) as u32;
                            (fast.read_bits64(n), slow.read_bits64(n))
                        }
                        2 => {
                            let n = rng.below(33) as u32;
                            (
                                fast.read_bits(n).map(u64::from),
                                slow.read_bits(n).map(u64::from),
                            )
                        }
                        _ => (fast.read_elias_gamma(), slow.read_elias_gamma()),
                    };
                    if a != b {
                        return false;
                    }
                    if a.is_none() {
                        break;
                    }
                }
                true
            },
        );
    }

    /// Scalar reference decode-accumulate for every wire format: the exact
    /// per-coordinate arithmetic of the vectorized kernels, driven bit by
    /// bit. Any divergence in value *or* in f32 add order shows up as a
    /// `to_bits` mismatch in the parity tests below.
    fn scalar_decode_add(e: &Encoded, acc: &mut [f32]) -> Result<(), WireError> {
        assert_eq!(acc.len(), e.d);
        match e.format {
            Format::DenseF32 => {
                if e.bytes.len() < e.d * 4 {
                    return Err(WireError::Truncated);
                }
                for (i, a) in acc.iter_mut().enumerate() {
                    let b = &e.bytes[i * 4..i * 4 + 4];
                    *a += f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
                }
            }
            Format::SignScaled => {
                let (scale, body) = sign_payload(e)?;
                for (i, a) in acc.iter_mut().enumerate() {
                    let bit = (body[i / 8] >> (i % 8)) & 1;
                    *a += if bit == 1 { scale } else { -scale };
                }
            }
            Format::SparseIdxVal => {
                let mut r = ScalarBitReader::new(&e.bytes);
                let count = r.read_bits(32).ok_or(WireError::Truncated)? as usize;
                if count > e.d {
                    return Err(WireError::Malformed);
                }
                for _ in 0..count {
                    let i = r.read_bits(32).ok_or(WireError::Truncated)? as usize;
                    let x = r.read_f32().ok_or(WireError::Truncated)?;
                    if i >= e.d || !x.is_finite() {
                        return Err(WireError::Malformed);
                    }
                    acc[i] += x;
                }
            }
            Format::Ternary => {
                let mut r = ScalarBitReader::new(&e.bytes);
                let m = r.read_f32().ok_or(WireError::Truncated)?;
                for a in acc.iter_mut() {
                    match r.read_bits(2).ok_or(WireError::Truncated)? {
                        0 => {}
                        1 => *a += m,
                        _ => *a -= m,
                    }
                }
            }
            Format::Qsgd => {
                let mut r = ScalarBitReader::new(&e.bytes);
                let norm = r.read_f32().ok_or(WireError::Truncated)?;
                let s = r.read_bits(8).ok_or(WireError::Truncated)?;
                let s_f = s as f32;
                for a in acc.iter_mut() {
                    let l = r.read_elias_gamma().ok_or(WireError::Truncated)? - 1;
                    if l > u64::from(s) {
                        return Err(WireError::Malformed);
                    }
                    if l > 0 {
                        let mag = norm * l as f32 / s_f;
                        if r.read_bit().ok_or(WireError::Truncated)? {
                            *a -= mag;
                        } else {
                            *a += mag;
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Build one valid frame of each format over a shared gaussian vector
    /// slice (seeded per call so shard slices get distinct content).
    fn frames_for(p: &[f32], seed: u64) -> [Encoded; Format::COUNT] {
        let d = p.len();
        let sparse_v = TopK::count((d / 4).max(1)).compress_vec(p, &mut Pcg64::seeded(seed));
        let tern_v = TernGrad.compress_vec(p, &mut Pcg64::seeded(seed + 1));
        let qsgd_v = Qsgd::new(4).compress_vec(p, &mut Pcg64::seeded(seed + 2));
        let norm = crate::tensor::norm2(p) as f32;
        [
            encode_dense(p),
            encode_scaled_sign(p),
            encode_sparse(&sparse_v),
            encode_ternary(&tern_v),
            encode_qsgd(&qsgd_v, norm, 4),
        ]
    }

    /// Tentpole parity bar: for every wire format and every alignment
    /// class d mod 64 ∈ {0, 1, 63}, the vectorized `decode_any_add` is
    /// **bitwise** identical (f32::to_bits per coordinate) to the scalar
    /// per-bit reference on a non-trivial accumulator.
    #[test]
    fn prop_vectorized_decode_add_matches_scalar() {
        let mut rng = Pcg64::seeded(31);
        for d in [1usize, 63, 64, 65, 127, 128, 191, 192] {
            let mut p = vec![0.0f32; d];
            rng.fill_normal(&mut p, 0.0, 1.0);
            for e in &frames_for(&p, d as u64) {
                let init: Vec<f32> = (0..d).map(|i| (i as f32 * 0.37).sin() * 3.0).collect();
                let mut fast = init.clone();
                let mut slow = init;
                decode_any_add(e, &mut fast).unwrap();
                scalar_decode_add(e, &mut slow).unwrap();
                for i in 0..d {
                    assert_eq!(
                        fast[i].to_bits(),
                        slow[i].to_bits(),
                        "{:?} d={d} i={i}: {} vs {}",
                        e.format,
                        fast[i],
                        slow[i]
                    );
                }
            }
        }
    }

    /// Sharded variant of the parity bar: slice the vector with a 4-way
    /// [`crate::collectives::ShardPlan`], encode each slice as a tagged
    /// frame (exactly what workers push), decode each into its coordinate
    /// range — still bitwise identical to the scalar reference. Shard
    /// boundaries land at ragged offsets, so the word kernels hit partial
    /// leading/trailing lanes.
    #[test]
    fn prop_vectorized_decode_add_matches_scalar_sharded() {
        use crate::collectives::ShardPlan;
        let mut rng = Pcg64::seeded(37);
        for d in [63usize, 64, 65, 191, 192] {
            let mut p = vec![0.0f32; d];
            rng.fill_normal(&mut p, 0.0, 1.0);
            for shards in [1usize, 4] {
                let plan = ShardPlan::new(d, shards);
                for fi in 0..Format::COUNT {
                    let init: Vec<f32> =
                        (0..d).map(|i| (i as f32 * 0.53).cos() * 2.0).collect();
                    let mut fast = init.clone();
                    let mut slow = init;
                    for s in 0..plan.num_shards() {
                        let r = plan.range(s);
                        let e = frames_for(&p[r.clone()], (d + s) as u64)[fi]
                            .clone()
                            .with_shard(s as u16, r.start as u32);
                        decode_any_add(&e, &mut fast[r.clone()]).unwrap();
                        scalar_decode_add(&e, &mut slow[r]).unwrap();
                    }
                    for i in 0..d {
                        assert_eq!(
                            fast[i].to_bits(),
                            slow[i].to_bits(),
                            "{:?} d={d} shards={shards} i={i}",
                            Format::ALL[fi]
                        );
                    }
                }
            }
        }
    }
}
