//! Sign-based compressors: the unscaled sign (SIGNSGD's operator, *not* a
//! δ-approximate compressor — the source of the paper's counterexamples)
//! and the scaled sign `C(v) = (‖v‖₁/d)·sign(v)` of Lemma 8, which is a
//! φ(v)-approximate compressor with φ(v) = ‖v‖₁²/(d‖v‖₂²).

use super::Compressor;
use crate::tensor;
use crate::util::Pcg64;

/// Unscaled sign: `C(v)_i = sign(v_i)` with sign(0) = 0.
///
/// Not a contraction — `‖sign(v) − v‖` can exceed `‖v‖` arbitrarily — which
/// is exactly why SIGNSGD diverges on the paper's counterexamples. Included
/// as the baseline the paper argues against.
pub struct Sign;

impl Compressor for Sign {
    fn name(&self) -> &'static str {
        "sign"
    }

    fn compress(&self, p: &[f32], out: &mut [f32], _rng: &mut Pcg64) {
        tensor::sign_into(p, out);
    }

    fn wire_bits(&self, d: usize) -> u64 {
        d as u64
    }
}

/// Scaled sign (Lemma 8): `C(v) = (‖v‖₁/d)·sign(v)`.
///
/// The magnitude information is kept through the single scale factor, making
/// this a density-approximate compressor and the operator inside
/// EF-SIGNSGD (Algorithm 1, line 5). Wire format: d sign bits + one 32-bit
/// scale (the paper's `d_i + 32` bits per layer).
pub struct ScaledSign;

impl ScaledSign {
    /// The scale ‖v‖₁/d.
    pub fn scale(v: &[f32]) -> f32 {
        if v.is_empty() {
            0.0
        } else {
            (tensor::norm1(v) / v.len() as f64) as f32
        }
    }
}

impl Compressor for ScaledSign {
    fn name(&self) -> &'static str {
        "scaled_sign"
    }

    fn compress(&self, p: &[f32], out: &mut [f32], _rng: &mut Pcg64) {
        let scale = Self::scale(p);
        for (o, v) in out.iter_mut().zip(p) {
            *o = if *v > 0.0 {
                scale
            } else if *v < 0.0 {
                -scale
            } else {
                0.0
            };
        }
    }

    fn wire_bits(&self, d: usize) -> u64 {
        d as u64 + 32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::measure_delta;
    use crate::propcheck::{self, VecF32};

    #[test]
    fn sign_semantics() {
        let p = [2.0, -0.5, 0.0, 1e-9];
        let mut rng = Pcg64::seeded(0);
        let out = Sign.compress_vec(&p, &mut rng);
        assert_eq!(out, vec![1.0, -1.0, 0.0, 1.0]);
    }

    #[test]
    fn scaled_sign_magnitudes() {
        let p = [3.0, -1.0, 0.0, 2.0];
        let mut rng = Pcg64::seeded(0);
        let out = ScaledSign.compress_vec(&p, &mut rng);
        let scale = 6.0 / 4.0;
        assert_eq!(out, vec![scale, -scale, 0.0, scale]);
    }

    #[test]
    fn scaled_sign_preserves_l1_norm_on_dense_vectors() {
        // For vectors with no zeros, ||C(v)||_1 = ||v||_1 exactly.
        let mut rng = Pcg64::seeded(1);
        let mut p = vec![0.0f32; 333];
        rng.fill_normal(&mut p, 0.0, 2.0);
        let out = ScaledSign.compress_vec(&p, &mut rng);
        let l1_in = tensor::norm1(&p);
        let l1_out = tensor::norm1(&out);
        assert!((l1_in - l1_out).abs() / l1_in < 1e-5);
    }

    #[test]
    fn prop_scaled_sign_delta_equals_density() {
        // The contraction factor of the scaled sign is *exactly* phi(v).
        propcheck::check(&VecF32::new(2, 400), |p| {
            let mut rng = Pcg64::seeded(2);
            let delta = measure_delta(&ScaledSign, p, &mut rng);
            let phi = tensor::density(p);
            (delta - phi).abs() < 1e-5
        });
    }

    #[test]
    fn prop_unscaled_sign_not_contractive_for_small_vectors() {
        // Exhibit the failure mode: for tiny-magnitude vectors the sign
        // *expands* the norm, violating Assumption A.
        let p = vec![1e-3f32; 16];
        let mut rng = Pcg64::seeded(3);
        let delta = measure_delta(&Sign, &p, &mut rng);
        assert!(delta < 0.0, "sign should not contract here, delta={delta}");
    }

    #[test]
    fn wire_bits_formula() {
        assert_eq!(Sign.wire_bits(1000), 1000);
        assert_eq!(ScaledSign.wire_bits(1000), 1032);
    }
}
