//! Random-k sparsification: keep k uniformly random coordinates, scaled by
//! d/k so the operator is **unbiased** (E[C(v)] = v). Without the scaling it
//! is a biased (k/d)-approximate compressor; we expose both via `scaled`.

use super::Compressor;
use crate::util::Pcg64;

pub struct RandomK {
    k: usize,
    /// If true (default), multiply kept coordinates by d/k (unbiased).
    scaled: bool,
}

impl RandomK {
    pub fn count(k: usize) -> Self {
        assert!(k >= 1);
        RandomK { k, scaled: true }
    }

    /// Biased variant: kept coordinates keep their value (a k/d-approximate
    /// compressor in expectation).
    pub fn biased(k: usize) -> Self {
        RandomK { k, scaled: false }
    }
}

impl Compressor for RandomK {
    fn name(&self) -> &'static str {
        if self.scaled {
            "randomk"
        } else {
            "randomk_biased"
        }
    }

    fn compress(&self, p: &[f32], out: &mut [f32], rng: &mut Pcg64) {
        let d = p.len();
        out.iter_mut().for_each(|v| *v = 0.0);
        if d == 0 {
            return;
        }
        let k = self.k.min(d);
        let idxs = rng.sample_indices(d, k);
        let scale = if self.scaled { d as f32 / k as f32 } else { 1.0 };
        for i in idxs {
            out[i] = p[i] * scale;
        }
    }

    fn wire_bits(&self, d: usize) -> u64 {
        // With a shared PRNG seed the indices need not be transmitted; we
        // still count them (conservative) plus the count header.
        let k = self.k.min(d) as u64;
        k * (32 + 32) + 32
    }

    fn unbiased(&self) -> bool {
        self.scaled
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_exactly_k() {
        let p: Vec<f32> = (1..=100).map(|i| i as f32).collect();
        let mut rng = Pcg64::seeded(0);
        let out = RandomK::count(10).compress_vec(&p, &mut rng);
        assert_eq!(out.iter().filter(|v| **v != 0.0).count(), 10);
    }

    #[test]
    fn scaling_factor_applied() {
        let p = vec![1.0f32; 50];
        let mut rng = Pcg64::seeded(1);
        let out = RandomK::count(5).compress_vec(&p, &mut rng);
        for v in out.iter().filter(|v| **v != 0.0) {
            assert!((*v - 10.0).abs() < 1e-6);
        }
    }

    #[test]
    fn biased_variant_keeps_values() {
        let p: Vec<f32> = (0..20).map(|i| i as f32).collect();
        let mut rng = Pcg64::seeded(2);
        let out = RandomK::biased(5).compress_vec(&p, &mut rng);
        for (o, v) in out.iter().zip(&p) {
            assert!(*o == 0.0 || *o == *v);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let p: Vec<f32> = (0..64).map(|i| (i as f32).sin()).collect();
        let a = RandomK::count(8).compress_vec(&p, &mut Pcg64::seeded(7));
        let b = RandomK::count(8).compress_vec(&p, &mut Pcg64::seeded(7));
        assert_eq!(a, b);
    }

    #[test]
    fn empirical_mean_is_unbiased() {
        let p: Vec<f32> = (0..32).map(|i| (i as f32 / 5.0).cos()).collect();
        let c = RandomK::count(8);
        let trials = 8000;
        let mut mean = vec![0.0f64; p.len()];
        for t in 0..trials {
            let out = c.compress_vec(&p, &mut Pcg64::seeded(t));
            for (m, o) in mean.iter_mut().zip(&out) {
                *m += *o as f64 / trials as f64;
            }
        }
        for (m, v) in mean.iter().zip(&p) {
            assert!((m - *v as f64).abs() < 0.1, "{m} vs {v}");
        }
    }
}
