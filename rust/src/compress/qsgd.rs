//! Unbiased stochastic quantizers: QSGD (Alistarh et al. 2017) and TernGrad
//! (Wen et al. 2017), plus the paper's Remark-5 wrapper `C(x) = U(x)/k`
//! that turns any unbiased U with `E‖U(x)‖² ≤ k‖x‖²` into a
//! (1/k)-approximate compressor suitable for error feedback.

use super::Compressor;
use crate::tensor;
use crate::util::Pcg64;

/// QSGD with `s` quantization levels: each coordinate is rounded
/// stochastically to one of `s` levels of `|v_i|/‖v‖₂`, keeping the sign.
/// Unbiased: E[Q(v)] = v.
pub struct Qsgd {
    levels: u32,
}

impl Qsgd {
    pub fn new(levels: u32) -> Self {
        assert!(levels >= 1);
        Qsgd { levels }
    }

    pub fn levels(&self) -> u32 {
        self.levels
    }

    /// The second-moment expansion factor k with E‖Q(v)‖² ≤ k‖v‖²:
    /// k = 1 + min(d/s², √d/s) (Alistarh et al., Lemma 3.1).
    pub fn expansion(&self, d: usize) -> f64 {
        let s = self.levels as f64;
        1.0 + (d as f64 / (s * s)).min((d as f64).sqrt() / s)
    }
}

impl Compressor for Qsgd {
    fn name(&self) -> &'static str {
        "qsgd"
    }

    fn compress(&self, p: &[f32], out: &mut [f32], rng: &mut Pcg64) {
        let norm = tensor::norm2(p) as f32;
        if norm == 0.0 {
            out.iter_mut().for_each(|v| *v = 0.0);
            return;
        }
        let s = self.levels as f32;
        for (o, v) in out.iter_mut().zip(p) {
            let r = v.abs() / norm * s; // in [0, s]
            let low = r.floor();
            let frac = r - low;
            let level = low + if rng.uniform() < frac as f64 { 1.0 } else { 0.0 };
            *o = v.signum() * norm * level / s;
        }
    }

    fn wire_bits(&self, d: usize) -> u64 {
        // Worst case of the Elias-gamma wire pack (`wire::encode_qsgd`):
        // every coordinate at the top level s costs γ(s+1) = 2⌊log₂(s+1)⌋+1
        // bits plus a sign bit, after a 32-bit norm + 8-bit level-count
        // header. Real frames are far smaller (mostly level 0 at 1 bit);
        // the fabric accounts the exact per-frame `Encoded::bits`, and
        // `wire::qsgd_wire_bits` gives the exact size for a given vector.
        let gamma_top = 2 * u64::from(31 - (self.levels + 1).leading_zeros()) + 1;
        (gamma_top + 1) * d as u64 + 32 + 8
    }

    fn unbiased(&self) -> bool {
        true
    }
}

/// TernGrad: stochastic ternarization to {-m, 0, +m} with m = max|v_i|.
/// Unbiased; 2 bits per coordinate + one scale.
pub struct TernGrad;

impl Compressor for TernGrad {
    fn name(&self) -> &'static str {
        "terngrad"
    }

    fn compress(&self, p: &[f32], out: &mut [f32], rng: &mut Pcg64) {
        let m = tensor::norm_inf(p) as f32;
        if m == 0.0 {
            out.iter_mut().for_each(|v| *v = 0.0);
            return;
        }
        for (o, v) in out.iter_mut().zip(p) {
            let prob = (v.abs() / m) as f64;
            *o = if rng.uniform() < prob { v.signum() * m } else { 0.0 };
        }
    }

    fn wire_bits(&self, d: usize) -> u64 {
        2 * d as u64 + 32
    }

    fn unbiased(&self) -> bool {
        true
    }
}

/// Remark 5: wrap an unbiased compressor `U` with expansion factor k as
/// `C(x) = U(x)/k`, a (1/k)-approximate compressor — this is what you feed
/// to error feedback to get the O(1/T)-only dependence on k.
pub struct ScaledUnbiased {
    pub inner: Box<dyn Compressor>,
    pub k: f64,
}

impl ScaledUnbiased {
    pub fn new(inner: Box<dyn Compressor>, k: f64) -> Self {
        assert!(k >= 1.0);
        ScaledUnbiased { inner, k }
    }
}

impl Compressor for ScaledUnbiased {
    fn name(&self) -> &'static str {
        "scaled_unbiased"
    }

    fn compress(&self, p: &[f32], out: &mut [f32], rng: &mut Pcg64) {
        self.inner.compress(p, out, rng);
        let inv = (1.0 / self.k) as f32;
        for o in out.iter_mut() {
            *o *= inv;
        }
    }

    fn wire_bits(&self, d: usize) -> u64 {
        self.inner.wire_bits(d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qsgd_levels_are_discrete() {
        let mut rng = Pcg64::seeded(0);
        let mut p = vec![0.0f32; 128];
        rng.fill_normal(&mut p, 0.0, 1.0);
        let s = 4;
        let out = Qsgd::new(s).compress_vec(&p, &mut rng);
        let norm = tensor::norm2(&p) as f32;
        for v in &out {
            let level = v.abs() / norm * s as f32;
            assert!((level - level.round()).abs() < 1e-4, "level {level}");
        }
    }

    #[test]
    fn qsgd_empirically_unbiased() {
        let p: Vec<f32> = (0..16).map(|i| ((i * 7) % 5) as f32 - 2.0).collect();
        let c = Qsgd::new(2);
        let trials = 20_000;
        let mut mean = vec![0.0f64; p.len()];
        for t in 0..trials {
            let out = c.compress_vec(&p, &mut Pcg64::seeded(t));
            for (m, o) in mean.iter_mut().zip(&out) {
                *m += *o as f64 / trials as f64;
            }
        }
        for (m, v) in mean.iter().zip(&p) {
            assert!((m - *v as f64).abs() < 0.06, "{m} vs {v}");
        }
    }

    #[test]
    fn qsgd_second_moment_within_expansion() {
        let mut rng = Pcg64::seeded(1);
        let mut p = vec![0.0f32; 256];
        rng.fill_normal(&mut p, 0.0, 1.0);
        let c = Qsgd::new(4);
        let k = c.expansion(p.len());
        let trials = 500;
        let mut sum = 0.0f64;
        for t in 0..trials {
            let out = c.compress_vec(&p, &mut Pcg64::seeded(t));
            sum += tensor::norm2_sq(&out);
        }
        let mean_sq = sum / trials as f64;
        assert!(
            mean_sq <= k * tensor::norm2_sq(&p) * 1.05,
            "E||Q||^2 = {mean_sq} vs bound {}",
            k * tensor::norm2_sq(&p)
        );
    }

    #[test]
    fn terngrad_values_are_ternary() {
        let mut rng = Pcg64::seeded(2);
        let mut p = vec![0.0f32; 64];
        rng.fill_normal(&mut p, 0.0, 1.0);
        let m = tensor::norm_inf(&p) as f32;
        let out = TernGrad.compress_vec(&p, &mut rng);
        for v in &out {
            assert!(*v == 0.0 || (v.abs() - m).abs() < 1e-6);
        }
    }

    #[test]
    fn scaled_unbiased_is_contractive() {
        // Remark 5 / B.5: ||U(x)/k - x||^2 <= (1 - 1/k) ||x||^2 in
        // expectation.
        let mut rng = Pcg64::seeded(3);
        let mut p = vec![0.0f32; 128];
        rng.fill_normal(&mut p, 0.0, 1.0);
        let q = Qsgd::new(2);
        let k = q.expansion(p.len());
        let c = ScaledUnbiased::new(Box::new(Qsgd::new(2)), k);
        let trials = 2000;
        let mut err = 0.0f64;
        for t in 0..trials {
            let out = c.compress_vec(&p, &mut Pcg64::seeded(t));
            let mut e = 0.0f64;
            for (o, x) in out.iter().zip(&p) {
                e += (*o as f64 - *x as f64).powi(2);
            }
            err += e / trials as f64;
        }
        let bound = (1.0 - 1.0 / k) * tensor::norm2_sq(&p);
        assert!(err <= bound * 1.05, "E err {err} vs bound {bound}");
    }

    #[test]
    fn wire_bits_reasonable() {
        assert_eq!(TernGrad.wire_bits(100), 232);
        // s = 4: worst coordinate = γ(5) (5 bits) + sign = 6 bits; header
        // is norm (32) + level count (8).
        let q = Qsgd::new(4);
        assert_eq!(q.wire_bits(100), 6 * 100 + 40);
        // s = 1: worst coordinate = γ(2) (3 bits) + sign = 4 bits
        assert_eq!(Qsgd::new(1).wire_bits(100), 4 * 100 + 40);
    }

    /// The trait-level estimate upper-bounds every actual Elias-packed
    /// frame (the exact size is data-dependent and always smaller on
    /// non-degenerate inputs).
    #[test]
    fn wire_bits_bounds_actual_frames() {
        use crate::compress::wire;
        let mut rng = Pcg64::seeded(8);
        let mut p = vec![0.0f32; 4096];
        rng.fill_normal(&mut p, 0.0, 1.0);
        for s in [1u32, 4, 16] {
            let q = Qsgd::new(s);
            let v = q.compress_vec(&p, &mut rng);
            let norm = tensor::norm2(&p) as f32;
            let e = wire::encode_qsgd(&v, norm, s);
            assert!(
                e.bits <= q.wire_bits(p.len()),
                "s={s}: frame {} bits exceeds bound {}",
                e.bits,
                q.wire_bits(p.len())
            );
        }
    }
}
