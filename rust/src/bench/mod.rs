//! A criterion-style micro/macro benchmark harness.
//!
//! criterion is unavailable offline; this harness provides what the paper's
//! benches need: warmup, adaptive iteration counts targeting a measurement
//! budget, mean/std/median/min over samples, throughput reporting
//! (elements/sec and bytes/sec), and a `--quick` mode for CI. Benches are
//! `harness = false` binaries that build a [`Bench`] and call
//! [`Bench::finish`].

use crate::util::stats;
use crate::util::timer::{fmt_duration, fmt_rate, Timer};
use std::time::Duration;

/// One benchmark group's settings.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    /// Target wall-clock per measurement phase.
    pub measure_time: Duration,
    /// Target wall-clock for warmup.
    pub warmup_time: Duration,
    /// Number of samples (each sample = `iters` runs).
    pub samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        if quick_mode() {
            BenchConfig {
                measure_time: Duration::from_millis(200),
                warmup_time: Duration::from_millis(50),
                samples: 10,
            }
        } else {
            BenchConfig {
                measure_time: Duration::from_secs(2),
                warmup_time: Duration::from_millis(300),
                samples: 20,
            }
        }
    }
}

/// `--quick` flag or `BENCH_QUICK=1`: short measurement windows.
pub fn quick_mode() -> bool {
    std::env::args().any(|a| a == "--quick" || a == "--test")
        || std::env::var("BENCH_QUICK").map_or(false, |v| v == "1")
}

/// Result of a single benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub mean: Duration,
    pub std: Duration,
    pub median: Duration,
    pub min: Duration,
    pub iters_per_sample: u64,
    /// Optional element count per iteration for throughput reporting.
    pub elements: Option<u64>,
    pub bytes: Option<u64>,
}

impl BenchResult {
    pub fn elements_per_sec(&self) -> Option<f64> {
        self.elements
            .map(|n| n as f64 / self.mean.as_secs_f64())
    }

    pub fn bytes_per_sec(&self) -> Option<f64> {
        self.bytes.map(|n| n as f64 / self.mean.as_secs_f64())
    }
}

/// A named group of benchmark cases, printed as a table on `finish`.
pub struct Bench {
    group: String,
    cfg: BenchConfig,
    results: Vec<BenchResult>,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        let cfg = BenchConfig::default();
        println!("\n== bench group: {group} ==");
        Bench {
            group: group.to_string(),
            cfg,
            results: Vec::new(),
        }
    }

    pub fn with_config(group: &str, cfg: BenchConfig) -> Self {
        println!("\n== bench group: {group} ==");
        Bench {
            group: group.to_string(),
            cfg,
            results: Vec::new(),
        }
    }

    /// Benchmark `f`, which performs ONE iteration of the workload.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> &BenchResult {
        self.bench_with_meta(name, None, None, &mut f)
    }

    /// Benchmark with an element count (per iteration) for throughput.
    pub fn bench_elems(&mut self, name: &str, elements: u64, mut f: impl FnMut()) -> &BenchResult {
        self.bench_with_meta(name, Some(elements), None, &mut f)
    }

    /// Benchmark with a byte count (per iteration) for bandwidth.
    pub fn bench_bytes(&mut self, name: &str, bytes: u64, mut f: impl FnMut()) -> &BenchResult {
        self.bench_with_meta(name, None, Some(bytes), &mut f)
    }

    fn bench_with_meta(
        &mut self,
        name: &str,
        elements: Option<u64>,
        bytes: Option<u64>,
        f: &mut dyn FnMut(),
    ) -> &BenchResult {
        // Warmup + calibrate iterations per sample.
        let mut iters: u64 = 1;
        let warmup = Timer::start();
        let mut one_iter = f64::INFINITY;
        loop {
            let t = Timer::start();
            for _ in 0..iters {
                f();
            }
            let per = t.elapsed_secs() / iters as f64;
            one_iter = one_iter.min(per.max(1e-9));
            if warmup.elapsed() >= self.cfg.warmup_time {
                break;
            }
            iters = (iters * 2).min(1 << 24);
        }
        let per_sample = self.cfg.measure_time.as_secs_f64() / self.cfg.samples as f64;
        let iters = ((per_sample / one_iter).ceil() as u64).clamp(1, 1 << 26);

        let mut samples_ns: Vec<f64> = Vec::with_capacity(self.cfg.samples);
        for _ in 0..self.cfg.samples {
            let t = Timer::start();
            for _ in 0..iters {
                f();
            }
            samples_ns.push(t.elapsed().as_nanos() as f64 / iters as f64);
        }

        let mean = stats::mean(&samples_ns);
        let result = BenchResult {
            name: name.to_string(),
            mean: Duration::from_nanos(mean as u64),
            std: Duration::from_nanos(stats::std(&samples_ns) as u64),
            median: Duration::from_nanos(stats::median(&samples_ns) as u64),
            min: Duration::from_nanos(
                samples_ns.iter().cloned().fold(f64::INFINITY, f64::min) as u64,
            ),
            iters_per_sample: iters,
            elements,
            bytes,
        };
        let mut line = format!(
            "  {:<42} mean {:>10}  median {:>10}  min {:>10}  (±{})",
            result.name,
            fmt_duration(result.mean),
            fmt_duration(result.median),
            fmt_duration(result.min),
            fmt_duration(result.std),
        );
        if let Some(eps) = result.elements_per_sec() {
            line.push_str(&format!("  {}", fmt_rate(eps)));
        }
        if let Some(bps) = result.bytes_per_sec() {
            line.push_str(&format!(
                "  {}/s",
                crate::util::timer::fmt_bytes(bps)
            ));
        }
        println!("{line}");
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Print a closing line. Returns the collected results for programmatic
    /// comparison (used by the regression checks in benches).
    pub fn finish(self) -> Vec<BenchResult> {
        println!("== end group: {} ({} cases) ==", self.group, self.results.len());
        self.results
    }
}

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let cfg = BenchConfig {
            measure_time: Duration::from_millis(20),
            warmup_time: Duration::from_millis(2),
            samples: 3,
        };
        let mut b = Bench::with_config("test", cfg);
        let mut acc = 0u64;
        let r = b.bench_elems("noop-ish", 100, || {
            for i in 0..100u64 {
                acc = black_box(acc.wrapping_add(i));
            }
        });
        assert!(r.mean.as_nanos() > 0);
        assert!(r.elements_per_sec().unwrap() > 0.0);
        let results = b.finish();
        assert_eq!(results.len(), 1);
    }

    #[test]
    fn quick_mode_env() {
        // Just exercise the path; value depends on environment.
        let _ = quick_mode();
    }
}
