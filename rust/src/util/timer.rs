//! Wall-clock timing helpers for the bench harness and coordinator metrics.

use std::time::{Duration, Instant};

/// A simple scoped timer.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    // detlint: profiling — this whole module is wall-clock measurement by
    // design; sim-time code uses net::simclock instead
    pub fn start() -> Self {
        Timer {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    // detlint: profiling
    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Format a duration in adaptive human units (ns/µs/ms/s).
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

/// Format a throughput (items/sec) adaptively.
pub fn fmt_rate(per_sec: f64) -> String {
    if per_sec >= 1e9 {
        format!("{:.2}G/s", per_sec / 1e9)
    } else if per_sec >= 1e6 {
        format!("{:.2}M/s", per_sec / 1e6)
    } else if per_sec >= 1e3 {
        format!("{:.2}k/s", per_sec / 1e3)
    } else {
        format!("{per_sec:.2}/s")
    }
}

/// Format a byte count.
pub fn fmt_bytes(b: f64) -> String {
    if b >= 1024.0 * 1024.0 * 1024.0 {
        format!("{:.2}GiB", b / (1024.0 * 1024.0 * 1024.0))
    } else if b >= 1024.0 * 1024.0 {
        format!("{:.2}MiB", b / (1024.0 * 1024.0))
    } else if b >= 1024.0 {
        format!("{:.2}KiB", b / 1024.0)
    } else {
        format!("{b:.0}B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats() {
        assert_eq!(fmt_duration(Duration::from_nanos(500)), "500ns");
        assert_eq!(fmt_duration(Duration::from_micros(1500)), "1.50ms");
        assert_eq!(fmt_rate(2_000_000.0), "2.00M/s");
        assert_eq!(fmt_bytes(2048.0), "2.00KiB");
    }

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(1));
        assert!(t.elapsed_secs() > 0.0);
    }
}
