//! Deterministic pseudo-random number generation.
//!
//! The crates.io `rand` stack is unavailable in this offline build, so we
//! implement PCG-XSH-RR-64/32 (O'Neill 2014) plus the distributions the
//! experiments need (uniform, normal via Box–Muller, categorical,
//! Fisher–Yates shuffle). Every experiment takes an explicit seed so results
//! are exactly reproducible.

/// PCG-XSH-RR 64/32 generator. 64-bit state, 32-bit output; we compose two
/// outputs for `next_u64`.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
    /// Cached second normal variate from Box–Muller.
    spare_normal: Option<f64>,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg64 {
    /// Create a generator from a seed and stream id. Different streams with
    /// the same seed are independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: (stream << 1) | 1,
            spare_normal: None,
        };
        rng.state = rng.inc.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    /// Derive an independent child generator (used to give each worker its
    /// own stream).
    pub fn split(&mut self, tag: u64) -> Pcg64 {
        let seed = self.next_u64();
        Pcg64::new(seed, tag.wrapping_mul(0x9e3779b97f4a7c15) | 1)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random bits / 2^53.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Uses rejection sampling to avoid modulo
    /// bias.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            let u2 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fill a slice with N(mean, std^2) samples (f32).
    pub fn fill_normal(&mut self, out: &mut [f32], mean: f64, std: f64) {
        for v in out.iter_mut() {
            *v = self.normal_ms(mean, std) as f32;
        }
    }

    /// Fill a slice with U[lo, hi) samples (f32).
    pub fn fill_uniform(&mut self, out: &mut [f32], lo: f64, hi: f64) {
        for v in out.iter_mut() {
            *v = self.uniform_in(lo, hi) as f32;
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Random sign in {-1.0, +1.0}.
    #[inline]
    pub fn sign(&mut self) -> f64 {
        if self.next_u32() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical with zero mass");
        let mut u = self.uniform() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx
    }

    /// Sample `k` distinct indices from 0..n (k <= n), unordered.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Floyd's algorithm.
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            if chosen.insert(t) {
                out.push(t);
            } else {
                chosen.insert(j);
                out.push(j);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg64::seeded(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Pcg64::seeded(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seeded(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg64::seeded(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seeded(9);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::seeded(13);
        for _ in 0..100 {
            let s = r.sample_indices(50, 10);
            assert_eq!(s.len(), 10);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), 10);
            assert!(s.iter().all(|&i| i < 50));
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Pcg64::seeded(21);
        let w = [1.0, 3.0];
        let n = 50_000;
        let ones = (0..n).filter(|_| r.categorical(&w) == 1).count();
        let frac = ones as f64 / n as f64;
        assert!((frac - 0.75).abs() < 0.01, "frac={frac}");
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Pcg64::seeded(1);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
