//! A minimal JSON parser and writer.
//!
//! serde/serde_json are not available in this offline build; the artifact
//! manifest (written by `python/compile/aot.py`) and the experiment result
//! files need JSON, so we implement the subset of RFC 8259 we use: objects,
//! arrays, strings (with escapes), numbers, booleans, null. Numbers are
//! parsed as f64 (the manifest only contains integers that fit exactly).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Path lookup: `j.at(&["configs", "0", "d"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for seg in path {
            cur = match cur {
                Json::Obj(m) => m.get(*seg)?,
                Json::Arr(v) => v.get(seg.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    /// Serialize to a compact JSON string.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // multi-byte UTF-8: copy the remaining continuation bytes
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump().ok_or_else(|| self.err("truncated utf8"))?;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

/// Builder helpers for constructing JSON values ergonomically.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(text: &str) -> Json {
    Json::Str(text.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_doc() {
        let text = r#"{"version": 1, "configs": [{"name": "tiny", "d": 30336,
            "artifacts": [{"file": "a.hlo.txt", "inputs": [{"shape": [30336], "dtype": "f32"}]}]}]}"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.at(&["version"]).unwrap().as_usize(), Some(1));
        assert_eq!(
            j.at(&["configs", "0", "name"]).unwrap().as_str(),
            Some("tiny")
        );
        assert_eq!(j.at(&["configs", "0", "d"]).unwrap().as_usize(), Some(30336));
        assert_eq!(
            j.at(&["configs", "0", "artifacts", "0", "inputs", "0", "shape", "0"])
                .unwrap()
                .as_usize(),
            Some(30336)
        );
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"a":[1,2.5,-3],"b":"hi\nthere","c":true,"d":null,"e":{"x":1e-3}}"#;
        let j = Json::parse(text).unwrap();
        let again = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""a\"b\\cA\n""#).unwrap();
        assert_eq!(j.as_str(), Some("a\"b\\cA\n"));
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"héllo → ok\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo → ok"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}extra").is_err());
        assert!(Json::parse("nope").is_err());
    }

    #[test]
    fn builder_writes_valid_json() {
        let j = obj(vec![
            ("name", s("run1")),
            ("loss", num(1.25)),
            ("steps", arr(vec![num(1.0), num(2.0)])),
        ]);
        let text = j.to_string_compact();
        assert_eq!(Json::parse(&text).unwrap(), j);
        assert!(text.contains("\"loss\":1.25"));
    }
}
