//! A counting global allocator for allocation-regression tests and the
//! fabric benchmark: wraps the system allocator and counts every
//! allocation event (alloc / alloc_zeroed / realloc) process-wide, across
//! all threads.
//!
//! The library never installs it; each binary that wants counting opts in:
//!
//! ```ignore
//! use ef_sgd::util::alloc_count::{self, CountingAllocator};
//!
//! #[global_allocator]
//! static ALLOC: CountingAllocator = CountingAllocator;
//!
//! let before = alloc_count::allocs();
//! hot_path();
//! assert_eq!(alloc_count::allocs() - before, 0);
//! ```
//!
//! Deallocations are deliberately not counted: the steady-state contract
//! of docs/PERF.md is "no new allocations per round", and a path that
//! allocates nothing cannot free anything it allocated either.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

/// System-allocator wrapper that counts allocation events.
pub struct CountingAllocator;

// SAFETY: every method delegates verbatim to `System`, which upholds the
// GlobalAlloc contract; the atomic counters have no effect on layout,
// aliasing, or the returned pointers.
unsafe impl GlobalAlloc for CountingAllocator {
    // SAFETY: caller upholds the GlobalAlloc contract (non-zero-sized
    // `layout`); delegated unchanged to `System.alloc`.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    // SAFETY: caller guarantees `ptr` came from this allocator with this
    // `layout`; delegated unchanged to `System.dealloc`.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    // SAFETY: same contract as `alloc`; delegated unchanged to
    // `System.alloc_zeroed`.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    // SAFETY: caller guarantees `ptr`/`layout` match a live allocation from
    // this allocator and `new_size` is non-zero; delegated unchanged to
    // `System.realloc`.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

/// Allocation events so far (allocs + zeroed allocs + reallocs, all
/// threads). Only meaningful in a binary that installed
/// [`CountingAllocator`] as its `#[global_allocator]`; otherwise 0.
pub fn allocs() -> u64 {
    ALLOCS.load(Ordering::SeqCst)
}

/// Total bytes requested by the counted allocation events.
pub fn alloc_bytes() -> u64 {
    ALLOC_BYTES.load(Ordering::SeqCst)
}
