//! Small shared utilities: RNG, statistics, timing, JSON.
pub mod json;
pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Pcg64;
