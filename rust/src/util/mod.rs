//! Small shared utilities: RNG, statistics, timing, JSON, allocation
//! counting.
pub mod alloc_count;
pub mod json;
pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Pcg64;
