//! Summary statistics used by the experiment drivers and the bench harness.

/// Streaming mean/variance (Welford) with min/max tracking.
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn extend(&mut self, xs: &[f64]) {
        for &x in xs {
            self.push(x);
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator); 0 for n < 2.
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation of a slice.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Quantile with linear interpolation; q in [0,1].
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "quantile of empty slice");
    assert!((0.0..=1.0).contains(&q));
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = pos - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// Index of the minimum value (first on ties); None for empty.
pub fn argmin(xs: &[f64]) -> Option<usize> {
    xs.iter()
        .enumerate()
        .filter(|(_, v)| !v.is_nan())
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
}

/// Index of the maximum value (first on ties); None for empty.
pub fn argmax(xs: &[f64]) -> Option<usize> {
    xs.iter()
        .enumerate()
        .filter(|(_, v)| !v.is_nan())
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut s = OnlineStats::new();
        s.extend(&xs);
        assert!((s.mean() - mean(&xs)).abs() < 1e-12);
        assert!((s.std() - std(&xs)).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 10.0);
        assert_eq!(s.count(), 5);
    }

    #[test]
    fn quantiles() {
        let xs = [3.0, 1.0, 2.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn argminmax() {
        let xs = [3.0, 1.0, 2.0];
        assert_eq!(argmin(&xs), Some(1));
        assert_eq!(argmax(&xs), Some(0));
        assert_eq!(argmin(&[]), None);
    }

    #[test]
    fn var_single_element_zero() {
        let mut s = OnlineStats::new();
        s.push(5.0);
        assert_eq!(s.var(), 0.0);
    }
}
