//! Dense linear algebra for the generalization experiments: Cholesky
//! factorization, triangular solves, the minimum-norm least-squares solution
//! (max-margin dual, Lemma 9) and projection onto the span of a set of
//! vectors (Theorem IV's distance-to-gradient-span metric).

use crate::tensor::Matrix;

#[derive(Debug)]
pub enum LinalgError {
    NotPositiveDefinite(usize, f64),
    Shape(String),
}

impl std::fmt::Display for LinalgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LinalgError::NotPositiveDefinite(pivot, value) => {
                write!(f, "matrix not positive definite at pivot {pivot} (value {value})")
            }
            LinalgError::Shape(msg) => write!(f, "dimension mismatch: {msg}"),
        }
    }
}

impl std::error::Error for LinalgError {}

/// Cholesky factorization A = L L^T for symmetric positive definite A
/// (computed in f64 internally for stability). Returns lower-triangular L.
pub fn cholesky(a: &Matrix) -> Result<Matrix, LinalgError> {
    if a.rows != a.cols {
        return Err(LinalgError::Shape(format!("{}x{} not square", a.rows, a.cols)));
    }
    let n = a.rows;
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.at(i, j) as f64;
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if sum <= 0.0 {
                    return Err(LinalgError::NotPositiveDefinite(i, sum));
                }
                l[i * n + j] = sum.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    Ok(Matrix::from_vec(
        n,
        n,
        l.into_iter().map(|v| v as f32).collect(),
    ))
}

/// Solve L y = b for lower-triangular L.
pub fn solve_lower(l: &Matrix, b: &[f32]) -> Vec<f32> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    let mut y = vec![0.0f64; n];
    for i in 0..n {
        let mut sum = b[i] as f64;
        for k in 0..i {
            sum -= l.at(i, k) as f64 * y[k];
        }
        y[i] = sum / l.at(i, i) as f64;
    }
    y.into_iter().map(|v| v as f32).collect()
}

/// Solve L^T x = y for lower-triangular L.
pub fn solve_upper_t(l: &Matrix, y: &[f32]) -> Vec<f32> {
    let n = l.rows;
    assert_eq!(y.len(), n);
    let mut x = vec![0.0f64; n];
    for i in (0..n).rev() {
        let mut sum = y[i] as f64;
        for k in (i + 1)..n {
            sum -= l.at(k, i) as f64 * x[k];
        }
        x[i] = sum / l.at(i, i) as f64;
    }
    x.into_iter().map(|v| v as f32).collect()
}

/// Solve the SPD system A x = b via Cholesky.
pub fn solve_spd(a: &Matrix, b: &[f32]) -> Result<Vec<f32>, LinalgError> {
    let l = cholesky(a)?;
    Ok(solve_upper_t(&l, &solve_lower(&l, b)))
}

/// Minimum-norm solution of the under-determined system A x = y for
/// A in R^{n x d}, d > n, rank n:  x* = A^T (A A^T)^{-1} y.
/// This is the max-margin solution of the over-parameterized least-squares
/// problem (paper §5.1 / Lemma 9). A small ridge stabilizes near-singular
/// Gram matrices.
pub fn min_norm_solution(a: &Matrix, y: &[f32], ridge: f32) -> Result<Vec<f32>, LinalgError> {
    if a.rows != y.len() {
        return Err(LinalgError::Shape(format!(
            "A has {} rows but y has {}",
            a.rows,
            y.len()
        )));
    }
    let mut gram = a.gram();
    for i in 0..gram.rows {
        *gram.at_mut(i, i) += ridge;
    }
    let alpha = solve_spd(&gram, y)?;
    Ok(a.matvec_t(&alpha))
}

/// Projection of x onto the row space of G (rows = spanning vectors):
/// P x = G^T (G G^T)^{-1} G x, computed via ridge-regularized Gram solve.
/// Used for Theorem IV's ||x_t - Pi_{G_t}(x_t)||.
pub fn project_onto_rowspace(g: &Matrix, x: &[f32], ridge: f32) -> Result<Vec<f32>, LinalgError> {
    if g.cols != x.len() {
        return Err(LinalgError::Shape(format!(
            "G has {} cols but x has {}",
            g.cols,
            x.len()
        )));
    }
    let gx = g.matvec(x);
    let mut gram = g.gram();
    for i in 0..gram.rows {
        *gram.at_mut(i, i) += ridge;
    }
    let alpha = solve_spd(&gram, &gx)?;
    Ok(g.matvec_t(&alpha))
}

/// Largest eigenvalue of A·Aᵀ via power iteration (used to pick stable
/// step sizes: for f = ‖Ax−y‖²/n, L = 2·λmax(AᵀA)/n = 2·λmax(AAᵀ)/n).
pub fn gram_lambda_max(a: &Matrix, iters: usize) -> f64 {
    let n = a.rows;
    let mut v = vec![1.0f32 / (n as f32).sqrt(); n];
    let mut lambda = 0.0f64;
    for _ in 0..iters {
        // w = A (A^T v)
        let atv = a.matvec_t(&v);
        let w = a.matvec(&atv);
        lambda = crate::tensor::norm2(&w);
        if lambda == 0.0 {
            return 0.0;
        }
        for (vi, wi) in v.iter_mut().zip(&w) {
            *vi = (*wi as f64 / lambda) as f32;
        }
    }
    lambda
}

/// Distance from x to the row space of G.
pub fn distance_to_rowspace(g: &Matrix, x: &[f32], ridge: f32) -> Result<f64, LinalgError> {
    let p = project_onto_rowspace(g, x, ridge)?;
    let mut diff = vec![0.0f32; x.len()];
    crate::tensor::sub(x, &p, &mut diff);
    Ok(crate::tensor::norm2(&diff))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor;
    use crate::util::Pcg64;

    fn spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::seeded(seed);
        let b = Matrix::randn(n, n, 1.0, &mut rng);
        let mut a = b.matmul(&b.transpose());
        for i in 0..n {
            *a.at_mut(i, i) += n as f32; // well-conditioned
        }
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = spd(8, 1);
        let l = cholesky(&a).unwrap();
        let rec = l.matmul(&l.transpose());
        for (x, y) in rec.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-2, "{x} vs {y}");
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Matrix::from_rows(vec![vec![1.0, 2.0], vec![2.0, 1.0]]); // eig -1
        assert!(matches!(
            cholesky(&a),
            Err(LinalgError::NotPositiveDefinite(..))
        ));
    }

    #[test]
    fn solve_spd_solves() {
        let a = spd(10, 3);
        let mut rng = Pcg64::seeded(4);
        let mut x_true = vec![0.0f32; 10];
        rng.fill_normal(&mut x_true, 0.0, 1.0);
        let b = a.matvec(&x_true);
        let x = solve_spd(&a, &b).unwrap();
        for (g, e) in x.iter().zip(&x_true) {
            assert!((g - e).abs() < 1e-3);
        }
    }

    #[test]
    fn min_norm_is_interpolating_and_in_rowspace() {
        let mut rng = Pcg64::seeded(5);
        let a = Matrix::randn(6, 30, 1.0, &mut rng);
        let y: Vec<f32> = (0..6).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let x = min_norm_solution(&a, &y, 1e-6).unwrap();
        // interpolates
        let pred = a.matvec(&x);
        for (p, t) in pred.iter().zip(&y) {
            assert!((p - t).abs() < 1e-3, "{p} vs {t}");
        }
        // lies in the row space: distance to rowspace ~ 0
        let dist = distance_to_rowspace(&a, &x, 1e-8).unwrap();
        assert!(dist < 1e-3, "dist={dist}");
    }

    #[test]
    fn min_norm_has_smallest_norm() {
        let mut rng = Pcg64::seeded(6);
        let a = Matrix::randn(4, 20, 1.0, &mut rng);
        let y = vec![1.0f32, -1.0, 1.0, 1.0];
        let x_min = min_norm_solution(&a, &y, 1e-8).unwrap();
        // Any other interpolating solution (min-norm + rowspace-orthogonal
        // perturbation) has strictly larger norm.
        for trial in 0..5 {
            let mut z = vec![0.0f32; 20];
            let mut rng2 = Pcg64::seeded(100 + trial);
            rng2.fill_normal(&mut z, 0.0, 1.0);
            // orthogonalize z against rows of a
            let proj = project_onto_rowspace(&a, &z, 1e-9).unwrap();
            tensor::sub_assign(&mut z, &proj);
            if tensor::norm2(&z) < 1e-6 {
                continue;
            }
            let mut other = x_min.clone();
            tensor::add_assign(&mut other, &z);
            // still interpolates
            let pred = a.matvec(&other);
            for (p, t) in pred.iter().zip(&y) {
                assert!((p - t).abs() < 1e-2);
            }
            assert!(tensor::norm2(&other) > tensor::norm2(&x_min));
        }
    }

    #[test]
    fn projection_is_idempotent_and_contractive() {
        let mut rng = Pcg64::seeded(8);
        let g = Matrix::randn(5, 40, 1.0, &mut rng);
        let mut x = vec![0.0f32; 40];
        rng.fill_normal(&mut x, 0.0, 1.0);
        let p1 = project_onto_rowspace(&g, &x, 1e-9).unwrap();
        let p2 = project_onto_rowspace(&g, &p1, 1e-9).unwrap();
        for (a, b) in p1.iter().zip(&p2) {
            assert!((a - b).abs() < 1e-3);
        }
        assert!(tensor::norm2(&p1) <= tensor::norm2(&x) * (1.0 + 1e-6));
    }

    #[test]
    fn distance_zero_for_vector_in_span() {
        let g = Matrix::from_rows(vec![vec![1.0, 0.0, 0.0], vec![0.0, 1.0, 0.0]]);
        let x = [3.0, -2.0, 0.0];
        assert!(distance_to_rowspace(&g, &x, 1e-10).unwrap() < 1e-4);
        let y = [0.0, 0.0, 5.0];
        assert!((distance_to_rowspace(&g, &y, 1e-10).unwrap() - 5.0).abs() < 1e-3);
    }
}
