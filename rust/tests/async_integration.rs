//! Integration: the bounded-staleness async engine end to end —
//! sync-equivalence at the degenerate setting, bit-determinism across
//! thread counts under heavy-tail stragglers, and the staleness sweep's
//! EF-robustness claim.

use ef_sgd::config::CompressorKind;
use ef_sgd::coordinator::async_driver::AsyncTrainDriver;
use ef_sgd::coordinator::driver::{DriverConfig, TrainDriver};
use ef_sgd::coordinator::worker::{ObjectiveSource, Worker, WorkerMode};
use ef_sgd::coordinator::LrSchedule;
use ef_sgd::experiments::{staleness, ExpContext};
use ef_sgd::metrics::Recorder;
use ef_sgd::model::toy::SparseNoiseQuadratic;
use ef_sgd::net::{MessageKind, StragglerModel, StragglerSchedule};
use ef_sgd::util::Pcg64;

fn quadratic_workers(n: usize, d: usize, kind: CompressorKind) -> Vec<Worker> {
    (0..n)
        .map(|id| {
            Worker::new(
                id,
                Box::new(ObjectiveSource::new(
                    SparseNoiseQuadratic::new(d, 0.5),
                    Pcg64::new(17, 100 + id as u64),
                )),
                WorkerMode::ErrorFeedback,
                kind,
                4,
                4,
                Pcg64::new(18, id as u64),
            )
        })
        .collect()
}

fn lognormal(sigma: f64, seed: u64) -> StragglerSchedule {
    StragglerSchedule::new(1e-3, StragglerModel::LogNormal { sigma }, seed)
}

/// `--quorum n --max-staleness 0` must reproduce the synchronous driver
/// byte for byte — same theta, same EF residuals, same corrected
/// gradients — even under heavy-tail stragglers (they then only shift
/// virtual time, never the fold schedule).
#[test]
fn staleness_zero_matches_sync_driver() {
    for kind in [CompressorKind::ScaledSign, CompressorKind::Qsgd] {
        let d = 48;
        let steps = 20;
        let n = 4;
        let cfg = || DriverConfig {
            steps,
            schedule: LrSchedule::new(0.05, steps, vec![0.5]),
            straggler: lognormal(1.0, 5),
            ..Default::default()
        };
        let mut sync = TrainDriver::new(cfg(), quadratic_workers(n, d, kind), vec![1.0f32; d]);
        let mut rec = Recorder::new();
        for _ in 0..steps {
            sync.round(&mut rec);
        }
        let mut asynch = AsyncTrainDriver::new(
            cfg(),
            n,
            0,
            quadratic_workers(n, d, kind),
            vec![1.0f32; d],
        );
        let mut rec2 = Recorder::new();
        for _ in 0..steps {
            asynch.step_round(&mut rec2);
        }
        let a = sync.snapshot();
        let b = asynch.snapshot();
        // byte-identical snapshot: exact f32 equality on every tensor
        assert_eq!(a.round, b.round, "{kind:?}");
        assert_eq!(a.theta, b.theta, "{kind:?}");
        assert_eq!(a.worker_errors, b.worker_errors, "{kind:?}");
        assert_eq!(a.worker_corrected, b.worker_corrected, "{kind:?}");
        // and the wire traffic is the same, bit for bit
        let ta = sync.traffic();
        let tb = asynch.traffic();
        assert_eq!(ta.total_bits, tb.total_bits, "{kind:?}");
        assert_eq!(
            ta.bits_of_kind(MessageKind::GradPush),
            tb.bits_of_kind(MessageKind::GradPush)
        );
        assert_eq!(asynch.staleness().stale_frames, 0);
    }
}

fn async_run(threads: usize) -> (Vec<f32>, Vec<Vec<f32>>, Vec<Vec<f32>>, u64, f64) {
    let d = 64;
    let steps = 40;
    let n = 6;
    let cfg = DriverConfig {
        steps,
        schedule: LrSchedule::constant(0.05),
        straggler: lognormal(1.5, 11),
        threads,
        ..Default::default()
    };
    let mut driver = AsyncTrainDriver::new(
        cfg,
        3,
        2,
        quadratic_workers(n, d, CompressorKind::ScaledSign),
        vec![1.0f32; d],
    );
    let mut rec = Recorder::new();
    for _ in 0..steps {
        driver.step_round(&mut rec);
    }
    let snap = driver.snapshot();
    let bits = driver.traffic().total_bits;
    let sim = driver.sim_time_s();
    (
        snap.theta,
        snap.worker_errors,
        snap.worker_corrected,
        bits,
        sim,
    )
}

/// The async engine is bit-deterministic for any `--threads` value: the
/// event order is a pure function of the straggler schedule and link
/// model, so a fixed seed yields the identical final theta, EF states,
/// wire-bit totals, AND virtual-clock time at 1 and 4 threads — even with
/// lognormal stragglers driving a partial quorum.
#[test]
fn async_quorum_is_bit_deterministic_across_threads() {
    let (theta1, errs1, corr1, bits1, sim1) = async_run(1);
    let (theta4, errs4, corr4, bits4, sim4) = async_run(4);
    assert_eq!(theta1, theta4, "theta differs across thread counts");
    assert_eq!(errs1, errs4, "EF residuals differ across thread counts");
    assert_eq!(corr1, corr4, "corrected grads differ across thread counts");
    assert_eq!(bits1, bits4, "wire bits differ across thread counts");
    assert_eq!(sim1, sim4, "virtual time differs across thread counts");
}

/// The acceptance claim: across straggler severities, EF-SGD's final loss
/// degrades strictly less than plain SIGNSGD's (and stays far below it in
/// absolute terms) — the residual keeps late/dropped information, the
/// sign baseline loses it.
#[test]
fn staleness_sweep_ef_degrades_less_than_signsgd() {
    let result = staleness::staleness(&ExpContext::quick()).unwrap();
    let rec = &result.recorders[0].1;
    let series =
        |name: &str| -> Vec<f64> { rec.get(name).expect(name).values.clone() };
    let ef = series("final_ef_sign");
    let sign = series("final_signsgd");
    assert_eq!(ef.len(), staleness::SEVERITIES.len());
    assert_eq!(sign.len(), staleness::SEVERITIES.len());
    for (i, (e, s)) in ef.iter().zip(&sign).enumerate() {
        // EF lands far below plain sign at every severity (Theorem 1's
        // trap vs Theorem II's convergence): > 4x in loss
        assert!(e * 4.0 < *s, "severity #{i}: ef {e} not well below sign {s}");
    }
    // degradation versus the severity-0 baseline: strictly smaller for EF
    // at every positive severity
    for i in 1..ef.len() {
        let deg_ef = ef[i] - ef[0];
        let deg_sign = sign[i] - sign[0];
        assert!(
            deg_ef < deg_sign,
            "severity #{i}: EF degradation {deg_ef} not below signSGD's {deg_sign}"
        );
        // the sign baseline genuinely degrades (the sweep is not vacuous)
        assert!(deg_sign > 0.0, "severity #{i}: signSGD did not degrade");
    }
}

/// Under severe stragglers the bounded-staleness engine actually
/// exercises staleness, never exceeds its bound, and still descends.
#[test]
fn severe_stragglers_stay_within_bound_and_descend() {
    let d = 64;
    let steps = 50;
    let cfg = DriverConfig {
        steps,
        schedule: LrSchedule::constant(0.1),
        straggler: lognormal(2.0, 23),
        ..Default::default()
    };
    let out = AsyncTrainDriver::new(
        cfg,
        3,
        3,
        quadratic_workers(6, d, CompressorKind::ScaledSign),
        vec![1.0f32; d],
    )
    .run();
    assert_eq!(out.rounds, steps as u64);
    assert!(out.staleness.max_staleness_seen <= 3);
    assert!(out.staleness.stale_frames > 0, "sweep exercised no staleness");
    assert!(out.sim_time_s > 0.0);
    let losses = &out.recorder.get("train_loss").unwrap().values;
    assert!(
        losses.last().unwrap() < &(losses.first().unwrap() * 0.5),
        "no descent under stragglers"
    );
}
