//! Integration: the flight recorder and metrics registry end to end —
//! stripped-trace bit-determinism across thread counts, the Chrome
//! trace-event export's golden shape, the RunReport / Prometheus key
//! contract, and the EF-residual metric staying within Lemma 3's bound.

use ef_sgd::config::CompressorKind;
use ef_sgd::coordinator::async_driver::AsyncTrainDriver;
use ef_sgd::coordinator::driver::{DriverConfig, TrainDriver};
use ef_sgd::coordinator::worker::{ObjectiveSource, Worker, WorkerMode};
use ef_sgd::coordinator::{LrSchedule, TrainOutcome};
use ef_sgd::model::toy::SparseNoiseQuadratic;
use ef_sgd::net::{StragglerModel, StragglerSchedule};
use ef_sgd::obs::{self, RunMetrics, DEFAULT_RING_CAPACITY};
use ef_sgd::util::json::Json;
use ef_sgd::util::Pcg64;
use std::sync::Arc;

fn workers(n: usize, d: usize, noise: f64) -> Vec<Worker> {
    (0..n)
        .map(|id| {
            Worker::new(
                id,
                Box::new(ObjectiveSource::new(
                    SparseNoiseQuadratic::new(d, noise),
                    Pcg64::new(17, 100 + id as u64),
                )),
                WorkerMode::ErrorFeedback,
                CompressorKind::ScaledSign,
                4,
                4,
                Pcg64::new(18, id as u64),
            )
        })
        .collect()
}

fn traced_cfg(threads: usize, shards: usize, steps: usize) -> DriverConfig {
    DriverConfig {
        steps,
        schedule: LrSchedule::constant(0.05),
        straggler: StragglerSchedule::new(1e-3, StragglerModel::LogNormal { sigma: 1.0 }, 7),
        threads,
        shards,
        trace_capacity: DEFAULT_RING_CAPACITY,
        ..Default::default()
    }
}

fn stripped(outcome: &TrainOutcome) -> String {
    outcome
        .trace
        .as_ref()
        .expect("tracing was enabled")
        .to_chrome_json(false)
        .to_string_compact()
}

/// The determinism contract: within a fixed shard count, the stripped
/// (wall-clock-free) trace is byte-identical for any `--threads` value.
/// (Across shard counts the framing overhead differs — each shard message
/// carries its own header bits — so arrival timestamps legitimately move;
/// see docs/OBSERVABILITY.md.)
#[test]
fn stripped_trace_identical_across_threads() {
    for shards in [1usize, 4] {
        let traces: Vec<String> = [1usize, 4]
            .iter()
            .map(|&threads| {
                let out = TrainDriver::new(
                    traced_cfg(threads, shards, 12),
                    workers(4, 64, 0.5),
                    vec![1.0f32; 64],
                )
                .run();
                stripped(&out)
            })
            .collect();
        assert!(
            traces[0].contains("round_start"),
            "trace is missing round events"
        );
        assert!(traces[0].contains("frame_encoded"));
        assert_eq!(
            traces[0], traces[1],
            "shards={shards}: stripped trace differs between 1 and 4 threads"
        );
    }
}

/// Same contract for the bounded-staleness engine, where pool threads race
/// hardest: quorum folds, arrivals, and drops land in the same ring order
/// for any thread count.
#[test]
fn stripped_async_trace_identical_across_threads() {
    for shards in [1usize, 4] {
        let traces: Vec<String> = [1usize, 4]
            .iter()
            .map(|&threads| {
                let out = AsyncTrainDriver::new(
                    traced_cfg(threads, shards, 15),
                    3,
                    2,
                    workers(6, 64, 0.5),
                    vec![1.0f32; 64],
                )
                .run();
                stripped(&out)
            })
            .collect();
        assert!(
            traces[0].contains("quorum_fold"),
            "async trace is missing fold events"
        );
        assert_eq!(
            traces[0], traces[1],
            "shards={shards}: stripped async trace differs between 1 and 4 threads"
        );
    }
}

/// Golden-shape test for the Chrome trace-event export: the JSON parses,
/// metadata names every track, instants ride the virtual timeline, and
/// driver-track round spans pair up RoundStart/AggregateDone.
#[test]
fn chrome_trace_shape_is_stable() {
    let steps = 8;
    let out = TrainDriver::new(
        traced_cfg(2, 2, steps),
        workers(3, 64, 0.5),
        vec![1.0f32; 64],
    )
    .run();
    let recorder = out.trace.as_ref().unwrap();
    let json = Json::parse(&recorder.to_chrome_json(false).to_string_compact()).unwrap();
    assert_eq!(json.at(&["displayTimeUnit"]).unwrap().as_str(), Some("ms"));
    let events = json.at(&["traceEvents"]).unwrap().as_arr().unwrap();
    // tracks: 3 workers + 2 shard leaders + driver
    assert_eq!(recorder.num_tracks(), 6);
    let phase = |e: &Json| e.at(&["ph"]).unwrap().as_str().unwrap().to_string();
    // metadata first: one process_name + one thread_name per track
    let metas: Vec<&Json> = events.iter().filter(|e| phase(e) == "M").collect();
    assert_eq!(metas.len(), 1 + recorder.num_tracks());
    assert_eq!(
        metas[0].at(&["args", "name"]).unwrap().as_str(),
        Some("ef-sgd simulated cluster")
    );
    assert!(
        events.iter().take(metas.len()).all(|e| phase(e) == "M"),
        "metadata must precede all events"
    );
    // every instant carries a round and a virtual timestamp
    let instants: Vec<&Json> = events.iter().filter(|e| phase(e) == "i").collect();
    assert!(!instants.is_empty());
    for e in &instants {
        assert!(e.at(&["ts"]).unwrap().as_f64().unwrap() >= 0.0);
        assert!(e.at(&["args", "round"]).is_some());
        assert_eq!(e.at(&["s"]).unwrap().as_str(), Some("t"));
    }
    // one complete span per finished round, on the driver track
    let spans: Vec<&Json> = events.iter().filter(|e| phase(e) == "X").collect();
    assert_eq!(spans.len(), steps);
    for (r, e) in spans.iter().enumerate() {
        assert_eq!(
            e.at(&["name"]).unwrap().as_str(),
            Some(format!("round {r}").as_str())
        );
        assert!(e.at(&["dur"]).unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(
            e.at(&["tid"]).unwrap().as_f64(),
            Some(recorder.driver_track() as f64)
        );
    }
    // the stripped export never leaks wall-clock stamps
    assert!(!recorder
        .to_chrome_json(false)
        .to_string_compact()
        .contains("wall_ns"));
}

/// Lemma 3 (paper): with a δ-approximate compressor and step size γ, the
/// EF residual satisfies E‖e_t‖² ≤ 4(1−δ)γ²σ²/δ². On the noiseless
/// quadratic with scaled-sign compression (empirically δ ≥ 0.25 here),
/// the per-worker residual gauges must sit inside a conservative version
/// of that bound instead of drifting.
#[test]
fn ef_residual_metric_bounded_per_lemma3() {
    let d = 64;
    let n = 4;
    let steps = 200;
    let gamma = 0.05;
    let metrics = Arc::new(RunMetrics::new(n));
    let cfg = DriverConfig {
        steps,
        schedule: LrSchedule::constant(gamma),
        metrics: Some(metrics.clone()),
        ..Default::default()
    };
    let out = TrainDriver::new(cfg, workers(n, d, 0.0), vec![1.0f32; d]).run();
    assert_eq!(out.rounds, steps as u64);
    // conservative constants: δ_lb = 0.25 (measured scaled-sign quality on
    // this objective is far higher), σ² bounded by the initial gradient
    // second moment ‖∇f(θ₀)‖² ≤ d on the unit quadratic
    let delta_lb = 0.25;
    let sigma_sq = d as f64;
    let bound_sq = 4.0 * (1.0 - delta_lb) * gamma * gamma * sigma_sq / (delta_lb * delta_lb);
    for w in 0..n {
        let norm = metrics.residual_norm(w);
        assert!(norm.is_finite() && norm >= 0.0);
        assert!(
            norm * norm <= bound_sq,
            "worker {w}: ‖e‖² = {} exceeds Lemma 3 bound {bound_sq}",
            norm * norm
        );
    }
    // the histogram of milli-norms agrees: the top occupied bucket's lower
    // edge stays within the bound too (upper edges over-count by 2x)
    let hist = metrics.residual_hist();
    assert_eq!(hist.count, (steps * n) as u64);
    let top = hist.max_bucket().expect("residuals were observed");
    if top > 0 {
        let lower_edge_milli = (1u64 << (top - 1)) as f64;
        let lower_norm = lower_edge_milli / 1e3;
        assert!(
            lower_norm * lower_norm <= bound_sq,
            "hist top bucket {top} lower edge {lower_norm} breaks the bound"
        );
    }
}

/// The RunReport JSON and the Prometheus text carry the documented keys.
#[test]
fn run_report_and_prometheus_have_expected_keys() {
    let n = 4;
    let metrics = Arc::new(RunMetrics::new(n));
    let cfg = DriverConfig {
        steps: 10,
        schedule: LrSchedule::constant(0.05),
        straggler: StragglerSchedule::new(1e-3, StragglerModel::LogNormal { sigma: 1.0 }, 7),
        metrics: Some(metrics.clone()),
        ..Default::default()
    };
    let out = AsyncTrainDriver::new(cfg, 3, 2, workers(n, 64, 0.5), vec![1.0f32; 64]).run();
    let report = obs::run_report(&out, Some(&metrics));
    let parsed = Json::parse(&report.to_string_compact()).unwrap();
    for key in ["run", "traffic", "leader", "staleness", "metrics"] {
        assert!(parsed.at(&[key]).is_some(), "report is missing '{key}'");
    }
    assert_eq!(parsed.at(&["run", "rounds"]).unwrap().as_f64(), Some(10.0));
    assert!(parsed.at(&["traffic", "dropped_frames"]).is_some());
    assert!(parsed
        .at(&["traffic", "per_kind_bits", "grad_push"])
        .is_some());
    assert!(parsed
        .at(&["metrics", "counters", "ef_frames_total"])
        .is_some());
    let prom = metrics.to_prometheus();
    assert!(prom.contains("# TYPE ef_frames_total counter"));
    assert!(prom.contains("ef_frame_bits_bucket"));
    assert!(prom.contains("le=\"+Inf\""));
    assert!(prom.contains("ef_residual_norm{worker=\"0\"}"));
    assert!(metrics.frames_total() > 0);
}
